#!/usr/bin/env python3
"""Validate a flight-recorder trace against the Chrome trace-event schema.

Checks the JSON that `gzccl ... --trace out.json` emits (the object
form, `{"traceEvents": [...]}`, as loaded by ui.perfetto.dev and
chrome://tracing):

  * the file parses and has a `traceEvents` list plus `displayTimeUnit`;
  * every event's `ph` is one of "X" (complete span), "i" (instant) or
    "M" (metadata) — the exporter never emits B/E pairs, so any other
    phase is a bug, and balance is structural;
  * every "X" event has finite `ts >= 0` and `dur >= 0` and names a
    `pid`/`tid` track;
  * on the host lane (tid 0) of every pid, span start times are
    monotone in file order (the recorder appends host activity in
    virtual-time order; a backwards jump means a clock or run-offset
    bug — other lanes record queue-entry times that legitimately
    interleave);
  * the host lane of every pid nests like a call stack: spans sorted
    by (start, -dur) are each fully contained in — never partially
    overlapping — the enclosing open span.

Exits non-zero with a per-violation report; prints a summary on
success. Usage: trace_validate.py TRACE.json
"""

import json
import math
import sys

ALLOWED_PH = {"X", "i", "M"}
HOST_TID = 0


def err(errors, i, ev, msg):
    name = ev.get("name", "?") if isinstance(ev, dict) else "?"
    errors.append(f"event {i} ({name!r}): {msg}")


def finite_nonneg(v):
    return isinstance(v, (int, float)) and math.isfinite(v) and v >= 0


def validate(path):
    errors = []
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, dict):
        return ["top level is not a JSON object"], {}
    events = data.get("traceEvents")
    if not isinstance(events, list):
        return ["missing traceEvents list"], {}
    if "displayTimeUnit" not in data:
        errors.append("missing displayTimeUnit")

    counts = {"X": 0, "i": 0, "M": 0}
    spans_by_lane = {}  # (pid, tid) -> [(ts, dur, name)] in file order
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            err(errors, i, ev, "event is not an object")
            continue
        ph = ev.get("ph")
        if ph not in ALLOWED_PH:
            err(errors, i, ev, f"phase {ph!r} not in {sorted(ALLOWED_PH)} "
                "(B/E pairs are never emitted)")
            continue
        counts[ph] += 1
        if ph == "M":
            continue
        if not finite_nonneg(ev.get("ts")):
            err(errors, i, ev, f"ts {ev.get('ts')!r} is not finite and >= 0")
        if ph == "X":
            if not finite_nonneg(ev.get("dur")):
                err(errors, i, ev,
                    f"dur {ev.get('dur')!r} is not finite and >= 0")
            if not isinstance(ev.get("pid"), int) or not isinstance(
                    ev.get("tid"), int):
                err(errors, i, ev, "complete event without integer pid/tid")
            elif finite_nonneg(ev.get("ts")) and finite_nonneg(ev.get("dur")):
                spans_by_lane.setdefault((ev["pid"], ev["tid"]), []).append(
                    (ev["ts"], ev["dur"], ev.get("name", "?")))

    for (pid, tid), spans in sorted(spans_by_lane.items()):
        if tid != HOST_TID:
            continue
        # Monotone start times in file order on the host lane.
        for a, b in zip(spans, spans[1:]):
            if b[0] < a[0]:
                errors.append(
                    f"pid {pid} host lane: span {b[2]!r} starts at {b[0]} "
                    f"before predecessor {a[2]!r} at {a[0]}")
                break
        # Host lane nests like a call stack: no partial overlaps.
        stack = []  # end timestamps of open spans
        for ts, dur, name in sorted(spans, key=lambda s: (s[0], -s[1])):
            while stack and ts >= stack[-1]:
                stack.pop()
            end = ts + dur
            if stack and end > stack[-1] + 1e-9:
                errors.append(
                    f"pid {pid} host lane: span {name!r} [{ts}, {end}] "
                    f"partially overlaps enclosing span ending at {stack[-1]}")
                break
            stack.append(end)

    return errors, counts


def main():
    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    path = sys.argv[1]
    try:
        errors, counts = validate(path)
    except (OSError, ValueError) as e:
        print(f"::error title=Trace invalid::{path}: {e}")
        return 1
    if errors:
        for e in errors[:50]:
            print(f"::error title=Trace invalid::{path}: {e}")
        if len(errors) > 50:
            print(f"... and {len(errors) - 50} more")
        return 1
    print(
        f"{path}: valid — {counts.get('X', 0)} spans, "
        f"{counts.get('i', 0)} instants, {counts.get('M', 0)} metadata events"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
