#!/usr/bin/env python3
"""Validate a flight-recorder trace against the Chrome trace-event schema.

Checks the JSON that `gzccl ... --trace out.json` emits (the object
form, `{"traceEvents": [...]}`, as loaded by ui.perfetto.dev and
chrome://tracing):

  * the file parses and has a `traceEvents` list plus `displayTimeUnit`;
  * every event's `ph` is one of "X" (complete span), "i" (instant) or
    "M" (metadata) — the exporter never emits B/E pairs, so any other
    phase is a bug, and balance is structural;
  * every "X" event has finite `ts >= 0` and `dur >= 0` and names a
    `pid`/`tid` track;
  * on the host lane (tid 0) of every pid, span start times are
    monotone in file order (the recorder appends host activity in
    virtual-time order; a backwards jump means a clock or run-offset
    bug — other lanes record queue-entry times that legitimately
    interleave);
  * the host lane of every pid nests like a call stack: spans sorted
    by (start, -dur) are each fully contained in — never partially
    overlapping — the enclosing open span.

With a second argument, also validates the aggregated-metrics sidecar
(`<stem>.metrics.json`):

  * top level is `{"schema_version": 1, "metrics": {...}}`;
  * every key matches the registry grammar: a `[a-z0-9_]+` family name
    followed by dot-separated `[A-Za-z0-9_+-]+` qualifiers (codec
    labels like `lorenzo+prequant+rice` ride the qualifier segments);
  * every entry is a typed object — `counter`/`gauge` carry a finite
    `value`, `histogram` carries finite `count`/`sum`/`min`/`max`/
    `mean`/`p50`/`p95`/`p99` with `min <= p50 <= p95 <= p99 <= max`
    and `min <= mean <= max`.

Exits non-zero with a per-violation report; prints a summary on
success. Usage: trace_validate.py TRACE.json [METRICS.json]
"""

import json
import math
import re
import sys

ALLOWED_PH = {"X", "i", "M"}
HOST_TID = 0
METRIC_KEY = re.compile(r"^[a-z0-9_]+(\.[A-Za-z0-9_+-]+)*$")
HIST_FIELDS = ("count", "sum", "min", "max", "mean", "p50", "p95", "p99")


def err(errors, i, ev, msg):
    name = ev.get("name", "?") if isinstance(ev, dict) else "?"
    errors.append(f"event {i} ({name!r}): {msg}")


def finite_nonneg(v):
    return isinstance(v, (int, float)) and math.isfinite(v) and v >= 0


def validate(path):
    errors = []
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, dict):
        return ["top level is not a JSON object"], {}
    events = data.get("traceEvents")
    if not isinstance(events, list):
        return ["missing traceEvents list"], {}
    if "displayTimeUnit" not in data:
        errors.append("missing displayTimeUnit")

    counts = {"X": 0, "i": 0, "M": 0}
    spans_by_lane = {}  # (pid, tid) -> [(ts, dur, name)] in file order
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            err(errors, i, ev, "event is not an object")
            continue
        ph = ev.get("ph")
        if ph not in ALLOWED_PH:
            err(errors, i, ev, f"phase {ph!r} not in {sorted(ALLOWED_PH)} "
                "(B/E pairs are never emitted)")
            continue
        counts[ph] += 1
        if ph == "M":
            continue
        if not finite_nonneg(ev.get("ts")):
            err(errors, i, ev, f"ts {ev.get('ts')!r} is not finite and >= 0")
        if ph == "X":
            if not finite_nonneg(ev.get("dur")):
                err(errors, i, ev,
                    f"dur {ev.get('dur')!r} is not finite and >= 0")
            if not isinstance(ev.get("pid"), int) or not isinstance(
                    ev.get("tid"), int):
                err(errors, i, ev, "complete event without integer pid/tid")
            elif finite_nonneg(ev.get("ts")) and finite_nonneg(ev.get("dur")):
                spans_by_lane.setdefault((ev["pid"], ev["tid"]), []).append(
                    (ev["ts"], ev["dur"], ev.get("name", "?")))

    for (pid, tid), spans in sorted(spans_by_lane.items()):
        if tid != HOST_TID:
            continue
        # Monotone start times in file order on the host lane.
        for a, b in zip(spans, spans[1:]):
            if b[0] < a[0]:
                errors.append(
                    f"pid {pid} host lane: span {b[2]!r} starts at {b[0]} "
                    f"before predecessor {a[2]!r} at {a[0]}")
                break
        # Host lane nests like a call stack: no partial overlaps.
        stack = []  # end timestamps of open spans
        for ts, dur, name in sorted(spans, key=lambda s: (s[0], -s[1])):
            while stack and ts >= stack[-1]:
                stack.pop()
            end = ts + dur
            if stack and end > stack[-1] + 1e-9:
                errors.append(
                    f"pid {pid} host lane: span {name!r} [{ts}, {end}] "
                    f"partially overlaps enclosing span ending at {stack[-1]}")
                break
            stack.append(end)

    return errors, counts


def finite(v):
    return isinstance(v, (int, float)) and math.isfinite(v)


def validate_metrics(path):
    """Validate the aggregated-metrics sidecar; returns (errors, counts)."""
    errors = []
    counts = {"counter": 0, "gauge": 0, "histogram": 0}
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, dict):
        return ["top level is not a JSON object"], counts
    if data.get("schema_version") != 1:
        errors.append(
            f"schema_version {data.get('schema_version')!r} != 1")
    metrics = data.get("metrics")
    if not isinstance(metrics, dict):
        return errors + ["missing metrics object"], counts
    for key, entry in metrics.items():
        if not METRIC_KEY.match(key):
            errors.append(f"metric {key!r}: bad key (want "
                          "family[.qualifier]*, lowercase family)")
        if not isinstance(entry, dict):
            errors.append(f"metric {key!r}: entry is not an object")
            continue
        kind = entry.get("type")
        if kind in ("counter", "gauge"):
            counts[kind] += 1
            if not finite(entry.get("value")):
                errors.append(
                    f"metric {key!r}: {kind} value "
                    f"{entry.get('value')!r} is not finite")
        elif kind == "histogram":
            counts[kind] += 1
            bad = [fld for fld in HIST_FIELDS if not finite(entry.get(fld))]
            if bad:
                errors.append(f"metric {key!r}: non-finite or missing "
                              f"histogram fields {bad}")
                continue
            lo, p50, p95, p99, hi = (entry[f]
                                     for f in ("min", "p50", "p95", "p99",
                                               "max"))
            if not lo <= p50 <= p95 <= p99 <= hi:
                errors.append(
                    f"metric {key!r}: quantiles not ordered "
                    f"(min {lo} <= p50 {p50} <= p95 {p95} <= p99 {p99} "
                    f"<= max {hi} fails)")
            if not lo <= entry["mean"] <= hi:
                errors.append(f"metric {key!r}: mean {entry['mean']} "
                              f"outside [{lo}, {hi}]")
            if entry["count"] < 0 or entry["count"] != int(entry["count"]):
                errors.append(
                    f"metric {key!r}: count {entry['count']!r} is not a "
                    "non-negative integer")
        else:
            errors.append(f"metric {key!r}: unknown type {kind!r}")
    return errors, counts


def main():
    if len(sys.argv) not in (2, 3):
        print(__doc__)
        return 2
    path = sys.argv[1]
    try:
        errors, counts = validate(path)
    except (OSError, ValueError) as e:
        print(f"::error title=Trace invalid::{path}: {e}")
        return 1
    if errors:
        for e in errors[:50]:
            print(f"::error title=Trace invalid::{path}: {e}")
        if len(errors) > 50:
            print(f"... and {len(errors) - 50} more")
        return 1
    print(
        f"{path}: valid — {counts.get('X', 0)} spans, "
        f"{counts.get('i', 0)} instants, {counts.get('M', 0)} metadata events"
    )
    if len(sys.argv) == 3:
        mpath = sys.argv[2]
        try:
            merrors, mcounts = validate_metrics(mpath)
        except (OSError, ValueError) as e:
            print(f"::error title=Metrics invalid::{mpath}: {e}")
            return 1
        if merrors:
            for e in merrors[:50]:
                print(f"::error title=Metrics invalid::{mpath}: {e}")
            if len(merrors) > 50:
                print(f"... and {len(merrors) - 50} more")
            return 1
        print(
            f"{mpath}: valid — {mcounts['counter']} counters, "
            f"{mcounts['gauge']} gauges, {mcounts['histogram']} histograms"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
