#!/usr/bin/env python3
"""Non-blocking benchmark trend check.

Compares the current sweep artifact (BENCH_allreduce.json, the
engine's BENCH_engine.json rank-scale sweep, the codec-throughput
BENCH_codec.json, or the pipeline-depth BENCH_pipeline.json sweep)
against the previous run's artifact and emits a
GitHub Actions ::warning:: annotation for every sweep point whose
metric regressed by more than the threshold. The metric is the virtual
makespan for collective sweeps and the combined encode+decode wall
seconds for codec rows — bigger is worse in both. Always exits 0 —
this is a trend report, not a gate (the surrounding job is
continue-on-error as well).

Usage: bench_trend.py PREV.json CURR.json [--threshold 0.15]
"""

import argparse
import json
import sys


def load_rows(path):
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    # Provenance stamp (schema_version + git describe) added to the
    # artifact top level by bench_support::schema_stamp(). Artifacts
    # from before the stamp existed have neither key; both generations
    # must keep loading, so the stamp is surfaced for the log and
    # otherwise ignored — row keys and metrics never depend on it.
    version = data.get("schema_version")
    if version is not None:
        print(f"{path}: schema v{version}, git {data.get('git', 'unknown')}")
    rows = {}
    for row in data.get("rows", []):
        # `tiers` distinguishes the 3-tier node/rack sweep columns;
        # pre-tiers artifacts default to the flat 2-tier label so a
        # schema bump only orphans keys once.
        # `backend` separates the event engine's rows from the thread
        # oracle's in BENCH_engine.json; allreduce artifacts (old and
        # new) have no such column and default to the same "".
        # Codec rows (BENCH_codec.json) have no algo/ranks columns at
        # all: the staged-pipeline label is the identity instead.
        # `pipeline` separates BENCH_pipeline.json's depth sweep rows by
        # the REQUESTED policy ("1"/"2"/"4"/"8"/"auto") rather than the
        # executed depth, so an auto row keeps matching its baseline
        # even when the tuner's depth pick changes; artifacts from
        # before the column existed default to the same "".
        key = (
            row.get("algo", ""),
            row.get("codec", ""),
            row.get("backend", ""),
            row.get("pipeline", ""),
            row.get("ranks", 0),
            row.get("gpus_per_node", 0),
            row.get("tiers", ""),
            row.get("size_mib", 0),
        )
        rows[key] = row
    return rows


def metric(row):
    """Seconds where bigger is worse: the virtual makespan for
    collective sweep rows, encode+decode wall time for codec rows."""
    if "virtual_makespan_s" in row:
        return row["virtual_makespan_s"]
    return row.get("encode_s", 0.0) + row.get("decode_s", 0.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("prev")
    ap.add_argument("curr")
    ap.add_argument("--threshold", type=float, default=0.15)
    args = ap.parse_args()

    try:
        prev = load_rows(args.prev)
        curr = load_rows(args.curr)
    except (OSError, ValueError, KeyError) as e:
        print(f"trend check skipped: could not parse artifacts ({e})")
        return 0

    regressions = []
    improvements = 0
    for key, row in sorted(curr.items()):
        base = prev.get(key)
        if base is None:
            continue
        old = metric(base)
        new = metric(row)
        if old <= 0.0:
            continue
        delta = (new - old) / old
        algo, codec, backend, pipeline, ranks, gpn, tiers, size = key
        if codec:
            label = f"codec={codec} size={size}MiB"
        else:
            label = f"algo={algo} ranks={ranks} gpn={gpn} tiers={tiers} size={size}MiB"
        if backend:
            label += f" backend={backend}"
        if pipeline:
            label += f" pipeline={pipeline} depth={row.get('depth', 0)}"
            prev_depth = base.get("depth", 0)
            if prev_depth and prev_depth != row.get("depth", 0):
                print(f"note: executed depth changed for {label}: "
                      f"{prev_depth} -> {row.get('depth', 0)}")
        # Optional per-leg-eb column (absent in pre-ExecPlan artifacts):
        # shown for context, and a change is flagged because different
        # per-leg bounds change compressed wire volume, which can
        # explain an apparent makespan shift.
        leg_ebs = row.get("leg_ebs", "")
        if leg_ebs:
            label += f" legs={leg_ebs}"
        prev_legs = base.get("leg_ebs", "")
        if prev_legs and leg_ebs and prev_legs != leg_ebs:
            print(f"note: per-leg ebs changed for {label}: {prev_legs} -> {leg_ebs}")
        # Optional analyzer columns (absent in pre-analytics artifacts):
        # the dominant bottleneck category explains a makespan shift
        # (e.g. wire -> queue means contention, not slower kernels), and
        # the critical path must stay glued to the makespan.
        bott = row.get("bottleneck", "")
        if bott:
            label += f" bottleneck={bott}"
        prev_bott = base.get("bottleneck", "")
        if prev_bott and bott and prev_bott != bott:
            print(f"note: dominant bottleneck changed for {label}: "
                  f"{prev_bott} -> {bott}")
        cp = row.get("critical_path_s")
        mk = row.get("virtual_makespan_s")
        if cp is not None and mk and abs(cp - mk) > 1e-9 * mk:
            print(f"::warning title=Critical path drift::{label}: "
                  f"critical_path_s {cp} != virtual_makespan_s {mk}")
        if delta > args.threshold:
            regressions.append((label, old, new, delta))
            print(
                f"::warning title=Benchmark regression::{label}: "
                f"{old:.6f}s -> {new:.6f}s (+{delta * 100:.1f}%)"
            )
        elif delta < -args.threshold:
            improvements += 1
            print(f"improved  {label}: {old:.6f}s -> {new:.6f}s ({delta * 100:.1f}%)")
        else:
            print(f"unchanged {label}: {old:.6f}s -> {new:.6f}s ({delta * 100:+.1f}%)")

    compared = len([k for k in curr if k in prev])
    print(
        f"\ntrend: {compared} points compared, {len(regressions)} regressed "
        f"(> {args.threshold * 100:.0f}%), {improvements} improved"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
