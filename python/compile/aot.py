"""AOT lowering: JAX graphs → HLO *text* artifacts for the Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``make artifacts``). Python never runs on the request path: the Rust
binary is self-contained once these files exist.
"""

import argparse
import hashlib
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


#: name → (fn, example-arg specs). Every entry becomes
#: ``artifacts/<name>.hlo.txt``.
ARTIFACTS = {
    "reduce_pair": (model.reduce_pair, (f32(model.IMG_ELEMS), f32(model.IMG_ELEMS))),
    "stack_update": (model.stack_update, (f32(model.IMG_ELEMS), f32(model.IMG_ELEMS))),
    "quantize": (model.quantize, (f32(model.CPR_ELEMS),)),
    "dequantize": (model.dequantize, (i32(model.CPR_ELEMS),)),
    "mlp_grads": (
        model.mlp_grads,
        (
            f32(model.MLP_PARAMS),
            f32(model.MLP_BATCH, model.MLP_IN),
            f32(model.MLP_BATCH, model.MLP_OUT),
        ),
    ),
    "mlp_apply": (model.mlp_apply, (f32(model.MLP_PARAMS), f32(model.MLP_PARAMS))),
}


def lower_one(name: str) -> str:
    fn, args = ARTIFACTS[name]
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) path for model.hlo.txt")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = []
    for name in sorted(ARTIFACTS):
        text = lower_one(name)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest.append(f"{name} sha256:{digest} bytes:{len(text)}")
        print(f"wrote {path} ({len(text)} chars)")

    # Makefile contract: artifacts/model.hlo.txt is the collective
    # computation hot-spot (the reduction).
    model_path = os.path.join(out_dir, "model.hlo.txt")
    with open(os.path.join(out_dir, "reduce_pair.hlo.txt")) as f:
        text = f.read()
    with open(model_path, "w") as f:
        f.write(text)
    print(f"wrote {model_path}")

    shapes = [
        f"img_elems {model.IMG_ELEMS}",
        f"cpr_elems {model.CPR_ELEMS}",
        f"default_eb {model.DEFAULT_EB}",
        f"mlp_params {model.MLP_PARAMS}",
        f"mlp_in {model.MLP_IN}",
        f"mlp_out {model.MLP_OUT}",
        f"mlp_batch {model.MLP_BATCH}",
    ]
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(shapes + manifest) + "\n")
    print("wrote manifest.txt")


if __name__ == "__main__":
    main()
