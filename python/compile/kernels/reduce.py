"""L1 Pallas kernels: reduction and parameter-update primitives.

``reduce_pair`` is the GPU reduction kernel of gZCCL §3.3.1 (the paper
moves reduction from host to device); the Rust coordinator executes its
AOT artifact on the hot path of Allreduce-backed applications (image
stacking, DDP gradient averaging). ``axpy`` is the SGD parameter update
used by the DDP training example.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _axpy_kernel(p_ref, g_ref, o_ref, *, lr):
    o_ref[...] = p_ref[...] - lr * g_ref[...]


def reduce_pair(a, b):
    """Elementwise sum — the Allreduce reduction operator, on device."""
    n = a.shape[0]
    assert a.shape == b.shape and n % BLOCK == 0
    return pl.pallas_call(
        _add_kernel,
        grid=(n // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(a, b)


def axpy(params, grads, lr):
    """SGD step ``params - lr * grads`` as a Pallas kernel."""
    n = params.shape[0]
    assert params.shape == grads.shape and n % BLOCK == 0
    kernel = functools.partial(_axpy_kernel, lr=lr)
    return pl.pallas_call(
        kernel,
        grid=(n // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(params, grads)
