"""L1 Pallas kernels: the cuSZp encode/decode compute core.

The paper's compression hot-spot is cuSZp's (prequant + 1D integer
Lorenzo) kernel. On CUDA this is one thread-block per 32-value block;
the TPU-minded Pallas adaptation tiles the array into VMEM-sized grid
blocks via ``BlockSpec`` (the HBM->VMEM schedule CUDA expressed with
threadblocks) and keeps each grid block independently decodable: the
first delta of a block is absolute, exactly like the Rust/cuSZp layout.

Variable-length bit-packing cannot be a dense Pallas output, so — as in
cuSZp itself, which splits quantization and packing kernels — the
kernels here emit fixed-shape i32 quantization deltas; the entropy/
packing stage lives in the Rust coordinator (L3).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
the Rust runtime loads (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Values per independently-decodable grid block. Must divide any input
# length fed to the kernels (callers pad).
BLOCK = 256


def _encode_kernel(x_ref, o_ref, *, inv_two_eb):
    """Prequantize + intra-block integer Lorenzo delta."""
    x = x_ref[...]
    q = jnp.round(x * inv_two_eb).astype(jnp.int32)
    prev = jnp.concatenate([jnp.zeros((1,), jnp.int32), q[:-1]])
    o_ref[...] = q - prev


def _decode_kernel(d_ref, o_ref, *, two_eb):
    """Prefix-sum the deltas and rescale to bin centers."""
    d = d_ref[...]
    q = jnp.cumsum(d)
    o_ref[...] = (q.astype(jnp.float32)) * two_eb


def lorenzo_encode(x, eb):
    """Quantization deltas of ``x`` at absolute error bound ``eb``.

    ``x`` must be 1-D with length a multiple of ``BLOCK``. Returns i32
    deltas of the same shape; block ``i`` covers ``[i*BLOCK, (i+1)*BLOCK)``
    and decodes independently.
    """
    n = x.shape[0]
    assert n % BLOCK == 0, f"length {n} not a multiple of {BLOCK}"
    kernel = functools.partial(_encode_kernel, inv_two_eb=1.0 / (2.0 * eb))
    return pl.pallas_call(
        kernel,
        grid=(n // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(x)


def lorenzo_decode(deltas, eb):
    """Inverse of :func:`lorenzo_encode` (up to the eb quantization)."""
    n = deltas.shape[0]
    assert n % BLOCK == 0, f"length {n} not a multiple of {BLOCK}"
    kernel = functools.partial(_decode_kernel, two_eb=2.0 * eb)
    return pl.pallas_call(
        kernel,
        grid=(n // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(deltas)


def compress_roundtrip(x, eb):
    """encode→decode composition: ``x`` snapped to its eb bins.

    This is the accuracy path a payload takes through one gZCCL
    compression stage; the Rust accuracy experiments validate against
    the same semantics.
    """
    return lorenzo_decode(lorenzo_encode(x, eb), eb)
