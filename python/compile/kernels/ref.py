"""Pure-jnp oracles for every L1 kernel.

These are the correctness ground truth: pytest asserts the Pallas
kernels match these (and hypothesis sweeps shapes/values). No pallas
imports here — nothing to get subtly wrong twice the same way.
"""

import jax.numpy as jnp

BLOCK = 256


def lorenzo_encode_ref(x, eb):
    """Blockwise prequant + delta, block-independent (first delta absolute).

    Uses ``x * (1/(2eb))`` — multiplication, not division — matching the
    kernel's arithmetic bit-for-bit in f32.
    """
    q = jnp.round(x * (1.0 / (2.0 * eb))).astype(jnp.int32)
    qb = q.reshape(-1, BLOCK)
    prev = jnp.concatenate([jnp.zeros((qb.shape[0], 1), jnp.int32), qb[:, :-1]], axis=1)
    return (qb - prev).reshape(-1)


def lorenzo_decode_ref(deltas, eb):
    """Blockwise prefix sum and rescale."""
    db = deltas.reshape(-1, BLOCK)
    q = jnp.cumsum(db, axis=1)
    return (q.astype(jnp.float32) * (2.0 * eb)).reshape(-1)


def compress_roundtrip_ref(x, eb):
    """Values snapped to their eb bins."""
    return lorenzo_decode_ref(lorenzo_encode_ref(x, eb), eb)


def reduce_pair_ref(a, b):
    return a + b


def axpy_ref(p, g, lr):
    return p - lr * g
