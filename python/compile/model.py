"""L2: JAX compute graphs AOT-lowered for the Rust coordinator.

Three graph families, all calling the L1 Pallas kernels so they lower
into the same HLO the Rust runtime executes:

* ``reduce_pair`` / ``stack_update`` — the device reduction of gZCCL
  §3.3.1, used by the image-stacking application (paper §4.5).
* ``quantize`` / ``dequantize`` — the compression round-trip stage
  (cuSZp core) at the paper's default eb = 1e-4.
* ``mlp_grads`` / ``mlp_apply`` — fwd+bwd and SGD apply of a small MLP
  regressor, the per-rank compute of the DDP training example whose
  gradients are averaged with gZ-Allreduce.

Shapes are fixed at AOT time and mirrored in
``rust/src/runtime/artifacts.rs``; ``aot.py`` also emits a manifest the
Rust side validates against.
"""

import jax
import jax.numpy as jnp

from .kernels import lorenzo, reduce

# ---- Fixed AOT shapes (mirrored in rust/src/runtime/artifacts.rs) ----

#: Image stacking: one 128×128 partial image, flattened.
IMG_ELEMS = 128 * 128
#: Compression round-trip vector length.
CPR_ELEMS = 64 * 1024
#: Paper-default absolute error bound.
DEFAULT_EB = 1e-4

#: MLP dims: x[batch, IN] → h[HID] → y[batch, OUT].
MLP_IN = 64
MLP_HID = 256
MLP_OUT = 16
MLP_BATCH = 256
#: Total flat parameter count (padded to the kernel BLOCK).
MLP_PARAMS_RAW = MLP_IN * MLP_HID + MLP_HID + MLP_HID * MLP_OUT + MLP_OUT
MLP_PARAMS = ((MLP_PARAMS_RAW + reduce.BLOCK - 1) // reduce.BLOCK) * reduce.BLOCK


def reduce_pair(a, b):
    """Elementwise sum of two flat f32 vectors (Pallas kernel)."""
    return (reduce.reduce_pair(a, b),)


def stack_update(acc, img):
    """One image-stacking accumulation step: ``acc + img``."""
    return (reduce.reduce_pair(acc, img),)


def quantize(x):
    """cuSZp-core quantization deltas at the default error bound."""
    return (lorenzo.lorenzo_encode(x, DEFAULT_EB),)


def dequantize(d):
    """Inverse of :func:`quantize`."""
    return (lorenzo.lorenzo_decode(d, DEFAULT_EB),)


def _unpack(params):
    """Flat parameter vector → (W1, b1, W2, b2)."""
    i = 0
    w1 = params[i : i + MLP_IN * MLP_HID].reshape(MLP_IN, MLP_HID)
    i += MLP_IN * MLP_HID
    b1 = params[i : i + MLP_HID]
    i += MLP_HID
    w2 = params[i : i + MLP_HID * MLP_OUT].reshape(MLP_HID, MLP_OUT)
    i += MLP_HID * MLP_OUT
    b2 = params[i : i + MLP_OUT]
    return w1, b1, w2, b2


def mlp_loss(params, x, y):
    """MSE of the 2-layer tanh MLP on (x, y)."""
    w1, b1, w2, b2 = _unpack(params)
    h = jnp.tanh(x @ w1 + b1)
    pred = h @ w2 + b2
    return jnp.mean((pred - y) ** 2)


def mlp_grads(params, x, y):
    """Per-rank training compute: (loss, flat gradient vector)."""
    loss, g = jax.value_and_grad(mlp_loss)(params, x, y)
    return loss.reshape(1), g


def mlp_apply(params, grads):
    """SGD apply at lr=0.05 through the Pallas axpy kernel."""
    return (reduce.axpy(params, grads, 0.05),)


def mlp_init(seed: int = 0):
    """Deterministic flat parameter init (matches the Rust driver)."""
    key = jax.random.PRNGKey(seed)
    p = jax.random.normal(key, (MLP_PARAMS,), jnp.float32) * 0.1
    return p


def mlp_batch(seed: int):
    """Synthetic regression batch: y = sines of a fixed random projection."""
    key = jax.random.PRNGKey(1000 + seed)
    kx, kw = jax.random.split(jax.random.PRNGKey(555))
    del kx
    x = jax.random.normal(jax.random.fold_in(key, 1), (MLP_BATCH, MLP_IN), jnp.float32)
    w = jax.random.normal(kw, (MLP_IN, MLP_OUT), jnp.float32) / jnp.sqrt(MLP_IN)
    y = jnp.sin(x @ w)
    return x, y
