"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

This is the core correctness signal for the compiled artifacts:
hypothesis sweeps shapes/values/error bounds and asserts allclose
against the reference implementations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import lorenzo, reduce, ref

jax.config.update("jax_platform_name", "cpu")

BLOCK = lorenzo.BLOCK


def vec(rng, n, scale=1.0):
    return jnp.asarray(rng.standard_normal(n).astype(np.float32) * scale)


# ---------------------------------------------------------------------
# Deterministic unit tests
# ---------------------------------------------------------------------


class TestLorenzoEncode:
    def test_matches_ref_smoke(self):
        rng = np.random.default_rng(0)
        x = vec(rng, 4 * BLOCK)
        got = lorenzo.lorenzo_encode(x, 1e-3)
        want = ref.lorenzo_encode_ref(x, 1e-3)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_first_delta_of_each_block_is_absolute(self):
        # Constant input: within a block all deltas but the first are 0.
        x = jnp.full((2 * BLOCK,), 0.5, jnp.float32)
        d = np.asarray(lorenzo.lorenzo_encode(x, 1e-3))
        q = round(0.5 / 2e-3)
        assert d[0] == q and d[BLOCK] == q
        assert (d[1:BLOCK] == 0).all() and (d[BLOCK + 1 :] == 0).all()

    def test_zero_input_all_zero(self):
        x = jnp.zeros((BLOCK,), jnp.float32)
        assert (np.asarray(lorenzo.lorenzo_encode(x, 1e-4)) == 0).all()

    def test_rejects_misaligned_length(self):
        with pytest.raises(AssertionError):
            lorenzo.lorenzo_encode(jnp.zeros((BLOCK + 1,), jnp.float32), 1e-4)


class TestLorenzoDecode:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(1)
        x = vec(rng, 8 * BLOCK, scale=3.0)
        for eb in (1e-2, 1e-3, 1e-4):
            back = lorenzo.compress_roundtrip(x, eb)
            err = np.abs(np.asarray(back) - np.asarray(x)).max()
            assert err <= eb * (1 + 1e-3), f"eb={eb}: {err}"

    def test_matches_ref(self):
        rng = np.random.default_rng(2)
        x = vec(rng, 4 * BLOCK)
        d = lorenzo.lorenzo_encode(x, 1e-3)
        got = lorenzo.lorenzo_decode(d, 1e-3)
        want = ref.lorenzo_decode_ref(d, 1e-3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)

    def test_blocks_decode_independently(self):
        rng = np.random.default_rng(3)
        x = vec(rng, 4 * BLOCK)
        d = np.asarray(lorenzo.lorenzo_encode(x, 1e-3))
        # Decoding a single block in isolation equals that block's slice
        # of the full decode.
        blk = jnp.asarray(d[BLOCK : 2 * BLOCK])
        solo = np.asarray(lorenzo.lorenzo_decode(blk, 1e-3))
        full = np.asarray(lorenzo.lorenzo_decode(jnp.asarray(d), 1e-3))
        np.testing.assert_allclose(solo, full[BLOCK : 2 * BLOCK], atol=0)


class TestReduce:
    def test_add_matches_ref(self):
        rng = np.random.default_rng(4)
        a, b = vec(rng, 2 * BLOCK), vec(rng, 2 * BLOCK)
        np.testing.assert_allclose(
            np.asarray(reduce.reduce_pair(a, b)),
            np.asarray(ref.reduce_pair_ref(a, b)),
            atol=0,
        )

    def test_axpy_matches_ref(self):
        rng = np.random.default_rng(5)
        p, g = vec(rng, BLOCK), vec(rng, BLOCK)
        np.testing.assert_allclose(
            np.asarray(reduce.axpy(p, g, 0.05)),
            np.asarray(ref.axpy_ref(p, g, 0.05)),
            rtol=1e-6,
        )


# ---------------------------------------------------------------------
# Hypothesis sweeps
# ---------------------------------------------------------------------

sizes = st.integers(min_value=1, max_value=6).map(lambda k: k * BLOCK)
ebs = st.sampled_from([1e-2, 1e-3, 1e-4])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(n=sizes, eb=ebs, seed=seeds)
def test_encode_matches_ref_swept(n, eb, seed):
    rng = np.random.default_rng(seed)
    x = vec(rng, n, scale=10.0)
    got = np.asarray(lorenzo.lorenzo_encode(x, eb))
    want = np.asarray(ref.lorenzo_encode_ref(x, eb))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(n=sizes, eb=ebs, seed=seeds)
def test_roundtrip_error_bound_swept(n, eb, seed):
    rng = np.random.default_rng(seed)
    scale = float(rng.uniform(0.1, 50.0))
    x = vec(rng, n, scale=scale)
    back = np.asarray(lorenzo.compress_roundtrip(x, eb))
    # eb plus float32 representation slack at the data's magnitude.
    tol = eb + np.abs(np.asarray(x)).max() * 1e-6
    assert np.abs(back - np.asarray(x)).max() <= tol


@settings(max_examples=20, deadline=None)
@given(n=sizes, seed=seeds)
def test_reduce_pair_swept(n, seed):
    rng = np.random.default_rng(seed)
    a, b = vec(rng, n), vec(rng, n)
    np.testing.assert_allclose(
        np.asarray(reduce.reduce_pair(a, b)), np.asarray(a) + np.asarray(b), atol=0
    )


@settings(max_examples=15, deadline=None)
@given(n=sizes, seed=seeds, lr=st.sampled_from([0.01, 0.05, 0.5]))
def test_axpy_swept(n, seed, lr):
    rng = np.random.default_rng(seed)
    p, g = vec(rng, n), vec(rng, n)
    np.testing.assert_allclose(
        np.asarray(reduce.axpy(p, g, lr)),
        np.asarray(ref.axpy_ref(p, g, lr)),
        rtol=1e-5,
        atol=1e-6,
    )
