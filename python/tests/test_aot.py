"""AOT path: every artifact lowers to non-trivial HLO text."""

import jax

from compile import aot

jax.config.update("jax_platform_name", "cpu")


def test_all_artifacts_lower():
    for name in aot.ARTIFACTS:
        text = aot.lower_one(name)
        assert "HloModule" in text, name
        assert len(text) > 200, name


def test_reduce_pair_hlo_mentions_add():
    text = aot.lower_one("reduce_pair")
    assert "add" in text


def test_artifact_set_covers_runtime_contract():
    # The Rust runtime (rust/src/runtime/artifacts.rs) loads exactly
    # these names; keep the contract in sync.
    expected = {
        "reduce_pair",
        "stack_update",
        "quantize",
        "dequantize",
        "mlp_grads",
        "mlp_apply",
    }
    assert set(aot.ARTIFACTS) == expected
