"""L2 correctness: model graphs, shapes, and training signal."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")


class TestReduceGraphs:
    def test_reduce_pair_shape_and_value(self):
        a = jnp.arange(model.IMG_ELEMS, dtype=jnp.float32)
        b = jnp.ones((model.IMG_ELEMS,), jnp.float32)
        (out,) = model.reduce_pair(a, b)
        assert out.shape == (model.IMG_ELEMS,)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a) + 1.0, atol=0)

    def test_stack_update_accumulates(self):
        acc = jnp.zeros((model.IMG_ELEMS,), jnp.float32)
        img = jnp.full((model.IMG_ELEMS,), 0.25, jnp.float32)
        for _ in range(4):
            (acc,) = model.stack_update(acc, img)
        np.testing.assert_allclose(np.asarray(acc), 1.0, rtol=1e-6)


class TestQuantizeGraphs:
    def test_quantize_dequantize_round_trip(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.standard_normal(model.CPR_ELEMS).astype(np.float32))
        (d,) = model.quantize(x)
        assert d.dtype == jnp.int32
        (back,) = model.dequantize(d)
        err = np.abs(np.asarray(back) - np.asarray(x)).max()
        # eb plus f32 representation slack at the data magnitude.
        tol = model.DEFAULT_EB + float(np.abs(np.asarray(x)).max()) * 1e-6
        assert err <= tol


class TestMlp:
    def test_param_vector_padded_to_block(self):
        from compile.kernels.reduce import BLOCK

        assert model.MLP_PARAMS % BLOCK == 0
        assert model.MLP_PARAMS >= model.MLP_PARAMS_RAW

    def test_grads_shapes(self):
        p = model.mlp_init(0)
        x, y = model.mlp_batch(0)
        loss, g = model.mlp_grads(p, x, y)
        assert loss.shape == (1,)
        assert g.shape == (model.MLP_PARAMS,)
        # Padding tail has zero gradient (unused parameters).
        tail = np.asarray(g[model.MLP_PARAMS_RAW :])
        assert np.all(tail == 0.0)

    def test_sgd_decreases_loss(self):
        p = model.mlp_init(0)
        x, y = model.mlp_batch(0)
        first, g = model.mlp_grads(p, x, y)
        for step in range(30):
            _, g = model.mlp_grads(p, x, y)
            (p,) = model.mlp_apply(p, g)
        last, _ = model.mlp_grads(p, x, y)
        assert float(last[0]) < 0.5 * float(first[0]), (first, last)

    def test_batches_are_deterministic_per_seed(self):
        x1, y1 = model.mlp_batch(3)
        x2, y2 = model.mlp_batch(3)
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
        x3, _ = model.mlp_batch(4)
        assert not np.array_equal(np.asarray(x1), np.asarray(x3))
