//! Image stacking (paper §4.5): stack per-rank partial images with
//! every variant, report Table-2-style performance + Fig-13 accuracy,
//! and write PGM visualizations. Uses the PJRT `stack_update` artifact
//! for the lossless reference (all three layers composing).
//!
//! ```bash
//! make artifacts && cargo run --release --example image_stacking
//! ```

use gzccl::apps::stacking::{run_stacking, write_pgm, StackingConfig, StackingVariant};
use gzccl::metrics::Table;
use gzccl::runtime::Engine;

fn main() -> gzccl::Result<()> {
    let engine = Engine::discover()?;
    let cfg = StackingConfig {
        ranks: 16,
        ..Default::default()
    };

    let mut table = Table::new(
        format!("Image stacking: {} ranks, {}x{} images, eb {:.0e}",
                cfg.ranks, cfg.width, cfg.height, cfg.error_bound),
        &["variant", "virtual time", "PSNR (dB)", "NRMSE", "CPR share"],
    );
    let out_dir = std::path::Path::new("artifacts/stacking");
    std::fs::create_dir_all(out_dir)?;

    for variant in [
        StackingVariant::CrayMpi,
        StackingVariant::Nccl,
        StackingVariant::GzcclRing,
        StackingVariant::GzcclReDoub,
    ] {
        let out = run_stacking(&cfg, variant, Some(&engine))?;
        table.row(&[
            variant.name().to_string(),
            gzccl::metrics::table::fmt_time(out.makespan),
            format!("{:.2}", out.psnr),
            format!("{:.2e}", out.nrmse),
            format!("{:.1}%", 100.0 * out.breakdown.fraction(gzccl::sim::Phase::Cpr)),
        ]);
        let name = format!("{}.pgm", variant.name().replace([' ', '(', ')'], ""));
        write_pgm(&out_dir.join(name), &out.image, cfg.width, cfg.height)?;
    }
    table.print();
    println!("visualizations written to {}", out_dir.display());
    Ok(())
}
