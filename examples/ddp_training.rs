//! End-to-end driver: data-parallel training with compression-
//! accelerated gradient Allreduce.
//!
//! Each simulated rank computes MLP gradients through the PJRT
//! `mlp_grads` artifact (JAX/Pallas-authored, AOT-compiled), gradients
//! are summed with gZ-Allreduce (real error-bounded compression on the
//! real gradient bytes, virtual-time cluster accounting), averaged, and
//! applied through the Pallas `axpy` artifact. Logs the loss curve and
//! the collective cost — the run recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example ddp_training
//! ```

use gzccl::apps::ddp::{train_ddp, DdpConfig};
use gzccl::runtime::Engine;

fn main() -> gzccl::Result<()> {
    let engine = Engine::discover()?;
    let shapes = engine.shapes();
    println!(
        "DDP training: {} params MLP, batch {}, 8 ranks, gZ-Allreduce(ReDoub) eb=1e-4",
        shapes.mlp_params, shapes.mlp_batch
    );

    let cfg = DdpConfig {
        ranks: 8,
        steps: 200,
        error_bound: 1e-4,
        redoub: true,
        compress: true,
        seed: 42,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let out = train_ddp(&cfg, &engine)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("step   loss");
    for (i, loss) in out.loss_curve.iter().enumerate() {
        if i % 10 == 0 || i + 1 == out.loss_curve.len() {
            println!("{i:5}  {loss:.5}");
        }
    }
    let first = out.loss_curve[0];
    let last = *out.loss_curve.last().unwrap();
    println!("loss: {first:.4} -> {last:.4} ({:.1}% of initial)", 100.0 * last / first);
    println!(
        "gradient allreduce: {:.3} virtual ms total, {:.2} MB on the wire",
        out.allreduce_time * 1e3,
        out.wire_bytes as f64 / 1e6
    );
    println!("wall time: {wall:.1}s for {} steps", cfg.steps);
    assert!(last < 0.5 * first, "training did not converge");
    println!("OK");
    Ok(())
}
