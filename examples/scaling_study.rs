//! Scaling study (Fig. 10/12-style): sweep GPU counts for Allreduce
//! and Scatter across all variants, on the full 646 MB dataset.
//!
//! ```bash
//! cargo run --release --example scaling_study [max_gpus]
//! ```

use gzccl::experiments::{fig10_scale, fig12_scatter_scale};

fn main() -> gzccl::Result<()> {
    println!("Sweeping GPU counts on the 646 MB dataset (virtual payloads,");
    println!("compression sizes from a profile measured on real RTM-like data).\n");
    fig10_scale(4)?.print();
    println!();
    fig12_scatter_scale()?.print();
    Ok(())
}
