//! Codec-pipeline quickstart: staged compressors picked **per leg**
//! under one accuracy target, plus the lossless tier for bitwise-exact
//! callers.
//!
//! Part 1 derives one compressor bound from a single end-to-end
//! `AccuracyTarget`, then runs a 512-rank, 3-tier (4×16×8) Allreduce
//! whose rack uplinks are oversubscribed. The tuner prices every stage
//! of every codec composition against each leg's link speed and mixes
//! pipelines: the cheap bitpack coder on fast intranode legs, the
//! denser RLE+Rice entropy coder on the thin uplinks — different
//! codecs, one target.
//!
//! Part 2 asks for `AccuracyTarget::Bitexact`. Instead of vetoing
//! compression, the planner binds every compressed leg to the lossless
//! codec composition (zero distortion at any amplification, `eb = 0`)
//! and the summed result is bit-identical to the uncompressed
//! reference — compression wins for callers that tolerate no error at
//! all.
//!
//! ```bash
//! cargo run --release --example codec_pipeline
//! ```

use gzccl::accuracy::{plan_for_algo_tiers, AccuracyTarget};
use gzccl::collectives::{Algo, Op};
use gzccl::comm::{CollectiveSpec, Communicator};
use gzccl::compress::CodecSpec;
use gzccl::coordinator::{ClusterSpec, CompressionMode, DeviceBuf, ExecPolicy};
use gzccl::net::LinkModel;
use gzccl::testkit::Pcg32;
use gzccl::topo::TierTree;

fn main() -> gzccl::Result<()> {
    // ---- Part 1: per-leg codec selection under one target -----------
    // 512 ranks as 4 GPUs/node × 16 nodes/rack × 8 racks, with a thin
    // shared rack uplink (25 µs, 1.25 GB/s effective).
    let ranks = 512;
    let tree = TierTree::new(ranks, &[4, 16, 8])?;
    let target = 1e-3;
    let plan = plan_for_algo_tiers(
        AccuracyTarget::AbsError(target),
        None,
        1,
        Op::Allreduce,
        Algo::Hierarchical,
        &tree,
        CompressionMode::ErrorBounded,
    )?;
    let mut spec = ClusterSpec::with_tiers(tree, ExecPolicy::gzccl());
    spec.uplinks = vec![LinkModel::new(25e-6, 1.25e9)];
    spec.error_bound = plan.eb;
    let comm = Communicator::from_spec(spec);

    // 64 MiB virtual payloads: big enough that the uplink exchange is
    // bandwidth-bound, which is where entropy coding pays for itself.
    let inputs: Vec<DeviceBuf> = (0..ranks).map(|_| DeviceBuf::Virtual(16 << 20)).collect();
    let report = comm.allreduce(inputs, &CollectiveSpec::forced(Algo::Hierarchical))?;

    println!("per-leg codec selection over {ranks} ranks (4x16x8, thin rack uplinks)");
    println!("  target {target:.0e} end-to-end -> planned eb {:.3e}", plan.eb);
    for l in &report.legs {
        let codec = if l.exec.compresses() {
            l.exec.codec.label()
        } else {
            "-".into()
        };
        println!(
            "  leg {:<2} tier {:<2} {:?}: codec {codec}",
            l.leg,
            l.tier,
            l.kind.expect("hierarchical legs carry kinds"),
        );
    }
    let uplink_rice = report
        .legs
        .iter()
        .any(|l| l.tier >= 2 && l.exec.compresses() && l.exec.codec == CodecSpec::rle_rice());
    let intranode_cuszp = report
        .legs
        .iter()
        .filter(|l| l.tier <= 1 && l.exec.compresses())
        .all(|l| l.exec.codec == CodecSpec::cuszp());
    assert!(uplink_rice, "the thin rack uplink should flip at least one leg to rle-rice");
    assert!(intranode_cuszp, "fast intranode legs should stay on the cheap cuszp pipeline");
    println!("  virtual makespan : {}", report.makespan);

    // ---- Part 2: the lossless tier for bitwise-exact callers --------
    // Integer-valued payloads keep every f32 summation order exact, so
    // "bit-identical" is well-defined whatever schedule the tuner
    // compiles.
    let ranks = 8;
    let dim = 4096;
    let comm = Communicator::builder(ranks)
        .policy(ExecPolicy::gzccl())
        .accuracy_target(AccuracyTarget::Bitexact)
        .build()?;
    let make = |r: usize| -> Vec<f32> {
        let mut rng = Pcg32::new(5, r as u64);
        (0..dim).map(|_| (rng.next_u32() % 33) as f32 - 16.0).collect()
    };
    let mut expect = vec![0.0f32; dim];
    for r in 0..ranks {
        for (s, v) in expect.iter_mut().zip(make(r)) {
            *s += v;
        }
    }
    let inputs: Vec<DeviceBuf> = (0..ranks).map(|r| DeviceBuf::Real(make(r))).collect();
    let report = comm.allreduce(inputs, &CollectiveSpec::auto())?;

    println!("\nbitexact target over {ranks} ranks: no veto, lossless codec tier");
    for l in &report.legs {
        if l.exec.compresses() {
            println!(
                "  leg {:<2} tier {:<2}: codec {} (eb {})",
                l.leg,
                l.tier,
                l.exec.codec.label(),
                l.exec.eb
            );
        }
    }
    let out = report.outputs[0].as_real();
    for (a, b) in out.iter().zip(expect.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "lossless tier must be bit-exact");
    }
    let raw_bytes = ranks * dim * 4;
    println!(
        "  wire bytes       : {} (uncompressed inputs total {raw_bytes})",
        report.total_wire_bytes()
    );
    println!("  result           : bit-identical to the uncompressed sum");
    println!("OK");
    Ok(())
}
