//! Quickstart: run one compression-accelerated Allreduce and inspect
//! the report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gzccl::collectives::allreduce_recursive_doubling;
use gzccl::coordinator::{run_collective, ClusterSpec, DeviceBuf, ExecPolicy};
use gzccl::testkit::Pcg32;

fn main() -> gzccl::Result<()> {
    // 8 simulated A100s (2 nodes x 4 GPUs), gZCCL policy, eb = 1e-4.
    let ranks = 8;
    let spec = ClusterSpec::new(ranks, ExecPolicy::gzccl()).with_error_bound(1e-4);

    // Real per-rank payloads: 1M floats of smooth data each.
    let inputs: Vec<DeviceBuf> = (0..ranks)
        .map(|r| {
            let mut rng = Pcg32::new(7, r as u64);
            let mut acc = 0.0f32;
            DeviceBuf::Real(
                (0..1 << 20)
                    .map(|_| {
                        acc += rng.next_gaussian() * 1e-3;
                        acc
                    })
                    .collect(),
            )
        })
        .collect();
    let expect: Vec<f32> = {
        let mut sum = vec![0.0f32; 1 << 20];
        for b in &inputs {
            for (s, v) in sum.iter_mut().zip(b.as_real()) {
                *s += v;
            }
        }
        sum
    };

    // gZ-Allreduce (ReDoub): real compression, virtual time.
    let report = run_collective(&spec, inputs, &allreduce_recursive_doubling)?;

    let out = report.outputs[0].as_real();
    let max_err = out
        .iter()
        .zip(&expect)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);

    println!("gZ-Allreduce (ReDoub) over {ranks} simulated GPUs");
    println!("  virtual makespan : {}", report.makespan);
    println!("  wire bytes       : {} (vs {} raw)", report.total_wire_bytes(), ranks * (1 << 22) * (ranks - 1) / ranks);
    println!("  cpr kernel calls : {}", report.total_cpr_calls());
    println!("  breakdown        : {}", report.total_breakdown().percent_string());
    println!("  max |err|        : {max_err:.2e} (log2({ranks}) stages x eb 1e-4)");
    assert!(max_err < 3.0 * 3.0 * 1e-4);
    println!("OK");
    Ok(())
}
