//! Quickstart: run one compression-accelerated Allreduce through the
//! unified [`Communicator`] API with an **accuracy target** — instead
//! of hand-picking a compressor error bound, ask for an end-to-end
//! L∞ ceiling and let the error-budget planner derive the per-call
//! bound — then inspect the report (makespan, planned bound, observed
//! error telemetry).
//!
//! The second half shows **adaptive mode** (`.adaptive(true)`): every
//! dispatch compiles an `ExecPlan` (one compression directive + error
//! bound per schedule leg), and with adaptation on, the telemetry
//! headroom of each call relaxes the next call's planned bounds —
//! monotonically, at most 8× per step, never past the certified
//! per-call budget, snapping back to the certified plan if an
//! observation ever exceeds it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gzccl::accuracy::AccuracyTarget;
use gzccl::comm::{CollectiveSpec, Communicator};
use gzccl::coordinator::{DeviceBuf, ExecPolicy};
use gzccl::testkit::Pcg32;

fn main() -> gzccl::Result<()> {
    // 8 simulated A100s (2 nodes x 4 GPUs), gZCCL policy. Rather than
    // `.error_bound(1e-4)`, hand the builder the end-to-end target: the
    // planner inverts the error-propagation model (anchored on the
    // hierarchical schedule this topology supports) and derives the
    // compressor bound; the tuner then refuses any algorithm whose
    // stage count would blow the budget.
    let ranks = 8;
    let target = 5e-4;
    let comm = Communicator::builder(ranks)
        .policy(ExecPolicy::gzccl())
        .accuracy_target(AccuracyTarget::AbsError(target))
        .build()?;
    let plan = comm.budget_plan().expect("compressed policy plans a budget");

    // Real per-rank payloads: 1M floats of smooth data each.
    let inputs: Vec<DeviceBuf> = (0..ranks)
        .map(|r| {
            let mut rng = Pcg32::new(7, r as u64);
            let mut acc = 0.0f32;
            DeviceBuf::Real(
                (0..1 << 20)
                    .map(|_| {
                        acc += rng.next_gaussian() * 1e-3;
                        acc
                    })
                    .collect(),
            )
        })
        .collect();
    let expect: Vec<f32> = {
        let mut sum = vec![0.0f32; 1 << 20];
        for b in &inputs {
            for (s, v) in sum.iter_mut().zip(b.as_real()) {
                *s += v;
            }
        }
        sum
    };

    // `CollectiveSpec::auto()` lets the tuner pick the algorithm from
    // the message size (4 MB), policy, topology — and now the budget:
    // here (2 nodes of 4 GPUs, compressed, below the ring crossover)
    // that lands on the hierarchical two-level schedule, whose single
    // compressed internode exchange is also the budget anchor.
    let report = comm.allreduce(inputs, &CollectiveSpec::auto())?;

    let out = report.outputs[0].as_real();
    let max_err = out
        .iter()
        .zip(&expect)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);

    println!("gZ-Allreduce over {ranks} simulated GPUs");
    println!("  accuracy target  : |err| <= {target:.1e} end-to-end");
    println!(
        "  planned per-call : eb {:.3e} ({}x amplification, anchored on {:?})",
        plan.eb, plan.amplification, plan.planned_algo
    );
    println!("  algorithm chosen : {:?} (auto-tuned: {})", report.algo, report.auto_tuned);
    println!("  virtual makespan : {}", report.makespan);
    println!("  wire bytes       : {} (vs {} raw)", report.total_wire_bytes(), ranks * (1 << 22) * (ranks - 1) / ranks);
    println!("  cpr kernel calls : {}", report.total_cpr_calls());
    println!("  breakdown        : {}", report.total_breakdown().percent_string());
    if let Some(acc) = report.accuracy {
        println!(
            "  telemetry        : observed {:.3e} vs predicted {:?} (within bound: {:?})",
            acc.observed_max_err,
            acc.prediction,
            acc.within_bound()
        );
    }
    println!("  max |err|        : {max_err:.2e} (target {target:.1e})");
    // 5% headroom over the certified bound absorbs f32 reassociation
    // noise between the reference loop and the collective's order.
    assert!((max_err as f64) <= target * 1.05, "budget violated");

    // --- Adaptive mode: close the telemetry loop ---------------------
    // A deeper cluster (64 nodes) pays 63 worst-case error stages, but
    // the observed error of the quantization random walk grows only
    // ~√stages — the certified plan leaves real headroom on the table.
    // `.adaptive(true)` harvests it: each call's telemetry relaxes the
    // next call's per-leg bounds, capped at the per-call budget.
    let n = 256;
    let adaptive = Communicator::builder(n)
        .policy(ExecPolicy::gzccl())
        .accuracy_target(AccuracyTarget::AbsError(63e-4))
        .adaptive(true)
        .build()?;
    let plan = adaptive.budget_plan().expect("compressed policy plans");
    println!(
        "adaptive Allreduce over {n} GPUs: certified eb {:.3e}, per-call budget {:.3e}",
        plan.eb, plan.per_call_abs
    );
    let per_call = plan.per_call_abs;
    for call in 0..3u64 {
        let inputs: Vec<DeviceBuf> = (0..n)
            .map(|r| {
                let mut rng = Pcg32::new(100 + call, r as u64);
                DeviceBuf::Real(rng.uniform_vec(512, -1.0, 1.0))
            })
            .collect();
        let rep = adaptive.allreduce(inputs, &CollectiveSpec::auto())?;
        let leg_eb = rep
            .legs
            .iter()
            .filter(|l| l.exec.compresses())
            .map(|l| l.exec.eb)
            .fold(0.0f64, f64::max);
        let obs = rep.accuracy.map(|a| a.observed_max_err).unwrap_or(0.0);
        println!(
            "  call {call}: leg eb {leg_eb:.3e} | observed {obs:.3e} | budget {per_call:.3e}"
        );
        assert!(obs <= per_call, "adaptation must never violate the per-call budget");
    }
    println!(
        "  next-call eb     : {:.3e} (telemetry-relaxed, certified plan was {:.3e})",
        adaptive.adaptive_eb().unwrap(),
        plan.eb
    );
    println!("OK");
    Ok(())
}
