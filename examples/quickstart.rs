//! Quickstart: run one compression-accelerated Allreduce through the
//! unified [`Communicator`] API and inspect the report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gzccl::comm::{CollectiveSpec, Communicator};
use gzccl::coordinator::{DeviceBuf, ExecPolicy};
use gzccl::testkit::Pcg32;

fn main() -> gzccl::Result<()> {
    // 8 simulated A100s (2 nodes x 4 GPUs), gZCCL policy, eb = 1e-4.
    let ranks = 8;
    let comm = Communicator::builder(ranks)
        .policy(ExecPolicy::gzccl())
        .error_bound(1e-4)
        .build()?;

    // Real per-rank payloads: 1M floats of smooth data each.
    let inputs: Vec<DeviceBuf> = (0..ranks)
        .map(|r| {
            let mut rng = Pcg32::new(7, r as u64);
            let mut acc = 0.0f32;
            DeviceBuf::Real(
                (0..1 << 20)
                    .map(|_| {
                        acc += rng.next_gaussian() * 1e-3;
                        acc
                    })
                    .collect(),
            )
        })
        .collect();
    let expect: Vec<f32> = {
        let mut sum = vec![0.0f32; 1 << 20];
        for b in &inputs {
            for (s, v) in sum.iter_mut().zip(b.as_real()) {
                *s += v;
            }
        }
        sum
    };

    // `CollectiveSpec::auto()` lets the tuner pick the algorithm from
    // the message size (4 MB), policy and topology — here (2 nodes of
    // 4 GPUs, compressed, below the ring crossover) that lands on the
    // hierarchical two-level schedule: NVLink-only intranode legs and
    // one compressed internode exchange between the node leaders.
    // `CollectiveSpec::forced(Algo::Ring)` would pin the ring instead.
    let report = comm.allreduce(inputs, &CollectiveSpec::auto())?;

    let out = report.outputs[0].as_real();
    let max_err = out
        .iter()
        .zip(&expect)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);

    println!("gZ-Allreduce over {ranks} simulated GPUs");
    println!("  algorithm chosen : {:?} (auto-tuned: {})", report.algo, report.auto_tuned);
    println!("  virtual makespan : {}", report.makespan);
    println!("  wire bytes       : {} (vs {} raw)", report.total_wire_bytes(), ranks * (1 << 22) * (ranks - 1) / ranks);
    println!("  cpr kernel calls : {}", report.total_cpr_calls());
    println!("  breakdown        : {}", report.total_breakdown().percent_string());
    println!("  max |err|        : {max_err:.2e} (log2({ranks}) stages x eb 1e-4)");
    assert!(max_err < 3.0 * 3.0 * 1e-4);
    println!("OK");
    Ok(())
}
