//! Tour of the non-blocking pipelined collectives.
//!
//! Three stops:
//!
//! 1. **Depth as a tuned axis** — on the paper's 512-rank 4x16x8
//!    testbed at 64 MiB, the dispatcher prices every pipeline depth
//!    with the cost model and the chunk-level leg overlap strictly
//!    beats the barrier executor.
//! 2. **Persistent plans** — `Communicator::persistent` runs
//!    selection, schedule compilation and depth choice once;
//!    `run`/`irun` replay the frozen plan every step.
//! 3. **A DDP step loop** — each step launches its gradient
//!    allreduce non-blocking (`irun`) and synthesizes the next
//!    batch while the collective flies, then verifies the overlapped
//!    loop is bit-identical to the synchronous one.
//!
//! ```bash
//! cargo run --release --example pipeline_tour
//! ```

use gzccl::collectives::{Algo, Op};
use gzccl::comm::{CollectiveSpec, Communicator, Pipeline};
use gzccl::coordinator::{DeviceBuf, ExecPolicy};
use gzccl::testkit::Pcg32;

const MIB: usize = 1 << 20;

fn main() -> gzccl::Result<()> {
    // ── Stop 1: the tuner picks the depth ──────────────────────────
    let n = 512;
    println!("512 ranks, 4x16x8 tiers, 64 MiB gZ-Allreduce:");
    let run = |pipeline: Pipeline| -> gzccl::Result<_> {
        let comm = Communicator::builder(n)
            .tiers(&[4, 16, 8])
            .policy(ExecPolicy::gzccl())
            .pipeline(pipeline)
            .build()?;
        let inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(64 * MIB / 4)).collect();
        comm.allreduce(inputs, &CollectiveSpec::auto())
    };
    let piped = run(Pipeline::Auto)?;
    let barrier = run(Pipeline::Off)?;
    println!(
        "  barrier (depth 1)        : {} ({:?})",
        barrier.makespan, barrier.algo
    );
    println!(
        "  pipelined (depth {})      : {}  — chunk k's internode leg\n\
         \x20                            overlaps chunk k+1's intranode reduce",
        piped.exec_plan.depth, piped.makespan
    );
    assert!(piped.exec_plan.depth > 1);
    assert!(piped.makespan.as_secs() < barrier.makespan.as_secs());
    let speedup = barrier.makespan.as_secs() / piped.makespan.as_secs();
    println!("  overlap speedup          : {speedup:.2}x");

    // ── Stop 2: plan once, run many ────────────────────────────────
    let ranks = 8;
    let params = 4096;
    let comm = Communicator::builder(ranks)
        .gpus_per_node(2)
        .error_bound(1e-4)
        .build()?;
    let spec = CollectiveSpec::forced(Algo::Hierarchical);
    let plan = comm.persistent(Op::Allreduce, params, &spec)?;
    println!(
        "\npersistent gradient plan: {:?}/{:?}, depth {} — per-step dispatch cost amortized",
        plan.op(),
        plan.algo(),
        plan.depth()
    );

    // ── Stop 3: overlap backward compute with the allreduce ────────
    // A mock DDP step: "gradients" are a deterministic function of the
    // batch, batch synthesis is the parameter-independent work we can
    // hide inside the collective's flight time.
    let steps = 5;
    let grads = |step: usize| -> Vec<DeviceBuf> {
        (0..ranks)
            .map(|r| {
                let mut rng = Pcg32::new(0xD0, (step * ranks + r) as u64);
                DeviceBuf::Real(rng.uniform_vec(params, -1.0, 1.0))
            })
            .collect()
    };

    // Synchronous reference: dispatch, then synthesize the next batch.
    let mut sync_out = Vec::new();
    let mut sync_comm_s = 0.0;
    for step in 0..steps {
        let report = plan.run(grads(step))?;
        sync_comm_s += report.makespan.as_secs();
        sync_out.push(report.outputs[0].as_real().to_vec());
        let _next = grads(step + 1); // batch synthesis AFTER the wait
    }

    // Overlapped: irun the collective, synthesize while it flies.
    let mut over_out = Vec::new();
    let mut batch = grads(0);
    for step in 0..steps {
        let handle = plan.irun(std::mem::take(&mut batch));
        batch = grads(step + 1); // batch synthesis DURING the flight
        let report = handle.wait()?;
        over_out.push(report.outputs[0].as_real().to_vec());
    }
    assert_eq!(sync_out, over_out, "overlap must not change a single bit");
    println!(
        "overlapped {steps}-step loop: {:.3} virtual ms of collective time,\n\
         batch synthesis hidden in flight — outputs bit-identical to the sync loop",
        sync_comm_s * 1e3
    );
    println!("OK");
    Ok(())
}
