//! Trace tour: the flight recorder on a multi-tenant contention run.
//!
//! Two tenant jobs window onto one physical cluster so that both push
//! ring traffic through rack 1's shared uplinks. With a tracer
//! attached to each tenant's spec, the contended run records every
//! rank's spans — including the `wait:*.t2` rack-uplink queue-wait
//! spans that exist only because the neighbor tenant is there — plus
//! per-tenant slowdown and Jain-fairness gauges, and exports the whole
//! thing as a Perfetto-loadable Chrome trace.
//!
//! ```bash
//! cargo run --release --example trace_tour
//! ```

use gzccl::collectives::allreduce_ring;
use gzccl::coordinator::{ClusterSpec, DeviceBuf, ExecPolicy};
use gzccl::engine::{run_multi_tenant, Tenant};
use gzccl::error::Error;
use gzccl::obs::Tracer;
use gzccl::topo::TierTree;

fn main() -> gzccl::Result<()> {
    // Physical machine: 16 GPUs as 2/node, 2 nodes/rack, 4 racks.
    let physical = ClusterSpec::with_tiers(TierTree::new(16, &[2, 2, 4])?, ExecPolicy::nccl());

    // Tenant A occupies leaves [2, 6) (straddling the rack0/rack1
    // boundary), tenant B leaves [6, 10) (straddling rack1/rack2):
    // both cross rack 1's uplinks every ring step. One shared tracer
    // records both tenants; their tracks are labeled `<name>/<rank>`.
    let tracer = Tracer::new();
    let tenant = |name: &str, base: usize| {
        let tree = TierTree::new(4, &[2, 2]).unwrap();
        let mut spec = ClusterSpec::with_tiers(tree, ExecPolicy::nccl());
        spec.trace = Some(tracer.clone());
        Tenant {
            name: name.into(),
            spec,
            base,
            inputs: (0..4).map(|_| DeviceBuf::Virtual(1 << 20)).collect(),
            program: Box::new(allreduce_ring),
        }
    };
    let report = run_multi_tenant(&physical, vec![tenant("job-a", 2), tenant("job-b", 6)])?;

    println!("multi-tenant contention on shared rack uplinks");
    for t in &report.tenants {
        println!(
            "  {:6}  contended {:8.3} ms | isolated {:8.3} ms | slowdown {:.3}x",
            t.name,
            t.makespan.as_secs() * 1e3,
            t.isolated_makespan.as_secs() * 1e3,
            t.slowdown
        );
    }
    println!("  Jain fairness index: {:.4}", report.fairness);

    // Drain the recorded tracks into one archived run and inspect it.
    let run = tracer.take_run(vec![
        ("scenario".into(), "two tenants, shared rack uplinks".into()),
        ("collective".into(), "allreduce_ring".into()),
    ]);
    println!("\n{}", run.summary());

    // The rack-uplink queue-wait spans record, per message, when it
    // was ready at a shared tier-2 uplink and how long it queued
    // behind the neighbor tenant's traffic.
    let mut uplink_waits = 0usize;
    let mut waited = 0.0f64;
    for track in run.tracks.values() {
        for s in &track.spans {
            if s.name.starts_with("wait:") && s.name.ends_with(".t2") {
                uplink_waits += 1;
                waited += s.dur;
            }
        }
    }
    println!(
        "rack-uplink (tier-2) queue-wait spans: {uplink_waits}, total wait {:.3} ms",
        waited * 1e3
    );

    // The same story, aggregated: the metrics registry folds every
    // rank's samples into per-link-class wire bytes, queue-wait
    // histograms, and the fairness gauges the tenant runner left.
    let reg = run.metrics_registry();
    if let Some(h) = reg.hist("queue_wait_s.uplink_t2") {
        println!(
            "queue_wait_s.uplink_t2: count {} | mean {:.3} ms | max {:.3} ms",
            h.count,
            h.mean() * 1e3,
            h.max * 1e3
        );
    }
    println!("wire_bytes.internode = {}", reg.counter("wire_bytes.internode"));
    for t in &report.tenants {
        if let Some(s) = reg.gauge(&format!("slowdown.{}", t.name)) {
            println!("gauge slowdown.{} = {s:.3}", t.name);
        }
    }
    if let Some(f) = reg.gauge("fairness.jain") {
        println!("gauge fairness.jain = {f:.4}");
    }

    // ---- Trace analytics: who sets the makespan, and why ----------
    // The analyzer chains span pieces and cross-rank message hops
    // into the critical path — the one chain of work that tiles
    // [0, makespan] — then rolls its seconds up by bottleneck
    // category. With two tenants hammering rack 1's uplinks, the
    // queue category (waits at shared fabric stages) is what
    // dominates the chain: the fabric is busy with the neighbor's
    // bytes, not slow.
    let analysis = run.analyze();
    println!("\n{analysis}");
    let total = analysis.critical_path.total_s();
    if let Some((cat, share)) = analysis.bottlenecks.dominant(total) {
        println!(
            "dominant category: {} at {:.1}% of the {:.3} ms critical path",
            cat.label(),
            share * 100.0,
            total * 1e3
        );
    }
    let queue = analysis.bottlenecks.category_s(gzccl::obs::analysis::Category::Queue);
    println!(
        "rack-uplink queueing on the path: {:.3} ms ({:.1}%)",
        queue * 1e3,
        if total > 0.0 { queue / total * 100.0 } else { 0.0 }
    );

    // ---- Calibration: fold the measurement back into the model ----
    // The least-squares fit prices each crossing tier at its
    // *effective* latency/bandwidth — contention included — so the
    // fitted tier-2 uplink comes out well below nameplate. Hand the
    // run to `CommBuilder::calibrate_from` and the tuner schedules
    // with these numbers instead of the spec sheet.
    let cal = gzccl::obs::calibrate::calibrate(&run, &physical.gpu, &physical.tier_links());
    print!("\n{cal}");

    // Perfetto-loadable export: open trace_tour.json in
    // https://ui.perfetto.dev — one process per tenant rank
    // (`job-a/0` ... `job-b/3`), lanes as threads, virtual time as
    // the track clock. The critical path rides along as its own
    // top-sorted track.
    let extra = gzccl::obs::export::critical_path_events(&analysis, 0.0);
    std::fs::write(
        "trace_tour.json",
        gzccl::obs::export::chrome_json_with_extra(&[run.as_ref()], &extra),
    )
    .map_err(Error::Io)?;
    std::fs::write("trace_tour.metrics.json", reg.to_json()).map_err(Error::Io)?;
    println!("\nwrote trace_tour.json + trace_tour.metrics.json");
    Ok(())
}
