//! Regenerates Fig. 12: Scatter scalability to 512 GPUs.
use gzccl::bench_support::bench;
use gzccl::experiments::fig12_scatter_scale;

fn main() {
    let (table, stats) = bench(1, || fig12_scatter_scale().unwrap());
    table.print();
    println!("[bench fig12] {stats}");
}
