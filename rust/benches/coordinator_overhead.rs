//! §Perf hot-path bench: wall-clock cost of the coordinator itself
//! (thread spawn, channels, virtual-time accounting) relative to the
//! virtual time it simulates.
use gzccl::bench_support::bench;
use gzccl::collectives::allreduce_recursive_doubling;
use gzccl::coordinator::{run_collective, ClusterSpec, DeviceBuf, ExecPolicy};

fn main() {
    for ranks in [8usize, 64, 256] {
        let inputs = || -> Vec<DeviceBuf> {
            (0..ranks).map(|_| DeviceBuf::Virtual((64 << 20) / 4)).collect()
        };
        let spec = ClusterSpec::new(ranks, ExecPolicy::gzccl());
        let (report, stats) = bench(5, || {
            run_collective(&spec, inputs(), &allreduce_recursive_doubling).unwrap()
        });
        println!(
            "{ranks:4} ranks, 64 MB virtual allreduce: wall {:8.2}ms for {:8.2}ms virtual ({} msgs)",
            stats.min * 1e3,
            report.makespan.as_secs() * 1e3,
            report.counters.iter().map(|c| c.msgs_sent).sum::<usize>(),
        );
    }
}
