//! §Perf engine-scale bench: ranks-vs-wall-time for the event-driven
//! execution engine.
//!
//! Sweeps the 64 MiB compressed hierarchical Allreduce from 512 to
//! 16384 ranks under the event backend — wall time must grow with the
//! *event* count, not the rank count — and keeps the thread-per-rank
//! oracle in the 512-rank row as the overhead yardstick. Emits
//! `BENCH_engine.json` at the workspace root; CI archives it per
//! commit and diffs consecutive artifacts with `bench_trend.py`
//! (rows carry a `backend` column so the two runners trend
//! independently).

use gzccl::bench_support::bench;
use gzccl::collectives::Algo;
use gzccl::comm::{CollectiveSpec, Communicator};
use gzccl::coordinator::{DeviceBuf, ExecBackend, ExecPolicy};

fn tiers_label(widths: &[usize]) -> String {
    widths
        .iter()
        .map(|w| w.to_string())
        .collect::<Vec<_>>()
        .join("x")
}

/// Virtual makespan plus total messages of one compressed
/// hierarchical Allreduce on `ranks` laid out as `widths`.
fn makespan(ranks: usize, widths: &[usize], bytes: usize, backend: ExecBackend) -> (f64, usize) {
    let comm = Communicator::builder(ranks)
        .tiers(widths)
        .policy(ExecPolicy::gzccl())
        .error_bound(1e-4)
        .backend(backend)
        .build()
        .expect("communicator");
    let inputs: Vec<DeviceBuf> = (0..ranks).map(|_| DeviceBuf::Virtual(bytes / 4)).collect();
    let report = comm
        .allreduce(inputs, &CollectiveSpec::forced(Algo::Hierarchical))
        .expect("allreduce");
    let msgs = report.counters.iter().map(|c| c.msgs_sent).sum();
    (report.makespan.as_secs(), msgs)
}

fn main() {
    // 512 → 16384 ranks on node/rack layouts; the thread oracle runs
    // only the 512-rank row (it spawns one OS thread per rank — its
    // design cap is exactly what this engine removes).
    let layouts: [(usize, &[usize], &[ExecBackend]); 6] = [
        (512, &[4, 16, 8], &[ExecBackend::Events, ExecBackend::Threads]),
        (1024, &[4, 16, 16], &[ExecBackend::Events]),
        (2048, &[8, 16, 16], &[ExecBackend::Events]),
        (4096, &[8, 16, 32], &[ExecBackend::Events]),
        (8192, &[8, 32, 32], &[ExecBackend::Events]),
        (16384, &[8, 32, 64], &[ExecBackend::Events]),
    ];
    let mb = 64usize;

    let mut rows = Vec::new();
    for &(ranks, widths, backends) in &layouts {
        let label = tiers_label(widths);
        for &backend in backends {
            let runs = if ranks >= 8192 { 1 } else { 2 };
            let ((virt_s, msgs), stats) =
                bench(runs, || makespan(ranks, widths, mb << 20, backend));
            println!(
                "{backend:>7} | {ranks:>5} ranks | tiers {label:>8} | {mb:>3} MiB | \
                 virtual {:.3} ms | {msgs:>7} msgs | wall {stats}",
                virt_s * 1e3
            );
            rows.push(format!(
                concat!(
                    "    {{\"algo\": \"hier\", \"backend\": \"{}\", \"ranks\": {}, ",
                    "\"gpus_per_node\": {}, \"tiers\": \"{}\", \"size_mib\": {}, ",
                    "\"virtual_makespan_s\": {:.9}, \"msgs\": {}, ",
                    "\"wall_mean_s\": {:.6}, \"wall_min_s\": {:.6}, \"wall_runs\": {}}}"
                ),
                backend, ranks, widths[0], label, mb, virt_s, msgs, stats.mean, stats.min,
                stats.runs
            ));
        }
    }

    let json = format!(
        "{{\n  {},\n  \"bench\": \"engine_rank_scale\",\n  \"policy\": \"gzccl\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        gzccl::bench_support::schema_stamp(),
        rows.join(",\n")
    );
    // `cargo bench` runs the harness with CWD set to the *package*
    // root (rust/); anchor the artifact at the workspace root where CI
    // expects it.
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::PathBuf::from(dir).join("..").join("BENCH_engine.json"),
        Err(_) => std::path::PathBuf::from("BENCH_engine.json"),
    };
    std::fs::write(&path, &json).expect("write BENCH_engine.json");
    println!("wrote {} ({} rows)", path.display(), rows.len());
}
