//! §Perf hot-path bench: real compressor encode/decode throughput on
//! RTM-like data (the L3 hot loop of every real-payload collective).
use gzccl::bench_support::{bench, throughput_gbps};
use gzccl::compress::{ratio, Compressor, CuszpLike, FixedRate};
use gzccl::data::RtmDataset;

fn main() {
    let data = RtmDataset::setting1().sample(8 << 20); // 32 MB
    let bytes = data.len() * 4;
    for eb in [1e-3, 1e-4, 1e-5] {
        let c = CuszpLike::new(eb);
        let (stream, enc) = bench(3, || c.compress(&data));
        let (_, dec) = bench(3, || c.decompress(&stream).unwrap());
        println!(
            "cuszp-like eb={eb:.0e}: encode {:6.2} GB/s  decode {:6.2} GB/s  ratio {:6.2}",
            throughput_gbps(bytes, enc.min),
            throughput_gbps(bytes, dec.min),
            ratio(bytes, stream.len()),
        );
    }
    let c = FixedRate::new(8);
    let (stream, enc) = bench(3, || c.compress(&data));
    let (_, dec) = bench(3, || c.decompress(&stream).unwrap());
    println!(
        "fixed-rate(8b):   encode {:6.2} GB/s  decode {:6.2} GB/s  ratio {:6.2}",
        throughput_gbps(bytes, enc.min),
        throughput_gbps(bytes, dec.min),
        ratio(bytes, stream.len()),
    );
}
