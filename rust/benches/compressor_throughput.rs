//! §Perf hot-path bench: staged-codec encode/decode throughput on
//! RTM-like data (the L3 hot loop of every real-payload collective).
//!
//! Benches every canonical codec plus the stage-isolating compositions
//! a differential attribution needs, prints per-stage columns
//! (predictor | quantizer | coder) for each row, and emits
//! `BENCH_codec.json` at the workspace root — the codec-throughput
//! trend artifact CI archives per commit (non-blocking trend job, same
//! shape as the allreduce and engine sweeps).

use gzccl::bench_support::{bench, throughput_gbps};
use gzccl::compress::{ratio, CodecSpec, CoderKind, Compressor, PredictorKind, QuantizerKind};
use gzccl::data::RtmDataset;

fn predictor_name(s: CodecSpec) -> &'static str {
    match s.predictor {
        PredictorKind::None => "none",
        PredictorKind::Lorenzo1D => "lorenzo",
    }
}

fn quantizer_name(s: CodecSpec) -> String {
    match s.quantizer {
        QuantizerKind::Prequant => "prequant".into(),
        QuantizerKind::Lossless => "lossless".into(),
        QuantizerKind::FixedRate(b) => format!("fixed{b}"),
    }
}

fn coder_name(s: CodecSpec) -> &'static str {
    match s.coder {
        CoderKind::Bitpack => "bitpack",
        CoderKind::Byteplane => "byteplane",
        CoderKind::RleRice => "rice",
    }
}

struct Row {
    spec: CodecSpec,
    encode_s: f64,
    decode_s: f64,
    stream_len: usize,
}

fn main() {
    let data = RtmDataset::setting1().sample(8 << 20); // 32 MiB
    let bytes = data.len() * 4;
    let size_mib = bytes >> 20;
    let eb = 1e-4;

    // The canonical pipelines plus the compositions that isolate one
    // stage swap each (for the differential attribution below).
    let specs = [
        CodecSpec::cuszp(), // lorenzo+prequant+bitpack
        CodecSpec::parse("none+prequant+bitpack").unwrap(),
        CodecSpec::parse("lorenzo+prequant+byteplane").unwrap(),
        CodecSpec::rle_rice(), // lorenzo+prequant+rice
        CodecSpec::lossless(), // lorenzo+lossless+byteplane
        CodecSpec::parse("lorenzo+lossless+bitpack").unwrap(),
        CodecSpec::fixed_rate(8),
    ];

    println!(
        "{:<28} {:>9} {:>9} {:>9} | encode GB/s | decode GB/s | ratio",
        "codec", "predictor", "quantizer", "coder"
    );
    let mut rows = Vec::new();
    for spec in specs {
        let c = spec.build(eb).expect("composition must build");
        let (stream, enc) = bench(3, || c.compress(&data));
        let (_, dec) = bench(3, || c.decompress(&stream).unwrap());
        println!(
            "{:<28} {:>9} {:>9} {:>9} | {:>11.2} | {:>11.2} | {:6.2}",
            spec.label(),
            predictor_name(spec),
            quantizer_name(spec),
            coder_name(spec),
            throughput_gbps(bytes, enc.min),
            throughput_gbps(bytes, dec.min),
            ratio(bytes, stream.len()),
        );
        rows.push(Row {
            spec,
            encode_s: enc.min,
            decode_s: dec.min,
            stream_len: stream.len(),
        });
    }

    // Differential stage attribution on the encode path: swap exactly
    // one stage against the canonical lorenzo+prequant+bitpack pipeline
    // and report the wall-clock delta that stage costs (noisy, for
    // orientation — the JSON rows are the trend signal).
    let enc_of = |s: CodecSpec| {
        rows.iter()
            .find(|r| r.spec == s)
            .map(|r| r.encode_s)
            .unwrap_or(f64::NAN)
    };
    let base = enc_of(CodecSpec::cuszp());
    println!(
        "\nencode stage deltas vs cuszp ({:.1} ms): predictor(lorenzo) {:+.1} ms, \
         coder(byteplane) {:+.1} ms, coder(rice) {:+.1} ms",
        base * 1e3,
        (base - enc_of(CodecSpec::parse("none+prequant+bitpack").unwrap())) * 1e3,
        (enc_of(CodecSpec::parse("lorenzo+prequant+byteplane").unwrap()) - base) * 1e3,
        (enc_of(CodecSpec::rle_rice()) - base) * 1e3,
    );

    let json_rows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "    {{\"codec\": \"{}\", \"predictor\": \"{}\", ",
                    "\"quantizer\": \"{}\", \"coder\": \"{}\", \"size_mib\": {}, ",
                    "\"encode_s\": {:.6}, \"decode_s\": {:.6}, ",
                    "\"encode_gbps\": {:.3}, \"decode_gbps\": {:.3}, \"ratio\": {:.3}}}"
                ),
                r.spec.label(),
                predictor_name(r.spec),
                quantizer_name(r.spec),
                coder_name(r.spec),
                size_mib,
                r.encode_s,
                r.decode_s,
                throughput_gbps(bytes, r.encode_s),
                throughput_gbps(bytes, r.decode_s),
                ratio(bytes, r.stream_len),
            )
        })
        .collect();
    let json = format!(
        "{{\n  {},\n  \"bench\": \"codec_throughput\",\n  \"eb\": {eb:e},\n  \"rows\": [\n{}\n  ]\n}}\n",
        gzccl::bench_support::schema_stamp(),
        json_rows.join(",\n")
    );
    // `cargo bench` runs with CWD at the package root (rust/); anchor
    // the artifact at the workspace root where CI expects it.
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::PathBuf::from(dir).join("..").join("BENCH_codec.json"),
        Err(_) => std::path::PathBuf::from("BENCH_codec.json"),
    };
    std::fs::write(&path, &json).expect("write BENCH_codec.json");
    println!("wrote {} ({} rows)", path.display(), rows.len());
}
