//! Pipeline-depth sweep for the hierarchical gZ-Allreduce.
//!
//! Grids pipeline depth × message size at the paper's 512-rank 4x16x8
//! acceptance shape (plus a smaller 128-rank layout), forced depths 1,
//! 2, 4, 8 alongside the dispatcher's `Pipeline::Auto` pick. Each row
//! records the virtual makespan, the analyzer's critical-path length,
//! and an `exposed_comm_s` column — the wire + queue seconds left ON
//! the critical path, i.e. the communication the chunk-level overlap
//! failed to hide behind kernels. Emits `BENCH_pipeline.json` at the
//! workspace root, the trend artifact CI archives per commit (the
//! trend script keys rows by depth, tolerating artifacts from before
//! the column existed).

use gzccl::bench_support::{bench, schema_stamp};
use gzccl::collectives::Algo;
use gzccl::comm::{CollectiveSpec, Communicator, Pipeline};
use gzccl::coordinator::{DeviceBuf, ExecPolicy};
use gzccl::obs::analysis::Category;
use gzccl::obs::Tracer;

fn tiers_label(widths: &[usize]) -> String {
    widths
        .iter()
        .map(|w| w.to_string())
        .collect::<Vec<_>>()
        .join("x")
}

/// One traced hierarchical dispatch under `pipeline`: executed depth,
/// virtual makespan, exposed communication (critical-path wire+queue
/// seconds), path length and dominant bottleneck.
fn makespan(
    ranks: usize,
    widths: &[usize],
    bytes: usize,
    pipeline: Pipeline,
) -> (usize, f64, f64, f64, String) {
    let comm = Communicator::builder(ranks)
        .tiers(widths)
        .policy(ExecPolicy::gzccl())
        .error_bound(1e-4)
        .pipeline(pipeline)
        .trace(Tracer::new())
        .build()
        .expect("communicator");
    let inputs: Vec<DeviceBuf> = (0..ranks).map(|_| DeviceBuf::Virtual(bytes / 4)).collect();
    let report = comm
        .allreduce(inputs, &CollectiveSpec::forced(Algo::Hierarchical))
        .expect("allreduce");
    let analysis = report.trace.as_ref().expect("traced run").analyze();
    let critical_path_s = analysis.critical_path.total_s();
    let exposed_comm_s = analysis.bottlenecks.category_s(Category::Wire)
        + analysis.bottlenecks.category_s(Category::Queue);
    let bottleneck = analysis
        .bottlenecks
        .dominant(critical_path_s)
        .map(|(c, _)| c.label().to_string())
        .unwrap_or_default();
    (
        report.exec_plan.depth,
        report.makespan.as_secs(),
        exposed_comm_s,
        critical_path_s,
        bottleneck,
    )
}

fn main() {
    let layouts: [(usize, &[usize]); 2] = [(128, &[4, 8, 4]), (512, &[4, 16, 8])];
    let sizes_mb = [4usize, 16, 64];
    let pipelines = [
        ("1", Pipeline::Off),
        ("2", Pipeline::Fixed(2)),
        ("4", Pipeline::Fixed(4)),
        ("8", Pipeline::Fixed(8)),
        ("auto", Pipeline::Auto),
    ];

    let mut rows = Vec::new();
    for &(ranks, widths) in &layouts {
        let label = tiers_label(widths);
        for &mb in &sizes_mb {
            for &(name, pipeline) in &pipelines {
                let ((depth, virt_s, exposed_s, cp_s, bottleneck), stats) =
                    bench(2, || makespan(ranks, widths, mb << 20, pipeline));
                println!(
                    "depth {name:>4} (ran {depth}) | {ranks:>4} ranks | tiers {label:>7} | \
                     {mb:>3} MiB | virtual {:.3} ms | exposed comm {:.3} ms | \
                     bottleneck {bottleneck:>6} | wall {stats}",
                    virt_s * 1e3,
                    exposed_s * 1e3
                );
                rows.push(format!(
                    concat!(
                        "    {{\"algo\": \"hier\", \"pipeline\": \"{}\", \"depth\": {}, ",
                        "\"ranks\": {}, \"gpus_per_node\": {}, \"tiers\": \"{}\", ",
                        "\"size_mib\": {}, \"virtual_makespan_s\": {:.9}, ",
                        "\"exposed_comm_s\": {:.9}, \"critical_path_s\": {:.9}, ",
                        "\"bottleneck\": \"{}\", ",
                        "\"wall_mean_s\": {:.6}, \"wall_min_s\": {:.6}, \"wall_runs\": {}}}"
                    ),
                    name, depth, ranks, widths[0], label, mb, virt_s, exposed_s, cp_s,
                    bottleneck, stats.mean, stats.min, stats.runs
                ));
            }
        }
    }

    let json = format!(
        "{{\n  {},\n  \"bench\": \"allreduce_pipeline_sweep\",\n  \"policy\": \"gzccl\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        schema_stamp(),
        rows.join(",\n")
    );
    // `cargo bench` runs the harness with CWD set to the *package*
    // root (rust/); anchor the artifact at the workspace root where CI
    // expects it.
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::PathBuf::from(dir).join("..").join("BENCH_pipeline.json"),
        Err(_) => std::path::PathBuf::from("BENCH_pipeline.json"),
    };
    std::fs::write(&path, &json).expect("write BENCH_pipeline.json");
    println!(
        "wrote {} ({} rows)",
        path.display(),
        layouts.len() * sizes_mb.len() * pipelines.len()
    );
}
