//! Regenerates Fig. 2: ring-Allreduce breakdown under CPRP2P vs C-Coll.
use gzccl::bench_support::bench;
use gzccl::experiments::fig02_breakdown;

fn main() {
    let (table, stats) = bench(3, || fig02_breakdown(64, 646 << 20).unwrap());
    table.print();
    println!("[bench fig02] {stats}");
}
