//! Regenerates Fig. 3: compressor execution time vs data size, plus the
//! real Rust compressor's measured throughput on this host.
use gzccl::bench_support::{bench, throughput_gbps};
use gzccl::compress::{Compressor, CuszpLike};
use gzccl::experiments::fig03_characterization;
use gzccl::testkit::Pcg32;

fn main() {
    let (table, stats) = bench(3, || fig03_characterization().unwrap());
    table.print();
    println!("[bench fig03] {stats}");

    // Measured: the real compressor on uniform data (the paper's Fig. 3
    // workload), host CPU.
    let mut rng = Pcg32::seeded(1);
    let data = rng.uniform_vec(16 << 20, 0.0, 1.0); // 64 MB
    let c = CuszpLike::new(1e-4);
    let (stream, enc) = bench(3, || c.compress(&data));
    let (_, dec) = bench(3, || c.decompress(&stream).unwrap());
    println!(
        "[bench fig03] rust cuszp-like on 64 MB uniform: encode {:.2} GB/s, decode {:.2} GB/s, ratio {:.2}",
        throughput_gbps(data.len() * 4, enc.min),
        throughput_gbps(data.len() * 4, dec.min),
        (data.len() * 4) as f64 / stream.len() as f64,
    );
}
