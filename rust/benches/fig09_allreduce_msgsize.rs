//! Regenerates Fig. 9: Allreduce vs Cray MPI / NCCL across sizes.
use gzccl::bench_support::bench;
use gzccl::experiments::fig09_msgsize;

fn main() {
    let (table, stats) = bench(1, || fig09_msgsize(64, 4).unwrap());
    table.print();
    println!("[bench fig09] {stats}");
}
