//! Regenerates Table 2: image-stacking speedups + phase breakdown.
use gzccl::bench_support::bench;
use gzccl::experiments::table2_stacking;

fn main() {
    let (table, stats) = bench(1, || table2_stacking(64, 256 << 20).unwrap());
    table.print();
    println!("[bench table2] {stats}");
}
