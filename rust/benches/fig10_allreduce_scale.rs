//! Regenerates Fig. 10: Allreduce scalability to 512 GPUs.
use gzccl::bench_support::bench;
use gzccl::experiments::fig10_scale;

fn main() {
    let (table, stats) = bench(1, || fig10_scale(4).unwrap());
    table.print();
    println!("[bench fig10] {stats}");
}
