//! Flat vs hierarchical Allreduce sweep.
//!
//! Sweeps rank counts and message sizes under the full gZCCL policy,
//! comparing the flat ring, flat gZ-ReDoub and the hierarchical
//! schedule — on the classic 2-tier layout (4 GPUs per node) *and* on
//! a 3-tier node/rack layout whose oversubscribed rack uplinks the
//! tier-aware fabric models. Emits the virtual makespans plus
//! wall-clock regeneration stats as `BENCH_allreduce.json` in the
//! working directory — the perf trajectory artifact CI archives per
//! commit, with a `tiers` column so tier-depth regressions show up in
//! the trend job, a `leg_ebs` column recording the executed plan's
//! per-leg compressor bounds, and `critical_path_s`/`bottleneck`
//! columns from the trace analyzer — the path length cross-checks the
//! makespan and the dominant category explains a shift (the trend
//! script tolerates artifacts from before any of these columns
//! existed).

use gzccl::bench_support::{bench, schema_stamp};
use gzccl::collectives::Algo;
use gzccl::comm::{CollectiveSpec, Communicator};
use gzccl::coordinator::{DeviceBuf, ExecPolicy};
use gzccl::obs::Tracer;

fn tiers_label(widths: &[usize]) -> String {
    widths
        .iter()
        .map(|w| w.to_string())
        .collect::<Vec<_>>()
        .join("x")
}

/// Virtual makespan plus the executed plan's per-leg eb column
/// (`"t1:1.0e-4+t2:1.0e-4"` — compressed legs only, empty when nothing
/// compresses), the analyzer's critical-path length (equal to the
/// makespan by invariant — the trend job cross-checks the pair) and
/// its dominant bottleneck category.
fn makespan(ranks: usize, widths: &[usize], bytes: usize, algo: Algo) -> (f64, String, f64, String) {
    let comm = Communicator::builder(ranks)
        .tiers(widths)
        .policy(ExecPolicy::gzccl())
        .error_bound(1e-4)
        .trace(Tracer::new())
        .build()
        .expect("communicator");
    let inputs: Vec<DeviceBuf> = (0..ranks).map(|_| DeviceBuf::Virtual(bytes / 4)).collect();
    let report = comm
        .allreduce(inputs, &CollectiveSpec::forced(algo))
        .expect("allreduce");
    let leg_ebs = report
        .legs
        .iter()
        .filter(|l| l.exec.compresses())
        .map(|l| format!("t{}:{:.1e}", l.tier, l.exec.eb))
        .collect::<Vec<_>>()
        .join("+");
    let analysis = report.trace.as_ref().expect("traced run").analyze();
    let critical_path_s = analysis.critical_path.total_s();
    let bottleneck = analysis
        .bottlenecks
        .dominant(critical_path_s)
        .map(|(c, _)| c.label().to_string())
        .unwrap_or_default();
    (report.makespan.as_secs(), leg_ebs, critical_path_s, bottleneck)
}

fn main() {
    // 2-tier sweeps (the PR 2 baseline shape) plus a 3-tier node/rack
    // sweep: 128 ranks as 4 GPUs/node × 8 nodes/rack × 4 racks.
    let layouts: [(usize, &[usize]); 3] = [
        (32, &[4, 8]),
        (128, &[4, 32]),
        (128, &[4, 8, 4]),
    ];
    let sizes_mb = [16usize, 64, 256];
    let algos = [
        ("ring", Algo::Ring),
        ("redoub", Algo::RecursiveDoubling),
        ("hier", Algo::Hierarchical),
    ];

    let mut rows = Vec::new();
    for &(ranks, widths) in &layouts {
        let label = tiers_label(widths);
        for &mb in &sizes_mb {
            for &(name, algo) in &algos {
                let ((virt_s, leg_ebs, cp_s, bottleneck), stats) =
                    bench(2, || makespan(ranks, widths, mb << 20, algo));
                println!(
                    "{name:>7} | {ranks:>4} ranks | tiers {label:>8} | {mb:>4} MiB | \
                     virtual {:.3} ms | bottleneck {bottleneck:>6} | legs {leg_ebs:>18} | \
                     wall {stats}",
                    virt_s * 1e3
                );
                rows.push(format!(
                    concat!(
                        "    {{\"algo\": \"{}\", \"ranks\": {}, \"gpus_per_node\": {}, ",
                        "\"tiers\": \"{}\", \"size_mib\": {}, \"virtual_makespan_s\": {:.9}, ",
                        "\"leg_ebs\": \"{}\", ",
                        "\"critical_path_s\": {:.9}, \"bottleneck\": \"{}\", ",
                        "\"wall_mean_s\": {:.6}, \"wall_min_s\": {:.6}, \"wall_runs\": {}}}"
                    ),
                    name, ranks, widths[0], label, mb, virt_s, leg_ebs, cp_s, bottleneck,
                    stats.mean, stats.min, stats.runs
                ));
            }
        }
    }

    let json = format!(
        "{{\n  {},\n  \"bench\": \"allreduce_flat_vs_hier\",\n  \"policy\": \"gzccl\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        schema_stamp(),
        rows.join(",\n")
    );
    // `cargo bench` runs the harness with CWD set to the *package*
    // root (rust/); anchor the artifact at the workspace root where CI
    // expects it.
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::PathBuf::from(dir).join("..").join("BENCH_allreduce.json"),
        Err(_) => std::path::PathBuf::from("BENCH_allreduce.json"),
    };
    std::fs::write(&path, &json).expect("write BENCH_allreduce.json");
    println!(
        "wrote {} ({} rows)",
        path.display(),
        layouts.len() * sizes_mb.len() * algos.len()
    );
}
