//! Regenerates Fig. 7: gZ-Allreduce optimization gains vs the
//! unoptimized GPU-centric baseline.
use gzccl::bench_support::bench;
use gzccl::experiments::fig07_allreduce_opt;

fn main() {
    let (table, stats) = bench(1, || fig07_allreduce_opt(64).unwrap());
    table.print();
    println!("[bench fig07] {stats}");
}
