//! Regenerates Fig. 6: GPU-centric vs CPU-centric Allreduce (both datasets).
use gzccl::bench_support::bench;
use gzccl::experiments::{fig06_gpu_centric, Dataset};

fn main() {
    for ds in [Dataset::Rtm1, Dataset::Rtm2] {
        let (table, stats) = bench(1, move || fig06_gpu_centric(64, ds).unwrap());
        table.print();
        println!("[bench fig06 {}] {stats}", ds.name());
    }
}
