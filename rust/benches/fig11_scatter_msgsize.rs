//! Regenerates Fig. 11: Scatter vs Cray MPI across sizes.
use gzccl::bench_support::bench;
use gzccl::experiments::fig11_scatter_msgsize;

fn main() {
    let (table, stats) = bench(1, || fig11_scatter_msgsize(64).unwrap());
    table.print();
    println!("[bench fig11] {stats}");
}
