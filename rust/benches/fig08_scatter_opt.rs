//! Regenerates Fig. 8: gZ-Scatter optimization gains.
use gzccl::bench_support::bench;
use gzccl::experiments::fig08_scatter_opt;

fn main() {
    let (table, stats) = bench(1, || fig08_scatter_opt(64).unwrap());
    table.print();
    println!("[bench fig08] {stats}");
}
