//! Regenerates Fig. 13: stacking accuracy (real data, real compression)
//! + PGM visualizations under artifacts/fig13/.
use gzccl::bench_support::bench;
use gzccl::experiments::fig13_accuracy;
use gzccl::runtime::Engine;

fn main() {
    let engine = Engine::discover().ok();
    let dir = std::path::PathBuf::from("artifacts/fig13");
    let (table, stats) = bench(1, || {
        fig13_accuracy(16, engine.as_ref(), Some(&dir)).unwrap()
    });
    table.print();
    println!("[bench fig13] {stats} (PGMs in {})", dir.display());
}
