//! Regenerates Table 1: compression ratio + PSNR on both RTM datasets.
use gzccl::bench_support::bench;
use gzccl::experiments::table1_compression;

fn main() {
    // 8M values/dataset: representative sample, minutes-not-hours.
    let (table, stats) = bench(1, || table1_compression(1 << 23).unwrap());
    table.print();
    println!("[bench table1] {stats}");
}
