//! Roundtrip property suite for the staged codec pipeline.
//!
//! Every (predictor × quantizer × coder) composition must round-trip
//! each payload class — empty, constant, NaN-free random, and
//! non-block-multiple lengths — bit-exactly for the lossless tier and
//! within the error bound for the quantizing tiers. A separate test
//! pins the cuSZp-like stream byte-for-byte against an independent
//! reference encoder written longhand from the format description, so
//! pipeline refactors cannot silently change the wire format.

use gzccl::compress::{decode_any, CodecSpec, Compressor, CuszpLike, QuantizerKind};
use gzccl::testkit::Pcg32;

const EB: f64 = 1e-3;

/// (name, payload) classes the whole matrix must survive.
fn payloads() -> Vec<(&'static str, Vec<f32>)> {
    let mut rng = Pcg32::seeded(0xC0DEC);
    vec![
        ("empty", Vec::new()),
        ("single", vec![-3.5f32]),
        // 101 = 3 blocks + 5: constant data plus a partial final block.
        ("constant", vec![7.25f32; 101]),
        ("random", rng.uniform_vec(1000, -50.0, 50.0)),
        ("random-short", rng.uniform_vec(31, -50.0, 50.0)),
        ("random-block-edge", rng.uniform_vec(33, -50.0, 50.0)),
    ]
}

fn max_abs(data: &[f32]) -> f64 {
    data.iter().fold(0.0f64, |m, &x| m.max(x.abs() as f64))
}

#[test]
fn every_composition_survives_every_payload_class() {
    for (name, data) in payloads() {
        for spec in CodecSpec::compositions(12) {
            let c = spec
                .build(EB)
                .unwrap_or_else(|| panic!("{} unbuildable at eb {EB}", spec.label()));
            let stream = c.compress(&data);
            let back = c.decompress(&stream).unwrap();
            let ctx = format!("{} on `{name}`", spec.label());
            assert_eq!(back.len(), data.len(), "{ctx}: length");
            // Streams are self-describing: the codec-blind entry point
            // must reproduce the owning compressor's decode exactly.
            let blind = decode_any(&stream).unwrap();
            for (a, b) in blind.iter().zip(back.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: decode_any");
            }
            match spec.quantizer {
                QuantizerKind::Lossless => {
                    for (a, b) in back.iter().zip(data.iter()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: bit-exact");
                    }
                }
                QuantizerKind::Prequant => {
                    let tol = EB + 1e-4;
                    for (i, (a, b)) in back.iter().zip(data.iter()).enumerate() {
                        assert!(
                            ((a - b).abs() as f64) <= tol,
                            "{ctx}: |err| at {i}: {a} vs {b}"
                        );
                    }
                }
                QuantizerKind::FixedRate(_) => {
                    // Per-block relative bound; the block scale never
                    // exceeds the payload's max magnitude.
                    let tol = max_abs(&data) / 2047.0 + 1e-4;
                    for (i, (a, b)) in back.iter().zip(data.iter()).enumerate() {
                        assert!(
                            ((a - b).abs() as f64) <= tol,
                            "{ctx}: |err| at {i}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn quantizer_overflow_falls_back_lossless_in_every_composition() {
    // Magnitudes that overflow the prequant range force verbatim
    // blocks, which must be lossless for the error-bounded tiers.
    let data = vec![1e30f32, -1e30, 5e29, 0.0];
    for spec in CodecSpec::compositions(12) {
        if matches!(spec.quantizer, QuantizerKind::FixedRate(_)) {
            continue; // fixed-rate scales per block instead of overflowing
        }
        let c = spec.build(EB).unwrap();
        let back = c.decompress(&c.compress(&data)).unwrap();
        assert_eq!(back, data, "{}", spec.label());
    }
}

// ---------------------------------------------------------------------
// Byte-pinning: an independent longhand encoder for the cuSZp-like
// format. Deliberately re-implements zigzag/varint/bit-packing rather
// than importing the library helpers — the assertion below is the
// format specification, not a tautology.
// ---------------------------------------------------------------------

fn ref_zigzag(v: i32) -> u32 {
    ((v << 1) ^ (v >> 31)) as u32
}

fn ref_varint(out: &mut Vec<u8>, mut v: u32) {
    loop {
        if v < 0x80 {
            out.push(v as u8);
            return;
        }
        out.push((v & 0x7F) as u8 | 0x80);
        v >>= 7;
    }
}

fn ref_bit_width(v: u32) -> u32 {
    32 - v.leading_zeros()
}

/// Little-endian fixed-width packing, bit 0 of value 0 in bit 0 of
/// byte 0.
fn ref_pack(values: &[u32], width: u32, out: &mut Vec<u8>) {
    if width == 0 {
        return;
    }
    let mut acc: u64 = 0;
    let mut bits: u32 = 0;
    for &v in values {
        acc |= (v as u64) << bits;
        bits += width;
        while bits >= 8 {
            out.push(acc as u8);
            acc >>= 8;
            bits -= 8;
        }
    }
    if bits > 0 {
        out.push(acc as u8);
    }
}

/// Prequant + 1D Lorenzo symbols for one block, `None` on overflow
/// (same f32-fast-path arithmetic the format mandates).
fn ref_symbols(block: &[f32], eb: f64) -> Option<Vec<u32>> {
    let inv = 1.0 / (2.0 * eb);
    let inv_f32 = inv as f32;
    let mut prev: i64 = 0;
    let mut out = Vec::with_capacity(block.len());
    for &x in block {
        let qf = (x * inv_f32).round();
        let q: i64 = if qf.abs() < 8_388_608.0 {
            qf as i64
        } else {
            let qd = (x as f64 * inv).round();
            if !qd.is_finite() || qd.abs() > i32::MAX as f64 / 2.0 {
                return None;
            }
            qd as i64
        };
        out.push(ref_zigzag((q - prev) as i32));
        prev = q;
    }
    Some(out)
}

/// The GZCP stream, written longhand: `magic(4) | version(1) | eb(8 LE)
/// | count(8 LE) | width table (1 byte per 32-value block) | payload`.
/// Packed blocks store `varint(zigzag(q0))` then the remaining deltas
/// at the block's max bit width; width `0xFF` marks a verbatim-f32
/// block (overflow or width > 28).
fn ref_cuszp_stream(data: &[f32], eb: f64) -> Vec<u8> {
    let mut widths = Vec::new();
    let mut payload = Vec::new();
    for block in data.chunks(32) {
        let raw = |payload: &mut Vec<u8>, widths: &mut Vec<u8>| {
            widths.push(0xFF);
            for &x in block {
                payload.extend_from_slice(&x.to_le_bytes());
            }
        };
        match ref_symbols(block, eb) {
            None => raw(&mut payload, &mut widths),
            Some(symbols) => {
                let maxw = symbols[1..].iter().map(|&z| ref_bit_width(z)).max().unwrap_or(0);
                if maxw > 28 {
                    raw(&mut payload, &mut widths);
                } else {
                    widths.push(maxw as u8);
                    ref_varint(&mut payload, symbols[0]);
                    if block.len() > 1 {
                        ref_pack(&symbols[1..], maxw, &mut payload);
                    }
                }
            }
        }
    }
    let mut out = Vec::new();
    out.extend_from_slice(b"GZCP");
    out.push(1);
    out.extend_from_slice(&eb.to_le_bytes());
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());
    out.extend_from_slice(&widths);
    out.extend_from_slice(&payload);
    out
}

#[test]
fn cuszp_stream_is_byte_pinned_to_the_reference_encoder() {
    // One payload exercising every encoder path: smooth packed blocks,
    // a width-0 constant block, an overflow + NaN raw block, and a
    // partial final block.
    let mut data: Vec<f32> = (0..64).map(|i| (i as f32 * 0.05).sin() * 3.0).collect();
    data.extend(std::iter::repeat(2.5f32).take(32));
    data.extend([1e30f32, -1e30, f32::NAN, 0.0, 0.125]);
    let eb = 1e-3;

    let got = CuszpLike::new(eb).compress(&data);
    let want = ref_cuszp_stream(&data, eb);
    assert_eq!(&got[0..4], b"GZCP");
    assert_eq!(got[4], 1, "format version");
    assert_eq!(got, want, "cuSZp-like stream drifted from the pinned format");

    // The canonical staged composition emits the identical stream.
    let staged = CodecSpec::cuszp().build(eb).unwrap().compress(&data);
    assert_eq!(staged, want, "CodecSpec::cuszp() is not byte-compatible");

    // And the pinned bytes decode within the bound (raw blocks exact).
    let back = decode_any(&want).unwrap();
    assert_eq!(back.len(), data.len());
    for (a, b) in back.iter().zip(data.iter()).take(96) {
        assert!((a - b).abs() <= eb as f32 + 1e-6);
    }
    assert_eq!(back[96], 1e30);
    assert!(back[98].is_nan());
}
