//! Multi-tier topology subsystem: equivalence against the PR 2
//! two-level reference, per-tier budget-split properties, and the
//! 512-rank 3-tier acceptance criteria.

use gzccl::accuracy::{
    complies_tiers, plan_auto, plan_auto_tiers, split_across_tiers, AccuracyTarget,
};
use gzccl::collectives::{allreduce_hierarchical, Algo, Op, SchedProg};
use gzccl::comm::{CollectiveSpec, Communicator, Tuner};
use gzccl::coordinator::{
    run_collective, ClusterSpec, DeviceBuf, ExecPolicy, Payload, ProgFut, RankCtx,
};
use gzccl::error::Result;
use gzccl::gpu::StreamId;
use gzccl::net::Topology;
use gzccl::sim::VirtTime;
use gzccl::testkit::{forall, Cases, Pcg32};
use gzccl::topo::{compile_min_error, TierTree};

const MIB: usize = 1 << 20;

// ---------------------------------------------------------------------
// The PR 2 two-level Allreduce, kept verbatim as a *reference
// implementation* (built on the public RankCtx API): the generalized
// schedule engine must reproduce it bit-for-bit on degenerate 2-tier
// trees — compressed and uncompressed alike, since the dataflow (fold
// order, MPICH remainder scheme, per-hop compression points) is what
// determines every output bit.
// ---------------------------------------------------------------------

const TAG_HIER_UP: u64 = 0x4852_0000_0000;
const TAG_HIER_X: u64 = 0x4852_1000_0000;
const TAG_HIER_FOLD: u64 = 0x4852_2000_0000;
const TAG_HIER_UNFOLD: u64 = 0x4852_3000_0000;
const TAG_HIER_DOWN: u64 = 0x4852_4000_0000;

fn send_whole(
    ctx: &mut RankCtx,
    stream: StreamId,
    to: usize,
    tag: u64,
    data: &DeviceBuf,
    data_t: VirtTime,
) {
    if ctx.compression_enabled() {
        ctx.memset(stream, data.bytes(), data_t);
        let (c, t_c) = ctx.compress(stream, data, data_t);
        ctx.send(to, tag, Payload::Comp(c), t_c);
    } else {
        ctx.send(to, tag, Payload::Raw(data.clone()), data_t);
    }
}

async fn recv_whole(
    ctx: &mut RankCtx,
    stream: StreamId,
    from: usize,
    tag: u64,
) -> (DeviceBuf, VirtTime) {
    if ctx.compression_enabled() {
        let (c, t_in) = ctx.recv_comp(from, tag).await;
        ctx.decompress(stream, &c, t_in)
    } else {
        ctx.recv_raw(from, tag).await
    }
}

async fn leaders_recursive_doubling(
    ctx: &mut RankCtx,
    stream: StreamId,
    input: DeviceBuf,
    input_t: VirtTime,
    topo: &Topology,
) -> Result<(DeviceBuf, VirtTime)> {
    let nodes = topo.nodes();
    let my_idx = topo.node_of(ctx.rank());
    let pof2 = 1usize << (usize::BITS - 1 - nodes.leading_zeros()) as usize;
    let rem = nodes - pof2;
    let mut data = input;
    let mut data_t = input_t;
    let newidx: isize;
    if my_idx < 2 * rem {
        if my_idx % 2 == 0 {
            let peer = topo.leader_of_node(my_idx + 1);
            send_whole(ctx, stream, peer, TAG_HIER_FOLD, &data, data_t);
            newidx = -1;
        } else {
            let peer = topo.leader_of_node(my_idx - 1);
            let (theirs, t_in) = recv_whole(ctx, stream, peer, TAG_HIER_FOLD).await;
            let (sum, t_sum) = ctx.reduce(stream, &data, &theirs, t_in.join(data_t))?;
            data = sum;
            data_t = t_sum;
            newidx = (my_idx / 2) as isize;
        }
    } else {
        newidx = (my_idx - rem) as isize;
    }
    if newidx >= 0 {
        let nr = newidx as usize;
        let mut mask = 1usize;
        let mut round: u64 = 0;
        while mask < pof2 {
            let peer_nr = nr ^ mask;
            let peer_idx = if peer_nr < rem {
                peer_nr * 2 + 1
            } else {
                peer_nr + rem
            };
            let peer = topo.leader_of_node(peer_idx);
            send_whole(ctx, stream, peer, TAG_HIER_X + round, &data, data_t);
            let (theirs, t_in) = recv_whole(ctx, stream, peer, TAG_HIER_X + round).await;
            let (sum, t_sum) = ctx.reduce(stream, &data, &theirs, t_in.join(data_t))?;
            data = sum;
            data_t = t_sum;
            mask <<= 1;
            round += 1;
        }
    }
    if my_idx < 2 * rem {
        if my_idx % 2 == 1 {
            let peer = topo.leader_of_node(my_idx - 1);
            send_whole(ctx, stream, peer, TAG_HIER_UNFOLD, &data, data_t);
        } else {
            let peer = topo.leader_of_node(my_idx + 1);
            let (result, t_in) = recv_whole(ctx, stream, peer, TAG_HIER_UNFOLD).await;
            data = result;
            data_t = t_in;
        }
    }
    Ok((data, data_t))
}

/// The PR 2 two-level Allreduce, verbatim (recv suspension points
/// aside — the dataflow and timestamps are untouched).
fn reference_two_level(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(async move {
    let n = ctx.nranks();
    let me = ctx.rank();
    if n == 1 {
        return Ok(input);
    }
    let topo = ctx.topology().clone();
    let node = topo.node_of(me);
    let leader = topo.leader_of(me);
    let members = topo.node_ranks(node);
    let stream = if ctx.policy().overlap {
        StreamId::NonDefault(0)
    } else {
        StreamId::Default
    };
    if me != leader {
        let now = ctx.now();
        ctx.send(leader, TAG_HIER_UP + me as u64, Payload::Raw(input), now);
        let (out, _t) = ctx.recv_raw(leader, TAG_HIER_DOWN + me as u64).await;
        ctx.sync_device();
        return Ok(out);
    }
    let mut data = input;
    let mut data_t = ctx.now();
    for m in members.clone().skip(1) {
        let (theirs, t_in) = ctx.recv_raw(m, TAG_HIER_UP + m as u64).await;
        let (sum, t_sum) = ctx.reduce(stream, &data, &theirs, t_in.join(data_t))?;
        data = sum;
        data_t = t_sum;
    }
    if topo.nodes() > 1 {
        let (d, t) = leaders_recursive_doubling(ctx, stream, data, data_t, &topo).await?;
        data = d;
        data_t = t;
    }
    for m in members.skip(1) {
        ctx.send(m, TAG_HIER_DOWN + m as u64, Payload::Raw(data.clone()), data_t);
    }
    ctx.sync_device();
    Ok(data)
    })
}

fn spec(n: usize, g: usize, policy: ExecPolicy) -> ClusterSpec {
    ClusterSpec::with_topology(Topology::new(n, g).unwrap(), policy)
}

fn real_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
    (0..n)
        .map(|r| {
            let mut rng = Pcg32::new(seed, r as u64);
            DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
        })
        .collect()
}

/// The ISSUE satellite property: on degenerate 2-tier trees the
/// schedule engine is **bitwise identical** to the PR 2 two-level
/// Allreduce — including compressed runs, where the per-hop
/// compression points decide every output bit.
#[test]
fn prop_engine_matches_pr2_reference_bitwise() {
    forall(
        Cases::n(14),
        |rng| {
            let g = rng.range_usize(1, 4);
            let n = rng.range_usize(2, 13);
            let d = rng.range_usize(4, 150);
            let compressed = rng.range_usize(0, 1) == 1;
            (n, g, d, compressed, rng.next_u64())
        },
        |&(n, g, d, compressed, seed)| {
            let policy = if compressed {
                ExecPolicy::gzccl()
            } else {
                ExecPolicy::nccl()
            };
            let inputs = real_inputs(n, d, seed);
            let reference =
                run_collective(&spec(n, g, policy), inputs.clone(), &reference_two_level)
                    .map_err(|e| e.to_string())?;
            let engine = run_collective(&spec(n, g, policy), inputs, &allreduce_hierarchical)
                .map_err(|e| e.to_string())?;
            for r in 0..n {
                if engine.outputs[r].as_real() != reference.outputs[r].as_real() {
                    return Err(format!(
                        "n={n} g={g} compressed={compressed} rank {r} diverged from PR 2"
                    ));
                }
            }
            // The compression-kernel profile is identical too.
            for r in 0..n {
                let e = &engine.counters[r];
                let p = &reference.counters[r];
                if (e.compress_calls, e.decompress_calls) != (p.compress_calls, p.decompress_calls)
                {
                    return Err(format!(
                        "n={n} g={g} rank {r}: kernel counts {:?} vs PR 2 {:?}",
                        (e.compress_calls, e.decompress_calls),
                        (p.compress_calls, p.decompress_calls)
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The ISSUE satellite property: per-tier budget splits always sum to
/// ≤ the per-call budget — across non-power-of-two widths, partial
/// groups, random depths and skewed compressibility weights.
#[test]
fn prop_tier_budget_split_never_exceeds_per_call() {
    forall(
        Cases::n(40),
        |rng| {
            let depth = rng.range_usize(2, 4);
            let widths: Vec<usize> = (0..depth).map(|_| rng.range_usize(2, 5)).collect();
            let span: usize = widths.iter().product();
            let ranks = rng.range_usize(span / 2 + 1, span).max(2);
            let weights: Vec<f64> = (0..depth)
                .map(|_| rng.range_usize(1, 100) as f64 / 10.0)
                .collect();
            let op = *rng.choose(&[Op::Allreduce, Op::ReduceScatter, Op::Allgather]);
            (ranks, widths, weights, op)
        },
        |(ranks, widths, weights, op)| {
            let tree = TierTree::new(*ranks, widths).map_err(|e| e.to_string())?;
            let plan = plan_auto_tiers(
                AccuracyTarget::AbsError(1e-2),
                None,
                1,
                &tree,
                gzccl::coordinator::CompressionMode::ErrorBounded,
            )
            .map_err(|e| e.to_string())?;
            let split = split_across_tiers(&plan, *op, &tree, Some(weights.as_slice()))
                .map_err(|e| e.to_string())?;
            let total = split.predicted_total();
            if total > plan.per_call_abs * (1.0 + 1e-9) {
                return Err(format!(
                    "ranks={ranks} widths={widths:?} {op:?}: Σ A·eb = {total} exceeds \
                     per-call {}",
                    plan.per_call_abs
                ));
            }
            if split.tier(0).is_some() {
                return Err("tier 0 must never receive a compression budget".into());
            }
            for tb in &split.tiers {
                if !(tb.eb.is_finite() && tb.eb > 0.0) {
                    return Err(format!("degenerate tier bound {tb:?}"));
                }
            }
            Ok(())
        },
    );
}

/// ISSUE acceptance: on the 512-rank, 3-tier topology (4 GPUs/node,
/// 16 nodes/rack, 8 racks) at 64 MiB the tuner selects the 3-tier
/// schedule, and its simulated makespan beats both the flat ring and
/// the collapsed two-level schedule on the same (uplink-modeling)
/// fabric.
#[test]
fn acceptance_512_rank_three_tier_beats_ring_and_two_level() {
    let n = 512;
    let widths = [4usize, 16, 8];
    let comm = Communicator::builder(n)
        .tiers(&widths)
        .policy(ExecPolicy::gzccl())
        .error_bound(1e-4)
        .build()
        .unwrap();
    let virt = || -> Vec<DeviceBuf> { (0..n).map(|_| DeviceBuf::Virtual(64 * MIB / 4)).collect() };

    // The tuner keeps the rack tier: a depth-3 schedule with a leg on
    // tier 2.
    let auto = comm.allreduce(virt(), &CollectiveSpec::auto()).unwrap();
    assert_eq!(auto.algo, Algo::Hierarchical, "tuner must go hierarchical");
    assert!(auto.auto_tuned);
    let sched = auto.schedule.as_ref().expect("hierarchical records its schedule");
    assert_eq!(sched.tree.depth(), 3, "tuner must select the 3-tier schedule");
    assert!(sched.legs.iter().any(|l| l.tier == 2));

    // …it beats the flat ring…
    let ring = comm
        .allreduce(virt(), &CollectiveSpec::forced(Algo::Ring))
        .unwrap();
    assert!(
        auto.makespan.as_secs() < ring.makespan.as_secs(),
        "3-tier {} must beat the flat ring {}",
        auto.makespan,
        ring.makespan
    );

    // …and the two-level schedule run on the *same* 3-tier fabric
    // (collapsing the tree hides the rack uplinks from the schedule,
    // not from the network).
    let tree = TierTree::new(n, &widths).unwrap();
    let two_level = compile_min_error(Op::Allreduce, &tree.collapsed(2), true).unwrap();
    let two = run_collective(&comm.cluster().clone(), virt(), &SchedProg(two_level)).unwrap();
    assert!(
        auto.makespan.as_secs() < two.makespan.as_secs(),
        "3-tier {} must beat the two-level schedule {}",
        auto.makespan,
        two.makespan
    );

    // The analytic model agrees with the simulation's ordering (the
    // tuner's selection was not a fluke of the estimate).
    let cost = gzccl::topo::CostModel::default_a100();
    let est3 = Tuner::default()
        .plan_schedule(Op::Allreduce, ExecPolicy::gzccl(), &tree, &cost, 64 * MIB)
        .unwrap()
        .estimate_makespan(&tree, &cost, 64 * MIB);
    let est_ring = gzccl::topo::estimate_flat_ring(&tree, &cost, 64 * MIB, true);
    assert!(est3 < est_ring);
}

/// ISSUE acceptance: a tight budget that previously vetoed
/// Reduce_scatter outright now plans a compliant hierarchical
/// Reduce_scatter — on the 2-tier shape PR 3 rejected and on the
/// 3-tier acceptance topology.
#[test]
fn acceptance_budget_reduce_scatter_has_a_compliant_plan() {
    // PR 3's shape: 32 ranks / 4 GPUs per node, hierarchical-anchored
    // budget. The ring's 31 linear stages blow it; the schedule
    // engine's Reduce_scatter complies.
    let layout = Topology::new(32, 4).unwrap();
    let plan = plan_auto(
        AccuracyTarget::AbsError(1e-3),
        1,
        &layout,
        gzccl::coordinator::CompressionMode::ErrorBounded,
    )
    .unwrap();
    let picked = Tuner::default()
        .select_within_budget(
            Op::ReduceScatter,
            ExecPolicy::gzccl(),
            &layout,
            MIB,
            0,
            &plan,
        )
        .expect("a compliant Reduce_scatter now exists");
    assert_eq!(picked, Algo::Hierarchical);

    // The 512-rank 3-tier acceptance topology: same story through the
    // tiers entry points.
    let tree = TierTree::new(512, &[4, 16, 8]).unwrap();
    let plan = plan_auto_tiers(
        AccuracyTarget::AbsError(1e-2),
        None,
        1,
        &tree,
        gzccl::coordinator::CompressionMode::ErrorBounded,
    )
    .unwrap();
    assert!(complies_tiers(&plan, Op::ReduceScatter, Algo::Hierarchical, &tree, 0));
    assert!(!complies_tiers(&plan, Op::ReduceScatter, Algo::Ring, &tree, 0));

    // End-to-end on real payloads at the PR 3 shape: the budgeted
    // communicator dispatches the hierarchical Reduce_scatter and the
    // observed error honors the per-call bound.
    let comm = Communicator::builder(32)
        .gpus_per_node(4)
        .accuracy_target(AccuracyTarget::AbsError(1e-3))
        .build()
        .unwrap();
    let out = comm
        .reduce_scatter(real_inputs(32, 192, 4242), &CollectiveSpec::auto())
        .unwrap();
    assert_eq!(out.algo, Algo::Hierarchical);
    let acc = out.accuracy.expect("telemetry on real compressed payloads");
    assert_eq!(acc.within_bound(), Some(true), "{acc:?}");
}

/// ClusterSpec keeps the 2-tier view, the tier tree, and the uplink
/// models in sync through `set_tiers`.
#[test]
fn cluster_spec_tier_views_stay_in_sync() {
    let mut spec = ClusterSpec::new(64, ExecPolicy::gzccl());
    assert_eq!(spec.tiers.depth(), 2);
    assert!(spec.uplinks.is_empty());
    assert_eq!(spec.tier_links().len(), 2);
    spec.set_tiers(TierTree::new(64, &[4, 4, 4]).unwrap());
    assert_eq!(spec.topo.gpus_per_node(), 4);
    assert_eq!(spec.tiers.depth(), 3);
    assert_eq!(spec.uplinks.len(), 1, "one uplink level above the node tier");
    assert_eq!(spec.tier_links().len(), 3);
}
