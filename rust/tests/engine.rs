//! Event-engine equivalence and scale suite.
//!
//! The thread-per-rank runner is the reference oracle; the event
//! engine must be *indistinguishable* from it on everything the
//! simulator reports: per-rank payloads bitwise, makespans exactly,
//! counters exactly. Dataflow is timing-independent (mailboxes are
//! `(src, tag)`-FIFO under both backends) and link reservations are
//! gap-filling, so equality holds on causal patterns (rings) and on
//! symmetric power-of-two shapes; the matrices below stay inside that
//! envelope on purpose.
//!
//! Beyond equivalence: the multi-tenant contention test shares one
//! physical fabric between two communicators whose windows straddle
//! the same rack boundary, and the `#[ignore]`d acceptance run drives
//! a 16384-rank (8×32×64) hierarchical Allreduce through the engine
//! (CI runs it release-mode in the non-blocking `engine-16k` job).

use gzccl::accuracy::AccuracyTarget;
use gzccl::collectives::{allreduce_hierarchical, allreduce_ring, Algo, Op};
use gzccl::comm::{AlgoRegistry, CollectiveSpec, Communicator};
use gzccl::coordinator::{
    run_collective, ClusterSpec, DeviceBuf, ExecBackend, ExecPolicy, RunReport,
};
use gzccl::engine::{run_multi_tenant, Tenant};
use gzccl::testkit::{forall, Cases, Pcg32};
use gzccl::topo::TierTree;

fn real_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
    (0..n)
        .map(|r| {
            let mut rng = Pcg32::new(seed, r as u64);
            DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
        })
        .collect()
}

/// Inputs shaped for `op`: rooted collectives feed the full vector at
/// `root` and empty buffers elsewhere; the rest get per-rank vectors.
fn op_inputs(op: Op, n: usize, d: usize, root: usize, seed: u64) -> Vec<DeviceBuf> {
    match op {
        Op::Scatter | Op::Bcast => {
            let full = real_inputs(1, d, seed).remove(0);
            (0..n)
                .map(|r| {
                    if r == root {
                        full.clone()
                    } else {
                        DeviceBuf::Real(vec![])
                    }
                })
                .collect()
        }
        _ => real_inputs(n, d, seed),
    }
}

/// Panics unless the two reports agree on everything observable:
/// payloads bitwise, makespan exactly, per-rank counters exactly.
fn assert_reports_identical(t: &RunReport, e: &RunReport, what: &str) {
    assert_eq!(t.makespan, e.makespan, "{what}: makespan");
    assert_eq!(t.outputs.len(), e.outputs.len(), "{what}: rank count");
    for r in 0..t.outputs.len() {
        assert_eq!(
            t.outputs[r].as_real(),
            e.outputs[r].as_real(),
            "{what}: rank {r} payload"
        );
        let (tc, ec) = (&t.counters[r], &e.counters[r]);
        assert_eq!(tc.msgs_sent, ec.msgs_sent, "{what}: rank {r} msgs");
        assert_eq!(tc.wire_bytes, ec.wire_bytes, "{what}: rank {r} wire bytes");
        assert_eq!(
            tc.compress_calls, ec.compress_calls,
            "{what}: rank {r} compress calls"
        );
        assert_eq!(
            tc.decompress_calls, ec.decompress_calls,
            "{what}: rank {r} decompress calls"
        );
    }
}

#[test]
fn every_registered_pair_matches_thread_oracle() {
    // Every (op, algo) the registry advertises, on a 2-tier and a
    // 3-tier power-of-two topology, compressed and uncompressed.
    let shapes: &[&[usize]] = &[&[4, 2], &[2, 2, 2]];
    let policies = [("nccl", ExecPolicy::nccl()), ("gzccl", ExecPolicy::gzccl())];
    let d = 96;
    for widths in shapes {
        let tree = TierTree::new(8, widths).unwrap();
        let n = tree.ranks();
        for (pname, policy) in policies {
            for op in [
                Op::Allreduce,
                Op::Allgather,
                Op::ReduceScatter,
                Op::Scatter,
                Op::Bcast,
            ] {
                for &algo in AlgoRegistry::supported(op) {
                    let root = n - 1;
                    let program = AlgoRegistry::resolve(op, algo, d, root).unwrap();
                    let inputs = op_inputs(op, n, d, root, 0xC0FFEE);
                    let run = |backend| {
                        let spec = ClusterSpec::with_tiers(tree.clone(), policy)
                            .with_error_bound(1e-3)
                            .with_backend(backend);
                        run_collective(&spec, inputs.clone(), &*program)
                            .unwrap_or_else(|e| panic!("{pname} {op:?}/{algo:?} {backend}: {e}"))
                    };
                    let threads = run(ExecBackend::Threads);
                    let events = run(ExecBackend::Events);
                    assert_reports_identical(
                        &threads,
                        &events,
                        &format!("{pname} {op:?}/{algo:?} tiers {widths:?}"),
                    );
                }
            }
        }
    }
}

#[test]
fn prop_backends_bitwise_equal_on_random_rings() {
    // Ring is fully causal (each NIC serves exactly one rank), so the
    // backends must agree exactly on *any* rank count, compressed or
    // not.
    forall(
        Cases::n(12),
        |rng| {
            let n = *rng.choose(&[2usize, 3, 4, 5, 8]);
            let d = rng.range_usize(n, 256);
            let compressed = rng.range_usize(0, 2) == 1;
            (n, d, compressed, rng.next_u64())
        },
        |&(n, d, compressed, seed)| {
            let policy = if compressed {
                ExecPolicy::gzccl()
            } else {
                ExecPolicy::nccl()
            };
            let inputs = real_inputs(n, d, seed);
            let run = |backend| {
                let spec = ClusterSpec::new(n, policy)
                    .with_error_bound(1e-4)
                    .with_backend(backend);
                run_collective(&spec, inputs.clone(), &allreduce_ring).map_err(|e| e.to_string())
            };
            let threads = run(ExecBackend::Threads)?;
            let events = run(ExecBackend::Events)?;
            if threads.makespan != events.makespan {
                return Err(format!(
                    "makespan {:?} vs {:?}",
                    threads.makespan, events.makespan
                ));
            }
            for r in 0..n {
                if threads.outputs[r].as_real() != events.outputs[r].as_real() {
                    return Err(format!("rank {r} payload differs"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn budgeted_dispatch_matches_thread_oracle() {
    // Full communicator path under an accuracy budget: the planner
    // splits the bound across tiers, the dispatcher compiles an
    // ExecPlan, and both backends must execute it identically.
    let n = 32;
    let d = 128;
    let run = |backend| {
        let comm = Communicator::builder(n)
            .tiers(&[4, 4, 2])
            .accuracy_target(AccuracyTarget::AbsError(1e-3))
            .backend(backend)
            .build()
            .unwrap();
        let ar = comm
            .allreduce(real_inputs(n, d, 21), &CollectiveSpec::auto())
            .unwrap();
        let rs = comm
            .reduce_scatter(real_inputs(n, d, 22), &CollectiveSpec::auto())
            .unwrap();
        (ar, rs)
    };
    let (t_ar, t_rs) = run(ExecBackend::Threads);
    let (e_ar, e_rs) = run(ExecBackend::Events);
    assert_eq!(t_ar.algo, e_ar.algo, "allreduce algo choice");
    assert_eq!(t_rs.algo, e_rs.algo, "reduce_scatter algo choice");
    assert_reports_identical(&t_ar.report, &e_ar.report, "budgeted allreduce");
    assert_reports_identical(&t_rs.report, &e_rs.report, "budgeted reduce_scatter");
}

#[test]
fn two_tenants_contend_on_shared_rack_uplinks() {
    // Physical machine: 16 GPUs as 2/node, 2 nodes/rack, 4 racks.
    // Tenant A occupies leaves [2, 6) (straddles the rack0/rack1
    // boundary), tenant B leaves [6, 10) (straddles rack1/rack2) —
    // both push ring traffic through rack 1's uplink pair every step,
    // so each must finish later than it would alone.
    let physical = ClusterSpec::with_tiers(
        TierTree::new(16, &[2, 2, 4]).unwrap(),
        ExecPolicy::nccl(),
    );
    let tenant = |name: &str, base: usize| Tenant {
        name: name.into(),
        spec: ClusterSpec::with_tiers(TierTree::new(4, &[2, 2]).unwrap(), ExecPolicy::nccl()),
        base,
        inputs: (0..4).map(|_| DeviceBuf::Virtual(1 << 20)).collect(),
        program: Box::new(allreduce_ring),
    };
    let report = run_multi_tenant(&physical, vec![tenant("job-a", 2), tenant("job-b", 6)]).unwrap();
    assert_eq!(report.tenants.len(), 2);
    for t in &report.tenants {
        assert!(
            t.slowdown > 1.0,
            "tenant {} slowdown {} must exceed 1.0 under contention \
             (contended {:?} vs isolated {:?})",
            t.name,
            t.slowdown,
            t.makespan,
            t.isolated_makespan
        );
        assert_eq!(t.report.outputs.len(), 4, "tenant {} rank count", t.name);
    }
    assert!(
        report.fairness > 0.0 && report.fairness <= 1.0 + 1e-12,
        "Jain fairness {} out of (0, 1]",
        report.fairness
    );
}

#[test]
fn disjoint_racks_do_not_contend() {
    // Control for the test above: windows confined to different racks
    // never share a NIC or an uplink, so the contended run equals the
    // isolated runs and fairness is exactly 1.
    let physical = ClusterSpec::with_tiers(
        TierTree::new(16, &[2, 2, 4]).unwrap(),
        ExecPolicy::nccl(),
    );
    let tenant = |name: &str, base: usize| Tenant {
        name: name.into(),
        spec: ClusterSpec::with_tiers(TierTree::new(4, &[2, 2]).unwrap(), ExecPolicy::nccl()),
        base,
        inputs: (0..4).map(|_| DeviceBuf::Virtual(1 << 20)).collect(),
        program: Box::new(allreduce_ring),
    };
    let report = run_multi_tenant(&physical, vec![tenant("job-a", 0), tenant("job-b", 8)]).unwrap();
    for t in &report.tenants {
        assert_eq!(t.makespan, t.isolated_makespan, "tenant {}", t.name);
        assert!((t.slowdown - 1.0).abs() < 1e-12, "tenant {}", t.name);
    }
    assert!((report.fairness - 1.0).abs() < 1e-12);
}

#[test]
fn acceptance_512_ranks_bit_identical_across_backends() {
    // The ISSUE's equivalence acceptance topology: 512 ranks as
    // 4×16×8, compressed hierarchical Allreduce over real payloads.
    let n = 512;
    let tree = TierTree::new(n, &[4, 16, 8]).unwrap();
    let inputs = real_inputs(n, 24, 4242);
    let run = |backend| {
        let spec = ClusterSpec::with_tiers(tree.clone(), ExecPolicy::gzccl())
            .with_error_bound(1e-3)
            .with_backend(backend);
        run_collective(&spec, inputs.clone(), &allreduce_hierarchical).unwrap()
    };
    let threads = run(ExecBackend::Threads);
    let events = run(ExecBackend::Events);
    assert_reports_identical(&threads, &events, "512-rank hierarchical");
    assert!(events.makespan.as_secs() > 0.0);
}

#[test]
#[ignore = "release-mode scale acceptance (~16k actors); CI runs it in the engine-16k job"]
fn acceptance_16384_ranks_under_60s() {
    // Scale acceptance: 16384 ranks (8 GPUs/node × 32 nodes/rack ×
    // 64 racks), 64 MiB virtual payloads, compressed hierarchical
    // Allreduce — must finish in well under a minute of wall time
    // because events, not ranks, bound the engine's work.
    let n = 16384;
    let start = std::time::Instant::now();
    let comm = Communicator::builder(n)
        .tiers(&[8, 32, 64])
        .policy(ExecPolicy::gzccl())
        .error_bound(1e-3)
        .build()
        .unwrap();
    assert_eq!(comm.cluster().backend, ExecBackend::Events, "default backend");
    let inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual((64 << 20) / 4)).collect();
    let report = comm
        .allreduce(inputs, &CollectiveSpec::forced(Algo::Hierarchical))
        .unwrap();
    assert_eq!(report.algo, Algo::Hierarchical);
    assert!(report.makespan.as_secs() > 0.0);
    let wall = start.elapsed();
    println!(
        "16384-rank hierarchical allreduce: wall {:.2?}, virtual {:.6} s",
        wall,
        report.makespan.as_secs()
    );
    assert!(
        wall.as_secs() < 60,
        "16384-rank run took {wall:.2?} (budget: 60 s)"
    );
}
