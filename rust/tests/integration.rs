//! Integration tests: whole-stack flows through the public API.

use gzccl::collectives::{
    allgather_ring, allreduce_hierarchical, allreduce_recursive_doubling, allreduce_ring,
    reduce_scatter_ring, Algo, BcastProg, Chunks, ScatterProg,
};
use gzccl::comm::{CollectiveSpec, Communicator};
use gzccl::config::{ClusterConfig, TomlDoc};
use gzccl::coordinator::{run_collective, ClusterSpec, DeviceBuf, ExecPolicy};
use gzccl::testkit::{forall, Cases, Pcg32};

fn real_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
    (0..n)
        .map(|r| {
            let mut rng = Pcg32::new(seed, r as u64);
            DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
        })
        .collect()
}

fn exact_sum(inputs: &[DeviceBuf]) -> Vec<f32> {
    let d = inputs[0].elems();
    let mut out = vec![0.0f32; d];
    for b in inputs {
        for (o, v) in out.iter_mut().zip(b.as_real()) {
            *o += v;
        }
    }
    out
}

#[test]
fn config_file_to_collective_run() {
    // Config file → ClusterSpec → Communicator → tuned collective.
    let doc = TomlDoc::parse(
        "[cluster]\nranks = 8\nvariant = \"gzccl\"\n[compression]\nerror_bound = 1e-3\n",
    )
    .unwrap();
    let cfg = ClusterConfig::from_doc(&doc);
    let comm = Communicator::from_spec(cfg.to_spec().unwrap());
    let inputs = real_inputs(8, 256, 1);
    let expect = exact_sum(&inputs);
    let report = comm.allreduce(inputs, &CollectiveSpec::auto()).unwrap();
    // 1 KiB on 8 ranks (2 nodes × 4 GPUs) is far below the compressed
    // ring crossover → the topology-aware hierarchical schedule.
    assert_eq!(report.algo, Algo::Hierarchical);
    assert!(report.auto_tuned);
    for out in &report.outputs {
        for (a, b) in out.as_real().iter().zip(&expect) {
            assert!((a - b).abs() < 9.0 * 1e-3);
        }
    }
    assert!(report.makespan.as_secs() > 0.0);
}

#[test]
fn every_variant_completes_every_collective() {
    // Smoke matrix: all policies × all ops on a small real cluster.
    let policies = [
        ("gzccl", ExecPolicy::gzccl()),
        ("gpu-centric", ExecPolicy::gpu_centric_unoptimized()),
        ("ccoll", ExecPolicy::ccoll()),
        ("cprp2p", ExecPolicy::cprp2p()),
        ("nccl", ExecPolicy::nccl()),
        ("cray", ExecPolicy::cray_mpi()),
    ];
    let n = 4;
    let d = 128;
    for (name, policy) in policies {
        let spec = ClusterSpec::new(n, policy).with_error_bound(1e-3);
        // Allreduce (all three algorithms).
        for algo in 0..3 {
            let inputs = real_inputs(n, d, 7);
            let report = match algo {
                0 => run_collective(&spec, inputs, &allreduce_recursive_doubling),
                1 => run_collective(&spec, inputs, &allreduce_ring),
                _ => run_collective(&spec, inputs, &allreduce_hierarchical),
            }
            .unwrap_or_else(|e| panic!("{name} allreduce({algo}): {e}"));
            assert_eq!(report.outputs[0].elems(), d, "{name}");
        }
        // Reduce_scatter + Allgather.
        let report =
            run_collective(&spec, real_inputs(n, d, 8), &reduce_scatter_ring).unwrap();
        assert_eq!(report.outputs[1].elems(), Chunks::new(d, n).len(1));
        let report = run_collective(&spec, real_inputs(n, d, 9), &allgather_ring).unwrap();
        assert_eq!(report.outputs[2].elems(), d * n);
        // Scatter + Bcast (root-fed, from a non-zero root too).
        for root in [0usize, n - 1] {
            let rooted = |seed: u64| -> Vec<DeviceBuf> {
                let full = real_inputs(1, d, seed).remove(0);
                (0..n)
                    .map(|r| {
                        if r == root {
                            full.clone()
                        } else {
                            DeviceBuf::Real(vec![])
                        }
                    })
                    .collect()
            };
            let report = run_collective(&spec, rooted(10), &ScatterProg { total: d, root })
                .unwrap_or_else(|e| panic!("{name} scatter root {root}: {e}"));
            assert_eq!(report.outputs[3].elems(), Chunks::new(d, n).len(3));
            let report = run_collective(&spec, rooted(11), &BcastProg { root })
                .unwrap_or_else(|e| panic!("{name} bcast root {root}: {e}"));
            assert_eq!(report.outputs[3].elems(), d, "{name} bcast root {root}");
        }
    }
}

#[test]
fn prop_allreduce_agrees_across_algorithms_and_sizes() {
    forall(
        Cases::n(12),
        |rng| {
            let n = *rng.choose(&[2usize, 3, 4, 5, 8]);
            let d = rng.range_usize(n, 300);
            let seed = rng.next_u64();
            (n, d, seed)
        },
        |&(n, d, seed)| {
            let inputs = real_inputs(n, d, seed);
            let expect = exact_sum(&inputs);
            let spec = ClusterSpec::new(n, ExecPolicy::gzccl()).with_error_bound(1e-4);
            let ring = run_collective(&spec, inputs.clone(), &allreduce_ring)
                .map_err(|e| e.to_string())?;
            let redoub = run_collective(&spec, inputs, &allreduce_recursive_doubling)
                .map_err(|e| e.to_string())?;
            let tol = (3 * n) as f32 * 1e-4;
            for r in 0..n {
                for i in 0..d {
                    let a = ring.outputs[r].as_real()[i];
                    let b = redoub.outputs[r].as_real()[i];
                    if (a - expect[i]).abs() > tol {
                        return Err(format!("ring off at rank {r} elem {i}"));
                    }
                    if (b - expect[i]).abs() > tol {
                        return Err(format!("redoub off at rank {r} elem {i}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_all_ranks_get_identical_allreduce_output() {
    forall(
        Cases::n(10),
        |rng| {
            let n = *rng.choose(&[2usize, 4, 6, 8]);
            let d = rng.range_usize(1, 200);
            (n, d, rng.next_u64())
        },
        |&(n, d, seed)| {
            // Uncompressed: every rank's result is bitwise identical
            // (commutative f32 pairwise sums in the same tree order).
            let spec = ClusterSpec::new(n, ExecPolicy::nccl());
            let report =
                run_collective(&spec, real_inputs(n, d, seed), &allreduce_recursive_doubling)
                    .map_err(|e| e.to_string())?;
            let first = report.outputs[0].as_real();
            for r in 1..n {
                if report.outputs[r].as_real() != first {
                    return Err(format!("rank {r} output differs from rank 0"));
                }
            }
            // Compressed: each side decompresses the peer's stream, so
            // results differ across ranks — but only within the
            // stage-scaled error bound (the paper's accuracy property).
            let spec = ClusterSpec::new(n, ExecPolicy::gzccl()).with_error_bound(1e-4);
            let report =
                run_collective(&spec, real_inputs(n, d, seed), &allreduce_recursive_doubling)
                    .map_err(|e| e.to_string())?;
            let first = report.outputs[0].as_real();
            let tol = (3 * n) as f32 * 1e-4;
            for r in 1..n {
                for (a, b) in report.outputs[r].as_real().iter().zip(first) {
                    if (a - b).abs() > tol {
                        return Err(format!("rank {r} disagrees beyond {tol}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_virtual_and_real_runs_have_identical_timing() {
    // The cost model must not depend on payload contents: a virtual run
    // with the same sizes gives the same makespan as a real run when the
    // profile predicts the real compressed sizes exactly. Use the
    // uncompressed baseline where sizes are trivially equal.
    forall(
        Cases::n(8),
        |rng| {
            let n = *rng.choose(&[2usize, 4, 8]);
            let d = rng.range_usize(n, 5000);
            (n, d, rng.next_u64())
        },
        |&(n, d, seed)| {
            let spec = ClusterSpec::new(n, ExecPolicy::nccl());
            let real = run_collective(&spec, real_inputs(n, d, seed), &allreduce_ring)
                .map_err(|e| e.to_string())?;
            let virt_inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(d)).collect();
            let virt = run_collective(&spec, virt_inputs, &allreduce_ring)
                .map_err(|e| e.to_string())?;
            let (a, b) = (real.makespan.as_secs(), virt.makespan.as_secs());
            if (a - b).abs() > 1e-12 * a.max(1.0) {
                return Err(format!("real {a} vs virtual {b}"));
            }
            Ok(())
        },
    );
}

#[test]
fn error_bound_stacking_scales_with_stages() {
    // Accuracy-aware design (§3.3.3): ReDoub's log N stages stack less
    // error than Ring's N−1 stages. Verify with a tight statistical
    // check over many elements.
    let n = 16;
    let d = 4096;
    let inputs = real_inputs(n, d, 99);
    let expect = exact_sum(&inputs);
    let spec = ClusterSpec::new(n, ExecPolicy::gzccl()).with_error_bound(1e-3);
    let ring = run_collective(&spec, inputs.clone(), &allreduce_ring).unwrap();
    let redoub = run_collective(&spec, inputs, &allreduce_recursive_doubling).unwrap();
    let rms = |outs: &[DeviceBuf]| {
        let o = outs[0].as_real();
        (o.iter()
            .zip(&expect)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / d as f64)
            .sqrt()
    };
    let e_ring = rms(&ring.outputs);
    let e_redoub = rms(&redoub.outputs);
    assert!(
        e_redoub <= e_ring * 1.5,
        "redoub rms {e_redoub} should not exceed ring rms {e_ring}"
    );
}
