//! Accuracy subsystem end-to-end: the propagation model's predictions
//! hold on real runs across algorithms / rank counts / error bounds,
//! the fixed-rate hazard is demonstrated and rejected, and the tuner's
//! accuracy veto changes real dispatch decisions.

use gzccl::accuracy::{plan_auto, AccuracyTarget, ErrorPrediction};
use gzccl::collectives::Algo;
use gzccl::comm::{CollectiveSpec, Communicator};
use gzccl::coordinator::{DeviceBuf, ExecPolicy};
use gzccl::testkit::{forall, Cases, Pcg32};

const MIB: usize = 1 << 20;

fn real_inputs(n: usize, d: usize, seed: u64, scale: f32) -> Vec<DeviceBuf> {
    (0..n)
        .map(|r| {
            let mut rng = Pcg32::new(seed, r as u64);
            DeviceBuf::Real(rng.uniform_vec(d, -scale, scale))
        })
        .collect()
}

/// The satellite property: observed stacking error stays within the
/// predicted budget across algorithms (ring, gZ-ReDoub, hierarchical),
/// non-power-of-two rank counts, node shapes, and several error bounds.
#[test]
fn prop_observed_error_within_predicted_bound() {
    let algos = [Algo::Ring, Algo::RecursiveDoubling, Algo::Hierarchical];
    forall(
        Cases::n(18),
        |rng| {
            let n = rng.range_usize(2, 13); // includes non-pow2
            let g = rng.range_usize(1, 4);
            let d = rng.range_usize(32, 200);
            let eb = *rng.choose(&[1e-2f64, 1e-3, 1e-4]);
            let algo = *rng.choose(&algos);
            (n, g, d, eb, algo, rng.next_u64())
        },
        |&(n, g, d, eb, algo, seed)| {
            let comm = Communicator::builder(n)
                .gpus_per_node(g)
                .policy(ExecPolicy::gzccl())
                .error_bound(eb)
                .build()
                .map_err(|e| e.to_string())?;
            let report = comm
                .allreduce(real_inputs(n, d, seed, 1.0), &CollectiveSpec::forced(algo))
                .map_err(|e| e.to_string())?;
            let acc = report
                .accuracy
                .ok_or("telemetry missing on a real compressed run")?;
            match acc.prediction {
                ErrorPrediction::Bounded(b) => {
                    if acc.within_bound() != Some(true) {
                        return Err(format!(
                            "observed {:.3e} exceeds predicted {b:.3e} (n={n} g={g} {algo:?} eb={eb:e})",
                            acc.observed_max_err
                        ));
                    }
                }
                ErrorPrediction::Exact => {
                    // Hierarchical on a single node never compresses.
                    if acc.observed_max_err > acc.fp_slack {
                        return Err(format!(
                            "exact path deviated by {:.3e}",
                            acc.observed_max_err
                        ));
                    }
                }
                ErrorPrediction::Unbounded => {
                    return Err("error-bounded policy predicted unbounded".into())
                }
            }
            // The record is mirrored into every rank's counters.
            for c in report.counters.iter() {
                if c.observed_max_err != Some(acc.observed_max_err) {
                    return Err("counters out of sync with telemetry".into());
                }
            }
            Ok(())
        },
    );
}

/// The fixed-rate counterexample: on large-magnitude data the CPRP2P
/// compressor's observed error dwarfs every bound the error-bounded
/// path certifies — the unbounded hazard the planner must reject.
#[test]
fn fixed_rate_counterexample_shows_the_unbounded_hazard() {
    let n = 8;
    let comm = Communicator::builder(n)
        .policy(ExecPolicy::cprp2p())
        .build()
        .unwrap();
    // Magnitudes ~1000: fixed-rate error scales along (≈ blockmax/2^7
    // at 8 bits/value), unlike the absolute error-bounded guarantee.
    let report = comm
        .allreduce(real_inputs(n, 256, 77, 1000.0), &CollectiveSpec::forced(Algo::Ring))
        .unwrap();
    let acc = report.accuracy.expect("telemetry observes fixed-rate runs too");
    assert_eq!(acc.prediction, ErrorPrediction::Unbounded);
    assert_eq!(acc.within_bound(), None, "no bound exists to hold");
    assert!(
        acc.observed_max_err > 0.1,
        "observed {:.3e} should dwarf any error-bounded budget",
        acc.observed_max_err
    );
    // And the planner refuses to plan around it.
    let topo = gzccl::net::Topology::new(n, 4).unwrap();
    assert!(plan_auto(
        AccuracyTarget::AbsError(1e-3),
        1,
        &topo,
        gzccl::coordinator::CompressionMode::FixedRate,
    )
    .is_err());
    assert!(Communicator::builder(n)
        .policy(ExecPolicy::cprp2p())
        .accuracy_target(AccuracyTarget::AbsError(1e-3))
        .build()
        .is_err());
}

/// The ISSUE acceptance criterion: under an accuracy budget the tuner
/// demonstrably rejects the algorithm whose stage count would exceed
/// the budget (the flat ring it would otherwise prefer at this message
/// size) and selects a compliant one (hierarchical).
#[test]
fn acceptance_tuner_vetoes_over_budget_algorithm() {
    let n = 32;
    let msg = 256 * MIB; // 8 MiB saturated ring chunks → ring preferred
    let virt = || -> Vec<DeviceBuf> { (0..n).map(|_| DeviceBuf::Virtual(msg / 4)).collect() };

    // Without a budget: performance alone picks the flat ring.
    let free = Communicator::builder(n)
        .gpus_per_node(4)
        .policy(ExecPolicy::gzccl())
        .build()
        .unwrap();
    let unbudgeted = free.allreduce(virt(), &CollectiveSpec::auto()).unwrap();
    assert_eq!(unbudgeted.algo, Algo::Ring);

    // With a budget: ring's 32 linear error stages blow the plan
    // (anchored on hierarchical, 8 nodes → amplification 7), flat
    // ReDoub's 31 doubling stages blow it too — the veto lands on the
    // compliant hierarchical schedule.
    let budgeted = Communicator::builder(n)
        .gpus_per_node(4)
        .policy(ExecPolicy::gzccl())
        .accuracy_target(AccuracyTarget::AbsError(1e-3))
        .build()
        .unwrap();
    let plan = *budgeted.budget_plan().unwrap();
    assert_eq!(plan.amplification, 7.0);
    let picked = budgeted.allreduce(virt(), &CollectiveSpec::auto()).unwrap();
    assert_eq!(picked.algo, Algo::Hierarchical, "veto must reroute the dispatch");
    assert!(picked.auto_tuned);

    // Forcing the over-budget algorithm is rejected, the compliant one
    // is allowed.
    let err = budgeted
        .allreduce(virt(), &CollectiveSpec::forced(Algo::Ring))
        .unwrap_err();
    assert!(
        matches!(err, gzccl::error::Error::Budget(_)),
        "rejection must be the typed budget error, got {err}"
    );
    assert!(err.to_string().contains("accuracy budget"), "{err}");
    assert!(budgeted
        .allreduce(virt(), &CollectiveSpec::forced(Algo::Hierarchical))
        .is_ok());
}

/// End-to-end budget on real payloads: auto dispatch under a planned
/// budget keeps the observed error inside the per-call bound.
#[test]
fn budgeted_dispatch_holds_on_real_payloads() {
    let n = 12; // non-pow2, 3 nodes of 4
    let target = 2e-3;
    let comm = Communicator::builder(n)
        .gpus_per_node(4)
        .policy(ExecPolicy::gzccl())
        .accuracy_target(AccuracyTarget::AbsError(target))
        .build()
        .unwrap();
    let plan = *comm.budget_plan().unwrap();
    let report = comm
        .allreduce(real_inputs(n, 512, 5150, 1.0), &CollectiveSpec::auto())
        .unwrap();
    let acc = report.accuracy.unwrap();
    assert_eq!(acc.within_bound(), Some(true), "{acc:?}");
    assert!(
        acc.observed_max_err <= plan.per_call_abs * 1.01,
        "observed {:.3e} vs per-call budget {:.3e}",
        acc.observed_max_err,
        plan.per_call_abs
    );
}
