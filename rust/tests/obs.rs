//! Flight-recorder suite: backend trace equivalence, exact span
//! accounting, export sanity, and the disabled-by-default guarantee.
//!
//! The tracer's contract is stronger than "produces plausible JSON":
//! (1) the event engine must emit the SAME span tree as the
//! thread-per-rank oracle — names, nesting, lanes and bit-exact
//! virtual timestamps — for every registered (op, algo) pair; (2) the
//! span-derived phase sums must equal the `RankClock`'s own
//! accounting exactly (the spans mirror every charge site 1:1); and
//! (3) with no tracer attached the timeline must be bit-identical to
//! an untraced run — tracing can never perturb what it observes.

use gzccl::collectives::{Algo, Op};
use gzccl::comm::{AlgoRegistry, CollectiveReport, CollectiveSpec, Communicator};
use gzccl::coordinator::{DeviceBuf, ExecBackend};
use gzccl::error::Result;
use gzccl::obs::Tracer;
use gzccl::testkit::Pcg32;

fn real_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
    (0..n)
        .map(|r| {
            let mut rng = Pcg32::new(seed, r as u64);
            DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
        })
        .collect()
}

/// Inputs shaped for `op`: rooted collectives feed the full vector at
/// root 0 and empty buffers elsewhere.
fn op_inputs(op: Op, n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
    match op {
        Op::Scatter | Op::Bcast => {
            let mut inputs = vec![DeviceBuf::Real(vec![]); n];
            inputs[0] = real_inputs(1, d, seed).remove(0);
            inputs
        }
        _ => real_inputs(n, d, seed),
    }
}

fn dispatch(
    comm: &Communicator,
    op: Op,
    inputs: Vec<DeviceBuf>,
    spec: &CollectiveSpec,
) -> Result<CollectiveReport> {
    match op {
        Op::Allreduce => comm.allreduce(inputs, spec),
        Op::Allgather => comm.allgather(inputs, spec),
        Op::ReduceScatter => comm.reduce_scatter(inputs, spec),
        Op::Scatter => comm.scatter(inputs, spec),
        Op::Bcast => comm.bcast(inputs, spec),
    }
}

/// Run `(op, algo)` traced under `backend` and return the report (with
/// its drained `TraceRun` attached).
fn traced_run(op: Op, algo: Algo, backend: ExecBackend, seed: u64) -> CollectiveReport {
    let n = 8;
    let comm = Communicator::builder(n)
        .gpus_per_node(2)
        .error_bound(1e-3)
        .backend(backend)
        .trace(Tracer::new())
        .build()
        .expect("communicator");
    dispatch(
        &comm,
        op,
        op_inputs(op, n, 128, seed),
        &CollectiveSpec::forced(algo),
    )
    .unwrap_or_else(|e| panic!("{op:?}/{algo:?} under {backend:?}: {e}"))
}

/// Satellite: every registered (op, algo) pair produces identical span
/// trees — names, nesting, lanes, bit-exact virtual durations — under
/// the thread oracle and the event engine.
#[test]
fn every_op_algo_pair_traces_identically_across_backends() {
    for &op in &[
        Op::Allreduce,
        Op::Allgather,
        Op::ReduceScatter,
        Op::Scatter,
        Op::Bcast,
    ] {
        for &algo in AlgoRegistry::supported(op) {
            let t = traced_run(op, algo, ExecBackend::Threads, 11);
            let e = traced_run(op, algo, ExecBackend::Events, 11);
            let (tr, er) = (t.trace.as_ref().unwrap(), e.trace.as_ref().unwrap());
            assert_eq!(
                tr.digest(),
                er.digest(),
                "{op:?}/{algo:?}: span trees diverge between backends"
            );
            assert_eq!(
                tr.instant_count(),
                er.instant_count(),
                "{op:?}/{algo:?}: instant counts diverge"
            );
            tr.check_well_formed()
                .unwrap_or_else(|e| panic!("{op:?}/{algo:?} threads: {e}"));
            er.check_well_formed()
                .unwrap_or_else(|e| panic!("{op:?}/{algo:?} events: {e}"));
            // Root spans close exactly at the makespan on both.
            assert_eq!(tr.root_end(), t.report.makespan.as_secs(), "{op:?}/{algo:?}");
            assert_eq!(er.root_end(), e.report.makespan.as_secs(), "{op:?}/{algo:?}");
        }
    }
}

/// The ISSUE's acceptance scenario: a traced 512-rank 4x16x8
/// hierarchical Allreduce whose root spans sum to the makespan
/// exactly, with identical span trees under both backends and a
/// Perfetto-loadable export.
#[test]
fn traced_512_rank_hierarchical_allreduce_acceptance() {
    let run = |backend: ExecBackend| -> CollectiveReport {
        let comm = Communicator::builder(512)
            .tiers(&[4, 16, 8])
            .error_bound(1e-3)
            .backend(backend)
            .trace(Tracer::new())
            .build()
            .expect("communicator");
        let inputs: Vec<DeviceBuf> = (0..512).map(|_| DeviceBuf::Virtual(1 << 16)).collect();
        comm.allreduce(inputs, &CollectiveSpec::forced(Algo::Hierarchical))
            .expect("hierarchical allreduce")
    };
    let t = run(ExecBackend::Threads);
    let e = run(ExecBackend::Events);
    assert_eq!(t.algo, Algo::Hierarchical);
    let (tr, er) = (t.trace.as_ref().unwrap(), e.trace.as_ref().unwrap());
    assert_eq!(tr.tracks.len(), 512);
    // Root spans end exactly at the makespan — f64 equality, no slack.
    assert_eq!(tr.root_end(), t.report.makespan.as_secs());
    assert_eq!(er.root_end(), e.report.makespan.as_secs());
    // Identical trees across backends, structurally well formed.
    assert_eq!(tr.digest(), er.digest());
    tr.check_well_formed().expect("threads trace well formed");
    // Perfetto-loadable: complete events only, on the virtual clock.
    let json = tr.to_chrome_json();
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"displayTimeUnit\""));
    assert!(json.contains("\"ph\": \"X\""));
    assert!(!json.contains("\"ph\": \"B\"") && !json.contains("\"ph\": \"E\""));
    // The uplink tiers left wire-byte and queue-wait metrics behind.
    let reg = tr.metrics_registry();
    assert!(reg.counter("wire_bytes.internode") > 0.0, "{:?}", reg.entries);
    assert!(reg.counter("wire_bytes.uplink_t2") > 0.0, "{:?}", reg.entries);
    assert!(reg.hist("queue_wait_s.nic").is_some());
    let summary = t.trace_summary().expect("traced dispatch has a summary");
    assert_eq!(summary.tracks, 512);
    assert_eq!(summary.root_end, t.report.makespan.as_secs());
}

/// Satellite: the span-derived phase sums equal the clock's own
/// `Breakdown` accounting exactly — every charge site emits exactly
/// one span of the same duration.
#[test]
fn span_phase_sums_match_the_clock_accounting_exactly() {
    for &backend in &[ExecBackend::Threads, ExecBackend::Events] {
        let comm = Communicator::builder(16)
            .tiers(&[2, 4, 2])
            .error_bound(1e-3)
            .backend(backend)
            .trace(Tracer::new())
            .build()
            .expect("communicator");
        let out = comm
            .allreduce(
                real_inputs(16, 256, 21),
                &CollectiveSpec::forced(Algo::Hierarchical),
            )
            .expect("allreduce");
        let run = out.trace.as_ref().unwrap();
        assert_eq!(
            run.total_breakdown(),
            out.report.total_breakdown(),
            "{backend:?}: span-derived phase sums drifted from the clock"
        );
    }
}

/// Tracing is disabled by default and must not perturb the timeline:
/// the same collective with and without a tracer attached reports the
/// identical makespan and wire volume.
#[test]
fn tracing_disabled_leaves_the_timeline_untouched() {
    let run = |trace: bool| {
        let mut b = Communicator::builder(32)
            .tiers(&[4, 4, 2])
            .error_bound(1e-3)
            .backend(ExecBackend::Events);
        if trace {
            b = b.trace(Tracer::new());
        }
        let comm = b.build().expect("communicator");
        let inputs: Vec<DeviceBuf> = (0..32).map(|_| DeviceBuf::Virtual(1 << 18)).collect();
        comm.allreduce(inputs, &CollectiveSpec::forced(Algo::Hierarchical))
            .expect("allreduce")
    };
    let plain = run(false);
    let traced = run(true);
    assert_eq!(plain.report.makespan, traced.report.makespan);
    assert_eq!(plain.report.total_wire_bytes(), traced.report.total_wire_bytes());
    assert!(plain.trace.is_none());
    assert!(traced.trace.is_some());
}

/// Dispatch instants: the tuner's decision record (with priced
/// rejected alternatives) rides along every traced auto dispatch, and
/// compression metrics aggregate per codec.
#[test]
fn dispatch_instants_and_codec_metrics_are_recorded() {
    let tracer = Tracer::new();
    let comm = Communicator::builder(8)
        .gpus_per_node(4)
        .error_bound(1e-3)
        .trace(tracer.clone())
        .build()
        .expect("communicator");
    let out = comm
        .allreduce(real_inputs(8, 256, 33), &CollectiveSpec::auto())
        .expect("allreduce");
    let run = out.trace.as_ref().unwrap();
    let decision = run
        .instants
        .iter()
        .find(|i| i.name == "tuner-decision")
        .expect("auto dispatch records its tuner decision");
    assert!(decision.args.iter().any(|(k, _)| *k == "rejected"));
    assert!(decision.args.iter().any(|(k, v)| *k == "source" && v == "auto"));
    let reg = run.metrics_registry();
    let ratio: Vec<&String> = reg
        .entries
        .keys()
        .filter(|k| k.starts_with("cpr_ratio."))
        .collect();
    assert!(!ratio.is_empty(), "compressed run derives a codec ratio gauge");
    // Two dispatches through one tracer stack up as two archived runs.
    comm.allreduce(real_inputs(8, 256, 34), &CollectiveSpec::auto())
        .expect("second allreduce");
    assert_eq!(tracer.runs().len(), 2);
    let merged = tracer.chrome_json();
    assert!(merged.contains("run 0 start") && merged.contains("run 1 start"));
}

/// Satellite: analyzer invariants on the ISSUE's 512-rank 4x16x8
/// acceptance scenario, under both backends. The critical path must
/// reproduce the makespan bit-exactly, slacks are non-negative by
/// construction, the category rollup sums to the path total, and the
/// extracted path is digest-stable across execution backends.
#[test]
fn analyzer_invariants_on_512_rank_hierarchical_allreduce() {
    use gzccl::obs::analysis::Category;
    let run = |backend: ExecBackend| -> CollectiveReport {
        let comm = Communicator::builder(512)
            .tiers(&[4, 16, 8])
            .error_bound(1e-3)
            .backend(backend)
            .trace(Tracer::new())
            .build()
            .expect("communicator");
        let inputs: Vec<DeviceBuf> = (0..512).map(|_| DeviceBuf::Virtual(1 << 16)).collect();
        comm.allreduce(inputs, &CollectiveSpec::forced(Algo::Hierarchical))
            .expect("hierarchical allreduce")
    };
    let t = run(ExecBackend::Threads);
    let e = run(ExecBackend::Events);
    let mut digests = Vec::new();
    for (name, rep) in [("threads", &t), ("events", &e)] {
        let tr = rep.trace.as_ref().unwrap();
        let a = tr.analyze();
        // Critical path == makespan, bit-exact f64 equality.
        assert_eq!(a.critical_path.total_s(), tr.root_end(), "{name}");
        assert_eq!(a.makespan_s, rep.report.makespan.as_secs(), "{name}");
        // Chain segments tile the interval with shared boundaries.
        for w in a.critical_path.segments.windows(2) {
            assert_eq!(w[0].end, w[1].start, "{name}: gap in the chain");
        }
        // Slack is non-negative everywhere by construction.
        assert!(a.slacks.iter().all(|s| s.slack_s >= 0.0), "{name}");
        // Category rollup sums to the path total.
        let by_cat: f64 = [Category::Kernel, Category::Wire, Category::Queue, Category::Host]
            .iter()
            .map(|&c| a.bottlenecks.category_s(c))
            .sum();
        let total = a.critical_path.total_s();
        assert!(
            (by_cat - total).abs() <= 1e-9 * total.max(1e-30),
            "{name}: categories sum to {by_cat}, critical path is {total}"
        );
        // A deep schedule with shared uplinks must show network time.
        assert!(a.bottlenecks.category_s(Category::Wire) > 0.0, "{name}");
        assert!(!a.bottlenecks.by_tier.is_empty(), "{name}");
        digests.push(a.digest());
    }
    assert_eq!(digests[0], digests[1], "critical path diverges across backends");
}

/// The ISSUE's calibration acceptance: fit a calibration from a traced
/// run, then on a *held-out* message size the calibrated cost model's
/// per-leg predictions must carry a strictly smaller max relative
/// residual than the nameplate model's, and the tuner's pick under the
/// calibrated model must be no slower than before.
#[test]
fn calibration_shrinks_heldout_residuals_and_never_degrades_tuning() {
    let fit_elems = 1 << 16; // traced fitting size
    let heldout_elems = 1 << 18; // never seen by the fit
    let build = |cal: Option<std::sync::Arc<gzccl::obs::TraceRun>>| -> Communicator {
        let mut b = Communicator::builder(512)
            .tiers(&[4, 16, 8])
            .error_bound(1e-3)
            .trace(Tracer::new());
        if let Some(run) = cal {
            b = b.calibrate_from(run);
        }
        b.build().expect("communicator")
    };
    let inputs = |elems: usize| -> Vec<DeviceBuf> {
        (0..512).map(|_| DeviceBuf::Virtual(elems)).collect()
    };
    let base = build(None);
    let fit_run = base
        .allreduce(inputs(fit_elems), &CollectiveSpec::forced(Algo::Hierarchical))
        .expect("fitting run")
        .trace
        .clone()
        .expect("traced");
    let calibrated = build(Some(fit_run));
    assert!(
        calibrated.calibration().is_some_and(|c| !c.is_empty()),
        "the traced run must yield a non-empty fit"
    );

    // Held-out size, forced hierarchical on both communicators. Each
    // dispatch annotates ITS cost model's per-leg predictions onto the
    // trace, so each run's residuals score that model against the
    // fabric it actually simulated.
    let spec = CollectiveSpec::forced(Algo::Hierarchical);
    let before = base.allreduce(inputs(heldout_elems), &spec).expect("uncalibrated");
    let after = calibrated
        .allreduce(inputs(heldout_elems), &spec)
        .expect("calibrated");
    let r_before = before
        .analysis()
        .and_then(|a| a.max_relative_residual())
        .expect("uncalibrated residuals");
    let r_after = after
        .analysis()
        .and_then(|a| a.max_relative_residual())
        .expect("calibrated residuals");
    assert!(
        r_after < r_before,
        "calibration must shrink the held-out max residual ({r_before:.3} -> {r_after:.3})"
    );

    // The tuner under the calibrated model picks a schedule that is no
    // slower than the nameplate model's pick.
    let auto_before = base
        .allreduce(inputs(heldout_elems), &CollectiveSpec::auto())
        .expect("auto uncalibrated");
    let auto_after = calibrated
        .allreduce(inputs(heldout_elems), &CollectiveSpec::auto())
        .expect("auto calibrated");
    assert!(
        auto_after.report.makespan.as_secs() <= auto_before.report.makespan.as_secs(),
        "calibrated tuning must not degrade the pick ({} -> {})",
        auto_before.report.makespan,
        auto_after.report.makespan
    );
}
