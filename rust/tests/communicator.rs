//! Integration tests for the unified `Communicator` API: policy- and
//! topology-aware tuning, forced hints, decision recording, and the
//! root-dependent compression-stage predictions.

use gzccl::collectives::{
    expected_cpr_stages, expected_cpr_stages_at, expected_cpr_stages_hier, Algo, Op,
};
use gzccl::comm::{AlgoHint, CollectiveSpec, Communicator, Tuner};
use gzccl::coordinator::{DeviceBuf, ExecPolicy};

const MIB: usize = 1 << 20;

fn virt(n: usize, bytes: usize) -> Vec<DeviceBuf> {
    (0..n).map(|_| DeviceBuf::Virtual(bytes / 4)).collect()
}

fn virt_root(n: usize, bytes: usize) -> Vec<DeviceBuf> {
    let mut v = vec![DeviceBuf::Virtual(bytes / 4)];
    for _ in 1..n {
        v.push(DeviceBuf::Virtual(0));
    }
    v
}

#[test]
fn auto_allreduce_crossover_32_ranks_gzccl() {
    // With `AlgoHint::Auto` on 32 ranks (8 nodes × 4 GPUs) under the
    // full gZCCL policy, the tuner selects the ring at ≥ 64 MiB (its
    // D/N chunks stay above the utilization knee) and the two-level
    // hierarchical schedule below it.
    let comm = Communicator::builder(32)
        .policy(ExecPolicy::gzccl())
        .build()
        .unwrap();
    for mb in [64usize, 256] {
        let r = comm
            .allreduce(virt(32, mb * MIB), &CollectiveSpec::auto())
            .unwrap();
        assert_eq!(r.algo, Algo::Ring, "{mb} MiB should pick the ring");
        assert!(r.auto_tuned);
        for c in &r.counters {
            assert_eq!(c.algo_selected, Some(Algo::Ring));
            assert_eq!(c.tuner_decisions, 1);
        }
    }
    for kib in [256usize, 1024] {
        let r = comm
            .allreduce(virt(32, kib << 10), &CollectiveSpec::auto())
            .unwrap();
        assert_eq!(
            r.algo,
            Algo::Hierarchical,
            "{kib} KiB on a multi-node layout should pick hierarchical"
        );
        for c in &r.counters {
            assert_eq!(c.algo_selected, Some(Algo::Hierarchical));
        }
    }
    // On a single fat node the flat model is back: small messages go
    // to recursive doubling.
    let single = Communicator::builder(32)
        .gpus_per_node(32)
        .policy(ExecPolicy::gzccl())
        .build()
        .unwrap();
    let r = single
        .allreduce(virt(32, MIB), &CollectiveSpec::auto())
        .unwrap();
    assert_eq!(r.algo, Algo::RecursiveDoubling);
}

#[test]
fn force_hint_bypasses_tuner_at_any_size() {
    let comm = Communicator::builder(32).build().unwrap();
    // 256 MiB would auto-select the ring; the hint pins ReDoub.
    let r = comm
        .allreduce(
            virt(32, 256 * MIB),
            &CollectiveSpec::hinted(AlgoHint::Force(Algo::RecursiveDoubling)),
        )
        .unwrap();
    assert_eq!(r.algo, Algo::RecursiveDoubling);
    assert!(!r.auto_tuned);
    for c in &r.counters {
        assert_eq!(c.algo_selected, Some(Algo::RecursiveDoubling));
        assert_eq!(c.tuner_decisions, 0);
    }
}

#[test]
fn auto_choice_depends_on_policy() {
    // 4 MiB on 32 ranks (8 nodes): 128 KiB ring chunks are under the
    // compression utilization knee → the hierarchical schedule for
    // gZCCL; the uncompressed NCCL-class baseline is bandwidth-bound
    // there → flat ring.
    let gz = Communicator::builder(32).policy(ExecPolicy::gzccl()).build().unwrap();
    let nccl = Communicator::builder(32).policy(ExecPolicy::nccl()).build().unwrap();
    let a = gz.allreduce(virt(32, 4 * MIB), &CollectiveSpec::auto()).unwrap();
    let b = nccl.allreduce(virt(32, 4 * MIB), &CollectiveSpec::auto()).unwrap();
    assert_eq!(a.algo, Algo::Hierarchical);
    assert_eq!(b.algo, Algo::Ring);
}

#[test]
fn crossover_moves_with_nranks() {
    // Same 64 MiB message; ring chunks shrink with scale, so the
    // crossover message size grows with the rank count.
    let t = Tuner::default();
    let p = ExecPolicy::gzccl();
    assert_eq!(t.select(Op::Allreduce, p, 8, 64 * MIB), Algo::Ring);
    assert_eq!(t.select(Op::Allreduce, p, 128, 64 * MIB), Algo::RecursiveDoubling);
    assert!(t.allreduce_crossover_bytes(p, 128) > t.allreduce_crossover_bytes(p, 8));
}

#[test]
fn scatter_and_bcast_match_root_dependent_stage_table() {
    // The §3.3.3 complexity table, root-resolved: actual per-rank
    // kernel counters must equal expected_cpr_stages_at for the gZCCL
    // compress-once binomial-tree collectives.
    let n = 8;
    let comm = Communicator::builder(n).policy(ExecPolicy::gzccl()).build().unwrap();

    let scatter = comm
        .scatter(virt_root(n, 4 * MIB), &CollectiveSpec::auto())
        .unwrap();
    assert_eq!(scatter.algo, Algo::Binomial);
    for (rank, c) in scatter.counters.iter().enumerate() {
        let (cpr, dec) =
            expected_cpr_stages_at(Op::Scatter, Algo::Binomial, n, rank, 0).expect("predicted");
        assert_eq!(c.compress_calls, cpr, "scatter rank {rank} compressions");
        assert_eq!(c.decompress_calls, dec, "scatter rank {rank} decompressions");
    }

    let bcast = comm
        .bcast(virt_root(n, 4 * MIB), &CollectiveSpec::auto())
        .unwrap();
    assert_eq!(bcast.algo, Algo::Binomial);
    for (rank, c) in bcast.counters.iter().enumerate() {
        let (cpr, dec) =
            expected_cpr_stages_at(Op::Bcast, Algo::Binomial, n, rank, 0).expect("predicted");
        assert_eq!(c.compress_calls, cpr, "bcast rank {rank} compressions");
        assert_eq!(c.decompress_calls, dec, "bcast rank {rank} decompressions");
    }
}

#[test]
fn nonzero_roots_match_stage_table_and_outputs() {
    // Arbitrary-root Scatter/Bcast: the kernel-stage table rotates with
    // the root, and every root in 0..n succeeds.
    let n = 8;
    let comm = Communicator::builder(n).policy(ExecPolicy::gzccl()).build().unwrap();
    for root in 0..n {
        let mk = || -> Vec<DeviceBuf> {
            (0..n)
                .map(|r| DeviceBuf::Virtual(if r == root { MIB } else { 0 }))
                .collect()
        };
        let spec = CollectiveSpec::auto().with_root(root);
        let scatter = comm.scatter(mk(), &spec).unwrap();
        for (rank, c) in scatter.counters.iter().enumerate() {
            let (cpr, dec) = expected_cpr_stages_at(Op::Scatter, Algo::Binomial, n, rank, root)
                .expect("predicted");
            assert_eq!(c.compress_calls, cpr, "scatter root {root} rank {rank}");
            assert_eq!(c.decompress_calls, dec, "scatter root {root} rank {rank}");
        }
        let bcast = comm.bcast(mk(), &spec).unwrap();
        for (rank, c) in bcast.counters.iter().enumerate() {
            let (cpr, dec) = expected_cpr_stages_at(Op::Bcast, Algo::Binomial, n, rank, root)
                .expect("predicted");
            assert_eq!(c.compress_calls, cpr, "bcast root {root} rank {rank}");
            assert_eq!(c.decompress_calls, dec, "bcast root {root} rank {rank}");
        }
        // Every rank gets the root's element count back.
        for out in &bcast.outputs {
            assert_eq!(out.elems(), MIB, "bcast root {root}");
        }
    }
}

#[test]
fn rank_symmetric_ops_match_stage_table_through_communicator() {
    let n = 8;
    let comm = Communicator::builder(n).policy(ExecPolicy::gzccl()).build().unwrap();
    for (algo, op_bytes) in [(Algo::Ring, 4 * MIB), (Algo::RecursiveDoubling, MIB)] {
        let r = comm
            .allreduce(virt(n, op_bytes), &CollectiveSpec::forced(algo))
            .unwrap();
        let (cpr, dec) = expected_cpr_stages(Op::Allreduce, algo, n).expect("predicted");
        for c in &r.counters {
            assert_eq!(c.compress_calls, cpr, "{algo:?} compressions");
            assert_eq!(c.decompress_calls, dec, "{algo:?} decompressions");
        }
    }
}

#[test]
fn tuned_ring_and_hier_actually_run_their_schedules() {
    // The dispatch is not just a label: kernel counters must match the
    // algorithm the tuner reports.
    let n = 32;
    let g = 4;
    let comm = Communicator::builder(n).build().unwrap();
    let big = comm.allreduce(virt(n, 64 * MIB), &CollectiveSpec::auto()).unwrap();
    assert_eq!(big.algo, Algo::Ring);
    // Ring: N compressions, 2(N−1) decompressions per rank.
    assert_eq!(big.counters[0].compress_calls, n);
    assert_eq!(big.counters[0].decompress_calls, 2 * (n - 1));
    let small = comm.allreduce(virt(n, MIB), &CollectiveSpec::auto()).unwrap();
    assert_eq!(small.algo, Algo::Hierarchical);
    // Hierarchical: only node leaders compress, ⌈log₂ nodes⌉ = 3 times
    // (8 nodes); members never touch the compressor.
    for (rank, c) in small.counters.iter().enumerate() {
        let (cpr, dec) = expected_cpr_stages_hier(n, g, rank);
        assert_eq!(c.compress_calls, cpr, "rank {rank} compressions");
        assert_eq!(c.decompress_calls, dec, "rank {rank} decompressions");
    }
    assert_eq!(small.counters[0].compress_calls, 3);
    assert_eq!(small.counters[1].compress_calls, 0);
    // A forced flat ReDoub still runs its own schedule.
    let forced = comm
        .allreduce(virt(n, MIB), &CollectiveSpec::forced(Algo::RecursiveDoubling))
        .unwrap();
    assert_eq!(forced.counters[0].compress_calls, 5);
    assert_eq!(forced.counters[0].decompress_calls, 5);
}
