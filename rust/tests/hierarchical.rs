//! Topology-aware hierarchical Allreduce: equivalence properties,
//! error accounting, and the 128-rank acceptance criterion.

use gzccl::collectives::{allreduce_hierarchical, allreduce_ring, Algo};
use gzccl::comm::{CollectiveSpec, Communicator};
use gzccl::coordinator::{run_collective, ClusterSpec, DeviceBuf, ExecPolicy};
use gzccl::net::Topology;
use gzccl::testkit::{forall, Cases, Pcg32};

const MIB: usize = 1 << 20;

fn spec(n: usize, g: usize, policy: ExecPolicy) -> ClusterSpec {
    ClusterSpec::with_topology(Topology::new(n, g).unwrap(), policy)
}

/// Integer-valued inputs: sums of small integers are exact in f32, so
/// schedules with different reduction orders must agree bit-for-bit.
fn int_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
    (0..n)
        .map(|r| {
            let mut rng = Pcg32::new(seed, r as u64);
            DeviceBuf::Real((0..d).map(|_| rng.range_usize(0, 33) as f32 - 16.0).collect())
        })
        .collect()
}

fn real_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
    (0..n)
        .map(|r| {
            let mut rng = Pcg32::new(seed, r as u64);
            DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
        })
        .collect()
}

fn exact_sum(inputs: &[DeviceBuf]) -> Vec<f32> {
    let d = inputs[0].elems();
    let mut out = vec![0.0f32; d];
    for b in inputs {
        for (o, v) in out.iter_mut().zip(b.as_real()) {
            *o += v;
        }
    }
    out
}

#[test]
fn prop_hier_matches_flat_ring_bitwise_uncompressed() {
    // Random shapes including non-power-of-two rank counts, partial
    // last nodes and degenerate layouts: uncompressed hierarchical
    // must equal the flat ring bit-for-bit on integer-exact data.
    forall(
        Cases::n(16),
        |rng| {
            let g = rng.range_usize(1, 4); // GPUs per node (inclusive)
            let n = rng.range_usize(2, 13); // ranks (inclusive)
            let d = rng.range_usize(1, 120);
            (n, g, d, rng.next_u64())
        },
        |&(n, g, d, seed)| {
            let inputs = int_inputs(n, d, seed);
            let ring = run_collective(&spec(n, g, ExecPolicy::nccl()), inputs.clone(), &allreduce_ring)
                .map_err(|e| e.to_string())?;
            let hier = run_collective(
                &spec(n, g, ExecPolicy::nccl()),
                inputs,
                &allreduce_hierarchical,
            )
            .map_err(|e| e.to_string())?;
            for r in 0..n {
                if hier.outputs[r].as_real() != ring.outputs[r].as_real() {
                    return Err(format!("rank {r} differs from flat ring"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_hier_compressed_within_stacked_error_bound() {
    // Compression is confined to the internode leg: the stacked error
    // scales with the internode exchange count (⌈log₂ nodes⌉ plus the
    // non-pow2 fold/unfold), never with the rank count.
    let eb = 1e-3f32;
    forall(
        Cases::n(10),
        |rng| {
            let g = rng.range_usize(2, 4);
            let n = rng.range_usize(2, 13);
            let d = rng.range_usize(8, 160);
            (n, g, d, rng.next_u64())
        },
        |&(n, g, d, seed)| {
            let inputs = real_inputs(n, d, seed);
            let expect = exact_sum(&inputs);
            let report = run_collective(
                &spec(n, g, ExecPolicy::gzccl()).with_error_bound(eb as f64),
                inputs,
                &allreduce_hierarchical,
            )
            .map_err(|e| e.to_string())?;
            let nodes = n.div_ceil(g);
            let stages = (usize::BITS - nodes.leading_zeros()) as usize + 2;
            // Worst-case exchange-error recurrence e' = 2e + eb over
            // `stages` steps is (2^stages − 1)·eb.
            let tol = ((1usize << stages) as f32) * eb;
            for (r, out) in report.outputs.iter().enumerate() {
                for (i, (a, b)) in out.as_real().iter().zip(&expect).enumerate() {
                    if (a - b).abs() > tol {
                        return Err(format!(
                            "n={n} g={g} rank {r} elem {i}: {a} vs {b} beyond {tol}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The ISSUE acceptance criterion: on a simulated 128-rank,
/// 4-GPUs-per-node cluster at 64 MiB, the tuner selects the
/// hierarchical schedule and it strictly beats the flat ring.
#[test]
fn acceptance_128_ranks_tuner_picks_hier_and_beats_flat_ring() {
    let n = 128;
    let comm = Communicator::builder(n)
        .gpus_per_node(4)
        .policy(ExecPolicy::gzccl())
        .build()
        .unwrap();
    let virt = || -> Vec<DeviceBuf> {
        (0..n).map(|_| DeviceBuf::Virtual(64 * MIB / 4)).collect()
    };
    let auto = comm.allreduce(virt(), &CollectiveSpec::auto()).unwrap();
    assert_eq!(auto.algo, Algo::Hierarchical, "tuner must select hierarchical");
    assert!(auto.auto_tuned);
    let ring = comm
        .allreduce(virt(), &CollectiveSpec::forced(Algo::Ring))
        .unwrap();
    assert!(
        auto.makespan.as_secs() < ring.makespan.as_secs(),
        "hierarchical {} must strictly beat the flat ring {}",
        auto.makespan,
        ring.makespan
    );
    // It also beats the flat whole-vector schedule it generalizes.
    let redoub = comm
        .allreduce(virt(), &CollectiveSpec::forced(Algo::RecursiveDoubling))
        .unwrap();
    assert!(
        auto.makespan.as_secs() < redoub.makespan.as_secs(),
        "hierarchical {} vs flat redoub {}",
        auto.makespan,
        redoub.makespan
    );
}

/// Companion to the acceptance criterion: at the same 128-rank shape,
/// the hierarchical schedule produces results identical to the flat
/// ring when uncompressed.
#[test]
fn acceptance_128_ranks_identical_results_uncompressed() {
    let n = 128;
    let d = 96;
    let sp = spec(n, 4, ExecPolicy::nccl());
    let inputs = int_inputs(n, d, 4242);
    let ring = run_collective(&sp, inputs.clone(), &allreduce_ring).unwrap();
    let hier = run_collective(&sp, inputs, &allreduce_hierarchical).unwrap();
    for r in 0..n {
        assert_eq!(
            hier.outputs[r].as_real(),
            ring.outputs[r].as_real(),
            "rank {r}"
        );
    }
}

#[test]
fn hier_keeps_internode_wire_volume_on_leaders() {
    // Only leaders talk across nodes; members' wire traffic is exactly
    // their two NVLink legs (one raw vector up, one down — the down leg
    // is charged to the leader's counters as the sender).
    let n = 16;
    let g = 4;
    let d = 1 << 14;
    let inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(d)).collect();
    let report = run_collective(&spec(n, g, ExecPolicy::nccl()), inputs, &allreduce_hierarchical)
        .unwrap();
    for r in 0..n {
        let c = &report.counters[r];
        if r % g == 0 {
            // Leader: 3 intranode down-sends + log2(4 nodes) = 2
            // internode exchanges.
            assert_eq!(c.msgs_sent, 3 + 2, "leader {r}");
        } else {
            assert_eq!(c.msgs_sent, 1, "member {r} sends only its up-leg");
            assert_eq!(c.wire_bytes, d * 4, "member {r} wire volume");
        }
    }
}
