//! Pipelined-collective suite: chunk-level leg overlap must never
//! change a single output bit, `icollective`/persistent surfaces must
//! match the blocking dispatch exactly, and at the 512-rank 4x16x8
//! acceptance shape the tuner must choose a depth > 1 that strictly
//! beats the barrier executor while keeping every trace invariant.
//!
//! Bitwise identity holds even under compression because the cuSZp
//! quantizer reconstructs each element as `q·2eb` independently of the
//! codec's block boundaries: slicing a dispatch into chunk windows
//! moves those boundaries but not the per-element quantum. Integer
//! inputs keep the reduction arithmetic exact so different leg
//! interleavings cannot introduce rounding skew either.

use gzccl::collectives::{Algo, Op, MAX_PIPELINE_DEPTH};
use gzccl::comm::{AlgoRegistry, CollectiveReport, CollectiveSpec, Communicator, Pipeline};
use gzccl::coordinator::{DeviceBuf, ExecBackend, ExecPolicy};
use gzccl::obs::Tracer;
use gzccl::testkit::Pcg32;

const MIB: usize = 1 << 20;
const ALL_OPS: [Op; 5] = [
    Op::Allreduce,
    Op::Allgather,
    Op::ReduceScatter,
    Op::Scatter,
    Op::Bcast,
];

/// Integer-valued inputs shaped for `op`: rooted collectives feed the
/// full vector at `root` and empty buffers elsewhere; sums of small
/// integers are exact in f32, so any leg interleaving must agree
/// bit-for-bit.
fn op_inputs(op: Op, n: usize, d: usize, root: usize, seed: u64) -> Vec<DeviceBuf> {
    let ints = |r: usize| -> DeviceBuf {
        let mut rng = Pcg32::new(seed, r as u64);
        DeviceBuf::Real((0..d).map(|_| rng.range_usize(0, 33) as f32 - 16.0).collect())
    };
    match op {
        Op::Scatter | Op::Bcast => {
            let mut inputs = vec![DeviceBuf::Real(vec![]); n];
            inputs[root] = ints(root);
            inputs
        }
        _ => (0..n).map(ints).collect(),
    }
}

fn assert_outputs_bitwise_eq(a: &CollectiveReport, b: &CollectiveReport, what: &str) {
    assert_eq!(a.outputs.len(), b.outputs.len(), "{what}: rank counts");
    for (r, (x, y)) in a.outputs.iter().zip(&b.outputs).enumerate() {
        assert_eq!(x.as_real(), y.as_real(), "{what}: rank {r} outputs differ");
    }
}

/// Satellite: for EVERY registered (op, algo) pair, on BOTH execution
/// backends, forcing a pipeline depth produces bit-identical outputs
/// to the barrier executor. No makespan assertion here on purpose —
/// forced depth on small messages can lose to per-chunk latency
/// floors; winning is asserted at the 512-rank acceptance shape where
/// the tuner picks the depth itself.
#[test]
fn every_pair_pipelined_matches_barrier_bitwise_on_both_backends() {
    let n = 8;
    let d = 97; // ragged against both the 32-wide codec blocks and every chunk split
    let root = 3; // non-zero: the rooted hierarchical descent exercises its RootShift leg
    for &op in &ALL_OPS {
        for &algo in AlgoRegistry::supported(op) {
            for &backend in &[ExecBackend::Threads, ExecBackend::Events] {
                let run = |pipeline: Pipeline| -> CollectiveReport {
                    let comm = Communicator::builder(n)
                        .gpus_per_node(2)
                        .error_bound(1e-3)
                        .backend(backend)
                        .pipeline(pipeline)
                        .build()
                        .expect("communicator");
                    comm.collective(
                        op,
                        op_inputs(op, n, d, root, 7),
                        &CollectiveSpec::forced(algo).with_root(root),
                    )
                    .unwrap_or_else(|e| panic!("{op:?}/{algo:?} under {backend:?}: {e}"))
                };
                let barrier = run(Pipeline::Off);
                assert_eq!(barrier.exec_plan.depth, 1);
                for depth in [2usize, 4] {
                    let piped = run(Pipeline::Fixed(depth));
                    assert_outputs_bitwise_eq(
                        &piped,
                        &barrier,
                        &format!("{op:?}/{algo:?}/{backend:?} depth {depth}"),
                    );
                }
            }
        }
    }
}

/// Satellite: the rooted hierarchical registry entries accept
/// arbitrary roots and agree bit-for-bit with the binomial baseline
/// (uncompressed, so both paths are lossless), at every depth.
#[test]
fn rooted_hierarchical_matches_binomial_for_arbitrary_roots() {
    let n = 8;
    let d = 120;
    for &op in &[Op::Scatter, Op::Bcast] {
        for root in [0usize, 1, 5, 7] {
            let run = |algo: Algo, pipeline: Pipeline| -> CollectiveReport {
                let comm = Communicator::builder(n)
                    .gpus_per_node(2)
                    .policy(ExecPolicy::nccl())
                    .pipeline(pipeline)
                    .build()
                    .expect("communicator");
                comm.collective(
                    op,
                    op_inputs(op, n, d, root, 11),
                    &CollectiveSpec::forced(algo).with_root(root),
                )
                .unwrap_or_else(|e| panic!("{op:?}/{algo:?} root {root}: {e}"))
            };
            let binomial = run(Algo::Binomial, Pipeline::Off);
            let hier = run(Algo::Hierarchical, Pipeline::Off);
            assert_outputs_bitwise_eq(&hier, &binomial, &format!("{op:?} root {root}"));
            let piped = run(Algo::Hierarchical, Pipeline::Fixed(3));
            assert_outputs_bitwise_eq(&piped, &binomial, &format!("{op:?} root {root} piped"));
        }
    }
}

/// The ISSUE acceptance criterion, part 1: at 512 ranks (4x16x8,
/// 64 MiB) the auto dispatch chooses the hierarchical schedule at a
/// pipeline depth > 1 whose makespan strictly beats the barrier
/// executor, and the traced run keeps every flight-recorder and
/// analyzer invariant — on both execution backends, with identical
/// span trees across them.
#[test]
fn acceptance_512_ranks_tuner_picks_depth_and_beats_barrier() {
    let n = 512;
    let run = |backend: ExecBackend, pipeline: Pipeline| -> CollectiveReport {
        let comm = Communicator::builder(n)
            .tiers(&[4, 16, 8])
            .policy(ExecPolicy::gzccl())
            .backend(backend)
            .pipeline(pipeline)
            .trace(Tracer::new())
            .build()
            .expect("communicator");
        let inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(64 * MIB / 4)).collect();
        comm.allreduce(inputs, &CollectiveSpec::auto()).expect("allreduce")
    };
    let mut digests = Vec::new();
    for &backend in &[ExecBackend::Threads, ExecBackend::Events] {
        let piped = run(backend, Pipeline::Auto);
        assert_eq!(piped.algo, Algo::Hierarchical, "{backend:?}: tuner must pick hierarchical");
        assert!(piped.auto_tuned);
        assert!(
            piped.exec_plan.depth > 1,
            "{backend:?}: 64 MiB must pipeline (got depth {})",
            piped.exec_plan.depth
        );
        let barrier = run(backend, Pipeline::Off);
        assert_eq!(barrier.exec_plan.depth, 1);
        assert!(
            piped.makespan.as_secs() < barrier.makespan.as_secs(),
            "{backend:?}: depth {} makespan {} must strictly beat the barrier {}",
            piped.exec_plan.depth,
            piped.makespan,
            barrier.makespan
        );
        // Chunk-aware telemetry keeps every invariant the barrier
        // executor guaranteed: well-formed span trees closing at the
        // makespan, and a critical path that tiles it bit-exactly.
        let tr = piped.trace.as_ref().expect("traced dispatch");
        tr.check_well_formed().unwrap_or_else(|e| panic!("{backend:?}: {e}"));
        assert_eq!(tr.root_end(), piped.report.makespan.as_secs(), "{backend:?}");
        let a = tr.analyze();
        assert_eq!(a.critical_path.total_s(), tr.root_end(), "{backend:?}: path != makespan");
        for w in a.critical_path.segments.windows(2) {
            assert_eq!(w[0].end, w[1].start, "{backend:?}: gap in the critical path");
        }
        digests.push(tr.digest());
    }
    assert_eq!(digests[0], digests[1], "pipelined span trees diverge across backends");
}

/// The ISSUE acceptance criterion, part 2: at the same 512-rank shape
/// on real payloads, the pipelined hierarchical Allreduce is bitwise
/// identical to the barrier run on both backends.
#[test]
fn acceptance_512_ranks_pipelined_outputs_bitwise_match_barrier() {
    let n = 512;
    let d = 1000;
    for &backend in &[ExecBackend::Threads, ExecBackend::Events] {
        let run = |pipeline: Pipeline| -> CollectiveReport {
            let comm = Communicator::builder(n)
                .tiers(&[4, 16, 8])
                .error_bound(1e-3)
                .backend(backend)
                .pipeline(pipeline)
                .build()
                .expect("communicator");
            comm.allreduce(
                op_inputs(Op::Allreduce, n, d, 0, 99),
                &CollectiveSpec::forced(Algo::Hierarchical),
            )
            .expect("allreduce")
        };
        let barrier = run(Pipeline::Off);
        let piped = run(Pipeline::Fixed(4));
        assert_eq!(piped.exec_plan.depth, 4);
        assert_outputs_bitwise_eq(&piped, &barrier, &format!("512-rank {backend:?}"));
    }
}

/// `persistent()` freezes one plan and replays it: every run matches
/// the equivalent per-dispatch path bit-for-bit, the frozen depth is
/// the one the dispatcher would have chosen, and the plan survives
/// reuse across distinct payloads.
#[test]
fn persistent_plan_replays_match_direct_dispatch() {
    let n = 8;
    let d = 256;
    let comm = Communicator::builder(n)
        .gpus_per_node(2)
        .error_bound(1e-3)
        .build()
        .expect("communicator");
    let spec = CollectiveSpec::forced(Algo::Hierarchical);
    let pc = comm.persistent(Op::Allreduce, d, &spec).expect("persistent plan");
    assert_eq!(pc.op(), Op::Allreduce);
    assert_eq!(pc.algo(), Algo::Hierarchical);
    assert_eq!(pc.depth(), pc.exec_plan().depth);
    assert!(pc.schedule().is_some(), "hierarchical plan carries its schedule");
    for seed in [21u64, 22] {
        let inputs = op_inputs(Op::Allreduce, n, d, 0, seed);
        let direct = comm.allreduce(inputs.clone(), &spec).expect("direct");
        let frozen = pc.run(inputs).expect("persistent run");
        assert_eq!(frozen.exec_plan.depth, direct.exec_plan.depth);
        assert_outputs_bitwise_eq(&frozen, &direct, &format!("persistent seed {seed}"));
    }
    // A forced depth ABOVE the cap clamps rather than erroring.
    let deep = comm
        .with_pipeline(Pipeline::Fixed(64))
        .persistent(Op::Allreduce, d, &spec)
        .expect("clamped plan");
    assert_eq!(deep.depth(), MAX_PIPELINE_DEPTH);
}

/// `icollective()` and `PersistentColl::irun()` run the dispatch on a
/// worker thread and hand back the identical report through the
/// handle.
#[test]
fn icollective_handles_return_the_blocking_result() {
    let n = 8;
    let d = 192;
    let comm = Communicator::builder(n)
        .gpus_per_node(2)
        .error_bound(1e-3)
        .build()
        .expect("communicator");
    let spec = CollectiveSpec::forced(Algo::Hierarchical);
    let inputs = || op_inputs(Op::Allreduce, n, d, 0, 5);
    let blocking = comm.allreduce(inputs(), &spec).expect("blocking");
    let handle = comm.icollective(Op::Allreduce, inputs(), &spec);
    let async_report = handle.wait().expect("icollective");
    assert_outputs_bitwise_eq(&async_report, &blocking, "icollective");
    let pc = comm.persistent(Op::Allreduce, d, &spec).expect("persistent plan");
    let irun_report = pc.irun(inputs()).wait().expect("irun");
    assert_outputs_bitwise_eq(&irun_report, &blocking, "persistent irun");
}
