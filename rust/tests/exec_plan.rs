//! ExecPlan enforcement end-to-end: per-leg bounds are *load-bearing*
//! (each leg's compressor demonstrably runs at its own eb, not the
//! ambient one), flat algorithms ride degenerate one-leg plans, and
//! the adaptive controller closes the telemetry loop without ever
//! leaving the certified per-call budget.

use gzccl::accuracy::AccuracyTarget;
use gzccl::collectives::Algo;
use gzccl::comm::{CollectiveSpec, Communicator};
use gzccl::coordinator::{CompressionMode, DeviceBuf, ExecPolicy};
use gzccl::testkit::Pcg32;

fn real_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
    (0..n)
        .map(|r| {
            let mut rng = Pcg32::new(seed, r as u64);
            DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
        })
        .collect()
}

/// The ISSUE property: on the 3-tier 4x16x8 acceptance topology under
/// a budget, the compiled plan assigns tier 1 and tier 2 genuinely
/// different bounds (the per-tier split, not one ambient eb), and
/// every leg's **observed** compression error sits at or below its own
/// leg eb — the runtime proof the executor enforced the plan.
#[test]
fn per_leg_observed_error_within_per_leg_eb_on_three_tiers() {
    // Target 1e-1: large enough that the per-leg bounds dominate the
    // compressor's f32 arithmetic noise (which scales with |value|,
    // not with eb) by ~500×, so the ≤-eb assertion is sharp.
    let n = 512;
    let comm = Communicator::builder(n)
        .tiers(&[4, 16, 8])
        .policy(ExecPolicy::gzccl())
        .accuracy_target(AccuracyTarget::AbsError(1e-1))
        .build()
        .unwrap();
    let plan = *comm.budget_plan().unwrap();
    let report = comm
        .allreduce(real_inputs(n, 257, 77), &CollectiveSpec::forced(Algo::Hierarchical))
        .unwrap();

    // The executed plan carries per-tier bounds that genuinely differ:
    // tier 1's sensitivity (121 on this tree) dwarfs tier 2's (7), so
    // the equal-weight split hands tier 2 a far looser bound.
    let eb_of_tier = |t: usize| -> f64 {
        report
            .legs
            .iter()
            .filter(|l| l.tier == t && l.exec.compresses())
            .map(|l| l.exec.eb)
            .fold(0.0, f64::max)
    };
    let (eb1, eb2) = (eb_of_tier(1), eb_of_tier(2));
    assert!(eb1 > 0.0 && eb2 > 0.0, "tiers 1 and 2 must compress");
    assert!(
        eb2 > 4.0 * eb1,
        "per-tier bounds must differ (eb1 {eb1:.3e} vs eb2 {eb2:.3e})"
    );
    // Neither bound is the ambient plan.eb the old executor ran.
    assert!((eb1 - plan.eb).abs() > 0.1 * plan.eb, "tier 1 runs its own bound");
    assert!((eb2 - plan.eb).abs() > 0.1 * plan.eb, "tier 2 runs its own bound");

    // Per-leg enforcement: every compressed leg's observed max error
    // honors ITS eb (compressor guarantee at the leg's bound — an
    // executor falling back to a looser ambient bound would exceed the
    // tight tier-1 legs).
    let mut observed_legs = 0;
    for l in &report.legs {
        if !l.exec.compresses() {
            assert!(l.observed_max_err.is_none(), "raw legs record nothing");
            continue;
        }
        let obs = l
            .observed_max_err
            .expect("compressed legs on real payloads must be observed");
        assert!(
            obs <= l.exec.eb * 1.01 + 1e-12,
            "leg {} (tier {}) observed {obs:.3e} exceeds its eb {:.3e}",
            l.leg,
            l.tier,
            l.exec.eb
        );
        observed_legs += 1;
    }
    assert!(observed_legs >= 3, "t1 ascent, t2 exchange, t1 descent all compress");

    // End-to-end: the tiered plan's prediction (Σ A·eb = per-call) holds.
    let acc = report.accuracy.expect("real compressed payloads probe");
    assert_eq!(acc.within_bound(), Some(true), "{acc:?}");
    assert!(
        acc.observed_max_err <= plan.per_call_abs * 1.01,
        "end-to-end {:.3e} vs per-call {:.3e}",
        acc.observed_max_err,
        plan.per_call_abs
    );
}

/// Flat algorithms flow through the same contract: a degenerate
/// one-leg plan whose observed error honors the single bound; virtual
/// payloads record nothing.
#[test]
fn flat_algorithms_ride_one_leg_plans() {
    let n = 8;
    let eb = 1e-3;
    let comm = Communicator::builder(n)
        .policy(ExecPolicy::gzccl())
        .error_bound(eb)
        .build()
        .unwrap();
    let report = comm
        .allreduce(real_inputs(n, 300, 5), &CollectiveSpec::forced(Algo::Ring))
        .unwrap();
    assert_eq!(report.legs.len(), 1, "flat plans are one leg");
    let leg = &report.legs[0];
    assert_eq!(leg.tier, 0);
    assert!(leg.kind.is_none(), "the leg is the whole collective");
    assert_eq!(leg.exec.compression, CompressionMode::ErrorBounded);
    let obs = leg.observed_max_err.expect("real payloads are observed");
    assert!(obs > 0.0 && obs <= eb * 1.01 + 1e-12, "observed {obs:.3e} vs eb {eb:.3e}");
    assert_eq!(report.exec_plan.legs.len(), 1);

    // Virtual payloads: the plan still exists, but nothing to observe.
    let virt: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(1 << 12)).collect();
    let vr = comm.allreduce(virt, &CollectiveSpec::forced(Algo::Ring)).unwrap();
    assert_eq!(vr.legs.len(), 1);
    assert!(vr.legs[0].observed_max_err.is_none());
}

/// The ISSUE adaptation criterion: repeated Allreduce with headroom
/// relaxes the planned eb monotonically (≤ 8× per step), and the
/// certified per-call budget is never violated — neither by a leg's
/// bound nor by the observed end-to-end error.
#[test]
fn adaptive_allreduce_relaxes_monotonically_within_budget() {
    // 256 ranks / 4 per node → 64 nodes: the hierarchical anchor pays
    // 63 worst-case stages, but the observed error of the random-sign
    // quantization walk grows only ~√stages — real headroom (≈4× on
    // this data) for the controller to harvest.
    let n = 256;
    let comm = Communicator::builder(n)
        .gpus_per_node(4)
        .policy(ExecPolicy::gzccl())
        .accuracy_target(AccuracyTarget::AbsError(63e-4))
        .adaptive(true)
        .build()
        .unwrap();
    let plan = *comm.budget_plan().unwrap();
    assert_eq!(plan.amplification, 63.0);
    assert!((plan.eb - 1e-4).abs() < 1e-15);
    assert_eq!(comm.adaptive_eb(), Some(plan.eb), "fresh controller starts at the plan");

    let max_leg_eb = |report: &gzccl::comm::CollectiveReport| -> f64 {
        report
            .legs
            .iter()
            .filter(|l| l.exec.compresses())
            .map(|l| l.exec.eb)
            .fold(0.0, f64::max)
    };

    let mut prev_eb = 0.0f64;
    for step in 0..5u64 {
        let report = comm
            .allreduce(
                real_inputs(n, 512, 1000 + step),
                &CollectiveSpec::forced(Algo::Hierarchical),
            )
            .unwrap();
        let eb = max_leg_eb(&report);
        assert!(eb > 0.0);
        // Monotone, ≤ 8× per step, capped at the per-call budget.
        if step > 0 {
            assert!(eb >= prev_eb * (1.0 - 1e-12), "step {step}: {eb:.3e} < {prev_eb:.3e}");
            assert!(
                eb <= prev_eb * 8.0 * (1.0 + 1e-9),
                "step {step}: {eb:.3e} jumped more than 8x from {prev_eb:.3e}"
            );
        }
        assert!(
            eb <= plan.per_call_abs * (1.0 + 1e-9),
            "step {step}: leg eb {eb:.3e} exceeds the certified per-call {:.3e}",
            plan.per_call_abs
        );
        // The budget itself is never violated at runtime.
        let acc = report.accuracy.expect("telemetry runs every step");
        assert!(
            acc.observed_max_err <= plan.per_call_abs * 1.01 + acc.fp_slack,
            "step {step}: observed {:.3e} vs per-call {:.3e}",
            acc.observed_max_err,
            plan.per_call_abs
        );
        prev_eb = eb;
    }
    // The loop actually harvested headroom: the final bound is looser
    // than the certified worst-case plan, and the communicator reports
    // the adapted bound the next call would run at.
    assert!(
        prev_eb > plan.eb * (1.0 + 1e-9),
        "headroom never relaxed the bound (final {prev_eb:.3e} vs planned {:.3e})",
        plan.eb
    );
    let next = comm.adaptive_eb().unwrap();
    assert!(next >= prev_eb * (1.0 - 1e-9) && next <= plan.per_call_abs * (1.0 + 1e-9));
}

/// Adaptive mode is gated on a certified budget: without one there is
/// nothing sound to cap the relaxation against.
#[test]
fn adaptive_without_a_budget_is_rejected_at_build() {
    let err = Communicator::builder(8)
        .policy(ExecPolicy::gzccl())
        .error_bound(1e-4)
        .adaptive(true)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("adaptive"), "{err}");
    // An uncompressed policy with a target plans nothing → same gate.
    assert!(Communicator::builder(8)
        .policy(ExecPolicy::nccl())
        .accuracy_target(AccuracyTarget::AbsError(1e-3))
        .adaptive(true)
        .build()
        .is_err());
    // With a budget the switch is accepted.
    assert!(Communicator::builder(8)
        .policy(ExecPolicy::gzccl())
        .accuracy_target(AccuracyTarget::AbsError(1e-3))
        .adaptive(true)
        .build()
        .is_ok());
}
