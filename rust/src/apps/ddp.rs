//! End-to-end data-parallel training driver.
//!
//! The intro's motivating workload: distributed training where gradient
//! Allreduce dominates (the paper quotes up to 94% communication
//! overhead). Each simulated rank computes MLP gradients on its own
//! batch through the PJRT `mlp_grads` artifact (L2/L1), gradients are
//! summed with a gZCCL Allreduce (L3, real compression), averaged, and
//! applied through the `mlp_apply` artifact. The PJRT client is not
//! `Send`, so per-rank compute steps execute sequentially on the driver
//! thread — the *collective* still runs on real rank threads with
//! virtual-time accounting, which is the part under study.

use crate::accuracy::{plan_for_algo, AccuracyTarget, BudgetPlan};
use crate::collectives::{Algo, Op};
use crate::comm::{AlgoHint, CollectiveSpec, Communicator, Pipeline};
use crate::compress::CodecSpec;
use crate::coordinator::{CompressionMode, DeviceBuf, ExecPolicy};
use crate::error::Result;
use crate::net::Topology;
use crate::runtime::Engine;
use crate::testkit::Pcg32;

/// DDP experiment configuration.
#[derive(Debug, Clone)]
pub struct DdpConfig {
    /// Data-parallel ranks.
    pub ranks: usize,
    /// Optimization steps.
    pub steps: usize,
    /// Absolute error bound for gradient compression. Superseded by the
    /// planner's derived bound when `accuracy_target` is set.
    pub error_bound: f64,
    /// End-to-end accuracy target: absolute L∞ ceiling on the total
    /// compression error injected into the summed gradients across
    /// **all** steps. The budget planner splits it over `steps`
    /// iterations and inverts the propagation model for the chosen
    /// algorithm to derive the per-call compressor bound.
    pub accuracy_target: Option<f64>,
    /// Close the telemetry adaptation loop across training steps
    /// ([`crate::comm::CommBuilder::adaptive`]): each step's observed
    /// headroom relaxes the next step's per-call compressor bound,
    /// never past the certified per-step budget. Needs
    /// `accuracy_target` under a compressed run; ignored otherwise.
    pub adaptive: bool,
    /// Use recursive doubling (true) or ring (false) for the Allreduce.
    pub redoub: bool,
    /// Compress gradients at all (false = NCCL-style baseline).
    pub compress: bool,
    /// Ambient staged codec for gradient compression. `None` keeps the
    /// canonical cuSZp-like pipeline (and lets the tuner pick per-leg
    /// codecs); `Some` pins every compressed leg to this pipeline.
    pub codec: Option<CodecSpec>,
    /// Flight recorder sink ([`crate::obs::Tracer`]): every step's
    /// gradient Allreduce records its span tree and metrics here.
    /// `None` (the default) runs untraced.
    pub trace: Option<crate::obs::Tracer>,
    /// Calibrate the cost model from this previously recorded run
    /// ([`crate::comm::CommBuilder::calibrate_from`]): fitted per-tier
    /// bandwidths/latencies and per-codec kernel factors replace the
    /// nameplate values for every step's Allreduce.
    pub calibrate: Option<std::sync::Arc<crate::obs::TraceRun>>,
    /// Pipeline-depth policy for the gradient Allreduce
    /// ([`crate::comm::CommBuilder::pipeline`]).
    pub pipeline: Pipeline,
    /// Overlap the step loop with the collective: plan the gradient
    /// Allreduce **once** ([`Communicator::persistent`]), launch each
    /// step's reduction non-blocking ([`crate::comm::PersistentColl::irun`])
    /// and generate the next step's batches while it flies. `false`
    /// keeps the historical synchronous `allreduce` call per step.
    pub overlap: bool,
    /// RNG seed.
    pub seed: u64,
}

impl Default for DdpConfig {
    fn default() -> Self {
        DdpConfig {
            ranks: 8,
            steps: 60,
            error_bound: 1e-4,
            accuracy_target: None,
            adaptive: false,
            redoub: true,
            compress: true,
            codec: None,
            trace: None,
            calibrate: None,
            pipeline: Pipeline::Auto,
            overlap: false,
            seed: 42,
        }
    }
}

/// Training outcome.
#[derive(Debug, Clone)]
pub struct DdpResult {
    /// Mean loss per step (averaged over ranks).
    pub loss_curve: Vec<f32>,
    /// Total virtual seconds spent in gradient Allreduce.
    pub allreduce_time: f64,
    /// Total wire bytes across all steps and ranks.
    pub wire_bytes: usize,
    /// Per-call compressor bound the budget planner derived (`None`
    /// without an accuracy target or when not compressing).
    pub planned_eb: Option<f64>,
    /// The bound the adaptive controller would hand the next step after
    /// training finished (`None` unless `adaptive` ran with a plan;
    /// equal to `planned_eb` when no headroom ever justified relaxing).
    pub final_eb: Option<f64>,
    /// Predicted per-step worst-case gradient error (`m · eb`).
    pub predicted_step_err: Option<f64>,
    /// Max observed per-step gradient deviation from the telemetry.
    pub observed_step_err: Option<f64>,
    /// Steps whose telemetry observation exceeded the certified
    /// per-step budget (with a plan) or the predicted bound (without
    /// one). Should stay 0 on error-bounded runs — including adaptive
    /// ones, where the prediction tracks the *relaxed* bounds but the
    /// per-step budget stays the certified yardstick.
    pub budget_violations: usize,
    /// Pipeline depth of the frozen persistent plan (`None` on the
    /// synchronous per-step dispatch path, where depth is re-chosen
    /// each call).
    pub pipeline_depth: Option<usize>,
    /// Final parameters.
    pub params: Vec<f32>,
}

/// Synthetic regression batch for `rank` at `step`: y = sin(x·W) for a
/// fixed random projection W (the learnable target).
fn make_batch(
    rng_w: &mut Pcg32,
    seed: u64,
    rank: usize,
    step: usize,
    batch: usize,
    nin: usize,
    nout: usize,
    w: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let _ = rng_w;
    let mut rng = Pcg32::new(seed ^ 0xBA7C4, (rank as u64) << 32 | step as u64);
    let x: Vec<f32> = (0..batch * nin).map(|_| rng.next_gaussian()).collect();
    let mut y = vec![0.0f32; batch * nout];
    for b in 0..batch {
        for o in 0..nout {
            let mut acc = 0.0f32;
            for i in 0..nin {
                acc += x[b * nin + i] * w[i * nout + o];
            }
            y[b * nout + o] = acc.sin();
        }
    }
    (x, y)
}

/// Train the MLP data-parallel across `cfg.ranks` simulated GPUs.
pub fn train_ddp(cfg: &DdpConfig, engine: &Engine) -> Result<DdpResult> {
    let s = engine.shapes();
    let mut rng = Pcg32::seeded(cfg.seed);
    // Target projection (shared across ranks).
    let w: Vec<f32> = (0..s.mlp_in * s.mlp_out)
        .map(|_| rng.next_gaussian() / (s.mlp_in as f32).sqrt())
        .collect();
    // Replicated initial parameters.
    let mut params: Vec<f32> = (0..s.mlp_params).map(|_| rng.next_gaussian() * 0.1).collect();

    let policy = if cfg.compress {
        ExecPolicy::gzccl()
    } else {
        ExecPolicy::nccl()
    };
    let algo = if cfg.redoub {
        Algo::RecursiveDoubling
    } else {
        Algo::Ring
    };
    // Accuracy-aware path: split the end-to-end target across all
    // training steps (compression error compounds linearly into the
    // parameters) and invert the model for the pinned algorithm. The
    // node shape is set once here so the plan and the communicator are
    // guaranteed to share one layout.
    let gpus_per_node = 4;
    let mut plan: Option<BudgetPlan> = None;
    if let Some(target) = cfg.accuracy_target {
        if policy.compression == CompressionMode::ErrorBounded {
            let topo = Topology::new(cfg.ranks, gpus_per_node)?;
            let p = plan_for_algo(
                AccuracyTarget::AbsError(target),
                cfg.steps.max(1),
                Op::Allreduce,
                algo,
                &topo,
                policy.compression,
            )?;
            plan = Some(p);
        }
    }
    // With a plan, the communicator adopts it whole (dispatch-time
    // validation, per-tier split, adaptive controller); without one
    // the explicit error bound stands.
    let mut builder = Communicator::builder(cfg.ranks)
        .gpus_per_node(gpus_per_node)
        .policy(policy)
        .pipeline(cfg.pipeline);
    if let Some(c) = cfg.codec {
        builder = builder.codec(c);
    }
    if let Some(t) = &cfg.trace {
        builder = builder.trace(t.clone());
    }
    if let Some(run) = &cfg.calibrate {
        builder = builder.calibrate_from(run.clone());
    }
    let comm = match plan {
        Some(p) => builder.budget_plan(p).adaptive(cfg.adaptive).build()?,
        None => builder.error_bound(cfg.error_bound).build()?,
    };
    // The config pins the algorithm (the experiment compares them);
    // `AlgoHint::Auto` would let the tuner decide from the gradient
    // size and rank count instead.
    let spec = CollectiveSpec::hinted(AlgoHint::Force(algo));
    // Overlapped path: plan/compile/budget the gradient Allreduce once;
    // every step launches the frozen plan non-blocking and the driver
    // generates the next step's batches while the collective flies.
    let pcoll = if cfg.overlap {
        Some(comm.persistent(Op::Allreduce, s.mlp_params, &spec)?)
    } else {
        None
    };

    let mut loss_curve = Vec::with_capacity(cfg.steps);
    let mut allreduce_time = 0.0;
    let mut wire_bytes = 0usize;
    let mut observed_step_err: Option<f64> = None;
    let mut predicted_step_err: Option<f64> = None;
    let mut budget_violations = 0usize;

    // Batches for step 0; later iterations refill this while the
    // collective is in flight (batch synthesis is the only
    // parameter-independent slice of the step).
    let gen_batches = |rng: &mut Pcg32, step: usize| -> Vec<(Vec<f32>, Vec<f32>)> {
        (0..cfg.ranks)
            .map(|rank| {
                make_batch(rng, cfg.seed, rank, step, s.mlp_batch, s.mlp_in, s.mlp_out, &w)
            })
            .collect()
    };
    let mut batches = gen_batches(&mut rng, 0);

    for step in 0..cfg.steps {
        // ---- per-rank local compute (L2/L1 via PJRT) ----------------
        let mut grads: Vec<DeviceBuf> = Vec::with_capacity(cfg.ranks);
        let mut loss_sum = 0.0f32;
        for (x, y) in &batches {
            let (loss, g) = engine.mlp_grads(&params, x, y)?;
            loss_sum += loss;
            grads.push(DeviceBuf::Real(g));
        }
        loss_curve.push(loss_sum / cfg.ranks as f32);

        // ---- gradient Allreduce (L3, real bytes + virtual time) -----
        let report = match &pcoll {
            Some(pc) => {
                let handle = pc.irun(grads);
                // Overlap: synthesize the next step's batches while the
                // collective runs on its worker thread.
                if step + 1 < cfg.steps {
                    batches = gen_batches(&mut rng, step + 1);
                }
                handle.wait()?
            }
            None => {
                let report = comm.allreduce(grads, &spec)?;
                if step + 1 < cfg.steps {
                    batches = gen_batches(&mut rng, step + 1);
                }
                report
            }
        };
        allreduce_time += report.makespan.as_secs();
        wire_bytes += report.total_wire_bytes();
        if let Some(acc) = report.accuracy {
            observed_step_err =
                Some(observed_step_err.unwrap_or(0.0).max(acc.observed_max_err));
            if let Some(b) = acc.prediction.bound() {
                predicted_step_err = Some(predicted_step_err.unwrap_or(0.0).max(b));
            }
            // With a plan, violations are judged against the certified
            // per-step budget — under adaptation the dispatch
            // prediction follows the *relaxed* bounds and would mask a
            // genuine budget miss.
            let violated = match &plan {
                Some(p) => {
                    acc.observed_max_err > p.per_call_abs * (1.0 + 1e-9) + acc.fp_slack
                }
                None => acc.within_bound() == Some(false),
            };
            if violated {
                budget_violations += 1;
            }
        }

        // ---- average + apply (PJRT axpy artifact) -------------------
        let summed = report.outputs[0].as_real();
        let avg: Vec<f32> = summed.iter().map(|g| g / cfg.ranks as f32).collect();
        params = engine.mlp_apply(&params, &avg)?;
    }

    Ok(DdpResult {
        loss_curve,
        allreduce_time,
        wire_bytes,
        planned_eb: plan.map(|p| p.eb),
        final_eb: comm.adaptive_eb(),
        predicted_step_err,
        observed_step_err,
        budget_violations,
        pipeline_depth: pcoll.as_ref().map(|pc| pc.depth()),
        params,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    thread_local! {
        static ENGINE: Engine =
            Engine::discover().expect("artifacts/ exists but failed shape validation");
    }

    #[test]
    fn ddp_loss_decreases_with_compressed_gradients() {
        ENGINE.with(|e| {
            let cfg = DdpConfig {
                ranks: 4,
                steps: 25,
                ..Default::default()
            };
            let out = train_ddp(&cfg, e).unwrap();
            let first = out.loss_curve[0];
            let last = *out.loss_curve.last().unwrap();
            assert!(
                last < 0.6 * first,
                "loss did not decrease: {first} -> {last}"
            );
            assert!(out.allreduce_time > 0.0);
            assert!(out.wire_bytes > 0);
        });
    }

    #[test]
    fn accuracy_target_plans_and_holds_per_step() {
        ENGINE.with(|e| {
            let cfg = DdpConfig {
                ranks: 4,
                steps: 4,
                accuracy_target: Some(1e-3),
                ..Default::default()
            };
            let out = train_ddp(&cfg, e).unwrap();
            // ReDoub on 4 ranks: m = 3; per-step budget 2.5e-4 →
            // eb = 2.5e-4 / 3.
            let eb = out.planned_eb.expect("target must produce a plan");
            assert!((eb - 1e-3 / 4.0 / 3.0).abs() < 1e-12, "eb {eb}");
            // Telemetry ran every step and never exceeded the bound.
            assert!(out.observed_step_err.is_some());
            assert_eq!(out.budget_violations, 0);
            assert!(
                out.observed_step_err.unwrap()
                    <= out.predicted_step_err.unwrap() * 1.01,
                "obs {:?} vs pred {:?}",
                out.observed_step_err,
                out.predicted_step_err
            );
            // Still trains.
            assert!(out.loss_curve.iter().all(|l| l.is_finite()));
        });
    }

    #[test]
    fn adaptive_training_relaxes_within_the_per_step_budget() {
        ENGINE.with(|e| {
            let target = 1e-3;
            let steps = 6;
            let cfg = DdpConfig {
                ranks: 4,
                steps,
                accuracy_target: Some(target),
                adaptive: true,
                ..Default::default()
            };
            let out = train_ddp(&cfg, e).unwrap();
            let planned = out.planned_eb.unwrap();
            let fin = out.final_eb.expect("adaptive run reports its final eb");
            let per_step = target / steps as f64;
            // Monotone relaxation, never past the certified per-step
            // budget, never a telemetry violation along the way.
            assert!(fin >= planned, "final {fin} vs planned {planned}");
            assert!(fin <= per_step * (1.0 + 1e-9), "final {fin} vs per-step {per_step}");
            assert_eq!(out.budget_violations, 0);
            assert!(out.loss_curve.iter().all(|l| l.is_finite()));
        });
    }

    #[test]
    fn overlapped_persistent_training_matches_synchronous() {
        ENGINE.with(|e| {
            let base = DdpConfig {
                ranks: 4,
                steps: 5,
                ..Default::default()
            };
            let ovl = DdpConfig {
                overlap: true,
                ..base.clone()
            };
            let sync = train_ddp(&base, e).unwrap();
            let over = train_ddp(&ovl, e).unwrap();
            // The frozen persistent plan runs the same selection /
            // ExecPlan the per-step dispatch re-derives, so the math is
            // bit-identical — overlap only moves batch synthesis into
            // the collective's flight time.
            assert_eq!(sync.loss_curve, over.loss_curve);
            assert_eq!(sync.params, over.params);
            assert_eq!(sync.pipeline_depth, None);
            assert!(over.pipeline_depth.is_some());
        });
    }

    #[test]
    fn compression_cuts_gradient_traffic() {
        ENGINE.with(|e| {
            let base = DdpConfig {
                ranks: 4,
                steps: 3,
                compress: false,
                ..Default::default()
            };
            let comp = DdpConfig {
                ranks: 4,
                steps: 3,
                compress: true,
                // Loose bound: gradients are small-magnitude.
                error_bound: 1e-5,
                ..Default::default()
            };
            let raw = train_ddp(&base, e).unwrap();
            let gz = train_ddp(&comp, e).unwrap();
            assert!(
                gz.wire_bytes * 2 < raw.wire_bytes,
                "gz {} vs raw {}",
                gz.wire_bytes,
                raw.wire_bytes
            );
            // Both still train.
            assert!(*gz.loss_curve.last().unwrap() < gz.loss_curve[0]);
        });
    }
}
