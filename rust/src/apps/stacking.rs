//! Image stacking (paper §4.5).
//!
//! Stacking sums per-process partial images into one final image — an
//! Allreduce. The experiment runs the *real* data through the selected
//! variant's collective (real compression, real reduction), reports the
//! virtual-time performance breakdown (Table 2) and the reconstructed
//! image quality vs the lossless stack (Fig. 13). When a PJRT
//! [`Engine`] is supplied, the lossless reference stack is computed
//! through the `stack_update` artifact — the L2/L1 reduction graph —
//! proving the three layers compose.

use crate::accuracy::{plan_for_algo, AccuracyReport, AccuracyTarget, BudgetPlan};
use crate::collectives::{Algo, Op};
use crate::comm::{CollectiveSpec, Communicator};
use crate::compress::CodecSpec;
use crate::coordinator::{CompressionMode, DeviceBuf, ExecPolicy};
use crate::data::images::StackingScenario;
use crate::data::metrics::{linf, nrmse, psnr, value_range};
use crate::error::Result;
use crate::net::Topology;
use crate::runtime::Engine;
use crate::sim::Breakdown;

/// Which collective performs the stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StackingVariant {
    /// gZCCL ring Allreduce (compressed).
    GzcclRing,
    /// gZCCL recursive-doubling Allreduce (compressed).
    GzcclReDoub,
    /// gZCCL two-level hierarchical Allreduce (compression on the
    /// internode leg only).
    GzcclHier,
    /// NCCL-class uncompressed ring.
    Nccl,
    /// Cray-MPI-class staged reduce+bcast.
    CrayMpi,
    /// CPRP2P-class fixed-rate-compressed ring — the accuracy hazard
    /// baseline: its pointwise error scales with data magnitude, so the
    /// budget planner must reject it under any accuracy target.
    Cprp2p,
}

impl StackingVariant {
    /// Display name matching Table 2.
    pub fn name(self) -> &'static str {
        match self {
            StackingVariant::GzcclRing => "gZCCL (Ring)",
            StackingVariant::GzcclReDoub => "gZCCL (ReDoub)",
            StackingVariant::GzcclHier => "gZCCL (Hier)",
            StackingVariant::Nccl => "NCCL",
            StackingVariant::CrayMpi => "Cray MPI",
            StackingVariant::Cprp2p => "CPRP2P",
        }
    }

    fn policy(self) -> ExecPolicy {
        match self {
            StackingVariant::GzcclRing
            | StackingVariant::GzcclReDoub
            | StackingVariant::GzcclHier => ExecPolicy::gzccl(),
            StackingVariant::Nccl => ExecPolicy::nccl(),
            StackingVariant::CrayMpi => ExecPolicy::cray_mpi(),
            StackingVariant::Cprp2p => ExecPolicy::cprp2p(),
        }
    }

    /// Allreduce algorithm this variant pins (Table 2 compares specific
    /// algorithms, so the tuner is bypassed).
    fn algo(self) -> Algo {
        match self {
            StackingVariant::GzcclRing | StackingVariant::Nccl | StackingVariant::Cprp2p => {
                Algo::Ring
            }
            StackingVariant::GzcclReDoub => Algo::RecursiveDoubling,
            StackingVariant::GzcclHier => Algo::Hierarchical,
            // Staged binomial reduce+bcast (the Cray MPI baseline).
            StackingVariant::CrayMpi => Algo::Binomial,
        }
    }
}

/// App-level accuracy target for the stacked image. `PsnrDb` is
/// converted to an absolute bound against the lossless reference's
/// value range once that reference is computed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StackingTarget {
    /// Absolute L∞ ceiling on the stacked image.
    Abs(f64),
    /// Minimum PSNR in dB vs the lossless stack.
    PsnrDb(f64),
}

/// Stacking experiment configuration.
#[derive(Debug, Clone)]
pub struct StackingConfig {
    /// Image width (must give width×height == the AOT img contract when
    /// a PJRT engine is used: 128×128).
    pub width: usize,
    /// Image height.
    pub height: usize,
    /// Number of partial images / ranks.
    pub ranks: usize,
    /// GPUs per node (topology the hierarchical variant exploits).
    pub gpus_per_node: usize,
    /// Per-partial incoherent noise amplitude.
    pub noise: f32,
    /// Absolute error bound for the compressed variants. Superseded by
    /// the planner's derived bound when `accuracy_target` is set.
    pub error_bound: f64,
    /// Optional end-to-end accuracy target: the budget planner derives
    /// the per-call error bound for the chosen variant (and *rejects*
    /// variants it cannot certify, e.g. the fixed-rate CPRP2P).
    pub accuracy_target: Option<StackingTarget>,
    /// Close the telemetry adaptation loop
    /// ([`crate::comm::CommBuilder::adaptive`]): observed headroom
    /// relaxes the planned bound for subsequent calls through the same
    /// communicator. Needs `accuracy_target`; ignored for variants the
    /// planner does not certify a budget for.
    pub adaptive: bool,
    /// Ambient staged codec for the compressed variants. `None` keeps
    /// the canonical cuSZp-like pipeline (and lets the tuner pick
    /// per-leg codecs); `Some` pins every compressed leg to this one.
    pub codec: Option<CodecSpec>,
    /// Flight recorder sink ([`crate::obs::Tracer`]): every variant's
    /// collective records its span tree and metrics here. `None` (the
    /// default) runs untraced.
    pub trace: Option<crate::obs::Tracer>,
    /// Calibrate the cost model from this previously recorded run
    /// ([`crate::comm::CommBuilder::calibrate_from`]): fitted per-tier
    /// bandwidths/latencies and per-codec kernel factors replace the
    /// nameplate values for tuning and simulation.
    pub calibrate: Option<std::sync::Arc<crate::obs::TraceRun>>,
    /// Scenario seed.
    pub seed: u64,
}

impl Default for StackingConfig {
    fn default() -> Self {
        StackingConfig {
            width: 128,
            height: 128,
            ranks: 16,
            gpus_per_node: 4,
            noise: 0.002,
            error_bound: 1e-4,
            accuracy_target: None,
            adaptive: false,
            codec: None,
            trace: None,
            calibrate: None,
            seed: 0xEEC,
        }
    }
}

/// Result of one stacking run.
#[derive(Debug, Clone)]
pub struct StackingOutcome {
    /// Variant that produced this outcome.
    pub variant: StackingVariant,
    /// Virtual makespan of the collective.
    pub makespan: f64,
    /// Aggregate phase breakdown.
    pub breakdown: Breakdown,
    /// PSNR of the stacked image vs the lossless stack (dB).
    pub psnr: f64,
    /// NRMSE vs the lossless stack.
    pub nrmse: f64,
    /// L∞ of the stacked image vs the lossless stack.
    pub max_abs_err: f64,
    /// The per-call error bound the budget planner derived (`None`
    /// without an accuracy target or for uncompressed variants).
    pub planned_eb: Option<f64>,
    /// The bound the *next* call through the same communicator would
    /// run at, after this call's telemetry fed the adaptive controller
    /// (`None` unless `adaptive` was set with a planned budget).
    pub adapted_eb: Option<f64>,
    /// The plan itself, when one was made.
    pub plan: Option<BudgetPlan>,
    /// Runtime accuracy telemetry from the collective dispatch.
    pub accuracy: Option<AccuracyReport>,
    /// The stacked image (rank 0's output).
    pub image: Vec<f32>,
}

/// Run the stacking collective under `variant` and score accuracy
/// against the lossless stack (computed through PJRT when `engine` is
/// given).
pub fn run_stacking(
    cfg: &StackingConfig,
    variant: StackingVariant,
    engine: Option<&Engine>,
) -> Result<StackingOutcome> {
    let scenario = StackingScenario::new(cfg.width, cfg.height, cfg.ranks, cfg.seed);
    let partials: Vec<Vec<f32>> = (0..cfg.ranks)
        .map(|r| scenario.partial(r, cfg.noise))
        .collect();

    // Lossless reference stack — through the PJRT reduction graph when
    // available (L3 → runtime → L1 kernel), else a host loop.
    let reference = match engine {
        Some(e) if cfg.width * cfg.height == e.shapes().img_elems => {
            let mut acc = vec![0.0f32; cfg.width * cfg.height];
            for p in &partials {
                acc = e.reduce_pair(&acc, p)?;
            }
            acc
        }
        _ => {
            let mut acc = vec![0.0f32; cfg.width * cfg.height];
            for p in &partials {
                for (a, v) in acc.iter_mut().zip(p) {
                    *a += v;
                }
            }
            acc
        }
    };

    // Accuracy-aware path: invert the propagation model for *this*
    // variant's algorithm to get the per-call compressor bound; the
    // planner rejects variants it cannot certify (fixed-rate CPRP2P).
    let policy = variant.policy();
    let mut plan: Option<BudgetPlan> = None;
    if let Some(app_target) = cfg.accuracy_target {
        if policy.compression != CompressionMode::None {
            let target = match app_target {
                StackingTarget::Abs(t) => AccuracyTarget::AbsError(t),
                StackingTarget::PsnrDb(db) => AccuracyTarget::PsnrFloor {
                    db,
                    value_range: value_range(&reference),
                },
            };
            let topo = Topology::new(cfg.ranks, cfg.gpus_per_node)?;
            let p = plan_for_algo(
                target,
                1,
                Op::Allreduce,
                variant.algo(),
                &topo,
                policy.compression,
            )?;
            plan = Some(p);
        }
    }

    let inputs: Vec<DeviceBuf> = partials.into_iter().map(DeviceBuf::Real).collect();
    // With a plan, the communicator adopts it whole: dispatch-time
    // budget validation, the per-tier split, and (when asked) the
    // adaptive controller all see the same certified plan.
    let mut builder = Communicator::builder(cfg.ranks)
        .gpus_per_node(cfg.gpus_per_node)
        .policy(policy);
    if let Some(c) = cfg.codec {
        builder = builder.codec(c);
    }
    if let Some(t) = &cfg.trace {
        builder = builder.trace(t.clone());
    }
    if let Some(run) = &cfg.calibrate {
        builder = builder.calibrate_from(run.clone());
    }
    let comm = match plan {
        Some(p) => builder.budget_plan(p).adaptive(cfg.adaptive).build()?,
        None => builder.error_bound(cfg.error_bound).build()?,
    };
    let report = comm.allreduce(inputs, &CollectiveSpec::forced(variant.algo()))?;

    let image = report.outputs[0].clone().into_real();
    Ok(StackingOutcome {
        variant,
        makespan: report.makespan.as_secs(),
        breakdown: report.total_breakdown(),
        psnr: psnr(&reference, &image),
        nrmse: nrmse(&reference, &image),
        max_abs_err: linf(&reference, &image),
        planned_eb: plan.map(|p| p.eb),
        adapted_eb: comm.adaptive_eb(),
        plan,
        accuracy: report.accuracy,
        image,
    })
}

/// Write an image as a binary PGM (Fig. 13 visualization artifact).
pub fn write_pgm(path: &std::path::Path, img: &[f32], width: usize, height: usize) -> Result<()> {
    assert_eq!(img.len(), width * height);
    let lo = img.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = img.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let range = (hi - lo).max(1e-12);
    let mut bytes = format!("P5\n{width} {height}\n255\n").into_bytes();
    bytes.extend(img.iter().map(|v| ((v - lo) / range * 255.0) as u8));
    std::fs::write(path, bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> StackingConfig {
        StackingConfig {
            width: 64,
            height: 64,
            ranks: 8,
            ..Default::default()
        }
    }

    #[test]
    fn nccl_stack_is_near_lossless() {
        let out = run_stacking(&small_cfg(), StackingVariant::Nccl, None).unwrap();
        assert!(out.psnr > 100.0, "psnr {}", out.psnr);
    }

    #[test]
    fn gzccl_stacks_with_high_quality() {
        // Paper Fig. 13 / §4.5: PSNR ≈ 57 dB at eb=1e-4; ReDoub ≥ Ring
        // thanks to fewer error-propagation steps.
        let ring = run_stacking(&small_cfg(), StackingVariant::GzcclRing, None).unwrap();
        let redoub = run_stacking(&small_cfg(), StackingVariant::GzcclReDoub, None).unwrap();
        assert!(ring.psnr > 45.0, "ring psnr {}", ring.psnr);
        assert!(redoub.psnr > 45.0, "redoub psnr {}", redoub.psnr);
        // The hierarchical schedule compresses only its single
        // internode exchange (8 ranks / 4 per node → 2 nodes), so its
        // quality is at least ReDoub-class.
        let hier = run_stacking(&small_cfg(), StackingVariant::GzcclHier, None).unwrap();
        assert!(hier.psnr > 45.0, "hier psnr {}", hier.psnr);
        assert!(
            hier.psnr >= ring.psnr - 1.0,
            "hier {} vs ring {}",
            hier.psnr,
            ring.psnr
        );
        assert!(
            redoub.psnr >= ring.psnr - 1.0,
            "redoub {} vs ring {}",
            redoub.psnr,
            ring.psnr
        );
        assert!(ring.nrmse < 0.01);
    }

    #[test]
    fn accuracy_target_met_for_every_accepted_variant() {
        // The ISSUE acceptance criterion: with an accuracy target set,
        // measured L∞/PSNR meets the target for every variant the
        // planner accepts, and the telemetry's observed error stays
        // within the predicted bound.
        let db = 55.0;
        let cfg = StackingConfig {
            accuracy_target: Some(StackingTarget::PsnrDb(db)),
            ..small_cfg()
        };
        for v in [
            StackingVariant::GzcclRing,
            StackingVariant::GzcclReDoub,
            StackingVariant::GzcclHier,
        ] {
            let out = run_stacking(&cfg, v, None).unwrap();
            let plan = out.plan.expect("compressed variant must be planned");
            assert!(out.psnr >= db, "{v:?}: psnr {} < {db}", out.psnr);
            // 1% headroom over the certified bound absorbs the f32
            // reassociation noise between the host-loop reference and
            // the collective's reduction order.
            assert!(
                out.max_abs_err <= plan.per_call_abs * 1.01,
                "{v:?}: L∞ {} vs budget {}",
                out.max_abs_err,
                plan.per_call_abs
            );
            let acc = out.accuracy.expect("telemetry must run");
            assert_eq!(acc.within_bound(), Some(true), "{v:?}: {acc:?}");
            assert!(out.planned_eb.unwrap() > 0.0);
        }
        // Uncompressed variants are trivially accepted (no plan).
        let nccl = run_stacking(&cfg, StackingVariant::Nccl, None).unwrap();
        assert!(nccl.plan.is_none());
        assert!(nccl.psnr >= db);
    }

    #[test]
    fn adaptive_flag_wires_the_controller() {
        let cfg = StackingConfig {
            accuracy_target: Some(StackingTarget::PsnrDb(55.0)),
            adaptive: true,
            ..small_cfg()
        };
        let out = run_stacking(&cfg, StackingVariant::GzcclReDoub, None).unwrap();
        let planned = out.planned_eb.unwrap();
        let next = out
            .adapted_eb
            .expect("adaptive communicator reports its next-call eb");
        let plan = out.plan.unwrap();
        // Monotone (never tighter than the plan) and capped by the
        // certified per-call budget.
        assert!(
            next >= planned && next <= plan.per_call_abs * (1.0 + 1e-9),
            "planned {planned} next {next} cap {}",
            plan.per_call_abs
        );
        // Without the flag there is no controller to report.
        let plain = StackingConfig {
            accuracy_target: Some(StackingTarget::PsnrDb(55.0)),
            ..small_cfg()
        };
        let out = run_stacking(&plain, StackingVariant::GzcclReDoub, None).unwrap();
        assert!(out.adapted_eb.is_none());
    }

    #[test]
    fn planner_rejects_fixed_rate_under_target() {
        let cfg = StackingConfig {
            accuracy_target: Some(StackingTarget::Abs(1e-3)),
            ..small_cfg()
        };
        let err = run_stacking(&cfg, StackingVariant::Cprp2p, None).unwrap_err();
        assert!(err.to_string().contains("fixed-rate"), "{err}");
        // Without a target the hazard baseline runs — and the telemetry
        // marks its prediction unbounded.
        let free = run_stacking(&small_cfg(), StackingVariant::Cprp2p, None).unwrap();
        assert!(free.psnr.is_finite());
        let acc = free.accuracy.expect("telemetry still observes");
        assert_eq!(acc.within_bound(), None, "fixed-rate has no bound to hold");
    }

    #[test]
    fn breakdown_structure_matches_variant() {
        // At unit-test image sizes the *absolute* ordering flips (a
        // 16 KB image sits below the compression-kernel floor; the
        // paper's Table 2 speedups need stack images in the 100s of MB,
        // which the bench covers with virtual payloads). What must hold
        // at any size is the breakdown structure.
        let cfg = StackingConfig {
            ranks: 16,
            ..small_cfg()
        };
        let cray = run_stacking(&cfg, StackingVariant::CrayMpi, None).unwrap();
        let redoub = run_stacking(&cfg, StackingVariant::GzcclReDoub, None).unwrap();
        // Cray stages through PCIe; gZCCL never touches it.
        assert!(cray.breakdown.datamove > 0.0);
        assert_eq!(redoub.breakdown.datamove, 0.0);
        // gZCCL compresses; Cray doesn't.
        assert!(redoub.breakdown.cpr > 0.0);
        assert_eq!(cray.breakdown.cpr, 0.0);
        assert!(cray.makespan > 0.0 && redoub.makespan > 0.0);
    }

    #[test]
    fn pgm_roundtrip_shape() {
        let dir = std::env::temp_dir().join("gzccl_pgm_test.pgm");
        let img: Vec<f32> = (0..64).map(|i| i as f32).collect();
        write_pgm(&dir, &img, 8, 8).unwrap();
        let data = std::fs::read(&dir).unwrap();
        assert!(data.starts_with(b"P5\n8 8\n255\n"));
        assert_eq!(data.len(), 11 + 64);
        let _ = std::fs::remove_file(dir);
    }
}
