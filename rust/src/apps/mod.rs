//! Applications built on the gZCCL collectives.
//!
//! * [`stacking`] — the paper's §4.5 image-stacking analysis (an
//!   Allreduce over per-process partial images), with accuracy
//!   reporting (PSNR/NRMSE, Fig. 13) and the Table-2 breakdown.
//! * [`ddp`] — the end-to-end data-parallel training driver: per-rank
//!   MLP fwd/bwd through the PJRT artifacts, gradient averaging through
//!   gZ-Allreduce.

pub mod ddp;
pub mod stacking;

pub use ddp::{train_ddp, DdpConfig, DdpResult};
pub use stacking::{run_stacking, StackingConfig, StackingOutcome, StackingVariant};
