//! Reporting: aligned ASCII tables and series, matching the paper's
//! figure/table layouts.

pub mod table;

pub use table::Table;
