//! Minimal aligned-column table printer (no external deps).

use std::fmt::Write as _;

/// An ASCII table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: append a row of displayable items.
    pub fn rowd<D: std::fmt::Display>(&mut self, cells: &[D]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the table has no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let _ = write!(s, " {:width$} |", cells[i], width = widths[i]);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.header);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&mut out, &sep);
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}

/// Format a speedup factor.
pub fn fmt_x(factor: f64) -> String {
    format!("{factor:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.rowd(&["a", "1"]);
        t.rowd(&["longer-name", "22"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| longer-name | 22    |") || s.contains("| longer-name | 22"));
        assert_eq!(s.lines().count(), 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        Table::new("x", &["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.500s");
        assert_eq!(fmt_time(0.0025), "2.500ms");
        assert_eq!(fmt_time(2.5e-6), "2.5us");
        assert_eq!(fmt_x(3.14159), "3.14x");
    }
}
