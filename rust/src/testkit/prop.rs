//! Miniature property-test harness.
//!
//! `proptest` is not in the vendored dependency set, so this module
//! provides the 10% of it we need: run a property over `n` randomly
//! generated cases, and on failure report the case index and seed so the
//! exact case can be replayed. No shrinking — cases are kept small by
//! construction instead.

use super::rng::Pcg32;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Cases {
    /// Number of random cases to run.
    pub n: usize,
    /// Base seed; case `i` uses seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Cases {
    fn default() -> Self {
        Cases {
            n: 64,
            base_seed: 0xC0FFEE,
        }
    }
}

impl Cases {
    /// A run with `n` cases and the default base seed.
    pub fn n(n: usize) -> Self {
        Cases {
            n,
            ..Self::default()
        }
    }

    /// Override the base seed (useful to replay a failure).
    pub fn seed(mut self, s: u64) -> Self {
        self.base_seed = s;
        self
    }
}

/// Run `prop` over `cases.n` generated inputs.
///
/// `gen` receives a fresh deterministic RNG per case. `prop` returns
/// `Err(reason)` (or panics) to signal failure; the harness re-panics
/// with the case index and seed embedded for replay.
pub fn forall<T, G, P>(cases: Cases, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Pcg32) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for i in 0..cases.n {
        let seed = cases.base_seed.wrapping_add(i as u64);
        let mut rng = Pcg32::seeded(seed);
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property failed on case {i} (seed {seed:#x}): {reason}\ninput: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            Cases::n(32),
            |r| r.range_usize(0, 100),
            |_x| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            Cases::n(16),
            |r| r.range_usize(0, 100),
            |x| {
                if *x < 1000 {
                    Err(format!("{x} too small"))
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<usize> = vec![];
        forall(
            Cases::n(8).seed(5),
            |r| r.range_usize(0, 1_000_000),
            |x| {
                first.push(*x);
                Ok(())
            },
        );
        let mut second: Vec<usize> = vec![];
        forall(
            Cases::n(8).seed(5),
            |r| r.range_usize(0, 1_000_000),
            |x| {
                second.push(*x);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}
