//! Test utilities: a deterministic PRNG and a miniature property-test
//! harness.
//!
//! The vendored dependency set has neither `rand` nor `proptest`, so the
//! crate ships its own seeded PCG32 generator and a small "run this
//! property over N random cases, report the failing seed" runner. All
//! randomized tests in the crate are reproducible from a fixed seed.

pub mod prop;
pub mod rng;

pub use prop::{forall, Cases};
pub use rng::Pcg32;

/// Assert two f32 slices are elementwise close (absolute tolerance).
///
/// Panics with the first offending index, which is far more useful than
/// a bare boolean assert when debugging kernels.
pub fn assert_close(actual: &[f32], expected: &[f32], atol: f32) {
    assert_eq!(
        actual.len(),
        expected.len(),
        "length mismatch: {} vs {}",
        actual.len(),
        expected.len()
    );
    for (i, (a, e)) in actual.iter().zip(expected.iter()).enumerate() {
        let diff = (a - e).abs();
        assert!(
            diff <= atol,
            "index {i}: |{a} - {e}| = {diff} > atol {atol}"
        );
    }
}

/// Maximum absolute elementwise difference between two slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assert_close_passes_on_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0], 0.0);
    }

    #[test]
    #[should_panic(expected = "index 1")]
    fn assert_close_reports_index() {
        assert_close(&[1.0, 2.0], &[1.0, 3.0], 0.5);
    }

    #[test]
    fn max_abs_diff_basics() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
    }
}
