//! PCG32: a small, fast, statistically solid PRNG (O'Neill 2014).
//!
//! Used for dataset synthesis and property tests. Deterministic across
//! platforms: only integer arithmetic feeds the stream.

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a bare seed (stream 0xda3e39cb94b95bdb).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Next uniform u32.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform u64.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Requires lo <= hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo + 1;
        if span == 0 {
            // Full u64 range.
            return self.next_u64();
        }
        // Rejection-free (slightly biased for huge spans; fine for tests).
        lo + self.next_u64() % span
    }

    /// Uniform usize in [lo, hi] (inclusive).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.next_f32() * (hi - lo)
    }

    /// Standard normal via Box–Muller (uses two uniforms).
    pub fn next_gaussian(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fill a vector with uniform f32 in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.range_f32(lo, hi)).collect()
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len() - 1)]
    }

    /// Random boolean with probability `p` of being true.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Pcg32::seeded(9);
        for _ in 0..1000 {
            let v = r.range_usize(3, 17);
            assert!((3..=17).contains(&v));
        }
    }

    #[test]
    fn mean_of_uniform_near_half() {
        let mut r = Pcg32::seeded(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::seeded(13);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
