//! Worst-case pointwise error propagation across collective stages.
//!
//! The model formalizes how per-stage compression error compounds
//! (C-Coll §"error propagation", gZCCL §3.4): every decompression of an
//! error-bounded stream reconstructs each value to within `eb` of what
//! the sender held. Whether those `eb`s *add* or *double* depends on
//! the dataflow of the algorithm:
//!
//! * **Linear chains** — when each hop reduces a once-compressed
//!   partial into *exact local* data (the ring Reduce_scatter), the
//!   recurrence is `e' = e + eb`: error grows linearly with the hop
//!   count (`stages × eb`).
//! * **Doubling trees** — when both reduction operands are themselves
//!   accumulated partials (recursive doubling), the recurrence is
//!   `e' = 2e + eb`: after `S` exchanges the worst case is
//!   `(2^S − 1)·eb`. The MPICH remainder fold/unfold adds two more
//!   effective stages for non-power-of-two participant counts.
//! * **Forwarded streams** — compress-once algorithms (binomial
//!   Scatter/Bcast, the ring Allgather) forward the compressed bytes
//!   verbatim, so every consumer pays exactly one `eb`.
//!
//! The **fixed-rate** compressor (CPRP2P baseline) has *no* absolute
//! bound — its error scales with block magnitude — so every prediction
//! under [`CompressionMode::FixedRate`] is
//! [`ErrorPrediction::Unbounded`]: the hazard the paper's
//! accuracy-aware design exists to reject, and the one the
//! [`crate::accuracy::budget`] planner refuses to plan around.
//!
//! The per-rank stage counts come from the
//! `crate::collectives::expected_cpr_stages*` family, which
//! [`cpr_stages`] unifies behind one rank/root/topology-resolved entry
//! point.

use crate::collectives::{expected_cpr_stages_at, expected_cpr_stages_hier, Algo, Op};
use crate::coordinator::CompressionMode;
use crate::net::Topology;
// The doubling-stage recurrence is defined once, next to the schedule
// walk that shares it — the two error models cannot drift apart.
use crate::topo::schedule::{doubling_error_stages, pow2_minus_1};
use crate::topo::{compile_min_error, compile_rooted, TierTree};

/// Predicted worst-case pointwise deviation of a collective's output
/// from the exact (lossless) result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ErrorPrediction {
    /// No lossy stage touches the data: the output is exact up to f32
    /// reduction rounding.
    Exact,
    /// Error-bounded path: `|out − exact| ≤ bound` pointwise.
    Bounded(f64),
    /// Fixed-rate path: the pointwise error scales with data magnitude
    /// and admits **no** a-priori absolute bound.
    Unbounded,
}

impl ErrorPrediction {
    /// The absolute bound, if one exists (`Exact` ⇒ 0).
    pub fn bound(&self) -> Option<f64> {
        match *self {
            ErrorPrediction::Exact => Some(0.0),
            ErrorPrediction::Bounded(b) => Some(b),
            ErrorPrediction::Unbounded => None,
        }
    }

    /// Whether the prediction carries an absolute bound.
    pub fn is_bounded(&self) -> bool {
        !matches!(self, ErrorPrediction::Unbounded)
    }

    /// The prediction after `iters` dependent repetitions (iterative
    /// apps: stacking batches, DDP steps). Per-call errors add linearly
    /// across iterations because each iteration's output feeds the next
    /// through exact local computation.
    pub fn iterated(&self, iters: usize) -> ErrorPrediction {
        match *self {
            ErrorPrediction::Bounded(b) => ErrorPrediction::Bounded(b * iters as f64),
            other => other,
        }
    }
}

fn ceil_log2(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        (n - 1).ilog2() as usize + 1
    }
}

/// Worst-case amplification of a hierarchical schedule on `tree`: the
/// min-error compile's leg walk (what budgeted dispatch runs). On a
/// 2-tier tree this is exactly the PR 2 internode model,
/// `(2^S − 1)·eb` over the node count.
fn hier_amplification(op: Op, tree: &TierTree) -> Option<f64> {
    compile_min_error(op, tree, true).ok().map(|s| s.amplification())
}

/// Worst-case error **amplification** `m` for `(op, algo)` at `rank`:
/// under an error-bounded compressor with bound `eb`, the output at
/// `rank` deviates from the exact result by at most `m · eb`.
///
/// Returns `None` for `(op, algo)` pairs the model does not cover —
/// callers must treat that as "cannot certify", never as zero.
pub fn amplification(
    op: Op,
    algo: Algo,
    topo: &Topology,
    rank: usize,
    root: usize,
) -> Option<f64> {
    amplification_tiers(op, algo, &TierTree::from(topo), rank, root)
}

/// [`amplification`] over an N-level [`TierTree`]: hierarchical
/// schedules walk the tree's legs; flat algorithms depend only on the
/// rank count.
pub fn amplification_tiers(
    op: Op,
    algo: Algo,
    tree: &TierTree,
    rank: usize,
    root: usize,
) -> Option<f64> {
    let n = tree.ranks();
    if n <= 1 {
        return Some(0.0);
    }
    match (op, algo) {
        (_, Algo::Identity) => Some(0.0),
        // Ring Allreduce: N−1 linear reduce-scatter hops (`e' = e + eb`
        // — each hop folds a once-compressed partial into exact local
        // data) plus one compress-once allgather forward.
        (Op::Allreduce, Algo::Ring) => Some(n as f64),
        // Recursive doubling: S doubling exchanges (`e' = 2e + eb`)
        // including the non-pow2 fold/unfold → (2^S − 1)·eb. For pow2
        // N this is exactly (N−1)·eb.
        (Op::Allreduce, Algo::RecursiveDoubling) => {
            Some(pow2_minus_1(doubling_error_stages(n)))
        }
        // Hierarchical schedules: tier-0 legs are raw NVLink (exact);
        // compression error follows the tree's compiled legs, and
        // members inherit their leader's error verbatim (rank-uniform).
        (Op::Allreduce, Algo::Hierarchical)
        | (Op::ReduceScatter, Algo::Hierarchical)
        | (Op::Allgather, Algo::Hierarchical) => hier_amplification(op, tree),
        // Rooted hierarchical descents: walk the schedule compiled
        // around this root (worst case over ranks — conservative for
        // the root itself, which keeps a shorter lossy path).
        (Op::Scatter | Op::Bcast, Algo::Hierarchical) => {
            compile_rooted(op, tree, true, root).ok().map(|s| s.amplification())
        }
        // Staged reduce+bcast (Cray-MPI baseline shape): the binomial
        // reduce sends raw; only the broadcast compresses, once.
        (Op::Allreduce, Algo::Binomial) => Some(1.0),
        // Ring Allgather: gZCCL one-compression invariant — every
        // origin block is compressed exactly once and forwarded
        // verbatim.
        (Op::Allgather, Algo::Ring) => Some(1.0),
        // Log-step allgathers recompress doubling aggregates: the
        // farthest block crosses ⌈log₂N⌉ compress hops (no reduction,
        // so hops add linearly).
        (Op::Allgather, Algo::RecursiveDoubling) | (Op::Allgather, Algo::Bruck) => {
            Some(ceil_log2(n) as f64)
        }
        // Ring Reduce_scatter: N−1 linear hops.
        (Op::ReduceScatter, Algo::Ring) => Some((n - 1) as f64),
        // Binomial Scatter: each block compressed once at the root,
        // forwarded verbatim, decompressed once per consumer (the root
        // included — it decodes its own block).
        (Op::Scatter, Algo::Binomial) => Some(1.0),
        // Binomial Bcast: the root keeps its lossless copy.
        (Op::Bcast, Algo::Binomial) => Some(if rank == root { 0.0 } else { 1.0 }),
        _ => None,
    }
}

/// [`amplification`] maximized over ranks — the number the planner and
/// the tuner veto compare against a per-call budget.
pub fn worst_amplification(op: Op, algo: Algo, topo: &Topology, root: usize) -> Option<f64> {
    worst_amplification_tiers(op, algo, &TierTree::from(topo), root)
}

/// [`worst_amplification`] over an N-level [`TierTree`].
pub fn worst_amplification_tiers(
    op: Op,
    algo: Algo,
    tree: &TierTree,
    root: usize,
) -> Option<f64> {
    let n = tree.ranks();
    if n <= 1 {
        return Some(0.0);
    }
    // Amplification is rank-uniform except for rooted ops, where the
    // root is the *smaller* case; any non-root rank is the worst.
    let probe_rank = if root == 0 { n - 1 } else { 0 };
    amplification_tiers(op, algo, tree, probe_rank, root)
}

/// Predicted worst-case pointwise error of one `(op, algo)` call at
/// `rank` under `(mode, eb)`. `None` when the model does not cover the
/// pair (cannot certify).
pub fn predict(
    op: Op,
    algo: Algo,
    topo: &Topology,
    rank: usize,
    root: usize,
    mode: CompressionMode,
    eb: f64,
) -> Option<ErrorPrediction> {
    match mode {
        CompressionMode::None => Some(ErrorPrediction::Exact),
        CompressionMode::FixedRate => Some(ErrorPrediction::Unbounded),
        CompressionMode::ErrorBounded => amplification(op, algo, topo, rank, root).map(|m| {
            if m == 0.0 {
                ErrorPrediction::Exact
            } else {
                ErrorPrediction::Bounded(m * eb)
            }
        }),
    }
}

/// [`predict`] maximized over ranks.
pub fn predict_worst(
    op: Op,
    algo: Algo,
    topo: &Topology,
    root: usize,
    mode: CompressionMode,
    eb: f64,
) -> Option<ErrorPrediction> {
    predict_worst_tiers(op, algo, &TierTree::from(topo), root, mode, eb)
}

/// [`predict_worst`] over an N-level [`TierTree`].
pub fn predict_worst_tiers(
    op: Op,
    algo: Algo,
    tree: &TierTree,
    root: usize,
    mode: CompressionMode,
    eb: f64,
) -> Option<ErrorPrediction> {
    match mode {
        CompressionMode::None => Some(ErrorPrediction::Exact),
        CompressionMode::FixedRate => Some(ErrorPrediction::Unbounded),
        CompressionMode::ErrorBounded => {
            worst_amplification_tiers(op, algo, tree, root).map(|m| {
                if m == 0.0 {
                    ErrorPrediction::Exact
                } else {
                    ErrorPrediction::Bounded(m * eb)
                }
            })
        }
    }
}

/// Rank/root/topology-resolved predicted `(compress, decompress)`
/// kernel counts for any implemented `(op, algo)` — the single entry
/// point over the `expected_cpr_stages*` family in
/// [`crate::collectives`]:
///
/// * topology-dependent pairs (hierarchical Allreduce) dispatch to
///   `expected_cpr_stages_hier`,
/// * root-dependent binomial trees and everything rank-symmetric
///   dispatch through `expected_cpr_stages_at`.
pub fn cpr_stages(
    op: Op,
    algo: Algo,
    topo: &Topology,
    rank: usize,
    root: usize,
) -> Option<(usize, usize)> {
    match (op, algo) {
        (Op::Allreduce, Algo::Hierarchical) => Some(expected_cpr_stages_hier(
            topo.ranks(),
            topo.gpus_per_node(),
            rank,
        )),
        // The multi-tier hierarchical variants count stages by walking
        // their compiled schedule.
        (Op::ReduceScatter, Algo::Hierarchical) | (Op::Allgather, Algo::Hierarchical) => {
            compile_min_error(op, &TierTree::from(topo), true)
                .ok()
                .map(|s| s.cpr_stages_at(rank))
        }
        (Op::Scatter | Op::Bcast, Algo::Hierarchical) => {
            compile_rooted(op, &TierTree::from(topo), true, root)
                .ok()
                .map(|s| s.cpr_stages_at(rank))
        }
        _ => expected_cpr_stages_at(op, algo, topo.ranks(), rank, root),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo(ranks: usize, g: usize) -> Topology {
        Topology::new(ranks, g).unwrap()
    }

    #[test]
    fn flat_allreduce_amplifications() {
        let t = topo(8, 4);
        assert_eq!(amplification(Op::Allreduce, Algo::Ring, &t, 0, 0), Some(8.0));
        // pow2 ReDoub: 2^3 − 1 = 7.
        assert_eq!(
            amplification(Op::Allreduce, Algo::RecursiveDoubling, &t, 0, 0),
            Some(7.0)
        );
        // Non-pow2 (6 ranks): pof2 = 4 → log 2, +2 fold stages → 2^4−1.
        assert_eq!(
            amplification(Op::Allreduce, Algo::RecursiveDoubling, &topo(6, 2), 0, 0),
            Some(15.0)
        );
        assert_eq!(amplification(Op::Allreduce, Algo::Binomial, &t, 3, 0), Some(1.0));
    }

    #[test]
    fn hierarchical_amplification_counts_nodes_not_ranks() {
        // 128 ranks / 4 per node → 32 nodes: 2^5 − 1 = 31 ≪ ring's 128.
        let t = topo(128, 4);
        assert_eq!(
            amplification(Op::Allreduce, Algo::Hierarchical, &t, 0, 0),
            Some(31.0)
        );
        assert_eq!(amplification(Op::Allreduce, Algo::Ring, &t, 0, 0), Some(128.0));
        // Single node: the hierarchical schedule never compresses.
        assert_eq!(
            amplification(Op::Allreduce, Algo::Hierarchical, &topo(4, 4), 0, 0),
            Some(0.0)
        );
        // Non-pow2 node count (6 nodes): fold/unfold stages included.
        assert_eq!(
            amplification(Op::Allreduce, Algo::Hierarchical, &topo(12, 2), 0, 0),
            Some(15.0)
        );
    }

    #[test]
    fn forwarded_stream_ops_pay_one_eb() {
        let t = topo(16, 4);
        assert_eq!(amplification(Op::Allgather, Algo::Ring, &t, 0, 0), Some(1.0));
        assert_eq!(amplification(Op::Scatter, Algo::Binomial, &t, 5, 2), Some(1.0));
        assert_eq!(amplification(Op::Bcast, Algo::Binomial, &t, 2, 2), Some(0.0));
        assert_eq!(amplification(Op::Bcast, Algo::Binomial, &t, 3, 2), Some(1.0));
        assert_eq!(worst_amplification(Op::Bcast, Algo::Binomial, &t, 2), Some(1.0));
        assert_eq!(
            amplification(Op::ReduceScatter, Algo::Ring, &t, 0, 0),
            Some(15.0)
        );
        // Log-step allgathers recompress aggregates.
        assert_eq!(amplification(Op::Allgather, Algo::Bruck, &t, 0, 0), Some(4.0));
    }

    #[test]
    fn uncovered_pairs_are_none_not_zero() {
        let t = topo(8, 4);
        assert_eq!(amplification(Op::Scatter, Algo::Ring, &t, 0, 0), None);
        assert_eq!(
            predict(Op::Scatter, Algo::Ring, &t, 0, 0, CompressionMode::ErrorBounded, 1e-4),
            None
        );
    }

    #[test]
    fn predictions_by_mode() {
        let t = topo(8, 4);
        assert_eq!(
            predict_worst(Op::Allreduce, Algo::Ring, &t, 0, CompressionMode::None, 1e-4),
            Some(ErrorPrediction::Exact)
        );
        assert_eq!(
            predict_worst(Op::Allreduce, Algo::Ring, &t, 0, CompressionMode::FixedRate, 1e-4),
            Some(ErrorPrediction::Unbounded)
        );
        let p = predict_worst(
            Op::Allreduce,
            Algo::Ring,
            &t,
            0,
            CompressionMode::ErrorBounded,
            1e-4,
        )
        .unwrap();
        assert_eq!(p.bound(), Some(8.0 * 1e-4));
        assert!(p.is_bounded());
        assert_eq!(ErrorPrediction::Unbounded.bound(), None);
        // Identity on a one-rank communicator is exact.
        assert_eq!(
            predict_worst(
                Op::Allreduce,
                Algo::Identity,
                &topo(1, 4),
                0,
                CompressionMode::ErrorBounded,
                1e-4
            ),
            Some(ErrorPrediction::Exact)
        );
    }

    #[test]
    fn iteration_compounding_is_linear() {
        let p = ErrorPrediction::Bounded(1e-4);
        assert_eq!(p.iterated(10), ErrorPrediction::Bounded(1e-3));
        assert_eq!(ErrorPrediction::Unbounded.iterated(10), ErrorPrediction::Unbounded);
        assert_eq!(ErrorPrediction::Exact.iterated(10), ErrorPrediction::Exact);
    }

    #[test]
    fn hierarchical_rs_and_ag_are_certifiable() {
        // 32 ranks / 4 per node → 8 nodes: the hierarchical
        // Reduce_scatter pays the top doubling (2^3 − 1), not the ring's
        // N−1 linear stages; the Allgather forwards compress-once
        // streams (one crossing on a 2-tier tree).
        let t = topo(32, 4);
        assert_eq!(
            amplification(Op::ReduceScatter, Algo::Hierarchical, &t, 0, 0),
            Some(7.0)
        );
        assert_eq!(
            amplification(Op::Allgather, Algo::Hierarchical, &t, 0, 0),
            Some(1.0)
        );
        assert_eq!(amplification(Op::ReduceScatter, Algo::Ring, &t, 0, 0), Some(31.0));
        // Deep trees through the tiers entry points.
        let tree = crate::topo::TierTree::new(512, &[4, 16, 8]).unwrap();
        assert_eq!(
            worst_amplification_tiers(Op::Allreduce, Algo::Hierarchical, &tree, 0),
            Some(128.0)
        );
        assert_eq!(
            worst_amplification_tiers(Op::ReduceScatter, Algo::Hierarchical, &tree, 0),
            Some(128.0)
        );
        assert_eq!(
            worst_amplification_tiers(Op::Allgather, Algo::Hierarchical, &tree, 0),
            Some(3.0)
        );
        // Flat algorithms agree between the two entry points.
        assert_eq!(
            worst_amplification_tiers(Op::Allreduce, Algo::Ring, &tree, 0),
            Some(512.0)
        );
    }

    #[test]
    fn cpr_stages_unifies_the_family() {
        let t = topo(16, 4);
        // Rank-symmetric pair → flat table.
        assert_eq!(cpr_stages(Op::Allreduce, Algo::Ring, &t, 3, 0), Some((16, 30)));
        // Root-dependent pair.
        assert_eq!(cpr_stages(Op::Scatter, Algo::Binomial, &t, 5, 5), Some((16, 1)));
        assert_eq!(cpr_stages(Op::Scatter, Algo::Binomial, &t, 0, 5), Some((0, 1)));
        // Topology-dependent pair: leaders compress log₂(nodes) times.
        assert_eq!(cpr_stages(Op::Allreduce, Algo::Hierarchical, &t, 0, 0), Some((2, 2)));
        assert_eq!(cpr_stages(Op::Allreduce, Algo::Hierarchical, &t, 5, 0), Some((0, 0)));
    }
}
