//! Accuracy-aware error control (paper §3.4, §4.5; C-Coll's error
//! propagation analysis).
//!
//! gZCCL's second headline contribution — beyond pipelined/hierarchical
//! performance — is *controlling* the error that lossy compression
//! injects into collectives. This subsystem makes that a first-class
//! layer with three parts:
//!
//! * [`propagation`] — the forward model: worst-case pointwise error
//!   per `(Op, Algo, rank, root, Topology)`, built on and subsuming the
//!   `expected_cpr_stages*` stage-count family. Linear `stages × eb`
//!   accumulation for chained hops, `(2^S − 1)·eb` for doubling trees,
//!   one `eb` for forwarded streams, explicit
//!   [`propagation::ErrorPrediction::Unbounded`] for the fixed-rate
//!   hazard, and linear compounding across dependent iterations.
//! * [`budget`] — the inverse model: given an end-to-end target
//!   (absolute L∞ or a PSNR floor vs a value range), rank count,
//!   topology, algorithm and iteration count, derive the per-call
//!   compressor error bound. Exposed as
//!   [`crate::comm::CommBuilder::accuracy_target`]; the
//!   [`crate::comm::Tuner`] gains an accuracy veto
//!   ([`crate::comm::Tuner::select_within_budget`]) so auto-selection
//!   never picks an algorithm whose stage count blows the budget.
//! * [`telemetry`] — the runtime check: each compressed collective on
//!   real payloads records predicted bound vs observed max deviation
//!   against an exact reference on a deterministic element sample,
//!   surfaced through [`crate::comm::CollectiveReport::accuracy`] and
//!   the per-rank [`crate::coordinator::OpCounters`].
//!   [`AccuracyReport::relaxation_factor_vs`] turns observed headroom
//!   into a conservative bound-relaxation proposal, which the
//!   [`crate::comm::Communicator`]'s adaptive controller
//!   ([`crate::comm::CommBuilder::adaptive`]) folds back into the next
//!   dispatch's execution plan — the closed telemetry loop.
//!
//! All three walk the same [`crate::topo::TierTree`] the scheduler
//! compiles against (`*_tiers` entry points): hierarchical algorithms'
//! amplification is the compiled schedule's leg walk, and
//! [`split_across_tiers`] divides a per-call budget across tiers by
//! predicted compressibility. Targets come in absolute, PSNR-floor,
//! and value-range-relative ([`AccuracyTarget::RelError`], resolved at
//! plan time) forms.

pub mod budget;
pub mod propagation;
pub mod telemetry;

pub use budget::{
    complies, complies_tiers, plan_auto, plan_auto_tiers, plan_for_algo, plan_for_algo_tiers,
    split_across_tiers, AccuracyTarget, BudgetPlan, TierBudget, TieredPlan,
};
pub use propagation::{
    amplification, amplification_tiers, cpr_stages, predict, predict_worst, predict_worst_tiers,
    worst_amplification, worst_amplification_tiers, ErrorPrediction,
};
pub use telemetry::{
    AccuracyObservation, AccuracyReport, ErrorProbe, MAX_EB_RELAXATION, MAX_SAMPLE,
};
