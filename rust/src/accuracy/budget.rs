//! The error-budget planner: invert the propagation model.
//!
//! Given an end-to-end accuracy target — an absolute L∞ ceiling or a
//! PSNR floor against a known value range — the planner derives the
//! per-call compressor error bound that *guarantees* the target:
//!
//! ```text
//! eb = target_abs / (iterations × amplification(op, algo, topology))
//! ```
//!
//! A PSNR floor converts soundly to an absolute target because
//! `PSNR = 20·log₁₀(range / RMSE)` and `RMSE ≤ L∞`: holding
//! `L∞ ≤ range · 10^(−dB/20)` implies the floor.
//!
//! The planner **rejects** the fixed-rate compressor outright — its
//! pointwise error scales with data magnitude (the CPRP2P hazard,
//! [`crate::accuracy::propagation::ErrorPrediction::Unbounded`]), so no
//! per-call bound can certify any finite target.
//!
//! [`complies`] is the check the [`crate::comm::Tuner`] accuracy veto
//! and the forced-algorithm validation use: an algorithm complies with
//! a plan iff its worst-case amplification times the planned `eb` fits
//! inside the per-call budget.

use crate::collectives::{Algo, Op};
use crate::coordinator::CompressionMode;
use crate::error::{Error, Result};
use crate::net::Topology;

use super::propagation::worst_amplification;

/// End-to-end accuracy target for a budgeted run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AccuracyTarget {
    /// Absolute pointwise ceiling: `|out − exact| ≤ value` everywhere.
    AbsError(f64),
    /// PSNR floor in dB against data spanning `value_range`
    /// (the SZ/cuSZp convention: peak = max − min of the reference).
    PsnrFloor {
        /// Minimum acceptable PSNR in dB.
        db: f64,
        /// Value range of the reference data the PSNR is taken against.
        value_range: f64,
    },
}

impl AccuracyTarget {
    /// The absolute L∞ ceiling this target reduces to.
    pub fn abs_bound(&self) -> f64 {
        match *self {
            AccuracyTarget::AbsError(t) => t,
            AccuracyTarget::PsnrFloor { db, value_range } => {
                value_range * 10f64.powf(-db / 20.0)
            }
        }
    }
}

/// A planned error budget: the inverted model plus everything needed to
/// check other algorithms against it.
#[derive(Debug, Clone, Copy)]
pub struct BudgetPlan {
    /// The end-to-end target the plan certifies.
    pub target: AccuracyTarget,
    /// Dependent iterations the target is split across (DDP steps,
    /// stacking batches); 1 for one-shot collectives.
    pub iterations: usize,
    /// Per-call absolute budget: `target.abs_bound() / iterations`.
    pub per_call_abs: f64,
    /// The derived per-call compressor error bound.
    pub eb: f64,
    /// Algorithm the inversion was anchored on.
    pub planned_algo: Algo,
    /// That algorithm's worst-case amplification.
    pub amplification: f64,
}

fn validated_abs(target: AccuracyTarget, iterations: usize) -> Result<f64> {
    let abs = target.abs_bound();
    if !(abs.is_finite() && abs > 0.0) {
        return Err(Error::budget(format!(
            "accuracy target reduces to a non-positive / non-finite bound ({abs:e})"
        )));
    }
    if iterations == 0 {
        return Err(Error::budget("accuracy plan needs iterations >= 1"));
    }
    Ok(abs)
}

/// Plan the per-call error bound for a **specific** `(op, algo)` on
/// `topo`, splitting the target across `iterations` dependent calls.
///
/// Rejections (typed errors): the fixed-rate compressor (unbounded
/// hazard), an uncompressed policy (nothing to plan), a non-positive
/// target, and `(op, algo)` pairs the propagation model cannot certify.
pub fn plan_for_algo(
    target: AccuracyTarget,
    iterations: usize,
    op: Op,
    algo: Algo,
    topo: &Topology,
    mode: CompressionMode,
) -> Result<BudgetPlan> {
    match mode {
        CompressionMode::FixedRate => {
            return Err(Error::budget(
                "accuracy target rejected: the fixed-rate compressor's pointwise error scales \
                 with data magnitude and cannot be bounded a priori; use the error-bounded policy",
            ));
        }
        CompressionMode::None => {
            return Err(Error::budget(
                "accuracy plan is moot: the policy never compresses (results are exact)",
            ));
        }
        CompressionMode::ErrorBounded => {}
    }
    let abs = validated_abs(target, iterations)?;
    let per_call_abs = abs / iterations as f64;
    let m = worst_amplification(op, algo, topo, 0).ok_or_else(|| {
        Error::budget(format!(
            "accuracy plan rejected: no propagation model for {algo:?} {op:?}"
        ))
    })?;
    // m == 0 (single-rank, or hierarchical on one node) means the call
    // introduces no compression error at all: any eb meets the target,
    // so hand the compressor the whole per-call budget.
    let eb = if m == 0.0 { per_call_abs } else { per_call_abs / m };
    Ok(BudgetPlan {
        target,
        iterations,
        per_call_abs,
        eb,
        planned_algo: algo,
        amplification: m,
    })
}

/// Plan a communicator-level budget: anchor the inversion on the
/// best-accuracy Allreduce schedule the topology supports — the
/// hierarchical two-level schedule on multi-node multi-GPU layouts
/// (compression confined to `⌈log₂ nodes⌉` internode exchanges), flat
/// recursive doubling otherwise. The [`crate::comm::Tuner`] accuracy
/// veto then restricts auto-selection to algorithms that
/// [`complies`]-check against the resulting plan.
pub fn plan_auto(
    target: AccuracyTarget,
    iterations: usize,
    topo: &Topology,
    mode: CompressionMode,
) -> Result<BudgetPlan> {
    let anchor = if topo.nodes() >= 2 && topo.gpus_per_node() >= 2 {
        Algo::Hierarchical
    } else {
        Algo::RecursiveDoubling
    };
    plan_for_algo(target, iterations, Op::Allreduce, anchor, topo, mode)
}

/// Whether `(op, algo)` fits inside `plan`'s per-call budget: its
/// worst-case predicted error `m · eb` must not exceed `per_call_abs`
/// (with a 1e-9 relative slack for the division round-trip). Pairs the
/// model cannot certify never comply.
pub fn complies(plan: &BudgetPlan, op: Op, algo: Algo, topo: &Topology, root: usize) -> bool {
    match worst_amplification(op, algo, topo, root) {
        None => false,
        Some(m) => m * plan.eb <= plan.per_call_abs * (1.0 + 1e-9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo(ranks: usize, g: usize) -> Topology {
        Topology::new(ranks, g).unwrap()
    }

    #[test]
    fn psnr_floor_converts_to_abs_bound() {
        let t = AccuracyTarget::PsnrFloor {
            db: 60.0,
            value_range: 2.0,
        };
        // 2 · 10^(−3) = 2e-3.
        assert!((t.abs_bound() - 2e-3).abs() < 1e-12);
        assert_eq!(AccuracyTarget::AbsError(5e-4).abs_bound(), 5e-4);
    }

    #[test]
    fn plan_inverts_the_model() {
        let t = topo(8, 4);
        let plan = plan_for_algo(
            AccuracyTarget::AbsError(8e-3),
            1,
            Op::Allreduce,
            Algo::Ring,
            &t,
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        // Ring amplification on 8 ranks is 8 → eb = 1e-3.
        assert!((plan.eb - 1e-3).abs() < 1e-15);
        assert_eq!(plan.amplification, 8.0);
        assert!(complies(&plan, Op::Allreduce, Algo::Ring, &t, 0));
        // Iterations split the budget linearly.
        let it = plan_for_algo(
            AccuracyTarget::AbsError(8e-3),
            10,
            Op::Allreduce,
            Algo::Ring,
            &t,
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        assert!((it.eb - 1e-4).abs() < 1e-15);
    }

    #[test]
    fn planner_rejects_the_fixed_rate_hazard() {
        let t = topo(8, 4);
        let err = plan_for_algo(
            AccuracyTarget::AbsError(1e-3),
            1,
            Op::Allreduce,
            Algo::Ring,
            &t,
            CompressionMode::FixedRate,
        )
        .unwrap_err();
        assert!(err.to_string().contains("fixed-rate"), "{err}");
        assert!(plan_for_algo(
            AccuracyTarget::AbsError(1e-3),
            1,
            Op::Allreduce,
            Algo::Ring,
            &t,
            CompressionMode::None,
        )
        .is_err());
    }

    #[test]
    fn planner_rejects_degenerate_targets() {
        let t = topo(8, 4);
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(plan_for_algo(
                AccuracyTarget::AbsError(bad),
                1,
                Op::Allreduce,
                Algo::Ring,
                &t,
                CompressionMode::ErrorBounded,
            )
            .is_err());
        }
        assert!(plan_for_algo(
            AccuracyTarget::AbsError(1e-3),
            0,
            Op::Allreduce,
            Algo::Ring,
            &t,
            CompressionMode::ErrorBounded,
        )
        .is_err());
    }

    #[test]
    fn auto_plan_anchors_on_best_accuracy_schedule() {
        // Multi-node multi-GPU → hierarchical anchor (smallest m).
        let plan = plan_auto(
            AccuracyTarget::AbsError(1e-3),
            1,
            &topo(32, 4),
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        assert_eq!(plan.planned_algo, Algo::Hierarchical);
        assert_eq!(plan.amplification, 7.0); // 8 nodes → 2^3 − 1
        // The flat schedules blow the same budget...
        assert!(!complies(&plan, Op::Allreduce, Algo::Ring, &topo(32, 4), 0));
        assert!(!complies(
            &plan,
            Op::Allreduce,
            Algo::RecursiveDoubling,
            &topo(32, 4),
            0
        ));
        // ...while the anchor and the compress-once ops fit.
        assert!(complies(&plan, Op::Allreduce, Algo::Hierarchical, &topo(32, 4), 0));
        assert!(complies(&plan, Op::Bcast, Algo::Binomial, &topo(32, 4), 0));
        assert!(complies(&plan, Op::Allgather, Algo::Ring, &topo(32, 4), 0));
        // Single node → flat ReDoub anchor.
        let flat = plan_auto(
            AccuracyTarget::AbsError(1e-3),
            1,
            &topo(4, 4),
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        assert_eq!(flat.planned_algo, Algo::RecursiveDoubling);
    }

    #[test]
    fn uncertifiable_pairs_never_comply() {
        let t = topo(8, 4);
        let plan = plan_auto(
            AccuracyTarget::AbsError(1.0),
            1,
            &t,
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        assert!(!complies(&plan, Op::Scatter, Algo::Ring, &t, 0));
    }
}
