//! The error-budget planner: invert the propagation model.
//!
//! Given an end-to-end accuracy target — an absolute L∞ ceiling, a
//! PSNR floor against a known value range, or a value-range-relative
//! bound resolved at plan time — the planner derives the per-call
//! compressor error bound that *guarantees* the target:
//!
//! ```text
//! eb = target_abs / (iterations × amplification(op, algo, tree))
//! ```
//!
//! A PSNR floor converts soundly to an absolute target because
//! `PSNR = 20·log₁₀(range / RMSE)` and `RMSE ≤ L∞`: holding
//! `L∞ ≤ range · 10^(−dB/20)` implies the floor. A relative target
//! `RelError(r)` resolves to `r · range` against the payload's value
//! range supplied at plan time — the planner rejects it when no range
//! is known (it cannot certify a relative bound a priori).
//!
//! The planner **rejects** the fixed-rate compressor outright — its
//! pointwise error scales with data magnitude (the CPRP2P hazard,
//! [`crate::accuracy::propagation::ErrorPrediction::Unbounded`]), so no
//! per-call bound can certify any finite target.
//!
//! [`complies`] / [`complies_tiers`] is the check the
//! [`crate::comm::Tuner`] accuracy veto and the forced-algorithm
//! validation use: an algorithm complies with a plan iff its
//! worst-case amplification times the planned `eb` fits inside the
//! per-call budget.
//!
//! **Per-tier budgets.** On a multi-tier [`TierTree`] the hierarchical
//! schedule compresses on several tiers, and the end-to-end error is
//! `Σ_t A[t] · eb_t` with the sensitivities `A` from
//! [`crate::topo::Schedule::tier_sensitivities`].
//! [`split_across_tiers`] divides the per-call budget across tiers
//! proportionally to caller-supplied *compressibility weights* (a tier
//! whose data compresses well can afford a larger share): the
//! resulting per-tier bounds always satisfy
//! `Σ_t A[t] · eb_t ≤ per_call_abs`.

use crate::collectives::{Algo, Op};
use crate::coordinator::CompressionMode;
use crate::error::{Error, Result};
use crate::net::Topology;
use crate::topo::{compile_min_error, TierTree};

use super::propagation::worst_amplification_tiers;

/// End-to-end accuracy target for a budgeted run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AccuracyTarget {
    /// Absolute pointwise ceiling: `|out − exact| ≤ value` everywhere.
    AbsError(f64),
    /// PSNR floor in dB against data spanning `value_range`
    /// (the SZ/cuSZp convention: peak = max − min of the reference).
    PsnrFloor {
        /// Minimum acceptable PSNR in dB.
        db: f64,
        /// Value range of the reference data the PSNR is taken against.
        value_range: f64,
    },
    /// Value-range-relative ceiling: `|out − exact| ≤ value · range`,
    /// resolved against the payload's value range **at plan time**
    /// (the SZ "REL" convention). Planning without a known range is a
    /// typed rejection.
    RelError(f64),
    /// Bitwise-exact results: zero tolerated deviation. Instead of
    /// vetoing every compressed algorithm, the planner certifies the
    /// lossless codec tier ([`crate::compress::CodecSpec::lossless`],
    /// zero distortion at any amplification) with `eb = 0` — the
    /// collective still compresses, it just stops quantizing.
    Bitexact,
}

impl AccuracyTarget {
    /// The absolute L∞ ceiling this target reduces to, when it is
    /// self-contained (`None` for [`AccuracyTarget::RelError`], which
    /// needs a value range — see [`AccuracyTarget::resolve_abs`]).
    pub fn abs_bound(&self) -> Option<f64> {
        match *self {
            AccuracyTarget::AbsError(t) => Some(t),
            AccuracyTarget::PsnrFloor { db, value_range } => {
                Some(value_range * 10f64.powf(-db / 20.0))
            }
            AccuracyTarget::RelError(_) => None,
            AccuracyTarget::Bitexact => Some(0.0),
        }
    }

    /// Resolve to an absolute L∞ ceiling, using `value_range` for the
    /// relative form. Typed rejection when a relative target has no
    /// range to resolve against.
    pub fn resolve_abs(&self, value_range: Option<f64>) -> Result<f64> {
        match *self {
            AccuracyTarget::RelError(r) => {
                let range = value_range.ok_or_else(|| {
                    Error::budget(
                        "relative accuracy target needs the payload's value range at plan \
                         time (set it via CommBuilder::value_range)",
                    )
                })?;
                if !(range.is_finite() && range > 0.0) {
                    return Err(Error::budget(format!(
                        "relative accuracy target cannot resolve against value range {range:e}"
                    )));
                }
                Ok(r * range)
            }
            _ => Ok(self.abs_bound().expect("non-relative targets are self-contained")),
        }
    }
}

/// A planned error budget: the inverted model plus everything needed to
/// check other algorithms against it.
#[derive(Debug, Clone, Copy)]
pub struct BudgetPlan {
    /// The end-to-end target the plan certifies.
    pub target: AccuracyTarget,
    /// Dependent iterations the target is split across (DDP steps,
    /// stacking batches); 1 for one-shot collectives.
    pub iterations: usize,
    /// Per-call absolute budget: resolved target bound / iterations.
    pub per_call_abs: f64,
    /// The derived per-call compressor error bound.
    pub eb: f64,
    /// Algorithm the inversion was anchored on.
    pub planned_algo: Algo,
    /// That algorithm's worst-case amplification.
    pub amplification: f64,
}

fn validated_abs(
    target: AccuracyTarget,
    value_range: Option<f64>,
    iterations: usize,
) -> Result<f64> {
    let abs = target.resolve_abs(value_range)?;
    if !(abs.is_finite() && abs > 0.0) {
        return Err(Error::budget(format!(
            "accuracy target reduces to a non-positive / non-finite bound ({abs:e})"
        )));
    }
    if iterations == 0 {
        return Err(Error::budget("accuracy plan needs iterations >= 1"));
    }
    Ok(abs)
}

fn reject_uncompressable(mode: CompressionMode) -> Result<()> {
    match mode {
        CompressionMode::FixedRate => Err(Error::budget(
            "accuracy target rejected: the fixed-rate compressor's pointwise error scales \
             with data magnitude and cannot be bounded a priori; use the error-bounded policy",
        )),
        CompressionMode::None => Err(Error::budget(
            "accuracy plan is moot: the policy never compresses (results are exact)",
        )),
        CompressionMode::ErrorBounded => Ok(()),
    }
}

/// Plan the per-call error bound for a **specific** `(op, algo)` on
/// `topo`, splitting the target across `iterations` dependent calls.
///
/// Rejections (typed errors): the fixed-rate compressor (unbounded
/// hazard), an uncompressed policy (nothing to plan), a non-positive
/// target, a relative target with no value range, and `(op, algo)`
/// pairs the propagation model cannot certify.
pub fn plan_for_algo(
    target: AccuracyTarget,
    iterations: usize,
    op: Op,
    algo: Algo,
    topo: &Topology,
    mode: CompressionMode,
) -> Result<BudgetPlan> {
    plan_for_algo_tiers(target, None, iterations, op, algo, &TierTree::from(topo), mode)
}

/// [`plan_for_algo`] over an N-level [`TierTree`], with an optional
/// payload value range for resolving relative targets.
pub fn plan_for_algo_tiers(
    target: AccuracyTarget,
    value_range: Option<f64>,
    iterations: usize,
    op: Op,
    algo: Algo,
    tree: &TierTree,
    mode: CompressionMode,
) -> Result<BudgetPlan> {
    reject_uncompressable(mode)?;
    if target == AccuracyTarget::Bitexact {
        // Zero budget: only the lossless codec satisfies it, and it
        // does so at *any* amplification — plan eb = 0 instead of
        // vetoing (the dispatcher binds the lossless pipeline).
        if iterations == 0 {
            return Err(Error::budget("accuracy plan needs iterations >= 1"));
        }
        let m = worst_amplification_tiers(op, algo, tree, 0).ok_or_else(|| {
            Error::budget(format!(
                "accuracy plan rejected: no propagation model for {algo:?} {op:?}"
            ))
        })?;
        return Ok(BudgetPlan {
            target,
            iterations,
            per_call_abs: 0.0,
            eb: 0.0,
            planned_algo: algo,
            amplification: m,
        });
    }
    let abs = validated_abs(target, value_range, iterations)?;
    let per_call_abs = abs / iterations as f64;
    let m = worst_amplification_tiers(op, algo, tree, 0).ok_or_else(|| {
        Error::budget(format!(
            "accuracy plan rejected: no propagation model for {algo:?} {op:?}"
        ))
    })?;
    // m == 0 (single-rank, or hierarchical on one node) means the call
    // introduces no compression error at all: any eb meets the target,
    // so hand the compressor the whole per-call budget.
    let eb = if m == 0.0 { per_call_abs } else { per_call_abs / m };
    Ok(BudgetPlan {
        target,
        iterations,
        per_call_abs,
        eb,
        planned_algo: algo,
        amplification: m,
    })
}

fn auto_anchor(tree: &TierTree) -> Algo {
    if tree.groups(0) >= 2 && tree.width(0) >= 2 {
        Algo::Hierarchical
    } else {
        Algo::RecursiveDoubling
    }
}

/// Plan a communicator-level budget: anchor the inversion on the
/// best-accuracy Allreduce schedule the topology supports — the
/// hierarchical schedule on multi-node multi-GPU layouts (compression
/// confined to the tier-≥1 legs), flat recursive doubling otherwise.
/// The [`crate::comm::Tuner`] accuracy veto then restricts
/// auto-selection to algorithms that [`complies`]-check against the
/// resulting plan.
pub fn plan_auto(
    target: AccuracyTarget,
    iterations: usize,
    topo: &Topology,
    mode: CompressionMode,
) -> Result<BudgetPlan> {
    plan_auto_tiers(target, None, iterations, &TierTree::from(topo), mode)
}

/// [`plan_auto`] over an N-level [`TierTree`], with an optional payload
/// value range for resolving relative targets.
pub fn plan_auto_tiers(
    target: AccuracyTarget,
    value_range: Option<f64>,
    iterations: usize,
    tree: &TierTree,
    mode: CompressionMode,
) -> Result<BudgetPlan> {
    plan_for_algo_tiers(
        target,
        value_range,
        iterations,
        Op::Allreduce,
        auto_anchor(tree),
        tree,
        mode,
    )
}

/// Whether `(op, algo)` fits inside `plan`'s per-call budget: its
/// worst-case predicted error `m · eb` must not exceed `per_call_abs`
/// (with a 1e-9 relative slack for the division round-trip). Pairs the
/// model cannot certify never comply.
pub fn complies(plan: &BudgetPlan, op: Op, algo: Algo, topo: &Topology, root: usize) -> bool {
    complies_tiers(plan, op, algo, &TierTree::from(topo), root)
}

/// [`complies`] over an N-level [`TierTree`].
pub fn complies_tiers(
    plan: &BudgetPlan,
    op: Op,
    algo: Algo,
    tree: &TierTree,
    root: usize,
) -> bool {
    match worst_amplification_tiers(op, algo, tree, root) {
        None => false,
        Some(m) => m * plan.eb <= plan.per_call_abs * (1.0 + 1e-9),
    }
}

/// One tier's share of a per-call budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierBudget {
    /// The tier the bound applies to.
    pub tier: usize,
    /// Compressibility weight the split used.
    pub weight: f64,
    /// Error sensitivity `A[t]` of the schedule to this tier's bound.
    pub sensitivity: f64,
    /// The tier's compressor error bound.
    pub eb: f64,
}

/// A per-call budget split across the tiers of a hierarchical
/// schedule: `Σ_t sensitivity · eb ≤ per_call_abs` by construction.
#[derive(Debug, Clone, PartialEq)]
pub struct TieredPlan {
    /// The per-call budget being split.
    pub per_call_abs: f64,
    /// Per-tier shares (only tiers whose legs compress appear).
    pub tiers: Vec<TierBudget>,
}

impl TieredPlan {
    /// Worst-case end-to-end error if each tier runs at its own bound:
    /// `Σ_t A[t] · eb_t` — never exceeds `per_call_abs`.
    pub fn predicted_total(&self) -> f64 {
        self.tiers.iter().map(|t| t.sensitivity * t.eb).sum()
    }

    /// The tier budget for `tier`, if that tier compresses.
    pub fn tier(&self, tier: usize) -> Option<&TierBudget> {
        self.tiers.iter().find(|t| t.tier == tier)
    }

    /// The split as a dense per-tier eb table of `depth` entries
    /// (`None` for tiers with no share) — the form
    /// [`crate::topo::ExecPlan::tiered`] consumes when the dispatcher
    /// compiles the runtime execution plan from this split.
    pub fn tier_ebs(&self, depth: usize) -> Vec<Option<f64>> {
        let mut ebs = vec![None; depth];
        for t in &self.tiers {
            if t.tier < depth {
                ebs[t.tier] = Some(t.eb);
            }
        }
        ebs
    }
}

/// Split `plan`'s per-call budget across the tiers of `op`'s min-error
/// hierarchical schedule on `tree`, proportionally to `weights`
/// (predicted per-tier compressibility; missing entries default to 1 —
/// an equal split). A tier with weight `w` gets
/// `eb_t = per_call · w / (A[t] · Σw)`, so the shares always satisfy
/// `Σ_t A[t] · eb_t = per_call · (Σ_{used} w) / Σw ≤ per_call`.
///
/// Tiers whose legs never compress (tier 0, single-group tiers) get no
/// share; a schedule that compresses nowhere yields an empty split.
pub fn split_across_tiers(
    plan: &BudgetPlan,
    op: Op,
    tree: &TierTree,
    weights: Option<&[f64]>,
) -> Result<TieredPlan> {
    let sched = compile_min_error(op, tree, true)?;
    let sens = sched.tier_sensitivities();
    let weight_of = |t: usize| -> f64 {
        weights
            .and_then(|w| w.get(t).copied())
            .unwrap_or(1.0)
            .max(0.0)
    };
    let total_w: f64 = (0..sens.len())
        .filter(|&t| sens[t] > 0.0)
        .map(weight_of)
        .sum();
    let mut tiers = Vec::new();
    if total_w > 0.0 {
        for (t, &a) in sens.iter().enumerate() {
            if a <= 0.0 {
                continue;
            }
            let w = weight_of(t);
            tiers.push(TierBudget {
                tier: t,
                weight: w,
                sensitivity: a,
                eb: plan.per_call_abs * w / (a * total_w),
            });
        }
    }
    Ok(TieredPlan {
        per_call_abs: plan.per_call_abs,
        tiers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo(ranks: usize, g: usize) -> Topology {
        Topology::new(ranks, g).unwrap()
    }

    #[test]
    fn psnr_floor_converts_to_abs_bound() {
        let t = AccuracyTarget::PsnrFloor {
            db: 60.0,
            value_range: 2.0,
        };
        // 2 · 10^(−3) = 2e-3.
        assert!((t.abs_bound().unwrap() - 2e-3).abs() < 1e-12);
        assert_eq!(AccuracyTarget::AbsError(5e-4).abs_bound(), Some(5e-4));
    }

    #[test]
    fn relative_target_resolves_against_the_value_range() {
        let t = AccuracyTarget::RelError(1e-3);
        // No standalone bound…
        assert_eq!(t.abs_bound(), None);
        // …but resolves at plan time against the payload's range.
        assert!((t.resolve_abs(Some(4.0)).unwrap() - 4e-3).abs() < 1e-15);
        assert!(t.resolve_abs(None).is_err());
        assert!(t.resolve_abs(Some(0.0)).is_err());
        assert!(t.resolve_abs(Some(f64::NAN)).is_err());
        // The planner derives eb from the resolved bound: 8 ranks ring
        // → amplification 8, range 4 → eb = 4e-3/8.
        let plan = plan_for_algo_tiers(
            t,
            Some(4.0),
            1,
            Op::Allreduce,
            Algo::Ring,
            &TierTree::from(&topo(8, 4)),
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        assert!((plan.eb - 5e-4).abs() < 1e-15);
        // Without a range the plan is a typed budget rejection.
        let err = plan_for_algo(
            t,
            1,
            Op::Allreduce,
            Algo::Ring,
            &topo(8, 4),
            CompressionMode::ErrorBounded,
        )
        .unwrap_err();
        assert!(matches!(err, Error::Budget(_)), "{err}");
        assert!(err.to_string().contains("value range"), "{err}");
    }

    #[test]
    fn plan_inverts_the_model() {
        let t = topo(8, 4);
        let plan = plan_for_algo(
            AccuracyTarget::AbsError(8e-3),
            1,
            Op::Allreduce,
            Algo::Ring,
            &t,
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        // Ring amplification on 8 ranks is 8 → eb = 1e-3.
        assert!((plan.eb - 1e-3).abs() < 1e-15);
        assert_eq!(plan.amplification, 8.0);
        assert!(complies(&plan, Op::Allreduce, Algo::Ring, &t, 0));
        // Iterations split the budget linearly.
        let it = plan_for_algo(
            AccuracyTarget::AbsError(8e-3),
            10,
            Op::Allreduce,
            Algo::Ring,
            &t,
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        assert!((it.eb - 1e-4).abs() < 1e-15);
    }

    #[test]
    fn planner_rejects_the_fixed_rate_hazard() {
        let t = topo(8, 4);
        let err = plan_for_algo(
            AccuracyTarget::AbsError(1e-3),
            1,
            Op::Allreduce,
            Algo::Ring,
            &t,
            CompressionMode::FixedRate,
        )
        .unwrap_err();
        assert!(err.to_string().contains("fixed-rate"), "{err}");
        assert!(plan_for_algo(
            AccuracyTarget::AbsError(1e-3),
            1,
            Op::Allreduce,
            Algo::Ring,
            &t,
            CompressionMode::None,
        )
        .is_err());
    }

    #[test]
    fn planner_rejects_degenerate_targets() {
        let t = topo(8, 4);
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(plan_for_algo(
                AccuracyTarget::AbsError(bad),
                1,
                Op::Allreduce,
                Algo::Ring,
                &t,
                CompressionMode::ErrorBounded,
            )
            .is_err());
        }
        assert!(plan_for_algo(
            AccuracyTarget::AbsError(1e-3),
            0,
            Op::Allreduce,
            Algo::Ring,
            &t,
            CompressionMode::ErrorBounded,
        )
        .is_err());
    }

    #[test]
    fn auto_plan_anchors_on_best_accuracy_schedule() {
        // Multi-node multi-GPU → hierarchical anchor (smallest m).
        let plan = plan_auto(
            AccuracyTarget::AbsError(1e-3),
            1,
            &topo(32, 4),
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        assert_eq!(plan.planned_algo, Algo::Hierarchical);
        assert_eq!(plan.amplification, 7.0); // 8 nodes → 2^3 − 1
        // The flat schedules blow the same budget...
        assert!(!complies(&plan, Op::Allreduce, Algo::Ring, &topo(32, 4), 0));
        assert!(!complies(
            &plan,
            Op::Allreduce,
            Algo::RecursiveDoubling,
            &topo(32, 4),
            0
        ));
        // ...while the anchor and the compress-once ops fit.
        assert!(complies(&plan, Op::Allreduce, Algo::Hierarchical, &topo(32, 4), 0));
        assert!(complies(&plan, Op::Bcast, Algo::Binomial, &topo(32, 4), 0));
        assert!(complies(&plan, Op::Allgather, Algo::Ring, &topo(32, 4), 0));
        // The hierarchical Reduce_scatter shares the anchor's stage
        // structure: it complies where the ring cannot — the compliant
        // fallback the veto needed.
        assert!(complies(&plan, Op::ReduceScatter, Algo::Hierarchical, &topo(32, 4), 0));
        assert!(!complies(&plan, Op::ReduceScatter, Algo::Ring, &topo(32, 4), 0));
        // Single node → flat ReDoub anchor.
        let flat = plan_auto(
            AccuracyTarget::AbsError(1e-3),
            1,
            &topo(4, 4),
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        assert_eq!(flat.planned_algo, Algo::RecursiveDoubling);
    }

    #[test]
    fn bitexact_target_plans_lossless_zero_budget() {
        let t = topo(32, 4);
        let plan = plan_auto(
            AccuracyTarget::Bitexact,
            1,
            &t,
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        assert_eq!(plan.eb, 0.0);
        assert_eq!(plan.per_call_abs, 0.0);
        // Zero distortion fits any certifiable algorithm, even the
        // high-amplification flat rings the lossy budgets veto...
        assert!(complies(&plan, Op::Allreduce, Algo::Ring, &t, 0));
        assert!(complies(&plan, Op::Allreduce, Algo::Hierarchical, &t, 0));
        // ...but still never an uncertifiable pair.
        assert!(!complies(&plan, Op::Scatter, Algo::Ring, &t, 0));
        // Fixed rate cannot certify bit-exactness.
        assert!(plan_auto(AccuracyTarget::Bitexact, 1, &t, CompressionMode::FixedRate).is_err());
        // The per-tier split degenerates to zero bounds everywhere.
        let split = split_across_tiers(&plan, Op::Allreduce, &TierTree::from(&t), None).unwrap();
        assert!(!split.tiers.is_empty());
        assert!(split.tiers.iter().all(|tb| tb.eb == 0.0));
        assert_eq!(split.predicted_total(), 0.0);
    }

    #[test]
    fn uncertifiable_pairs_never_comply() {
        let t = topo(8, 4);
        let plan = plan_auto(
            AccuracyTarget::AbsError(1.0),
            1,
            &t,
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        assert!(!complies(&plan, Op::Scatter, Algo::Ring, &t, 0));
    }

    #[test]
    fn tiered_split_respects_the_per_call_budget() {
        // Non-power-of-two everything: 300 ranks as 3 GPUs/node, 10
        // nodes/rack, 10 racks.
        let tree = TierTree::new(300, &[3, 10, 10]).unwrap();
        let plan = plan_auto_tiers(
            AccuracyTarget::AbsError(1e-2),
            None,
            1,
            &tree,
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        // Equal weights.
        let split = split_across_tiers(&plan, Op::Allreduce, &tree, None).unwrap();
        assert!(!split.tiers.is_empty());
        assert!(split.tier(0).is_none(), "tier 0 never compresses");
        assert!(
            split.predicted_total() <= plan.per_call_abs * (1.0 + 1e-9),
            "Σ A·eb = {} vs per-call {}",
            split.predicted_total(),
            plan.per_call_abs
        );
        // Dense per-tier table form (what the ExecPlan compiler eats).
        let ebs = split.tier_ebs(3);
        assert_eq!(ebs[0], None);
        assert_eq!(ebs[1], Some(split.tier(1).unwrap().eb));
        assert_eq!(ebs[2], Some(split.tier(2).unwrap().eb));
        // Skewed compressibility weights trade eb between tiers but
        // never blow the budget.
        let skew = split_across_tiers(&plan, Op::Allreduce, &tree, Some(&[1.0, 5.0, 0.5]))
            .unwrap();
        assert!(skew.predicted_total() <= plan.per_call_abs * (1.0 + 1e-9));
        assert!(
            skew.tier(1).unwrap().eb > split.tier(1).unwrap().eb,
            "a heavier weight buys tier 1 a looser bound"
        );
        assert!(skew.tier(2).unwrap().eb < split.tier(2).unwrap().eb);
        // Single-node tree: nothing compresses, empty split.
        let solo = TierTree::new(4, &[4, 1]).unwrap();
        let plan = plan_auto_tiers(
            AccuracyTarget::AbsError(1e-2),
            None,
            1,
            &solo,
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        let empty = split_across_tiers(&plan, Op::Allreduce, &solo, None).unwrap();
        assert!(empty.tiers.is_empty());
        assert_eq!(empty.predicted_total(), 0.0);
    }
}
