//! Runtime error telemetry: predicted bound vs observed deviation.
//!
//! Every compressed collective dispatched on **real** payloads gets an
//! [`ErrorProbe`]: before the run, a deterministic element sample
//! (evenly-strided global indices, capped at [`MAX_SAMPLE`]) is
//! evaluated against an exact f64 reference computed from the inputs;
//! after the run, every rank's output is compared at the same indices
//! and the maximum deviation recorded. The
//! [`crate::comm::Communicator`] pairs the observation with the
//! propagation model's prediction into an [`AccuracyReport`], surfaced
//! through [`crate::comm::CollectiveReport`] and mirrored into each
//! rank's [`crate::coordinator::OpCounters`].
//!
//! The observed deviation includes f32 reduction-reassociation noise
//! and per-stage reconstruction rounding (the collective sums in f32,
//! the reference in f64), so [`AccuracyReport::within_bound`] allows a
//! floating-point slack of `O(nranks) · ε_f32 · max Σ|inputs|` on top
//! of the predicted compression bound. Virtual (size-only) payloads
//! produce no probe.

use crate::collectives::{Chunks, Op};
use crate::coordinator::DeviceBuf;

use super::propagation::ErrorPrediction;

/// Maximum sampled elements per collective.
pub const MAX_SAMPLE: usize = 4096;

/// The outcome of one probe: observed deviation on the sample.
#[derive(Debug, Clone, Copy)]
pub struct AccuracyObservation {
    /// Maximum `|output − exact|` over all ranks and sampled elements.
    pub observed_max_err: f64,
    /// Number of sampled elements.
    pub samples: usize,
    /// f32 reassociation slack the comparison must tolerate.
    pub fp_slack: f64,
}

/// Predicted-vs-observed accuracy record for one dispatched collective.
#[derive(Debug, Clone, Copy)]
pub struct AccuracyReport {
    /// The propagation model's worst-case prediction.
    pub prediction: ErrorPrediction,
    /// Maximum observed deviation on the sample.
    pub observed_max_err: f64,
    /// Number of sampled elements.
    pub samples: usize,
    /// f32 reassociation slack.
    pub fp_slack: f64,
}

/// Cap on how much [`AccuracyReport::suggested_eb`] may relax the
/// bound in one step: large single-step jumps would outrun the sampled
/// evidence the suggestion is based on.
pub const MAX_EB_RELAXATION: f64 = 8.0;

impl AccuracyReport {
    /// Whether the observation respects the predicted bound (plus the
    /// f32 slack). `None` when the prediction is unbounded (fixed-rate
    /// hazard): there is no bound to hold.
    pub fn within_bound(&self) -> Option<bool> {
        match self.prediction {
            ErrorPrediction::Exact => Some(self.observed_max_err <= self.fp_slack),
            ErrorPrediction::Bounded(b) => {
                Some(self.observed_max_err <= b * (1.0 + 1e-9) + self.fp_slack)
            }
            ErrorPrediction::Unbounded => None,
        }
    }

    /// The multiplicative eb-relaxation this report's headroom
    /// justifies: half the measured headroom (the other half held in
    /// reserve), capped at [`MAX_EB_RELAXATION`] per step. `None` when
    /// there is nothing sound to propose — an unbounded or exact
    /// prediction, or headroom under 2× (the model is already close to
    /// tight). This is what the [`crate::comm::Communicator`]'s
    /// adaptive controller folds back into the next dispatch's
    /// execution plan.
    pub fn relaxation_factor(&self) -> Option<f64> {
        match self.prediction {
            ErrorPrediction::Bounded(b) if b > 0.0 => self.relaxation_factor_vs(b),
            _ => None,
        }
    }

    /// [`AccuracyReport::relaxation_factor`] measured against an
    /// explicit absolute `budget` instead of this report's own
    /// prediction. This is the form the adaptive controller uses:
    /// observed quantization error scales with the compressor bound, so
    /// prediction-relative headroom is scale-invariant and would chase
    /// the cap forever — held against the fixed certified per-call
    /// budget, the loop converges (steady state ≈ half the budget) and
    /// the budget is the quantity the reserve protects.
    ///
    /// The raw observation is used undiscounted: `fp_slack` is a
    /// deliberately paranoid worst-case allowance that grows linearly
    /// with the rank count and can dwarf a tight budget — subtracting
    /// it would overstate headroom exactly when caution matters most,
    /// while leaving the noise in only makes the proposal more
    /// conservative.
    pub fn relaxation_factor_vs(&self, budget: f64) -> Option<f64> {
        if !(budget.is_finite() && budget > 0.0) {
            return None;
        }
        if self.prediction == ErrorPrediction::Unbounded {
            return None; // no bound governs the stream: nothing to relax
        }
        let headroom = if self.observed_max_err <= 0.0 {
            MAX_EB_RELAXATION * 2.0
        } else {
            budget / self.observed_max_err
        };
        if headroom <= 2.0 {
            return None;
        }
        Some((headroom / 2.0).min(MAX_EB_RELAXATION))
    }

    /// Telemetry-driven bound relaxation: when the observed deviation
    /// sits far inside the worst-case prediction, propose a larger
    /// compressor `eb` that would still have met the bound —
    /// `current_eb ×` [`AccuracyReport::relaxation_factor`]. `None`
    /// when the factor is (or the current bound makes relaxation)
    /// unsound.
    pub fn suggested_eb(&self, current_eb: f64) -> Option<f64> {
        if !(current_eb.is_finite() && current_eb > 0.0) {
            return None;
        }
        Some(current_eb * self.relaxation_factor()?)
    }
}

/// Evenly-strided deterministic sample of `len` indices (all of them
/// when `len ≤ MAX_SAMPLE`): the `floor(j·len/k)` chunk starts of
/// [`Chunks`] — the one boundary convention shared with the chunked
/// collectives. Strictly increasing, so every index is distinct.
fn sample_indices(len: usize) -> Vec<usize> {
    let k = len.min(MAX_SAMPLE);
    if k == 0 {
        return Vec::new();
    }
    let split = Chunks::new(len, k);
    (0..k).map(|j| split.start(j)).collect()
}

/// A pre-run probe: sampled indices plus their exact f64 reference.
#[derive(Debug, Clone)]
pub struct ErrorProbe {
    op: Op,
    nranks: usize,
    /// Global element index space of the op's output (see `observe`).
    domain_len: usize,
    indices: Vec<usize>,
    reference: Vec<f64>,
    /// max over samples of Σ_r |input_r| — the magnitude that bounds
    /// f32 reassociation error even under heavy cancellation.
    abs_sum_max: f64,
}

impl ErrorProbe {
    /// Build a probe from the collective's inputs, or `None` when the
    /// payloads are virtual / empty / shape-inconsistent (no telemetry).
    pub fn prepare(op: Op, inputs: &[DeviceBuf], root: usize) -> Option<ErrorProbe> {
        let n = inputs.len();
        if n == 0 || root >= n {
            return None;
        }
        let mut abs_sum_max = 0f64;
        let (domain_len, indices, reference) = match op {
            Op::Allreduce | Op::ReduceScatter => {
                if inputs.iter().any(|b| b.is_virtual()) {
                    return None;
                }
                let d = inputs[0].elems();
                if d == 0 || inputs.iter().any(|b| b.elems() != d) {
                    return None;
                }
                let indices = sample_indices(d);
                let mut reference = Vec::with_capacity(indices.len());
                for &i in &indices {
                    let mut sum = 0f64;
                    let mut abs = 0f64;
                    for b in inputs {
                        let v = b.as_real()[i] as f64;
                        sum += v;
                        abs += v.abs();
                    }
                    abs_sum_max = abs_sum_max.max(abs);
                    reference.push(sum);
                }
                (d, indices, reference)
            }
            Op::Allgather => {
                if inputs.iter().any(|b| b.is_virtual()) {
                    return None;
                }
                let total: usize = inputs.iter().map(|b| b.elems()).sum();
                if total == 0 {
                    return None;
                }
                let mut offsets = Vec::with_capacity(n + 1);
                let mut acc = 0usize;
                offsets.push(0);
                for b in inputs {
                    acc += b.elems();
                    offsets.push(acc);
                }
                let indices = sample_indices(total);
                let mut reference = Vec::with_capacity(indices.len());
                let mut owner = 0usize;
                for &i in &indices {
                    while offsets[owner + 1] <= i {
                        owner += 1;
                    }
                    let v = inputs[owner].as_real()[i - offsets[owner]] as f64;
                    abs_sum_max = abs_sum_max.max(v.abs());
                    reference.push(v);
                }
                (total, indices, reference)
            }
            Op::Scatter | Op::Bcast => {
                let rootbuf = &inputs[root];
                if rootbuf.is_virtual() {
                    return None;
                }
                let d = rootbuf.elems();
                if d == 0 {
                    return None;
                }
                let indices = sample_indices(d);
                let mut reference = Vec::with_capacity(indices.len());
                for &i in &indices {
                    let v = rootbuf.as_real()[i] as f64;
                    abs_sum_max = abs_sum_max.max(v.abs());
                    reference.push(v);
                }
                (d, indices, reference)
            }
        };
        Some(ErrorProbe {
            op,
            nranks: n,
            domain_len,
            indices,
            reference,
            abs_sum_max,
        })
    }

    /// Compare the run's outputs against the pre-computed reference.
    /// `None` when any relevant output is virtual or shaped
    /// unexpectedly (telemetry silently stands down rather than
    /// mis-reporting).
    pub fn observe(&self, outputs: &[DeviceBuf]) -> Option<AccuracyObservation> {
        if outputs.len() != self.nranks {
            return None;
        }
        let mut max_dev = 0f64;
        match self.op {
            // Every rank holds the full vector at global indexing.
            Op::Allreduce | Op::Allgather | Op::Bcast => {
                for out in outputs {
                    let v = match out {
                        DeviceBuf::Real(v) => v,
                        DeviceBuf::Virtual(_) => return None,
                    };
                    for (j, &i) in self.indices.iter().enumerate() {
                        let got = *v.get(i)? as f64;
                        max_dev = max_dev.max((got - self.reference[j]).abs());
                    }
                }
            }
            // Rank r holds chunk r of the global vector.
            Op::ReduceScatter | Op::Scatter => {
                let chunks = Chunks::new(self.domain_len, self.nranks);
                for (j, &i) in self.indices.iter().enumerate() {
                    let r = chunks.owner_of(i);
                    let local = i - chunks.start(r);
                    let v = match &outputs[r] {
                        DeviceBuf::Real(v) => v,
                        DeviceBuf::Virtual(_) => return None,
                    };
                    let got = *v.get(local)? as f64;
                    max_dev = max_dev.max((got - self.reference[j]).abs());
                }
            }
        }
        // Slack: f32 reassociation of the up-to-n-term sums plus the
        // compressor's per-stage reconstruction rounding (≈4·ε·|value|
        // per hop, up to ~2n hops on the ring) — everything the f64
        // reference sees that is *not* quantization error.
        Some(AccuracyObservation {
            observed_max_err: max_dev,
            samples: self.indices.len(),
            fp_slack: self.abs_sum_max * (8.0 * self.nranks as f64 + 8.0) * f32::EPSILON as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::DeviceBuf;

    #[test]
    fn sample_is_deterministic_distinct_and_capped() {
        let s = sample_indices(10);
        assert_eq!(s, vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9]);
        let big = sample_indices(1_000_000);
        assert_eq!(big.len(), MAX_SAMPLE);
        assert!(big.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(big, sample_indices(1_000_000));
    }

    #[test]
    fn allreduce_probe_detects_deviation() {
        let inputs = vec![
            DeviceBuf::Real(vec![1.0, 2.0, 3.0]),
            DeviceBuf::Real(vec![1.0, 1.0, 1.0]),
        ];
        let probe = ErrorProbe::prepare(Op::Allreduce, &inputs, 0).unwrap();
        // Exact outputs → zero deviation.
        let exact = vec![
            DeviceBuf::Real(vec![2.0, 3.0, 4.0]),
            DeviceBuf::Real(vec![2.0, 3.0, 4.0]),
        ];
        let obs = probe.observe(&exact).unwrap();
        assert_eq!(obs.observed_max_err, 0.0);
        assert_eq!(obs.samples, 3);
        // Perturbed output → the max deviation across ranks/samples.
        let off = vec![
            DeviceBuf::Real(vec![2.0, 3.0, 4.5]),
            DeviceBuf::Real(vec![2.0, 3.25, 4.0]),
        ];
        let obs = probe.observe(&off).unwrap();
        assert!((obs.observed_max_err - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rooted_and_chunked_probes_map_indices() {
        // Scatter from root 1: outputs are chunks of the root vector.
        let full: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let inputs = vec![DeviceBuf::Real(vec![]), DeviceBuf::Real(full.clone())];
        let probe = ErrorProbe::prepare(Op::Scatter, &inputs, 1).unwrap();
        let outputs = vec![
            DeviceBuf::Real(full[0..5].to_vec()),
            DeviceBuf::Real(full[5..10].to_vec()),
        ];
        let obs = probe.observe(&outputs).unwrap();
        assert_eq!(obs.observed_max_err, 0.0);
        // Allgather: concatenation order is rank order.
        let ag_in = vec![
            DeviceBuf::Real(vec![1.0, 2.0]),
            DeviceBuf::Real(vec![3.0]),
        ];
        let ag_probe = ErrorProbe::prepare(Op::Allgather, &ag_in, 0).unwrap();
        let cat = DeviceBuf::Real(vec![1.0, 2.0, 3.0]);
        let obs = ag_probe.observe(&[cat.clone(), cat]).unwrap();
        assert_eq!(obs.observed_max_err, 0.0);
    }

    #[test]
    fn virtual_or_empty_payloads_stand_down() {
        assert!(ErrorProbe::prepare(Op::Allreduce, &[DeviceBuf::Virtual(8)], 0).is_none());
        assert!(ErrorProbe::prepare(Op::Allreduce, &[], 0).is_none());
        assert!(ErrorProbe::prepare(Op::Allreduce, &[DeviceBuf::Real(vec![])], 0).is_none());
        let probe = ErrorProbe::prepare(
            Op::Allreduce,
            &[DeviceBuf::Real(vec![1.0]), DeviceBuf::Real(vec![2.0])],
            0,
        )
        .unwrap();
        assert!(probe.observe(&[DeviceBuf::Virtual(1), DeviceBuf::Virtual(1)]).is_none());
    }

    #[test]
    fn within_bound_semantics() {
        let mk = |prediction, observed| AccuracyReport {
            prediction,
            observed_max_err: observed,
            samples: 10,
            fp_slack: 1e-9,
        };
        assert_eq!(mk(ErrorPrediction::Bounded(1e-3), 5e-4).within_bound(), Some(true));
        assert_eq!(mk(ErrorPrediction::Bounded(1e-3), 2e-3).within_bound(), Some(false));
        assert_eq!(mk(ErrorPrediction::Exact, 0.0).within_bound(), Some(true));
        assert_eq!(mk(ErrorPrediction::Unbounded, 42.0).within_bound(), None);
    }

    #[test]
    fn relaxation_vs_budget_is_budget_anchored() {
        let mk = |prediction, observed| AccuracyReport {
            prediction,
            observed_max_err: observed,
            samples: 10,
            fp_slack: 0.0,
        };
        // Observed sitting AT the prediction still relaxes against a
        // wider per-call budget (headroom 7 → 3.5×)…
        let r = mk(ErrorPrediction::Bounded(1e-3), 1e-3);
        assert_eq!(r.relaxation_factor(), None, "prediction-relative: tight");
        assert!((r.relaxation_factor_vs(7e-3).unwrap() - 3.5).abs() < 1e-12);
        // …and the half-held-back reserve stops the loop at half the
        // budget (headroom exactly 2).
        assert_eq!(mk(ErrorPrediction::Bounded(1e-2), 3.5e-3).relaxation_factor_vs(7e-3), None);
        // Unbounded streams and degenerate budgets never relax.
        assert_eq!(
            mk(ErrorPrediction::Unbounded, 1e-9).relaxation_factor_vs(7e-3),
            None
        );
        assert_eq!(r.relaxation_factor_vs(0.0), None);
        assert_eq!(r.relaxation_factor_vs(f64::NAN), None);
    }

    #[test]
    fn suggested_eb_proposes_from_headroom() {
        let mk = |prediction, observed| AccuracyReport {
            prediction,
            observed_max_err: observed,
            samples: 10,
            fp_slack: 1e-9,
        };
        // 100× headroom → relax by min(100/2, 8) = the 8× cap.
        let r = mk(ErrorPrediction::Bounded(1e-2), 1e-4);
        assert!((r.suggested_eb(1e-4).unwrap() - 8e-4).abs() < 1e-15);
        // 5× headroom → relax by exactly 2.5× (half the headroom in
        // reserve; the raw observation is used — no fp_slack discount).
        let r = mk(ErrorPrediction::Bounded(5e-3), 1e-3);
        assert!((r.suggested_eb(1e-4).unwrap() - 2.5e-4).abs() < 1e-15);
        assert_eq!(r.relaxation_factor(), Some(2.5));
        // Near-tight observations (≤ 2× headroom) propose nothing.
        assert_eq!(mk(ErrorPrediction::Bounded(1e-3), 6e-4).suggested_eb(1e-4), None);
        // Zero observed deviation: cap applies (no infinite proposal).
        let r = mk(ErrorPrediction::Bounded(1e-3), 0.0);
        assert!((r.suggested_eb(1e-4).unwrap() - 8e-4).abs() < 1e-15);
        // Unbounded / exact predictions and degenerate ebs: nothing.
        assert_eq!(mk(ErrorPrediction::Unbounded, 1e-4).suggested_eb(1e-4), None);
        assert_eq!(mk(ErrorPrediction::Exact, 0.0).suggested_eb(1e-4), None);
        assert_eq!(mk(ErrorPrediction::Bounded(1e-2), 1e-4).suggested_eb(0.0), None);
    }
}
