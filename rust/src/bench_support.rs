//! Minimal benchmark harness (criterion is not in the vendored set).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that
//! regenerates one of the paper's tables/figures and reports wall-clock
//! statistics for the regeneration itself.

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    /// Number of measured runs.
    pub runs: usize,
    /// Mean seconds.
    pub mean: f64,
    /// Standard deviation (seconds).
    pub stddev: f64,
    /// Fastest run (seconds).
    pub min: f64,
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} runs: mean {:.3}s ± {:.3}s (min {:.3}s)",
            self.runs, self.mean, self.stddev, self.min
        )
    }
}

/// Run `f` once as warmup, then `runs` measured times.
pub fn bench<R>(runs: usize, mut f: impl FnMut() -> R) -> (R, Stats) {
    let warm = f();
    let mut samples = Vec::with_capacity(runs);
    let mut last = warm;
    for _ in 0..runs {
        let t0 = Instant::now();
        last = f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    (
        last,
        Stats {
            runs,
            mean,
            stddev: var.sqrt(),
            min,
        },
    )
}

/// Throughput helper: bytes processed per wall second.
pub fn throughput_gbps(bytes: usize, secs: f64) -> f64 {
    bytes as f64 / secs / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_result_and_stats() {
        let (r, s) = bench(3, || 41 + 1);
        assert_eq!(r, 42);
        assert_eq!(s.runs, 3);
        assert!(s.mean >= 0.0 && s.min <= s.mean + 1e-12);
    }

    #[test]
    fn throughput_math() {
        assert!((throughput_gbps(2_000_000_000, 2.0) - 1.0).abs() < 1e-12);
    }
}
