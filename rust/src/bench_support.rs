//! Minimal benchmark harness (criterion is not in the vendored set).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that
//! regenerates one of the paper's tables/figures and reports wall-clock
//! statistics for the regeneration itself.

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    /// Number of measured runs.
    pub runs: usize,
    /// Mean seconds.
    pub mean: f64,
    /// Standard deviation (seconds).
    pub stddev: f64,
    /// Fastest run (seconds).
    pub min: f64,
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} runs: mean {:.3}s ± {:.3}s (min {:.3}s)",
            self.runs, self.mean, self.stddev, self.min
        )
    }
}

/// Run `f` once as warmup, then `runs` measured times.
pub fn bench<R>(runs: usize, mut f: impl FnMut() -> R) -> (R, Stats) {
    let warm = f();
    let mut samples = Vec::with_capacity(runs);
    let mut last = warm;
    for _ in 0..runs {
        let t0 = Instant::now();
        last = f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    (
        last,
        Stats {
            runs,
            mean,
            stddev: var.sqrt(),
            min,
        },
    )
}

/// Throughput helper: bytes processed per wall second.
pub fn throughput_gbps(bytes: usize, secs: f64) -> f64 {
    bytes as f64 / secs / 1e9
}

/// Version of the `BENCH_*.json` artifact layout. Bump when the
/// top-level shape changes; the trend script
/// (`.github/scripts/bench_trend.py`) tolerates artifacts both with
/// and without the stamp, so old archived artifacts keep loading.
pub const BENCH_SCHEMA_VERSION: u32 = 1;

/// `git describe --always --dirty` for the tree the bench ran from, or
/// `"unknown"` when git (or the repository) is unavailable — bench
/// artifacts must still be writable from an exported tarball.
pub fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// The provenance stamp every `BENCH_*.json` artifact carries at its
/// top level: `"schema_version": N, "git": "<describe>"` (no braces,
/// no trailing comma — splice it into the artifact's header).
pub fn schema_stamp() -> String {
    format!(
        "\"schema_version\": {BENCH_SCHEMA_VERSION}, \"git\": \"{}\"",
        git_describe().replace('\\', "_").replace('"', "_")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_result_and_stats() {
        let (r, s) = bench(3, || 41 + 1);
        assert_eq!(r, 42);
        assert_eq!(s.runs, 3);
        assert!(s.mean >= 0.0 && s.min <= s.mean + 1e-12);
    }

    #[test]
    fn throughput_math() {
        assert!((throughput_gbps(2_000_000_000, 2.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn schema_stamp_is_splicable_json() {
        let stamp = schema_stamp();
        assert!(stamp.starts_with("\"schema_version\": "));
        assert!(stamp.contains("\"git\": \""));
        // Splicing into an object header must yield valid JSON: the
        // stamp itself carries no braces and no trailing comma.
        assert!(!stamp.contains('{') && !stamp.contains('}'));
        assert!(!stamp.ends_with(','));
        // The git field never breaks out of its string literal.
        let git = stamp.split("\"git\": \"").nth(1).unwrap();
        assert!(git.ends_with('"'));
        assert!(!git[..git.len() - 1].contains('"'));
    }
}
