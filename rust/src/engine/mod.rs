//! Event-driven execution engine: ranks as resumable state machines.
//!
//! The thread backend burns one OS thread (and its stack) per rank,
//! which caps simulations near 512 ranks. This engine replaces
//! threads with the futures the [`crate::coordinator::Program`] trait
//! already produces: each rank's collective program is a state
//! machine whose only suspension point is `recv`, driven by a single
//! scheduler that pops the earliest pending event (a message arrival
//! in virtual time) and advances the one rank it unblocks. Memory and
//! wall time scale with the number of *events* (messages), not with
//! ranks × thread-stack — a 16384-rank hierarchical Allreduce is a
//! few tens of thousands of message events.
//!
//! Determinism and equivalence with the thread oracle rest on two
//! invariants, property-tested in `tests/engine.rs`:
//!
//! 1. The payload dataflow of every collective is timing-independent —
//!    what a rank sends never depends on *when* its inputs arrived, so
//!    any scheduling order produces bit-identical buffers.
//! 2. The fabric's interval timelines allocate the earliest free gap
//!    and are insensitive to reservation order (up to ties), so the
//!    engine's virtual arrival times — and hence makespans — equal the
//!    thread backend's even though reservations happen in a different
//!    wall-clock order.
//!
//! The [`tenant`] submodule layers multi-tenancy on top: N
//! communicators window onto one physical fabric ([`crate::net::FabricSlice`])
//! and contend on its NIC/uplink timelines inside one scheduler.

mod tenant;

pub use tenant::{run_multi_tenant, MultiTenantReport, Tenant, TenantReport};

use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll};

use crate::coordinator::buffer::DeviceBuf;
use crate::coordinator::ctx::{Port, RankCtx};
use crate::coordinator::mailbox::Msg;
use crate::coordinator::program::{noop_waker, Program};
use crate::coordinator::runner::{merge_outcomes, ClusterSpec, RankOutcome, RunReport};
use crate::error::{Error, Result};
use crate::gpu::GpuDevice;
use crate::net::{Fabric, FabricSlice};
use crate::sim::VirtTime;

/// The engine's shared message store — the event-mode replacement for
/// the thread backend's N×N channel mesh. Messages are keyed by
/// `(destination actor, logical source rank, tag)` with FIFO order per
/// key, mirroring the mailbox's MPI-style non-overtaking matching
/// exactly. Actor ids are globally unique across tenants (each tenant
/// addresses its peers relative to its own actor base), so tenants
/// sharing one store can never cross-deliver.
#[derive(Default)]
pub struct MsgStore {
    /// Delivered-but-unconsumed messages, FIFO per (dst, src, tag).
    held: HashMap<(usize, usize, u64), VecDeque<Msg>>,
    /// Actors suspended in `recv`, with the (src, tag) they await. A
    /// sequential rank program awaits at most one receive at a time.
    waiting: HashMap<usize, (usize, u64)>,
    /// Actors unblocked since the scheduler last drained: (actor,
    /// virtual arrival time of the message that woke it).
    woken: Vec<(usize, VirtTime)>,
}

/// One rank's handle into the [`MsgStore`]: its own global actor id
/// plus the actor-id base of its communicator (logical peer rank `r`
/// lives at actor `peer_base + r`).
pub struct EventPort {
    actor: usize,
    peer_base: usize,
    store: Arc<Mutex<MsgStore>>,
}

impl EventPort {
    /// Deposit `msg` for logical peer `to`; wake it if it is suspended
    /// on exactly this (src, tag).
    pub(crate) fn send(&self, to: usize, msg: Msg) {
        let dst = self.peer_base + to;
        let src = msg.src;
        let tag = msg.tag;
        let arrival = msg.arrival;
        let mut st = self.store.lock().expect("message store poisoned");
        st.held.entry((dst, src, tag)).or_default().push_back(msg);
        if st.waiting.get(&dst) == Some(&(src, tag)) {
            st.waiting.remove(&dst);
            st.woken.push((dst, arrival));
        }
    }

    /// A future resolving to the next message from logical rank `from`
    /// with `tag` — the engine's (sole) suspension point.
    pub(crate) fn recv(&self, from: usize, tag: u64) -> EventRecv {
        EventRecv {
            store: Arc::clone(&self.store),
            actor: self.actor,
            from,
            tag,
        }
    }
}

/// See [`EventPort::recv`].
pub(crate) struct EventRecv {
    store: Arc<Mutex<MsgStore>>,
    actor: usize,
    from: usize,
    tag: u64,
}

impl Future for EventRecv {
    type Output = Msg;

    fn poll(self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<Msg> {
        let key = (self.actor, self.from, self.tag);
        let mut st = self.store.lock().expect("message store poisoned");
        if let Some(q) = st.held.get_mut(&key) {
            if let Some(msg) = q.pop_front() {
                return Poll::Ready(msg);
            }
        }
        st.waiting.insert(self.actor, (self.from, self.tag));
        Poll::Pending
    }
}

/// One rank's whole execution as a future: owns its context, borrows
/// only the program.
pub(crate) type ActorFut<'p> = Pin<Box<dyn Future<Output = Result<RankOutcome>> + 'p>>;

/// Build the actor future for one rank: context construction plus the
/// program run and outcome capture.
#[allow(clippy::too_many_arguments)]
pub(crate) fn spawn_actor<'p, P: Program + ?Sized>(
    spec: &ClusterSpec,
    slice: &FabricSlice,
    store: &Arc<Mutex<MsgStore>>,
    peer_base: usize,
    rank: usize,
    nranks: usize,
    input: DeviceBuf,
    program: &'p P,
) -> ActorFut<'p> {
    let gpu = GpuDevice::new(spec.gpu, spec.streams_per_rank);
    let port = Port::Event(EventPort {
        actor: peer_base + rank,
        peer_base,
        store: Arc::clone(store),
    });
    let mut ctx = RankCtx::new(
        rank,
        nranks,
        spec.policy,
        gpu,
        slice.clone(),
        port,
        spec.make_compressor(),
        spec.profile.clone(),
    );
    if let Some(tr) = &spec.trace {
        // Track = global actor id, so multi-tenant runs get one track
        // per actor and single-tenant runs get track == rank — the
        // same ids (and hence the same span tree) as the thread oracle.
        ctx.set_tracer(tr, peer_base + rank);
    }
    Box::pin(async move {
        let out = program.run(&mut ctx, input).await?;
        let finish = ctx.finish();
        let legs = ctx.leg_errors().to_vec();
        let warns = ctx.leg_warnings().to_vec();
        Ok((out, finish, ctx.breakdown(), ctx.counters(), legs, warns))
    })
}

/// A scheduler event: actor `actor` is runnable at virtual time `t`.
/// Ordered so the [`BinaryHeap`] (a max-heap) pops the *earliest* time,
/// ties broken by the lowest actor id — a total, deterministic order.
struct Ready {
    t: VirtTime,
    actor: usize,
}

impl PartialEq for Ready {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Ready {}

impl PartialOrd for Ready {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ready {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .t
            .as_secs()
            .total_cmp(&self.t.as_secs())
            .then_with(|| other.actor.cmp(&self.actor))
    }
}

/// The event loop: seed every actor at time zero, then repeatedly pop
/// the earliest runnable actor, advance it until it completes or
/// suspends in `recv`, and requeue whichever actors its sends woke.
/// Returns per-actor outcomes; `None` marks an actor that never
/// completed (a deadlock, or starvation behind a failed peer).
pub(crate) fn drive<'p>(
    actors: Vec<ActorFut<'p>>,
    store: &Arc<Mutex<MsgStore>>,
) -> Vec<Option<Result<RankOutcome>>> {
    let n = actors.len();
    let mut slots: Vec<Option<ActorFut<'p>>> = actors.into_iter().map(Some).collect();
    let mut outcomes: Vec<Option<Result<RankOutcome>>> = (0..n).map(|_| None).collect();
    let mut heap: BinaryHeap<Ready> = BinaryHeap::with_capacity(n);
    for actor in 0..n {
        heap.push(Ready {
            t: VirtTime::ZERO,
            actor,
        });
    }
    let waker = noop_waker();
    let mut cx = Context::from_waker(&waker);
    while let Some(Ready { actor, .. }) = heap.pop() {
        if let Some(fut) = slots[actor].as_mut() {
            if let Poll::Ready(res) = fut.as_mut().poll(&mut cx) {
                outcomes[actor] = Some(res);
                slots[actor] = None;
            }
            let woken = {
                let mut st = store.lock().expect("message store poisoned");
                std::mem::take(&mut st.woken)
            };
            for (a, t) in woken {
                heap.push(Ready { t, actor: a });
            }
        }
    }
    outcomes
}

/// Per-actor wait diagnostics for a deadlocked run: which (src, tag)
/// each suspended actor is blocked on, in actor order.
fn deadlock_detail(store: &Arc<Mutex<MsgStore>>) -> String {
    let st = store.lock().expect("message store poisoned");
    let mut waits: Vec<(usize, (usize, u64))> =
        st.waiting.iter().map(|(a, w)| (*a, *w)).collect();
    waits.sort();
    waits
        .iter()
        .map(|(a, (src, tag))| format!("actor {a} awaits (src {src}, tag {tag})"))
        .collect::<Vec<_>>()
        .join("; ")
}

/// Turn raw drive outcomes into a merged report, surfacing deadlocks
/// (and the rank errors that caused them) as typed coordinator errors
/// enriched with per-actor wait diagnostics; a traced deadlock also
/// lands as a `deadlock` instant in the flight recorder.
pub(crate) fn collect(
    outcomes: Vec<Option<Result<RankOutcome>>>,
    store: &Arc<Mutex<MsgStore>>,
    trace: Option<&crate::obs::Tracer>,
) -> Result<RunReport> {
    let n = outcomes.len();
    let stuck = outcomes.iter().filter(|o| o.is_none()).count();
    if stuck > 0 {
        // A rank that failed early starves its peers; its error is the
        // root cause, so report it rather than the generic deadlock.
        for o in outcomes.into_iter().flatten() {
            if let Err(e) = o {
                return Err(e);
            }
        }
        let detail = deadlock_detail(store);
        if let Some(tr) = trace {
            tr.instant(
                "deadlock",
                0.0,
                vec![("stuck", stuck.to_string()), ("waits", detail.clone())],
            );
        }
        return Err(Error::coordinator(format!(
            "event engine deadlock: {stuck} of {n} ranks suspended in recv \
             with no matching send in flight ({detail})"
        )));
    }
    merge_outcomes(
        outcomes
            .into_iter()
            .map(|o| o.expect("no outcome is stuck"))
            .collect(),
    )
}

/// Run `program` on every rank of `spec`'s cluster under the event
/// engine. Same contract (and, property-tested, same payloads and
/// makespan) as the thread backend.
pub fn run_events<P: Program + ?Sized>(
    spec: &ClusterSpec,
    inputs: Vec<DeviceBuf>,
    program: &P,
) -> Result<RunReport> {
    let n = spec.topo.ranks();
    if inputs.len() != n {
        return Err(Error::coordinator(format!(
            "inputs.len()={} != ranks={}",
            inputs.len(),
            n
        )));
    }
    let fabric = Fabric::tiered(
        spec.tiers.clone(),
        spec.intranode,
        spec.internode,
        spec.uplinks.clone(),
    );
    let slice = FabricSlice::whole(fabric);
    let store = Arc::new(Mutex::new(MsgStore::default()));
    let actors: Vec<ActorFut<'_>> = inputs
        .into_iter()
        .enumerate()
        .map(|(rank, input)| spawn_actor(spec, &slice, &store, 0, rank, n, input, program))
        .collect();
    let outcomes = drive(actors, &store);
    collect(outcomes, &store, spec.trace.as_ref())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::mailbox::Payload;
    use crate::coordinator::program::ProgFut;
    use crate::coordinator::{ExecPolicy, RankCtx};

    fn msg(src: usize, tag: u64, at: f64) -> Msg {
        Msg {
            src,
            tag,
            payload: Payload::Meta(vec![tag]),
            arrival: VirtTime::secs(at),
        }
    }

    fn store_port(actor: usize, peer_base: usize, store: &Arc<Mutex<MsgStore>>) -> EventPort {
        EventPort {
            actor,
            peer_base,
            store: Arc::clone(store),
        }
    }

    #[test]
    fn store_is_fifo_per_src_tag() {
        let store = Arc::new(Mutex::new(MsgStore::default()));
        let port = store_port(0, 0, &store);
        port.send(1, msg(0, 7, 0.1));
        port.send(1, msg(0, 7, 0.2));
        let rx = store_port(1, 0, &store);
        let m1 = crate::coordinator::program::block_on(rx.recv(0, 7));
        let m2 = crate::coordinator::program::block_on(rx.recv(0, 7));
        assert_eq!(m1.arrival, VirtTime::secs(0.1));
        assert_eq!(m2.arrival, VirtTime::secs(0.2));
    }

    #[test]
    fn send_wakes_exactly_the_matching_waiter() {
        let store = Arc::new(Mutex::new(MsgStore::default()));
        // Actor 1 waits on (src 0, tag 5).
        store
            .lock()
            .unwrap()
            .waiting
            .insert(1, (0, 5));
        let port = store_port(0, 0, &store);
        // Non-matching tag: held, no wake.
        port.send(1, msg(0, 6, 0.3));
        assert!(store.lock().unwrap().woken.is_empty());
        // Matching: exactly one wake at the arrival time.
        port.send(1, msg(0, 5, 0.4));
        {
            let st = store.lock().unwrap();
            assert_eq!(st.woken, vec![(1, VirtTime::secs(0.4))]);
            assert!(st.waiting.is_empty());
        }
        // A second matching send does not wake again (no waiter left).
        port.send(1, msg(0, 5, 0.5));
        assert_eq!(store.lock().unwrap().woken.len(), 1);
    }

    #[test]
    fn peer_base_isolates_tenants() {
        let store = Arc::new(Mutex::new(MsgStore::default()));
        // Two 2-rank tenants: actors 0-1 and 2-3. Both tenant-logical
        // rank 0s send to their logical rank 1 with the same tag.
        let a = store_port(0, 0, &store);
        let b = store_port(2, 2, &store);
        a.send(1, msg(0, 9, 0.1));
        b.send(1, msg(0, 9, 0.2));
        let rx_a = store_port(1, 0, &store);
        let rx_b = store_port(3, 2, &store);
        let got_b = crate::coordinator::program::block_on(rx_b.recv(0, 9));
        let got_a = crate::coordinator::program::block_on(rx_a.recv(0, 9));
        assert_eq!(got_a.arrival, VirtTime::secs(0.1));
        assert_eq!(got_b.arrival, VirtTime::secs(0.2));
    }

    #[test]
    fn deadlock_is_a_typed_error() {
        fn never(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
            Box::pin(async move {
                if ctx.rank() == 0 {
                    // Waits for a message nobody sends.
                    ctx.recv_raw(1, 99).await;
                }
                Ok(input)
            })
        }
        let spec = ClusterSpec::new(2, ExecPolicy::nccl());
        let inputs: Vec<DeviceBuf> = (0..2).map(|_| DeviceBuf::Virtual(8)).collect();
        let err = run_events(&spec, inputs, &never).unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
    }

    #[test]
    fn ready_orders_by_time_then_actor() {
        let mut heap = BinaryHeap::new();
        heap.push(Ready {
            t: VirtTime::secs(2.0),
            actor: 0,
        });
        heap.push(Ready {
            t: VirtTime::secs(1.0),
            actor: 5,
        });
        heap.push(Ready {
            t: VirtTime::secs(1.0),
            actor: 3,
        });
        let order: Vec<(f64, usize)> = std::iter::from_fn(|| heap.pop())
            .map(|r| (r.t.as_secs(), r.actor))
            .collect();
        assert_eq!(order, vec![(1.0, 3), (1.0, 5), (2.0, 0)]);
    }
}
