//! Multi-tenant execution: N communicators sharing one physical
//! fabric.
//!
//! Each [`Tenant`] is a communicator (its own logical tier tree,
//! policy, compressor, program, inputs) windowed onto a contiguous
//! range of the physical cluster's leaves. All tenants' ranks run as
//! actors in *one* event scheduler over *one* [`Fabric`], so their
//! messages reserve the same NIC and uplink timelines — cross-tenant
//! contention emerges exactly where their traffic shares physical
//! links, with no extra modeling. Per-tenant isolated re-runs on a
//! fresh fabric quantify the interference: the report carries each
//! tenant's contended and isolated makespans, the slowdown ratio, and
//! the Jain fairness index across tenants.

use std::sync::{Arc, Mutex};

use crate::coordinator::buffer::DeviceBuf;
use crate::coordinator::program::RankProgram;
use crate::coordinator::runner::{ClusterSpec, RankOutcome, RunReport};
use crate::error::{Error, Result};
use crate::net::{Fabric, FabricSlice};
use crate::sim::VirtTime;

use super::{collect, drive, spawn_actor, ActorFut, MsgStore};

/// One communicator in a multi-tenant run.
pub struct Tenant {
    /// Display name (reports, errors).
    pub name: String,
    /// The tenant's *logical* cluster: tier tree, policy, compressor
    /// settings, profile. Its link models are ignored — delivery goes
    /// through the shared physical fabric.
    pub spec: ClusterSpec,
    /// First physical leaf of the tenant's window: logical rank `r`
    /// occupies physical leaf `base + r`.
    pub base: usize,
    /// Per-rank input buffers (`spec.topo.ranks()` of them).
    pub inputs: Vec<DeviceBuf>,
    /// The collective every rank of this tenant executes.
    pub program: Box<RankProgram>,
}

/// Per-tenant outcome of a multi-tenant run.
#[derive(Debug, Clone)]
pub struct TenantReport {
    /// Tenant name.
    pub name: String,
    /// Makespan under contention (all tenants sharing the fabric).
    pub makespan: VirtTime,
    /// Makespan of the same collective alone on a fresh fabric.
    pub isolated_makespan: VirtTime,
    /// `makespan / isolated_makespan` (≥ 1 under contention).
    pub slowdown: f64,
    /// Full run report of the contended run.
    pub report: RunReport,
}

/// Outcome of [`run_multi_tenant`].
#[derive(Debug, Clone)]
pub struct MultiTenantReport {
    /// Per-tenant reports, in input order.
    pub tenants: Vec<TenantReport>,
    /// Jain fairness index over normalized service rates
    /// `x_i = isolated_i / contended_i`: `(Σx)² / (N·Σx²)`, 1.0 when
    /// contention degrades every tenant equally, → 1/N when one tenant
    /// monopolizes the fabric.
    pub fairness: f64,
}

fn physical_fabric(physical: &ClusterSpec) -> Fabric {
    Fabric::tiered(
        physical.tiers.clone(),
        physical.intranode,
        physical.internode,
        physical.uplinks.clone(),
    )
}

fn validate(physical: &ClusterSpec, tenants: &[Tenant]) -> Result<()> {
    if tenants.is_empty() {
        return Err(Error::coordinator("multi-tenant run with no tenants"));
    }
    let phys = physical.topo.ranks();
    for t in tenants {
        let n = t.spec.topo.ranks();
        if t.inputs.len() != n {
            return Err(Error::coordinator(format!(
                "tenant {}: {} inputs for {} ranks",
                t.name,
                t.inputs.len(),
                n
            )));
        }
        if t.base + n > phys {
            return Err(Error::coordinator(format!(
                "tenant {}: window [{}, {}) exceeds physical fabric of {} ranks",
                t.name,
                t.base,
                t.base + n,
                phys
            )));
        }
    }
    let mut windows: Vec<(usize, usize, &str)> = tenants
        .iter()
        .map(|t| (t.base, t.base + t.spec.topo.ranks(), t.name.as_str()))
        .collect();
    windows.sort();
    for w in windows.windows(2) {
        if w[1].0 < w[0].1 {
            return Err(Error::coordinator(format!(
                "tenant windows overlap: {} [{}, {}) and {} [{}, {})",
                w[0].2, w[0].0, w[0].1, w[1].2, w[1].0, w[1].1
            )));
        }
    }
    Ok(())
}

/// Run every tenant's collective concurrently on one shared physical
/// fabric (described by `physical` — its tier tree and link models),
/// then each tenant alone on a fresh fabric, and report contended vs
/// isolated makespans, per-tenant slowdowns, and the Jain fairness
/// index.
pub fn run_multi_tenant(
    physical: &ClusterSpec,
    mut tenants: Vec<Tenant>,
) -> Result<MultiTenantReport> {
    validate(physical, &tenants)?;

    // Take the inputs out now: one copy feeds the contended run, one
    // the isolated re-runs.
    let shared_inputs: Vec<Vec<DeviceBuf>> = tenants
        .iter_mut()
        .map(|t| std::mem::take(&mut t.inputs))
        .collect();
    let iso_inputs: Vec<Vec<DeviceBuf>> = shared_inputs.clone();

    // Contended run: all tenants' actors in one scheduler, one fabric.
    let fabric = physical_fabric(physical);
    let store = Arc::new(Mutex::new(MsgStore::default()));
    let mut actors: Vec<ActorFut<'_>> = Vec::new();
    let mut actor_base = 0;
    for (t, inputs) in tenants.iter().zip(shared_inputs) {
        let n = t.spec.topo.ranks();
        let slice = FabricSlice::window(fabric.clone(), t.base, t.spec.tiers.clone());
        if let Some(tr) = &t.spec.trace {
            // Tracks `actor_base..actor_base + n` display as
            // `<tenant>/<logical rank>` in the exported trace.
            tr.label_tracks(actor_base, n, &t.name);
        }
        for (rank, input) in inputs.into_iter().enumerate() {
            actors.push(spawn_actor(
                &t.spec,
                &slice,
                &store,
                actor_base,
                rank,
                n,
                input,
                &*t.program,
            ));
        }
        actor_base += n;
    }
    let mut outcomes = drive(actors, &store).into_iter();
    let mut contended: Vec<RunReport> = Vec::with_capacity(tenants.len());
    for t in &tenants {
        let n = t.spec.topo.ranks();
        let chunk: Vec<Option<Result<RankOutcome>>> = outcomes.by_ref().take(n).collect();
        contended.push(collect(chunk, &store, t.spec.trace.as_ref())?);
    }

    // Isolated baselines: same window, fresh fabric, no neighbors.
    // Tracing is stripped so only the contended timeline records —
    // the baselines would otherwise overwrite the shared tracks.
    let mut reports = Vec::with_capacity(tenants.len());
    for ((t, inputs), shared) in tenants.iter().zip(iso_inputs).zip(contended) {
        let fabric = physical_fabric(physical);
        let slice = FabricSlice::window(fabric, t.base, t.spec.tiers.clone());
        let store = Arc::new(Mutex::new(MsgStore::default()));
        let n = t.spec.topo.ranks();
        let mut iso_spec = t.spec.clone();
        iso_spec.trace = None;
        let actors: Vec<ActorFut<'_>> = inputs
            .into_iter()
            .enumerate()
            .map(|(rank, input)| {
                spawn_actor(&iso_spec, &slice, &store, 0, rank, n, input, &*t.program)
            })
            .collect();
        let isolated = collect(drive(actors, &store), &store, None)?;
        let iso_s = isolated.makespan.as_secs();
        let shared_s = shared.makespan.as_secs();
        let slowdown = if iso_s > 0.0 { shared_s / iso_s } else { 1.0 };
        reports.push(TenantReport {
            name: t.name.clone(),
            makespan: shared.makespan,
            isolated_makespan: isolated.makespan,
            slowdown,
            report: shared,
        });
    }

    // Jain fairness over normalized service rates.
    let xs: Vec<f64> = reports
        .iter()
        .map(|r| {
            let shared = r.makespan.as_secs();
            let iso = r.isolated_makespan.as_secs();
            if shared > 0.0 {
                iso / shared
            } else {
                1.0
            }
        })
        .collect();
    let sum: f64 = xs.iter().sum();
    let sumsq: f64 = xs.iter().map(|x| x * x).sum();
    let fairness = if sumsq > 0.0 {
        sum * sum / (xs.len() as f64 * sumsq)
    } else {
        1.0
    };

    for (t, r) in tenants.iter().zip(&reports) {
        if let Some(tr) = &t.spec.trace {
            tr.gauge("fairness.jain", fairness);
            tr.gauge(&format!("slowdown.{}", r.name), r.slowdown);
        }
    }

    Ok(MultiTenantReport {
        tenants: reports,
        fairness,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::program::ProgFut;
    use crate::coordinator::{ExecPolicy, RankCtx};
    use crate::topo::TierTree;

    fn ident_boxed() -> Box<RankProgram> {
        fn ident(_ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
            Box::pin(async move { Ok(input) })
        }
        Box::new(ident)
    }

    fn tenant(name: &str, base: usize, ranks: usize) -> Tenant {
        let tree = TierTree::new(ranks, &[2, ranks / 2]).unwrap();
        Tenant {
            name: name.to_string(),
            spec: ClusterSpec::with_tiers(tree, ExecPolicy::nccl()),
            base,
            inputs: (0..ranks).map(|_| DeviceBuf::Virtual(64)).collect(),
            program: ident_boxed(),
        }
    }

    fn physical(ranks: usize) -> ClusterSpec {
        ClusterSpec::with_tiers(TierTree::new(ranks, &[2, ranks / 2]).unwrap(), ExecPolicy::nccl())
    }

    #[test]
    fn overlapping_windows_rejected() {
        let err = run_multi_tenant(&physical(16), vec![tenant("a", 0, 8), tenant("b", 4, 8)])
            .unwrap_err();
        assert!(err.to_string().contains("overlap"), "{err}");
    }

    #[test]
    fn window_must_fit_physical() {
        let err =
            run_multi_tenant(&physical(8), vec![tenant("a", 4, 8)]).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
    }

    #[test]
    fn input_count_must_match_ranks() {
        let mut t = tenant("a", 0, 8);
        t.inputs.pop();
        let err = run_multi_tenant(&physical(8), vec![t]).unwrap_err();
        assert!(err.to_string().contains("inputs"), "{err}");
    }

    #[test]
    fn identity_tenants_report_unit_fairness() {
        let rep = run_multi_tenant(&physical(16), vec![tenant("a", 0, 8), tenant("b", 8, 8)])
            .unwrap();
        assert_eq!(rep.tenants.len(), 2);
        for t in &rep.tenants {
            assert_eq!(t.makespan, VirtTime::ZERO);
            assert!((t.slowdown - 1.0).abs() < 1e-12);
        }
        assert!((rep.fairness - 1.0).abs() < 1e-12);
    }
}
