//! Scatter experiments: Figs. 8, 11, 12.
//!
//! Dispatched through the [`Communicator`]; Scatter has a single
//! binomial-tree algorithm, so `CollectiveSpec::auto()` is exact.

use crate::comm::{CollectiveSpec, Communicator};
use crate::coordinator::ExecPolicy;
use crate::error::Result;
use crate::metrics::table::{fmt_time, fmt_x};
use crate::metrics::Table;

use super::{rtm_profile, virtual_root_inputs, Dataset, FULL_DATASET_BYTES, GPU_COUNTS, MSG_SIZES_MB};

fn run_scatter(ranks: usize, bytes: usize, policy: ExecPolicy, eb: f64) -> Result<f64> {
    let comm = Communicator::builder(ranks)
        .policy(policy)
        .error_bound(eb)
        .compression_profile(rtm_profile(Dataset::Rtm2, eb))
        .build()?;
    let report = comm.scatter(virtual_root_inputs(ranks, bytes), &CollectiveSpec::auto())?;
    Ok(report.makespan.as_secs())
}

/// **Fig. 8** — gZ-Scatter vs the unoptimized GPU-centric scatter
/// (sequential root compression, no multi-stream/overlap/packing).
pub fn fig08_scatter_opt(ranks: usize) -> Result<Table> {
    let mut t = Table::new(
        format!("Fig 8: gZ-Scatter optimization gains ({} GPUs)", ranks),
        &["size", "gpu-centric", "gZ-Scatter", "speedup"],
    );
    for &mb in &MSG_SIZES_MB {
        let bytes = mb << 20;
        let base = run_scatter(ranks, bytes, ExecPolicy::gpu_centric_unoptimized(), 1e-4)?;
        let gz = run_scatter(ranks, bytes, ExecPolicy::gzccl(), 1e-4)?;
        t.row(&[
            format!("{mb} MB"),
            fmt_time(base),
            fmt_time(gz),
            fmt_x(base / gz),
        ]);
    }
    Ok(t)
}

/// **Fig. 11** — gZ-Scatter vs Cray MPI across message sizes (NCCL has
/// no Scatter).
pub fn fig11_scatter_msgsize(ranks: usize) -> Result<Table> {
    let mut t = Table::new(
        format!("Fig 11: Scatter vs Cray MPI ({} GPUs)", ranks),
        &["size", "Cray MPI", "gZ-Scatter", "speedup"],
    );
    for &mb in &MSG_SIZES_MB {
        let bytes = mb << 20;
        let cray = run_scatter(ranks, bytes, ExecPolicy::cray_mpi(), 1e-4)?;
        let gz = run_scatter(ranks, bytes, ExecPolicy::gzccl(), 1e-4)?;
        t.row(&[
            format!("{mb} MB"),
            fmt_time(cray),
            fmt_time(gz),
            fmt_x(cray / gz),
        ]);
    }
    Ok(t)
}

/// **Fig. 12** — Scatter scalability on the full dataset.
pub fn fig12_scatter_scale() -> Result<Table> {
    let mut t = Table::new(
        "Fig 12: Scatter scalability (646 MB)",
        &["GPUs", "Cray MPI", "gZ-Scatter", "speedup"],
    );
    for &n in &GPU_COUNTS {
        let cray = run_scatter(n, FULL_DATASET_BYTES, ExecPolicy::cray_mpi(), 1e-4)?;
        let gz = run_scatter(n, FULL_DATASET_BYTES, ExecPolicy::gzccl(), 1e-4)?;
        t.row(&[n.to_string(), fmt_time(cray), fmt_time(gz), fmt_x(cray / gz)]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gz_scatter_beats_unoptimized_and_cray() {
        let n = 16;
        let bytes = 300 << 20;
        let base = run_scatter(n, bytes, ExecPolicy::gpu_centric_unoptimized(), 1e-4).unwrap();
        let gz = run_scatter(n, bytes, ExecPolicy::gzccl(), 1e-4).unwrap();
        let cray = run_scatter(n, bytes, ExecPolicy::cray_mpi(), 1e-4).unwrap();
        assert!(gz < base, "gz {gz} base {base}");
        assert!(gz < cray, "gz {gz} cray {cray}");
    }

    #[test]
    fn fig11_speedup_grows_with_size() {
        // Paper: "The speedup of gZ-Scatter enhances as the data size
        // increases".
        let n = 16;
        let s_small = {
            let cray = run_scatter(n, 50 << 20, ExecPolicy::cray_mpi(), 1e-4).unwrap();
            let gz = run_scatter(n, 50 << 20, ExecPolicy::gzccl(), 1e-4).unwrap();
            cray / gz
        };
        let s_big = {
            let cray = run_scatter(n, 600 << 20, ExecPolicy::cray_mpi(), 1e-4).unwrap();
            let gz = run_scatter(n, 600 << 20, ExecPolicy::gzccl(), 1e-4).unwrap();
            cray / gz
        };
        assert!(s_big > s_small, "{s_big} vs {s_small}");
        assert!(s_big > 3.0, "expect a large-factor win, got {s_big}");
    }

    #[test]
    fn fig12_speedup_positive_across_scale() {
        for n in [8usize, 64, 256] {
            let cray = run_scatter(n, FULL_DATASET_BYTES, ExecPolicy::cray_mpi(), 1e-4).unwrap();
            let gz = run_scatter(n, FULL_DATASET_BYTES, ExecPolicy::gzccl(), 1e-4).unwrap();
            assert!(gz < cray, "n={n}: gz {gz} cray {cray}");
        }
    }
}
