//! Image-stacking experiments: Table 2 (performance + breakdown) and
//! Fig. 13 (reconstruction accuracy).

use crate::apps::stacking::{run_stacking, write_pgm, StackingConfig, StackingVariant};
use crate::collectives::Algo;
use crate::comm::{CollectiveSpec, Communicator};
use crate::coordinator::ExecPolicy;
use crate::error::Result;
use crate::metrics::table::fmt_x;
use crate::metrics::Table;
use crate::runtime::Engine;
use crate::sim::Phase;

use super::{rtm_profile, virtual_inputs, Dataset};

/// **Table 2** — stacking performance vs Cray MPI plus phase
/// breakdowns. Performance runs at paper scale with virtual payloads
/// (`ranks` × `image_bytes`, 4 GPUs per node); the breakdown
/// percentages come from the same runs.
pub fn table2_stacking(ranks: usize, image_bytes: usize) -> Result<Table> {
    let eb = 1e-4;
    let profile = rtm_profile(Dataset::Rtm1, eb);
    let run = |policy: ExecPolicy, algo: Algo| -> Result<(f64, crate::sim::Breakdown)> {
        let comm = Communicator::builder(ranks)
            .gpus_per_node(4)
            .policy(policy)
            .error_bound(eb)
            .compression_profile(profile.clone())
            .build()?;
        let report =
            comm.allreduce(virtual_inputs(ranks, image_bytes), &CollectiveSpec::forced(algo))?;
        Ok((report.makespan.as_secs(), report.total_breakdown()))
    };
    let (cray, _) = run(ExecPolicy::cray_mpi(), Algo::Binomial)?;
    let (nccl, _) = run(ExecPolicy::nccl(), Algo::Ring)?;
    let (ring, bd_ring) = run(ExecPolicy::gzccl(), Algo::Ring)?;
    let (redoub, bd_redoub) = run(ExecPolicy::gzccl(), Algo::RecursiveDoubling)?;
    let (hier, bd_hier) = run(ExecPolicy::gzccl(), Algo::Hierarchical)?;

    let mut t = Table::new(
        format!("Table 2: image stacking ({} ranks, {} MB images)", ranks, image_bytes >> 20),
        &["variant", "speedup vs Cray", "Cmpr.", "Comm.", "Redu.", "Others"],
    );
    let pct = |b: crate::sim::Breakdown, p: Phase| format!("{:.2}%", 100.0 * b.fraction(p));
    // Fold DATAMOVE into Others for the paper's 4-column layout (gZCCL
    // variants have zero DATAMOVE anyway).
    let oth = |b: crate::sim::Breakdown| {
        format!(
            "{:.2}%",
            100.0 * (b.fraction(Phase::Other) + b.fraction(Phase::DataMove))
        )
    };
    t.row(&[
        "gZCCL (Ring)".into(),
        fmt_x(cray / ring),
        pct(bd_ring, Phase::Cpr),
        pct(bd_ring, Phase::Comm),
        pct(bd_ring, Phase::Redu),
        oth(bd_ring),
    ]);
    t.row(&[
        "gZCCL (ReDoub)".into(),
        fmt_x(cray / redoub),
        pct(bd_redoub, Phase::Cpr),
        pct(bd_redoub, Phase::Comm),
        pct(bd_redoub, Phase::Redu),
        oth(bd_redoub),
    ]);
    t.row(&[
        "gZCCL (Hier)".into(),
        fmt_x(cray / hier),
        pct(bd_hier, Phase::Cpr),
        pct(bd_hier, Phase::Comm),
        pct(bd_hier, Phase::Redu),
        oth(bd_hier),
    ]);
    t.row(&[
        "NCCL".into(),
        fmt_x(cray / nccl),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    Ok(t)
}

/// **Fig. 13** — reconstructed stack quality at eb 2e-4 and 1e-4 for
/// both gZCCL algorithms; real data end-to-end. Optionally writes PGM
/// visualizations next to `pgm_dir`.
pub fn fig13_accuracy(
    ranks: usize,
    engine: Option<&Engine>,
    pgm_dir: Option<&std::path::Path>,
) -> Result<Table> {
    let mut t = Table::new(
        "Fig 13: stacking accuracy",
        &["variant", "ABS", "PSNR (dB)", "NRMSE"],
    );
    for eb in [2e-4, 1e-4] {
        for variant in [StackingVariant::GzcclRing, StackingVariant::GzcclReDoub] {
            let cfg = StackingConfig {
                ranks,
                error_bound: eb,
                ..Default::default()
            };
            let out = run_stacking(&cfg, variant, engine)?;
            t.row(&[
                variant.name().to_string(),
                format!("{eb:.0e}"),
                format!("{:.2}", out.psnr),
                format!("{:.2e}", out.nrmse),
            ]);
            if let Some(dir) = pgm_dir {
                std::fs::create_dir_all(dir)?;
                let name = format!(
                    "stack_{}_{eb:.0e}.pgm",
                    variant.name().replace([' ', '(', ')'], "")
                );
                write_pgm(&dir.join(name), &out.image, cfg.width, cfg.height)?;
            }
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_gz_variants_beat_cray_at_paper_scale() {
        let t = table2_stacking(16, 256 << 20).unwrap();
        let s = t.render();
        assert!(s.contains("gZCCL (Ring)") && s.contains("NCCL"));
        // Parse the ReDoub speedup cell loosely: must be > 1x.
        let line = s.lines().find(|l| l.contains("ReDoub")).unwrap();
        let speedup: f64 = line
            .split('|')
            .nth(2)
            .unwrap()
            .trim()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(speedup > 1.0, "ReDoub speedup {speedup}");
    }

    #[test]
    fn fig13_quality_in_paper_regime() {
        let t = fig13_accuracy(8, None, None).unwrap();
        assert_eq!(t.len(), 4);
        let s = t.render();
        // Paper: PSNR ≈ 56.8–57.8 dB at 1e-4; anything ≥ ~45 dB on our
        // synthetic scene matches the "high quality" claim.
        for line in s.lines().skip(3) {
            let psnr: f64 = line.split('|').nth(3).unwrap().trim().parse().unwrap();
            assert!(psnr > 40.0, "low psnr in {line}");
        }
    }
}
