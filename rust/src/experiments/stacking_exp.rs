//! Image-stacking experiments: Table 2 (performance + breakdown) and
//! Fig. 13 (reconstruction accuracy).

use crate::accuracy::{plan_for_algo, AccuracyTarget};
use crate::apps::stacking::{
    run_stacking, write_pgm, StackingConfig, StackingTarget, StackingVariant,
};
use crate::collectives::{Algo, Op};
use crate::comm::{CollectiveSpec, Communicator};
use crate::coordinator::{CompressionMode, ExecPolicy};
use crate::error::{Error, Result};
use crate::metrics::table::fmt_x;
use crate::metrics::Table;
use crate::net::Topology;
use crate::runtime::Engine;
use crate::sim::Phase;

use super::{rtm_profile, virtual_inputs, Dataset};

/// **Table 2** — stacking performance vs Cray MPI plus phase
/// breakdowns. Performance runs at paper scale with virtual payloads
/// (`ranks` × `image_bytes`, 4 GPUs per node); the breakdown
/// percentages come from the same runs.
pub fn table2_stacking(ranks: usize, image_bytes: usize) -> Result<Table> {
    let eb = 1e-4;
    let profile = rtm_profile(Dataset::Rtm1, eb);
    let run = |policy: ExecPolicy, algo: Algo| -> Result<(f64, crate::sim::Breakdown)> {
        let comm = Communicator::builder(ranks)
            .gpus_per_node(4)
            .policy(policy)
            .error_bound(eb)
            .compression_profile(profile.clone())
            .build()?;
        let report =
            comm.allreduce(virtual_inputs(ranks, image_bytes), &CollectiveSpec::forced(algo))?;
        Ok((report.makespan.as_secs(), report.total_breakdown()))
    };
    let (cray, _) = run(ExecPolicy::cray_mpi(), Algo::Binomial)?;
    let (nccl, _) = run(ExecPolicy::nccl(), Algo::Ring)?;
    let (ring, bd_ring) = run(ExecPolicy::gzccl(), Algo::Ring)?;
    let (redoub, bd_redoub) = run(ExecPolicy::gzccl(), Algo::RecursiveDoubling)?;
    let (hier, bd_hier) = run(ExecPolicy::gzccl(), Algo::Hierarchical)?;

    // Budgeted column: the per-call eb the accuracy planner would
    // derive for each compressed algorithm under an end-to-end
    // L∞ ≤ 1e-3 target on this layout.
    let topo = Topology::new(ranks, 4)?;
    let budget_eb = |algo: Algo| -> String {
        match plan_for_algo(
            AccuracyTarget::AbsError(1e-3),
            1,
            Op::Allreduce,
            algo,
            &topo,
            CompressionMode::ErrorBounded,
        ) {
            Ok(p) => format!("{:.1e}", p.eb),
            Err(_) => "-".into(),
        }
    };
    let mut t = Table::new(
        format!("Table 2: image stacking ({} ranks, {} MB images)", ranks, image_bytes >> 20),
        &["variant", "speedup vs Cray", "Cmpr.", "Comm.", "Redu.", "Others", "eb@1e-3"],
    );
    let pct = |b: crate::sim::Breakdown, p: Phase| format!("{:.2}%", 100.0 * b.fraction(p));
    // Fold DATAMOVE into Others for the paper's 4-column layout (gZCCL
    // variants have zero DATAMOVE anyway).
    let oth = |b: crate::sim::Breakdown| {
        format!(
            "{:.2}%",
            100.0 * (b.fraction(Phase::Other) + b.fraction(Phase::DataMove))
        )
    };
    t.row(&[
        "gZCCL (Ring)".into(),
        fmt_x(cray / ring),
        pct(bd_ring, Phase::Cpr),
        pct(bd_ring, Phase::Comm),
        pct(bd_ring, Phase::Redu),
        oth(bd_ring),
        budget_eb(Algo::Ring),
    ]);
    t.row(&[
        "gZCCL (ReDoub)".into(),
        fmt_x(cray / redoub),
        pct(bd_redoub, Phase::Cpr),
        pct(bd_redoub, Phase::Comm),
        pct(bd_redoub, Phase::Redu),
        oth(bd_redoub),
        budget_eb(Algo::RecursiveDoubling),
    ]);
    t.row(&[
        "gZCCL (Hier)".into(),
        fmt_x(cray / hier),
        pct(bd_hier, Phase::Cpr),
        pct(bd_hier, Phase::Comm),
        pct(bd_hier, Phase::Redu),
        oth(bd_hier),
        budget_eb(Algo::Hierarchical),
    ]);
    t.row(&[
        "NCCL".into(),
        fmt_x(cray / nccl),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    Ok(t)
}

/// **Fig. 13** — reconstructed stack quality at eb 2e-4 and 1e-4 for
/// both gZCCL algorithms; real data end-to-end. Optionally writes PGM
/// visualizations next to `pgm_dir`.
///
/// The **budgeted** section inverts the question: instead of "what
/// quality does eb X give", each variant is handed a 50 dB PSNR floor
/// and the error-budget planner derives its per-call eb (shown in the
/// ABS column). The fixed-rate CPRP2P baseline is *rejected* — its
/// error is unbounded, the hazard the accuracy-aware design exists to
/// refuse.
pub fn fig13_accuracy(
    ranks: usize,
    engine: Option<&Engine>,
    pgm_dir: Option<&std::path::Path>,
) -> Result<Table> {
    let mut t = Table::new(
        "Fig 13: stacking accuracy",
        &["variant", "ABS", "PSNR (dB)", "NRMSE", "budget"],
    );
    for eb in [2e-4, 1e-4] {
        for variant in [StackingVariant::GzcclRing, StackingVariant::GzcclReDoub] {
            let cfg = StackingConfig {
                ranks,
                error_bound: eb,
                ..Default::default()
            };
            let out = run_stacking(&cfg, variant, engine)?;
            t.row(&[
                variant.name().to_string(),
                format!("{eb:.0e}"),
                format!("{:.2}", out.psnr),
                format!("{:.2e}", out.nrmse),
                "-".into(),
            ]);
            if let Some(dir) = pgm_dir {
                std::fs::create_dir_all(dir)?;
                let name = format!(
                    "stack_{}_{eb:.0e}.pgm",
                    variant.name().replace([' ', '(', ')'], "")
                );
                write_pgm(&dir.join(name), &out.image, cfg.width, cfg.height)?;
            }
        }
    }
    let floor_db = 50.0;
    for variant in [
        StackingVariant::GzcclRing,
        StackingVariant::GzcclReDoub,
        StackingVariant::GzcclHier,
        StackingVariant::Cprp2p,
    ] {
        let cfg = StackingConfig {
            ranks,
            accuracy_target: Some(StackingTarget::PsnrDb(floor_db)),
            ..Default::default()
        };
        let label = format!("{} @{floor_db:.0}dB", variant.name());
        match run_stacking(&cfg, variant, engine) {
            Ok(out) => {
                t.row(&[
                    label,
                    format!("{:.1e}", out.planned_eb.unwrap_or(f64::NAN)),
                    format!("{:.2}", out.psnr),
                    format!("{:.2e}", out.nrmse),
                    if out.psnr >= floor_db { "met" } else { "MISS" }.into(),
                ]);
            }
            // Only planner rejections render as a row; a genuine
            // failure in an accepted variant must surface, not
            // masquerade as an intentional rejection.
            Err(Error::Budget(_)) => {
                t.row(&[label, "-".into(), "-".into(), "-".into(), "rejected".into()]);
            }
            Err(e) => return Err(e),
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_gz_variants_beat_cray_at_paper_scale() {
        let t = table2_stacking(16, 256 << 20).unwrap();
        let s = t.render();
        assert!(s.contains("gZCCL (Ring)") && s.contains("NCCL"));
        // Parse the ReDoub speedup cell loosely: must be > 1x.
        let line = s.lines().find(|l| l.contains("ReDoub")).unwrap();
        let speedup: f64 = line
            .split('|')
            .nth(2)
            .unwrap()
            .trim()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(speedup > 1.0, "ReDoub speedup {speedup}");
    }

    #[test]
    fn fig13_quality_in_paper_regime() {
        let t = fig13_accuracy(8, None, None).unwrap();
        // 4 fixed-eb rows + 4 budgeted rows (3 accepted + CPRP2P
        // rejected).
        assert_eq!(t.len(), 8);
        let s = t.render();
        // The fixed-rate hazard baseline is rejected by the planner —
        // and it is the *only* rejection (the accepted variants ran).
        let cpr = s.lines().find(|l| l.contains("CPRP2P")).unwrap();
        assert!(cpr.contains("rejected"), "{cpr}");
        assert_eq!(s.matches("rejected").count(), 1, "exactly one rejection:\n{s}");
        for line in s.lines().skip(3) {
            if line.contains("rejected") {
                continue;
            }
            // Paper: PSNR ≈ 56.8–57.8 dB at 1e-4; anything ≥ ~45 dB on
            // our synthetic scene matches the "high quality" claim.
            let psnr: f64 = line.split('|').nth(3).unwrap().trim().parse().unwrap();
            assert!(psnr > 40.0, "low psnr in {line}");
            // Budgeted rows must meet their 50 dB floor.
            if line.contains("@50dB") {
                assert!(line.contains("met"), "budget missed in {line}");
                assert!(psnr >= 50.0, "floor violated in {line}");
            }
        }
    }
}
