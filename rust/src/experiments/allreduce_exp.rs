//! Allreduce experiments: Figs. 2, 6, 7, 9, 10.
//!
//! All runs dispatch through the [`Communicator`] with explicit
//! algorithm hints — each figure compares *specific* algorithms, so the
//! tuner is bypassed with `AlgoHint::Force`.

use crate::collectives::Algo;
use crate::comm::{CollectiveSpec, Communicator};
use crate::coordinator::ExecPolicy;
use crate::error::Result;
use crate::metrics::table::{fmt_time, fmt_x};
use crate::metrics::Table;
use crate::sim::Breakdown;

use super::{rtm_profile, virtual_inputs, Dataset, FULL_DATASET_BYTES, GPU_COUNTS, MSG_SIZES_MB};

fn run_ar_topo(
    ranks: usize,
    gpus_per_node: usize,
    bytes: usize,
    policy: ExecPolicy,
    eb: f64,
    algo: Algo,
) -> Result<(f64, Breakdown)> {
    let comm = Communicator::builder(ranks)
        .gpus_per_node(gpus_per_node)
        .policy(policy)
        .error_bound(eb)
        .compression_profile(rtm_profile(Dataset::Rtm2, eb))
        .build()?;
    let report = comm.allreduce(virtual_inputs(ranks, bytes), &CollectiveSpec::forced(algo))?;
    Ok((report.makespan.as_secs(), report.total_breakdown()))
}

/// [`run_ar_topo`] on the paper-testbed layout (4 GPUs per node).
fn run_ar(
    ranks: usize,
    bytes: usize,
    policy: ExecPolicy,
    eb: f64,
    algo: Algo,
) -> Result<(f64, Breakdown)> {
    run_ar_topo(ranks, 4, bytes, policy, eb, algo)
}

/// **Fig. 2** — phase breakdown of the ring Allreduce under CPRP2P and
/// C-Coll (64 GPUs, full dataset). Returns the rendered table.
pub fn fig02_breakdown(ranks: usize, bytes: usize) -> Result<Table> {
    let mut t = Table::new(
        format!("Fig 2: Allreduce breakdown, {} GPUs", ranks),
        &["variant", "runtime", "CPR", "COMM", "DATAMOVE", "REDU", "OTHERS"],
    );
    for (name, policy) in [
        ("CPRP2P", ExecPolicy::cprp2p()),
        ("C-Coll", ExecPolicy::ccoll()),
    ] {
        let (mk, bd) = run_ar(ranks, bytes, policy, 1e-4, Algo::Ring)?;
        t.row(&[
            name.to_string(),
            fmt_time(mk),
            format!("{:.1}%", 100.0 * bd.fraction(crate::sim::Phase::Cpr)),
            format!("{:.1}%", 100.0 * bd.fraction(crate::sim::Phase::Comm)),
            format!("{:.1}%", 100.0 * bd.fraction(crate::sim::Phase::DataMove)),
            format!("{:.1}%", 100.0 * bd.fraction(crate::sim::Phase::Redu)),
            format!("{:.1}%", 100.0 * bd.fraction(crate::sim::Phase::Other)),
        ]);
    }
    Ok(t)
}

/// **Fig. 6** — GPU-centric vs CPU-centric design, speedup vs size.
pub fn fig06_gpu_centric(ranks: usize, ds: Dataset) -> Result<Table> {
    let mut t = Table::new(
        format!("Fig 6: GPU-centric vs CPU-centric ({}, {} GPUs)", ds.name(), ranks),
        &["size", "cpu-centric", "gpu-centric", "speedup"],
    );
    let max_mb = match ds {
        Dataset::Rtm1 => 180,
        Dataset::Rtm2 => 600,
    };
    for mb in MSG_SIZES_MB.iter().map(|&m| m * max_mb / 600).filter(|&m| m > 0) {
        let bytes = mb << 20;
        let (cpu, _) = run_ar(ranks, bytes, ExecPolicy::ccoll(), 1e-4, Algo::Ring)?;
        let (gpu, _) = run_ar(
            ranks,
            bytes,
            ExecPolicy::gpu_centric_unoptimized(),
            1e-4,
            Algo::Ring,
        )?;
        t.row(&[
            format!("{mb} MB"),
            fmt_time(cpu),
            fmt_time(gpu),
            fmt_x(cpu / gpu),
        ]);
    }
    Ok(t)
}

/// **Fig. 7** — optimized gZ-Allreduce (Ring / ReDoub) speedups over
/// the unoptimized GPU-centric baseline, vs message size.
pub fn fig07_allreduce_opt(ranks: usize) -> Result<Table> {
    let mut t = Table::new(
        format!("Fig 7: gZ-Allreduce optimization gains ({} GPUs)", ranks),
        &["size", "gpu-centric", "gZ-Ring", "gZ-ReDoub", "ring gain", "redoub gain"],
    );
    for &mb in &MSG_SIZES_MB {
        let bytes = mb << 20;
        let (base, _) = run_ar(
            ranks,
            bytes,
            ExecPolicy::gpu_centric_unoptimized(),
            1e-4,
            Algo::Ring,
        )?;
        let (ring, _) = run_ar(ranks, bytes, ExecPolicy::gzccl(), 1e-4, Algo::Ring)?;
        let (redoub, _) = run_ar(
            ranks,
            bytes,
            ExecPolicy::gzccl(),
            1e-4,
            Algo::RecursiveDoubling,
        )?;
        t.row(&[
            format!("{mb} MB"),
            fmt_time(base),
            fmt_time(ring),
            fmt_time(redoub),
            fmt_x(base / ring),
            fmt_x(base / redoub),
        ]);
    }
    Ok(t)
}

fn five_way(
    ranks: usize,
    gpus_per_node: usize,
    bytes: usize,
) -> Result<(f64, f64, f64, f64, f64)> {
    // Binomial = the staged reduce+bcast Allreduce (Cray MPI baseline).
    let (cray, _) = run_ar_topo(ranks, gpus_per_node, bytes, ExecPolicy::cray_mpi(), 1e-4, Algo::Binomial)?;
    let (nccl, _) = run_ar_topo(ranks, gpus_per_node, bytes, ExecPolicy::nccl(), 1e-4, Algo::Ring)?;
    let (ring, _) = run_ar_topo(ranks, gpus_per_node, bytes, ExecPolicy::gzccl(), 1e-4, Algo::Ring)?;
    let (redoub, _) = run_ar_topo(
        ranks,
        gpus_per_node,
        bytes,
        ExecPolicy::gzccl(),
        1e-4,
        Algo::RecursiveDoubling,
    )?;
    let (hier, _) = run_ar_topo(
        ranks,
        gpus_per_node,
        bytes,
        ExecPolicy::gzccl(),
        1e-4,
        Algo::Hierarchical,
    )?;
    Ok((cray, nccl, ring, redoub, hier))
}

/// **Fig. 9** — gZ-Allreduce vs Cray MPI and NCCL across message
/// sizes, on a `gpus_per_node`-wide node layout (the paper testbed is
/// 4; the hierarchical column exploits it).
pub fn fig09_msgsize(ranks: usize, gpus_per_node: usize) -> Result<Table> {
    let mut t = Table::new(
        format!("Fig 9: Allreduce vs baselines ({ranks} GPUs, {gpus_per_node}/node)"),
        &["size", "Cray MPI", "NCCL", "gZ-Ring", "gZ-ReDoub", "gZ-Hier", "best gZ vs Cray", "best gZ vs NCCL"],
    );
    for &mb in &MSG_SIZES_MB {
        let (cray, nccl, ring, redoub, hier) = five_way(ranks, gpus_per_node, mb << 20)?;
        let best = redoub.min(hier);
        t.row(&[
            format!("{mb} MB"),
            fmt_time(cray),
            fmt_time(nccl),
            fmt_time(ring),
            fmt_time(redoub),
            fmt_time(hier),
            fmt_x(cray / best),
            fmt_x(nccl / best),
        ]);
    }
    Ok(t)
}

/// **Fig. 10** — scalability on the full dataset across GPU counts,
/// on a `gpus_per_node`-wide node layout.
pub fn fig10_scale(gpus_per_node: usize) -> Result<Table> {
    let mut t = Table::new(
        format!("Fig 10: Allreduce scalability (646 MB, {gpus_per_node} GPUs/node)"),
        &["GPUs", "Cray MPI", "NCCL", "gZ-Ring", "gZ-ReDoub", "gZ-Hier", "best gZ vs Cray", "best gZ vs NCCL"],
    );
    for &n in &GPU_COUNTS {
        let (cray, nccl, ring, redoub, hier) = five_way(n, gpus_per_node, FULL_DATASET_BYTES)?;
        let best = redoub.min(hier);
        t.row(&[
            n.to_string(),
            fmt_time(cray),
            fmt_time(nccl),
            fmt_time(ring),
            fmt_time(redoub),
            fmt_time(hier),
            fmt_x(cray / best),
            fmt_x(nccl / best),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig02_ccoll_shifts_cost_to_datamove() {
        let t = fig02_breakdown(16, 64 << 20).unwrap();
        let s = t.render();
        assert!(s.contains("CPRP2P") && s.contains("C-Coll"));
        // Structured check: rerun and inspect directly.
        let (mk_p2p, cpr) =
            run_ar(16, 64 << 20, ExecPolicy::cprp2p(), 1e-4, Algo::Ring).unwrap();
        let (mk_ccoll, ccoll) =
            run_ar(16, 64 << 20, ExecPolicy::ccoll(), 1e-4, Algo::Ring).unwrap();
        // Fig. 2: C-Coll is faster overall than CPRP2P...
        assert!(mk_ccoll < mk_p2p, "ccoll {mk_ccoll} vs cprp2p {mk_p2p}");
        // ...spends fewer absolute seconds compressing (the AG stage
        // compresses once instead of per hop)...
        assert!(
            ccoll.cpr < cpr.cpr,
            "ccoll cpr {}s vs cprp2p cpr {}s",
            ccoll.cpr,
            cpr.cpr
        );
        // ...and a large share of its runtime is host-device staging.
        assert!(
            ccoll.fraction(crate::sim::Phase::DataMove) > 0.2,
            "ccoll datamove {}",
            ccoll.fraction(crate::sim::Phase::DataMove)
        );
    }

    #[test]
    fn fig06_gpu_centric_wins_and_grows_with_size() {
        // Small sweep for test speed.
        let bytes_small = 50 << 20;
        let bytes_big = 300 << 20;
        let (cpu_s, _) =
            run_ar(16, bytes_small, ExecPolicy::ccoll(), 1e-4, Algo::Ring).unwrap();
        let (gpu_s, _) = run_ar(
            16,
            bytes_small,
            ExecPolicy::gpu_centric_unoptimized(),
            1e-4,
            Algo::Ring,
        )
        .unwrap();
        let (cpu_b, _) = run_ar(16, bytes_big, ExecPolicy::ccoll(), 1e-4, Algo::Ring).unwrap();
        let (gpu_b, _) = run_ar(
            16,
            bytes_big,
            ExecPolicy::gpu_centric_unoptimized(),
            1e-4,
            Algo::Ring,
        )
        .unwrap();
        assert!(gpu_s < cpu_s);
        // Paper Fig. 6: speedup increases with data size.
        assert!(cpu_b / gpu_b > cpu_s / gpu_s);
    }

    #[test]
    fn fig07_redoub_gains_shrink_with_size() {
        // Paper: "the speedup of both gZ-Allreduce methods generally
        // decreases as the data size increases".
        let (b1, _) = run_ar(32, 50 << 20, ExecPolicy::gpu_centric_unoptimized(), 1e-4, Algo::Ring)
            .unwrap();
        let (r1, _) =
            run_ar(32, 50 << 20, ExecPolicy::gzccl(), 1e-4, Algo::RecursiveDoubling).unwrap();
        let (b2, _) = run_ar(32, 600 << 20, ExecPolicy::gpu_centric_unoptimized(), 1e-4, Algo::Ring)
            .unwrap();
        let (r2, _) =
            run_ar(32, 600 << 20, ExecPolicy::gzccl(), 1e-4, Algo::RecursiveDoubling).unwrap();
        assert!(b1 / r1 > b2 / r2, "{} vs {}", b1 / r1, b2 / r2);
        assert!(r1 < b1 && r2 < b2);
    }

    #[test]
    fn fig10_shape_matches_paper() {
        // ReDoub best among the flat schedules at scale; Ring beats
        // NCCL only at small counts.
        let (cray8, nccl8, ring8, redoub8, hier8) = five_way(8, 4, FULL_DATASET_BYTES).unwrap();
        let (cray256, nccl256, ring256, redoub256, hier256) =
            five_way(256, 4, FULL_DATASET_BYTES).unwrap();
        assert!(redoub8 < nccl8 && redoub8 < cray8);
        assert!(redoub256 < nccl256 && redoub256 < cray256);
        assert!(ring8 < nccl8, "ring wins at 8 GPUs");
        assert!(ring256 > nccl256, "ring loses at 256 GPUs");
        // The topology-aware schedule also beats both baselines.
        assert!(hier8 < nccl8 && hier8 < cray8);
        assert!(hier256 < nccl256 && hier256 < cray256);
        // Cray degrades fastest with GPU count.
        assert!(cray256 / cray8 > nccl256 / nccl8);
    }
}
