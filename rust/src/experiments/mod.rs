//! Experiment drivers: one function per table/figure of the paper's
//! evaluation (§4), shared by `cargo bench` targets and the CLI.
//!
//! Performance sweeps use virtual payloads (sizes from a compression
//! profile measured on the real synthetic-RTM data with the real
//! compressor); accuracy experiments run real data end-to-end. See
//! DESIGN.md §4 for the experiment index.

pub mod allreduce_exp;
pub mod compression_exp;
pub mod scatter_exp;
pub mod stacking_exp;

pub use allreduce_exp::{fig02_breakdown, fig06_gpu_centric, fig07_allreduce_opt, fig09_msgsize, fig10_scale};
pub use compression_exp::{fig03_characterization, table1_compression};
pub use scatter_exp::{fig08_scatter_opt, fig11_scatter_msgsize, fig12_scatter_scale};
pub use stacking_exp::{fig13_accuracy, table2_stacking};

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use crate::compress::{CompressionProfile, CuszpLike};
use crate::coordinator::DeviceBuf;
use crate::data::RtmDataset;

/// Which synthetic RTM dataset an experiment sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// 449×449×235 (~180 MB).
    Rtm1,
    /// 849×849×235 (~646 MB).
    Rtm2,
}

impl Dataset {
    /// Materialize the generator.
    pub fn dataset(self) -> RtmDataset {
        match self {
            Dataset::Rtm1 => RtmDataset::setting1(),
            Dataset::Rtm2 => RtmDataset::setting2(),
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Dataset::Rtm1 => "RTM-1",
            Dataset::Rtm2 => "RTM-2",
        }
    }
}

/// Values sampled per dataset when measuring a compression profile.
/// Large enough to be representative, small enough to generate quickly.
const PROFILE_SAMPLE: usize = 1 << 21;

static PROFILES: OnceLock<Mutex<HashMap<(Dataset, u64), CompressionProfile>>> = OnceLock::new();

fn profiles() -> &'static Mutex<HashMap<(Dataset, u64), CompressionProfile>> {
    PROFILES.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Measured compression profile for `(dataset, eb)` — the real
/// compressor over a real data sample, cached for the process.
pub fn rtm_profile(ds: Dataset, eb: f64) -> CompressionProfile {
    let key = (ds, eb.to_bits());
    if let Some(p) = profiles().lock().unwrap().get(&key) {
        return p.clone();
    }
    let sample = ds.dataset().sample(PROFILE_SAMPLE);
    let profile = CompressionProfile::measure(&CuszpLike::new(eb), &sample);
    profiles().lock().unwrap().insert(key, profile.clone());
    profile
}

/// Virtual per-rank inputs of `bytes` each.
pub fn virtual_inputs(ranks: usize, bytes: usize) -> Vec<DeviceBuf> {
    (0..ranks).map(|_| DeviceBuf::Virtual(bytes / 4)).collect()
}

/// Virtual scatter inputs: the root holds `bytes`, others empty.
pub fn virtual_root_inputs(ranks: usize, bytes: usize) -> Vec<DeviceBuf> {
    let mut v = vec![DeviceBuf::Virtual(bytes / 4)];
    for _ in 1..ranks {
        v.push(DeviceBuf::Virtual(0));
    }
    v
}

/// The message-size sweep of Figs. 6/7/8/9/11 (MB).
pub const MSG_SIZES_MB: [usize; 6] = [50, 100, 200, 300, 450, 600];

/// The GPU-count sweep of Figs. 10/12.
pub const GPU_COUNTS: [usize; 7] = [8, 16, 32, 64, 128, 256, 512];

/// Full-dataset size used by the scalability studies (bytes).
pub const FULL_DATASET_BYTES: usize = 646 << 20;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_cache_returns_consistent_ratio() {
        let a = rtm_profile(Dataset::Rtm1, 1e-4);
        let b = rtm_profile(Dataset::Rtm1, 1e-4);
        assert_eq!(a.ratio, b.ratio);
        assert!(a.ratio > 5.0, "ratio {}", a.ratio);
    }

    #[test]
    fn looser_bound_higher_ratio() {
        let loose = rtm_profile(Dataset::Rtm1, 1e-3);
        let tight = rtm_profile(Dataset::Rtm1, 1e-5);
        assert!(loose.ratio > tight.ratio);
    }

    #[test]
    fn input_helpers_shapes() {
        let v = virtual_inputs(4, 1024);
        assert_eq!(v.len(), 4);
        assert_eq!(v[0].elems(), 256);
        let r = virtual_root_inputs(4, 1024);
        assert_eq!(r[0].elems(), 256);
        assert_eq!(r[3].elems(), 0);
    }
}
