//! Compression experiments: Fig. 3 (compressor characterization) and
//! Table 1 (ratio + PSNR on the RTM datasets).

use crate::compress::{ratio, Compressor, CuszpLike};
use crate::data::metrics::psnr;
use crate::error::Result;
use crate::gpu::GpuModel;
use crate::metrics::table::fmt_time;
use crate::metrics::Table;

use super::Dataset;

/// **Fig. 3** — modeled cuSZp execution time vs data size (uniform
/// data), plus the *measured* throughput of the real Rust compressor on
/// this host for reference. The modeled columns are what the cluster
/// simulation uses.
pub fn fig03_characterization() -> Result<Table> {
    let model = GpuModel::a100();
    let mut t = Table::new(
        "Fig 3: compressor characterization",
        &["size", "compress (A100 model)", "decompress (A100 model)", "utilization"],
    );
    for &mb_x10 in &[1usize, 10, 50, 100, 500, 1000, 3000, 6460] {
        let bytes = mb_x10 * (1 << 20) / 10;
        t.row(&[
            if bytes >= 1 << 20 {
                format!("{} MB", bytes >> 20)
            } else {
                format!("{} KB", bytes >> 10)
            },
            fmt_time(model.compress.time(bytes)),
            fmt_time(model.decompress.time(bytes)),
            format!("{:.1}%", 100.0 * model.compress.utilization(bytes)),
        ]);
    }
    Ok(t)
}

/// **Table 1** — compression ratio and PSNR of the cuSZp-like
/// compressor on both synthetic RTM datasets at ABS 1e-3/1e-4/1e-5.
/// Real data, real compressor. `sample_values` bounds the per-dataset
/// sample (the full 646 MB set takes minutes to synthesize on one
/// core).
pub fn table1_compression(sample_values: usize) -> Result<Table> {
    let mut t = Table::new(
        "Table 1: compression ratio (CPR) and quality (PSNR)",
        &["dataset", "ABS", "CPR", "PSNR (dB)"],
    );
    for ds in [Dataset::Rtm1, Dataset::Rtm2] {
        let data = ds.dataset().sample(sample_values);
        for eb in [1e-3, 1e-4, 1e-5] {
            let c = CuszpLike::new(eb);
            let stream = c.compress(&data);
            let back = c.decompress(&stream)?;
            t.row(&[
                ds.name().to_string(),
                format!("{eb:.0e}"),
                format!("{:.2}", ratio(data.len() * 4, stream.len())),
                format!("{:.2}", psnr(&data, &back)),
            ]);
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig03_table_has_full_sweep() {
        let t = fig03_characterization().unwrap();
        assert_eq!(t.len(), 8);
        let s = t.render();
        assert!(s.contains("646 MB"));
    }

    #[test]
    fn table1_monotone_in_eb() {
        // Loose → higher CPR, lower PSNR (Table 1's trend).
        let t = table1_compression(1 << 19).unwrap();
        let s = t.render();
        assert!(s.contains("RTM-1") && s.contains("RTM-2"));
        // Structured re-check on one dataset.
        let data = Dataset::Rtm1.dataset().sample(1 << 19);
        let mut ratios = vec![];
        let mut psnrs = vec![];
        for eb in [1e-3, 1e-4, 1e-5] {
            let c = CuszpLike::new(eb);
            let stream = c.compress(&data);
            ratios.push(ratio(data.len() * 4, stream.len()));
            psnrs.push(psnr(&data, &c.decompress(&stream).unwrap()));
        }
        assert!(ratios[0] > ratios[1] && ratios[1] > ratios[2], "{ratios:?}");
        assert!(psnrs[0] < psnrs[1] && psnrs[1] < psnrs[2], "{psnrs:?}");
        // PSNR lands in Table 1's regime (≈53–89 dB).
        assert!(psnrs[0] > 40.0 && psnrs[2] > 70.0, "{psnrs:?}");
    }
}
