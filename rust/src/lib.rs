//! # gZCCL — Compression-Accelerated Collective Communication
//!
//! A full reimplementation of *gZCCL: Compression-Accelerated Collective
//! Communication Framework for GPU Clusters* (Huang et al., ICS '24) as
//! a three-layer Rust + JAX + Pallas stack.
//!
//! The Rust layer (this crate) is the coordinator: collective algorithms
//! (ring / recursive doubling / binomial / Bruck), compression-enabled
//! variants (CPRP2P, C-Coll, gZCCL), a real error-bounded lossy
//! compressor, a virtual-time cluster simulator calibrated to the
//! paper's testbed (512×A100, Slingshot-10), and a PJRT runtime that
//! executes JAX/Pallas-authored artifacts on the hot path.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod apps;
pub mod bench_support;
pub mod collectives;
pub mod config;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod experiments;
pub mod gpu;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod testkit;

pub use error::{Error, Result};
