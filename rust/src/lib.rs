//! # gZCCL — Compression-Accelerated Collective Communication
//!
//! A full reimplementation of *gZCCL: Compression-Accelerated Collective
//! Communication Framework for GPU Clusters* (Huang et al., ICS '24) as
//! a three-layer Rust + JAX + Pallas stack.
//!
//! The Rust layer (this crate) is the coordinator: collective algorithms
//! (ring / recursive doubling / binomial / Bruck), compression-enabled
//! variants (CPRP2P, C-Coll, gZCCL), a real error-bounded lossy
//! compressor, a virtual-time cluster simulator calibrated to the
//! paper's testbed (512×A100, Slingshot-10), and a runtime that
//! executes the JAX/Pallas-authored artifact contract on the hot path.
//!
//! Applications enter through the [`comm::Communicator`]: a
//! communicator object (built via [`comm::CommBuilder`]) that owns the
//! simulated cluster and dispatches each collective through a
//! policy-aware [`comm::Tuner`] — the paper's message-size/rank-count
//! crossover model — unless the caller forces an algorithm with
//! [`comm::AlgoHint::Force`].
//!
//! See `DESIGN.md` for the system inventory, the three-layer stack and
//! the communicator API.

pub mod accuracy;
pub mod apps;
pub mod bench_support;
pub mod collectives;
pub mod comm;
pub mod config;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod error;
pub mod experiments;
pub mod gpu;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod pipeline;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod topo;

pub use error::{Error, Result};
