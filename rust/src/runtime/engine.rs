//! The artifact execution engine.
//!
//! Executes the L2/L1 artifact contract (`python/compile/model.py`)
//! natively: every artifact the AOT step lowers to HLO has a
//! semantically identical Rust interpretation here, so the coordinator's
//! hot paths (image-stacking reduction, DDP gradient/apply steps,
//! quantization round-trips) run self-contained in an offline build.
//!
//! The original design compiled the `artifacts/*.hlo.txt` files on a
//! PJRT CPU client through the `xla` crate. That dependency is not in
//! the offline vendored set, so the engine interprets the same contract
//! directly; when an `artifacts/` directory exists it is still
//! discovered and shape-validated, which keeps the Python AOT pipeline
//! and the Rust side honest about the shared shape constants.

use crate::error::{Error, Result};

use super::artifacts::{ArtifactSet, Shapes};

/// Values per independently-decodable Lorenzo block — mirrors
/// `python/compile/kernels/lorenzo.py::BLOCK`.
const LORENZO_BLOCK: usize = 256;
/// MLP hidden width — mirrors `python/compile/model.py::MLP_HID`.
const MLP_HID: usize = 256;
/// SGD learning rate baked into the `mlp_apply` artifact.
const SGD_LR: f32 = 0.05;

/// A runtime value crossing the Rust↔artifact boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// f32 tensor with explicit dims.
    F32(Vec<f32>, Vec<i64>),
    /// i32 tensor with explicit dims.
    I32(Vec<i32>, Vec<i64>),
}

impl Value {
    /// Flat f32 vector (1-D).
    pub fn f32v(data: Vec<f32>) -> Value {
        let n = data.len() as i64;
        Value::F32(data, vec![n])
    }

    /// Flat i32 vector (1-D).
    pub fn i32v(data: Vec<i32>) -> Value {
        let n = data.len() as i64;
        Value::I32(data, vec![n])
    }

    /// 2-D f32 tensor.
    pub fn f32m(data: Vec<f32>, rows: usize, cols: usize) -> Value {
        assert_eq!(data.len(), rows * cols);
        Value::F32(data, vec![rows as i64, cols as i64])
    }

    /// Unwrap as f32 data (panics otherwise).
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Value::F32(v, _) => v,
            Value::I32(..) => panic!("expected f32 value"),
        }
    }

    /// Unwrap as i32 data (panics otherwise).
    pub fn into_i32(self) -> Vec<i32> {
        match self {
            Value::I32(v, _) => v,
            Value::F32(..) => panic!("expected i32 value"),
        }
    }

    fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(v, _) => Ok(v),
            Value::I32(..) => Err(Error::runtime("expected an f32 input value")),
        }
    }

    fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(v, _) => Ok(v),
            Value::F32(..) => Err(Error::runtime("expected an i32 input value")),
        }
    }
}

/// Artifact interpreter over the validated shape contract.
pub struct Engine {
    shapes: Shapes,
    /// The discovered artifact set, when one exists on disk.
    artifacts: Option<ArtifactSet>,
}

impl Engine {
    /// Create an engine over an artifact set (validates its manifest
    /// against the compiled-in shape contract).
    pub fn new(artifacts: ArtifactSet) -> Result<Self> {
        let shapes = artifacts.validate()?;
        Ok(Engine {
            shapes,
            artifacts: Some(artifacts),
        })
    }

    /// Create an engine with no on-disk artifacts: the compiled-in
    /// shape contract and the native interpreters.
    pub fn native() -> Self {
        Engine {
            shapes: Shapes::expected(),
            artifacts: None,
        }
    }

    /// Create an engine, discovering `artifacts/` from the cwd when it
    /// exists (shape-validating it) and falling back to the native
    /// contract otherwise.
    pub fn discover() -> Result<Self> {
        match ArtifactSet::discover() {
            Ok(set) => Self::new(set),
            Err(_) => Ok(Self::native()),
        }
    }

    /// The validated shape contract.
    pub fn shapes(&self) -> Shapes {
        self.shapes
    }

    /// The discovered artifact set, if any.
    pub fn artifacts(&self) -> Option<&ArtifactSet> {
        self.artifacts.as_ref()
    }

    /// Execute artifact `name` with `inputs`; returns the flattened
    /// tuple outputs (matching the `return_tuple` lowering of aot.py).
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let arity = |n: usize| -> Result<()> {
            if inputs.len() != n {
                return Err(Error::runtime(format!(
                    "artifact {name}: expected {n} inputs, got {}",
                    inputs.len()
                )));
            }
            Ok(())
        };
        // The AOT artifacts are fixed-shape; the interpreter enforces
        // the same contract so a build without `artifacts/` cannot
        // silently accept inputs the compiled graphs would reject.
        let shape = |what: &str, got: usize, want: usize| -> Result<()> {
            if got != want {
                return Err(Error::runtime(format!(
                    "artifact {name}: {what} length {got} != contract {want}"
                )));
            }
            Ok(())
        };
        match name {
            "reduce_pair" | "stack_update" => {
                arity(2)?;
                let a = inputs[0].as_f32()?;
                let b = inputs[1].as_f32()?;
                shape("lhs", a.len(), self.shapes.img_elems)?;
                shape("rhs", b.len(), self.shapes.img_elems)?;
                Ok(vec![Value::f32v(native_reduce_pair(a, b)?)])
            }
            "quantize" => {
                arity(1)?;
                let x = inputs[0].as_f32()?;
                shape("input", x.len(), self.shapes.cpr_elems)?;
                Ok(vec![Value::i32v(lorenzo_encode(x, self.shapes.default_eb)?)])
            }
            "dequantize" => {
                arity(1)?;
                let d = inputs[0].as_i32()?;
                shape("input", d.len(), self.shapes.cpr_elems)?;
                Ok(vec![Value::f32v(lorenzo_decode(d, self.shapes.default_eb)?)])
            }
            "mlp_grads" => {
                arity(3)?;
                let params = inputs[0].as_f32()?;
                let x = inputs[1].as_f32()?;
                let y = inputs[2].as_f32()?;
                let (loss, grads) = native_mlp_grads(&self.shapes, params, x, y)?;
                Ok(vec![
                    Value::F32(vec![loss], vec![1]),
                    Value::f32v(grads),
                ])
            }
            "mlp_apply" => {
                arity(2)?;
                let params = inputs[0].as_f32()?;
                let grads = inputs[1].as_f32()?;
                shape("params", params.len(), self.shapes.mlp_params)?;
                shape("grads", grads.len(), self.shapes.mlp_params)?;
                let out = params
                    .iter()
                    .zip(grads.iter())
                    .map(|(p, g)| p - SGD_LR * g)
                    .collect();
                Ok(vec![Value::f32v(out)])
            }
            other => Err(Error::runtime(format!("unknown artifact `{other}`"))),
        }
    }

    // ---- typed convenience wrappers used by the apps ----------------

    /// `reduce_pair(a, b) = a + b` on the device graph.
    pub fn reduce_pair(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let out = self.run(
            "reduce_pair",
            &[Value::f32v(a.to_vec()), Value::f32v(b.to_vec())],
        )?;
        Ok(out.into_iter().next().unwrap().into_f32())
    }

    /// Quantize at the AOT-baked error bound.
    pub fn quantize(&self, x: &[f32]) -> Result<Vec<i32>> {
        let out = self.run("quantize", &[Value::f32v(x.to_vec())])?;
        Ok(out.into_iter().next().unwrap().into_i32())
    }

    /// Dequantize (inverse of [`Engine::quantize`]).
    pub fn dequantize(&self, d: &[i32]) -> Result<Vec<f32>> {
        let out = self.run("dequantize", &[Value::i32v(d.to_vec())])?;
        Ok(out.into_iter().next().unwrap().into_f32())
    }

    /// MLP loss + flat gradients for one batch.
    pub fn mlp_grads(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, Vec<f32>)> {
        let s = self.shapes;
        let out = self.run(
            "mlp_grads",
            &[
                Value::f32v(params.to_vec()),
                Value::f32m(x.to_vec(), s.mlp_batch, s.mlp_in),
                Value::f32m(y.to_vec(), s.mlp_batch, s.mlp_out),
            ],
        )?;
        let mut it = out.into_iter();
        let loss = it.next().unwrap().into_f32()[0];
        let grads = it.next().unwrap().into_f32();
        Ok((loss, grads))
    }

    /// SGD apply step (AOT-baked learning rate).
    pub fn mlp_apply(&self, params: &[f32], grads: &[f32]) -> Result<Vec<f32>> {
        let out = self.run(
            "mlp_apply",
            &[Value::f32v(params.to_vec()), Value::f32v(grads.to_vec())],
        )?;
        Ok(out.into_iter().next().unwrap().into_f32())
    }
}

// ---- native kernel interpretations ----------------------------------

fn native_reduce_pair(a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
    if a.len() != b.len() {
        return Err(Error::runtime("reduce_pair: length mismatch"));
    }
    Ok(a.iter().zip(b.iter()).map(|(x, y)| x + y).collect())
}

/// Prequantize + per-block integer Lorenzo deltas, mirroring
/// `lorenzo.py::_encode_kernel`: block `i` covers
/// `[i*BLOCK, (i+1)*BLOCK)` and its first delta is absolute.
fn lorenzo_encode(x: &[f32], eb: f64) -> Result<Vec<i32>> {
    if x.len() % LORENZO_BLOCK != 0 {
        return Err(Error::runtime(format!(
            "quantize: length {} not a multiple of {LORENZO_BLOCK}",
            x.len()
        )));
    }
    let inv_two_eb = (1.0 / (2.0 * eb)) as f32;
    let mut out = Vec::with_capacity(x.len());
    for block in x.chunks(LORENZO_BLOCK) {
        let mut prev: i32 = 0;
        for &v in block {
            let q = (v * inv_two_eb).round() as i32;
            out.push(q - prev);
            prev = q;
        }
    }
    Ok(out)
}

/// Per-block prefix sum + rescale to bin centers, mirroring
/// `lorenzo.py::_decode_kernel`.
fn lorenzo_decode(deltas: &[i32], eb: f64) -> Result<Vec<f32>> {
    if deltas.len() % LORENZO_BLOCK != 0 {
        return Err(Error::runtime(format!(
            "dequantize: length {} not a multiple of {LORENZO_BLOCK}",
            deltas.len()
        )));
    }
    let two_eb = (2.0 * eb) as f32;
    let mut out = Vec::with_capacity(deltas.len());
    for block in deltas.chunks(LORENZO_BLOCK) {
        let mut q: i32 = 0;
        for &d in block {
            q += d;
            out.push(q as f32 * two_eb);
        }
    }
    Ok(out)
}

/// Forward + backward of the 2-layer tanh MLP under MSE loss —
/// semantically `model.py::mlp_grads` (loss = mean((pred − y)²), flat
/// gradient layout W1 | b1 | W2 | b2, zero-padded to `mlp_params`).
fn native_mlp_grads(
    s: &Shapes,
    params: &[f32],
    x: &[f32],
    y: &[f32],
) -> Result<(f32, Vec<f32>)> {
    let (nin, nout, batch, hid) = (s.mlp_in, s.mlp_out, s.mlp_batch, MLP_HID);
    let raw = nin * hid + hid + hid * nout + nout;
    if params.len() != s.mlp_params || raw > s.mlp_params {
        return Err(Error::runtime("mlp_grads: bad parameter vector length"));
    }
    if x.len() != batch * nin || y.len() != batch * nout {
        return Err(Error::runtime("mlp_grads: bad batch shapes"));
    }
    let (w1, rest) = params.split_at(nin * hid);
    let (b1, rest) = rest.split_at(hid);
    let (w2, rest) = rest.split_at(hid * nout);
    let b2 = &rest[..nout];

    // Forward: h = tanh(x·W1 + b1), pred = h·W2 + b2.
    let mut h = vec![0.0f32; batch * hid];
    for b in 0..batch {
        for j in 0..hid {
            let mut acc = b1[j];
            for i in 0..nin {
                acc += x[b * nin + i] * w1[i * hid + j];
            }
            h[b * hid + j] = acc.tanh();
        }
    }
    let mut dpred = vec![0.0f32; batch * nout];
    let mut loss = 0.0f64;
    let scale = 2.0f32 / (batch * nout) as f32;
    for b in 0..batch {
        for o in 0..nout {
            let mut acc = b2[o];
            for j in 0..hid {
                acc += h[b * hid + j] * w2[j * nout + o];
            }
            let diff = acc - y[b * nout + o];
            loss += (diff * diff) as f64;
            dpred[b * nout + o] = scale * diff;
        }
    }
    loss /= (batch * nout) as f64;

    // Backward.
    let mut grads = vec![0.0f32; s.mlp_params];
    {
        let (gw1, rest) = grads.split_at_mut(nin * hid);
        let (gb1, rest) = rest.split_at_mut(hid);
        let (gw2, rest) = rest.split_at_mut(hid * nout);
        let gb2 = &mut rest[..nout];
        let mut dz = vec![0.0f32; hid];
        for b in 0..batch {
            // gW2 += hᵀ·dpred ; gb2 += dpred.
            for j in 0..hid {
                let hv = h[b * hid + j];
                let mut dh = 0.0f32;
                for o in 0..nout {
                    let dp = dpred[b * nout + o];
                    gw2[j * nout + o] += hv * dp;
                    dh += dp * w2[j * nout + o];
                }
                dz[j] = dh * (1.0 - hv * hv);
            }
            for o in 0..nout {
                gb2[o] += dpred[b * nout + o];
            }
            // gW1 += xᵀ·dz ; gb1 += dz.
            for i in 0..nin {
                let xv = x[b * nin + i];
                for j in 0..hid {
                    gw1[i * hid + j] += xv * dz[j];
                }
            }
            for j in 0..hid {
                gb1[j] += dz[j];
            }
        }
    }
    Ok((loss as f32, grads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Pcg32;

    thread_local! {
        // One engine per test thread (mirrors the PJRT-era layout).
        static ENGINE: Engine = Engine::discover().expect("engine construction failed");
    }

    fn with_engine<R>(f: impl FnOnce(&Engine) -> R) -> R {
        ENGINE.with(|e| f(e))
    }

    #[test]
    fn reduce_pair_adds() {
        with_engine(|e| {
            let n = e.shapes().img_elems;
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b = vec![2.0f32; n];
            let out = e.reduce_pair(&a, &b).unwrap();
            assert_eq!(out.len(), n);
            assert_eq!(out[0], 2.0);
            assert_eq!(out[100], 102.0);
        });
    }

    #[test]
    fn quantize_round_trip_error_bounded() {
        with_engine(|e| {
            let n = e.shapes().cpr_elems;
            let eb = e.shapes().default_eb as f32;
            let mut rng = Pcg32::seeded(42);
            let x = rng.uniform_vec(n, -2.0, 2.0);
            let codes = e.quantize(&x).unwrap();
            let back = e.dequantize(&codes).unwrap();
            for (a, b) in back.iter().zip(x.iter()) {
                assert!((a - b).abs() <= eb * 1.01 + 2.0 * 1e-6);
            }
        });
    }

    #[test]
    fn quantize_agrees_with_rust_compressor_semantics() {
        // The artifact quantize and the Rust cuSZp-like prequant use
        // the same bins: reconstructions must agree to f32 slack.
        with_engine(|e| {
            let n = e.shapes().cpr_elems;
            let eb = e.shapes().default_eb;
            let mut rng = Pcg32::seeded(3);
            let x = rng.uniform_vec(n, -1.0, 1.0);
            let via_engine = e.dequantize(&e.quantize(&x).unwrap()).unwrap();
            use crate::compress::{Compressor, CuszpLike};
            let c = CuszpLike::new(eb);
            let via_rust = c.decompress(&c.compress(&x)).unwrap();
            for (a, b) in via_engine.iter().zip(via_rust.iter()) {
                // Each path reconstructs within eb of x (rounding may
                // pick adjacent bins near boundaries).
                assert!((a - b).abs() <= 2.0 * eb as f32 * 1.05 + 1e-6);
            }
        });
    }

    #[test]
    fn mlp_grads_and_apply_learn() {
        with_engine(|e| {
            let s = e.shapes();
            let mut rng = Pcg32::seeded(7);
            let mut params: Vec<f32> =
                (0..s.mlp_params).map(|_| rng.next_gaussian() * 0.1).collect();
            // Synthetic batch: y = first OUT features of tanh(x).
            let x: Vec<f32> = (0..s.mlp_batch * s.mlp_in)
                .map(|_| rng.next_gaussian())
                .collect();
            let y: Vec<f32> = (0..s.mlp_batch)
                .flat_map(|r| {
                    (0..s.mlp_out)
                        .map(|c| (x[r * s.mlp_in + c]).tanh() * 0.5)
                        .collect::<Vec<_>>()
                })
                .collect();
            let (first, _) = e.mlp_grads(&params, &x, &y).unwrap();
            for _ in 0..20 {
                let (_, g) = e.mlp_grads(&params, &x, &y).unwrap();
                params = e.mlp_apply(&params, &g).unwrap();
            }
            let (last, _) = e.mlp_grads(&params, &x, &y).unwrap();
            assert!(
                last < 0.7 * first,
                "loss did not decrease: {first} -> {last}"
            );
        });
    }

    #[test]
    fn unknown_artifact_rejected() {
        with_engine(|e| {
            assert!(e.run("nonexistent", &[]).is_err());
        });
    }

    #[test]
    fn artifact_shape_contract_enforced() {
        // The compiled artifacts were fixed-shape; the interpreter
        // must reject off-contract inputs the same way.
        with_engine(|e| {
            let s = e.shapes();
            assert!(e.quantize(&vec![0.0f32; s.cpr_elems]).is_ok());
            assert!(e.quantize(&[0.0f32; LORENZO_BLOCK]).is_err());
            assert!(e
                .reduce_pair(&vec![0.0; s.img_elems], &vec![0.0; s.img_elems - 1])
                .is_err());
            assert!(e
                .mlp_apply(&vec![0.0; s.mlp_params], &vec![0.0; s.mlp_params - 1])
                .is_err());
        });
    }

    #[test]
    fn mlp_apply_is_sgd_step() {
        with_engine(|e| {
            let s = e.shapes();
            let p = vec![1.0f32; s.mlp_params];
            let g = vec![2.0f32; s.mlp_params];
            let out = e.mlp_apply(&p, &g).unwrap();
            for v in out {
                assert!((v - (1.0 - SGD_LR * 2.0)).abs() < 1e-6);
            }
        });
    }
}
