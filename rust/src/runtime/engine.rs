//! The PJRT execution engine.
//!
//! Wraps the `xla` crate: parse HLO text → compile once per artifact on
//! the PJRT CPU client → execute with concrete inputs. Executables are
//! cached; compilation happens at most once per artifact per engine.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::error::{Error, Result};

use super::artifacts::{ArtifactSet, Shapes};

/// A runtime value crossing the Rust↔PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// f32 tensor with explicit dims.
    F32(Vec<f32>, Vec<i64>),
    /// i32 tensor with explicit dims.
    I32(Vec<i32>, Vec<i64>),
}

impl Value {
    /// Flat f32 vector (1-D).
    pub fn f32v(data: Vec<f32>) -> Value {
        let n = data.len() as i64;
        Value::F32(data, vec![n])
    }

    /// Flat i32 vector (1-D).
    pub fn i32v(data: Vec<i32>) -> Value {
        let n = data.len() as i64;
        Value::I32(data, vec![n])
    }

    /// 2-D f32 tensor.
    pub fn f32m(data: Vec<f32>, rows: usize, cols: usize) -> Value {
        assert_eq!(data.len(), rows * cols);
        Value::F32(data, vec![rows as i64, cols as i64])
    }

    /// Unwrap as f32 data (panics otherwise).
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Value::F32(v, _) => v,
            Value::I32(..) => panic!("expected f32 value"),
        }
    }

    /// Unwrap as i32 data (panics otherwise).
    pub fn into_i32(self) -> Vec<i32> {
        match self {
            Value::I32(v, _) => v,
            Value::F32(..) => panic!("expected i32 value"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32(v, dims) => xla::Literal::vec1(v).reshape(dims),
            Value::I32(v, dims) => xla::Literal::vec1(v).reshape(dims),
        };
        lit.map_err(|e| Error::runtime(format!("literal build failed: {e}")))
    }
}

fn literal_to_value(lit: &xla::Literal) -> Result<Value> {
    let ty = lit
        .element_type()
        .map_err(|e| Error::runtime(format!("element_type: {e}")))?;
    match ty {
        xla::ElementType::F32 => Ok(Value::f32v(
            lit.to_vec::<f32>()
                .map_err(|e| Error::runtime(format!("to_vec<f32>: {e}")))?,
        )),
        xla::ElementType::S32 => Ok(Value::i32v(
            lit.to_vec::<i32>()
                .map_err(|e| Error::runtime(format!("to_vec<i32>: {e}")))?,
        )),
        other => Err(Error::runtime(format!("unsupported output type {other:?}"))),
    }
}

/// Compiled-artifact cache + PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts: ArtifactSet,
    shapes: Shapes,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create an engine over an artifact set (validates it).
    pub fn new(artifacts: ArtifactSet) -> Result<Self> {
        let shapes = artifacts.validate()?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::runtime(format!("PJRT CPU client: {e}")))?;
        Ok(Engine {
            client,
            artifacts,
            shapes,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Create an engine by discovering `artifacts/` from the cwd.
    pub fn discover() -> Result<Self> {
        Self::new(ArtifactSet::discover()?)
    }

    /// The validated shape contract.
    pub fn shapes(&self) -> Shapes {
        self.shapes
    }

    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.artifacts.hlo_path(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| Error::runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::runtime(format!("compile {name}: {e}")))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name` with `inputs`; returns the flattened
    /// tuple outputs (aot.py lowers everything with `return_tuple`).
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::runtime(format!("execute {name}: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("readback {name}: {e}")))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| Error::runtime(format!("untuple {name}: {e}")))?;
        parts.iter().map(literal_to_value).collect()
    }

    // ---- typed convenience wrappers used by the apps ----------------

    /// `reduce_pair(a, b) = a + b` on the device graph.
    pub fn reduce_pair(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let out = self.run(
            "reduce_pair",
            &[Value::f32v(a.to_vec()), Value::f32v(b.to_vec())],
        )?;
        Ok(out.into_iter().next().unwrap().into_f32())
    }

    /// Quantize at the AOT-baked error bound.
    pub fn quantize(&self, x: &[f32]) -> Result<Vec<i32>> {
        let out = self.run("quantize", &[Value::f32v(x.to_vec())])?;
        Ok(out.into_iter().next().unwrap().into_i32())
    }

    /// Dequantize (inverse of [`Engine::quantize`]).
    pub fn dequantize(&self, d: &[i32]) -> Result<Vec<f32>> {
        let out = self.run("dequantize", &[Value::i32v(d.to_vec())])?;
        Ok(out.into_iter().next().unwrap().into_f32())
    }

    /// MLP loss + flat gradients for one batch.
    pub fn mlp_grads(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, Vec<f32>)> {
        let s = self.shapes;
        let out = self.run(
            "mlp_grads",
            &[
                Value::f32v(params.to_vec()),
                Value::f32m(x.to_vec(), s.mlp_batch, s.mlp_in),
                Value::f32m(y.to_vec(), s.mlp_batch, s.mlp_out),
            ],
        )?;
        let mut it = out.into_iter();
        let loss = it.next().unwrap().into_f32()[0];
        let grads = it.next().unwrap().into_f32();
        Ok((loss, grads))
    }

    /// SGD apply step (AOT-baked learning rate).
    pub fn mlp_apply(&self, params: &[f32], grads: &[f32]) -> Result<Vec<f32>> {
        let out = self.run(
            "mlp_apply",
            &[Value::f32v(params.to_vec()), Value::f32v(grads.to_vec())],
        )?;
        Ok(out.into_iter().next().unwrap().into_f32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Pcg32;

    thread_local! {
        // The PJRT client is not Send/Sync: one engine per test thread.
        static ENGINE: Engine =
            Engine::discover().expect("run `make artifacts` before cargo test");
    }

    fn with_engine<R>(f: impl FnOnce(&Engine) -> R) -> R {
        ENGINE.with(|e| f(e))
    }

    #[test]
    fn reduce_pair_adds() {
        with_engine(|e| {
        let n = e.shapes().img_elems;
        let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let b = vec![2.0f32; n];
        let out = e.reduce_pair(&a, &b).unwrap();
        assert_eq!(out.len(), n);
        assert_eq!(out[0], 2.0);
        assert_eq!(out[100], 102.0);
        });
    }

    #[test]
    fn quantize_round_trip_error_bounded() {
        with_engine(|e| {
        let n = e.shapes().cpr_elems;
        let eb = e.shapes().default_eb as f32;
        let mut rng = Pcg32::seeded(42);
        let x = rng.uniform_vec(n, -2.0, 2.0);
        let codes = e.quantize(&x).unwrap();
        let back = e.dequantize(&codes).unwrap();
        for (a, b) in back.iter().zip(x.iter()) {
            assert!((a - b).abs() <= eb * 1.01 + 2.0 * 1e-6);
        }
        });
    }

    #[test]
    fn quantize_agrees_with_rust_compressor_semantics() {
        // The PJRT quantize and the Rust cuSZp-like prequant use the
        // same bins: reconstructions must agree to f32 slack.
        with_engine(|e| {
        let n = e.shapes().cpr_elems;
        let eb = e.shapes().default_eb;
        let mut rng = Pcg32::seeded(3);
        let x = rng.uniform_vec(n, -1.0, 1.0);
        let via_pjrt = e.dequantize(&e.quantize(&x).unwrap()).unwrap();
        use crate::compress::{Compressor, CuszpLike};
        let c = CuszpLike::new(eb);
        let via_rust = c.decompress(&c.compress(&x)).unwrap();
        for (a, b) in via_pjrt.iter().zip(via_rust.iter()) {
            // Each path reconstructs within eb of x (f64 vs f32
            // rounding may pick adjacent bins near boundaries).
            assert!((a - b).abs() <= 2.0 * eb as f32 * 1.05 + 1e-6);
        }
        });
    }

    #[test]
    fn mlp_grads_and_apply_learn() {
        with_engine(|e| {
        let s = e.shapes();
        let mut rng = Pcg32::seeded(7);
        let mut params: Vec<f32> = (0..s.mlp_params).map(|_| rng.next_gaussian() * 0.1).collect();
        // Synthetic batch: y = first OUT features of tanh(x).
        let x: Vec<f32> = (0..s.mlp_batch * s.mlp_in)
            .map(|_| rng.next_gaussian())
            .collect();
        let y: Vec<f32> = (0..s.mlp_batch)
            .flat_map(|r| {
                (0..s.mlp_out)
                    .map(|c| (x[r * s.mlp_in + c]).tanh() * 0.5)
                    .collect::<Vec<_>>()
            })
            .collect();
        let (first, _) = e.mlp_grads(&params, &x, &y).unwrap();
        for _ in 0..20 {
            let (_, g) = e.mlp_grads(&params, &x, &y).unwrap();
            params = e.mlp_apply(&params, &g).unwrap();
        }
        let (last, _) = e.mlp_grads(&params, &x, &y).unwrap();
        assert!(
            last < 0.7 * first,
            "loss did not decrease: {first} -> {last}"
        );
        });
    }

    #[test]
    fn unknown_artifact_rejected() {
        with_engine(|e| {
            assert!(e.run("nonexistent", &[]).is_err());
        });
    }
}
