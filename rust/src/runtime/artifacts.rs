//! Artifact names and the shape contract with `python/compile/model.py`.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Shape constants mirrored from `python/compile/model.py`; validated
/// against `artifacts/manifest.txt` at engine startup.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Shapes {
    /// Flattened image-stacking image length (128×128).
    pub img_elems: usize,
    /// Compression round-trip vector length.
    pub cpr_elems: usize,
    /// AOT-baked absolute error bound.
    pub default_eb: f64,
    /// Flat MLP parameter count (padded).
    pub mlp_params: usize,
    /// MLP input features.
    pub mlp_in: usize,
    /// MLP output features.
    pub mlp_out: usize,
    /// MLP batch size.
    pub mlp_batch: usize,
}

impl Shapes {
    /// The compiled-in contract.
    pub const fn expected() -> Shapes {
        Shapes {
            img_elems: 128 * 128,
            cpr_elems: 64 * 1024,
            default_eb: 1e-4,
            mlp_params: 20_992,
            mlp_in: 64,
            mlp_out: 16,
            mlp_batch: 256,
        }
    }

    /// Parse `manifest.txt` produced by `python -m compile.aot`.
    pub fn from_manifest(text: &str) -> Result<Shapes> {
        let mut s = Shapes::expected();
        let mut seen = 0;
        for line in text.lines() {
            let mut it = line.split_whitespace();
            let (Some(key), Some(val)) = (it.next(), it.next()) else {
                continue;
            };
            seen += 1;
            match key {
                "img_elems" => s.img_elems = val.parse().map_err(bad(line))?,
                "cpr_elems" => s.cpr_elems = val.parse().map_err(bad(line))?,
                "default_eb" => {
                    s.default_eb = val
                        .parse()
                        .map_err(|_| Error::runtime(format!("bad manifest line: {line}")))?
                }
                "mlp_params" => s.mlp_params = val.parse().map_err(bad(line))?,
                "mlp_in" => s.mlp_in = val.parse().map_err(bad(line))?,
                "mlp_out" => s.mlp_out = val.parse().map_err(bad(line))?,
                "mlp_batch" => s.mlp_batch = val.parse().map_err(bad(line))?,
                _ => {
                    seen -= 1;
                }
            }
        }
        if seen < 7 {
            return Err(Error::runtime("manifest.txt missing shape entries"));
        }
        Ok(s)
    }
}

fn bad(line: &str) -> impl Fn(std::num::ParseIntError) -> Error + '_ {
    move |_| Error::runtime(format!("bad manifest line: {line}"))
}

/// The artifact directory and its expected contents.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    dir: PathBuf,
}

/// Every artifact the AOT step must produce.
pub const ARTIFACT_NAMES: [&str; 6] = [
    "reduce_pair",
    "stack_update",
    "quantize",
    "dequantize",
    "mlp_grads",
    "mlp_apply",
];

impl ArtifactSet {
    /// Point at an artifact directory (typically `artifacts/`).
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        ArtifactSet { dir: dir.into() }
    }

    /// Locate the artifact dir relative to the repo root, walking up
    /// from the current directory (tests run from nested dirs).
    pub fn discover() -> Result<Self> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.txt").is_file() {
                return Ok(ArtifactSet::new(cand));
            }
            if !dir.pop() {
                return Err(Error::runtime(
                    "artifacts/ not found — run `make artifacts` first",
                ));
            }
        }
    }

    /// Path of one artifact's HLO text.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// The artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Check presence of every artifact + parse and validate shapes.
    pub fn validate(&self) -> Result<Shapes> {
        for name in ARTIFACT_NAMES {
            let p = self.hlo_path(name);
            if !p.is_file() {
                return Err(Error::runtime(format!("missing artifact {}", p.display())));
            }
        }
        let manifest = std::fs::read_to_string(self.dir.join("manifest.txt"))?;
        let shapes = Shapes::from_manifest(&manifest)?;
        let exp = Shapes::expected();
        if shapes != exp {
            return Err(Error::runtime(format!(
                "artifact shapes {shapes:?} do not match the compiled-in contract {exp:?}; \
                 re-run `make artifacts` after syncing model.py and artifacts.rs"
            )));
        }
        Ok(shapes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = "img_elems 16384\ncpr_elems 65536\ndefault_eb 0.0001\n\
                    mlp_params 20992\nmlp_in 64\nmlp_out 16\nmlp_batch 256\n\
                    reduce_pair sha256:aa bytes:100\n";
        let s = Shapes::from_manifest(text).unwrap();
        assert_eq!(s, Shapes::expected());
    }

    #[test]
    fn manifest_missing_entries_rejected() {
        assert!(Shapes::from_manifest("img_elems 16384\n").is_err());
    }

    #[test]
    fn mlp_params_matches_python_formula() {
        // ceil((64*256 + 256 + 256*16 + 16) / 256) * 256
        let raw: usize = 64 * 256 + 256 + 256 * 16 + 16;
        let padded = raw.div_ceil(256) * 256;
        assert_eq!(Shapes::expected().mlp_params, padded);
    }

    #[test]
    fn validate_accepts_a_complete_artifact_dir() {
        // Build a synthetic artifact dir (offline CI has no JAX to run
        // `make artifacts`); validation must accept it end-to-end.
        let dir = std::env::temp_dir().join(format!(
            "gzccl_artifact_validate_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        for name in ARTIFACT_NAMES {
            std::fs::write(dir.join(format!("{name}.hlo.txt")), "HloModule stub\n").unwrap();
        }
        std::fs::write(
            dir.join("manifest.txt"),
            "img_elems 16384\ncpr_elems 65536\ndefault_eb 0.0001\n\
             mlp_params 20992\nmlp_in 64\nmlp_out 16\nmlp_batch 256\n",
        )
        .unwrap();
        let set = ArtifactSet::new(&dir);
        let shapes = set.validate().unwrap();
        assert_eq!(shapes, Shapes::expected());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn validate_rejects_missing_artifacts() {
        let dir = std::env::temp_dir().join(format!(
            "gzccl_artifact_missing_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let set = ArtifactSet::new(&dir);
        assert!(set.validate().is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
