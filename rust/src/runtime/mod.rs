//! Artifact runtime: execute the JAX/Pallas-authored artifact contract.
//!
//! Python runs only at build time (`python -m compile.aot`); this
//! module makes the Rust binary self-contained afterwards. The engine
//! interprets the artifact contract natively (the `xla`/PJRT client of
//! the original design is not in the offline dependency set — see
//! `engine.rs`), while `artifacts.rs` still discovers and
//! shape-validates an `artifacts/` directory when one exists, keeping
//! the Python AOT pipeline and the Rust side in lockstep. The
//! coordinator's hot paths (image-stacking reduction, DDP
//! gradient/apply steps, quantization round-trips) all route through
//! [`Engine::run`].

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactSet, Shapes};
pub use engine::{Engine, Value};
