//! PJRT runtime: load and execute the JAX/Pallas-authored artifacts.
//!
//! Python runs only at build time (`make artifacts`); this module makes
//! the Rust binary self-contained afterwards: it parses the HLO *text*
//! artifacts (the id-safe interchange format — see `python/compile/
//! aot.py`), compiles them once on the PJRT CPU client, and executes
//! them from the coordinator's hot paths (image-stacking reduction, DDP
//! gradient/apply steps, quantization round-trips).

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactSet, Shapes};
pub use engine::{Engine, Value};
