//! Per-rank execution context.
//!
//! [`RankCtx`] is what a collective algorithm programs against: device
//! operations (compress / decompress / reduce / memset / pack), p2p
//! communication, and synchronization — all with virtual-time
//! accounting. The [`ExecPolicy`] knobs select the *variant* under
//! study (CPRP2P, C-Coll CPU-centric, unoptimized GPU-centric, full
//! gZCCL), by toggling exactly the design decisions the paper's
//! sections 3.3.1–3.3.4 introduce.

use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::compress::{decode_any, CompressionProfile, Compressor};
use crate::error::Result;
use crate::gpu::{GpuDevice, StreamId};
use crate::net::{DeliverPath, FabricSlice, Topology};
use crate::obs::{Lane, SpanCat, TrackBuf, Tracer};
use crate::sim::{Breakdown, Phase, RankClock, VirtTime};
use crate::topo::LegExec;

use super::buffer::{CompBuf, DeviceBuf};
use super::mailbox::{Mailbox, Msg, Payload};

/// How a rank's messages move: over mpsc channels between OS threads
/// (the thread backend), or through the event engine's shared message
/// store (ranks as actors on one scheduler). The context's `send`/
/// `recv` are port-agnostic; only `recv` behaves differently — the
/// channel port blocks the rank's thread, the event port suspends the
/// rank's future until the scheduler replays the matching arrival.
pub(crate) enum Port {
    /// Thread backend: cloneable senders into every peer, one mailbox.
    Channel {
        senders: Vec<Sender<Msg>>,
        mailbox: Mailbox,
    },
    /// Event backend: a handle into the engine's shared [`crate::engine::MsgStore`].
    Event(crate::engine::EventPort),
}

/// Which compressor (if any) a variant runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressionMode {
    /// No compression (NCCL / Cray MPI baselines).
    None,
    /// Error-bounded cuSZp-class (gZCCL, C-Coll).
    ErrorBounded,
    /// Fixed-rate ZFP-class (CPRP2P baseline).
    FixedRate,
}

/// Variant knobs — each maps to a design decision in the paper.
#[derive(Debug, Clone, Copy)]
pub struct ExecPolicy {
    /// What compressor runs.
    pub compression: CompressionMode,
    /// §3.3.1 GPU-centric buffers: device-direct sends (no PCIe
    /// staging). `false` = CPU-centric (C-Coll / Cray MPI).
    pub gpu_centric: bool,
    /// §3.3.1 GPU reduction kernel. `false` = host reduction.
    pub gpu_reduce: bool,
    /// §3.3.4 overlap: async kernels on a non-default stream, host
    /// does not eagerly synchronize after each launch.
    pub overlap: bool,
    /// §3.3.4 multi-stream compression for chunked operations.
    pub multi_stream: bool,
    /// §3.3.1 pre-allocated device buffer pool (no per-call cudaMalloc).
    pub prealloc_pool: bool,
    /// §3.3.2 adapted compressor (no unified-memory offset buffer, no
    /// per-call temp allocation). `false` models stock cuSZp.
    pub adapted_compressor: bool,
}

impl ExecPolicy {
    /// Full gZCCL policy: everything on.
    pub fn gzccl() -> Self {
        ExecPolicy {
            compression: CompressionMode::ErrorBounded,
            gpu_centric: true,
            gpu_reduce: true,
            overlap: true,
            multi_stream: true,
            prealloc_pool: true,
            adapted_compressor: true,
        }
    }

    /// The paper's "original GPU-centric approach" (Fig. 7 baseline):
    /// device buffers and GPU reduction, but stock compressor, no
    /// overlap, no multi-stream.
    pub fn gpu_centric_unoptimized() -> Self {
        ExecPolicy {
            compression: CompressionMode::ErrorBounded,
            gpu_centric: true,
            gpu_reduce: true,
            overlap: false,
            multi_stream: false,
            prealloc_pool: true,
            adapted_compressor: false,
        }
    }

    /// C-Coll-style CPU-centric compression-enabled collectives.
    pub fn ccoll() -> Self {
        ExecPolicy {
            compression: CompressionMode::ErrorBounded,
            gpu_centric: false,
            gpu_reduce: false,
            overlap: false,
            multi_stream: false,
            prealloc_pool: false,
            adapted_compressor: false,
        }
    }

    /// CPRP2P: fixed-rate compression bolted onto every p2p op.
    pub fn cprp2p() -> Self {
        ExecPolicy {
            compression: CompressionMode::FixedRate,
            gpu_centric: false,
            gpu_reduce: false,
            overlap: false,
            multi_stream: false,
            prealloc_pool: false,
            adapted_compressor: false,
        }
    }

    /// NCCL-class baseline: no compression, device-direct, pipelined.
    pub fn nccl() -> Self {
        ExecPolicy {
            compression: CompressionMode::None,
            gpu_centric: true,
            gpu_reduce: true,
            overlap: true,
            multi_stream: false,
            prealloc_pool: true,
            adapted_compressor: true,
        }
    }

    /// Cray-MPI-class baseline: no compression, CPU-centric staging,
    /// host reduction.
    pub fn cray_mpi() -> Self {
        ExecPolicy {
            compression: CompressionMode::None,
            gpu_centric: false,
            gpu_reduce: false,
            overlap: false,
            multi_stream: false,
            prealloc_pool: true,
            adapted_compressor: true,
        }
    }
}

/// Operation counters (used by tests asserting the paper's complexity
/// claims: ring = N−1 compressions, ReDoub = log N, ...).
#[derive(Debug, Clone, Copy, Default)]
pub struct OpCounters {
    /// Compression kernel invocations (a multi-stream batch counts its
    /// chunk count).
    pub compress_calls: usize,
    /// Decompression kernel invocations.
    pub decompress_calls: usize,
    /// Reduction invocations.
    pub reduce_calls: usize,
    /// Messages sent.
    pub msgs_sent: usize,
    /// Bytes put on the wire.
    pub wire_bytes: usize,
    /// Bytes moved over PCIe (both directions).
    pub pcie_bytes: usize,
    /// Algorithm the [`crate::comm::Communicator`] dispatched for the
    /// collective that produced these counters (`None` when the
    /// collective free function was invoked directly).
    pub algo_selected: Option<crate::collectives::Algo>,
    /// Number of those dispatches decided by the
    /// [`crate::comm::Tuner`] (`AlgoHint::Auto`) rather than forced.
    pub tuner_decisions: usize,
    /// Predicted worst-case pointwise error bound of the dispatched
    /// collective (`None` when no accuracy telemetry ran — virtual
    /// payloads, uncompressed policy, direct free-function invocation —
    /// or when the compressor is not error-bounded).
    pub predicted_err_bound: Option<f64>,
    /// Collective-wide observed max deviation against the exact
    /// reference sample (see [`crate::accuracy::telemetry`]); recorded
    /// on every rank of the dispatch that produced it.
    pub observed_max_err: Option<f64>,
}

/// Elements above which a compress call skips the per-leg roundtrip
/// observation: the decode behind it is O(n), and the evidence a
/// 64Ki-element sample provides is the same. Virtual payloads and
/// larger real payloads simply report no per-leg observation.
pub const LEG_PROBE_MAX_ELEMS: usize = 1 << 16;

/// Observed compression error of one execution-plan leg on one rank:
/// the maximum `|reconstructed − input|` over the leg's compression
/// kernels (real payloads of at most [`LEG_PROBE_MAX_ELEMS`] elements —
/// virtual size-only buffers have nothing to measure, and huge buffers
/// skip the O(n) roundtrip decode). This is the runtime evidence that
/// the leg's compressor actually honored its [`LegExec::eb`]; the
/// [`crate::comm::Communicator`] aggregates it across ranks into the
/// per-leg breakdown of its `CollectiveReport`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LegError {
    /// Leg index in the dispatched [`crate::topo::ExecPlan`].
    pub leg: usize,
    /// Max pointwise deviation of the leg's compressed streams from
    /// their inputs.
    pub observed_max_err: f64,
    /// Compression kernels that contributed observations.
    pub samples: usize,
}

/// A typed warning raised while binding one execution-plan leg: the
/// plan asked for something the configured compressor could not honor
/// (a declined [`Compressor::rebound`], an unbuildable per-leg codec),
/// and the leg fell back to the ambient compressor instead. Previously
/// these declines were silent — the leg simply ran at the wrong bound
/// with no trace in the report. The [`crate::comm::Communicator`]
/// deduplicates them across ranks into its `CollectiveReport`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LegWarning {
    /// Leg index in the dispatched [`crate::topo::ExecPlan`].
    pub leg: usize,
    /// What could not be honored, and what ran instead.
    pub message: String,
}

/// Per-rank execution context handed to a collective algorithm.
pub struct RankCtx {
    rank: usize,
    nranks: usize,
    policy: ExecPolicy,
    clock: RankClock,
    gpu: GpuDevice,
    fabric: FabricSlice,
    port: Port,
    compressor: Option<Arc<dyn Compressor>>,
    profile: CompressionProfile,
    counters: OpCounters,
    /// The execution-plan leg currently being interpreted: compress
    /// calls run at its bound and record observations under its index.
    active_leg: Option<(usize, LegExec)>,
    /// Compressor rebound to the active leg's bound, cached once per
    /// leg (`None` when the ambient compressor already runs the leg's
    /// bound, or nothing rebinds).
    leg_compressor: Option<Arc<dyn Compressor>>,
    /// Per-leg observed compression errors accumulated this run.
    leg_errors: Vec<LegError>,
    /// Typed per-leg binding warnings accumulated this run.
    leg_warnings: Vec<LegWarning>,
    /// Flight-recorder state: the shared sink plus this rank's private
    /// span buffer. `None` (the default) keeps every hook a single
    /// discriminant test.
    trace: Option<Box<CtxTrace>>,
    /// Span-derived phase sums, stashed by [`RankCtx::finish`] when a
    /// recorder was attached: [`RankCtx::breakdown`] then answers from
    /// the spans instead of keeping a parallel accounting (the two are
    /// debug-asserted identical at flush).
    span_breakdown: Option<Breakdown>,
}

/// Tracing state attached to a recording context.
struct CtxTrace {
    tracer: Tracer,
    buf: TrackBuf,
}

/// Track lane for a GPU stream: `gpu.default` or `gpu.s{i}`.
fn lane_of(s: StreamId) -> Lane {
    match s {
        StreamId::Default => Lane::Gpu(0),
        StreamId::NonDefault(i) => Lane::Gpu(1 + i as u32),
    }
}

impl RankCtx {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        rank: usize,
        nranks: usize,
        policy: ExecPolicy,
        gpu: GpuDevice,
        fabric: FabricSlice,
        port: Port,
        compressor: Option<Arc<dyn Compressor>>,
        profile: CompressionProfile,
    ) -> Self {
        RankCtx {
            rank,
            nranks,
            policy,
            clock: RankClock::new(),
            gpu,
            fabric,
            port,
            compressor,
            profile,
            counters: OpCounters::default(),
            active_leg: None,
            leg_compressor: None,
            leg_errors: Vec::new(),
            leg_warnings: Vec::new(),
            trace: None,
            span_breakdown: None,
        }
    }

    /// Attach the flight recorder: subsequent operations record spans
    /// into this rank's private buffer (track `track`), flushed into
    /// `tracer` once at [`RankCtx::finish`]. Opens the per-rank root
    /// span at the current (normally zero) virtual time.
    pub(crate) fn set_tracer(&mut self, tracer: &Tracer, track: usize) {
        let mut buf = TrackBuf::new(track);
        buf.open_root("collective", self.clock.now().as_secs());
        self.trace = Some(Box::new(CtxTrace {
            tracer: tracer.clone(),
            buf,
        }));
    }

    /// Whether a flight recorder is attached.
    pub fn tracing(&self) -> bool {
        self.trace.is_some()
    }

    /// Record a charged phase span (skipping zero-length ones — a zero
    /// charge cannot perturb the span-derived phase sums).
    #[inline]
    fn tr_span(&mut self, name: &'static str, lane: Lane, start: VirtTime, dur: f64, charge: Phase) {
        if dur <= 0.0 {
            return;
        }
        if let Some(t) = self.trace.as_mut() {
            t.buf
                .span(name, SpanCat::Phase, lane, start.as_secs(), dur, Some(charge));
        }
    }

    /// Record a device-side span that *ends* at `end` with length `dur`
    /// (kernels and copies report their completion time).
    #[inline]
    fn tr_kernel(&mut self, name: &'static str, lane: Lane, end: VirtTime, dur: f64, charge: Phase) {
        if dur <= 0.0 {
            return;
        }
        if let Some(t) = self.trace.as_mut() {
            t.buf.span(
                name,
                SpanCat::Phase,
                lane,
                end.as_secs() - dur,
                dur,
                Some(charge),
            );
        }
    }

    /// Record a (de)compression kernel span plus codec-stage child
    /// spans splitting the kernel duration evenly across the staged
    /// pipeline (uncharged — the parent already carries the CPR
    /// charge).
    fn tr_codec_kernel(
        &mut self,
        name: &'static str,
        lane: Lane,
        end: VirtTime,
        dur: f64,
        bytes: usize,
        streams: usize,
    ) {
        if self.trace.is_none() || dur <= 0.0 {
            return;
        }
        let stages: Vec<String> = self
            .effective_compressor()
            .and_then(|c| c.spec())
            .map(|s| s.label().split('+').map(str::to_string).collect())
            .unwrap_or_default();
        // Bytes (and stream count for batched launches) annotate the
        // kernel span so the calibrator can fit effective throughputs;
        // args are digest-excluded.
        let mut args = vec![("bytes", format!("{bytes}"))];
        if streams > 1 {
            args.push(("streams", format!("{streams}")));
        }
        let t = self.trace.as_mut().expect("checked above");
        t.buf.span_args(
            name,
            SpanCat::Phase,
            lane,
            end.as_secs() - dur,
            dur,
            Some(Phase::Cpr),
            args,
        );
        if stages.len() > 1 {
            let start = end.as_secs() - dur;
            let step = dur / stages.len() as f64;
            let t = self.trace.as_mut().expect("checked above");
            for (i, stage) in stages.iter().enumerate() {
                t.buf.span(
                    format!("stage:{stage}"),
                    SpanCat::Codec,
                    lane,
                    start + step * i as f64,
                    step,
                    None,
                );
            }
        }
    }

    /// Account compressed bytes in/out against the effective codec's
    /// key (feeds the derived `cpr_ratio.<codec>` gauge).
    fn tr_cpr_bytes(&mut self, in_bytes: usize, out_bytes: usize) {
        if self.trace.is_none() {
            return;
        }
        let key = match self.effective_compressor() {
            Some(c) => c.spec().map(|s| s.label()).unwrap_or_else(|| c.name().to_string()),
            None => return,
        };
        let t = self.trace.as_mut().expect("checked above");
        t.buf.counter_add(&format!("cpr_in_bytes.{key}"), in_bytes as f64);
        t.buf.counter_add(&format!("cpr_out_bytes.{key}"), out_bytes as f64);
    }

    /// Record one message's fabric path: a sender-side `wire` span
    /// covering [depart, arrival] that carries the message-edge
    /// metadata the critical-path analyzer follows (destination track,
    /// bit-exact arrival, queued time, crossing tier, bytes), plus
    /// queue-wait spans on the net lane, wire-byte counters per link
    /// class, and queue-wait histograms for every shared stage the
    /// message crossed.
    fn tr_deliver(
        &mut self,
        to: usize,
        depart: VirtTime,
        arrival: VirtTime,
        path: &DeliverPath,
        bytes: usize,
    ) {
        let rank = self.rank;
        let Some(t) = self.trace.as_mut() else { return };
        let buf = &mut t.buf;
        let dur = arrival.since(depart);
        if dur > 0.0 {
            // Track ids are rank ids offset by the tenant's base (the
            // multi-tenant runner labels track `base + rank`), so the
            // destination track is recovered from this buffer's own
            // offset.
            let base = buf.track - rank;
            let queue: f64 = path.hops.iter().map(|h| h.wait).sum();
            buf.span_args(
                "wire",
                SpanCat::Net,
                Lane::Net,
                depart.as_secs(),
                dur,
                None,
                vec![
                    ("dst", format!("{}", base + to)),
                    ("arrival", format!("{:016x}", arrival.as_secs().to_bits())),
                    ("queue_s", format!("{queue:e}")),
                    ("tier", format!("{}", path.lca)),
                    ("bytes", format!("{bytes}")),
                ],
            );
        }
        if path.lca == 0 {
            buf.counter_add("wire_bytes.intranode", bytes as f64);
            return;
        }
        buf.counter_add("wire_bytes.internode", bytes as f64);
        for tier in 2..=path.lca {
            buf.counter_add(&format!("wire_bytes.uplink_t{tier}"), bytes as f64);
        }
        for h in &path.hops {
            if h.tier == 0 {
                buf.hist_add("queue_wait_s.nic", h.wait);
            } else {
                buf.hist_add(&format!("queue_wait_s.uplink_t{}", h.tier), h.wait);
            }
            if h.wait > 0.0 {
                let name = if h.tier == 0 {
                    format!("wait:{}", h.kind)
                } else {
                    format!("wait:{}.t{}", h.kind, h.tier)
                };
                buf.span(name, SpanCat::Net, Lane::Net, h.ready, h.wait, None);
            }
        }
    }

    /// This rank's id.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Communicator size.
    pub fn nranks(&self) -> usize {
        self.nranks
    }

    /// The active variant policy.
    pub fn policy(&self) -> ExecPolicy {
        self.policy
    }

    /// The physical rank↔node layout this rank runs on. Topology-aware
    /// collectives (e.g. the two-level hierarchical Allreduce) use it
    /// to keep intranode hops on NVLink and elect node leaders.
    pub fn topology(&self) -> &Topology {
        self.fabric.topology()
    }

    /// The full multi-tier layout (node → rack → pod) this rank runs
    /// on — 2-tier unless the cluster was built with an explicit
    /// [`crate::topo::TierTree`]. The hierarchical schedule engine
    /// compiles its default schedules from this.
    pub fn tiers(&self) -> &crate::topo::TierTree {
        self.fabric.tiers()
    }

    /// Current host virtual time.
    pub fn now(&self) -> VirtTime {
        self.clock.now()
    }

    /// Whether this variant compresses at all.
    pub fn compression_enabled(&self) -> bool {
        self.policy.compression != CompressionMode::None
    }

    /// The configured compressor's absolute error bound, when it has
    /// one (error-bounded policies only). Legacy direct invocations use
    /// it to turn a bare [`crate::topo::Schedule`] into the equivalent
    /// uniform [`crate::topo::ExecPlan`].
    pub fn compressor_error_bound(&self) -> Option<f64> {
        self.compressor.as_ref().and_then(|c| c.error_bound())
    }

    /// Enter leg `leg` of the active execution plan: subsequent
    /// compress calls run the leg's own codec and bound instead of the
    /// cluster's ambient ones, and their observed quantization error is
    /// recorded under the leg's index (see [`RankCtx::leg_errors`]).
    /// The leg compressor is resolved once here, not per kernel, in
    /// three steps: an explicitly tuned codec
    /// ([`LegExec::codec_overridden`]) rebuilds the staged pipeline at
    /// the leg's bound; otherwise a differing bound rebinds the ambient
    /// compressor; otherwise the ambient compressor runs as-is. A
    /// decline anywhere (unbuildable codec, refused rebind) raises a
    /// typed [`LegWarning`] instead of silently running the wrong
    /// configuration.
    pub fn begin_leg(&mut self, leg: usize, exec: LegExec) {
        self.begin_leg_inner(leg, exec, None)
    }

    /// Chunk-aware [`RankCtx::begin_leg`]: identical compressor
    /// binding, but the leg span additionally records the pipeline
    /// chunk it executes, so traces of pipelined runs attribute each
    /// span row of a leg to its chunk. Depth-1 dispatch keeps calling
    /// [`RankCtx::begin_leg`], whose spans carry no chunk arg — the
    /// barrier executor's traces are unchanged.
    pub fn begin_leg_chunk(&mut self, leg: usize, exec: LegExec, chunk: usize) {
        self.begin_leg_inner(leg, exec, Some(chunk))
    }

    fn begin_leg_inner(&mut self, leg: usize, exec: LegExec, chunk: Option<usize>) {
        self.active_leg = Some((leg, exec));
        self.leg_compressor = None;
        if let Some(t) = self.trace.as_mut() {
            let mut args = vec![
                ("mode", format!("{:?}", exec.compression)),
                ("codec", exec.codec.label()),
                ("eb", format!("{:e}", exec.eb)),
            ];
            if let Some(c) = chunk {
                args.push(("chunk", format!("{c}")));
            }
            t.buf.open_leg(leg as u32, self.clock.now().as_secs(), args);
        }
        let Some(base) = self.compressor.clone() else {
            return;
        };
        if exec.codec_overridden() {
            match base.spec() {
                // Already the requested pipeline: only the bound below.
                Some(s) if s == exec.codec => {}
                Some(_) => match exec.codec.build(exec.eb) {
                    Some(c) => {
                        self.leg_compressor = Some(c);
                        return;
                    }
                    None => self.warn(
                        leg,
                        format!(
                            "per-leg codec '{}' unbuildable at eb {:e}; \
                             leg falls back to the ambient compressor",
                            exec.codec.label(),
                            exec.eb
                        ),
                    ),
                },
                None => self.warn(
                    leg,
                    format!(
                        "per-leg codec '{}' ignored: ambient compressor '{}' \
                         is not a staged codec",
                        exec.codec.label(),
                        base.name()
                    ),
                ),
            }
        }
        if let Some(eb) = exec.bounded_eb() {
            if base.error_bound() != Some(eb) {
                match base.rebound(eb) {
                    Some(c) => self.leg_compressor = Some(c),
                    None => self.warn(
                        leg,
                        format!(
                            "compressor '{}' declined rebinding to eb {:e}; \
                             leg runs at its ambient bound",
                            base.name(),
                            eb
                        ),
                    ),
                }
            }
        }
    }

    /// Leave per-leg mode: compress calls fall back to the ambient
    /// compressor and stop recording.
    pub fn end_leg(&mut self) {
        self.active_leg = None;
        self.leg_compressor = None;
        let now = self.clock.now().as_secs();
        if let Some(t) = self.trace.as_mut() {
            t.buf.close_leg(now);
        }
    }

    /// Per-leg observed compression errors recorded so far (empty when
    /// no execution plan was interpreted or every payload was virtual).
    pub fn leg_errors(&self) -> &[LegError] {
        &self.leg_errors
    }

    /// Typed per-leg binding warnings raised so far (deduplicated).
    pub fn leg_warnings(&self) -> &[LegWarning] {
        &self.leg_warnings
    }

    /// The staged-pipeline identity of the ambient compressor, when it
    /// is a built-in codec composition.
    pub fn compressor_spec(&self) -> Option<crate::compress::CodecSpec> {
        self.compressor.as_ref().and_then(|c| c.spec())
    }

    fn warn(&mut self, leg: usize, message: String) {
        let dup = self
            .leg_warnings
            .iter()
            .any(|w| w.leg == leg && w.message == message);
        if !dup {
            let now = self.clock.now().as_secs();
            if let Some(t) = self.trace.as_mut() {
                t.buf.instant(
                    "leg-warning",
                    now,
                    vec![("leg", leg.to_string()), ("message", message.clone())],
                );
            }
            self.leg_warnings.push(LegWarning { leg, message });
        }
    }

    /// The compressor the next kernel runs: the per-leg rebound one
    /// when the active leg's bound differs from the ambient, else the
    /// ambient compressor.
    fn effective_compressor(&self) -> Option<Arc<dyn Compressor>> {
        self.leg_compressor.clone().or_else(|| self.compressor.clone())
    }

    /// Fold one compressed stream's observed reconstruction error into
    /// the active leg's record (no-op outside per-leg mode, and capped
    /// at [`LEG_PROBE_MAX_ELEMS`] — the roundtrip decode that backs the
    /// observation is O(n), so huge payloads skip it rather than double
    /// the compression path's CPU cost).
    fn record_leg_error(&mut self, comp: &dyn Compressor, input: &[f32], stream: &[u8]) {
        let Some((leg, _)) = self.active_leg else {
            return;
        };
        if input.len() > LEG_PROBE_MAX_ELEMS {
            return;
        }
        let Ok(recon) = comp.decompress(stream) else {
            return;
        };
        let mut max_err = 0f64;
        for (a, b) in recon.iter().zip(input) {
            let d = (*a as f64 - *b as f64).abs();
            if d > max_err {
                max_err = d;
            }
        }
        match self.leg_errors.iter_mut().find(|l| l.leg == leg) {
            Some(l) => {
                l.observed_max_err = l.observed_max_err.max(max_err);
                l.samples += 1;
            }
            None => self.leg_errors.push(LegError {
                leg,
                observed_max_err: max_err,
                samples: 1,
            }),
        }
    }

    /// Operation counters so far.
    pub fn counters(&self) -> OpCounters {
        self.counters
    }

    /// Phase breakdown so far. After a traced [`RankCtx::finish`] this
    /// is the span-derived accounting (deduplicating the clock's
    /// parallel one); otherwise it reads the clock directly.
    pub fn breakdown(&self) -> Breakdown {
        self.span_breakdown.unwrap_or_else(|| self.clock.breakdown())
    }

    /// Final per-rank completion time: host joined with device drain.
    /// With a flight recorder attached this also closes the root span
    /// at exactly this timestamp and flushes the rank's buffer into the
    /// shared sink — so the max root-span end across ranks equals the
    /// run's makespan bit-for-bit, and the span-derived phase sums are
    /// asserted against the clock's own accounting.
    pub fn finish(&mut self) -> VirtTime {
        let t = self.gpu.device_free();
        self.clock.wait_until(t);
        let now = self.clock.now();
        if let Some(mut tr) = self.trace.take() {
            tr.buf.close_all(now.as_secs());
            let spans = tr.buf.breakdown();
            debug_assert_eq!(
                spans,
                self.clock.breakdown(),
                "span-derived phase sums drifted from the clock's accounting"
            );
            self.span_breakdown = Some(spans);
            tr.tracer.sink(tr.buf);
        }
        now
    }

    // ---- internal cost helpers -------------------------------------

    /// Host-side cost of issuing a kernel; returns the stream-ready
    /// dependency time.
    fn issue_cost(&mut self, s: StreamId) -> VirtTime {
        let m = *self.gpu.model();
        let mut cost = m.host_api;
        if matches!(s, StreamId::NonDefault(_)) {
            cost += m.stream_issue;
        }
        let t0 = self.clock.now();
        let t = self.clock.advance(Phase::Other, cost);
        self.tr_span("issue", Lane::Host, t0, cost, Phase::Other);
        t
    }

    /// Stock-compressor penalties (§3.3.2): per-call temp allocation
    /// (unless pooled) and the unified-memory offset buffer, which
    /// forces an implicit host-device round trip and host block.
    fn stock_compressor_penalty(&mut self) {
        let m = *self.gpu.model();
        if !self.policy.prealloc_pool {
            let t0 = self.clock.now();
            self.clock.advance(Phase::Other, m.alloc);
            self.tr_span("alloc", Lane::Host, t0, m.alloc, Phase::Other);
        }
        if !self.policy.adapted_compressor {
            // Implicit unified-memory traffic: a small offsets buffer
            // migrates both ways and the host blocks on it.
            let penalty = 2.0 * m.pcie.transfer_time(4096) + m.sync;
            let t0 = self.clock.now();
            self.clock.advance(Phase::DataMove, penalty);
            self.tr_span("umem-penalty", Lane::Host, t0, penalty, Phase::DataMove);
            self.counters.pcie_bytes += 2 * 4096;
        }
    }

    /// Apply the overlap policy after enqueueing device work: eager
    /// host sync unless overlapping.
    fn maybe_sync(&mut self, end: VirtTime) {
        if !self.policy.overlap {
            let m = *self.gpu.model();
            self.clock.wait_until(end);
            let t0 = self.clock.now();
            self.clock.advance(Phase::Other, m.sync);
            self.tr_span("sync", Lane::Host, t0, m.sync, Phase::Other);
        }
    }

    // ---- device operations ------------------------------------------

    /// Compressed size of `buf` without running the compressor (virtual
    /// mode or planning).
    pub fn predicted_compressed_size(&self, buf: &DeviceBuf) -> usize {
        if let Some(c) = &self.compressor {
            if let Some(fix) = c.fixed_output_size(buf.elems()) {
                return fix;
            }
        }
        self.profile.compressed_size(buf.bytes())
    }

    /// Launch a compression kernel on stream `s` over `buf`, with the
    /// input ready at `ready`. Returns the stream and its completion.
    pub fn compress(&mut self, s: StreamId, buf: &DeviceBuf, ready: VirtTime) -> (CompBuf, VirtTime) {
        assert!(
            self.compression_enabled(),
            "compress called under CompressionMode::None"
        );
        self.stock_compressor_penalty();
        let issue = self.issue_cost(s);
        let m = *self.gpu.model();
        let dur = m.compress.time(buf.bytes());
        let end = self.gpu.enqueue(s, ready.join(issue), dur);
        self.clock.charge_only(Phase::Cpr, dur);
        self.tr_codec_kernel("compress", lane_of(s), end, dur, buf.bytes(), 1);
        self.counters.compress_calls += 1;
        let out = match buf {
            DeviceBuf::Real(v) => {
                let c = self.effective_compressor().expect("no compressor configured");
                let stream = c.compress(v);
                self.record_leg_error(&*c, v, &stream);
                CompBuf::Real(stream)
            }
            DeviceBuf::Virtual(n) => CompBuf::Virtual {
                bytes: self.predicted_compressed_size(buf),
                elems: *n,
            },
        };
        self.tr_cpr_bytes(buf.bytes(), out.bytes());
        self.maybe_sync(end);
        (out, end)
    }

    /// §3.3.4 multi-stream compression of `chunks` as one overlapped
    /// batch (gZ-Scatter's per-destination blocks). Returns per-chunk
    /// streams and the batch completion time.
    pub fn compress_multistream(
        &mut self,
        chunks: &[DeviceBuf],
        ready: VirtTime,
    ) -> (Vec<CompBuf>, VirtTime) {
        assert!(self.compression_enabled());
        if chunks.is_empty() {
            return (vec![], ready);
        }
        let m = *self.gpu.model();
        self.stock_compressor_penalty();
        let k = chunks.len();
        let issue = if self.policy.multi_stream {
            // One issue per stream, paid by the host.
            let cost = m.host_api + m.stream_issue * k as f64;
            let t0 = self.clock.now();
            let t = self.clock.advance(Phase::Other, cost);
            self.tr_span("issue", Lane::Host, t0, cost, Phase::Other);
            t
        } else {
            self.issue_cost(StreamId::Default)
        };
        let total: usize = chunks.iter().map(|c| c.bytes()).sum();
        let dur = if self.policy.multi_stream {
            m.compress.time_multistream(total, k, m.stream_issue)
        } else {
            // Sequential kernels, each paying the utilization floor.
            chunks.iter().map(|c| m.compress.time(c.bytes())).sum()
        };
        let end = self.gpu.enqueue(StreamId::Default, ready.join(issue), dur);
        self.clock.charge_only(Phase::Cpr, dur);
        let streams = if self.policy.multi_stream { k } else { 1 };
        self.tr_codec_kernel("compress-batch", Lane::Gpu(0), end, dur, total, streams);
        self.counters.compress_calls += k;
        let comp = self.effective_compressor();
        let mut outs = Vec::with_capacity(k);
        for buf in chunks {
            match buf {
                DeviceBuf::Real(v) => {
                    let c = comp.as_ref().expect("no compressor");
                    let stream = c.compress(v);
                    self.record_leg_error(&**c, v, &stream);
                    outs.push(CompBuf::Real(stream));
                }
                DeviceBuf::Virtual(n) => outs.push(CompBuf::Virtual {
                    bytes: self.predicted_compressed_size(buf),
                    elems: *n,
                }),
            }
        }
        self.tr_cpr_bytes(total, outs.iter().map(|c| c.bytes()).sum());
        self.maybe_sync(end);
        (outs, end)
    }

    /// Launch a decompression kernel on stream `s`.
    pub fn decompress(&mut self, s: StreamId, c: &CompBuf, ready: VirtTime) -> (DeviceBuf, VirtTime) {
        assert!(self.compression_enabled());
        self.stock_compressor_penalty();
        let issue = self.issue_cost(s);
        let m = *self.gpu.model();
        let out = match c {
            CompBuf::Real(stream) => {
                // Streams are self-describing: with per-leg codecs a
                // received stream may be a different composition than
                // the ambient compressor, so dispatch on its magic
                // first; unknown formats (custom compressors) fall
                // back to the configured implementation.
                let data = match decode_any(stream) {
                    Ok(v) => v,
                    Err(_) => {
                        let comp = self.compressor.as_ref().expect("no compressor");
                        comp.decompress(stream)
                            .expect("decompress failed on a stream we produced")
                    }
                };
                DeviceBuf::Real(data)
            }
            CompBuf::Virtual { elems, .. } => DeviceBuf::Virtual(*elems),
        };
        // Decompression cost scales with the *reconstructed* size.
        let dur = m.decompress.time(out.bytes());
        let end = self.gpu.enqueue(s, ready.join(issue), dur);
        self.clock.charge_only(Phase::Cpr, dur);
        self.tr_codec_kernel("decompress", lane_of(s), end, dur, out.bytes(), 1);
        self.counters.decompress_calls += 1;
        self.maybe_sync(end);
        (out, end)
    }

    /// Elementwise-sum reduction of `a + b`. Uses the GPU kernel or the
    /// host loop depending on policy (§3.3.1). Mixed real/virtual or
    /// mismatched-length operands surface as a typed error (a
    /// misconfigured experiment) rather than a rank-thread panic.
    pub fn reduce(
        &mut self,
        s: StreamId,
        a: &DeviceBuf,
        b: &DeviceBuf,
        ready: VirtTime,
    ) -> Result<(DeviceBuf, VirtTime)> {
        let m = *self.gpu.model();
        self.counters.reduce_calls += 1;
        let out = a.add(b)?;
        if self.policy.gpu_reduce {
            let issue = self.issue_cost(s);
            let dur = m.reduce.time(out.bytes());
            let end = self.gpu.enqueue(s, ready.join(issue), dur);
            self.clock.charge_only(Phase::Redu, dur);
            self.tr_kernel("reduce", lane_of(s), end, dur, Phase::Redu);
            self.maybe_sync(end);
            Ok((out, end))
        } else {
            // Host reduction (§3.3.1's motivation): stage the device-
            // resident operand down over PCIe, reduce on the host, and
            // stage the result back. This is the DATAMOVE the paper's
            // Fig. 2 shows dominating CPU-centric designs.
            let bytes = out.bytes();
            let staged = self.gpu.copy_d2h(ready, bytes);
            self.clock.charge_only(Phase::DataMove, staged.since(ready));
            self.tr_span("d2h", Lane::D2h, ready, staged.since(ready), Phase::DataMove);
            self.counters.pcie_bytes += bytes;
            self.clock.wait_until(staged);
            let dur = bytes as f64 / m.host_reduce_beta;
            let t0 = self.clock.now();
            self.clock.advance(Phase::Redu, dur);
            self.tr_span("host-reduce", Lane::Host, t0, dur, Phase::Redu);
            let h2d_from = self.clock.now();
            let back = self.gpu.copy_h2d(h2d_from, bytes);
            self.clock.charge_only(Phase::DataMove, back.since(h2d_from));
            self.tr_span("h2d", Lane::H2d, h2d_from, back.since(h2d_from), Phase::DataMove);
            self.counters.pcie_bytes += bytes;
            self.clock.wait_until(back);
            Ok((out, back))
        }
    }

    /// Async device memset (clearing compressor temp buffers).
    pub fn memset(&mut self, s: StreamId, bytes: usize, ready: VirtTime) -> VirtTime {
        let issue = self.issue_cost(s);
        let m = *self.gpu.model();
        let dur = m.memset.time(bytes);
        let end = self.gpu.enqueue(s, ready.join(issue), dur);
        self.clock.charge_only(Phase::Other, dur);
        self.tr_kernel("memset", lane_of(s), end, dur, Phase::Other);
        self.maybe_sync(end);
        end
    }

    /// Device-to-device pack of compressed chunks into one contiguous
    /// buffer (gZ-Scatter §3.3.4). Returns the packed total size.
    pub fn pack_d2d(&mut self, parts: &[CompBuf], ready: VirtTime) -> (usize, VirtTime) {
        let total: usize = parts.iter().map(|p| p.bytes()).sum();
        let issue = self.issue_cost(StreamId::Default);
        let m = *self.gpu.model();
        let dur = if self.policy.multi_stream {
            m.d2d_copy.time_multistream(total, parts.len().max(1), m.stream_issue)
        } else {
            parts.iter().map(|p| m.d2d_copy.time(p.bytes())).sum()
        };
        let end = self.gpu.enqueue(StreamId::Default, ready.join(issue), dur);
        self.clock.charge_only(Phase::Other, dur);
        self.tr_kernel("pack", Lane::Gpu(0), end, dur, Phase::Other);
        self.maybe_sync(end);
        (total, end)
    }

    // ---- communication ----------------------------------------------

    /// Non-blocking send of `payload` to `to`, with the data ready on
    /// this rank at `ready`. CPU-centric variants stage through PCIe.
    pub fn send(&mut self, to: usize, tag: u64, payload: Payload, ready: VirtTime) {
        let bytes = payload.wire_bytes();
        let host_api = self.gpu.model().host_api;
        let t0 = self.clock.now();
        self.clock.advance(Phase::Other, host_api);
        self.tr_span("send-api", Lane::Host, t0, host_api, Phase::Other);
        let depart = if self.policy.gpu_centric {
            ready
        } else {
            // Stage device → host before the wire.
            let end = self.gpu.copy_d2h(ready, bytes);
            self.clock.charge_only(Phase::DataMove, end.since(ready));
            self.tr_span("d2h", Lane::D2h, ready, end.since(ready), Phase::DataMove);
            self.counters.pcie_bytes += bytes;
            end
        };
        let arrival = if self.tracing() {
            let mut path = DeliverPath::default();
            let arrival = self
                .fabric
                .deliver_traced(self.rank, to, bytes, depart, &mut path);
            self.tr_deliver(to, depart, arrival, &path, bytes);
            arrival
        } else {
            self.fabric.deliver(self.rank, to, bytes, depart)
        };
        self.counters.msgs_sent += 1;
        self.counters.wire_bytes += bytes;
        let msg = Msg {
            src: self.rank,
            tag,
            payload,
            arrival,
        };
        match &self.port {
            Port::Channel { senders, .. } => senders[to]
                .send(msg)
                .expect("send failed: receiver thread gone"),
            Port::Event(ep) => ep.send(to, msg),
        }
    }

    /// Receive from `from` with `tag`. Returns the payload and the time
    /// at which the data is usable **on the device** (after H2D staging
    /// for CPU-centric variants). The host blocks (thread backend) or
    /// the rank's future suspends (event backend) until arrival; the
    /// wait is charged to COMM. This is the crate's only suspension
    /// point — everything a collective awaits bottoms out here.
    pub async fn recv(&mut self, from: usize, tag: u64) -> (Payload, VirtTime) {
        let msg = match &mut self.port {
            Port::Channel { mailbox, .. } => mailbox.recv(from, tag),
            Port::Event(ep) => ep.recv(from, tag).await,
        };
        let t0 = self.clock.now();
        self.clock.wait_charged(Phase::Comm, msg.arrival);
        let wait = msg.arrival.since(t0);
        if wait > 0.0 {
            // The source track and bit-exact arrival key the wire edge
            // the critical-path walk hops across (args are excluded
            // from the digest, so backend equivalence is untouched).
            let (rank, src) = (self.rank, msg.src);
            if let Some(t) = self.trace.as_mut() {
                let base = t.buf.track - rank;
                t.buf.span_args(
                    "recv-wait",
                    SpanCat::Phase,
                    Lane::Host,
                    t0.as_secs(),
                    wait,
                    Some(Phase::Comm),
                    vec![
                        ("src", format!("{}", base + src)),
                        ("arrival", format!("{:016x}", msg.arrival.as_secs().to_bits())),
                    ],
                );
            }
        }
        let mut usable = msg.arrival;
        if !self.policy.gpu_centric {
            let bytes = msg.payload.wire_bytes();
            let end = self.gpu.copy_h2d(usable, bytes);
            self.clock.charge_only(Phase::DataMove, end.since(usable));
            self.tr_span("h2d", Lane::H2d, usable, end.since(usable), Phase::DataMove);
            self.counters.pcie_bytes += bytes;
            usable = end;
        }
        (msg.payload, usable)
    }

    /// Receive, asserting a raw (uncompressed) payload.
    pub async fn recv_raw(&mut self, from: usize, tag: u64) -> (DeviceBuf, VirtTime) {
        match self.recv(from, tag).await {
            (Payload::Raw(b), t) => (b, t),
            (p, _) => panic!("expected Raw payload, got {p:?}"),
        }
    }

    /// Receive, asserting a compressed payload.
    pub async fn recv_comp(&mut self, from: usize, tag: u64) -> (CompBuf, VirtTime) {
        match self.recv(from, tag).await {
            (Payload::Comp(c), t) => (c, t),
            (p, _) => panic!("expected Comp payload, got {p:?}"),
        }
    }

    /// Receive, asserting a metadata payload.
    pub async fn recv_meta(&mut self, from: usize, tag: u64) -> (Vec<u64>, VirtTime) {
        match self.recv(from, tag).await {
            (Payload::Meta(v), t) => (v, t),
            (p, _) => panic!("expected Meta payload, got {p:?}"),
        }
    }

    /// Receive, asserting a compressed-batch payload.
    pub async fn recv_batch(&mut self, from: usize, tag: u64) -> (Vec<CompBuf>, VirtTime) {
        match self.recv(from, tag).await {
            (Payload::Batch(v), t) => (v, t),
            (p, _) => panic!("expected Batch payload, got {p:?}"),
        }
    }

    // ---- synchronization ---------------------------------------------

    /// Host-synchronize with stream `s`.
    pub fn sync_stream(&mut self, s: StreamId) {
        let m = *self.gpu.model();
        let t = self.gpu.stream_free(s);
        self.clock.wait_until(t);
        let t0 = self.clock.now();
        self.clock.advance(Phase::Other, m.sync);
        self.tr_span("sync", Lane::Host, t0, m.sync, Phase::Other);
    }

    /// Host-synchronize with the whole device.
    pub fn sync_device(&mut self) {
        let m = *self.gpu.model();
        let t = self.gpu.device_free();
        self.clock.wait_until(t);
        let t0 = self.clock.now();
        self.clock.advance(Phase::Other, m.sync);
        self.tr_span("sync", Lane::Host, t0, m.sync, Phase::Other);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CuszpLike;
    use crate::gpu::GpuModel;
    use crate::net::{Fabric, Topology};

    fn mk_ctx(policy: ExecPolicy) -> RankCtx {
        let topo = Topology::new(2, 2).unwrap();
        let fabric = Fabric::default_cluster(topo);
        let (senders, mut boxes) = super::super::mailbox::build_mesh(2);
        let mb = boxes.remove(0);
        RankCtx::new(
            0,
            2,
            policy,
            GpuDevice::new(GpuModel::a100(), 2),
            FabricSlice::whole(fabric),
            Port::Channel {
                senders: senders[0].clone(),
                mailbox: mb,
            },
            Some(Arc::new(CuszpLike::new(1e-4))),
            CompressionProfile::fixed(20.0),
        )
    }

    #[test]
    fn real_compress_round_trip_through_ctx() {
        let mut ctx = mk_ctx(ExecPolicy::gzccl());
        let data: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.01).sin()).collect();
        let buf = DeviceBuf::Real(data.clone());
        let (c, t1) = ctx.compress(StreamId::Default, &buf, VirtTime::ZERO);
        assert!(t1 > VirtTime::ZERO);
        let (back, t2) = ctx.decompress(StreamId::Default, &c, t1);
        assert!(t2 > t1);
        for (a, b) in back.as_real().iter().zip(data.iter()) {
            assert!((a - b).abs() <= 1e-4 + 1e-7);
        }
        assert_eq!(ctx.counters().compress_calls, 1);
        assert_eq!(ctx.counters().decompress_calls, 1);
        assert!(ctx.breakdown().cpr > 0.0);
    }

    #[test]
    fn virtual_compress_uses_profile() {
        let mut ctx = mk_ctx(ExecPolicy::gzccl());
        let buf = DeviceBuf::Virtual(1_000_000);
        let (c, _) = ctx.compress(StreamId::Default, &buf, VirtTime::ZERO);
        // profile ratio 20 → ~200 KB + overhead.
        let sz = c.bytes();
        assert!((200_000..210_000).contains(&sz), "size {sz}");
        let (back, _) = ctx.decompress(StreamId::Default, &c, VirtTime::ZERO);
        assert_eq!(back.elems(), 1_000_000);
    }

    #[test]
    fn no_overlap_blocks_host() {
        let mut a = mk_ctx(ExecPolicy::gpu_centric_unoptimized());
        let buf = DeviceBuf::Virtual(50 << 20);
        a.compress(StreamId::Default, &buf, VirtTime::ZERO);
        // Host advanced past the kernel duration.
        let kernel = a.gpu.model().compress.time(buf.bytes());
        assert!(a.now().as_secs() >= kernel);

        let mut b = mk_ctx(ExecPolicy::gzccl());
        b.compress(StreamId::NonDefault(0), &buf, VirtTime::ZERO);
        // Overlapping host returns immediately after issue.
        assert!(b.now().as_secs() < kernel);
    }

    #[test]
    fn cpu_centric_reduce_on_host_charges_redu() {
        let mut ctx = mk_ctx(ExecPolicy::cray_mpi());
        let a = DeviceBuf::Virtual(10 << 20);
        let b = DeviceBuf::Virtual(10 << 20);
        let t0 = ctx.now();
        let (_, end) = ctx.reduce(StreamId::Default, &a, &b, t0).unwrap();
        // Host-blocking: the clock advanced to the end.
        assert_eq!(ctx.now(), end);
        assert!(ctx.breakdown().redu > 0.0);
    }

    #[test]
    fn stock_compressor_pays_datamove_penalty() {
        let mut stock = mk_ctx(ExecPolicy::gpu_centric_unoptimized());
        let buf = DeviceBuf::Virtual(1 << 20);
        stock.compress(StreamId::Default, &buf, VirtTime::ZERO);
        assert!(stock.breakdown().datamove > 0.0, "unified-mem penalty");

        let mut adapted = mk_ctx(ExecPolicy::gzccl());
        adapted.compress(StreamId::Default, &buf, VirtTime::ZERO);
        assert_eq!(adapted.breakdown().datamove, 0.0);
    }

    #[test]
    fn multistream_batch_faster_than_sequential() {
        let chunks: Vec<DeviceBuf> = (0..8).map(|_| DeviceBuf::Virtual(1 << 18)).collect();
        let mut multi = mk_ctx(ExecPolicy::gzccl());
        let (_, t_multi) = multi.compress_multistream(&chunks, VirtTime::ZERO);
        let mut seq = mk_ctx(ExecPolicy::gpu_centric_unoptimized());
        let (_, t_seq) = seq.compress_multistream(&chunks, VirtTime::ZERO);
        assert!(
            t_multi.as_secs() < 0.6 * t_seq.as_secs(),
            "multi {t_multi} vs seq {t_seq}"
        );
        assert_eq!(multi.counters().compress_calls, 8);
    }

    #[test]
    fn mixed_mode_reduce_is_error_not_panic() {
        let mut ctx = mk_ctx(ExecPolicy::gzccl());
        let a = DeviceBuf::Real(vec![1.0]);
        let b = DeviceBuf::Virtual(1);
        assert!(ctx.reduce(StreamId::Default, &a, &b, VirtTime::ZERO).is_err());
    }

    #[test]
    fn topology_is_exposed() {
        let ctx = mk_ctx(ExecPolicy::gzccl());
        assert_eq!(ctx.topology().ranks(), 2);
        assert_eq!(ctx.topology().gpus_per_node(), 2);
    }

    #[test]
    fn per_leg_codec_override_binds_and_decodes() {
        use crate::compress::CodecSpec;
        let mut ctx = mk_ctx(ExecPolicy::gzccl());
        let data: Vec<f32> = (0..500).map(|i| (i as f32 * 0.02).cos()).collect();
        ctx.begin_leg(1, LegExec::with_codec(CodecSpec::lossless(), 0.0));
        let buf = DeviceBuf::Real(data.clone());
        let (c, t) = ctx.compress(StreamId::Default, &buf, VirtTime::ZERO);
        // Lossless leg: the stream decodes bit-exactly even though the
        // ambient compressor is the error-bounded cuszp pipeline.
        let (back, _) = ctx.decompress(StreamId::Default, &c, t);
        for (a, b) in back.as_real().iter().zip(data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        ctx.end_leg();
        assert!(ctx.leg_warnings().is_empty());
        let le = ctx.leg_errors().iter().find(|l| l.leg == 1).unwrap();
        assert_eq!(le.observed_max_err, 0.0);
    }

    #[test]
    fn declined_rebind_raises_a_typed_warning() {
        // FixedRate has no per-call bound to rebind; an error-bounded
        // leg directive against it used to silently run the ambient
        // rate with no trace in the report.
        let topo = Topology::new(2, 2).unwrap();
        let fabric = Fabric::default_cluster(topo);
        let (senders, mut boxes) = super::super::mailbox::build_mesh(2);
        let mut ctx = RankCtx::new(
            0,
            2,
            ExecPolicy::cprp2p(),
            GpuDevice::new(GpuModel::a100(), 2),
            FabricSlice::whole(fabric),
            Port::Channel {
                senders: senders[0].clone(),
                mailbox: boxes.remove(0),
            },
            Some(Arc::new(crate::compress::FixedRate::new(8))),
            CompressionProfile::fixed(4.0),
        );
        let exec = LegExec {
            compression: CompressionMode::ErrorBounded,
            codec: LegExec::default_codec(CompressionMode::ErrorBounded),
            eb: 1e-3,
        };
        ctx.begin_leg(0, exec);
        assert_eq!(ctx.leg_warnings().len(), 1);
        assert!(ctx.leg_warnings()[0].message.contains("declined"));
        // Re-entering the same leg does not duplicate the warning.
        ctx.begin_leg(0, exec);
        assert_eq!(ctx.leg_warnings().len(), 1);
    }

    #[test]
    fn fixed_rate_predicted_size_is_exact() {
        let topo = Topology::new(2, 2).unwrap();
        let fabric = Fabric::default_cluster(topo);
        let (senders, mut boxes) = super::super::mailbox::build_mesh(2);
        let mut ctx = RankCtx::new(
            0,
            2,
            ExecPolicy::cprp2p(),
            GpuDevice::new(GpuModel::a100(), 2),
            FabricSlice::whole(fabric),
            Port::Channel {
                senders: senders[0].clone(),
                mailbox: boxes.remove(0),
            },
            Some(Arc::new(crate::compress::FixedRate::new(8))),
            CompressionProfile::fixed(4.0),
        );
        let real = DeviceBuf::Real(vec![1.0f32; 320]);
        let predicted = ctx.predicted_compressed_size(&real);
        let (c, _) = ctx.compress(StreamId::Default, &real, VirtTime::ZERO);
        assert_eq!(c.bytes(), predicted);
    }
}
