//! Device buffers: real or virtual payloads.
//!
//! Collective algorithms are written once and run in two modes:
//!
//! * **Real** — the buffer holds actual f32s; compression, reduction and
//!   transfers move real bytes. Used for correctness/accuracy runs.
//! * **Virtual** — only the element count is tracked. Used for the
//!   paper-scale sweeps (512 ranks × 646 MB) where real payloads would
//!   need hundreds of GB. Compressed sizes then come from a measured
//!   [`crate::compress::CompressionProfile`].
//!
//! Mixing modes in one collective is a configuration bug; the mixing
//! operations ([`DeviceBuf::add`], [`DeviceBuf::concat`]) return a
//! typed [`Error`] so a misconfigured experiment fails with a report
//! instead of aborting a rank thread.

use crate::error::{Error, Result};

/// A buffer resident on the (simulated) GPU.
#[derive(Debug, Clone, PartialEq)]
pub enum DeviceBuf {
    /// Real payload.
    Real(Vec<f32>),
    /// Size-only payload (element count).
    Virtual(usize),
}

impl DeviceBuf {
    /// Number of f32 elements.
    pub fn elems(&self) -> usize {
        match self {
            DeviceBuf::Real(v) => v.len(),
            DeviceBuf::Virtual(n) => *n,
        }
    }

    /// Size in bytes.
    pub fn bytes(&self) -> usize {
        self.elems() * 4
    }

    /// Whether this is a virtual (size-only) buffer.
    pub fn is_virtual(&self) -> bool {
        matches!(self, DeviceBuf::Virtual(_))
    }

    /// A zero-filled buffer in the same mode as `self`.
    pub fn zeros_like(&self, elems: usize) -> DeviceBuf {
        match self {
            DeviceBuf::Real(_) => DeviceBuf::Real(vec![0.0; elems]),
            DeviceBuf::Virtual(_) => DeviceBuf::Virtual(elems),
        }
    }

    /// Copy out a sub-range (device-to-device slice).
    pub fn slice(&self, range: std::ops::Range<usize>) -> DeviceBuf {
        match self {
            DeviceBuf::Real(v) => DeviceBuf::Real(v[range].to_vec()),
            DeviceBuf::Virtual(n) => {
                assert!(range.end <= *n, "virtual slice out of range");
                DeviceBuf::Virtual(range.len())
            }
        }
    }

    /// Concatenate `parts` (all in the same mode). Mixing real and
    /// virtual buffers is a misconfiguration and yields a typed error.
    pub fn concat(parts: &[DeviceBuf]) -> Result<DeviceBuf> {
        if parts.is_empty() {
            return Err(Error::collective("concat of zero device buffers"));
        }
        if parts[0].is_virtual() {
            if parts.iter().any(|p| !p.is_virtual()) {
                return Err(Error::collective(
                    "mixed real/virtual concat: virtual lead buffer with real parts",
                ));
            }
            Ok(DeviceBuf::Virtual(parts.iter().map(|p| p.elems()).sum()))
        } else {
            let mut out = Vec::with_capacity(parts.iter().map(|p| p.elems()).sum());
            for p in parts {
                match p {
                    DeviceBuf::Real(v) => out.extend_from_slice(v),
                    DeviceBuf::Virtual(_) => {
                        return Err(Error::collective(
                            "mixed real/virtual concat: real lead buffer with virtual parts",
                        ))
                    }
                }
            }
            Ok(DeviceBuf::Real(out))
        }
    }

    /// Elementwise sum: `self + other` (the Allreduce reduction op).
    /// Mixed-mode or mismatched-length operands yield a typed error.
    pub fn add(&self, other: &DeviceBuf) -> Result<DeviceBuf> {
        if self.elems() != other.elems() {
            return Err(Error::collective(format!(
                "reduce length mismatch: {} vs {} elems",
                self.elems(),
                other.elems()
            )));
        }
        match (self, other) {
            (DeviceBuf::Real(a), DeviceBuf::Real(b)) => Ok(DeviceBuf::Real(
                a.iter().zip(b.iter()).map(|(x, y)| x + y).collect(),
            )),
            (DeviceBuf::Virtual(n), DeviceBuf::Virtual(_)) => Ok(DeviceBuf::Virtual(*n)),
            _ => Err(Error::collective(
                "mixed real/virtual reduce: one operand is a size-only buffer",
            )),
        }
    }

    /// Access the real payload (panics on virtual buffers).
    pub fn as_real(&self) -> &[f32] {
        match self {
            DeviceBuf::Real(v) => v,
            DeviceBuf::Virtual(_) => panic!("as_real on a virtual buffer"),
        }
    }

    /// Consume into the real payload (panics on virtual buffers).
    pub fn into_real(self) -> Vec<f32> {
        match self {
            DeviceBuf::Real(v) => v,
            DeviceBuf::Virtual(_) => panic!("into_real on a virtual buffer"),
        }
    }
}

/// A compressed byte stream on the (simulated) GPU.
#[derive(Debug, Clone, PartialEq)]
pub enum CompBuf {
    /// Real compressed stream.
    Real(Vec<u8>),
    /// Size-only stream: (compressed bytes, original element count).
    Virtual {
        /// Compressed size in bytes.
        bytes: usize,
        /// Original (uncompressed) element count.
        elems: usize,
    },
}

impl CompBuf {
    /// Compressed size in bytes (what travels on the wire).
    pub fn bytes(&self) -> usize {
        match self {
            CompBuf::Real(v) => v.len(),
            CompBuf::Virtual { bytes, .. } => *bytes,
        }
    }

    /// Whether this is a virtual stream.
    pub fn is_virtual(&self) -> bool {
        matches!(self, CompBuf::Virtual { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_buffer_ops() {
        let b = DeviceBuf::Real(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b.elems(), 4);
        assert_eq!(b.bytes(), 16);
        assert_eq!(b.slice(1..3), DeviceBuf::Real(vec![2.0, 3.0]));
        let sum = b.add(&DeviceBuf::Real(vec![10.0, 10.0, 10.0, 10.0])).unwrap();
        assert_eq!(sum.as_real(), &[11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn virtual_buffer_ops() {
        let b = DeviceBuf::Virtual(100);
        assert_eq!(b.elems(), 100);
        assert_eq!(b.slice(10..30).elems(), 20);
        assert_eq!(b.add(&DeviceBuf::Virtual(100)).unwrap().elems(), 100);
        assert!(b.zeros_like(5).is_virtual());
    }

    #[test]
    fn concat_both_modes() {
        let r = DeviceBuf::concat(&[
            DeviceBuf::Real(vec![1.0]),
            DeviceBuf::Real(vec![2.0, 3.0]),
        ])
        .unwrap();
        assert_eq!(r.as_real(), &[1.0, 2.0, 3.0]);
        let v = DeviceBuf::concat(&[DeviceBuf::Virtual(3), DeviceBuf::Virtual(4)]).unwrap();
        assert_eq!(v.elems(), 7);
    }

    #[test]
    fn mixed_mode_reduce_is_typed_error() {
        let err = DeviceBuf::Real(vec![1.0]).add(&DeviceBuf::Virtual(1)).unwrap_err();
        assert!(matches!(err, Error::Collective(_)), "{err}");
        assert!(err.to_string().contains("mixed real/virtual"));
    }

    #[test]
    fn length_mismatch_is_typed_error() {
        let err = DeviceBuf::Real(vec![1.0])
            .add(&DeviceBuf::Real(vec![1.0, 2.0]))
            .unwrap_err();
        assert!(err.to_string().contains("length mismatch"), "{err}");
    }

    #[test]
    fn mixed_mode_concat_is_typed_error() {
        for parts in [
            vec![DeviceBuf::Real(vec![1.0]), DeviceBuf::Virtual(1)],
            vec![DeviceBuf::Virtual(1), DeviceBuf::Real(vec![1.0])],
        ] {
            let err = DeviceBuf::concat(&parts).unwrap_err();
            assert!(matches!(err, Error::Collective(_)), "{err}");
        }
        assert!(DeviceBuf::concat(&[]).is_err());
    }

    #[test]
    fn compbuf_sizes() {
        assert_eq!(CompBuf::Real(vec![0u8; 7]).bytes(), 7);
        assert_eq!(
            CompBuf::Virtual {
                bytes: 9,
                elems: 100
            }
            .bytes(),
            9
        );
    }
}
