//! The collective-program abstraction shared by both execution
//! backends.
//!
//! A [`Program`] is what each rank executes: an async function from
//! (rank context, input buffer) to an output buffer. The async-ness is
//! the whole trick — `recv` is the *only* suspension point in the
//! crate, so a rank program compiles (via the ordinary Rust state
//! machine transform) into exactly the resumable per-rank state
//! machine the event engine needs, while the thread backend simply
//! drives the same future to completion with a blocking executor
//! ([`block_on`]) whose `recv` never actually suspends.
//!
//! Plain `fn` items of the shape
//! `fn(&mut RankCtx, DeviceBuf) -> ProgFut<'_>` implement [`Program`]
//! through the blanket impl below, so call sites like
//! `run_collective(&spec, inputs, &allreduce_ring)` keep working
//! unchanged. Programs that need captured state (a compiled
//! [`crate::topo::Schedule`], a scatter root, …) implement the trait
//! on a small named struct instead of a closure.

use std::future::Future;
use std::pin::Pin;
use std::task::{Context, Poll, RawWaker, RawWakerVTable, Waker};

use crate::error::Result;

use super::buffer::DeviceBuf;
use super::ctx::RankCtx;

/// The future a rank program returns: boxed so programs are
/// object-safe, lifetime-tied to the borrow of the rank context.
pub type ProgFut<'a> = Pin<Box<dyn Future<Output = Result<DeviceBuf>> + 'a>>;

/// A collective program: what each rank executes. `Sync` so one
/// program value can be shared by every rank (threads or actors).
pub trait Program: Sync {
    /// Start the program on one rank. The returned future borrows
    /// `ctx` until it completes.
    fn run<'a>(&'a self, ctx: &'a mut RankCtx, input: DeviceBuf) -> ProgFut<'a>;
}

/// Every `Fn`-shaped program (in particular plain `fn` items like
/// `allreduce_ring`) is a [`Program`].
impl<F> Program for F
where
    F: for<'a> Fn(&'a mut RankCtx, DeviceBuf) -> ProgFut<'a> + Sync,
{
    fn run<'b>(&'b self, ctx: &'b mut RankCtx, input: DeviceBuf) -> ProgFut<'b> {
        (self)(ctx, input)
    }
}

/// Object-safe alias used wherever a program is type-erased (the algo
/// registry hands out `Box<RankProgram>`).
pub type RankProgram = dyn Program;

fn noop_raw_waker() -> RawWaker {
    fn clone(_: *const ()) -> RawWaker {
        noop_raw_waker()
    }
    fn wake(_: *const ()) {}
    fn wake_by_ref(_: *const ()) {}
    fn drop(_: *const ()) {}
    static VTABLE: RawWakerVTable = RawWakerVTable::new(clone, wake, wake_by_ref, drop);
    RawWaker::new(std::ptr::null(), &VTABLE)
}

/// A waker that does nothing: both backends schedule by their own
/// bookkeeping (blocking recv / message-arrival heap), never through
/// the waker protocol.
pub(crate) fn noop_waker() -> Waker {
    unsafe { Waker::from_raw(noop_raw_waker()) }
}

/// Drive a program future to completion on the current thread.
///
/// Under the thread backend the channel-mode `recv` blocks *inside*
/// `poll`, so the future is ready on the first poll by construction;
/// `Pending` here means a program suspended on an event-mode await
/// while running on the thread backend — a wiring bug, not a runtime
/// condition, hence the panic.
pub(crate) fn block_on<T>(fut: impl Future<Output = T>) -> T {
    let mut fut = Box::pin(fut);
    let waker = noop_waker();
    let mut cx = Context::from_waker(&waker);
    match fut.as_mut().poll(&mut cx) {
        Poll::Ready(v) => v,
        Poll::Pending => panic!("thread-backend program suspended: event-mode port on a thread rank"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_on_drives_plain_futures() {
        let v = block_on(async { 40 + 2 });
        assert_eq!(v, 42);
    }

    #[test]
    fn fn_items_are_programs() {
        // Compile-time check: a fn item of the program shape satisfies
        // the trait bound without any adapter.
        fn ident(_ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
            Box::pin(async move { Ok(input) })
        }
        fn takes_program<P: Program + ?Sized>(_p: &P) {}
        takes_program(&ident);
    }
}
