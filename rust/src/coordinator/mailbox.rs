//! Point-to-point message plumbing between rank threads.
//!
//! Each rank owns one receiver; senders are cloneable. Matching is by
//! `(source, tag)` with an out-of-order hold queue, i.e. MPI-style
//! non-overtaking per (src, tag) pairs.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};

use crate::sim::VirtTime;

use super::buffer::{CompBuf, DeviceBuf};

/// What a message carries.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Uncompressed device data (baseline variants).
    Raw(DeviceBuf),
    /// Compressed stream.
    Comp(CompBuf),
    /// A packed batch of per-block compressed streams (gZ-Scatter sends
    /// subtree block ranges as one contiguous message; blocks stay
    /// individually decodable so intermediate ranks can forward
    /// sub-ranges without recompressing).
    Batch(Vec<CompBuf>),
    /// Small control metadata (e.g. gZ-Scatter's size/offset arrays).
    Meta(Vec<u64>),
}

impl Payload {
    /// Bytes this payload occupies on the wire.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::Raw(b) => b.bytes(),
            Payload::Comp(c) => c.bytes(),
            Payload::Batch(v) => v.iter().map(|c| c.bytes()).sum(),
            Payload::Meta(v) => v.len() * 8,
        }
    }
}

/// A virtual-time message.
#[derive(Debug, Clone)]
pub struct Msg {
    /// Sending rank.
    pub src: usize,
    /// User tag (collectives use the round number).
    pub tag: u64,
    /// The payload.
    pub payload: Payload,
    /// Virtual arrival timestamp (fabric-computed).
    pub arrival: VirtTime,
}

/// Receiving end with (src, tag) matching.
pub struct Mailbox {
    rx: Receiver<Msg>,
    held: HashMap<(usize, u64), VecDeque<Msg>>,
}

impl Mailbox {
    /// Blocking receive of the next message from `src` with `tag`.
    pub fn recv(&mut self, src: usize, tag: u64) -> Msg {
        if let Some(q) = self.held.get_mut(&(src, tag)) {
            if let Some(m) = q.pop_front() {
                return m;
            }
        }
        loop {
            let m = self
                .rx
                .recv()
                .expect("mailbox: peer threads hung up (rank panicked?)");
            if m.src == src && m.tag == tag {
                return m;
            }
            self.held.entry((m.src, m.tag)).or_default().push_back(m);
        }
    }
}

/// Build the full N×N mesh: `senders[i][j]` sends to rank j (from i —
/// all rows are clones), `boxes[i]` is rank i's mailbox.
pub fn build_mesh(n: usize) -> (Vec<Vec<Sender<Msg>>>, Vec<Mailbox>) {
    let mut txs = Vec::with_capacity(n);
    let mut boxes = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(tx);
        boxes.push(Mailbox {
            rx,
            held: HashMap::new(),
        });
    }
    let senders = (0..n).map(|_| txs.clone()).collect();
    (senders, boxes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(src: usize, tag: u64) -> Msg {
        Msg {
            src,
            tag,
            payload: Payload::Meta(vec![tag]),
            arrival: VirtTime::ZERO,
        }
    }

    #[test]
    fn in_order_delivery() {
        let (senders, mut boxes) = build_mesh(2);
        senders[0][1].send(msg(0, 1)).unwrap();
        senders[0][1].send(msg(0, 2)).unwrap();
        let b = &mut boxes[1];
        assert_eq!(b.recv(0, 1).tag, 1);
        assert_eq!(b.recv(0, 2).tag, 2);
    }

    #[test]
    fn out_of_order_matching() {
        let (senders, mut boxes) = build_mesh(3);
        // Rank 2 receives from 0 and 1; messages arrive interleaved.
        senders[1][2].send(msg(1, 7)).unwrap();
        senders[0][2].send(msg(0, 7)).unwrap();
        let b = &mut boxes[2];
        // Ask for rank 1 last: rank 1's msg is held while matching 0.
        assert_eq!(b.recv(0, 7).src, 0);
        assert_eq!(b.recv(1, 7).src, 1);
    }

    #[test]
    fn same_src_different_tags() {
        let (senders, mut boxes) = build_mesh(2);
        senders[0][1].send(msg(0, 5)).unwrap();
        senders[0][1].send(msg(0, 3)).unwrap();
        let b = &mut boxes[1];
        assert_eq!(b.recv(0, 3).tag, 3);
        assert_eq!(b.recv(0, 5).tag, 5);
    }

    #[test]
    fn cross_thread_send_recv() {
        let (senders, mut boxes) = build_mesh(2);
        let tx = senders[0][1].clone();
        let h = std::thread::spawn(move || {
            tx.send(msg(0, 42)).unwrap();
        });
        let m = boxes[1].recv(0, 42);
        assert_eq!(m.tag, 42);
        h.join().unwrap();
    }

    #[test]
    fn payload_wire_bytes() {
        assert_eq!(Payload::Raw(DeviceBuf::Virtual(10)).wire_bytes(), 40);
        assert_eq!(Payload::Comp(CompBuf::Real(vec![0; 5])).wire_bytes(), 5);
        assert_eq!(Payload::Meta(vec![1, 2]).wire_bytes(), 16);
    }
}
