//! The coordinator: rank runtime, execution policies, and the runner.
//!
//! This is the L3 home of the paper's system contribution. Ranks
//! execute *real* payload dataflow while all *timing* is virtual,
//! charged against calibrated GPU/network cost models. Collectives are
//! async [`program::Program`]s; the runner executes them on one of two
//! backends ([`runner::ExecBackend`]): scoped OS threads over
//! [`mailbox`] channels (the reference oracle) or the event-driven
//! [`crate::engine`] (the default, linear in events rather than
//! ranks × stacks). Variant policies ([`ctx::ExecPolicy`]) toggle
//! exactly the design decisions the paper studies: GPU-centric
//! buffering (§3.3.1), the adapted compressor (§3.3.2), overlap and
//! multi-stream compression (§3.3.4).

pub mod buffer;
pub mod ctx;
pub mod mailbox;
pub mod program;
pub mod runner;

pub use buffer::{CompBuf, DeviceBuf};
pub use ctx::{
    CompressionMode, ExecPolicy, LegError, LegWarning, OpCounters, RankCtx, LEG_PROBE_MAX_ELEMS,
};
pub use mailbox::{Msg, Payload};
pub use program::{ProgFut, Program, RankProgram};
pub use runner::{run_collective, ClusterSpec, ExecBackend, RunReport};
