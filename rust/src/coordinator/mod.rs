//! The coordinator: rank runtime, execution policies, and the runner.
//!
//! This is the L3 home of the paper's system contribution. A collective
//! run spawns one thread per simulated GPU rank; ranks exchange *real*
//! payloads through [`mailbox`] channels while all *timing* is virtual,
//! charged against calibrated GPU/network cost models. Variant policies
//! ([`ctx::ExecPolicy`]) toggle exactly the design decisions the paper
//! studies: GPU-centric buffering (§3.3.1), the adapted compressor
//! (§3.3.2), overlap and multi-stream compression (§3.3.4).

pub mod buffer;
pub mod ctx;
pub mod mailbox;
pub mod runner;

pub use buffer::{CompBuf, DeviceBuf};
pub use ctx::{
    CompressionMode, ExecPolicy, LegError, OpCounters, RankCtx, LEG_PROBE_MAX_ELEMS,
};
pub use mailbox::{Msg, Payload};
pub use runner::{run_collective, ClusterSpec, RankProgram, RunReport};
