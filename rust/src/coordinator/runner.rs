//! The cluster runner: spawns one thread per rank, wires the mesh,
//! executes a collective program, and aggregates the run report.

use std::sync::Arc;

use crate::compress::{CompressionProfile, Compressor, CuszpLike, FixedRate};
use crate::error::{Error, Result};
use crate::gpu::{GpuDevice, GpuModel};
use crate::net::{default_uplinks, Fabric, LinkModel, Topology};
use crate::sim::{Breakdown, VirtTime};
use crate::topo::TierTree;

use super::buffer::DeviceBuf;
use super::ctx::{CompressionMode, ExecPolicy, LegError, OpCounters, RankCtx};
use super::mailbox::build_mesh;

/// Everything needed to instantiate a simulated cluster.
#[derive(Clone)]
pub struct ClusterSpec {
    /// Rank layout (2-tier node-level view; kept in sync with `tiers`).
    pub topo: Topology,
    /// Full multi-tier layout (equals `TierTree::from(&topo)` unless
    /// set via [`ClusterSpec::with_tiers`] / [`ClusterSpec::set_tiers`]).
    pub tiers: TierTree,
    /// Device model (A100-calibrated by default).
    pub gpu: GpuModel,
    /// Intranode link.
    pub intranode: LinkModel,
    /// Internode link.
    pub internode: LinkModel,
    /// Shared uplink models for tiers ≥ 2 (empty on 2-tier layouts).
    pub uplinks: Vec<LinkModel>,
    /// Variant policy.
    pub policy: ExecPolicy,
    /// Absolute error bound for the error-bounded compressor.
    pub error_bound: f64,
    /// Bits/value for the fixed-rate compressor (CPRP2P).
    pub fixed_rate_bits: u32,
    /// Size profile for virtual-payload runs.
    pub profile: CompressionProfile,
    /// Non-default streams created per rank.
    pub streams_per_rank: usize,
}

impl ClusterSpec {
    /// A spec over `ranks` GPUs (4/node) with the given policy and
    /// paper-testbed defaults everywhere else.
    pub fn new(ranks: usize, policy: ExecPolicy) -> Self {
        Self::with_topology(Topology::new(ranks, 4).expect("ranks > 0"), policy)
    }

    /// A spec over an already-validated topology with paper-testbed
    /// defaults everywhere else (the panic-free constructor the
    /// [`crate::comm::CommBuilder`] uses).
    pub fn with_topology(topo: Topology, policy: ExecPolicy) -> Self {
        let tiers = TierTree::from(&topo);
        ClusterSpec {
            topo,
            tiers,
            gpu: GpuModel::a100(),
            intranode: LinkModel::nvlink_default(),
            internode: LinkModel::slingshot10_default(),
            uplinks: vec![],
            policy,
            error_bound: 1e-4,
            fixed_rate_bits: 8,
            profile: CompressionProfile::fixed(25.0),
            streams_per_rank: 4,
        }
    }

    /// A spec over a multi-tier layout: the 2-tier `topo` view is
    /// derived from the tree and default uplink models are attached
    /// for every tier above node level.
    pub fn with_tiers(tiers: TierTree, policy: ExecPolicy) -> Self {
        let mut spec = Self::with_topology(tiers.to_topology(), policy);
        spec.set_tiers(tiers);
        spec
    }

    /// Replace the tier layout, keeping `topo` and the uplink models in
    /// sync (existing uplink overrides are preserved where the depth
    /// allows, default models fill the rest).
    pub fn set_tiers(&mut self, tiers: TierTree) {
        self.topo = tiers.to_topology();
        let mut uplinks = default_uplinks(tiers.depth());
        for (slot, keep) in uplinks.iter_mut().zip(self.uplinks.iter()) {
            *slot = *keep;
        }
        self.uplinks = uplinks;
        self.tiers = tiers;
    }

    /// The per-tier link models, innermost first:
    /// `[intranode, internode, uplinks…]`.
    pub fn tier_links(&self) -> Vec<LinkModel> {
        let mut links = vec![self.intranode, self.internode];
        links.extend(self.uplinks.iter().copied());
        links
    }

    /// Override the error bound.
    pub fn with_error_bound(mut self, eb: f64) -> Self {
        self.error_bound = eb;
        self
    }

    /// Override the size profile (virtual runs).
    pub fn with_profile(mut self, p: CompressionProfile) -> Self {
        self.profile = p;
        self
    }

    fn make_compressor(&self) -> Option<Arc<dyn Compressor>> {
        match self.policy.compression {
            CompressionMode::None => None,
            CompressionMode::ErrorBounded => Some(Arc::new(CuszpLike::new(self.error_bound))),
            CompressionMode::FixedRate => Some(Arc::new(FixedRate::new(self.fixed_rate_bits))),
        }
    }
}

/// Result of one collective run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Per-rank output buffers.
    pub outputs: Vec<DeviceBuf>,
    /// Virtual makespan: the latest rank completion (host + device).
    pub makespan: VirtTime,
    /// Per-rank phase breakdowns.
    pub breakdowns: Vec<Breakdown>,
    /// Per-rank op counters.
    pub counters: Vec<OpCounters>,
    /// Per-leg observed compression errors, merged across ranks (max
    /// deviation per leg, summed sample counts). Empty unless the
    /// program interpreted an execution plan over real payloads.
    pub leg_errors: Vec<LegError>,
}

impl RunReport {
    /// Sum of all per-rank breakdowns.
    pub fn total_breakdown(&self) -> Breakdown {
        self.breakdowns
            .iter()
            .fold(Breakdown::new(), |acc, b| acc + *b)
    }

    /// Total bytes placed on the wire by all ranks.
    pub fn total_wire_bytes(&self) -> usize {
        self.counters.iter().map(|c| c.wire_bytes).sum()
    }

    /// Total compression + decompression kernel invocations.
    pub fn total_cpr_calls(&self) -> usize {
        self.counters
            .iter()
            .map(|c| c.compress_calls + c.decompress_calls)
            .sum()
    }
}

/// A collective program: what each rank executes. Receives the rank's
/// context and its input buffer; returns the rank's output buffer.
pub type RankProgram = dyn Fn(&mut RankCtx, DeviceBuf) -> Result<DeviceBuf> + Sync;

/// Run `program` on every rank of the cluster described by `spec`, with
/// `inputs[r]` as rank r's input. Threads execute the *real* data flow;
/// time is virtual.
pub fn run_collective(
    spec: &ClusterSpec,
    inputs: Vec<DeviceBuf>,
    program: &RankProgram,
) -> Result<RunReport> {
    let n = spec.topo.ranks();
    if inputs.len() != n {
        return Err(Error::coordinator(format!(
            "inputs.len()={} != ranks={}",
            inputs.len(),
            n
        )));
    }
    let fabric = Fabric::tiered(
        spec.tiers.clone(),
        spec.intranode,
        spec.internode,
        spec.uplinks.clone(),
    );
    let (senders, boxes) = build_mesh(n);
    let compressor = spec.make_compressor();

    type RankOutcome = (DeviceBuf, VirtTime, Breakdown, OpCounters, Vec<LegError>);
    let mut results: Vec<Option<Result<RankOutcome>>> = (0..n).map(|_| None).collect();

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n);
        let mut boxes = boxes;
        let mut inputs = inputs;
        // Drain in reverse to pop from the back cheaply.
        for rank in (0..n).rev() {
            let mailbox = boxes.pop().unwrap();
            let input = inputs.pop().unwrap();
            let senders = senders[rank].clone();
            let fabric = fabric.clone();
            let compressor = compressor.clone();
            let spec = &*spec;
            handles.push((
                rank,
                scope.spawn(move || {
                    let gpu = GpuDevice::new(spec.gpu, spec.streams_per_rank);
                    let mut ctx = RankCtx::new(
                        rank,
                        n,
                        spec.policy,
                        gpu,
                        fabric,
                        senders,
                        mailbox,
                        compressor,
                        spec.profile.clone(),
                    );
                    let out = program(&mut ctx, input)?;
                    let finish = ctx.finish();
                    let legs = ctx.leg_errors().to_vec();
                    Ok((out, finish, ctx.breakdown(), ctx.counters(), legs))
                }),
            ));
        }
        for (rank, h) in handles {
            let res = h
                .join()
                .unwrap_or_else(|_| Err(Error::coordinator(format!("rank {rank} panicked"))));
            results[rank] = Some(res);
        }
    });

    let mut outputs = Vec::with_capacity(n);
    let mut breakdowns = Vec::with_capacity(n);
    let mut counters = Vec::with_capacity(n);
    let mut leg_errors: Vec<LegError> = Vec::new();
    let mut makespan = VirtTime::ZERO;
    for r in results.into_iter() {
        let (out, finish, bd, ct, legs) = r.expect("missing rank result")?;
        outputs.push(out);
        makespan = makespan.join(finish);
        breakdowns.push(bd);
        counters.push(ct);
        for le in legs {
            match leg_errors.iter_mut().find(|m| m.leg == le.leg) {
                Some(m) => {
                    m.observed_max_err = m.observed_max_err.max(le.observed_max_err);
                    m.samples += le.samples;
                }
                None => leg_errors.push(le),
            }
        }
    }
    leg_errors.sort_by_key(|l| l.leg);
    Ok(RunReport {
        outputs,
        makespan,
        breakdowns,
        counters,
        leg_errors,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::mailbox::Payload;
    use crate::sim::VirtTime;

    #[test]
    fn identity_program_runs_all_ranks() {
        let spec = ClusterSpec::new(8, ExecPolicy::nccl());
        let inputs: Vec<DeviceBuf> = (0..8).map(|_| DeviceBuf::Virtual(1024)).collect();
        let report = run_collective(&spec, inputs, &|_ctx, input| Ok(input)).unwrap();
        assert_eq!(report.outputs.len(), 8);
        assert_eq!(report.makespan, VirtTime::ZERO);
    }

    #[test]
    fn neighbor_exchange_makespan_and_bytes() {
        // Every even rank sends 1 MB to rank+1 (intranode pairs).
        let spec = ClusterSpec::new(4, ExecPolicy::nccl());
        let inputs: Vec<DeviceBuf> = (0..4).map(|_| DeviceBuf::Virtual(1 << 18)).collect();
        let report = run_collective(&spec, inputs, &|ctx, input| {
            let r = ctx.rank();
            if r % 2 == 0 {
                ctx.send(r + 1, 0, Payload::Raw(input.clone()), ctx.now());
            } else {
                let (_buf, _t) = ctx.recv_raw(r - 1, 0);
            }
            Ok(input)
        })
        .unwrap();
        assert!(report.makespan > VirtTime::ZERO);
        assert_eq!(report.total_wire_bytes(), 2 << 20);
        // Receivers charged comm.
        assert!(report.breakdowns[1].comm > 0.0);
        assert_eq!(report.breakdowns[0].comm, 0.0);
    }

    #[test]
    fn rank_error_propagates() {
        let spec = ClusterSpec::new(2, ExecPolicy::nccl());
        let inputs: Vec<DeviceBuf> = (0..2).map(|_| DeviceBuf::Virtual(8)).collect();
        let res = run_collective(&spec, inputs, &|ctx, input| {
            if ctx.rank() == 1 {
                Err(Error::collective("boom"))
            } else {
                Ok(input)
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn mismatched_inputs_rejected() {
        let spec = ClusterSpec::new(4, ExecPolicy::nccl());
        let res = run_collective(&spec, vec![DeviceBuf::Virtual(8)], &|_c, i| Ok(i));
        assert!(res.is_err());
    }

    #[test]
    fn internode_exchange_slower_than_intranode() {
        let bytes = 8 << 20;
        let time_between = |a: usize, b: usize| {
            let spec = ClusterSpec::new(8, ExecPolicy::nccl());
            let inputs: Vec<DeviceBuf> = (0..8).map(|_| DeviceBuf::Virtual(bytes / 4)).collect();
            run_collective(&spec, inputs, &move |ctx, input| {
                if ctx.rank() == a {
                    ctx.send(b, 0, Payload::Raw(input.clone()), ctx.now());
                } else if ctx.rank() == b {
                    ctx.recv_raw(a, 0);
                }
                Ok(input)
            })
            .unwrap()
            .makespan
        };
        let intra = time_between(0, 1);
        let inter = time_between(0, 4);
        assert!(
            inter.as_secs() > 5.0 * intra.as_secs(),
            "inter {inter} intra {intra}"
        );
    }
}
