//! The cluster runner: instantiates a simulated cluster, executes a
//! collective program on every rank, and aggregates the run report.
//!
//! Two interchangeable backends execute the same [`Program`]:
//!
//! * [`ExecBackend::Threads`] — one OS thread per rank over mpsc
//!   channels, the original reference oracle. Each thread drives its
//!   rank's future with a blocking executor; `recv` blocks inside the
//!   mailbox.
//! * [`ExecBackend::Events`] (default) — the [`crate::engine`]: ranks
//!   are resumable state machines on one event scheduler, no OS
//!   threads, memory and wall time linear in events. This is what
//!   makes 10⁴–10⁵-rank topologies simulable.
//!
//! Both produce bit-identical payloads and identical makespans: the
//! payload dataflow never branches on timing, and the fabric's
//! interval timelines allocate the earliest free gap independent of
//! wall-clock arrival order.

use std::sync::Arc;

use crate::compress::{CodecSpec, CompressionProfile, Compressor, CuszpLike, FixedRate};
use crate::error::{Error, Result};
use crate::gpu::{GpuDevice, GpuModel};
use crate::net::{default_uplinks, Fabric, FabricSlice, LinkModel, Topology};
use crate::sim::{Breakdown, VirtTime};
use crate::topo::TierTree;

use super::buffer::DeviceBuf;
use super::ctx::{CompressionMode, ExecPolicy, LegError, LegWarning, OpCounters, Port, RankCtx};
use super::mailbox::{build_mesh, Mailbox};
use super::program::{block_on, Program};

/// Which execution backend runs a collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// One OS thread per rank (reference oracle; caps out near 512
    /// ranks on thread-stack memory).
    Threads,
    /// Event-driven engine: ranks as futures on one scheduler.
    #[default]
    Events,
}

impl std::fmt::Display for ExecBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecBackend::Threads => write!(f, "threads"),
            ExecBackend::Events => write!(f, "events"),
        }
    }
}

/// Everything needed to instantiate a simulated cluster.
#[derive(Clone)]
pub struct ClusterSpec {
    /// Rank layout (2-tier node-level view; kept in sync with `tiers`).
    pub topo: Topology,
    /// Full multi-tier layout (equals `TierTree::from(&topo)` unless
    /// set via [`ClusterSpec::with_tiers`] / [`ClusterSpec::set_tiers`]).
    pub tiers: TierTree,
    /// Device model (A100-calibrated by default).
    pub gpu: GpuModel,
    /// Intranode link.
    pub intranode: LinkModel,
    /// Internode link.
    pub internode: LinkModel,
    /// Shared uplink models for tiers ≥ 2 (empty on 2-tier layouts).
    pub uplinks: Vec<LinkModel>,
    /// Variant policy.
    pub policy: ExecPolicy,
    /// Absolute error bound for the error-bounded compressor.
    pub error_bound: f64,
    /// Bits/value for the fixed-rate compressor (CPRP2P).
    pub fixed_rate_bits: u32,
    /// Ambient staged codec. `None` keeps the mode's canonical
    /// compressor (cuSZp-like for error-bounded, fixed-rate for CPRP2P);
    /// `Some` builds the staged pipeline at the spec's bound instead.
    pub codec: Option<CodecSpec>,
    /// Size profile for virtual-payload runs.
    pub profile: CompressionProfile,
    /// Non-default streams created per rank.
    pub streams_per_rank: usize,
    /// Which execution backend runs collectives on this cluster.
    pub backend: ExecBackend,
    /// Flight-recorder sink. `None` (the default) disables tracing:
    /// every recording hook is one `Option` discriminant test.
    pub trace: Option<crate::obs::Tracer>,
}

impl ClusterSpec {
    /// A spec over `ranks` GPUs (4/node) with the given policy and
    /// paper-testbed defaults everywhere else.
    pub fn new(ranks: usize, policy: ExecPolicy) -> Self {
        Self::with_topology(Topology::new(ranks, 4).expect("ranks > 0"), policy)
    }

    /// A spec over an already-validated topology with paper-testbed
    /// defaults everywhere else (the panic-free constructor the
    /// [`crate::comm::CommBuilder`] uses).
    pub fn with_topology(topo: Topology, policy: ExecPolicy) -> Self {
        let tiers = TierTree::from(&topo);
        ClusterSpec {
            topo,
            tiers,
            gpu: GpuModel::a100(),
            intranode: LinkModel::nvlink_default(),
            internode: LinkModel::slingshot10_default(),
            uplinks: vec![],
            policy,
            error_bound: 1e-4,
            fixed_rate_bits: 8,
            codec: None,
            profile: CompressionProfile::fixed(25.0),
            streams_per_rank: 4,
            backend: ExecBackend::default(),
            trace: None,
        }
    }

    /// A spec over a multi-tier layout: the 2-tier `topo` view is
    /// derived from the tree and default uplink models are attached
    /// for every tier above node level.
    pub fn with_tiers(tiers: TierTree, policy: ExecPolicy) -> Self {
        let mut spec = Self::with_topology(tiers.to_topology(), policy);
        spec.set_tiers(tiers);
        spec
    }

    /// Replace the tier layout, keeping `topo` and the uplink models in
    /// sync (existing uplink overrides are preserved where the depth
    /// allows, default models fill the rest).
    pub fn set_tiers(&mut self, tiers: TierTree) {
        self.topo = tiers.to_topology();
        let mut uplinks = default_uplinks(tiers.depth());
        for (slot, keep) in uplinks.iter_mut().zip(self.uplinks.iter()) {
            *slot = *keep;
        }
        self.uplinks = uplinks;
        self.tiers = tiers;
    }

    /// The per-tier link models, innermost first:
    /// `[intranode, internode, uplinks…]`.
    pub fn tier_links(&self) -> Vec<LinkModel> {
        let mut links = vec![self.intranode, self.internode];
        links.extend(self.uplinks.iter().copied());
        links
    }

    /// Override the error bound.
    pub fn with_error_bound(mut self, eb: f64) -> Self {
        self.error_bound = eb;
        self
    }

    /// Override the ambient staged codec.
    pub fn with_codec(mut self, codec: CodecSpec) -> Self {
        self.codec = Some(codec);
        self
    }

    /// Override the size profile (virtual runs).
    pub fn with_profile(mut self, p: CompressionProfile) -> Self {
        self.profile = p;
        self
    }

    /// Override the execution backend.
    pub fn with_backend(mut self, backend: ExecBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Attach a flight-recorder sink: every rank records spans and
    /// metrics into `tracer` (see [`crate::obs`]).
    pub fn with_trace(mut self, tracer: crate::obs::Tracer) -> Self {
        self.trace = Some(tracer);
        self
    }

    pub(crate) fn make_compressor(&self) -> Option<Arc<dyn Compressor>> {
        if self.policy.compression != CompressionMode::None {
            if let Some(built) = self.codec.and_then(|spec| spec.build(self.error_bound)) {
                return Some(built);
            }
        }
        match self.policy.compression {
            CompressionMode::None => None,
            CompressionMode::ErrorBounded => Some(Arc::new(CuszpLike::new(self.error_bound))),
            CompressionMode::FixedRate => Some(Arc::new(FixedRate::new(self.fixed_rate_bits))),
        }
    }
}

/// Result of one collective run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Per-rank output buffers.
    pub outputs: Vec<DeviceBuf>,
    /// Virtual makespan: the latest rank completion (host + device).
    pub makespan: VirtTime,
    /// Per-rank phase breakdowns.
    pub breakdowns: Vec<Breakdown>,
    /// Per-rank op counters.
    pub counters: Vec<OpCounters>,
    /// Per-leg observed compression errors, merged across ranks (max
    /// deviation per leg, summed sample counts). Empty unless the
    /// program interpreted an execution plan over real payloads.
    pub leg_errors: Vec<LegError>,
    /// Per-leg execution warnings (declined rebinds, unbuildable codec
    /// overrides), deduplicated across ranks by leg and message.
    pub leg_warnings: Vec<LegWarning>,
}

impl RunReport {
    /// Sum of all per-rank breakdowns. Under tracing the per-rank
    /// values are the span-derived sums ([`RankCtx::finish`] stashes
    /// them at flush), so this and `obs::TraceRun::total_breakdown`
    /// are one accounting, not two parallel ones.
    pub fn total_breakdown(&self) -> Breakdown {
        self.breakdowns
            .iter()
            .fold(Breakdown::new(), |acc, b| acc + *b)
    }

    /// Total bytes placed on the wire by all ranks.
    pub fn total_wire_bytes(&self) -> usize {
        self.counters.iter().map(|c| c.wire_bytes).sum()
    }

    /// Total compression + decompression kernel invocations.
    pub fn total_cpr_calls(&self) -> usize {
        self.counters
            .iter()
            .map(|c| c.compress_calls + c.decompress_calls)
            .sum()
    }
}

/// What one rank's execution produces, on either backend.
pub(crate) type RankOutcome = (
    DeviceBuf,
    VirtTime,
    Breakdown,
    OpCounters,
    Vec<LegError>,
    Vec<LegWarning>,
);

/// Fold per-rank outcomes (in rank order) into a [`RunReport`]: the
/// first rank error wins, makespan is the join of completions, leg
/// errors merge by max deviation / summed samples, warnings dedupe by
/// leg and message.
pub(crate) fn merge_outcomes(results: Vec<Result<RankOutcome>>) -> Result<RunReport> {
    let n = results.len();
    let mut outputs = Vec::with_capacity(n);
    let mut breakdowns = Vec::with_capacity(n);
    let mut counters = Vec::with_capacity(n);
    let mut leg_errors: Vec<LegError> = Vec::new();
    let mut leg_warnings: Vec<LegWarning> = Vec::new();
    let mut makespan = VirtTime::ZERO;
    for r in results {
        let (out, finish, bd, ct, legs, warns) = r?;
        outputs.push(out);
        makespan = makespan.join(finish);
        breakdowns.push(bd);
        counters.push(ct);
        for le in legs {
            match leg_errors.iter_mut().find(|m| m.leg == le.leg) {
                Some(m) => {
                    m.observed_max_err = m.observed_max_err.max(le.observed_max_err);
                    m.samples += le.samples;
                }
                None => leg_errors.push(le),
            }
        }
        for w in warns {
            if !leg_warnings.contains(&w) {
                leg_warnings.push(w);
            }
        }
    }
    leg_errors.sort_by_key(|l| l.leg);
    leg_warnings.sort_by(|a, b| (a.leg, &a.message).cmp(&(b.leg, &b.message)));
    Ok(RunReport {
        outputs,
        makespan,
        breakdowns,
        counters,
        leg_errors,
        leg_warnings,
    })
}

/// Run `program` on every rank of the cluster described by `spec`, with
/// `inputs[r]` as rank r's input, on the spec's [`ExecBackend`]. Ranks
/// execute the *real* data flow; time is virtual.
pub fn run_collective<P: Program + ?Sized>(
    spec: &ClusterSpec,
    inputs: Vec<DeviceBuf>,
    program: &P,
) -> Result<RunReport> {
    let n = spec.topo.ranks();
    if inputs.len() != n {
        return Err(Error::coordinator(format!(
            "inputs.len()={} != ranks={}",
            inputs.len(),
            n
        )));
    }
    match spec.backend {
        ExecBackend::Threads => run_threads(spec, inputs, program),
        ExecBackend::Events => crate::engine::run_events(spec, inputs, program),
    }
}

/// The thread backend: one scoped OS thread per rank, channel mesh,
/// blocking recv. Kept as the reference oracle the event engine is
/// property-tested against.
fn run_threads<P: Program + ?Sized>(
    spec: &ClusterSpec,
    inputs: Vec<DeviceBuf>,
    program: &P,
) -> Result<RunReport> {
    let n = spec.topo.ranks();
    let fabric = Fabric::tiered(
        spec.tiers.clone(),
        spec.intranode,
        spec.internode,
        spec.uplinks.clone(),
    );
    let (senders, mut boxes) = build_mesh(n);
    let compressor = spec.make_compressor();

    // Drain the mesh into per-rank slots *before* spawning: a malformed
    // mesh surfaces as a typed coordinator error, not a panic inside
    // the scoped-thread join.
    if senders.len() != n {
        return Err(Error::coordinator(format!(
            "mesh underflow: {} sender rows for {} ranks",
            senders.len(),
            n
        )));
    }
    let mut inputs = inputs;
    let mut per_rank: Vec<(usize, Mailbox, DeviceBuf)> = Vec::with_capacity(n);
    for rank in (0..n).rev() {
        let mailbox = boxes.pop().ok_or_else(|| {
            Error::coordinator(format!("mesh underflow: no mailbox for rank {rank}"))
        })?;
        let input = inputs
            .pop()
            .ok_or_else(|| Error::coordinator(format!("no input buffer for rank {rank}")))?;
        per_rank.push((rank, mailbox, input));
    }

    let mut results: Vec<Option<Result<RankOutcome>>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n);
        for (rank, mailbox, input) in per_rank {
            let senders = senders[rank].clone();
            let fabric = fabric.clone();
            let compressor = compressor.clone();
            let spec = &*spec;
            handles.push((
                rank,
                scope.spawn(move || {
                    let gpu = GpuDevice::new(spec.gpu, spec.streams_per_rank);
                    let mut ctx = RankCtx::new(
                        rank,
                        n,
                        spec.policy,
                        gpu,
                        FabricSlice::whole(fabric),
                        Port::Channel { senders, mailbox },
                        compressor,
                        spec.profile.clone(),
                    );
                    if let Some(tr) = &spec.trace {
                        ctx.set_tracer(tr, rank);
                    }
                    let out = block_on(program.run(&mut ctx, input))?;
                    let finish = ctx.finish();
                    let legs = ctx.leg_errors().to_vec();
                    let warns = ctx.leg_warnings().to_vec();
                    Ok((out, finish, ctx.breakdown(), ctx.counters(), legs, warns))
                }),
            ));
        }
        for (rank, h) in handles {
            let res = h
                .join()
                .unwrap_or_else(|_| Err(Error::coordinator(format!("rank {rank} panicked"))));
            results[rank] = Some(res);
        }
    });

    let results: Vec<Result<RankOutcome>> = results
        .into_iter()
        .enumerate()
        .map(|(rank, r)| {
            r.unwrap_or_else(|| Err(Error::coordinator(format!("rank {rank} produced no result"))))
        })
        .collect();
    merge_outcomes(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::mailbox::Payload;
    use crate::coordinator::program::ProgFut;
    use crate::sim::VirtTime;

    fn ident(_ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
        Box::pin(async move { Ok(input) })
    }

    fn neighbor(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
        Box::pin(async move {
            let r = ctx.rank();
            if r % 2 == 0 {
                ctx.send(r + 1, 0, Payload::Raw(input.clone()), ctx.now());
            } else {
                let (_buf, _t) = ctx.recv_raw(r - 1, 0).await;
            }
            Ok(input)
        })
    }

    /// Rank `a` sends its buffer to rank `b`.
    struct Exchange {
        a: usize,
        b: usize,
    }

    impl Program for Exchange {
        fn run<'x>(&'x self, ctx: &'x mut RankCtx, input: DeviceBuf) -> ProgFut<'x> {
            Box::pin(async move {
                if ctx.rank() == self.a {
                    ctx.send(self.b, 0, Payload::Raw(input.clone()), ctx.now());
                } else if ctx.rank() == self.b {
                    ctx.recv_raw(self.a, 0).await;
                }
                Ok(input)
            })
        }
    }

    fn both_backends() -> [ExecBackend; 2] {
        [ExecBackend::Threads, ExecBackend::Events]
    }

    #[test]
    fn identity_program_runs_all_ranks() {
        for backend in both_backends() {
            let spec = ClusterSpec::new(8, ExecPolicy::nccl()).with_backend(backend);
            let inputs: Vec<DeviceBuf> = (0..8).map(|_| DeviceBuf::Virtual(1024)).collect();
            let report = run_collective(&spec, inputs, &ident).unwrap();
            assert_eq!(report.outputs.len(), 8, "{backend}");
            assert_eq!(report.makespan, VirtTime::ZERO, "{backend}");
        }
    }

    #[test]
    fn neighbor_exchange_makespan_and_bytes() {
        // Every even rank sends 1 MB to rank+1 (intranode pairs).
        for backend in both_backends() {
            let spec = ClusterSpec::new(4, ExecPolicy::nccl()).with_backend(backend);
            let inputs: Vec<DeviceBuf> = (0..4).map(|_| DeviceBuf::Virtual(1 << 18)).collect();
            let report = run_collective(&spec, inputs, &neighbor).unwrap();
            assert!(report.makespan > VirtTime::ZERO, "{backend}");
            assert_eq!(report.total_wire_bytes(), 2 << 20, "{backend}");
            // Receivers charged comm.
            assert!(report.breakdowns[1].comm > 0.0, "{backend}");
            assert_eq!(report.breakdowns[0].comm, 0.0, "{backend}");
        }
    }

    #[test]
    fn backends_agree_on_makespan() {
        let run = |backend: ExecBackend| {
            let spec = ClusterSpec::new(4, ExecPolicy::nccl()).with_backend(backend);
            let inputs: Vec<DeviceBuf> = (0..4).map(|_| DeviceBuf::Virtual(1 << 18)).collect();
            run_collective(&spec, inputs, &neighbor).unwrap().makespan
        };
        assert_eq!(run(ExecBackend::Threads), run(ExecBackend::Events));
    }

    #[test]
    fn rank_error_propagates() {
        fn failing(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
            Box::pin(async move {
                if ctx.rank() == 1 {
                    Err(Error::collective("boom"))
                } else {
                    Ok(input)
                }
            })
        }
        for backend in both_backends() {
            let spec = ClusterSpec::new(2, ExecPolicy::nccl()).with_backend(backend);
            let inputs: Vec<DeviceBuf> = (0..2).map(|_| DeviceBuf::Virtual(8)).collect();
            let res = run_collective(&spec, inputs, &failing);
            assert!(res.is_err(), "{backend}");
        }
    }

    #[test]
    fn mismatched_inputs_rejected() {
        for backend in both_backends() {
            let spec = ClusterSpec::new(4, ExecPolicy::nccl()).with_backend(backend);
            let res = run_collective(&spec, vec![DeviceBuf::Virtual(8)], &ident);
            assert!(res.is_err(), "{backend}");
        }
    }

    #[test]
    fn internode_exchange_slower_than_intranode() {
        let bytes = 8 << 20;
        let time_between = |a: usize, b: usize| {
            let spec = ClusterSpec::new(8, ExecPolicy::nccl());
            let inputs: Vec<DeviceBuf> = (0..8).map(|_| DeviceBuf::Virtual(bytes / 4)).collect();
            run_collective(&spec, inputs, &Exchange { a, b })
                .unwrap()
                .makespan
        };
        let intra = time_between(0, 1);
        let inter = time_between(0, 4);
        assert!(
            inter.as_secs() > 5.0 * intra.as_secs(),
            "inter {inter} intra {intra}"
        );
    }
}
