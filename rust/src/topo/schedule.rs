//! The hierarchical schedule engine: compile a [`TierTree`] + op into
//! per-tier legs, cost them, and walk their error propagation.
//!
//! A [`Schedule`] is plain data — an ascent of per-tier legs toward the
//! tree's top, one collective leg across the top tier's participants,
//! and a mirrored descent — that the executor in
//! [`crate::collectives::hierarchical`] interprets against a
//! [`crate::coordinator::RankCtx`]. Because the schedule is data, the
//! same structure serves four consumers:
//!
//! * the **executor** runs it (send/recv/compress per leg),
//! * the **cost model** ([`Schedule::estimate_makespan`]) prices it
//!   against a physical tree with per-tier links and uplink
//!   oversubscription — what [`crate::comm::Tuner`] uses for its
//!   per-tier crossover,
//! * the **error model** ([`Schedule::amplification`],
//!   [`Schedule::tier_sensitivities`]) walks the same legs so the
//!   accuracy planner can split a per-call budget across tiers, and
//! * the **stage counter** ([`Schedule::cpr_stages_at`]) predicts
//!   per-rank compression-kernel counts for tests and telemetry.
//!
//! Two compilers: [`compile_min_error`] picks the fewest-error leg per
//! tier (linear reduce-to-leader ascent, doubling top — what budgeted
//! dispatch runs, and what the planner's amplification anchors on);
//! [`compile_tuned`] picks each tier's leg from the cost model (ring
//! vs. recursive doubling at the top, gather-fold vs. in-group
//! doubling on middle tiers — ZCCL's per-level ring/doubling choice).
//! Compression never touches tier 0 (NVLink — the gZCCL raw-intranode
//! invariant); every higher leg compresses when the policy does.

use crate::collectives::Op;
use crate::compress::{CodecSpec, CoderKind, PredictorKind, QuantizerKind};
use crate::error::{Error, Result};
use crate::gpu::GpuModel;
use crate::net::LinkModel;

use super::tier_tree::TierTree;

/// What a leg does within each tier-`tier` group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LegKind {
    /// Ascent: every participant ships its vector to the group leader,
    /// which folds them in rank order (linear error accumulation).
    ReduceToLeader,
    /// In-group recursive-doubling Allreduce over the participants
    /// (MPICH remainder fold for non-power-of-two counts).
    AllreduceRedoub,
    /// In-group chunked ring Allreduce (reduce-scatter + allgather)
    /// over the participants.
    AllreduceRing,
    /// Descent: the leader's vector reaches every participant —
    /// compressed legs forward one compress-once stream along a
    /// binomial tree; raw legs fan out directly (NVLink).
    BcastFromLeader,
    /// Allgather ascent: participants ship their gathered blocks to the
    /// leader, which concatenates them in rank order.
    GatherToLeader,
    /// In-group ring Allgather over the participants (each origin
    /// super-block compressed once, forwarded verbatim).
    AllgatherRing,
    /// Reduce_scatter descent: the leader slices its vector by the
    /// participants' subtree chunk ranges and sends each its share.
    ScatterFromLeader,
    /// Rooted-op prologue for a non-zero root: the root ships its full
    /// vector to rank 0 (the global leader every descent starts from).
    /// Only the root and rank 0 take part — everyone else idles.
    RootShift,
}

/// One per-tier leg of a compiled schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Leg {
    /// Tier whose groups this leg runs within (participants are the
    /// leaders of the tier-`tier − 1` subgroups; everyone at tier 0).
    pub tier: usize,
    /// What the leg does.
    pub kind: LegKind,
    /// Whether payloads on this leg are compressed.
    pub compressed: bool,
    /// The staged codec the cost model priced this leg at, when the
    /// compiler chose one ([`compile_tuned`]'s per-leg codec pass).
    /// `None` means "whatever the dispatching policy runs" — the
    /// canonical error-bounded codec when pricing.
    pub codec: Option<CodecSpec>,
}

/// A compiled hierarchical schedule: the grouping tree the legs refer
/// to (possibly a [`TierTree::collapsed`] view of the physical tree)
/// plus the leg sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// The operation the schedule realizes.
    pub op: Op,
    /// The grouping the legs index into.
    pub tree: TierTree,
    /// Ascent legs, the top leg, then descent legs.
    pub legs: Vec<Leg>,
    /// Root rank for rooted ops ([`Op::Bcast`], [`Op::Scatter`]) — the
    /// rank whose buffer seeds the descent. Always `0` for unrooted
    /// ops; a non-zero root compiles a [`LegKind::RootShift`] prologue.
    pub root: usize,
}

fn ceil_log2(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        (n - 1).ilog2() as usize + 1
    }
}

/// Effective `e' = 2e + eb` stages of a recursive-doubling exchange
/// over `groups` participants, including the two extra MPICH
/// fold/unfold stages for non-power-of-two counts. The **single**
/// definition of the recurrence depth — `crate::accuracy::propagation`
/// imports it, so the schedule walk and the flat-algorithm error model
/// cannot drift apart.
pub(crate) fn doubling_error_stages(groups: usize) -> usize {
    if groups <= 1 {
        return 0;
    }
    let logp = groups.ilog2() as usize;
    logp + if groups.is_power_of_two() { 0 } else { 2 }
}

/// `2^s − 1` in f64 without overflowing for degenerate huge `s`.
pub(crate) fn pow2_minus_1(s: usize) -> f64 {
    if s < 53 {
        ((1u64 << s) - 1) as f64
    } else {
        2f64.powi(s.min(1000) as i32)
    }
}

/// Whether a tier's payloads compress: never on tier 0 (NVLink — raw
/// intranode is the gZCCL invariant), on every higher tier when the
/// policy compresses at all.
fn tier_compressed(policy_compresses: bool, tier: usize) -> bool {
    policy_compresses && tier >= 1
}

/// Compile the fewest-error schedule for `op` on `tree`: linear
/// reduce-to-leader (or gather) ascent, recursive doubling (or ring
/// allgather) across the top tier, mirrored broadcast/scatter descent.
/// This is what budgeted dispatch runs and what
/// [`Schedule::amplification`]-based planning anchors on — for every
/// tier the chosen leg has the smallest worst-case amplification among
/// the implemented alternatives.
pub fn compile_min_error(op: Op, tree: &TierTree, compressed: bool) -> Result<Schedule> {
    compile_rooted(op, tree, compressed, 0)
}

/// Compile the fewest-error schedule for `op` on `tree` with an
/// explicit `root`. Unrooted ops (Allreduce, Reduce_scatter, Allgather)
/// ignore the root; the rooted ops (Bcast, Scatter) compile a pure
/// top-down descent — every tier runs its compress-once
/// broadcast/scatter leg — prefixed by a [`LegKind::RootShift`] when
/// the root is not rank 0 (the leader the descent starts from).
pub fn compile_rooted(op: Op, tree: &TierTree, compressed: bool, root: usize) -> Result<Schedule> {
    if root >= tree.ranks() {
        return Err(Error::collective(format!(
            "root {root} out of range for {} ranks",
            tree.ranks()
        )));
    }
    let d = tree.depth();
    let mut legs = Vec::with_capacity(2 * d);
    match op {
        Op::Allreduce | Op::ReduceScatter | Op::Allgather => {
            for t in 0..d - 1 {
                legs.push(Leg {
                    tier: t,
                    kind: match op {
                        Op::Allgather => LegKind::GatherToLeader,
                        _ => LegKind::ReduceToLeader,
                    },
                    compressed: tier_compressed(compressed, t),
                    codec: None,
                });
            }
            legs.push(Leg {
                tier: d - 1,
                kind: match op {
                    Op::Allgather => LegKind::AllgatherRing,
                    _ => LegKind::AllreduceRedoub,
                },
                compressed: tier_compressed(compressed, d - 1),
                codec: None,
            });
            for t in (0..d - 1).rev() {
                legs.push(Leg {
                    tier: t,
                    kind: match op {
                        Op::ReduceScatter => LegKind::ScatterFromLeader,
                        _ => LegKind::BcastFromLeader,
                    },
                    compressed: tier_compressed(compressed, t),
                    codec: None,
                });
            }
            return Ok(Schedule {
                op,
                tree: tree.clone(),
                legs,
                root: 0,
            });
        }
        Op::Bcast | Op::Scatter => {
            if root != 0 {
                legs.push(Leg {
                    tier: d - 1,
                    kind: LegKind::RootShift,
                    compressed: tier_compressed(compressed, d - 1),
                    codec: None,
                });
            }
            for t in (0..d).rev() {
                legs.push(Leg {
                    tier: t,
                    kind: match op {
                        Op::Scatter => LegKind::ScatterFromLeader,
                        _ => LegKind::BcastFromLeader,
                    },
                    compressed: tier_compressed(compressed, t),
                    codec: None,
                });
            }
        }
    }
    Ok(Schedule {
        op,
        tree: tree.clone(),
        legs,
        root,
    })
}

/// Compile a cost-tuned schedule for `op` on `tree`: each middle
/// ascent tier picks reduce-to-leader vs. in-group doubling, and the
/// top tier picks doubling vs. ring, whichever the cost model prices
/// cheaper at `msg_bytes` (the per-tier crossover). Ties go to the
/// fewer-error alternative. A second pass then picks each compressed
/// leg's **codec**: the canonical bitpack pipeline vs. the
/// entropy-coded [`CodecSpec::rle_rice`], which trades slower kernels
/// for a denser wire format — it wins exactly where uplink contention
/// makes serialization dominate, so one schedule can mix codecs
/// across tiers.
pub fn compile_tuned(
    op: Op,
    tree: &TierTree,
    compressed: bool,
    msg_bytes: usize,
    cost: &CostModel,
) -> Result<Schedule> {
    let mut sched = compile_min_error(op, tree, compressed)?;
    let d = tree.depth();
    if matches!(op, Op::Allreduce | Op::ReduceScatter) {
        // Gather/ring legs have no implemented kind alternative, and
        // the rooted descents are compress-once streams with exactly
        // one implemented kind per tier.
        for (i, leg) in sched.legs.iter_mut().enumerate() {
            let candidates: &[LegKind] = if leg.tier == d - 1 && i == d - 1 {
                // The top collective leg.
                &[LegKind::AllreduceRedoub, LegKind::AllreduceRing]
            } else if i < d - 1 && leg.tier >= 1 {
                // Middle ascent legs (tier-0 stays the raw NVLink fold).
                &[LegKind::ReduceToLeader, LegKind::AllreduceRedoub]
            } else {
                continue;
            };
            let mut best = leg.kind;
            let mut best_cost = leg_cost(leg, op, tree, tree, cost, msg_bytes);
            for &k in candidates {
                if k == leg.kind {
                    continue;
                }
                let c = leg_cost(&Leg { kind: k, ..*leg }, op, tree, tree, cost, msg_bytes);
                if c < best_cost {
                    best = k;
                    best_cost = c;
                }
            }
            leg.kind = best;
        }
    }
    // Per-leg codec selection over the error-bounded family. Ties go
    // to the canonical codec (iterated first, strict improvement
    // required), so kernel-bound legs are untouched.
    for leg in sched.legs.iter_mut() {
        if !leg.compressed {
            continue;
        }
        let mut best = CodecSpec::cuszp();
        let mut best_cost = f64::INFINITY;
        for c in [CodecSpec::cuszp(), CodecSpec::rle_rice()] {
            let priced = Leg {
                codec: Some(c),
                ..*leg
            };
            let pc = leg_cost(&priced, op, tree, tree, cost, msg_bytes);
            if pc < best_cost {
                best = c;
                best_cost = pc;
            }
        }
        leg.codec = Some(best);
    }
    Ok(sched)
}

impl Schedule {
    /// Worst-case pointwise error amplification `m` of the whole
    /// schedule: under an error-bounded compressor with bound `eb`,
    /// every rank's output deviates from the exact result by at most
    /// `m · eb`. Walks the legs with the recurrences of
    /// [`crate::accuracy::propagation`]: linear accumulation for folds
    /// and rings, `e' = 2e + eb` for doubling exchanges, `+eb` for
    /// forwarded streams; raw legs only sum existing errors.
    pub fn amplification(&self) -> f64 {
        let mut e = 0.0f64;
        for leg in &self.legs {
            // Worst *actual* group, not the declared width: a spec that
            // overcovers the rank count must not inflate the bound (and
            // over-tighten the planned eb).
            let g = self.tree.effective_width(leg.tier) as f64;
            let c = if leg.compressed { 1.0 } else { 0.0 };
            match leg.kind {
                LegKind::ReduceToLeader => e = g * e + (g - 1.0) * c,
                LegKind::AllreduceRedoub => {
                    if leg.compressed {
                        let s = doubling_error_stages(self.tree.effective_width(leg.tier));
                        e = pow2_minus_1(s) + (pow2_minus_1(s) + 1.0) * e;
                    } else {
                        e *= g;
                    }
                }
                LegKind::AllreduceRing => e = g * e + g * c,
                LegKind::BcastFromLeader
                | LegKind::GatherToLeader
                | LegKind::AllgatherRing
                | LegKind::ScatterFromLeader
                | LegKind::RootShift => e += c,
            }
        }
        e
    }

    /// Per-tier sensitivity of the end-to-end error to each tier's
    /// compressor bound: `A[t]` such that running tier `t`'s
    /// compressed legs at bound `eb_t` yields worst-case error
    /// `Σ_t A[t] · eb_t`. With a uniform bound this sums to
    /// [`Schedule::amplification`]. The budget planner uses it to
    /// split a per-call budget across tiers.
    pub fn tier_sensitivities(&self) -> Vec<f64> {
        let mut a = vec![0.0f64; self.tree.depth()];
        for leg in &self.legs {
            let g = self.tree.effective_width(leg.tier) as f64;
            let c = if leg.compressed { 1.0 } else { 0.0 };
            // e' = gain·e + add·eb_tier: scale all accumulated
            // sensitivities by the gain, then credit the leg's own
            // contribution to its tier.
            let (gain, add) = match leg.kind {
                LegKind::ReduceToLeader => (g, (g - 1.0) * c),
                LegKind::AllreduceRedoub => {
                    if leg.compressed {
                        let p = pow2_minus_1(doubling_error_stages(
                            self.tree.effective_width(leg.tier),
                        ));
                        (p + 1.0, p)
                    } else {
                        (g, 0.0)
                    }
                }
                LegKind::AllreduceRing => (g, g * c),
                LegKind::BcastFromLeader
                | LegKind::GatherToLeader
                | LegKind::AllgatherRing
                | LegKind::ScatterFromLeader
                | LegKind::RootShift => (1.0, c),
            };
            for s in a.iter_mut() {
                *s *= gain;
            }
            a[leg.tier] += add;
        }
        a
    }

    /// Predicted `(compress, decompress)` kernel invocations at `rank`
    /// over the whole schedule — the multi-tier generalization of
    /// [`crate::collectives::expected_cpr_stages_hier`] (with which it
    /// agrees on 2-tier trees).
    ///
    /// Assumes every Reduce_scatter chunk range is non-empty (total
    /// elements ≥ ranks): for degenerate shorter vectors the executor
    /// sends empty scatter slices raw, so the actual counts can fall
    /// below this prediction on such inputs.
    pub fn cpr_stages_at(&self, rank: usize) -> (usize, usize) {
        let tree = &self.tree;
        let mut cpr = 0usize;
        let mut dec = 0usize;
        for leg in &self.legs {
            if leg.kind == LegKind::RootShift {
                // Only the root and rank 0 take part, regardless of
                // tier participation (the root can be any rank).
                if leg.compressed && self.root != 0 {
                    if rank == self.root {
                        cpr += 1;
                    } else if rank == 0 {
                        dec += 1;
                    }
                }
                continue;
            }
            if !leg.compressed || !tree.participates(leg.tier, rank) {
                continue;
            }
            let ps = tree.group_participants(leg.tier, tree.group_of(leg.tier, rank));
            let k = ps.len();
            if k <= 1 {
                continue;
            }
            let idx = tree.relative_rank(leg.tier, rank);
            match leg.kind {
                LegKind::ReduceToLeader => {
                    if idx == 0 {
                        dec += k - 1;
                    } else {
                        cpr += 1;
                    }
                }
                LegKind::AllreduceRedoub => {
                    let pof2 = 1usize << (usize::BITS - 1 - k.leading_zeros()) as usize;
                    let rem = k - pof2;
                    let logp = pof2.trailing_zeros() as usize;
                    let (c, d) = if idx < 2 * rem {
                        if idx % 2 == 0 {
                            (1, 1)
                        } else {
                            (logp + 1, logp + 1)
                        }
                    } else {
                        (logp, logp)
                    };
                    cpr += c;
                    dec += d;
                }
                LegKind::AllreduceRing => {
                    // RS phase: k−1 chunk compressions/decompressions;
                    // AG phase: one more compression, k−1 decodes.
                    cpr += k;
                    dec += 2 * (k - 1);
                }
                LegKind::BcastFromLeader => {
                    if idx == 0 {
                        cpr += 1;
                    } else {
                        dec += 1;
                    }
                }
                LegKind::GatherToLeader => {
                    if idx == 0 {
                        dec += k - 1;
                    } else {
                        cpr += 1;
                    }
                }
                LegKind::AllgatherRing => {
                    cpr += 1;
                    dec += k - 1;
                }
                LegKind::ScatterFromLeader => {
                    if idx == 0 {
                        cpr += k - 1;
                    } else {
                        dec += 1;
                    }
                }
                // Handled before the participation check.
                LegKind::RootShift => {}
            }
        }
        (cpr, dec)
    }

    /// Analytic makespan estimate of the schedule over a `msg_bytes`
    /// payload, priced against the **physical** tree `phys` (which may
    /// be deeper than the schedule's own grouping: a collapsed 2-tier
    /// schedule on a 3-tier machine still pays rack-uplink contention).
    pub fn estimate_makespan(&self, phys: &TierTree, cost: &CostModel, msg_bytes: usize) -> f64 {
        self.legs
            .iter()
            .map(|leg| leg_cost(leg, self.op, &self.tree, phys, cost, msg_bytes))
            .sum()
    }

    /// Analytic makespan of the schedule executed as a `depth`-chunk
    /// pipeline by the round-granular wavefront: every superstep issues
    /// each in-flight chunk's current exchange round (compress kernels
    /// on the chunk's own stream, then the sends) before awaiting any
    /// arrival, so the `depth` chunks advance one round per superstep,
    /// staggered by one round each, and their kernels overlap across
    /// streams. The makespan is one full chunk traversal
    /// (`Σ_legs c(B/d)`) plus the pipeline drain — each extra chunk
    /// finishes one bottleneck **round** after its predecessor:
    /// `(d−1) · max_legs c(B/d) / rounds(leg)` (see
    /// [`Schedule::leg_rounds`]). Depth 1 reproduces
    /// [`Schedule::estimate_makespan`] exactly (same addends, same
    /// order), so the depth sweep in the tuner is anchored on the
    /// barrier estimate. Chunking pays while the cross-chunk round
    /// overlap hides kernel and wire time, and stops paying once the
    /// per-chunk latency floors (`alpha`, kernel launch — the fixed
    /// `n0` overhead replicated per chunk) dominate — which is what
    /// gives the sweep an interior optimum.
    pub fn estimate_makespan_pipelined(
        &self,
        phys: &TierTree,
        cost: &CostModel,
        msg_bytes: usize,
        depth: usize,
    ) -> f64 {
        if depth <= 1 {
            return self.estimate_makespan(phys, cost, msg_bytes);
        }
        let per = self.leg_costs(phys, cost, msg_bytes.div_ceil(depth).max(1));
        let sum: f64 = per.iter().sum();
        let drain = per
            .iter()
            .enumerate()
            .map(|(li, c)| c / self.leg_rounds(li) as f64)
            .fold(0.0f64, f64::max);
        sum + (depth - 1) as f64 * drain
    }

    /// Exchange-round count of leg `li` under the pipeline's global
    /// round calendar: how many issue/await supersteps the
    /// round-granular wavefront allots the leg on **every** rank. The
    /// count is the max over the tier's groups (sizes differ when the
    /// widths overcover the rank count, and a partial trailing group
    /// can need *more* rounds than a full one once the recursive
    /// doubling remainder fold kicks in), so ranks whose group
    /// finishes early idle the leftover rounds and the calendar stays
    /// rank-independent — the property the wavefront's
    /// deadlock-freedom argument rests on. The pipelined estimator
    /// divides a leg's cost by this to price the drain of one extra
    /// in-flight chunk.
    pub fn leg_rounds(&self, li: usize) -> usize {
        let leg = &self.legs[li];
        let t = leg.tier;
        // Group 0 is always the fullest; the last group is the only
        // one that can be smaller. Checking both covers every size.
        let ngroups = self.tree.groups(t);
        let sizes = [
            self.tree.group_participants(t, 0).len(),
            self.tree.group_participants(t, ngroups - 1).len(),
        ];
        sizes
            .iter()
            .map(|&k| rounds_for(leg.kind, k))
            .max()
            .unwrap_or(1)
    }

    /// Per-leg analytic costs in leg order — the same addends
    /// [`Schedule::estimate_makespan`] sums. Recorded on the
    /// tuner-decision instant so the trace analyzer can join observed
    /// leg spans against the exact predictions planning used.
    pub fn leg_costs(&self, phys: &TierTree, cost: &CostModel, msg_bytes: usize) -> Vec<f64> {
        self.legs
            .iter()
            .map(|leg| leg_cost(leg, self.op, &self.tree, phys, cost, msg_bytes))
            .collect()
    }
}

/// Exchange rounds a leg kind needs over a `k`-participant group — the
/// per-group slice of the pipeline round calendar (one round = one
/// issue/await superstep of the round-granular wavefront).
fn rounds_for(kind: LegKind, k: usize) -> usize {
    if k <= 1 {
        return 1;
    }
    match kind {
        // The leader folds one member arrival per round.
        LegKind::ReduceToLeader | LegKind::GatherToLeader => k - 1,
        // Fold + ⌈log₂⌉ masked exchanges + unfold (MPICH remainder).
        LegKind::AllreduceRedoub => {
            let pof2 = 1usize << (usize::BITS - 1 - k.leading_zeros()) as usize;
            let logp = pof2.trailing_zeros() as usize;
            logp.max(1) + if k != pof2 { 2 } else { 0 }
        }
        // Reduce-scatter steps then allgather steps.
        LegKind::AllreduceRing => 2 * (k - 1),
        LegKind::AllgatherRing => k - 1,
        // Binomial relay depth; the raw fan-out variant uses only the
        // first round and idles the rest, so one calendar serves both.
        LegKind::BcastFromLeader => (usize::BITS - (k - 1).leading_zeros()).max(1) as usize,
        // One burst of leader sends / one receive.
        LegKind::ScatterFromLeader | LegKind::RootShift => 1,
    }
}

/// Analytic per-tier cost model: device kernel parameters, per-tier
/// link models (`[0]` intranode, `[1]` the node NIC, `[2..]` uplinks),
/// and the effective compression ratio for wire volume.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Device kernel cost parameters.
    pub gpu: GpuModel,
    /// Per-tier links; indices past the end clamp to the last entry.
    pub links: Vec<LinkModel>,
    /// Effective compression ratio (raw/wire bytes); 1.0 = no gain.
    pub cpr_ratio: f64,
    /// Trace-calibrated per-codec kernel-time factors keyed by codec
    /// label; codecs not listed fall back to the analytic
    /// [`CostModel::codec_kernel_factor`]. Empty by default.
    pub kernel_factors: Vec<(String, f64)>,
}

impl CostModel {
    /// Build a cost model; the ratio is clamped to ≥ 1.
    pub fn new(gpu: GpuModel, links: Vec<LinkModel>, cpr_ratio: f64) -> Self {
        assert!(!links.is_empty(), "cost model needs at least one link tier");
        CostModel {
            gpu,
            links,
            cpr_ratio: cpr_ratio.max(1.0),
            kernel_factors: Vec::new(),
        }
    }

    /// Install trace-calibrated per-codec kernel factors (see
    /// [`crate::obs::calibrate`]).
    pub fn with_kernel_factors(mut self, factors: Vec<(String, f64)>) -> Self {
        self.kernel_factors = factors;
        self
    }

    /// A100 + paper-testbed default links (NVLink, Slingshot, default
    /// uplinks) at the default virtual-profile ratio.
    pub fn default_a100() -> Self {
        let mut links = vec![
            LinkModel::nvlink_default(),
            LinkModel::slingshot10_default(),
        ];
        links.extend(crate::net::default_uplinks(4));
        CostModel::new(GpuModel::a100(), links, 25.0)
    }

    /// Link crossed by messages whose lowest common tier is `t`.
    pub fn link(&self, t: usize) -> LinkModel {
        self.links[t.min(self.links.len() - 1)]
    }

    /// Effective wire ratio (raw/wire bytes) of a staged codec. The
    /// canonical error-bounded codec answers `cpr_ratio` exactly;
    /// other compositions scale it by their stage characteristics
    /// (entropy coding denser, byteplane looser, lossless an absolute
    /// ~1.9× independent of the lossy profile, fixed-rate its exact
    /// arithmetic rate). Never below 1.
    pub fn codec_ratio(&self, codec: CodecSpec) -> f64 {
        if codec == CodecSpec::cuszp() {
            return self.cpr_ratio;
        }
        let r = match codec.quantizer {
            QuantizerKind::Lossless => {
                let coder = match codec.coder {
                    CoderKind::Bitpack => 0.8,
                    CoderKind::Byteplane => 1.0,
                    CoderKind::RleRice => 1.1,
                };
                1.9 * coder
            }
            // 32-bit values → 4 + 32·bits/8 bytes per 32-value block.
            QuantizerKind::FixedRate(bits) => 32.0 / (bits as f64 + 1.0),
            QuantizerKind::Prequant => {
                let coder = match codec.coder {
                    CoderKind::Bitpack => 1.0,
                    CoderKind::Byteplane => 0.8,
                    CoderKind::RleRice => 1.35,
                };
                let pred = match codec.predictor {
                    PredictorKind::Lorenzo1D => 1.0,
                    PredictorKind::None => 0.6,
                };
                self.cpr_ratio * coder * pred
            }
        };
        r.max(1.0)
    }

    /// Relative kernel-time factor of a codec against the canonical
    /// pipeline, summed from the per-stage shares of
    /// [`GpuModel::stage_split`] (predictor/quantizer/coder). Exactly
    /// `1.0` for the canonical codec, so pinned estimates are
    /// untouched; the Rice coder stage runs ~1.6× the bitpack stage.
    pub fn codec_kernel_factor(codec: CodecSpec) -> f64 {
        if codec == CodecSpec::cuszp() {
            return 1.0;
        }
        let [fp, fq, fc] = GpuModel::stage_split();
        let pred = match codec.predictor {
            PredictorKind::Lorenzo1D => fp,
            PredictorKind::None => 0.25 * fp,
        };
        let quant = match codec.quantizer {
            QuantizerKind::Prequant | QuantizerKind::FixedRate(_) => fq,
            QuantizerKind::Lossless => 0.5 * fq,
        };
        let coder_scale = match codec.coder {
            CoderKind::Bitpack => 1.0,
            CoderKind::Byteplane => 0.8,
            CoderKind::RleRice => 1.6,
        };
        pred + quant + fc * coder_scale
    }

    /// Kernel factor for `codec`, preferring a trace-calibrated
    /// override over the analytic stage-split estimate.
    pub fn kernel_factor(&self, codec: CodecSpec) -> f64 {
        let label = codec.label();
        self.kernel_factors
            .iter()
            .find(|(k, _)| *k == label)
            .map(|(_, f)| *f)
            .unwrap_or_else(|| Self::codec_kernel_factor(codec))
    }

    fn wire(&self, bytes: usize, codec: Option<CodecSpec>) -> f64 {
        match codec {
            Some(c) => bytes as f64 / self.codec_ratio(c),
            None => bytes as f64,
        }
    }

    fn comp(&self, bytes: usize, codec: Option<CodecSpec>) -> f64 {
        match codec {
            Some(c) => self.gpu.compress.time(bytes) * self.kernel_factor(c),
            None => 0.0,
        }
    }

    fn dec(&self, bytes: usize, codec: Option<CodecSpec>) -> f64 {
        match codec {
            Some(c) => self.gpu.decompress.time(bytes) * self.kernel_factor(c),
            None => 0.0,
        }
    }

    fn red(&self, bytes: usize) -> f64 {
        self.gpu.reduce.time(bytes)
    }
}

/// Physical tier a hop of `dist` ranks crosses (0 = intranode).
fn crossing_tier(phys: &TierTree, dist: usize) -> usize {
    for t in 0..phys.depth() {
        if dist < phys.span(t) {
            return t;
        }
    }
    phys.depth() - 1
}

/// Wire time of one exchange round between participants `dist` ranks
/// apart, `pspan` being the participant stride: the NIC serialization,
/// or — when the hop crosses an oversubscribed uplink — the uplink
/// serialization times the number of participants sharing it.
fn round_wire(phys: &TierTree, cost: &CostModel, pspan: usize, dist: usize, wire: f64) -> f64 {
    let cx = crossing_tier(phys, dist.max(1));
    if cx == 0 {
        return cost.link(0).alpha + wire / cost.link(0).beta;
    }
    let mut ser = wire / cost.link(1).beta;
    for l in 2..=cx {
        let contention = (phys.span(l - 1) / pspan.max(1)).max(1) as f64;
        ser = ser.max(contention * wire / cost.link(l).beta);
    }
    cost.link(cx).alpha + ser
}

/// Cost of the recursive-doubling rounds over `g` participants spaced
/// `pspan` apart: per-round kernels plus distance-resolved wire time
/// (low-distance rounds stay inside close tiers; high-distance rounds
/// pay uplink contention), with two extra neighbor-distance rounds for
/// the non-power-of-two remainder fold.
fn redoub_cost(
    phys: &TierTree,
    cost: &CostModel,
    g: usize,
    pspan: usize,
    bytes: usize,
    codec: Option<CodecSpec>,
) -> f64 {
    if g <= 1 {
        return 0.0;
    }
    let wire = cost.wire(bytes, codec);
    let kernels = cost.comp(bytes, codec) + cost.dec(bytes, codec) + cost.red(bytes);
    let pof2 = 1usize << (usize::BITS - 1 - g.leading_zeros()) as usize;
    let logp = pof2.trailing_zeros() as usize;
    let mut total = 0.0;
    for j in 0..logp {
        total += kernels + round_wire(phys, cost, pspan, pspan << j, wire);
    }
    if g != pof2 {
        total += 2.0 * (kernels + round_wire(phys, cost, pspan, pspan, wire));
    }
    total
}

/// Analytic cost of one leg (see [`Schedule::estimate_makespan`]).
fn leg_cost(
    leg: &Leg,
    op: Op,
    sched_tree: &TierTree,
    phys: &TierTree,
    cost: &CostModel,
    msg_bytes: usize,
) -> f64 {
    let t = leg.tier;
    let g = sched_tree.effective_width(t);
    if g <= 1 && leg.kind != LegKind::RootShift {
        return 0.0;
    }
    // The codec the leg is priced at: its tuned codec, the canonical
    // error-bounded pipeline otherwise; raw legs have none.
    let codec = if leg.compressed {
        Some(leg.codec.unwrap_or_else(CodecSpec::cuszp))
    } else {
        None
    };
    let pspan = sched_tree.pspan(t);
    let n = sched_tree.ranks();
    // Dominant per-participant payload of this leg.
    let bytes = match op {
        // Allgather legs carry the participants' gathered sub-blocks;
        // the descent broadcasts the full gathered vector.
        Op::Allgather => match leg.kind {
            LegKind::BcastFromLeader => msg_bytes,
            _ => (msg_bytes / n.max(1)) * pspan,
        },
        _ => msg_bytes,
    };
    let wire = cost.wire(bytes, codec);
    // Worst in-group hop distance (member farthest from its leader).
    let far = sched_tree.span(t).saturating_sub(pspan).max(pspan);
    match leg.kind {
        LegKind::ReduceToLeader | LegKind::GatherToLeader => {
            let reduce = if leg.kind == LegKind::ReduceToLeader {
                cost.red(bytes)
            } else {
                0.0
            };
            if t == 0 {
                // NVLink fan-in: parallel transfers, sequential folds.
                cost.link(0).alpha
                    + bytes as f64 / cost.link(0).beta
                    + (g - 1) as f64 * reduce
            } else {
                // One compression per member (parallel), then g−1
                // arrivals serialize on the leader's ingress.
                cost.comp(bytes, codec)
                    + (g - 1) as f64
                        * (round_wire(phys, cost, pspan, far, wire)
                            + cost.dec(bytes, codec)
                            + reduce)
            }
        }
        LegKind::AllreduceRedoub => redoub_cost(phys, cost, g, pspan, bytes, codec),
        LegKind::AllreduceRing => {
            let chunk = (bytes / g).max(1);
            let cw = cost.wire(chunk, codec);
            let per_round = cost.comp(chunk, codec)
                + cost.dec(chunk, codec)
                + cost.red(chunk)
                + round_wire(phys, cost, pspan, pspan, cw);
            2.0 * (g - 1) as f64 * per_round
        }
        LegKind::AllgatherRing => {
            let per_round = cost.dec(bytes, codec)
                + round_wire(phys, cost, pspan, pspan, wire);
            cost.comp(bytes, codec) + (g - 1) as f64 * per_round
        }
        LegKind::BcastFromLeader => {
            if leg.compressed {
                // Compress-once stream down a binomial tree.
                cost.comp(bytes, codec)
                    + cost.dec(bytes, codec)
                    + ceil_log2(g) as f64 * round_wire(phys, cost, pspan, far, wire)
            } else {
                // Direct NVLink fan-out from the leader.
                cost.link(0).alpha + (g - 1) as f64 * bytes as f64 / cost.link(0).beta
            }
        }
        LegKind::ScatterFromLeader => {
            // The leader ships (g−1)/g of its slice of the vector; the
            // group covers min(span, ranks)/ranks of the chunk space
            // (actual coverage, not the declared spec — see
            // `TierTree::effective_width`).
            let leg_bytes =
                (msg_bytes as f64) * sched_tree.span(t).min(n) as f64 / n.max(1) as f64;
            let out_wire = cost.wire(leg_bytes as usize, codec) * (g - 1) as f64
                / g as f64;
            cost.comp((leg_bytes as usize) / g.max(1), codec)
                + round_wire(phys, cost, pspan, far, out_wire)
                + cost.dec((leg_bytes as usize) / g.max(1), codec)
        }
        LegKind::RootShift => {
            // One point-to-point full-vector hop from the root to rank
            // 0 — priced at the worst in-group distance of the top
            // tier (the root can live in any subtree).
            cost.comp(bytes, codec)
                + round_wire(phys, cost, pspan, far, wire)
                + cost.dec(bytes, codec)
        }
    }
}

/// Per-round cost of a flat-ring chunk hop on the physical tree:
/// kernels at the utilization floor plus a neighbor hop that crosses
/// the node boundary for `1/width(0)` of the ranks.
fn flat_ring_round(phys: &TierTree, cost: &CostModel, msg_bytes: usize, compressed: bool) -> f64 {
    let codec = compressed.then(CodecSpec::cuszp);
    let n = phys.ranks();
    let chunk = (msg_bytes / n).max(1);
    let cw = cost.wire(chunk, codec);
    let f_inter = 1.0 / phys.width(0) as f64;
    let wire_time = (1.0 - f_inter) * (cost.link(0).alpha + cw / cost.link(0).beta)
        + f_inter * round_wire(phys, cost, 1, phys.span(0), cw);
    cost.comp(chunk, codec) + cost.dec(chunk, codec) + cost.red(chunk) + wire_time
}

/// Analytic makespan of the **flat ring Allreduce** on the physical
/// tree: `2(N−1)` chunk rounds (reduce-scatter + allgather).
pub fn estimate_flat_ring(phys: &TierTree, cost: &CostModel, msg_bytes: usize, compressed: bool) -> f64 {
    let n = phys.ranks();
    if n <= 1 {
        return 0.0;
    }
    2.0 * (n - 1) as f64 * flat_ring_round(phys, cost, msg_bytes, compressed)
}

/// Analytic makespan of the **flat ring Reduce_scatter**: only the
/// `N−1` reduce-scatter rounds (no allgather phase) — half the
/// Allreduce, which matters when pricing it against the hierarchical
/// alternative.
pub fn estimate_flat_reduce_scatter(
    phys: &TierTree,
    cost: &CostModel,
    msg_bytes: usize,
    compressed: bool,
) -> f64 {
    let n = phys.ranks();
    if n <= 1 {
        return 0.0;
    }
    (n - 1) as f64 * flat_ring_round(phys, cost, msg_bytes, compressed)
}

/// Analytic makespan of the **flat recursive-doubling** Allreduce on
/// the physical tree: whole-vector rounds whose high-distance
/// exchanges pay full uplink contention (every rank crosses at once).
pub fn estimate_flat_redoub(
    phys: &TierTree,
    cost: &CostModel,
    msg_bytes: usize,
    compressed: bool,
) -> f64 {
    redoub_cost(phys, cost, phys.ranks(), 1, msg_bytes, compressed.then(CodecSpec::cuszp))
}

/// Analytic makespan of the **flat ring Allgather** (compress-once
/// forwarding) over the gathered volume `total_bytes`.
pub fn estimate_flat_allgather(
    phys: &TierTree,
    cost: &CostModel,
    total_bytes: usize,
    compressed: bool,
) -> f64 {
    let codec = compressed.then(CodecSpec::cuszp);
    let n = phys.ranks();
    if n <= 1 {
        return 0.0;
    }
    let block = (total_bytes / n).max(1);
    let bw = cost.wire(block, codec);
    let f_inter = 1.0 / phys.width(0) as f64;
    let wire_time = (1.0 - f_inter) * (cost.link(0).alpha + bw / cost.link(0).beta)
        + f_inter * round_wire(phys, cost, 1, phys.span(0), bw);
    cost.comp(block, codec)
        + (n - 1) as f64 * (wire_time + cost.dec(block, codec))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree(ranks: usize, widths: &[usize]) -> TierTree {
        TierTree::new(ranks, widths).unwrap()
    }

    const MIB: usize = 1 << 20;

    #[test]
    fn min_error_two_tier_matches_pr2_shape() {
        let t = tree(16, &[4, 4]);
        let s = compile_min_error(Op::Allreduce, &t, true).unwrap();
        assert_eq!(
            s.legs,
            vec![
                Leg { tier: 0, kind: LegKind::ReduceToLeader, compressed: false, codec: None },
                Leg { tier: 1, kind: LegKind::AllreduceRedoub, compressed: true, codec: None },
                Leg { tier: 0, kind: LegKind::BcastFromLeader, compressed: false, codec: None },
            ]
        );
        // Uncompressed policies compile all-raw legs.
        let raw = compile_min_error(Op::Allreduce, &t, false).unwrap();
        assert!(raw.legs.iter().all(|l| !l.compressed));
    }

    #[test]
    fn three_tier_legs_are_mirrored() {
        let t = tree(512, &[4, 16, 8]);
        let s = compile_min_error(Op::ReduceScatter, &t, true).unwrap();
        let tiers: Vec<usize> = s.legs.iter().map(|l| l.tier).collect();
        assert_eq!(tiers, vec![0, 1, 2, 1, 0]);
        assert_eq!(s.legs[3].kind, LegKind::ScatterFromLeader);
        assert!(s.legs[1].compressed && !s.legs[0].compressed);
        // Rooted ops compile too (pure top-down descents from rank 0).
        assert!(compile_min_error(Op::Scatter, &t, true).is_ok());
    }

    #[test]
    fn rooted_ops_compile_top_down_descents() {
        let t = tree(512, &[4, 16, 8]);
        // Root 0: no shift leg, one descent leg per tier, top-down.
        let s = compile_rooted(Op::Bcast, &t, true, 0).unwrap();
        assert_eq!(s.root, 0);
        let shape: Vec<(usize, LegKind)> = s.legs.iter().map(|l| (l.tier, l.kind)).collect();
        assert_eq!(
            shape,
            vec![
                (2, LegKind::BcastFromLeader),
                (1, LegKind::BcastFromLeader),
                (0, LegKind::BcastFromLeader),
            ]
        );
        // Tier 0 stays raw, higher tiers compress.
        assert!(!s.legs[2].compressed && s.legs[0].compressed && s.legs[1].compressed);
        // Compress-once streams: one eb per compressed crossing.
        assert_eq!(s.amplification(), 2.0);
        // A non-zero root prepends the shift leg and records the root.
        let r = compile_rooted(Op::Scatter, &t, true, 37).unwrap();
        assert_eq!(r.root, 37);
        assert_eq!(r.legs[0].kind, LegKind::RootShift);
        assert_eq!(r.legs[1].kind, LegKind::ScatterFromLeader);
        assert_eq!(r.legs.len(), 4);
        assert_eq!(r.amplification(), 3.0);
        // Kernel counts: the root compresses the shift, rank 0 decodes
        // it (and then re-compresses the top scatter for its peers).
        assert_eq!(r.cpr_stages_at(37).0, 1);
        assert!(r.cpr_stages_at(0).1 >= 1);
        // Out-of-range roots are rejected.
        assert!(compile_rooted(Op::Bcast, &t, true, 512).is_err());
    }

    #[test]
    fn pipelined_estimate_reduces_to_barrier_at_depth_one_and_wins_after() {
        let cost = CostModel::default_a100();
        let phys = tree(512, &[4, 16, 8]);
        let s = compile_tuned(Op::Allreduce, &phys, true, 64 * MIB, &cost).unwrap();
        let barrier = s.estimate_makespan(&phys, &cost, 64 * MIB);
        // Depth 1 is the barrier estimate, addend for addend.
        assert_eq!(s.estimate_makespan_pipelined(&phys, &cost, 64 * MIB, 1), barrier);
        // Some depth > 1 strictly beats the barrier at 64 MiB
        // (bandwidth-dominated legs overlap)…
        let best = [2usize, 4, 8]
            .iter()
            .map(|&d| s.estimate_makespan_pipelined(&phys, &cost, 64 * MIB, d))
            .fold(f64::INFINITY, f64::min);
        assert!(best < barrier, "pipelined {best} vs barrier {barrier}");
        // …while a tiny payload pays the per-chunk latency floors and
        // stays at depth 1.
        let tiny = s.estimate_makespan(&phys, &cost, 4096);
        let tiny8 = s.estimate_makespan_pipelined(&phys, &cost, 4096, 8);
        assert!(tiny8 > tiny, "tiny pipelined {tiny8} vs barrier {tiny}");
    }

    #[test]
    fn amplification_matches_two_tier_model() {
        // [4, 4]: 4 nodes → 2^2 − 1 = 3 (the PR 2 internode model).
        let s = compile_min_error(Op::Allreduce, &tree(16, &[4, 4]), true).unwrap();
        assert_eq!(s.amplification(), 3.0);
        // Non-pow2 node count (6 nodes): fold/unfold adds 2 stages.
        let s = compile_min_error(Op::Allreduce, &tree(12, &[2, 6]), true).unwrap();
        assert_eq!(s.amplification(), 15.0);
        // Single node: nothing compresses.
        let s = compile_min_error(Op::Allreduce, &tree(4, &[4, 1]), true).unwrap();
        assert_eq!(s.amplification(), 0.0);
        // 3-tier 4x16x8: rack fold 15, top doubling ×8+7, descent +1.
        let s = compile_min_error(Op::Allreduce, &tree(512, &[4, 16, 8]), true).unwrap();
        assert_eq!(s.amplification(), 128.0);
        // Reduce_scatter shares the ascent; its tier-0 scatter is raw.
        let s = compile_min_error(Op::ReduceScatter, &tree(512, &[4, 16, 8]), true).unwrap();
        assert_eq!(s.amplification(), 128.0);
        // Allgather forwards compress-once streams: one eb per
        // compressed crossing (t1 up, top ring, t1 down).
        let s = compile_min_error(Op::Allgather, &tree(512, &[4, 16, 8]), true).unwrap();
        assert_eq!(s.amplification(), 3.0);
        // A spec that overcovers the rank count walks the *actual*
        // groups: 100 ranks on [4,16,8] have at most 2 top-tier
        // participants (1 doubling stage), not 8 — the bound must not
        // inflate to the declared widths' 128.
        let s = compile_min_error(Op::Allreduce, &tree(100, &[4, 16, 8]), true).unwrap();
        assert_eq!(s.amplification(), 2.0 * 15.0 + 1.0 + 1.0);
    }

    #[test]
    fn tier_sensitivities_sum_to_amplification() {
        for widths in [&[4usize, 16, 8][..], &[2, 6][..], &[4, 4, 4][..], &[3, 5, 7][..]] {
            let span: usize = widths.iter().product();
            for op in [Op::Allreduce, Op::ReduceScatter, Op::Allgather] {
                let s = compile_min_error(op, &tree(span, widths), true).unwrap();
                let sens = s.tier_sensitivities();
                let total: f64 = sens.iter().sum();
                assert!(
                    (total - s.amplification()).abs() < 1e-9 * (1.0 + total),
                    "{op:?} {widths:?}: Σ{sens:?} = {total} vs {}",
                    s.amplification()
                );
                // Tier 0 never compresses → zero sensitivity.
                assert_eq!(sens[0], 0.0);
            }
        }
    }

    #[test]
    fn cpr_stages_match_two_tier_table() {
        use crate::collectives::expected_cpr_stages_hier;
        for (n, g) in [(16usize, 4usize), (12, 2), (13, 4), (4, 4), (8, 1)] {
            let nodes = n.div_ceil(g);
            let s = compile_min_error(Op::Allreduce, &tree(n, &[g, nodes]), true).unwrap();
            for rank in 0..n {
                assert_eq!(
                    s.cpr_stages_at(rank),
                    expected_cpr_stages_hier(n, g, rank),
                    "n={n} g={g} rank={rank}"
                );
            }
        }
    }

    #[test]
    fn tuned_compile_prefers_doubling_over_fold_on_wide_middle_tiers() {
        // A 16-wide rack tier: the leader-side sequential decompress of
        // a linear fold costs ~4× the 4 doubling rounds.
        let cost = CostModel::default_a100();
        let t = tree(512, &[4, 16, 8]);
        let s = compile_tuned(Op::Allreduce, &t, true, 64 * MIB, &cost).unwrap();
        assert_eq!(s.legs[1].tier, 1);
        assert_eq!(s.legs[1].kind, LegKind::AllreduceRedoub);
        // The top leg stays doubling at 64 MiB (whole-vector kernels)…
        assert_eq!(s.legs[2].kind, LegKind::AllreduceRedoub);
        // …and flips to the chunked ring once chunks leave the
        // utilization floor and ring's lower wire volume wins.
        let huge = compile_tuned(Op::Allreduce, &t, true, 4096 * MIB, &cost).unwrap();
        assert_eq!(huge.legs[2].kind, LegKind::AllreduceRing);
    }

    #[test]
    fn codec_ratio_and_kernel_factor_anchor_on_the_canonical_codec() {
        let cost = CostModel::default_a100();
        assert_eq!(cost.codec_ratio(CodecSpec::cuszp()), 25.0);
        assert_eq!(CostModel::codec_kernel_factor(CodecSpec::cuszp()), 1.0);
        // Entropy coding: denser wire, slower kernels.
        assert!(cost.codec_ratio(CodecSpec::rle_rice()) > 25.0);
        assert!(CostModel::codec_kernel_factor(CodecSpec::rle_rice()) > 1.0);
        // Lossless is a modest absolute ratio independent of the lossy
        // profile, and cheaper kernels than the canonical pipeline.
        let ll = cost.codec_ratio(CodecSpec::lossless());
        assert!((1.0..3.0).contains(&ll), "lossless ratio {ll}");
        assert!(CostModel::codec_kernel_factor(CodecSpec::lossless()) < 1.0);
        // Fixed-rate at 8 bits: 32 codes + a scale per 128 raw bytes.
        let fr = cost.codec_ratio(CodecSpec::fixed_rate(8));
        assert!((3.0..4.0).contains(&fr), "fixed-rate ratio {fr}");
    }

    #[test]
    fn tuned_compile_mixes_codecs_across_tiers_on_thin_uplinks() {
        // 512 ranks as 4×16×8 with a rack uplink 10× thinner than the
        // node NIC: cross-rack serialization dominates the top leg, so
        // the denser Rice-coded pipeline wins there despite slower
        // kernels, while the NIC-bound tier-1 legs keep the canonical
        // codec — one schedule, two codecs.
        let links = vec![
            LinkModel::nvlink_default(),
            LinkModel::slingshot10_default(),
            LinkModel::new(25e-6, 1.25e9),
        ];
        let cost = CostModel::new(GpuModel::a100(), links, 25.0);
        let phys = tree(512, &[4, 16, 8]);
        let s = compile_tuned(Op::Allreduce, &phys, true, 64 * MIB, &cost).unwrap();
        let top = s.legs.iter().find(|l| l.tier == 2).unwrap();
        assert_eq!(top.codec, Some(CodecSpec::rle_rice()));
        for l in s.legs.iter().filter(|l| l.compressed && l.tier == 1) {
            assert_eq!(l.codec, Some(CodecSpec::cuszp()), "tier-1 {:?}", l.kind);
        }
        // The mixed-codec plan beats the same schedule forced uniform.
        let mixed = s.estimate_makespan(&phys, &cost, 64 * MIB);
        let mut uniform = s.clone();
        for l in uniform.legs.iter_mut().filter(|l| l.compressed) {
            l.codec = Some(CodecSpec::cuszp());
        }
        let uni = uniform.estimate_makespan(&phys, &cost, 64 * MIB);
        assert!(mixed < uni, "mixed {mixed} vs uniform {uni}");
        // The default testbed stays kernel-bound: canonical everywhere,
        // so existing makespan estimates are untouched.
        let dflt =
            compile_tuned(Op::Allreduce, &phys, true, 64 * MIB, &CostModel::default_a100())
                .unwrap();
        assert!(dflt
            .legs
            .iter()
            .filter(|l| l.compressed)
            .all(|l| l.codec == Some(CodecSpec::cuszp())));
    }

    #[test]
    fn estimates_rank_three_tier_below_two_tier_below_flats() {
        // The acceptance shape: 512 ranks as 4 GPUs/node, 16
        // nodes/rack, 8 racks, 64 MiB payload, oversubscribed rack
        // uplinks. Cross-rack rounds cost the 2-tier schedule 16
        // leaders per uplink; the 3-tier schedule sends one.
        let cost = CostModel::default_a100();
        let phys = tree(512, &[4, 16, 8]);
        let three = compile_tuned(Op::Allreduce, &phys, true, 64 * MIB, &cost)
            .unwrap()
            .estimate_makespan(&phys, &cost, 64 * MIB);
        let two = compile_tuned(Op::Allreduce, &phys.collapsed(2), true, 64 * MIB, &cost)
            .unwrap()
            .estimate_makespan(&phys, &cost, 64 * MIB);
        let ring = estimate_flat_ring(&phys, &cost, 64 * MIB, true);
        let redoub = estimate_flat_redoub(&phys, &cost, 64 * MIB, true);
        assert!(three < two, "3-tier {three} vs 2-tier {two}");
        assert!(three < ring, "3-tier {three} vs flat ring {ring}");
        assert!(three < redoub, "3-tier {three} vs flat redoub {redoub}");
        // Reduce_scatter's flat ring runs only the N−1 RS rounds.
        let rs_ring = estimate_flat_reduce_scatter(&phys, &cost, 64 * MIB, true);
        assert!((rs_ring - ring / 2.0).abs() <= 1e-9 * ring, "{rs_ring} vs {ring}");
    }

    #[test]
    fn collapsed_two_tier_estimate_still_pays_the_physical_uplinks() {
        // Pricing a 2-tier schedule against the 3-tier machine must
        // cost more than against a genuinely 2-tier machine.
        let cost = CostModel::default_a100();
        let phys = tree(512, &[4, 16, 8]);
        let flat2 = tree(512, &[4, 128]);
        let sched = compile_min_error(Op::Allreduce, &phys.collapsed(2), true).unwrap();
        let on_three = sched.estimate_makespan(&phys, &cost, 64 * MIB);
        let on_two = sched.estimate_makespan(&flat2, &cost, 64 * MIB);
        assert!(on_three > on_two, "{on_three} vs {on_two}");
    }
}
