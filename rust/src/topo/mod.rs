//! Multi-tier topology subsystem.
//!
//! Real GPU clusters are rack/pod **trees**, not the two flat networks
//! the PR 2 hierarchical Allreduce hard-coded. This subsystem supplies
//! the one structure everything topology-aware consumes:
//!
//! * [`TierTree`] — an N-level hierarchy (GPU → node → rack → pod) over
//!   the block-wise rank layout, with per-tier group/leader/participant
//!   helpers. [`crate::net::Topology`] is the lossless 2-tier special
//!   case (`TierTree::from(&topo)` / [`TierTree::to_topology`]).
//! * [`schedule`] — the schedule engine: compile a `TierTree` + op into
//!   per-tier [`Leg`]s ([`compile_min_error`], [`compile_tuned`]),
//!   price them against the physical tree and its oversubscribed
//!   uplinks ([`Schedule::estimate_makespan`], [`CostModel`]), and walk
//!   the same legs for worst-case error ([`Schedule::amplification`],
//!   [`Schedule::tier_sensitivities`]) and per-rank compression-stage
//!   counts ([`Schedule::cpr_stages_at`]).
//! * [`exec_plan`] — the [`ExecPlan`] / [`LegExec`] contract between
//!   planning and execution: one compression-mode + error-bound
//!   directive per leg (flat algorithms are degenerate one-leg plans),
//!   compiled by the [`crate::comm::Communicator`] at dispatch and
//!   enforced by the executor — the per-tier budget split is
//!   load-bearing, not advisory.
//!
//! The executor for compiled schedules lives in
//! [`crate::collectives::hierarchical`]; the per-tier algorithm
//! crossover in [`crate::comm::Tuner`]; the per-tier error-budget
//! split in [`crate::accuracy::budget`]. All three consume this module
//! so the schedule and the error model can never drift apart.

pub mod exec_plan;
pub mod schedule;
pub mod tier_tree;

pub use exec_plan::{ExecPlan, LegExec};
pub use schedule::{
    compile_min_error, compile_tuned, estimate_flat_allgather, estimate_flat_redoub,
    estimate_flat_reduce_scatter, estimate_flat_ring, CostModel, Leg, LegKind, Schedule,
};
pub use tier_tree::TierTree;
