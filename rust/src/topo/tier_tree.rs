//! `TierTree`: an N-level physical hierarchy of the rank space.
//!
//! Real GPU clusters are trees, not two flat networks: GPUs share a
//! node (NVLink), nodes share a rack (full-bandwidth leaf switch),
//! racks share a pod (oversubscribed uplinks), and so on. The
//! [`TierTree`] describes that nesting as a list of *widths* — children
//! per group at each tier, innermost first — over a block-wise rank
//! layout, exactly the convention [`crate::net::Topology`] already
//! uses for its two levels.
//!
//! `widths = [4, 16, 8]` reads "4 GPUs per node, 16 nodes per rack,
//! 8 racks per pod": tier-0 groups are nodes of 4 ranks, tier-1 groups
//! are racks of 64 ranks, tier-2 groups are pods of 512 ranks. The
//! topmost tier must cover the whole communicator (exactly one top
//! group), and the last group at any tier may be partially filled —
//! the same rule as `Topology`'s partial last node.
//!
//! [`Topology`] is the lossless 2-tier special case:
//! `TierTree::from(&topo)` yields `[gpus_per_node, nodes]`, and
//! [`TierTree::to_topology`] recovers the node-level view (ranks +
//! GPUs per node) that topology-oblivious code consumes.

use crate::error::{Error, Result};
use crate::net::Topology;

/// An N-level hierarchy over a block-wise rank layout. See the module
/// docs for the width convention.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TierTree {
    ranks: usize,
    /// Children per group at each tier, innermost (GPU→node) first.
    widths: Vec<usize>,
}

impl TierTree {
    /// Build a tree of `ranks` ranks with the given per-tier widths.
    ///
    /// Errors when `ranks == 0`, `widths` is empty or contains a zero,
    /// or the tree does not cover the communicator
    /// (`widths.iter().product() < ranks` — the top tier must be a
    /// single group).
    pub fn new(ranks: usize, widths: &[usize]) -> Result<Self> {
        if ranks == 0 {
            return Err(Error::config("tier tree: ranks must be > 0"));
        }
        if widths.is_empty() {
            return Err(Error::config("tier tree: at least one tier width required"));
        }
        if widths.iter().any(|&w| w == 0) {
            return Err(Error::config("tier tree: every tier width must be > 0"));
        }
        let span: usize = widths.iter().product();
        if span < ranks {
            return Err(Error::config(format!(
                "tier tree: widths {widths:?} span only {span} ranks but the \
                 communicator has {ranks} (the top tier must be one group)"
            )));
        }
        Ok(TierTree {
            ranks,
            widths: widths.to_vec(),
        })
    }

    /// Parse a `--tiers`-style spec: `"4x16x8"` → `[4, 16, 8]`.
    pub fn parse_widths(s: &str) -> Result<Vec<usize>> {
        let widths: Result<Vec<usize>> = s
            .split('x')
            .map(|p| {
                p.trim()
                    .parse::<usize>()
                    .map_err(|_| Error::config(format!("bad tier spec `{s}` (want e.g. 4x16x8)")))
            })
            .collect();
        let widths = widths?;
        if widths.is_empty() || widths.iter().any(|&w| w == 0) {
            return Err(Error::config(format!(
                "bad tier spec `{s}`: every width must be a positive integer"
            )));
        }
        Ok(widths)
    }

    /// Total number of ranks.
    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Number of tiers.
    pub fn depth(&self) -> usize {
        self.widths.len()
    }

    /// Children per group at tier `t`.
    pub fn width(&self, t: usize) -> usize {
        self.widths[t]
    }

    /// All per-tier widths, innermost first.
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    /// Ranks covered by one (full) tier-`t` group:
    /// `widths[0] · … · widths[t]`.
    pub fn span(&self, t: usize) -> usize {
        self.widths[..=t].iter().product()
    }

    /// Rank stride between the *participants* of a tier-`t` leg — the
    /// leaders of the tier-`t−1` groups (stride 1 at tier 0: every
    /// rank participates in its node's leg).
    pub fn pspan(&self, t: usize) -> usize {
        if t == 0 {
            1
        } else {
            self.span(t - 1)
        }
    }

    /// Number of tier-`t` groups (ceiling division; the last may be
    /// partially filled).
    pub fn groups(&self, t: usize) -> usize {
        self.ranks.div_ceil(self.span(t))
    }

    /// The tier-`t` group hosting `rank`.
    pub fn group_of(&self, t: usize, rank: usize) -> usize {
        debug_assert!(rank < self.ranks);
        rank / self.span(t)
    }

    /// The leader (lowest rank) of `rank`'s tier-`t` group.
    pub fn leader_of(&self, t: usize, rank: usize) -> usize {
        self.group_of(t, rank) * self.span(t)
    }

    /// Whether `rank` leads its tier-`t` group.
    pub fn is_leader(&self, t: usize, rank: usize) -> bool {
        rank % self.span(t) == 0
    }

    /// Whether `rank` participates in a tier-`t` leg — i.e. it leads
    /// its tier-`t−1` group (every rank participates at tier 0).
    pub fn participates(&self, t: usize, rank: usize) -> bool {
        rank % self.pspan(t) == 0
    }

    /// The participants of tier-`t` group `group`, ascending — the
    /// leaders of its tier-`t−1` subgroups (all member ranks at tier 0).
    pub fn group_participants(&self, t: usize, group: usize) -> Vec<usize> {
        let start = group * self.span(t);
        let end = ((group + 1) * self.span(t)).min(self.ranks);
        (start..end).step_by(self.pspan(t)).collect()
    }

    /// Index of `rank` among its tier-`t` group's participants.
    pub fn relative_rank(&self, t: usize, rank: usize) -> usize {
        debug_assert!(self.participates(t, rank));
        (rank - self.leader_of(t, rank)) / self.pspan(t)
    }

    /// The **largest actual participant count** of any tier-`t` group —
    /// equal to `width(t)` on fully-covered trees, smaller when the
    /// widths overcover the rank count (ranks fill groups left to
    /// right, so group 0 is always the fullest). Worst-case error and
    /// cost models walk this, not the declared width: a `[4, 16, 8]`
    /// spec over 100 ranks has at most 2 top-tier participants, not 8.
    pub fn effective_width(&self, t: usize) -> usize {
        self.span(t).min(self.ranks).div_ceil(self.pspan(t))
    }

    /// Lowest tier at which `a` and `b` share a group (0 = same node;
    /// the top tier is a single group, so this always resolves).
    pub fn lca_tier(&self, a: usize, b: usize) -> usize {
        for t in 0..self.depth() {
            if self.group_of(t, a) == self.group_of(t, b) {
                return t;
            }
        }
        self.depth() - 1
    }

    /// The same rank space with the top tiers merged down to `depth`
    /// levels (the widths above `depth − 1` multiply into one top
    /// width). `collapsed(2)` of `[4, 16, 8]` is `[4, 128]` — the
    /// two-level node/fabric view the PR 2 schedule assumed.
    pub fn collapsed(&self, depth: usize) -> TierTree {
        assert!(
            (1..=self.depth()).contains(&depth),
            "collapse depth {depth} out of 1..={}",
            self.depth()
        );
        if depth == self.depth() {
            return self.clone();
        }
        let mut widths: Vec<usize> = self.widths[..depth - 1].to_vec();
        widths.push(self.widths[depth - 1..].iter().product());
        TierTree {
            ranks: self.ranks,
            widths,
        }
    }

    /// The 2-tier node-level view (`ranks`, `gpus_per_node`) that
    /// topology-oblivious code consumes. Lossless for 2-tier trees.
    pub fn to_topology(&self) -> Topology {
        Topology::new(self.ranks, self.widths[0]).expect("a valid tree yields a valid topology")
    }
}

impl From<&Topology> for TierTree {
    fn from(topo: &Topology) -> Self {
        TierTree::new(topo.ranks(), &[topo.gpus_per_node(), topo.nodes()])
            .expect("a valid topology yields a valid 2-tier tree")
    }
}

impl From<Topology> for TierTree {
    fn from(topo: Topology) -> Self {
        TierTree::from(&topo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_tier_layout() {
        // 4 GPUs/node × 16 nodes/rack × 8 racks = 512 ranks.
        let t = TierTree::new(512, &[4, 16, 8]).unwrap();
        assert_eq!(t.depth(), 3);
        assert_eq!(t.span(0), 4);
        assert_eq!(t.span(1), 64);
        assert_eq!(t.span(2), 512);
        assert_eq!(t.groups(0), 128);
        assert_eq!(t.groups(1), 8);
        assert_eq!(t.groups(2), 1);
        // Rank 70: node 17, rack 1, pod 0.
        assert_eq!(t.group_of(0, 70), 17);
        assert_eq!(t.group_of(1, 70), 1);
        assert_eq!(t.group_of(2, 70), 0);
        assert_eq!(t.leader_of(1, 70), 64);
        assert!(t.is_leader(1, 64) && !t.is_leader(1, 70));
        // Participants: everyone at tier 0, node leaders at tier 1,
        // rack leaders at tier 2.
        assert!(t.participates(0, 70));
        assert!(!t.participates(1, 70) && t.participates(1, 68));
        assert!(t.participates(2, 64) && !t.participates(2, 68));
        assert_eq!(t.group_participants(1, 1), (64..128).step_by(4).collect::<Vec<_>>());
        assert_eq!(t.relative_rank(1, 72), 2);
        // LCA: same node → 0; same rack → 1; cross rack → 2.
        assert_eq!(t.lca_tier(70, 71), 0);
        assert_eq!(t.lca_tier(70, 64), 1);
        assert_eq!(t.lca_tier(70, 200), 2);
    }

    #[test]
    fn topology_round_trip_is_lossless() {
        let topo = Topology::new(10, 4).unwrap();
        let tree = TierTree::from(&topo);
        assert_eq!(tree.widths(), &[4, 3]);
        assert_eq!(tree.depth(), 2);
        let back = tree.to_topology();
        assert_eq!(back, topo);
        // Per-tier helpers agree with the Topology ones.
        for r in 0..10 {
            assert_eq!(tree.group_of(0, r), topo.node_of(r));
            assert_eq!(tree.leader_of(0, r), topo.leader_of(r));
            assert_eq!(tree.is_leader(0, r), topo.is_leader(r));
        }
        assert_eq!(tree.group_participants(0, 2), vec![8, 9]);
    }

    #[test]
    fn collapsed_merges_top_tiers() {
        let t = TierTree::new(512, &[4, 16, 8]).unwrap();
        let two = t.collapsed(2);
        assert_eq!(two.widths(), &[4, 128]);
        assert_eq!(two.span(1), 512);
        assert_eq!(t.collapsed(3), t);
        // Rank assignments below the merge point are unchanged.
        for r in [0usize, 5, 70, 511] {
            assert_eq!(two.group_of(0, r), t.group_of(0, r));
        }
    }

    #[test]
    fn partial_groups_and_validation() {
        // 100 ranks on a 4x16x8 tree: last rack partially filled.
        let t = TierTree::new(100, &[4, 16, 8]).unwrap();
        assert_eq!(t.groups(1), 2);
        assert_eq!(t.group_participants(1, 1), (64..100).step_by(4).collect::<Vec<_>>());
        // Effective widths follow the actual coverage, not the spec:
        // the fullest rack has 16 node leaders, the top tier only 2
        // rack leaders (the declared 8 never materialize).
        assert_eq!(t.effective_width(0), 4);
        assert_eq!(t.effective_width(1), 16);
        assert_eq!(t.effective_width(2), 2);
        let full = TierTree::new(512, &[4, 16, 8]).unwrap();
        for tier in 0..3 {
            assert_eq!(full.effective_width(tier), full.width(tier));
        }
        // Coverage and zero validation.
        assert!(TierTree::new(0, &[4]).is_err());
        assert!(TierTree::new(4, &[]).is_err());
        assert!(TierTree::new(4, &[0, 2]).is_err());
        assert!(TierTree::new(513, &[4, 16, 8]).is_err(), "tree must cover all ranks");
    }

    #[test]
    fn parse_widths_forms() {
        assert_eq!(TierTree::parse_widths("4x16x8").unwrap(), vec![4, 16, 8]);
        assert_eq!(TierTree::parse_widths("8").unwrap(), vec![8]);
        assert!(TierTree::parse_widths("").is_err());
        assert!(TierTree::parse_widths("4x0x8").is_err());
        assert!(TierTree::parse_widths("4xbanana").is_err());
    }

    #[test]
    fn lca_of_2tier_matches_same_node() {
        let topo = Topology::new(8, 4).unwrap();
        let tree = TierTree::from(&topo);
        for a in 0..8 {
            for b in 0..8 {
                let lca = tree.lca_tier(a, b);
                assert_eq!(lca == 0, topo.same_node(a, b), "{a},{b}");
            }
        }
    }
}
