//! `ExecPlan`: the per-leg execution contract between planning and
//! execution.
//!
//! The planner side of the stack (budget inversion, per-tier splits,
//! tuner schedule selection) and the executor side (the leg interpreter
//! in [`crate::collectives::hierarchical`], the flat collectives) used
//! to meet at a single ambient `spec.error_bound`: the per-tier budget
//! split was derived, reported — and ignored at runtime. The
//! [`ExecPlan`] closes that gap. Every dispatched collective compiles
//! one: a [`LegExec`] per schedule leg carrying the compression mode
//! and the **absolute error bound that leg's compressor must run at**.
//! Flat (non-hierarchical) algorithms become degenerate one-leg plans,
//! so every algorithm flows through the same contract and the executor
//! never falls back to an ambient bound.
//!
//! Construction forms:
//!
//! * [`ExecPlan::flat`] — one leg, the whole collective (ring, ReDoub,
//!   binomial trees).
//! * [`ExecPlan::uniform`] — a compiled [`Schedule`] with every
//!   compressed leg at one bound (un-budgeted hierarchical dispatch).
//! * [`ExecPlan::tiered`] — a compiled schedule with **per-tier**
//!   bounds from [`crate::accuracy::split_across_tiers`]: the budgeted
//!   path, where tier 1 and tier 2 legs genuinely run different
//!   compressors.
//!
//! [`ExecPlan::predicted_bound`] walks the same legs the error model
//! does (`Σ_t A[t] · eb_t` via [`Schedule::tier_sensitivities`]), so
//! the prediction attached to telemetry is exactly the plan that ran.
//! [`ExecPlan::relaxed`] is the adaptation hook: the
//! [`crate::comm::Communicator`]'s adaptive controller scales the
//! planned bounds by the telemetry-derived relaxation factor, with
//! every leg clamped at the certified per-call budget.

use crate::collectives::Op;
use crate::compress::CodecSpec;
use crate::coordinator::CompressionMode;

use super::schedule::Schedule;

/// How one leg of an [`ExecPlan`] compresses: the mode, the staged
/// codec pipeline, and the absolute error bound its compressor runs at.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LegExec {
    /// Compressor family on this leg (`None` = the leg ships raw
    /// payloads — e.g. the NVLink tier-0 legs).
    pub compression: CompressionMode,
    /// The staged codec pipeline the leg runs
    /// ([`crate::compress::CodecSpec`]). A placeholder on raw legs
    /// (never built); on compressed legs it defaults to the canonical
    /// codec of the mode and is overridden by per-leg tuning
    /// ([`crate::topo::Leg::codec`]) or [`ExecPlan::with_codec`].
    pub codec: CodecSpec,
    /// Absolute error bound for the leg's compressor. Ignored for raw
    /// legs; carried for reporting only under the fixed-rate mode
    /// (whose error no bound can describe). Exactly `0.0` on lossless
    /// legs — zero distortion is their guarantee.
    pub eb: f64,
}

impl LegExec {
    /// A raw (uncompressed) leg.
    pub fn raw() -> Self {
        LegExec {
            compression: CompressionMode::None,
            codec: CodecSpec::cuszp(),
            eb: 0.0,
        }
    }

    /// The canonical codec for a compression mode — what legs run when
    /// nothing overrides them. The 8-bit fixed-rate default mirrors
    /// `ClusterSpec::fixed_rate_bits`; executors treat a leg whose
    /// codec equals this default as "use the ambient compressor", so
    /// non-default ambient rates keep working unchanged.
    pub fn default_codec(mode: CompressionMode) -> CodecSpec {
        match mode {
            CompressionMode::FixedRate => CodecSpec::fixed_rate(8),
            _ => CodecSpec::cuszp(),
        }
    }

    /// The compression mode a codec implies: fixed-rate quantizers map
    /// to [`CompressionMode::FixedRate`]; everything else — including
    /// the zero-distortion lossless tier — is
    /// [`CompressionMode::ErrorBounded`].
    pub fn mode_for(codec: CodecSpec) -> CompressionMode {
        if codec.is_fixed_rate() {
            CompressionMode::FixedRate
        } else {
            CompressionMode::ErrorBounded
        }
    }

    /// A compressed leg running an explicit codec. Lossless codecs
    /// carry a zero bound (their distortion is exactly zero).
    pub fn with_codec(codec: CodecSpec, eb: f64) -> Self {
        LegExec {
            compression: Self::mode_for(codec),
            codec,
            eb: if codec.is_lossless() { 0.0 } else { eb },
        }
    }

    /// Whether the codec was explicitly chosen (differs from the
    /// mode's canonical default). Executors rebuild such a leg's
    /// compressor from the codec instead of rebinding the ambient one.
    pub fn codec_overridden(&self) -> bool {
        self.compresses() && self.codec != Self::default_codec(self.compression)
    }

    /// Whether the leg compresses at all.
    pub fn compresses(&self) -> bool {
        self.compression != CompressionMode::None
    }

    /// The error bound the leg's compressor must honor — `Some` only
    /// for the error-bounded mode (raw legs have no compressor;
    /// fixed-rate streams have no bound to rebind).
    pub fn bounded_eb(&self) -> Option<f64> {
        match self.compression {
            CompressionMode::ErrorBounded => Some(self.eb),
            _ => None,
        }
    }
}

/// A compiled execution plan: the leg structure (a hierarchical
/// [`Schedule`], or none for flat algorithms) plus one [`LegExec`] per
/// leg. See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecPlan {
    /// The operation the plan realizes.
    pub op: Op,
    /// Hierarchical leg structure; `None` for flat algorithms, whose
    /// single leg is the whole collective.
    pub schedule: Option<Schedule>,
    /// One directive per schedule leg (exactly one for flat plans).
    pub legs: Vec<LegExec>,
    /// Pipeline depth: how many chunk programs the executor splits the
    /// payload into. `1` (the default of every constructor) is the
    /// barrier executor — each leg runs to completion before the next
    /// starts. Depths above one interleave the legs of successive
    /// chunks in a wavefront so chunk `k`'s internode exchange overlaps
    /// chunk `k+1`'s intranode work. Accuracy is unaffected: every
    /// element still crosses exactly the same legs.
    pub depth: usize,
}

impl ExecPlan {
    /// Degenerate one-leg plan for a flat algorithm: the whole
    /// collective compresses (or not) at one bound.
    pub fn flat(op: Op, compression: CompressionMode, eb: f64) -> Self {
        ExecPlan {
            op,
            schedule: None,
            legs: vec![LegExec {
                compression,
                codec: LegExec::default_codec(compression),
                eb,
            }],
            depth: 1,
        }
    }

    /// Plan a compiled schedule with every compressed leg at the same
    /// bound (raw legs stay raw). This is the un-budgeted hierarchical
    /// dispatch — bitwise-identical execution to the pre-`ExecPlan`
    /// ambient-bound path.
    pub fn uniform(sched: Schedule, compression: CompressionMode, eb: f64) -> Self {
        let legs = sched
            .legs
            .iter()
            .map(|l| {
                if l.compressed && compression != CompressionMode::None {
                    match l.codec {
                        // Per-leg codecs picked by the tuner apply only
                        // to the error-bounded family they were tuned
                        // for; a fixed-rate run keeps its own codec.
                        Some(c) if compression == CompressionMode::ErrorBounded => {
                            LegExec::with_codec(c, eb)
                        }
                        _ => LegExec {
                            compression,
                            codec: LegExec::default_codec(compression),
                            eb,
                        },
                    }
                } else {
                    LegExec::raw()
                }
            })
            .collect();
        ExecPlan {
            op: sched.op,
            schedule: Some(sched),
            legs,
            depth: 1,
        }
    }

    /// Plan a compiled schedule with **per-tier** bounds: the leg at
    /// tier `t` runs at `tier_ebs[t]`, falling back to `fallback_eb`
    /// for compressed legs whose tier has no entry (a split that
    /// declined to budget the tier).
    pub fn tiered(
        sched: Schedule,
        compression: CompressionMode,
        tier_ebs: &[Option<f64>],
        fallback_eb: f64,
    ) -> Self {
        let legs = sched
            .legs
            .iter()
            .map(|l| {
                if l.compressed && compression != CompressionMode::None {
                    let eb = tier_ebs.get(l.tier).copied().flatten().unwrap_or(fallback_eb);
                    match l.codec {
                        Some(c) if compression == CompressionMode::ErrorBounded => {
                            LegExec::with_codec(c, eb)
                        }
                        _ => LegExec {
                            compression,
                            codec: LegExec::default_codec(compression),
                            eb,
                        },
                    }
                } else {
                    LegExec::raw()
                }
            })
            .collect();
        ExecPlan {
            op: sched.op,
            schedule: Some(sched),
            legs,
            depth: 1,
        }
    }

    /// The plan re-pointed at pipeline depth `d` (clamped to at least
    /// one). Chunked execution is only meaningful for scheduled plans;
    /// flat plans keep whatever depth they are given but their
    /// executors ignore it.
    pub fn with_depth(mut self, d: usize) -> Self {
        self.depth = d.max(1);
        self
    }

    /// The directive for leg `li` (flat plans answer their single leg
    /// for every index).
    pub fn leg(&self, li: usize) -> LegExec {
        self.legs
            .get(li)
            .or_else(|| self.legs.first())
            .copied()
            .unwrap_or_else(LegExec::raw)
    }

    /// Worst-case end-to-end pointwise error if every leg runs at its
    /// own bound: `Σ_t A[t] · eb_t` with the sensitivities of
    /// [`Schedule::tier_sensitivities`]. `None` for flat plans (their
    /// amplification is the flat propagation model's business) and for
    /// plans with a fixed-rate leg (unbounded). Uniform plans return
    /// exactly `amplification · eb`.
    pub fn predicted_bound(&self) -> Option<f64> {
        let sched = self.schedule.as_ref()?;
        let mut per_tier: Vec<f64> = vec![0.0; sched.tree.depth()];
        let mut uniform: Option<f64> = None;
        let mut any = false;
        for (leg, ex) in sched.legs.iter().zip(&self.legs) {
            if !ex.compresses() {
                continue;
            }
            let eb = ex.bounded_eb()?; // fixed-rate leg: no bound exists
            per_tier[leg.tier] = per_tier[leg.tier].max(eb);
            uniform = match uniform {
                None => Some(eb),
                Some(u) if u == eb => Some(u),
                Some(_) => Some(f64::NAN),
            };
            any = true;
        }
        if !any {
            return Some(0.0); // nothing compresses: exact
        }
        match uniform {
            // One shared bound: reproduce the closed form exactly (no
            // Σ-of-products rounding drift vs `amplification() · eb`).
            Some(u) if !u.is_nan() => Some(sched.amplification() * u),
            _ => Some(
                sched
                    .tier_sensitivities()
                    .iter()
                    .zip(&per_tier)
                    .map(|(a, e)| a * e)
                    .sum(),
            ),
        }
    }

    /// The adaptation hook: every error-bounded leg's bound scaled by
    /// `factor`, each clamped at `cap` (the certified per-call budget —
    /// no single quantization may exceed it). Raw and fixed-rate legs
    /// are untouched.
    pub fn relaxed(&self, factor: f64, cap: f64) -> ExecPlan {
        let legs = self
            .legs
            .iter()
            .map(|l| match l.compression {
                CompressionMode::ErrorBounded => LegExec {
                    compression: l.compression,
                    codec: l.codec,
                    eb: (l.eb * factor).min(cap),
                },
                _ => *l,
            })
            .collect();
        ExecPlan {
            op: self.op,
            schedule: self.schedule.clone(),
            legs,
            depth: self.depth,
        }
    }

    /// Every compressed leg re-pointed at `codec` — mode and bound
    /// updated to match (lossless legs run at a zero bound, their
    /// actual distortion). Raw legs stay raw. This is how an ambient
    /// `--codec` choice or a bitwise-exact accuracy target overrides
    /// whatever the tuner picked per leg.
    pub fn with_codec(&self, codec: CodecSpec) -> ExecPlan {
        let legs = self
            .legs
            .iter()
            .map(|l| {
                if l.compresses() {
                    LegExec::with_codec(codec, l.eb)
                } else {
                    *l
                }
            })
            .collect();
        ExecPlan {
            op: self.op,
            schedule: self.schedule.clone(),
            legs,
            depth: self.depth,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::{compile_min_error, TierTree};

    fn sched(ranks: usize, widths: &[usize]) -> Schedule {
        compile_min_error(Op::Allreduce, &TierTree::new(ranks, widths).unwrap(), true).unwrap()
    }

    #[test]
    fn uniform_plan_matches_schedule_amplification_exactly() {
        let s = sched(512, &[4, 16, 8]);
        let amp = s.amplification();
        let plan = ExecPlan::uniform(s, CompressionMode::ErrorBounded, 1e-3);
        assert_eq!(plan.predicted_bound(), Some(amp * 1e-3));
        // Raw legs got no bound, compressed legs the shared one.
        let raw = plan.legs.iter().filter(|l| !l.compresses()).count();
        assert!(raw >= 2, "tier-0 ascent/descent stay raw");
        for l in plan.legs.iter().filter(|l| l.compresses()) {
            assert_eq!(l.bounded_eb(), Some(1e-3));
        }
    }

    #[test]
    fn tiered_plan_sums_per_tier_sensitivities() {
        let s = sched(512, &[4, 16, 8]);
        let sens = s.tier_sensitivities();
        let tier_ebs = [None, Some(2e-4), Some(5e-5)];
        let plan = ExecPlan::tiered(s, CompressionMode::ErrorBounded, &tier_ebs, 1e-3);
        let want: f64 = sens[1] * 2e-4 + sens[2] * 5e-5;
        let got = plan.predicted_bound().unwrap();
        assert!((got - want).abs() <= 1e-12 * (1.0 + want), "{got} vs {want}");
        // Legs of different tiers genuinely run different bounds.
        let ebs: Vec<Option<f64>> = plan.legs.iter().map(|l| l.bounded_eb()).collect();
        assert!(ebs.contains(&Some(2e-4)) && ebs.contains(&Some(5e-5)));
    }

    #[test]
    fn flat_and_degenerate_plans() {
        let flat = ExecPlan::flat(Op::Allreduce, CompressionMode::ErrorBounded, 1e-4);
        assert_eq!(flat.legs.len(), 1);
        assert_eq!(flat.leg(0).bounded_eb(), Some(1e-4));
        // Flat plans answer their single leg for any index and predict
        // nothing themselves (the flat propagation model owns that).
        assert_eq!(flat.leg(7), flat.leg(0));
        assert_eq!(flat.predicted_bound(), None);
        // A fully-raw plan predicts exact.
        let raw = ExecPlan::uniform(sched(16, &[4, 4]), CompressionMode::None, 0.0);
        assert_eq!(raw.predicted_bound(), Some(0.0));
        // A fixed-rate leg has no bound at all.
        let fr = ExecPlan::uniform(sched(16, &[4, 4]), CompressionMode::FixedRate, 0.0);
        assert_eq!(fr.predicted_bound(), None);
    }

    #[test]
    fn default_codecs_follow_the_mode() {
        let plan = ExecPlan::uniform(sched(512, &[4, 16, 8]), CompressionMode::ErrorBounded, 1e-3);
        for l in plan.legs.iter().filter(|l| l.compresses()) {
            assert_eq!(l.codec, CodecSpec::cuszp());
            assert!(!l.codec_overridden());
        }
        let fr = ExecPlan::flat(Op::Allreduce, CompressionMode::FixedRate, 0.0);
        assert_eq!(fr.legs[0].codec, CodecSpec::fixed_rate(8));
        assert!(!fr.legs[0].codec_overridden());
    }

    #[test]
    fn with_codec_overrides_compressed_legs_only() {
        let plan = ExecPlan::uniform(sched(512, &[4, 16, 8]), CompressionMode::ErrorBounded, 1e-3);
        let lossless = plan.with_codec(CodecSpec::lossless());
        for (a, b) in plan.legs.iter().zip(&lossless.legs) {
            if a.compresses() {
                assert_eq!(b.codec, CodecSpec::lossless());
                assert!(b.codec_overridden());
                // Lossless legs carry the zero bound they honor.
                assert_eq!(b.bounded_eb(), Some(0.0));
            } else {
                assert_eq!(a, b);
            }
        }
        // Zero distortion on every leg ⇒ the plan predicts exact.
        assert_eq!(lossless.predicted_bound(), Some(0.0));
        // A fixed-rate override flips the mode and drops the bound.
        let fr = plan.with_codec(CodecSpec::fixed_rate(12));
        let ex = fr.legs.iter().find(|l| l.compresses()).unwrap();
        assert_eq!(ex.compression, CompressionMode::FixedRate);
        assert_eq!(ex.bounded_eb(), None);
        assert_eq!(fr.predicted_bound(), None);
    }

    #[test]
    fn relaxed_scales_and_clamps_at_the_cap() {
        let s = sched(512, &[4, 16, 8]);
        let plan = ExecPlan::tiered(
            s,
            CompressionMode::ErrorBounded,
            &[None, Some(2e-4), Some(8e-4)],
            1e-3,
        );
        let relaxed = plan.relaxed(4.0, 1e-3);
        for (a, b) in plan.legs.iter().zip(&relaxed.legs) {
            match a.bounded_eb() {
                Some(eb) => {
                    let want = (eb * 4.0).min(1e-3);
                    assert_eq!(b.bounded_eb(), Some(want));
                }
                None => assert_eq!(a, b),
            }
        }
        // The 8e-4 tier hit the cap, the 2e-4 tier scaled freely.
        assert!(relaxed.legs.iter().any(|l| l.bounded_eb() == Some(1e-3)));
        assert!(relaxed.legs.iter().any(|l| l.bounded_eb() == Some(8e-4)));
    }
}
