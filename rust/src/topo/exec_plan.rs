//! `ExecPlan`: the per-leg execution contract between planning and
//! execution.
//!
//! The planner side of the stack (budget inversion, per-tier splits,
//! tuner schedule selection) and the executor side (the leg interpreter
//! in [`crate::collectives::hierarchical`], the flat collectives) used
//! to meet at a single ambient `spec.error_bound`: the per-tier budget
//! split was derived, reported — and ignored at runtime. The
//! [`ExecPlan`] closes that gap. Every dispatched collective compiles
//! one: a [`LegExec`] per schedule leg carrying the compression mode
//! and the **absolute error bound that leg's compressor must run at**.
//! Flat (non-hierarchical) algorithms become degenerate one-leg plans,
//! so every algorithm flows through the same contract and the executor
//! never falls back to an ambient bound.
//!
//! Construction forms:
//!
//! * [`ExecPlan::flat`] — one leg, the whole collective (ring, ReDoub,
//!   binomial trees).
//! * [`ExecPlan::uniform`] — a compiled [`Schedule`] with every
//!   compressed leg at one bound (un-budgeted hierarchical dispatch).
//! * [`ExecPlan::tiered`] — a compiled schedule with **per-tier**
//!   bounds from [`crate::accuracy::split_across_tiers`]: the budgeted
//!   path, where tier 1 and tier 2 legs genuinely run different
//!   compressors.
//!
//! [`ExecPlan::predicted_bound`] walks the same legs the error model
//! does (`Σ_t A[t] · eb_t` via [`Schedule::tier_sensitivities`]), so
//! the prediction attached to telemetry is exactly the plan that ran.
//! [`ExecPlan::relaxed`] is the adaptation hook: the
//! [`crate::comm::Communicator`]'s adaptive controller scales the
//! planned bounds by the telemetry-derived relaxation factor, with
//! every leg clamped at the certified per-call budget.

use crate::collectives::Op;
use crate::coordinator::CompressionMode;

use super::schedule::Schedule;

/// How one leg of an [`ExecPlan`] compresses: the mode and the
/// absolute error bound its compressor runs at.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LegExec {
    /// Compressor family on this leg (`None` = the leg ships raw
    /// payloads — e.g. the NVLink tier-0 legs).
    pub compression: CompressionMode,
    /// Absolute error bound for the leg's compressor. Ignored for raw
    /// legs; carried for reporting only under the fixed-rate mode
    /// (whose error no bound can describe).
    pub eb: f64,
}

impl LegExec {
    /// A raw (lossless) leg.
    pub fn raw() -> Self {
        LegExec {
            compression: CompressionMode::None,
            eb: 0.0,
        }
    }

    /// Whether the leg compresses at all.
    pub fn compresses(&self) -> bool {
        self.compression != CompressionMode::None
    }

    /// The error bound the leg's compressor must honor — `Some` only
    /// for the error-bounded mode (raw legs have no compressor;
    /// fixed-rate streams have no bound to rebind).
    pub fn bounded_eb(&self) -> Option<f64> {
        match self.compression {
            CompressionMode::ErrorBounded => Some(self.eb),
            _ => None,
        }
    }
}

/// A compiled execution plan: the leg structure (a hierarchical
/// [`Schedule`], or none for flat algorithms) plus one [`LegExec`] per
/// leg. See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecPlan {
    /// The operation the plan realizes.
    pub op: Op,
    /// Hierarchical leg structure; `None` for flat algorithms, whose
    /// single leg is the whole collective.
    pub schedule: Option<Schedule>,
    /// One directive per schedule leg (exactly one for flat plans).
    pub legs: Vec<LegExec>,
}

impl ExecPlan {
    /// Degenerate one-leg plan for a flat algorithm: the whole
    /// collective compresses (or not) at one bound.
    pub fn flat(op: Op, compression: CompressionMode, eb: f64) -> Self {
        ExecPlan {
            op,
            schedule: None,
            legs: vec![LegExec { compression, eb }],
        }
    }

    /// Plan a compiled schedule with every compressed leg at the same
    /// bound (raw legs stay raw). This is the un-budgeted hierarchical
    /// dispatch — bitwise-identical execution to the pre-`ExecPlan`
    /// ambient-bound path.
    pub fn uniform(sched: Schedule, compression: CompressionMode, eb: f64) -> Self {
        let legs = sched
            .legs
            .iter()
            .map(|l| {
                if l.compressed && compression != CompressionMode::None {
                    LegExec { compression, eb }
                } else {
                    LegExec::raw()
                }
            })
            .collect();
        ExecPlan {
            op: sched.op,
            schedule: Some(sched),
            legs,
        }
    }

    /// Plan a compiled schedule with **per-tier** bounds: the leg at
    /// tier `t` runs at `tier_ebs[t]`, falling back to `fallback_eb`
    /// for compressed legs whose tier has no entry (a split that
    /// declined to budget the tier).
    pub fn tiered(
        sched: Schedule,
        compression: CompressionMode,
        tier_ebs: &[Option<f64>],
        fallback_eb: f64,
    ) -> Self {
        let legs = sched
            .legs
            .iter()
            .map(|l| {
                if l.compressed && compression != CompressionMode::None {
                    let eb = tier_ebs.get(l.tier).copied().flatten().unwrap_or(fallback_eb);
                    LegExec { compression, eb }
                } else {
                    LegExec::raw()
                }
            })
            .collect();
        ExecPlan {
            op: sched.op,
            schedule: Some(sched),
            legs,
        }
    }

    /// The directive for leg `li` (flat plans answer their single leg
    /// for every index).
    pub fn leg(&self, li: usize) -> LegExec {
        self.legs
            .get(li)
            .or_else(|| self.legs.first())
            .copied()
            .unwrap_or_else(LegExec::raw)
    }

    /// Worst-case end-to-end pointwise error if every leg runs at its
    /// own bound: `Σ_t A[t] · eb_t` with the sensitivities of
    /// [`Schedule::tier_sensitivities`]. `None` for flat plans (their
    /// amplification is the flat propagation model's business) and for
    /// plans with a fixed-rate leg (unbounded). Uniform plans return
    /// exactly `amplification · eb`.
    pub fn predicted_bound(&self) -> Option<f64> {
        let sched = self.schedule.as_ref()?;
        let mut per_tier: Vec<f64> = vec![0.0; sched.tree.depth()];
        let mut uniform: Option<f64> = None;
        let mut any = false;
        for (leg, ex) in sched.legs.iter().zip(&self.legs) {
            if !ex.compresses() {
                continue;
            }
            let eb = ex.bounded_eb()?; // fixed-rate leg: no bound exists
            per_tier[leg.tier] = per_tier[leg.tier].max(eb);
            uniform = match uniform {
                None => Some(eb),
                Some(u) if u == eb => Some(u),
                Some(_) => Some(f64::NAN),
            };
            any = true;
        }
        if !any {
            return Some(0.0); // nothing compresses: exact
        }
        match uniform {
            // One shared bound: reproduce the closed form exactly (no
            // Σ-of-products rounding drift vs `amplification() · eb`).
            Some(u) if !u.is_nan() => Some(sched.amplification() * u),
            _ => Some(
                sched
                    .tier_sensitivities()
                    .iter()
                    .zip(&per_tier)
                    .map(|(a, e)| a * e)
                    .sum(),
            ),
        }
    }

    /// The adaptation hook: every error-bounded leg's bound scaled by
    /// `factor`, each clamped at `cap` (the certified per-call budget —
    /// no single quantization may exceed it). Raw and fixed-rate legs
    /// are untouched.
    pub fn relaxed(&self, factor: f64, cap: f64) -> ExecPlan {
        let legs = self
            .legs
            .iter()
            .map(|l| match l.compression {
                CompressionMode::ErrorBounded => LegExec {
                    compression: l.compression,
                    eb: (l.eb * factor).min(cap),
                },
                _ => *l,
            })
            .collect();
        ExecPlan {
            op: self.op,
            schedule: self.schedule.clone(),
            legs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::{compile_min_error, TierTree};

    fn sched(ranks: usize, widths: &[usize]) -> Schedule {
        compile_min_error(Op::Allreduce, &TierTree::new(ranks, widths).unwrap(), true).unwrap()
    }

    #[test]
    fn uniform_plan_matches_schedule_amplification_exactly() {
        let s = sched(512, &[4, 16, 8]);
        let amp = s.amplification();
        let plan = ExecPlan::uniform(s, CompressionMode::ErrorBounded, 1e-3);
        assert_eq!(plan.predicted_bound(), Some(amp * 1e-3));
        // Raw legs got no bound, compressed legs the shared one.
        let raw = plan.legs.iter().filter(|l| !l.compresses()).count();
        assert!(raw >= 2, "tier-0 ascent/descent stay raw");
        for l in plan.legs.iter().filter(|l| l.compresses()) {
            assert_eq!(l.bounded_eb(), Some(1e-3));
        }
    }

    #[test]
    fn tiered_plan_sums_per_tier_sensitivities() {
        let s = sched(512, &[4, 16, 8]);
        let sens = s.tier_sensitivities();
        let tier_ebs = [None, Some(2e-4), Some(5e-5)];
        let plan = ExecPlan::tiered(s, CompressionMode::ErrorBounded, &tier_ebs, 1e-3);
        let want: f64 = sens[1] * 2e-4 + sens[2] * 5e-5;
        let got = plan.predicted_bound().unwrap();
        assert!((got - want).abs() <= 1e-12 * (1.0 + want), "{got} vs {want}");
        // Legs of different tiers genuinely run different bounds.
        let ebs: Vec<Option<f64>> = plan.legs.iter().map(|l| l.bounded_eb()).collect();
        assert!(ebs.contains(&Some(2e-4)) && ebs.contains(&Some(5e-5)));
    }

    #[test]
    fn flat_and_degenerate_plans() {
        let flat = ExecPlan::flat(Op::Allreduce, CompressionMode::ErrorBounded, 1e-4);
        assert_eq!(flat.legs.len(), 1);
        assert_eq!(flat.leg(0).bounded_eb(), Some(1e-4));
        // Flat plans answer their single leg for any index and predict
        // nothing themselves (the flat propagation model owns that).
        assert_eq!(flat.leg(7), flat.leg(0));
        assert_eq!(flat.predicted_bound(), None);
        // A fully-raw plan predicts exact.
        let raw = ExecPlan::uniform(sched(16, &[4, 4]), CompressionMode::None, 0.0);
        assert_eq!(raw.predicted_bound(), Some(0.0));
        // A fixed-rate leg has no bound at all.
        let fr = ExecPlan::uniform(sched(16, &[4, 4]), CompressionMode::FixedRate, 0.0);
        assert_eq!(fr.predicted_bound(), None);
    }

    #[test]
    fn relaxed_scales_and_clamps_at_the_cap() {
        let s = sched(512, &[4, 16, 8]);
        let plan = ExecPlan::tiered(
            s,
            CompressionMode::ErrorBounded,
            &[None, Some(2e-4), Some(8e-4)],
            1e-3,
        );
        let relaxed = plan.relaxed(4.0, 1e-3);
        for (a, b) in plan.legs.iter().zip(&relaxed.legs) {
            match a.bounded_eb() {
                Some(eb) => {
                    let want = (eb * 4.0).min(1e-3);
                    assert_eq!(b.bounded_eb(), Some(want));
                }
                None => assert_eq!(a, b),
            }
        }
        // The 8e-4 tier hit the cap, the 2e-4 tier scaled freely.
        assert!(relaxed.legs.iter().any(|l| l.bounded_eb() == Some(1e-3)));
        assert!(relaxed.legs.iter().any(|l| l.bounded_eb() == Some(8e-4)));
    }
}
