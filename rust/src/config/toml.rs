//! A small TOML-subset parser: `[section]` headers, `key = value` with
//! string / float / integer / boolean values, `#` comments. Flattened
//! into dotted keys (`section.key`). Enough for cluster config files;
//! intentionally not a full TOML implementation.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Any numeric literal (stored as f64).
    Num(f64),
    /// true/false.
    Bool(bool),
}

impl TomlValue {
    /// As f64, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// As usize, if numeric and integral.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Some(*v as usize),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// A flattened TOML document: dotted keys → values.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

fn parse_value(raw: &str, line_no: usize) -> Result<TomlValue> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(Error::config(format!("line {line_no}: empty value")));
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| Error::config(format!("line {line_no}: unterminated string")))?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match raw {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    raw.replace('_', "")
        .parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| Error::config(format!("line {line_no}: bad value `{raw}`")))
}

impl TomlDoc {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (i, line) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = match line.find('#') {
                // Keep '#' inside quoted strings.
                Some(pos) if !line[..pos].contains('"') => &line[..pos],
                _ => line,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner
                    .strip_suffix(']')
                    .ok_or_else(|| Error::config(format!("line {line_no}: bad section")))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| Error::config(format!("line {line_no}: expected key = value")))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{}.{}", section, key.trim())
            };
            doc.values.insert(full_key, parse_value(value, line_no)?);
        }
        Ok(doc)
    }

    /// Set a dotted key from a `key=value` override string.
    pub fn set_override(&mut self, pair: &str) -> Result<()> {
        let (key, value) = pair
            .split_once('=')
            .ok_or_else(|| Error::config(format!("override `{pair}`: expected key=value")))?;
        self.values
            .insert(key.trim().to_string(), parse_value(value, 0)?);
        Ok(())
    }

    /// Get a value by dotted key.
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    /// f64 with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// usize with a default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    /// bool with a default.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// string with a default.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    /// Number of keys.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// Whether the document is empty.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
# cluster layout
ranks = 64
[network]
internode_gbps = 100.0
name = "slingshot"
shared_nic = false
[gpu]
compress_beta = 350e9
"#,
        )
        .unwrap();
        assert_eq!(doc.usize_or("ranks", 0), 64);
        assert_eq!(doc.f64_or("network.internode_gbps", 0.0), 100.0);
        assert_eq!(doc.str_or("network.name", ""), "slingshot");
        assert!(!doc.bool_or("network.shared_nic", true));
        assert_eq!(doc.f64_or("gpu.compress_beta", 0.0), 350e9);
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let doc = TomlDoc::parse("").unwrap();
        assert!(doc.is_empty());
        assert_eq!(doc.usize_or("nope", 7), 7);
        assert_eq!(doc.str_or("nope", "x"), "x");
    }

    #[test]
    fn overrides_win() {
        let mut doc = TomlDoc::parse("a = 1\n").unwrap();
        doc.set_override("a=2").unwrap();
        doc.set_override("b.c=3.5").unwrap();
        assert_eq!(doc.usize_or("a", 0), 2);
        assert_eq!(doc.f64_or("b.c", 0.0), 3.5);
    }

    #[test]
    fn underscores_in_numbers() {
        let doc = TomlDoc::parse("n = 1_000_000\n").unwrap();
        assert_eq!(doc.usize_or("n", 0), 1_000_000);
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("novalue =\n").is_err());
        assert!(TomlDoc::parse("bad value\n").is_err());
        let e = TomlDoc::parse("x = @@\n").unwrap_err().to_string();
        assert!(e.contains("line 1"), "{e}");
    }

    #[test]
    fn comments_stripped_outside_strings() {
        let doc = TomlDoc::parse("a = 5 # five\ns = \"has # inside\"\n").unwrap();
        assert_eq!(doc.usize_or("a", 0), 5);
        assert_eq!(doc.str_or("s", ""), "has # inside");
    }
}
