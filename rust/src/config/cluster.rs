//! Typed cluster configuration → [`ClusterSpec`].

use crate::coordinator::{ClusterSpec, ExecPolicy};
use crate::error::{Error, Result};
use crate::gpu::{GpuModel, KernelModel};
use crate::net::{LinkModel, Topology};

use super::toml::TomlDoc;

/// Everything a run needs, with paper-testbed defaults. All fields can
/// come from a TOML file and/or `key=value` overrides.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Total ranks (GPUs).
    pub ranks: usize,
    /// GPUs per node.
    pub gpus_per_node: usize,
    /// Variant name: gzccl | gpu-centric | ccoll | cprp2p | nccl | cray.
    pub variant: String,
    /// Absolute error bound.
    pub error_bound: f64,
    /// Internode bandwidth (Gbit/s, Slingshot-10 = 100).
    pub internode_gbps: f64,
    /// Internode latency (µs).
    pub internode_lat_us: f64,
    /// Intranode bandwidth (GB/s).
    pub intranode_gbs: f64,
    /// GPU compressor saturated throughput (GB/s).
    pub compress_gbs: f64,
    /// GPU decompressor saturated throughput (GB/s).
    pub decompress_gbs: f64,
    /// Compressor fixed-work floor (MB).
    pub kernel_floor_mb: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            ranks: 64,
            gpus_per_node: 4,
            variant: "gzccl".into(),
            error_bound: 1e-4,
            internode_gbps: 100.0,
            internode_lat_us: 15.0,
            intranode_gbs: 200.0,
            compress_gbs: 350.0,
            decompress_gbs: 450.0,
            kernel_floor_mb: 200.0,
        }
    }
}

impl ClusterConfig {
    /// Build from a parsed TOML document (missing keys → defaults).
    pub fn from_doc(doc: &TomlDoc) -> Self {
        let d = ClusterConfig::default();
        ClusterConfig {
            ranks: doc.usize_or("cluster.ranks", d.ranks),
            gpus_per_node: doc.usize_or("cluster.gpus_per_node", d.gpus_per_node),
            variant: doc.str_or("cluster.variant", &d.variant).to_string(),
            error_bound: doc.f64_or("compression.error_bound", d.error_bound),
            internode_gbps: doc.f64_or("network.internode_gbps", d.internode_gbps),
            internode_lat_us: doc.f64_or("network.internode_lat_us", d.internode_lat_us),
            intranode_gbs: doc.f64_or("network.intranode_gbs", d.intranode_gbs),
            compress_gbs: doc.f64_or("gpu.compress_gbs", d.compress_gbs),
            decompress_gbs: doc.f64_or("gpu.decompress_gbs", d.decompress_gbs),
            kernel_floor_mb: doc.f64_or("gpu.kernel_floor_mb", d.kernel_floor_mb),
        }
    }

    /// Load from an optional file plus `key=value` overrides.
    pub fn load(path: Option<&str>, overrides: &[String]) -> Result<Self> {
        let mut doc = match path {
            Some(p) => TomlDoc::parse(&std::fs::read_to_string(p)?)?,
            None => TomlDoc::default(),
        };
        for o in overrides {
            doc.set_override(o)?;
        }
        Ok(Self::from_doc(&doc))
    }

    /// Resolve the variant name to a policy.
    pub fn policy(&self) -> Result<ExecPolicy> {
        Ok(match self.variant.as_str() {
            "gzccl" => ExecPolicy::gzccl(),
            "gpu-centric" => ExecPolicy::gpu_centric_unoptimized(),
            "ccoll" => ExecPolicy::ccoll(),
            "cprp2p" => ExecPolicy::cprp2p(),
            "nccl" => ExecPolicy::nccl(),
            "cray" => ExecPolicy::cray_mpi(),
            other => {
                return Err(Error::config(format!(
                    "unknown variant `{other}` (gzccl|gpu-centric|ccoll|cprp2p|nccl|cray)"
                )))
            }
        })
    }

    /// Materialize a [`ClusterSpec`].
    pub fn to_spec(&self) -> Result<ClusterSpec> {
        let policy = self.policy()?;
        let mut gpu = GpuModel::a100();
        gpu.compress = KernelModel::new(
            gpu.compress.launch,
            self.kernel_floor_mb * 1e6,
            self.compress_gbs * 1e9,
        );
        gpu.decompress = KernelModel::new(
            gpu.decompress.launch,
            self.kernel_floor_mb * 0.8 * 1e6,
            self.decompress_gbs * 1e9,
        );
        // Build from the real layout so the tier view stays in sync
        // with the topology (ClusterSpec keeps both).
        let topo = Topology::new(self.ranks, self.gpus_per_node)?;
        let mut spec = ClusterSpec::with_topology(topo, policy).with_error_bound(self.error_bound);
        spec.gpu = gpu;
        spec.internode = LinkModel::new(
            self.internode_lat_us * 1e-6,
            self.internode_gbps * 1e9 / 8.0,
        );
        spec.intranode = LinkModel::new(5e-6, self.intranode_gbs * 1e9);
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_build_a_valid_spec() {
        let cfg = ClusterConfig::default();
        let spec = cfg.to_spec().unwrap();
        assert_eq!(spec.topo.ranks(), 64);
        assert!((spec.internode.beta - 12.5e9).abs() < 1e3);
    }

    #[test]
    fn file_and_overrides_compose() {
        let doc = TomlDoc::parse(
            "[cluster]\nranks = 8\nvariant = \"nccl\"\n[network]\ninternode_gbps = 200\n",
        )
        .unwrap();
        let cfg = ClusterConfig::from_doc(&doc);
        assert_eq!(cfg.ranks, 8);
        assert_eq!(cfg.variant, "nccl");
        let spec = cfg.to_spec().unwrap();
        assert!((spec.internode.beta - 25e9).abs() < 1e3);
    }

    #[test]
    fn all_variants_resolve() {
        for v in ["gzccl", "gpu-centric", "ccoll", "cprp2p", "nccl", "cray"] {
            let cfg = ClusterConfig {
                variant: v.into(),
                ..Default::default()
            };
            assert!(cfg.policy().is_ok(), "{v}");
        }
        let bad = ClusterConfig {
            variant: "mystery".into(),
            ..Default::default()
        };
        assert!(bad.policy().is_err());
    }

    #[test]
    fn kernel_knobs_propagate() {
        let mut cfg = ClusterConfig::default();
        cfg.compress_gbs = 100.0;
        cfg.kernel_floor_mb = 10.0;
        let spec = cfg.to_spec().unwrap();
        assert!((spec.gpu.compress.beta - 100e9).abs() < 1.0);
        assert!((spec.gpu.compress.n0 - 10e6).abs() < 1.0);
    }
}
