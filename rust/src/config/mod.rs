//! Configuration system.
//!
//! A TOML-subset parser (`toml.rs` — the vendored dependency set has no
//! serde) plus typed cluster configuration that maps onto
//! [`crate::coordinator::ClusterSpec`]. Supports the testbed presets
//! the paper evaluates on and full per-parameter overrides from file or
//! `key=value` CLI pairs.

pub mod cluster;
pub mod toml;

pub use cluster::ClusterConfig;
pub use toml::TomlDoc;
