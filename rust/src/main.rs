//! gZCCL CLI: launch collectives, regenerate the paper's experiments,
//! run the applications.
//!
//! ```text
//! gzccl run        [--config F] [--set k=v ...] [--op allreduce|scatter|...] [--size-mb N]
//!                  [--codec cuszp|lossless|rle-rice|fixedN|p+q+c] [--calibrate]
//! gzccl experiment <fig2|fig3|fig6|fig7|fig8|fig9|fig10|fig11|fig12|table1|table2|fig13|all>
//! gzccl stack      [--ranks N] [--eb X] [--codec C] [--calibrate]
//! gzccl train      [--ranks N] [--steps N] [--no-compress] [--codec C] [--calibrate]
//! gzccl analyze    FILE
//! gzccl characterize
//! ```

use gzccl::apps::ddp::{train_ddp, DdpConfig};
use gzccl::apps::stacking::{run_stacking, StackingConfig, StackingTarget, StackingVariant};
use gzccl::collectives::Algo;
use gzccl::comm::{AlgoHint, CollectiveSpec, Communicator, Pipeline};
use gzccl::compress::CodecSpec;
use gzccl::config::ClusterConfig;
use gzccl::coordinator::{CompressionMode, DeviceBuf, ExecBackend};
use gzccl::error::{Error, Result};
use gzccl::experiments as exp;
use gzccl::obs::{export as obs_export, TraceRun, Tracer};
use gzccl::runtime::Engine;
use gzccl::topo::{LegExec, TierTree};

/// Tiny argument cursor: flags with values, collected overrides.
struct Args {
    rest: Vec<String>,
}

impl Args {
    fn new() -> Self {
        Args {
            rest: std::env::args().skip(1).collect(),
        }
    }

    fn subcommand(&mut self) -> Option<String> {
        if self.rest.is_empty() {
            None
        } else {
            Some(self.rest.remove(0))
        }
    }

    /// Take `--flag value`, if present.
    fn take(&mut self, flag: &str) -> Option<String> {
        let pos = self.rest.iter().position(|a| a == flag)?;
        if pos + 1 >= self.rest.len() {
            return None;
        }
        self.rest.remove(pos);
        Some(self.rest.remove(pos))
    }

    /// Take all occurrences of `--flag value`.
    fn take_all(&mut self, flag: &str) -> Vec<String> {
        let mut out = vec![];
        while let Some(v) = self.take(flag) {
            out.push(v);
        }
        out
    }

    /// Take a boolean `--flag`.
    fn take_bool(&mut self, flag: &str) -> bool {
        if let Some(pos) = self.rest.iter().position(|a| a == flag) {
            self.rest.remove(pos);
            true
        } else {
            false
        }
    }
}

const USAGE: &str = "\
gZCCL — compression-accelerated collective communication (paper reproduction)

USAGE:
  gzccl run         [--config FILE] [--set k=v ...] [--op OP] [--size-mb N]
                    [--gpus-per-node G] [--tiers WxWx...]
                    [--trace FILE]          record the flight recorder and
                        write a Perfetto-loadable Chrome trace (virtual
                        time) to FILE, plus aggregated metrics to
                        FILE's stem + `.metrics.json`. Also accepted by
                        `stack` and `train`.
                    [--codec C]             pin every compressed leg to one
                        staged codec pipeline instead of the canonical
                        compressor (and the tuner's per-leg picks).
                        C: cuszp | lossless | rle-rice | fixedN (N bits)
                        | predictor+quantizer+coder, e.g.
                        lorenzo+prequant+rice (see CodecSpec::parse)
                    [--calibrate]           trace the run, fit effective
                        per-tier bandwidths/latencies and per-codec
                        kernel factors from the observed spans, and
                        replay the collective under the calibrated
                        cost model (prints the fit, the makespan
                        delta, and the residual shrink). Also accepted
                        by `stack` and `train`. Implies an internal
                        tracer when --trace is absent.
                    [--backend threads|events]
                    --backend events (default): single-threaded
                        event-driven engine, scales to 10^4-10^5 ranks;
                        threads: the thread-per-rank reference runner
                        (identical payloads and makespans, bounded by
                        OS thread limits)
                    [--pipeline auto|off|D]  chunk-level leg overlap for
                        scheduled collectives: auto (default) prices
                        every depth up to 8 with the cost model and
                        runs the argmin, off pins the depth-1 barrier
                        executor, D pins an explicit depth. Outputs are
                        bitwise identical at every depth.
                    OP: allreduce (tuner-selected) | allreduce-ring |
                        allreduce-redoub | allreduce-hier | allreduce-tree |
                        reduce_scatter | reduce_scatter-hier |
                        allgather | allgather-hier | scatter | bcast
                    --tiers 4x16x8: multi-tier layout (GPUs/node x
                        nodes/rack x racks ...); the widths must cover
                        the rank count, and the first width overrides
                        --gpus-per-node. Deep layouts model shared,
                        oversubscribed rack/pod uplinks, and the tuner
                        picks the schedule depth and per-tier legs.
  gzccl experiment  <fig2|fig3|fig6|fig7|fig8|fig9|fig10|fig11|fig12|
                     table1|table2|fig13|all> [--fast] [--gpus-per-node G]
  gzccl stack       [--ranks N] [--eb X] [--gpus-per-node G]
                    [--accuracy-target T]   T: absolute L-inf (e.g. 1e-3)
                                            or a PSNR floor (e.g. 55db);
                                            the planner derives each
                                            variant's eb and rejects
                                            variants it cannot certify
                    [--adaptive]            close the telemetry loop:
                                            observed headroom relaxes the
                                            next call's planned eb (needs
                                            --accuracy-target)
                    [--codec C]             staged codec for the compressed
                                            variants (see `gzccl run`)
                    [--calibrate]           fit a calibration from the
                                            richest traced variant and
                                            replay all variants under it
  gzccl train       [--ranks N] [--steps N] [--no-compress]
                    [--accuracy-target X]   X: absolute L-inf budget on
                                            the summed gradients across
                                            all steps
                    [--adaptive]            relax the per-step eb from
                                            telemetry headroom across
                                            training steps (needs
                                            --accuracy-target)
                    [--codec C]             staged codec for gradient
                                            compression (see `gzccl run`)
                    [--calibrate]           fit a calibration from the
                                            traced steps and replay the
                                            training run under it
                    [--pipeline auto|off|D] pipeline-depth policy for the
                                            gradient allreduce (see
                                            `gzccl run`)
                    [--overlap]             plan the gradient allreduce
                                            once (persistent), launch it
                                            non-blocking each step and
                                            prepare the next batch while
                                            it flies
  gzccl analyze     FILE                    re-import a --trace file and
                                            print per-run summaries,
                                            the critical path, bottleneck
                                            attribution, and prediction
                                            residuals
  gzccl characterize
  gzccl help
";

/// Export the flight recorder: merged Chrome-trace JSON to `path`,
/// aggregated metrics next to it (`<stem>.metrics.json`), one summary
/// line per drained run. Called even when the traced command failed —
/// a partial trace is exactly what debugs a deadlock.
fn write_trace(path: &str, tracer: &Tracer) -> Result<()> {
    if tracer.has_pending() {
        tracer.take_run(vec![("run".into(), "partial".into())]);
    }
    let runs = tracer.runs();
    // Analyze every archived run once: the critical path rides the
    // export as a dedicated Perfetto track, and the same analysis
    // prints below each run's summary.
    let analyses: Vec<_> = runs.iter().map(|r| r.analyze()).collect();
    let mut extra = Vec::new();
    let mut offset = 0.0;
    for (run, a) in runs.iter().zip(&analyses) {
        extra.extend(obs_export::critical_path_events(a, offset));
        offset += run.root_end();
    }
    let views: Vec<&TraceRun> = runs.iter().map(|r| r.as_ref()).collect();
    std::fs::write(path, obs_export::chrome_json_with_extra(&views, &extra)).map_err(Error::Io)?;
    let metrics_path = match path.strip_suffix(".json") {
        Some(stem) => format!("{stem}.metrics.json"),
        None => format!("{path}.metrics.json"),
    };
    std::fs::write(&metrics_path, tracer.metrics_json()).map_err(Error::Io)?;
    for (run, a) in runs.iter().zip(&analyses) {
        println!("{}", run.summary());
        println!("{a}");
    }
    println!("trace written: {path} (metrics: {metrics_path})");
    Ok(())
}

/// `gzccl analyze FILE`: re-import a previously written Chrome trace
/// and rerun the analyzer on it — summary, critical path, bottleneck
/// attribution, residuals — without re-simulating anything.
fn cmd_analyze(mut args: Args) -> Result<()> {
    let file = args
        .subcommand()
        .ok_or_else(|| Error::config("analyze: which trace file? (gzccl analyze FILE)"))?;
    let text = std::fs::read_to_string(&file).map_err(Error::Io)?;
    let runs = obs_export::import_chrome_json(&text).map_err(Error::config)?;
    let many = runs.len() > 1;
    for (i, run) in runs.iter().enumerate() {
        if many {
            println!("== run {i} ==");
        }
        println!("{}", run.summary());
        println!("{}", run.analyze());
    }
    Ok(())
}

/// Parse a stacking accuracy target: `"55db"` → PSNR floor, plain
/// float → absolute L∞ bound.
fn parse_accuracy_target(s: &str) -> Result<StackingTarget> {
    let lower = s.to_ascii_lowercase();
    if let Some(db) = lower.strip_suffix("db") {
        Ok(StackingTarget::PsnrDb(db.parse().map_err(|_| {
            Error::config(format!("bad --accuracy-target `{s}`"))
        })?))
    } else {
        Ok(StackingTarget::Abs(s.parse().map_err(|_| {
            Error::config(format!("bad --accuracy-target `{s}`"))
        })?))
    }
}

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut args = Args::new();
    match args.subcommand().as_deref() {
        Some("run") => cmd_run(args),
        Some("experiment") => cmd_experiment(args),
        Some("stack") => cmd_stack(args),
        Some("train") => cmd_train(args),
        Some("analyze") => cmd_analyze(args),
        Some("characterize") => {
            exp::fig03_characterization()?.print();
            Ok(())
        }
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(Error::config(format!("unknown subcommand `{other}`\n{USAGE}"))),
    }
}

fn cmd_run(mut args: Args) -> Result<()> {
    let config = args.take("--config");
    let overrides = args.take_all("--set");
    let op = args.take("--op").unwrap_or_else(|| "allreduce".into());
    let size_mb: usize = args
        .take("--size-mb")
        .map(|s| s.parse().map_err(|_| Error::config("bad --size-mb")))
        .transpose()?
        .unwrap_or(64);
    let gpus_per_node: Option<usize> = args
        .take("--gpus-per-node")
        .map(|s| s.parse().map_err(|_| Error::config("bad --gpus-per-node")))
        .transpose()?;
    let tiers = args.take("--tiers");
    let trace_path = args.take("--trace");
    let calibrate = args.take_bool("--calibrate");
    let codec = args
        .take("--codec")
        .map(|s| {
            CodecSpec::parse(&s)
                .ok_or_else(|| Error::config(format!("bad --codec `{s}` (see `gzccl help`)")))
        })
        .transpose()?;
    let backend = match args.take("--backend").as_deref() {
        None => None,
        Some("threads") => Some(ExecBackend::Threads),
        Some("events") => Some(ExecBackend::Events),
        Some(other) => {
            return Err(Error::config(format!(
                "bad --backend `{other}` (expected threads|events)"
            )))
        }
    };
    let pipeline = args
        .take("--pipeline")
        .map(|s| Pipeline::parse(&s))
        .transpose()?;
    let mut cfg = ClusterConfig::load(config.as_deref(), &overrides)?;
    if let Some(g) = gpus_per_node {
        cfg.gpus_per_node = g;
    }
    let mut spec = cfg.to_spec()?;
    if let Some(t) = tiers {
        let widths = TierTree::parse_widths(&t)?;
        spec.set_tiers(TierTree::new(spec.topo.ranks(), &widths)?);
    }
    if let Some(b) = backend {
        spec.backend = b;
    }
    if let Some(c) = codec {
        if spec.policy.compression == CompressionMode::None {
            return Err(Error::config(
                "--codec needs a compressed variant (the uncompressed policy never compresses)",
            ));
        }
        // The compression family follows the codec: a fixed-rate
        // quantizer runs under the CPRP2P mode, everything else under
        // the error-bounded mode.
        spec.policy.compression = LegExec::mode_for(c);
        spec.codec = Some(c);
    }
    // --calibrate needs a trace to fit against even when the user
    // didn't ask for a trace file, so it implies an internal tracer.
    let tracer = (trace_path.is_some() || calibrate).then(Tracer::new);
    if let Some(t) = &tracer {
        spec.trace = Some(t.clone());
    }
    let exec_backend = spec.backend;
    let mut comm = Communicator::from_spec(spec);
    if let Some(p) = pipeline {
        comm = comm.with_pipeline(p);
    }
    let n = comm.nranks();
    let elems = (size_mb << 20) / 4;
    let all_ranks = |e: usize| -> Vec<DeviceBuf> { (0..n).map(|_| DeviceBuf::Virtual(e)).collect() };

    let spec = CollectiveSpec::auto();
    let dispatch = |c: &Communicator| match op.as_str() {
        "allreduce" => c.allreduce(all_ranks(elems), &spec),
        "allreduce-ring" => c.allreduce(all_ranks(elems), &CollectiveSpec::forced(Algo::Ring)),
        "allreduce-redoub" => c.allreduce(
            all_ranks(elems),
            &CollectiveSpec::hinted(AlgoHint::Force(Algo::RecursiveDoubling)),
        ),
        "allreduce-hier" => {
            c.allreduce(all_ranks(elems), &CollectiveSpec::forced(Algo::Hierarchical))
        }
        "allreduce-tree" => c.allreduce(all_ranks(elems), &CollectiveSpec::forced(Algo::Binomial)),
        "reduce_scatter" => c.reduce_scatter(all_ranks(elems), &spec),
        "reduce_scatter-hier" => {
            c.reduce_scatter(all_ranks(elems), &CollectiveSpec::forced(Algo::Hierarchical))
        }
        "allgather" => c.allgather(all_ranks(elems / n), &spec),
        "allgather-hier" => {
            c.allgather(all_ranks(elems / n), &CollectiveSpec::forced(Algo::Hierarchical))
        }
        "scatter" => c.scatter(exp::virtual_root_inputs(n, size_mb << 20), &spec),
        "bcast" => c.bcast(exp::virtual_root_inputs(n, size_mb << 20), &spec),
        other => Err(Error::config(format!("unknown --op `{other}`"))),
    };
    let result = dispatch(&comm);
    // With --calibrate, fit a calibration from the traced run and
    // replay the same collective under the corrected cost model — the
    // tuner re-decides with measured bandwidths and kernel factors.
    let recal = match (&result, calibrate) {
        (Ok(rep), true) => rep.trace.clone().map(|run| {
            let comm2 = comm.recalibrated(&run);
            let r2 = dispatch(&comm2);
            (comm2, r2)
        }),
        _ => None,
    };
    // Export the trace before propagating any error: a partial trace
    // of a failed run is the flight recorder's whole point.
    if let (Some(path), Some(t)) = (&trace_path, &tracer) {
        write_trace(path, t)?;
    }
    let report = result?;

    println!(
        "{op} | variant {} | {} ranks | {} MB | backend {}",
        cfg.variant, n, size_mb, exec_backend
    );
    println!(
        "  algorithm        : {:?}{}",
        report.algo,
        if report.auto_tuned { " (tuner)" } else { " (forced)" }
    );
    println!(
        "  pipeline depth   : {}{}",
        report.exec_plan.depth,
        if report.exec_plan.depth > 1 {
            " (chunked leg overlap)"
        } else {
            " (barrier)"
        }
    );
    if let Some(s) = &report.schedule {
        println!(
            "  schedule         : {} tiers {:?}, {} legs",
            s.tree.depth(),
            s.tree.widths(),
            s.legs.len()
        );
    }
    // The executed plan, leg by leg: what each leg did, how it
    // compressed, the bound its compressor was held to, and (real
    // payloads) the observed per-leg error proving the bound held.
    println!(
        "  exec plan        : leg  tier  kind               mode          codec      eb         obs |err|"
    );
    for l in &report.legs {
        let kind = match l.kind {
            Some(k) => format!("{k:?}"),
            None => "WholeCollective".into(),
        };
        let (codec, eb) = match l.exec.compression {
            CompressionMode::None => ("-".into(), "-".into()),
            _ => (l.exec.codec.label(), format!("{:.3e}", l.exec.eb)),
        };
        let obs = match l.observed_max_err {
            Some(o) => format!("{o:.3e}"),
            None => "-".into(),
        };
        let mode = format!("{:?}", l.exec.compression);
        println!(
            "                     {:<4} {:<5} {kind:<18} {mode:<13} {codec:<10} {eb:<10} {obs}",
            l.leg, l.tier
        );
    }
    // Directives the ranks could not honor verbatim (e.g. a rebind the
    // ambient compressor declined) — deduplicated across ranks.
    for w in &report.leg_warnings {
        println!("  leg warning      : leg {}: {}", w.leg, w.message);
    }
    println!("  virtual makespan : {}", report.makespan);
    println!("  wire bytes       : {}", report.total_wire_bytes());
    println!("  cpr kernel calls : {}", report.total_cpr_calls());
    println!("  breakdown        : {}", report.total_breakdown().percent_string());
    if let Some((comm2, result2)) = recal {
        let report2 = result2?;
        if let Some(cal) = comm2.calibration() {
            print!("{cal}");
        }
        println!(
            "  calibrated rerun : makespan {} (was {})",
            report2.makespan, report.makespan
        );
        if let (Some(a), Some(a2)) = (report.analysis(), report2.analysis()) {
            if let (Some(r), Some(r2)) = (a.max_relative_residual(), a2.max_relative_residual()) {
                println!(
                    "  max |leg residual|: {:.1}% -> {:.1}%",
                    r * 100.0,
                    r2 * 100.0
                );
            }
        }
    }
    Ok(())
}

fn cmd_experiment(mut args: Args) -> Result<()> {
    let fast = args.take_bool("--fast");
    let gpn: usize = args
        .take("--gpus-per-node")
        .map(|s| s.parse().map_err(|_| Error::config("bad --gpus-per-node")))
        .transpose()?
        .unwrap_or(4);
    let which = args
        .subcommand()
        .ok_or_else(|| Error::config("experiment: which one? (fig2..fig13, table1, table2, all)"))?;
    let ranks = if fast { 16 } else { 64 };
    let t1_sample = if fast { 1 << 20 } else { 1 << 23 };
    let run = |name: &str| -> Result<()> {
        match name {
            "fig2" => exp::fig02_breakdown(ranks, 646 << 20)?.print(),
            "fig3" => exp::fig03_characterization()?.print(),
            "fig6" => {
                exp::fig06_gpu_centric(ranks, exp::Dataset::Rtm1)?.print();
                exp::fig06_gpu_centric(ranks, exp::Dataset::Rtm2)?.print();
            }
            "fig7" => exp::fig07_allreduce_opt(ranks)?.print(),
            "fig8" => exp::fig08_scatter_opt(ranks)?.print(),
            "fig9" => exp::fig09_msgsize(ranks, gpn)?.print(),
            "fig10" => exp::fig10_scale(gpn)?.print(),
            "fig11" => exp::fig11_scatter_msgsize(ranks)?.print(),
            "fig12" => exp::fig12_scatter_scale()?.print(),
            "table1" => exp::table1_compression(t1_sample)?.print(),
            "table2" => exp::table2_stacking(ranks, 256 << 20)?.print(),
            "fig13" => {
                let engine = Engine::discover().ok();
                exp::fig13_accuracy(16, engine.as_ref(), Some(std::path::Path::new("artifacts/fig13")))?
                    .print()
            }
            other => return Err(Error::config(format!("unknown experiment `{other}`"))),
        }
        Ok(())
    };
    if which == "all" {
        for name in [
            "fig2", "fig3", "table1", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
            "table2", "fig13",
        ] {
            run(name)?;
            println!();
        }
        Ok(())
    } else {
        run(&which)
    }
}

fn cmd_stack(mut args: Args) -> Result<()> {
    let ranks = args
        .take("--ranks")
        .map(|s| s.parse().map_err(|_| Error::config("bad --ranks")))
        .transpose()?
        .unwrap_or(16);
    let eb = args
        .take("--eb")
        .map(|s| s.parse().map_err(|_| Error::config("bad --eb")))
        .transpose()?
        .unwrap_or(1e-4);
    let gpus_per_node = args
        .take("--gpus-per-node")
        .map(|s| s.parse().map_err(|_| Error::config("bad --gpus-per-node")))
        .transpose()?
        .unwrap_or(4);
    let accuracy_target = args
        .take("--accuracy-target")
        .map(|s| parse_accuracy_target(&s))
        .transpose()?;
    let adaptive = args.take_bool("--adaptive");
    if adaptive && accuracy_target.is_none() {
        return Err(Error::config(
            "--adaptive needs --accuracy-target (adaptation is bounded by the certified budget)",
        ));
    }
    let codec = args
        .take("--codec")
        .map(|s| {
            CodecSpec::parse(&s)
                .ok_or_else(|| Error::config(format!("bad --codec `{s}` (see `gzccl help`)")))
        })
        .transpose()?;
    let trace_path = args.take("--trace");
    let calibrate = args.take_bool("--calibrate");
    let tracer = (trace_path.is_some() || calibrate).then(Tracer::new);
    let engine = Engine::discover().ok();
    let cfg = StackingConfig {
        ranks,
        gpus_per_node,
        error_bound: eb,
        accuracy_target,
        adaptive,
        codec,
        trace: tracer.clone(),
        ..Default::default()
    };
    let result = cmd_stack_variants(&cfg, engine.as_ref());
    let mut rerun = Ok(());
    if result.is_ok() && calibrate {
        // Fit from the richest traced run (the hierarchical variant
        // records the most spans) and replay every variant under the
        // calibrated cost model.
        if let Some(run) = tracer
            .as_ref()
            .and_then(|t| t.runs().into_iter().max_by_key(|r| r.span_count()))
        {
            println!();
            println!("calibration source: richest traced run ({} spans)", run.span_count());
            println!("{}", run.analyze());
            println!("== calibrated rerun ==");
            let cfg2 = StackingConfig {
                calibrate: Some(run),
                ..cfg.clone()
            };
            rerun = cmd_stack_variants(&cfg2, engine.as_ref());
        }
    }
    if let (Some(path), Some(t)) = (&trace_path, &tracer) {
        write_trace(path, t)?;
    }
    result.and(rerun)
}

fn cmd_stack_variants(cfg: &StackingConfig, engine: Option<&Engine>) -> Result<()> {
    for v in [
        StackingVariant::CrayMpi,
        StackingVariant::Nccl,
        StackingVariant::GzcclRing,
        StackingVariant::GzcclReDoub,
        StackingVariant::GzcclHier,
        StackingVariant::Cprp2p,
    ] {
        match run_stacking(cfg, v, engine) {
            Ok(out) => {
                let planned = match out.planned_eb {
                    Some(eb) => format!(" planned-eb {eb:.2e}"),
                    None => String::new(),
                };
                // With --adaptive, the telemetry headroom of this call
                // already relaxed the bound the NEXT call would run at.
                let adapted = match out.adapted_eb {
                    Some(eb) => format!(" next-eb {eb:.2e}"),
                    None => String::new(),
                };
                let telemetry = match out.accuracy {
                    Some(a) => format!(
                        " | err obs {:.2e} pred {}",
                        a.observed_max_err,
                        match a.prediction.bound() {
                            Some(b) => format!("<={b:.2e}"),
                            None => "unbounded".into(),
                        }
                    ),
                    None => String::new(),
                };
                println!(
                    "{:16} time {:>10} psnr {:6.2} dB nrmse {:.2e}{planned}{adapted} | {}{telemetry}",
                    v.name(),
                    gzccl::metrics::table::fmt_time(out.makespan),
                    out.psnr,
                    out.nrmse,
                    out.breakdown.percent_string()
                );
            }
            // Only genuine planner rejections are reported-and-skipped;
            // any other failure still aborts the command.
            Err(Error::Budget(reason)) => {
                println!("{:16} rejected by the accuracy planner: {reason}", v.name());
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn cmd_train(mut args: Args) -> Result<()> {
    let ranks = args
        .take("--ranks")
        .map(|s| s.parse().map_err(|_| Error::config("bad --ranks")))
        .transpose()?
        .unwrap_or(8);
    let steps = args
        .take("--steps")
        .map(|s| s.parse().map_err(|_| Error::config("bad --steps")))
        .transpose()?
        .unwrap_or(100);
    let compress = !args.take_bool("--no-compress");
    let accuracy_target = args
        .take("--accuracy-target")
        .map(|s| s.parse().map_err(|_| Error::config("bad --accuracy-target")))
        .transpose()?;
    let adaptive = args.take_bool("--adaptive");
    if adaptive && accuracy_target.is_none() {
        return Err(Error::config(
            "--adaptive needs --accuracy-target (adaptation is bounded by the certified budget)",
        ));
    }
    let codec = args
        .take("--codec")
        .map(|s| {
            CodecSpec::parse(&s)
                .ok_or_else(|| Error::config(format!("bad --codec `{s}` (see `gzccl help`)")))
        })
        .transpose()?;
    if codec.is_some() && !compress {
        return Err(Error::config("--codec conflicts with --no-compress"));
    }
    let trace_path = args.take("--trace");
    let calibrate = args.take_bool("--calibrate");
    let pipeline = args
        .take("--pipeline")
        .map(|s| Pipeline::parse(&s))
        .transpose()?
        .unwrap_or_default();
    let overlap = args.take_bool("--overlap");
    let tracer = (trace_path.is_some() || calibrate).then(Tracer::new);
    let engine = Engine::discover()?;
    let cfg = DdpConfig {
        ranks,
        steps,
        compress,
        accuracy_target,
        adaptive,
        codec,
        trace: tracer.clone(),
        pipeline,
        overlap,
        ..Default::default()
    };
    let out = train_ddp(&cfg, &engine);
    // With --calibrate, refit the cost model from the richest traced
    // step and replay the training run under it.
    let out2 = match (&out, calibrate) {
        (Ok(_), true) => tracer
            .as_ref()
            .and_then(|t| t.runs().into_iter().max_by_key(|r| r.span_count()))
            .map(|run| {
                let cfg2 = DdpConfig {
                    calibrate: Some(run),
                    ..cfg.clone()
                };
                train_ddp(&cfg2, &engine)
            }),
        _ => None,
    };
    if let (Some(path), Some(t)) = (&trace_path, &tracer) {
        write_trace(path, t)?;
    }
    let out = out?;
    if let Some(eb) = out.planned_eb {
        println!(
            "accuracy budget: planned eb {eb:.3e} | per-step bound {:.3e} | observed max {:.3e} | violations {}",
            out.predicted_step_err.unwrap_or(f64::NAN),
            out.observed_step_err.unwrap_or(f64::NAN),
            out.budget_violations
        );
        if let Some(final_eb) = out.final_eb {
            if (final_eb - eb).abs() > f64::EPSILON * eb {
                println!(
                    "adaptive: telemetry headroom relaxed the per-step eb {eb:.3e} -> {final_eb:.3e}"
                );
            }
        }
    }
    for (i, loss) in out.loss_curve.iter().enumerate() {
        if i % 10 == 0 || i + 1 == out.loss_curve.len() {
            println!("step {i:5}  loss {loss:.5}");
        }
    }
    println!(
        "allreduce virtual time {:.3} ms | wire {:.2} MB",
        out.allreduce_time * 1e3,
        out.wire_bytes as f64 / 1e6
    );
    if let Some(depth) = out.pipeline_depth {
        println!(
            "overlap: persistent gradient plan at pipeline depth {depth}, \
             next-step batches prepared in flight"
        );
    }
    if let Some(r2) = out2 {
        let o2 = r2?;
        println!(
            "calibrated rerun: allreduce virtual time {:.3} ms (was {:.3} ms)",
            o2.allreduce_time * 1e3,
            out.allreduce_time * 1e3
        );
    }
    Ok(())
}
