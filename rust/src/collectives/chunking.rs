//! Chunk-boundary bookkeeping for chunked collectives.
//!
//! Ring algorithms split a D-element vector into N near-equal chunks;
//! sizes may differ by one when N ∤ D. This mirrors MPI's convention
//! (`floor(i·D/N)` boundaries).

/// Chunk layout of `total` elements over `n` chunks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunks {
    total: usize,
    n: usize,
}

impl Chunks {
    /// Layout `total` elements into `n` chunks.
    pub fn new(total: usize, n: usize) -> Self {
        assert!(n > 0);
        Chunks { total, n }
    }

    /// Number of chunks.
    pub fn count(&self) -> usize {
        self.n
    }

    /// Start offset of chunk `i`.
    pub fn start(&self, i: usize) -> usize {
        debug_assert!(i <= self.n);
        (i as u128 * self.total as u128 / self.n as u128) as usize
    }

    /// Element range of chunk `i`.
    pub fn range(&self, i: usize) -> std::ops::Range<usize> {
        self.start(i)..self.start(i + 1)
    }

    /// Length of chunk `i`.
    pub fn len(&self, i: usize) -> usize {
        self.range(i).len()
    }

    /// The chunk containing global element index `idx` — the inverse of
    /// [`Chunks::range`], kept next to the boundary convention it
    /// inverts. Requires `idx < total`.
    pub fn owner_of(&self, idx: usize) -> usize {
        debug_assert!(idx < self.total);
        // floor(idx·n/total) is within one chunk of the owner; fix up
        // against the exact floor(i·total/n) boundaries.
        let mut i = (idx * self.n / self.total.max(1)).min(self.n - 1);
        while i + 1 < self.n && self.start(i + 1) <= idx {
            i += 1;
        }
        while i > 0 && self.start(i) > idx {
            i -= 1;
        }
        i
    }

    /// Whether the layout is empty.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Cases};

    #[test]
    fn even_split() {
        let c = Chunks::new(100, 4);
        for i in 0..4 {
            assert_eq!(c.len(i), 25);
        }
        assert_eq!(c.range(2), 50..75);
    }

    #[test]
    fn uneven_split_covers_everything() {
        let c = Chunks::new(10, 3);
        let total: usize = (0..3).map(|i| c.len(i)).sum();
        assert_eq!(total, 10);
        assert_eq!(c.range(0).start, 0);
        assert_eq!(c.range(2).end, 10);
    }

    #[test]
    fn more_chunks_than_elements() {
        let c = Chunks::new(2, 5);
        let total: usize = (0..5).map(|i| c.len(i)).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn owner_of_inverts_range() {
        for (total, n) in [(100usize, 4usize), (10, 3), (7, 7), (5, 3), (64, 5), (2, 5)] {
            let c = Chunks::new(total, n);
            for idx in 0..total {
                let r = c.owner_of(idx);
                assert!(c.range(r).contains(&idx), "total {total} n {n} idx {idx} → {r}");
            }
        }
    }

    #[test]
    fn prop_chunks_partition_exactly() {
        forall(
            Cases::n(60),
            |rng| (rng.range_usize(0, 10_000), rng.range_usize(1, 600)),
            |(total, n)| {
                let c = Chunks::new(*total, *n);
                let mut cursor = 0;
                for i in 0..*n {
                    let r = c.range(i);
                    if r.start != cursor {
                        return Err(format!("gap at chunk {i}"));
                    }
                    cursor = r.end;
                }
                if cursor != *total {
                    return Err("doesn't cover total".into());
                }
                // Sizes differ by at most 1.
                let min = (0..*n).map(|i| c.len(i)).min().unwrap();
                let max = (0..*n).map(|i| c.len(i)).max().unwrap();
                if max - min > 1 {
                    return Err(format!("imbalance {min}..{max}"));
                }
                Ok(())
            },
        );
    }
}
