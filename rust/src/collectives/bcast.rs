//! Binomial-tree Broadcast.
//!
//! The whole vector travels every tree edge. With compression enabled
//! (gZCCL data-movement framework), the root compresses **once** and
//! the compressed stream is forwarded verbatim; every rank decompresses
//! once — so the error is one compression deep regardless of depth,
//! and the compression kernel runs at full size (high utilization).

use crate::coordinator::{CompBuf, DeviceBuf, Payload, RankCtx};
use crate::error::Result;
use crate::gpu::StreamId;

use super::scatter::tree_position;

const TAG_BC: u64 = 0x4243_0000;

/// Binomial broadcast from root 0. The root passes the vector as
/// `input`; other ranks receive it as the return value.
pub fn bcast_binomial(ctx: &mut RankCtx, input: DeviceBuf) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let me = ctx.rank();
    if n == 1 {
        return Ok(input);
    }
    let (mask, parent) = tree_position(me, n);
    let stream = if ctx.policy().overlap {
        StreamId::NonDefault(0)
    } else {
        StreamId::Default
    };

    if ctx.compression_enabled() {
        let (cstream, mut have_t, data): (CompBuf, _, Option<DeviceBuf>) = if me == 0 {
            let now = ctx.now();
            let (c, t) = ctx.compress(stream, &input, now);
            (c, t, Some(input))
        } else {
            let (c, t) = ctx.recv_comp(parent.unwrap(), TAG_BC);
            (c, t, None)
        };
        // Forward the compressed stream down the tree.
        let mut m = mask >> 1;
        while m > 0 {
            let dst = me + m;
            if dst < n {
                ctx.send(dst, TAG_BC, Payload::Comp(cstream.clone()), have_t);
            }
            m >>= 1;
        }
        let out = if let Some(d) = data {
            d // root keeps its lossless copy
        } else {
            let (dec, t_dec) = ctx.decompress(stream, &cstream, have_t);
            have_t = t_dec;
            let _ = have_t;
            dec
        };
        ctx.sync_device();
        Ok(out)
    } else {
        let (data, have_t) = if me == 0 {
            let t = ctx.now();
            (input, t)
        } else {
            ctx.recv_raw(parent.unwrap(), TAG_BC)
        };
        let mut m = mask >> 1;
        while m > 0 {
            let dst = me + m;
            if dst < n {
                ctx.send(dst, TAG_BC, Payload::Raw(data.clone()), have_t);
            }
            m >>= 1;
        }
        Ok(data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_collective, ClusterSpec, ExecPolicy};
    use crate::testkit::Pcg32;

    fn bcast_inputs(n: usize, d: usize) -> (Vec<DeviceBuf>, Vec<f32>) {
        let mut rng = Pcg32::seeded(77);
        let full = rng.uniform_vec(d, -1.0, 1.0);
        let mut inputs = vec![DeviceBuf::Real(full.clone())];
        for _ in 1..n {
            inputs.push(DeviceBuf::Real(vec![]));
        }
        (inputs, full)
    }

    #[test]
    fn raw_bcast_exact() {
        for n in [2usize, 5, 8] {
            let (inputs, full) = bcast_inputs(n, 128);
            let report = run_collective(
                &ClusterSpec::new(n, ExecPolicy::nccl()),
                inputs,
                &bcast_binomial,
            )
            .unwrap();
            for out in &report.outputs {
                assert_eq!(out.as_real(), &full[..]);
            }
        }
    }

    #[test]
    fn compressed_bcast_single_eb() {
        let n = 8;
        let (inputs, full) = bcast_inputs(n, 256);
        let report = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            inputs,
            &bcast_binomial,
        )
        .unwrap();
        for (r, out) in report.outputs.iter().enumerate() {
            for (a, b) in out.as_real().iter().zip(full.iter()) {
                let tol = if r == 0 { 0.0 } else { 1.1e-4 };
                assert!((a - b).abs() <= tol, "rank {r}: {a} vs {b}");
            }
        }
        // One compression total; one decompression per non-root.
        let total_cpr: usize = report.counters.iter().map(|c| c.compress_calls).sum();
        assert_eq!(total_cpr, 1);
        let total_dec: usize = report.counters.iter().map(|c| c.decompress_calls).sum();
        assert_eq!(total_dec, n - 1);
    }

    #[test]
    fn compression_cuts_bcast_wire_volume() {
        let n = 8;
        let d = 1 << 18;
        let smooth: Vec<f32> = (0..d).map(|i| (i as f32 * 1e-4).sin()).collect();
        let mk = |v: &Vec<f32>| {
            let mut inputs = vec![DeviceBuf::Real(v.clone())];
            for _ in 1..n {
                inputs.push(DeviceBuf::Real(vec![]));
            }
            inputs
        };
        let raw = run_collective(
            &ClusterSpec::new(n, ExecPolicy::nccl()),
            mk(&smooth),
            &bcast_binomial,
        )
        .unwrap();
        let gz = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            mk(&smooth),
            &bcast_binomial,
        )
        .unwrap();
        assert!(gz.total_wire_bytes() * 4 < raw.total_wire_bytes());
    }
}
