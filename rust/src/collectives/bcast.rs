//! Binomial-tree Broadcast from any root.
//!
//! The whole vector travels every tree edge. With compression enabled
//! (gZCCL data-movement framework), the root compresses **once** and
//! the compressed stream is forwarded verbatim; every rank decompresses
//! once — so the error is one compression deep regardless of depth,
//! and the compression kernel runs at full size (high utilization).
//!
//! Arbitrary roots use relative-rank rotation: the binomial tree is
//! built over virtual ranks `v = (rank − root) mod N`, so the root is
//! always virtual rank 0 and edges map back through
//! `rank = (v + root) mod N`.

use crate::coordinator::{CompBuf, DeviceBuf, Payload, ProgFut, Program, RankCtx};
use crate::error::{Error, Result};
use crate::gpu::StreamId;

use super::scatter::tree_position;

const TAG_BC: u64 = 0x4243_0000;

/// [`Program`] adapter for [`bcast_binomial`] rooted at `root`.
pub struct BcastProg {
    pub root: usize,
}

impl Program for BcastProg {
    fn run<'a>(&'a self, ctx: &'a mut RankCtx, input: DeviceBuf) -> ProgFut<'a> {
        Box::pin(async move { bcast_binomial(ctx, input, self.root).await })
    }
}

/// Binomial broadcast from `root`. The root passes the vector as
/// `input`; other ranks receive it as the return value.
pub async fn bcast_binomial(ctx: &mut RankCtx, input: DeviceBuf, root: usize) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let me = ctx.rank();
    if n == 1 {
        return Ok(input);
    }
    if root >= n {
        // A real guard (not debug-only): `me + n - root` would wrap in
        // release builds and hang or panic the rank mesh.
        return Err(Error::collective(format!(
            "bcast root {root} out of range 0..{n}"
        )));
    }
    let vr = (me + n - root) % n;
    let actual = |v: usize| (v + root) % n;
    let (mask, vparent) = tree_position(vr, n);
    let stream = if ctx.policy().overlap {
        StreamId::NonDefault(0)
    } else {
        StreamId::Default
    };

    if ctx.compression_enabled() {
        let (cstream, have_t, data): (CompBuf, _, Option<DeviceBuf>) = if vr == 0 {
            let now = ctx.now();
            let (c, t) = ctx.compress(stream, &input, now);
            (c, t, Some(input))
        } else {
            let (c, t) = ctx.recv_comp(actual(vparent.unwrap()), TAG_BC).await;
            (c, t, None)
        };
        // Forward the compressed stream down the tree.
        let mut m = mask >> 1;
        while m > 0 {
            let dst_v = vr + m;
            if dst_v < n {
                ctx.send(actual(dst_v), TAG_BC, Payload::Comp(cstream.clone()), have_t);
            }
            m >>= 1;
        }
        let out = if let Some(d) = data {
            d // root keeps its lossless copy
        } else {
            let (dec, _t_dec) = ctx.decompress(stream, &cstream, have_t);
            dec
        };
        ctx.sync_device();
        Ok(out)
    } else {
        let (data, have_t) = if vr == 0 {
            let t = ctx.now();
            (input, t)
        } else {
            ctx.recv_raw(actual(vparent.unwrap()), TAG_BC).await
        };
        let mut m = mask >> 1;
        while m > 0 {
            let dst_v = vr + m;
            if dst_v < n {
                ctx.send(actual(dst_v), TAG_BC, Payload::Raw(data.clone()), have_t);
            }
            m >>= 1;
        }
        Ok(data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_collective, ClusterSpec, ExecPolicy};
    use crate::testkit::Pcg32;

    fn bcast_inputs(n: usize, d: usize, root: usize) -> (Vec<DeviceBuf>, Vec<f32>) {
        let mut rng = Pcg32::seeded(77);
        let full = rng.uniform_vec(d, -1.0, 1.0);
        let inputs = (0..n)
            .map(|r| {
                if r == root {
                    DeviceBuf::Real(full.clone())
                } else {
                    DeviceBuf::Real(vec![])
                }
            })
            .collect();
        (inputs, full)
    }

    #[test]
    fn raw_bcast_exact() {
        for n in [2usize, 5, 8] {
            let (inputs, full) = bcast_inputs(n, 128, 0);
            let report = run_collective(
                &ClusterSpec::new(n, ExecPolicy::nccl()),
                inputs,
                &BcastProg { root: 0 },
            )
            .unwrap();
            for out in &report.outputs {
                assert_eq!(out.as_real(), &full[..]);
            }
        }
    }

    #[test]
    fn raw_bcast_exact_every_root() {
        for n in [3usize, 6, 8] {
            for root in 0..n {
                let (inputs, full) = bcast_inputs(n, 64, root);
                let report = run_collective(
                    &ClusterSpec::new(n, ExecPolicy::nccl()),
                    inputs,
                    &BcastProg { root },
                )
                .unwrap();
                for (r, out) in report.outputs.iter().enumerate() {
                    assert_eq!(out.as_real(), &full[..], "n={n} root={root} rank {r}");
                }
            }
        }
    }

    #[test]
    fn compressed_bcast_single_eb_any_root() {
        let n = 8;
        for root in [0usize, 3, 7] {
            let (inputs, full) = bcast_inputs(n, 256, root);
            let report = run_collective(
                &ClusterSpec::new(n, ExecPolicy::gzccl()),
                inputs,
                &BcastProg { root },
            )
            .unwrap();
            for (r, out) in report.outputs.iter().enumerate() {
                for (a, b) in out.as_real().iter().zip(full.iter()) {
                    let tol = if r == root { 0.0 } else { 1.1e-4 };
                    assert!((a - b).abs() <= tol, "root {root} rank {r}: {a} vs {b}");
                }
            }
            // One compression total (at the root); one decompression
            // per non-root.
            let total_cpr: usize = report.counters.iter().map(|c| c.compress_calls).sum();
            assert_eq!(total_cpr, 1);
            assert_eq!(report.counters[root].compress_calls, 1);
            let total_dec: usize = report.counters.iter().map(|c| c.decompress_calls).sum();
            assert_eq!(total_dec, n - 1);
            assert_eq!(report.counters[root].decompress_calls, 0);
        }
    }

    #[test]
    fn out_of_range_root_is_error() {
        let (inputs, _) = bcast_inputs(4, 8, 0);
        let res = run_collective(
            &ClusterSpec::new(4, ExecPolicy::nccl()),
            inputs,
            &BcastProg { root: 9 },
        );
        assert!(res.is_err());
    }

    #[test]
    fn compression_cuts_bcast_wire_volume() {
        let n = 8;
        let d = 1 << 18;
        let smooth: Vec<f32> = (0..d).map(|i| (i as f32 * 1e-4).sin()).collect();
        let mk = |v: &Vec<f32>| {
            let mut inputs = vec![DeviceBuf::Real(v.clone())];
            for _ in 1..n {
                inputs.push(DeviceBuf::Real(vec![]));
            }
            inputs
        };
        let raw = run_collective(
            &ClusterSpec::new(n, ExecPolicy::nccl()),
            mk(&smooth),
            &BcastProg { root: 0 },
        )
        .unwrap();
        let gz = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            mk(&smooth),
            &BcastProg { root: 0 },
        )
        .unwrap();
        assert!(gz.total_wire_bytes() * 4 < raw.total_wire_bytes());
    }
}
