//! Allreduce: ring and recursive-doubling (gZ-Allreduce) algorithms.
//!
//! * [`allreduce_ring`] — Reduce_scatter + Allgather, the NCCL/MPICH
//!   large-message algorithm. With compression: N compressions and
//!   2(N−1) decompressions per rank over D/N chunks — poor GPU
//!   utilization at scale (§3.2.3).
//! * [`allreduce_recursive_doubling`] — the paper's gZ-Allreduce
//!   (ReDoub), Fig. 4: ⌈log₂N⌉ whole-vector exchanges, each step
//!   memsets the reused temp buffers, compresses on a non-default
//!   stream, exchanges non-blocking, decompresses and reduces on
//!   device. High utilization (whole-vector kernels), log N
//!   compression stages, remainder ranks folded in/out at the edges.

use crate::coordinator::{DeviceBuf, Payload, ProgFut, RankCtx};
use crate::gpu::StreamId;

use super::allgather::allgather_ring_at;
use super::reduce_scatter::reduce_scatter_ring_at;

const TAG_AR: u64 = 0x4152_0000;

/// Ring Allreduce (Reduce_scatter stage then Allgather stage). The two
/// stages are chained on device-ready timestamps, so with the overlap
/// policy the Allgather's first compression overlaps the tail of the
/// Reduce_scatter.
pub fn allreduce_ring(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(async move {
        let now = ctx.now();
        let (chunk, t_rs) = reduce_scatter_ring_at(ctx, input, now).await?;
        let (out, _t_ag) = allgather_ring_at(ctx, chunk, t_rs).await?;
        ctx.sync_device();
        Ok(out)
    })
}

/// Recursive-doubling Allreduce (gZ-Allreduce ReDoub, Fig. 4).
///
/// Handles non-power-of-two communicators with the MPICH remainder
/// scheme: the first 2r ranks pair up (even → odd), odd ranks carry the
/// pair's sum through the power-of-two phase, and the result is pushed
/// back to the parked even ranks at the end. Every payload is the
/// *whole* vector — compressed once per step when compression is on.
pub fn allreduce_recursive_doubling(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(async move {
    let n = ctx.nranks();
    let me = ctx.rank();
    if n == 1 {
        return Ok(input);
    }
    let stream = if ctx.policy().overlap {
        StreamId::NonDefault(0)
    } else {
        StreamId::Default
    };
    let pof2 = 1usize << (usize::BITS - 1 - n.leading_zeros() as u32) as usize;
    let rem = n - pof2;

    let mut data = input;
    let mut data_t = ctx.now();
    let elems = data.elems();

    // ---- Stage 1: fold remainder ranks in (Fig. 4 left). -----------
    // newrank = -1 parks the rank until the final restore.
    let newrank: isize;
    if me < 2 * rem {
        if me % 2 == 0 {
            // Even: memset temps, compress whole vector on the side
            // stream, ship to the odd partner, park.
            if ctx.compression_enabled() {
                ctx.memset(stream, data.bytes(), data_t);
                let (c, t_c) = ctx.compress(stream, &data, data_t);
                ctx.send(me + 1, TAG_AR, Payload::Comp(c), t_c);
            } else {
                ctx.send(me + 1, TAG_AR, Payload::Raw(data.clone()), data_t);
            }
            newrank = -1;
        } else {
            let (theirs, t_in) = if ctx.compression_enabled() {
                let (c, t_in) = ctx.recv_comp(me - 1, TAG_AR).await;
                ctx.memset(stream, c.bytes(), ctx.now());
                ctx.decompress(stream, &c, t_in)
            } else {
                ctx.recv_raw(me - 1, TAG_AR).await
            };
            let (sum, t_sum) = ctx.reduce(stream, &data, &theirs, t_in.join(data_t))?;
            data = sum;
            data_t = t_sum;
            newrank = (me / 2) as isize;
        }
    } else {
        newrank = (me - rem) as isize;
    }

    // ---- Stage 2: recursive doubling over pof2 ranks (Fig. 4). -----
    if newrank >= 0 {
        let nr = newrank as usize;
        let mut mask = 1usize;
        let mut round: u64 = 1;
        while mask < pof2 {
            let peer_nr = nr ^ mask;
            // Map back to the real rank space.
            let peer = if peer_nr < rem {
                peer_nr * 2 + 1
            } else {
                peer_nr + rem
            };
            if ctx.compression_enabled() {
                // Fig. 4: async memset of the reused temp buffers, then
                // compress on the non-default stream.
                ctx.memset(stream, data.bytes(), data_t);
                let (c, t_c) = ctx.compress(stream, &data, data_t);
                ctx.send(peer, TAG_AR + round, Payload::Comp(c), t_c);
                let (cin, t_in) = ctx.recv_comp(peer, TAG_AR + round).await;
                let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
                let (sum, t_sum) = ctx.reduce(stream, &data, &dec, t_dec.join(data_t))?;
                data = sum;
                data_t = t_sum;
            } else {
                ctx.send(peer, TAG_AR + round, Payload::Raw(data.clone()), data_t);
                let (bin, t_in) = ctx.recv_raw(peer, TAG_AR + round).await;
                let (sum, t_sum) = ctx.reduce(stream, &data, &bin, t_in.join(data_t))?;
                data = sum;
                data_t = t_sum;
            }
            mask <<= 1;
            round += 1;
        }
    }

    // ---- Stage 3: restore remainder ranks (Fig. 4 right). ----------
    if me < 2 * rem {
        if me % 2 == 1 {
            if ctx.compression_enabled() {
                let (c, t_c) = ctx.compress(stream, &data, data_t);
                ctx.send(me - 1, TAG_AR + 0x1000, Payload::Comp(c), t_c);
            } else {
                ctx.send(me - 1, TAG_AR + 0x1000, Payload::Raw(data.clone()), data_t);
            }
        } else {
            let (result, _t) = if ctx.compression_enabled() {
                let (c, t_in) = ctx.recv_comp(me + 1, TAG_AR + 0x1000).await;
                ctx.decompress(stream, &c, t_in)
            } else {
                ctx.recv_raw(me + 1, TAG_AR + 0x1000).await
            };
            data = result;
        }
    }
    debug_assert_eq!(data.elems(), elems);
    ctx.sync_device();
    Ok(data)
    })
}

/// Reduce-to-root + broadcast Allreduce — the Cray-MPI-class baseline
/// observed in the paper's measurements (large-message CUDA-aware MPI
/// on the testbed behaved far off the ring bandwidth bound; a
/// staged binomial reduce+bcast with host buffers reproduces that
/// behaviour). Used only by the uncompressed CPU-centric baseline.
pub fn allreduce_reduce_bcast(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(async move {
    let n = ctx.nranks();
    let me = ctx.rank();
    if n == 1 {
        return Ok(input);
    }
    let stream = StreamId::Default;
    // --- Binomial reduce to rank 0 (children push up the tree). -----
    let mut data = input;
    let mut data_t = ctx.now();
    let mut mask = 1usize;
    let mut round = 0u64;
    while mask < n {
        if me & mask != 0 {
            let dst = me - mask;
            ctx.send(dst, TAG_AR + 0x2000 + round, Payload::Raw(data.clone()), data_t);
            break;
        } else if me + mask < n {
            let src = me + mask;
            let (theirs, t_in) = ctx.recv_raw(src, TAG_AR + 0x2000 + round).await;
            let (sum, t_sum) = ctx.reduce(stream, &data, &theirs, t_in.join(data_t))?;
            data = sum;
            data_t = t_sum;
        }
        mask <<= 1;
        round += 1;
    }
    // --- Binomial broadcast of the result from rank 0. --------------
    // Non-roots receive the broadcast payload; rank 0 returns its sum.
    super::bcast::bcast_binomial(ctx, if me == 0 { data } else { DeviceBuf::Virtual(0) }, 0).await
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_collective, ClusterSpec, ExecPolicy, Program};
    use crate::testkit::Pcg32;

    fn inputs_real(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
        (0..n)
            .map(|r| {
                let mut rng = Pcg32::new(seed, r as u64);
                DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
            })
            .collect()
    }

    fn expected_sums(inputs: &[DeviceBuf]) -> Vec<f32> {
        let d = inputs[0].elems();
        let mut out = vec![0.0f32; d];
        for b in inputs {
            for (o, v) in out.iter_mut().zip(b.as_real()) {
                *o += v;
            }
        }
        out
    }

    fn check_allreduce(
        n: usize,
        d: usize,
        policy: ExecPolicy,
        tol: f32,
        algo: impl Program,
    ) {
        let inputs = inputs_real(n, d, 1234);
        let expect = expected_sums(&inputs);
        let report = run_collective(&ClusterSpec::new(n, policy), inputs, &algo).unwrap();
        for (r, out) in report.outputs.iter().enumerate() {
            assert_eq!(out.elems(), d);
            for (i, (a, b)) in out.as_real().iter().zip(expect.iter()).enumerate() {
                assert!(
                    (a - b).abs() <= tol,
                    "rank {r} elem {i}: got {a} want {b} (tol {tol})"
                );
            }
        }
    }

    #[test]
    fn ring_uncompressed_exact() {
        check_allreduce(8, 64, ExecPolicy::nccl(), 1e-4, allreduce_ring);
    }

    #[test]
    fn ring_uncompressed_nondivisible_sizes() {
        check_allreduce(8, 61, ExecPolicy::nccl(), 1e-4, allreduce_ring);
        check_allreduce(5, 13, ExecPolicy::nccl(), 1e-4, allreduce_ring);
    }

    #[test]
    fn redoub_uncompressed_exact_pow2_and_not() {
        for n in [2usize, 4, 8, 3, 6, 7] {
            check_allreduce(n, 40, ExecPolicy::cray_mpi(), 1e-4, allreduce_recursive_doubling);
        }
    }

    #[test]
    fn ring_compressed_error_stacks_linearly() {
        let eb = 1e-3f32;
        // RS stage: ≤ 2eb per hop over N−1 hops; AG adds one more.
        check_allreduce(
            8,
            128,
            ExecPolicy::gzccl().clone(),
            2.0 * 9.0 * eb,
            allreduce_ring,
        );
    }

    #[test]
    fn redoub_compressed_error_stacks_logarithmically() {
        // log2(8)=3 exchange steps; each adds ≤ 2eb (tight: eb of my
        // compress seen by peer + eb of peer's compress) — use 3 eb per
        // step as a safe envelope.
        check_allreduce(
            8,
            128,
            ExecPolicy::gzccl(),
            3.0 * 3.0 * 1e-4,
            allreduce_recursive_doubling,
        );
        // Non-power-of-two adds the fold/unfold steps.
        check_allreduce(
            6,
            96,
            ExecPolicy::gzccl(),
            5.0 * 3.0 * 1e-4,
            allreduce_recursive_doubling,
        );
    }

    #[test]
    fn cpr_counts_ring_vs_redoub() {
        let n = 8;
        let mk = || -> Vec<DeviceBuf> { (0..n).map(|_| DeviceBuf::Virtual(1 << 16)).collect() };
        let ring = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            mk(),
            &allreduce_ring,
        )
        .unwrap();
        // Ring: N−1 compress (RS) + 1 compress (AG) = N; 2(N−1) decompress.
        for c in &ring.counters {
            assert_eq!(c.compress_calls, n, "ring compress");
            assert_eq!(c.decompress_calls, 2 * (n - 1), "ring decompress");
        }
        let redoub = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            mk(),
            &allreduce_recursive_doubling,
        )
        .unwrap();
        // Pow2: log N compress + log N decompress per rank.
        for c in &redoub.counters {
            assert_eq!(c.compress_calls, 3, "redoub compress");
            assert_eq!(c.decompress_calls, 3, "redoub decompress");
        }
    }

    #[test]
    fn redoub_beats_ring_at_scale_small_chunks() {
        // The paper's headline (Figs. 7/10): at large N with D/N below
        // the utilization knee, ReDoub's log N whole-vector exchanges
        // beat ring's 2(N−1) tiny-chunk stages.
        let n = 64;
        let d = (64 << 20) / 4; // 64 MB vector → 1 MB chunks: below knee
        let mk = || -> Vec<DeviceBuf> { (0..n).map(|_| DeviceBuf::Virtual(d)).collect() };
        let ring = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            mk(),
            &allreduce_ring,
        )
        .unwrap();
        let redoub = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            mk(),
            &allreduce_recursive_doubling,
        )
        .unwrap();
        assert!(
            redoub.makespan.as_secs() < ring.makespan.as_secs(),
            "redoub {} vs ring {}",
            redoub.makespan,
            ring.makespan
        );
    }

    #[test]
    fn reduce_bcast_exact_various_n() {
        for n in [2usize, 4, 6, 8] {
            check_allreduce(n, 48, ExecPolicy::cray_mpi(), 1e-4, allreduce_reduce_bcast);
        }
    }

    #[test]
    fn reduce_bcast_slower_than_ring_uncompressed() {
        // The Cray-MPI baseline ships the whole vector up and down the
        // tree with PCIe staging: far off the ring bandwidth bound.
        let n = 16;
        let d = (64 << 20) / 4;
        let mk = || -> Vec<DeviceBuf> { (0..n).map(|_| DeviceBuf::Virtual(d)).collect() };
        let cray = run_collective(
            &ClusterSpec::new(n, ExecPolicy::cray_mpi()),
            mk(),
            &allreduce_reduce_bcast,
        )
        .unwrap();
        let nccl = run_collective(&ClusterSpec::new(n, ExecPolicy::nccl()), mk(), &allreduce_ring)
            .unwrap();
        assert!(
            cray.makespan.as_secs() > 2.0 * nccl.makespan.as_secs(),
            "cray {} vs nccl {}",
            cray.makespan,
            nccl.makespan
        );
    }

    #[test]
    fn single_rank_identity() {
        check_allreduce(1, 16, ExecPolicy::gzccl(), 0.0, allreduce_recursive_doubling);
    }
}
