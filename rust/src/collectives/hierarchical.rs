//! Hierarchical collectives: the executor for compiled
//! [`crate::topo::Schedule`]s.
//!
//! PR 2's two-level Allreduce hard-coded one leader tier; this module
//! generalizes it: the algorithm is now *data* — a sequence of
//! per-tier legs compiled by [`crate::topo::schedule`] from a
//! [`TierTree`] — and [`run_schedule`] interprets the legs against a
//! [`RankCtx`]. On a 2-tier tree with the min-error compile this is
//! exactly the PR 2 schedule (raw NVLink reduce to the node leader, a
//! compressed recursive-doubling exchange over one leader per node
//! with the MPICH remainder fold, raw broadcast back); deeper trees
//! add rack/pod tiers whose legs the tuner picks per tier, and the
//! same engine realizes hierarchical **Reduce_scatter** and
//! **Allgather**.
//!
//! Leg semantics (all groups advance the same leg sequence; a rank
//! engages a leg iff it leads its tier-`t−1` group):
//!
//! * **ReduceToLeader** — members ship whole vectors to the group
//!   leader, which folds them in rank order. Raw on tier 0 (NVLink),
//!   compressed above.
//! * **AllreduceRedoub / AllreduceRing** — in-group Allreduce over the
//!   participants: whole-vector recursive doubling (remainder fold for
//!   non-power-of-two counts) or the chunked ring, compressed once per
//!   exchange.
//! * **BcastFromLeader** — descent: raw legs fan out directly over
//!   NVLink; compressed legs forward one compress-once stream down a
//!   binomial tree (every consumer decompresses exactly once).
//! * **GatherToLeader / AllgatherRing** — the Allgather mirror:
//!   concatenate in rank order going up, ring the super-blocks across
//!   the top, broadcast the gathered vector down.
//! * **ScatterFromLeader** — the Reduce_scatter descent: the leader
//!   slices its vector by each participant's subtree chunk range and
//!   sends the share; after the tier-0 leg every rank holds chunk `r`
//!   of the `Chunks::new(total, nranks)` layout.
//!
//! Compression is confined to tiers ≥ 1, so the error accounting is
//! exactly what [`crate::topo::Schedule::amplification`] walks — the
//! schedule and its error model can never drift apart.
//!
//! **Per-leg bounds.** Execution is driven by an
//! [`crate::topo::ExecPlan`]: each leg carries its own
//! [`crate::topo::LegExec`] (compression mode + absolute error bound),
//! and [`run_plan`] interprets exactly that — entering a leg rebinds
//! the rank's compressor to the leg's bound
//! ([`RankCtx::begin_leg`]), so a budgeted dispatch whose per-tier
//! split assigns tier 1 and tier 2 different `eb`s genuinely runs
//! different compressors on them. [`run_schedule`] remains the
//! bare-schedule entry point: it derives the equivalent uniform plan
//! from the cluster's ambient policy and bound.
//!
//! **Pipelining.** When the plan's `depth` exceeds 1,
//! [`run_legs_pipelined`] splits the vector into `depth` chunk windows
//! (the same [`Chunks`] floor math) and drives each chunk's legs as
//! [`LegCursor`] round state machines on a global round calendar, one
//! round of stagger between chunks, issuing every in-flight chunk's
//! sends before awaiting any arrival — chunk `k`'s wire rounds overlap
//! the other chunks' compress/reduce kernels. Per chunk, arithmetic,
//! tags, and reduction order are the barrier executor's exactly, so a
//! pipelined result is bitwise-identical to the depth-1 run.

use crate::compress::CodecSpec;
use crate::coordinator::{CompBuf, CompressionMode, DeviceBuf, Payload, ProgFut, Program, RankCtx};
use crate::error::{Error, Result};
use crate::gpu::StreamId;
use crate::sim::VirtTime;
use crate::topo::{compile_min_error, ExecPlan, LegExec, LegKind, Schedule, TierTree};

use super::chunking::Chunks;
use super::Op;

/// Tag base; the pipeline chunk is encoded above bit 28, the leg index
/// above bit 24, per-message offsets (member index / round) below.
const TAG_SCHED: u64 = 0x544F_0000_0000;

/// Hard cap on pipeline depth: chunk indices must fit the tag bits
/// (28..31), and deeper pipelines only pay more per-chunk latency
/// floors anyway. The tuner's depth sweep stays within this.
pub const MAX_PIPELINE_DEPTH: usize = 8;

fn tag_c(chunk: usize, leg: usize, off: u64) -> u64 {
    debug_assert!(chunk < MAX_PIPELINE_DEPTH);
    TAG_SCHED + ((chunk as u64) << 28) + ((leg as u64) << 24) + off
}

/// Offsets keeping a leg's sub-exchanges apart (member indices occupy
/// the low range).
const OFF_REDOUB: u64 = 0x10_0000;
const OFF_FOLD: u64 = 0x20_0000;
const OFF_UNFOLD: u64 = 0x30_0000;
const OFF_RING_RS: u64 = 0x40_0000;
const OFF_RING_AG: u64 = 0x50_0000;

/// Send the whole vector to `to`, compressed when the leg compresses
/// (async memset of the reused temp buffers, then compress on the side
/// stream — §3.3.4, exactly as flat gZ-ReDoub does).
fn send_vec(
    ctx: &mut RankCtx,
    stream: StreamId,
    to: usize,
    tag: u64,
    data: &DeviceBuf,
    data_t: VirtTime,
    compressed: bool,
) {
    if compressed {
        ctx.memset(stream, data.bytes(), data_t);
        let (c, t_c) = ctx.compress(stream, data, data_t);
        ctx.send(to, tag, Payload::Comp(c), t_c);
    } else {
        ctx.send(to, tag, Payload::Raw(data.clone()), data_t);
    }
}

/// Receive a whole vector from `from`, decompressing when compressed.
async fn recv_vec(
    ctx: &mut RankCtx,
    stream: StreamId,
    from: usize,
    tag: u64,
    compressed: bool,
) -> (DeviceBuf, VirtTime) {
    if compressed {
        let (c, t_in) = ctx.recv_comp(from, tag).await;
        ctx.decompress(stream, &c, t_in)
    } else {
        ctx.recv_raw(from, tag).await
    }
}

/// [`Program`] adapter running an owned [`Schedule`] via
/// [`run_schedule`].
pub struct SchedProg(pub Schedule);

impl Program for SchedProg {
    fn run<'a>(&'a self, ctx: &'a mut RankCtx, input: DeviceBuf) -> ProgFut<'a> {
        Box::pin(async move { run_schedule(ctx, &self.0, input).await })
    }
}

/// [`Program`] adapter running an owned [`ExecPlan`] via [`run_plan`].
pub struct PlanProg(pub ExecPlan);

impl Program for PlanProg {
    fn run<'a>(&'a self, ctx: &'a mut RankCtx, input: DeviceBuf) -> ProgFut<'a> {
        Box::pin(async move { run_plan(ctx, &self.0, input).await })
    }
}

/// [`Program`] adapter for the rooted hierarchical descents
/// ([`Op::Scatter`], [`Op::Bcast`]): carries the total element count of
/// the scattered/broadcast vector, which non-root ranks cannot derive
/// from their (possibly empty) local inputs.
pub struct RootedProg {
    /// The compiled plan (its schedule records the root).
    pub plan: ExecPlan,
    /// Element count of the root's vector.
    pub total: usize,
}

impl Program for RootedProg {
    fn run<'a>(&'a self, ctx: &'a mut RankCtx, input: DeviceBuf) -> ProgFut<'a> {
        Box::pin(async move {
            let sched = self.plan.schedule.as_ref().ok_or_else(|| {
                Error::collective("rooted hierarchical dispatch needs a scheduled plan")
            })?;
            if self.plan.legs.len() != sched.legs.len() {
                return Err(Error::collective(format!(
                    "execution plan carries {} leg directives for a {}-leg schedule",
                    self.plan.legs.len(),
                    sched.legs.len()
                )));
            }
            run_legs_pipelined(
                ctx,
                sched,
                &self.plan.legs,
                input,
                self.plan.depth,
                Some(self.total),
            )
            .await
        })
    }
}

/// Execute a compiled [`ExecPlan`] (a hierarchical schedule whose legs
/// carry their own compression mode and error bound). Every rank of
/// the communicator must run the same plan over a same-length input
/// (the root-free ops: Allreduce, Reduce_scatter, Allgather).
pub async fn run_plan(ctx: &mut RankCtx, plan: &ExecPlan, input: DeviceBuf) -> Result<DeviceBuf> {
    let sched = plan.schedule.as_ref().ok_or_else(|| {
        Error::collective("run_plan needs a scheduled (hierarchical) execution plan")
    })?;
    if plan.legs.len() != sched.legs.len() {
        return Err(Error::collective(format!(
            "execution plan carries {} leg directives for a {}-leg schedule",
            plan.legs.len(),
            sched.legs.len()
        )));
    }
    run_legs_pipelined(ctx, sched, &plan.legs, input, plan.depth, None).await
}

/// [`Program`] adapter for registry-default rooted hierarchical
/// dispatch ([`Op::Scatter`], [`Op::Bcast`] without a precompiled
/// plan): compiles the rooted descent from the cluster's tier tree at
/// run time and executes it at the ambient policy.
pub struct RootedDefaultProg {
    /// Which rooted descent to compile.
    pub op: Op,
    /// Element count of the root's vector.
    pub total: usize,
    /// The dispatch root.
    pub root: usize,
}

impl Program for RootedDefaultProg {
    fn run<'a>(&'a self, ctx: &'a mut RankCtx, input: DeviceBuf) -> ProgFut<'a> {
        Box::pin(async move {
            if ctx.nranks() <= 1 {
                return Ok(input);
            }
            let compressed = ctx.policy().compression != CompressionMode::None;
            let sched = crate::topo::compile_rooted(self.op, ctx.tiers(), compressed, self.root)?;
            run_schedule_with(ctx, &sched, input, Some(self.total)).await
        })
    }
}

/// Execute a compiled hierarchical schedule at the cluster's ambient
/// policy and compressor bound — the bare-schedule entry point for
/// direct invocation; equivalent to [`run_plan`] over the uniform
/// [`ExecPlan`] of that schedule.
pub async fn run_schedule(ctx: &mut RankCtx, sched: &Schedule, input: DeviceBuf) -> Result<DeviceBuf> {
    run_schedule_with(ctx, sched, input, None).await
}

/// [`run_schedule`] with an explicit total element count, which the
/// rooted descents need because non-root ranks hold empty inputs.
pub async fn run_schedule_with(
    ctx: &mut RankCtx,
    sched: &Schedule,
    input: DeviceBuf,
    total_override: Option<usize>,
) -> Result<DeviceBuf> {
    let mode = ctx.policy().compression;
    let eb = ctx.compressor_error_bound().unwrap_or(0.0);
    // Tuned per-leg codecs are honored only when the ambient compressor
    // is the canonical error-bounded pipeline; an explicit ambient
    // codec choice wins over the tuner's.
    let ambient = ctx.compressor_spec();
    let honor_tuned = mode == CompressionMode::ErrorBounded
        && ambient.unwrap_or_else(CodecSpec::cuszp) == CodecSpec::cuszp();
    let legs: Vec<LegExec> = sched
        .legs
        .iter()
        .map(|l| {
            if l.compressed && mode != CompressionMode::None {
                match l.codec {
                    Some(c) if honor_tuned => LegExec::with_codec(c, eb),
                    _ => LegExec {
                        compression: mode,
                        codec: LegExec::default_codec(mode),
                        eb,
                    },
                }
            } else {
                LegExec::raw()
            }
        })
        .collect();
    run_legs_pipelined(ctx, sched, &legs, input, 1, total_override).await
}

/// Mutable per-chunk execution state threaded through the legs: the
/// rank's current buffer, its virtual-time readiness, and the global
/// element window it covers. The barrier executor is the degenerate
/// single chunk over `[0, total)`.
struct ChunkState {
    data: DeviceBuf,
    data_t: VirtTime,
    /// Global element offset of `data` (advances down scatter descents).
    off: usize,
    /// Global chunk bounds `[lo, hi)` — scatter descents intersect the
    /// per-rank chunk ranges with this window.
    lo: usize,
    hi: usize,
}

/// Run one whole leg of the schedule — the depth-1 **barrier**
/// executor, whose message tags, stream choice, and leg spans are
/// bit-identical to the historical sequential interpreter. Pipelined
/// dispatch (depth ≥ 2) never calls this: it drives the same per-leg
/// arithmetic through [`LegCursor`] state machines so rounds of
/// different chunks can interleave (see the module docs for per-leg
/// semantics).
async fn run_one_leg(
    ctx: &mut RankCtx,
    sched: &Schedule,
    li: usize,
    lex: LegExec,
    total_elems: usize,
    st: &mut ChunkState,
) -> Result<()> {
    let n = ctx.nranks();
    let me = ctx.rank();
    let tree = &sched.tree;
    let leg = &sched.legs[li];
    let t = leg.tier;
    let cix = 0;
    let compressed = lex.compresses();
    let stream = if ctx.policy().overlap {
        StreamId::NonDefault(cix)
    } else {
        StreamId::Default
    };

    if leg.kind == LegKind::RootShift {
        // Engages exactly the root and rank 0, regardless of tier
        // membership (the root can be any rank).
        let root = sched.root;
        if root == 0 || (me != root && me != 0) {
            return Ok(());
        }
        ctx.begin_leg(li, lex);
        if me == root {
            send_vec(ctx, stream, 0, tag_c(cix, li, 0), &st.data, st.data_t, compressed);
            // The root's copy is stale until the descent hands its own
            // share back.
        } else {
            let (d, t_in) = recv_vec(ctx, stream, root, tag_c(cix, li, 0), compressed).await;
            st.data = d;
            st.data_t = t_in;
            st.off = st.lo;
        }
        return Ok(());
    }

    if !tree.participates(t, me) {
        return Ok(());
    }
    // Enter the leg: compress kernels below run at ITS bound and
    // record their observed error under its index.
    ctx.begin_leg(li, lex);
    let group = tree.group_of(t, me);
    let ps = tree.group_participants(t, group);
    let k = ps.len();
    if k <= 1 {
        if leg.kind == LegKind::ScatterFromLeader {
            // Sole participant: nothing to exchange, but the scatter
            // descent still narrows the vector to this subtree's chunk
            // range (within the pipeline chunk's window).
            let pspan = tree.pspan(t);
            let chunks = Chunks::new(total_elems, n);
            let lo = chunks.start(me).clamp(st.lo, st.hi);
            let hi = chunks.start((me + pspan).min(n)).clamp(st.lo, st.hi);
            st.data = st.data.slice(lo - st.off..hi - st.off);
            st.off = lo;
        }
        return Ok(());
    }
    let my_idx = tree.relative_rank(t, me);
    match leg.kind {
        LegKind::ReduceToLeader => {
            if my_idx != 0 {
                send_vec(ctx, stream, ps[0], tag_c(cix, li, my_idx as u64), &st.data, st.data_t, compressed);
                // `data` is stale until the mirrored descent leg.
            } else {
                for (j, m) in ps.iter().enumerate().skip(1) {
                    let (theirs, t_in) =
                        recv_vec(ctx, stream, *m, tag_c(cix, li, j as u64), compressed).await;
                    let (sum, t_sum) = ctx.reduce(stream, &st.data, &theirs, t_in.join(st.data_t))?;
                    st.data = sum;
                    st.data_t = t_sum;
                }
            }
        }

        LegKind::GatherToLeader => {
            if my_idx != 0 {
                send_vec(ctx, stream, ps[0], tag_c(cix, li, my_idx as u64), &st.data, st.data_t, compressed);
            } else {
                let mut parts = Vec::with_capacity(k);
                let mut t_all = st.data_t;
                parts.push(st.data.clone());
                for (j, m) in ps.iter().enumerate().skip(1) {
                    let (theirs, t_in) =
                        recv_vec(ctx, stream, *m, tag_c(cix, li, j as u64), compressed).await;
                    t_all = t_all.join(t_in);
                    parts.push(theirs);
                }
                st.data = DeviceBuf::concat(&parts)?;
                st.data_t = t_all;
            }
        }

        LegKind::AllreduceRedoub => {
            // MPICH remainder scheme over the participant list — the
            // PR 2 leader exchange, generalized from "one leader per
            // node" to any tier's participants.
            let pof2 = 1usize << (usize::BITS - 1 - k.leading_zeros()) as usize;
            let rem = k - pof2;
            let newidx: isize;
            if my_idx < 2 * rem {
                if my_idx % 2 == 0 {
                    send_vec(ctx, stream, ps[my_idx + 1], tag_c(cix, li, OFF_FOLD), &st.data, st.data_t, compressed);
                    newidx = -1;
                } else {
                    let (theirs, t_in) =
                        recv_vec(ctx, stream, ps[my_idx - 1], tag_c(cix, li, OFF_FOLD), compressed)
                            .await;
                    let (sum, t_sum) = ctx.reduce(stream, &st.data, &theirs, t_in.join(st.data_t))?;
                    st.data = sum;
                    st.data_t = t_sum;
                    newidx = (my_idx / 2) as isize;
                }
            } else {
                newidx = (my_idx - rem) as isize;
            }
            if newidx >= 0 {
                let nr = newidx as usize;
                let mut mask = 1usize;
                let mut round: u64 = 0;
                while mask < pof2 {
                    let peer_nr = nr ^ mask;
                    let peer_idx = if peer_nr < rem {
                        peer_nr * 2 + 1
                    } else {
                        peer_nr + rem
                    };
                    let peer = ps[peer_idx];
                    send_vec(ctx, stream, peer, tag_c(cix, li, OFF_REDOUB + round), &st.data, st.data_t, compressed);
                    let (theirs, t_in) =
                        recv_vec(ctx, stream, peer, tag_c(cix, li, OFF_REDOUB + round), compressed)
                            .await;
                    let (sum, t_sum) = ctx.reduce(stream, &st.data, &theirs, t_in.join(st.data_t))?;
                    st.data = sum;
                    st.data_t = t_sum;
                    mask <<= 1;
                    round += 1;
                }
            }
            if my_idx < 2 * rem {
                if my_idx % 2 == 1 {
                    send_vec(ctx, stream, ps[my_idx - 1], tag_c(cix, li, OFF_UNFOLD), &st.data, st.data_t, compressed);
                } else {
                    let (result, t_in) =
                        recv_vec(ctx, stream, ps[my_idx + 1], tag_c(cix, li, OFF_UNFOLD), compressed)
                            .await;
                    st.data = result;
                    st.data_t = t_in;
                }
            }
        }

        LegKind::AllreduceRing => {
            let next = ps[(my_idx + 1) % k];
            let prev = ps[(my_idx + k - 1) % k];
            let chunks = Chunks::new(st.data.elems(), k);
            let mut acc: Vec<DeviceBuf> =
                (0..k).map(|c| st.data.slice(chunks.range(c))).collect();
            let mut acc_t: Vec<VirtTime> = vec![st.data_t; k];
            // Reduce-scatter phase.
            for s in 1..k {
                let send_idx = (my_idx + k - s) % k;
                let recv_idx = (my_idx + k - s - 1) % k;
                if compressed {
                    let (c, t_c) = ctx.compress(stream, &acc[send_idx], acc_t[send_idx]);
                    ctx.send(next, tag_c(cix, li, OFF_RING_RS + s as u64), Payload::Comp(c), t_c);
                    let (cin, t_in) =
                        ctx.recv_comp(prev, tag_c(cix, li, OFF_RING_RS + s as u64)).await;
                    let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
                    let (sum, t_sum) =
                        ctx.reduce(stream, &acc[recv_idx], &dec, t_dec.join(acc_t[recv_idx]))?;
                    acc[recv_idx] = sum;
                    acc_t[recv_idx] = t_sum;
                } else {
                    ctx.send(
                        next,
                        tag_c(cix, li, OFF_RING_RS + s as u64),
                        Payload::Raw(acc[send_idx].clone()),
                        acc_t[send_idx],
                    );
                    let (bin, t_in) =
                        ctx.recv_raw(prev, tag_c(cix, li, OFF_RING_RS + s as u64)).await;
                    let (sum, t_sum) =
                        ctx.reduce(stream, &acc[recv_idx], &bin, t_in.join(acc_t[recv_idx]))?;
                    acc[recv_idx] = sum;
                    acc_t[recv_idx] = t_sum;
                }
            }
            // Allgather phase: forward finished chunks verbatim.
            if compressed {
                let (cmine, t0) = ctx.compress(stream, &acc[my_idx], acc_t[my_idx]);
                let mut outgoing: CompBuf = cmine;
                let mut out_t = t0;
                for s in 1..k {
                    let recv_idx = (my_idx + k - s) % k;
                    ctx.send(
                        next,
                        tag_c(cix, li, OFF_RING_AG + s as u64),
                        Payload::Comp(outgoing.clone()),
                        out_t,
                    );
                    let (cin, t_in) =
                        ctx.recv_comp(prev, tag_c(cix, li, OFF_RING_AG + s as u64)).await;
                    let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
                    acc[recv_idx] = dec;
                    acc_t[recv_idx] = t_dec;
                    outgoing = cin;
                    out_t = t_in;
                }
            } else {
                let mut outgoing = acc[my_idx].clone();
                let mut out_t = acc_t[my_idx];
                for s in 1..k {
                    let recv_idx = (my_idx + k - s) % k;
                    ctx.send(
                        next,
                        tag_c(cix, li, OFF_RING_AG + s as u64),
                        Payload::Raw(outgoing.clone()),
                        out_t,
                    );
                    let (bin, t_in) =
                        ctx.recv_raw(prev, tag_c(cix, li, OFF_RING_AG + s as u64)).await;
                    acc[recv_idx] = bin.clone();
                    acc_t[recv_idx] = t_in;
                    outgoing = bin;
                    out_t = t_in;
                }
            }
            st.data = DeviceBuf::concat(&acc)?;
            st.data_t = acc_t.iter().fold(VirtTime::ZERO, |a, b| a.join(*b));
        }

        LegKind::AllgatherRing => {
            let next = ps[(my_idx + 1) % k];
            let prev = ps[(my_idx + k - 1) % k];
            let mut blocks: Vec<Option<DeviceBuf>> = (0..k).map(|_| None).collect();
            let mut t_all = st.data_t;
            blocks[my_idx] = Some(st.data.clone());
            if compressed {
                let (cmine, t0) = ctx.compress(stream, &st.data, st.data_t);
                let mut outgoing: CompBuf = cmine;
                let mut out_t = t0;
                for s in 1..k {
                    let recv_idx = (my_idx + k - s) % k;
                    ctx.send(
                        next,
                        tag_c(cix, li, OFF_RING_AG + s as u64),
                        Payload::Comp(outgoing.clone()),
                        out_t,
                    );
                    let (cin, t_in) =
                        ctx.recv_comp(prev, tag_c(cix, li, OFF_RING_AG + s as u64)).await;
                    let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
                    t_all = t_all.join(t_dec);
                    blocks[recv_idx] = Some(dec);
                    outgoing = cin;
                    out_t = t_in;
                }
            } else {
                let mut outgoing = st.data.clone();
                let mut out_t = st.data_t;
                for s in 1..k {
                    let recv_idx = (my_idx + k - s) % k;
                    ctx.send(
                        next,
                        tag_c(cix, li, OFF_RING_AG + s as u64),
                        Payload::Raw(outgoing.clone()),
                        out_t,
                    );
                    let (bin, t_in) =
                        ctx.recv_raw(prev, tag_c(cix, li, OFF_RING_AG + s as u64)).await;
                    t_all = t_all.join(t_in);
                    blocks[recv_idx] = Some(bin.clone());
                    outgoing = bin;
                    out_t = t_in;
                }
            }
            let parts: Vec<DeviceBuf> = blocks.into_iter().map(|b| b.unwrap()).collect();
            st.data = DeviceBuf::concat(&parts)?;
            st.data_t = t_all;
        }

        LegKind::BcastFromLeader => {
            if compressed {
                // Compress-once stream forwarded down a binomial tree:
                // every consumer decodes exactly once.
                let mut held: Option<(CompBuf, VirtTime)> = None;
                if my_idx == 0 {
                    ctx.memset(stream, st.data.bytes(), st.data_t);
                    let (c, t_c) = ctx.compress(stream, &st.data, st.data_t);
                    held = Some((c, t_c));
                }
                let mut mask = 1usize;
                while mask < k {
                    if my_idx < mask {
                        if my_idx + mask < k {
                            let (c, t_c) = held.as_ref().expect("bcast sender holds the stream");
                            ctx.send(
                                ps[my_idx + mask],
                                tag_c(cix, li, (my_idx + mask) as u64),
                                Payload::Comp(c.clone()),
                                *t_c,
                            );
                        }
                    } else if my_idx < 2 * mask {
                        let (c, t_in) =
                            ctx.recv_comp(ps[my_idx - mask], tag_c(cix, li, my_idx as u64)).await;
                        held = Some((c, t_in));
                    }
                    mask <<= 1;
                }
                if my_idx != 0 {
                    let (c, t_in) = held.expect("bcast member received the stream");
                    let (d, t_d) = ctx.decompress(stream, &c, t_in);
                    st.data = d;
                    st.data_t = t_d;
                }
            } else if my_idx == 0 {
                // Raw NVLink fan-out, members in rank order.
                for (j, m) in ps.iter().enumerate().skip(1) {
                    ctx.send(*m, tag_c(cix, li, j as u64), Payload::Raw(st.data.clone()), st.data_t);
                }
            } else {
                let (d, t_in) = ctx.recv_raw(ps[0], tag_c(cix, li, my_idx as u64)).await;
                st.data = d;
                st.data_t = t_in;
            }
        }

        LegKind::ScatterFromLeader => {
            let pspan = tree.pspan(t);
            let chunks = Chunks::new(total_elems, n);
            if my_idx == 0 {
                for (j, m) in ps.iter().enumerate().skip(1) {
                    let lo = chunks.start(*m).clamp(st.lo, st.hi);
                    let hi = chunks.start((*m + pspan).min(n)).clamp(st.lo, st.hi);
                    let slice = st.data.slice(lo - st.off..hi - st.off);
                    if compressed && slice.elems() > 0 {
                        let (c, t_c) = ctx.compress(stream, &slice, st.data_t);
                        ctx.send(*m, tag_c(cix, li, j as u64), Payload::Comp(c), t_c);
                    } else {
                        ctx.send(*m, tag_c(cix, li, j as u64), Payload::Raw(slice), st.data_t);
                    }
                }
                let lo = chunks.start(me).clamp(st.lo, st.hi);
                let hi = chunks.start((me + pspan).min(n)).clamp(st.lo, st.hi);
                st.data = st.data.slice(lo - st.off..hi - st.off);
                st.off = lo;
            } else {
                let lo = chunks.start(me).clamp(st.lo, st.hi);
                let hi = chunks.start((me + pspan).min(n)).clamp(st.lo, st.hi);
                let (d, t_in) = if compressed && hi > lo {
                    let (c, t_in) = ctx.recv_comp(ps[0], tag_c(cix, li, my_idx as u64)).await;
                    ctx.decompress(stream, &c, t_in)
                } else {
                    ctx.recv_raw(ps[0], tag_c(cix, li, my_idx as u64)).await
                };
                st.data = d;
                st.data_t = t_in;
                st.off = lo;
            }
        }

        // Handled before the participation check.
        LegKind::RootShift => unreachable!("RootShift engages outside tier participation"),
    }
    Ok(())
}

/// A buffer forwarded verbatim between rounds of a ring leg on the
/// pipelined path (compress-once forwarding: the received stream is
/// re-sent, never re-encoded).
enum Fwd {
    Comp(CompBuf, VirtTime),
    Raw(DeviceBuf, VirtTime),
}

/// MPICH recursive-doubling peer of round `j` for post-fold index
/// `nr`: the partner index folds back through the remainder mapping.
fn redoub_peer(ps: &[usize], nr: usize, rem: usize, j: usize) -> usize {
    let peer_nr = nr ^ (1usize << j);
    let peer_idx = if peer_nr < rem {
        peer_nr * 2 + 1
    } else {
        peer_nr + rem
    };
    ps[peer_idx]
}

/// This rank's role in one (chunk, leg) pair, resolved once at leg
/// entry, plus the cross-round state the role carries.
enum CursorKind {
    /// Not engaged: outside the tier, a degenerate group, or a
    /// RootShift that doesn't involve this rank.
    Idle,
    /// RootShift source: ship the vector to rank 0.
    ShiftSend { to: usize },
    /// RootShift sink (rank 0): adopt the root's vector.
    ShiftRecv { from: usize },
    /// Sole participant of a scatter descent: narrow the window only.
    Narrow { pspan: usize },
    ReduceMember { leader: usize, my_idx: usize },
    ReduceLeader { ps: Vec<usize> },
    GatherMember { leader: usize, my_idx: usize },
    GatherLeader {
        ps: Vec<usize>,
        parts: Vec<Option<DeviceBuf>>,
        t_all: VirtTime,
    },
    Redoub {
        ps: Vec<usize>,
        my_idx: usize,
        pof2: usize,
        rem: usize,
        /// Post-fold index; −1 = folded out until the unfold.
        newidx: isize,
    },
    Ring {
        ps: Vec<usize>,
        my_idx: usize,
        k: usize,
        acc: Vec<DeviceBuf>,
        acc_t: Vec<VirtTime>,
        fwd: Option<Fwd>,
    },
    AgRing {
        ps: Vec<usize>,
        my_idx: usize,
        k: usize,
        blocks: Vec<Option<DeviceBuf>>,
        t_all: VirtTime,
        fwd: Option<Fwd>,
    },
    BcastTree {
        ps: Vec<usize>,
        my_idx: usize,
        k: usize,
        held: Option<(CompBuf, VirtTime)>,
    },
    BcastRaw { ps: Vec<usize>, my_idx: usize },
    ScatterLeader { ps: Vec<usize>, pspan: usize },
    ScatterMember { leader: usize, my_idx: usize, pspan: usize },
}

/// Round-granular state machine for one (chunk, leg) pair under the
/// pipelined wavefront. The leg's exchanges unroll into the global
/// round calendar ([`Schedule::leg_rounds`]); each calendar round
/// splits into a non-blocking [`LegCursor::issue`] half (kernel
/// enqueues + sends — phase A of a superstep) and an awaiting
/// [`LegCursor::complete`] half (arrivals + follow-up kernels —
/// phase B), with [`LegCursor::finalize`] reassembling multi-buffer
/// legs after the last round. Arithmetic, message tags, and kernel
/// order per chunk are exactly the barrier executor's
/// ([`run_one_leg`]) over the chunk's window — only the interleaving
/// across chunks differs, which is what lets one chunk's wire round
/// overlap the other chunks' compress/reduce kernels on their own
/// streams.
struct LegCursor {
    li: usize,
    lex: LegExec,
    cix: usize,
    stream: StreamId,
    kind: CursorKind,
}

impl LegCursor {
    /// Resolve this rank's role in leg `li` for chunk `cix` (mirrors
    /// the prologue of [`run_one_leg`]).
    fn new(
        ctx: &RankCtx,
        sched: &Schedule,
        li: usize,
        lex: LegExec,
        cix: usize,
        st: &ChunkState,
    ) -> Self {
        let me = ctx.rank();
        let tree = &sched.tree;
        let leg = &sched.legs[li];
        let t = leg.tier;
        let stream = if ctx.policy().overlap {
            StreamId::NonDefault(cix)
        } else {
            StreamId::Default
        };
        let kind = if leg.kind == LegKind::RootShift {
            let root = sched.root;
            if root == 0 || (me != root && me != 0) {
                CursorKind::Idle
            } else if me == root {
                CursorKind::ShiftSend { to: 0 }
            } else {
                CursorKind::ShiftRecv { from: root }
            }
        } else if !tree.participates(t, me) {
            CursorKind::Idle
        } else {
            let group = tree.group_of(t, me);
            let ps = tree.group_participants(t, group);
            let k = ps.len();
            if k <= 1 {
                if leg.kind == LegKind::ScatterFromLeader {
                    CursorKind::Narrow {
                        pspan: tree.pspan(t),
                    }
                } else {
                    CursorKind::Idle
                }
            } else {
                let my_idx = tree.relative_rank(t, me);
                match leg.kind {
                    LegKind::ReduceToLeader => {
                        if my_idx != 0 {
                            CursorKind::ReduceMember { leader: ps[0], my_idx }
                        } else {
                            CursorKind::ReduceLeader { ps }
                        }
                    }
                    LegKind::GatherToLeader => {
                        if my_idx != 0 {
                            CursorKind::GatherMember { leader: ps[0], my_idx }
                        } else {
                            let mut parts: Vec<Option<DeviceBuf>> = vec![None; k];
                            parts[0] = Some(st.data.clone());
                            CursorKind::GatherLeader {
                                ps,
                                parts,
                                t_all: st.data_t,
                            }
                        }
                    }
                    LegKind::AllreduceRedoub => {
                        let pof2 = 1usize << (usize::BITS - 1 - k.leading_zeros()) as usize;
                        let rem = k - pof2;
                        let newidx = if my_idx < 2 * rem {
                            if my_idx % 2 == 0 {
                                -1
                            } else {
                                (my_idx / 2) as isize
                            }
                        } else {
                            (my_idx - rem) as isize
                        };
                        CursorKind::Redoub {
                            ps,
                            my_idx,
                            pof2,
                            rem,
                            newidx,
                        }
                    }
                    LegKind::AllreduceRing => {
                        let chunks = Chunks::new(st.data.elems(), k);
                        let acc: Vec<DeviceBuf> =
                            (0..k).map(|c| st.data.slice(chunks.range(c))).collect();
                        let acc_t = vec![st.data_t; k];
                        CursorKind::Ring {
                            ps,
                            my_idx,
                            k,
                            acc,
                            acc_t,
                            fwd: None,
                        }
                    }
                    LegKind::AllgatherRing => {
                        let mut blocks: Vec<Option<DeviceBuf>> = vec![None; k];
                        blocks[my_idx] = Some(st.data.clone());
                        CursorKind::AgRing {
                            ps,
                            my_idx,
                            k,
                            blocks,
                            t_all: st.data_t,
                            fwd: None,
                        }
                    }
                    LegKind::BcastFromLeader => {
                        if lex.compresses() {
                            CursorKind::BcastTree {
                                ps,
                                my_idx,
                                k,
                                held: None,
                            }
                        } else {
                            CursorKind::BcastRaw { ps, my_idx }
                        }
                    }
                    LegKind::ScatterFromLeader => {
                        let pspan = tree.pspan(t);
                        if my_idx == 0 {
                            CursorKind::ScatterLeader { ps, pspan }
                        } else {
                            CursorKind::ScatterMember {
                                leader: ps[0],
                                my_idx,
                                pspan,
                            }
                        }
                    }
                    // Resolved before the participation check.
                    LegKind::RootShift => unreachable!("RootShift engages outside tiers"),
                }
            }
        };
        LegCursor {
            li,
            lex,
            cix,
            stream,
            kind,
        }
    }

    /// Phase A of calendar round `r`: enqueue this round's kernels on
    /// the chunk's stream and hand its sends to the fabric. Never
    /// awaits — the wavefront issues every in-flight chunk's round
    /// before any rank blocks on an arrival, which is both the overlap
    /// and the deadlock-freedom argument (every phase-B await matches
    /// a send issued in phase A of the same or an earlier superstep).
    /// Rounds past this group's need (smaller group than the global
    /// calendar) are idle. Each active round re-asserts the leg's
    /// compressor binding, because cursors of different legs
    /// interleave within a superstep.
    fn issue(
        &mut self,
        ctx: &mut RankCtx,
        st: &mut ChunkState,
        r: usize,
        total_elems: usize,
    ) -> Result<()> {
        let (li, lex, cix, stream) = (self.li, self.lex, self.cix, self.stream);
        let compressed = lex.compresses();
        let n = ctx.nranks();
        let me = ctx.rank();
        match &mut self.kind {
            CursorKind::Idle
            | CursorKind::ShiftRecv { .. }
            | CursorKind::ReduceLeader { .. }
            | CursorKind::GatherLeader { .. }
            | CursorKind::ScatterMember { .. } => {}

            CursorKind::ShiftSend { to } => {
                if r == 0 {
                    let to = *to;
                    ctx.begin_leg_chunk(li, lex, cix);
                    send_vec(ctx, stream, to, tag_c(cix, li, 0), &st.data, st.data_t, compressed);
                    // The root's copy is stale until the descent hands
                    // its own share back.
                }
            }

            CursorKind::Narrow { pspan } => {
                if r == 0 {
                    let pspan = *pspan;
                    ctx.begin_leg_chunk(li, lex, cix);
                    let chunks = Chunks::new(total_elems, n);
                    let lo = chunks.start(me).clamp(st.lo, st.hi);
                    let hi = chunks.start((me + pspan).min(n)).clamp(st.lo, st.hi);
                    st.data = st.data.slice(lo - st.off..hi - st.off);
                    st.off = lo;
                }
            }

            CursorKind::ReduceMember { leader, my_idx }
            | CursorKind::GatherMember { leader, my_idx } => {
                if r == 0 {
                    let (to, j) = (*leader, *my_idx);
                    ctx.begin_leg_chunk(li, lex, cix);
                    let tag = tag_c(cix, li, j as u64);
                    send_vec(ctx, stream, to, tag, &st.data, st.data_t, compressed);
                    // `data` is stale until the mirrored descent leg.
                }
            }

            CursorKind::Redoub {
                ps,
                my_idx,
                pof2,
                rem,
                newidx,
            } => {
                let (my_idx, rem, nix) = (*my_idx, *rem, *newidx);
                let fold_off = (rem > 0) as usize;
                let logp = pof2.trailing_zeros() as usize;
                if rem > 0 && r == 0 && my_idx < 2 * rem && my_idx % 2 == 0 {
                    // Fold: evens ship their vector to the odd partner.
                    let to = ps[my_idx + 1];
                    ctx.begin_leg_chunk(li, lex, cix);
                    let tag = tag_c(cix, li, OFF_FOLD);
                    send_vec(ctx, stream, to, tag, &st.data, st.data_t, compressed);
                } else if r >= fold_off && r < fold_off + logp {
                    let j = r - fold_off;
                    if nix >= 0 {
                        let peer = redoub_peer(ps, nix as usize, rem, j);
                        ctx.begin_leg_chunk(li, lex, cix);
                        send_vec(
                            ctx,
                            stream,
                            peer,
                            tag_c(cix, li, OFF_REDOUB + j as u64),
                            &st.data,
                            st.data_t,
                            compressed,
                        );
                    }
                } else if rem > 0 && r == fold_off + logp && my_idx < 2 * rem && my_idx % 2 == 1 {
                    // Unfold: odds hand the result back to the evens.
                    let to = ps[my_idx - 1];
                    ctx.begin_leg_chunk(li, lex, cix);
                    let tag = tag_c(cix, li, OFF_UNFOLD);
                    send_vec(ctx, stream, to, tag, &st.data, st.data_t, compressed);
                }
            }

            CursorKind::Ring {
                ps,
                my_idx,
                k,
                acc,
                acc_t,
                fwd,
            } => {
                let (k, my_idx) = (*k, *my_idx);
                let next = ps[(my_idx + 1) % k];
                if r < k - 1 {
                    // Reduce-scatter step: ship the walking chunk.
                    let s = r + 1;
                    let send_idx = (my_idx + k - s) % k;
                    ctx.begin_leg_chunk(li, lex, cix);
                    if compressed {
                        let (c, t_c) = ctx.compress(stream, &acc[send_idx], acc_t[send_idx]);
                        let tag = tag_c(cix, li, OFF_RING_RS + s as u64);
                        ctx.send(next, tag, Payload::Comp(c), t_c);
                    } else {
                        ctx.send(
                            next,
                            tag_c(cix, li, OFF_RING_RS + s as u64),
                            Payload::Raw(acc[send_idx].clone()),
                            acc_t[send_idx],
                        );
                    }
                } else if r < 2 * (k - 1) {
                    // Allgather step: forward finished chunks verbatim.
                    let s = r - (k - 1) + 1;
                    ctx.begin_leg_chunk(li, lex, cix);
                    if s == 1 {
                        *fwd = Some(if compressed {
                            let (c, t0) = ctx.compress(stream, &acc[my_idx], acc_t[my_idx]);
                            Fwd::Comp(c, t0)
                        } else {
                            Fwd::Raw(acc[my_idx].clone(), acc_t[my_idx])
                        });
                    }
                    match fwd.as_ref().expect("ring allgather forwards the walking chunk") {
                        Fwd::Comp(c, t) => ctx.send(
                            next,
                            tag_c(cix, li, OFF_RING_AG + s as u64),
                            Payload::Comp(c.clone()),
                            *t,
                        ),
                        Fwd::Raw(b, t) => ctx.send(
                            next,
                            tag_c(cix, li, OFF_RING_AG + s as u64),
                            Payload::Raw(b.clone()),
                            *t,
                        ),
                    }
                }
            }

            CursorKind::AgRing {
                ps, my_idx, k, fwd, ..
            } => {
                let (k, my_idx) = (*k, *my_idx);
                if r < k - 1 {
                    let s = r + 1;
                    let next = ps[(my_idx + 1) % k];
                    ctx.begin_leg_chunk(li, lex, cix);
                    if s == 1 {
                        *fwd = Some(if compressed {
                            let (c, t0) = ctx.compress(stream, &st.data, st.data_t);
                            Fwd::Comp(c, t0)
                        } else {
                            Fwd::Raw(st.data.clone(), st.data_t)
                        });
                    }
                    match fwd.as_ref().expect("allgather ring forwards its block") {
                        Fwd::Comp(c, t) => ctx.send(
                            next,
                            tag_c(cix, li, OFF_RING_AG + s as u64),
                            Payload::Comp(c.clone()),
                            *t,
                        ),
                        Fwd::Raw(b, t) => ctx.send(
                            next,
                            tag_c(cix, li, OFF_RING_AG + s as u64),
                            Payload::Raw(b.clone()),
                            *t,
                        ),
                    }
                }
            }

            CursorKind::BcastTree {
                ps,
                my_idx,
                k,
                held,
            } => {
                let (k, my_idx) = (*k, *my_idx);
                let mask = 1usize << r;
                let originates = my_idx == 0 && r == 0;
                let relays = mask < k && my_idx < mask && my_idx + mask < k;
                if originates || relays {
                    ctx.begin_leg_chunk(li, lex, cix);
                }
                if originates {
                    // Compress-once: the stream every consumer decodes.
                    ctx.memset(stream, st.data.bytes(), st.data_t);
                    let (c, t_c) = ctx.compress(stream, &st.data, st.data_t);
                    *held = Some((c, t_c));
                }
                if relays {
                    let (c, t_c) = held.as_ref().expect("bcast sender holds the stream");
                    ctx.send(
                        ps[my_idx + mask],
                        tag_c(cix, li, (my_idx + mask) as u64),
                        Payload::Comp(c.clone()),
                        *t_c,
                    );
                }
            }

            CursorKind::BcastRaw { ps, my_idx } => {
                if r == 0 && *my_idx == 0 {
                    // Raw NVLink fan-out, members in rank order.
                    ctx.begin_leg_chunk(li, lex, cix);
                    for (j, m) in ps.iter().enumerate().skip(1) {
                        let raw = Payload::Raw(st.data.clone());
                        ctx.send(*m, tag_c(cix, li, j as u64), raw, st.data_t);
                    }
                }
            }

            CursorKind::ScatterLeader { ps, pspan } => {
                if r == 0 {
                    let pspan = *pspan;
                    ctx.begin_leg_chunk(li, lex, cix);
                    let chunks = Chunks::new(total_elems, n);
                    for (j, m) in ps.iter().enumerate().skip(1) {
                        let lo = chunks.start(*m).clamp(st.lo, st.hi);
                        let hi = chunks.start((*m + pspan).min(n)).clamp(st.lo, st.hi);
                        let slice = st.data.slice(lo - st.off..hi - st.off);
                        if compressed && slice.elems() > 0 {
                            let (c, t_c) = ctx.compress(stream, &slice, st.data_t);
                            ctx.send(*m, tag_c(cix, li, j as u64), Payload::Comp(c), t_c);
                        } else {
                            ctx.send(*m, tag_c(cix, li, j as u64), Payload::Raw(slice), st.data_t);
                        }
                    }
                    let lo = chunks.start(me).clamp(st.lo, st.hi);
                    let hi = chunks.start((me + pspan).min(n)).clamp(st.lo, st.hi);
                    st.data = st.data.slice(lo - st.off..hi - st.off);
                    st.off = lo;
                }
            }
        }
        Ok(())
    }

    /// Phase B of calendar round `r`: await the round's arrivals and
    /// run the follow-up kernels (decompress, reduce). Matches sends
    /// issued in phase A of the same or an earlier superstep, so the
    /// superstep order is acyclic across ranks.
    async fn complete(
        &mut self,
        ctx: &mut RankCtx,
        st: &mut ChunkState,
        r: usize,
        total_elems: usize,
    ) -> Result<()> {
        let (li, lex, cix, stream) = (self.li, self.lex, self.cix, self.stream);
        let compressed = lex.compresses();
        let n = ctx.nranks();
        let me = ctx.rank();
        match &mut self.kind {
            CursorKind::Idle
            | CursorKind::ShiftSend { .. }
            | CursorKind::Narrow { .. }
            | CursorKind::ReduceMember { .. }
            | CursorKind::GatherMember { .. }
            | CursorKind::ScatterLeader { .. } => {}

            CursorKind::ShiftRecv { from } => {
                if r == 0 {
                    let from = *from;
                    ctx.begin_leg_chunk(li, lex, cix);
                    let (d, t_in) =
                        recv_vec(ctx, stream, from, tag_c(cix, li, 0), compressed).await;
                    st.data = d;
                    st.data_t = t_in;
                    st.off = st.lo;
                }
            }

            CursorKind::ReduceLeader { ps } => {
                // One member arrival folded per round, in rank order —
                // the barrier executor's reduction order exactly.
                let j = r + 1;
                if j < ps.len() {
                    let from = ps[j];
                    ctx.begin_leg_chunk(li, lex, cix);
                    let (theirs, t_in) =
                        recv_vec(ctx, stream, from, tag_c(cix, li, j as u64), compressed).await;
                    let (sum, t_sum) = ctx.reduce(stream, &st.data, &theirs, t_in.join(st.data_t))?;
                    st.data = sum;
                    st.data_t = t_sum;
                }
            }

            CursorKind::GatherLeader { ps, parts, t_all } => {
                let j = r + 1;
                if j < ps.len() {
                    let from = ps[j];
                    ctx.begin_leg_chunk(li, lex, cix);
                    let (theirs, t_in) =
                        recv_vec(ctx, stream, from, tag_c(cix, li, j as u64), compressed).await;
                    *t_all = t_all.join(t_in);
                    parts[j] = Some(theirs);
                }
            }

            CursorKind::Redoub {
                ps,
                my_idx,
                pof2,
                rem,
                newidx,
            } => {
                let (my_idx, rem, nix) = (*my_idx, *rem, *newidx);
                let fold_off = (rem > 0) as usize;
                let logp = pof2.trailing_zeros() as usize;
                if rem > 0 && r == 0 && my_idx < 2 * rem && my_idx % 2 == 1 {
                    // Fold arrival: the odd partner absorbs the even's vector.
                    let from = ps[my_idx - 1];
                    ctx.begin_leg_chunk(li, lex, cix);
                    let (theirs, t_in) =
                        recv_vec(ctx, stream, from, tag_c(cix, li, OFF_FOLD), compressed).await;
                    let (sum, t_sum) = ctx.reduce(stream, &st.data, &theirs, t_in.join(st.data_t))?;
                    st.data = sum;
                    st.data_t = t_sum;
                } else if r >= fold_off && r < fold_off + logp {
                    let j = r - fold_off;
                    if nix >= 0 {
                        let peer = redoub_peer(ps, nix as usize, rem, j);
                        ctx.begin_leg_chunk(li, lex, cix);
                        let (theirs, t_in) = recv_vec(
                            ctx,
                            stream,
                            peer,
                            tag_c(cix, li, OFF_REDOUB + j as u64),
                            compressed,
                        )
                        .await;
                        let (sum, t_sum) =
                            ctx.reduce(stream, &st.data, &theirs, t_in.join(st.data_t))?;
                        st.data = sum;
                        st.data_t = t_sum;
                    }
                } else if rem > 0 && r == fold_off + logp && my_idx < 2 * rem && my_idx % 2 == 0 {
                    // Unfold arrival: the even rank adopts the result.
                    let from = ps[my_idx + 1];
                    ctx.begin_leg_chunk(li, lex, cix);
                    let (result, t_in) =
                        recv_vec(ctx, stream, from, tag_c(cix, li, OFF_UNFOLD), compressed).await;
                    st.data = result;
                    st.data_t = t_in;
                }
            }

            CursorKind::Ring {
                ps,
                my_idx,
                k,
                acc,
                acc_t,
                fwd,
            } => {
                let (k, my_idx) = (*k, *my_idx);
                let prev = ps[(my_idx + k - 1) % k];
                if r < k - 1 {
                    let s = r + 1;
                    let recv_idx = (my_idx + k - s - 1) % k;
                    ctx.begin_leg_chunk(li, lex, cix);
                    if compressed {
                        let (cin, t_in) =
                            ctx.recv_comp(prev, tag_c(cix, li, OFF_RING_RS + s as u64)).await;
                        let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
                        let (sum, t_sum) =
                            ctx.reduce(stream, &acc[recv_idx], &dec, t_dec.join(acc_t[recv_idx]))?;
                        acc[recv_idx] = sum;
                        acc_t[recv_idx] = t_sum;
                    } else {
                        let (bin, t_in) =
                            ctx.recv_raw(prev, tag_c(cix, li, OFF_RING_RS + s as u64)).await;
                        let (sum, t_sum) =
                            ctx.reduce(stream, &acc[recv_idx], &bin, t_in.join(acc_t[recv_idx]))?;
                        acc[recv_idx] = sum;
                        acc_t[recv_idx] = t_sum;
                    }
                } else if r < 2 * (k - 1) {
                    let s = r - (k - 1) + 1;
                    let recv_idx = (my_idx + k - s) % k;
                    ctx.begin_leg_chunk(li, lex, cix);
                    if compressed {
                        let (cin, t_in) =
                            ctx.recv_comp(prev, tag_c(cix, li, OFF_RING_AG + s as u64)).await;
                        let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
                        acc[recv_idx] = dec;
                        acc_t[recv_idx] = t_dec;
                        *fwd = Some(Fwd::Comp(cin, t_in));
                    } else {
                        let (bin, t_in) =
                            ctx.recv_raw(prev, tag_c(cix, li, OFF_RING_AG + s as u64)).await;
                        acc[recv_idx] = bin.clone();
                        acc_t[recv_idx] = t_in;
                        *fwd = Some(Fwd::Raw(bin, t_in));
                    }
                }
            }

            CursorKind::AgRing {
                ps,
                my_idx,
                k,
                blocks,
                t_all,
                fwd,
            } => {
                let (k, my_idx) = (*k, *my_idx);
                if r < k - 1 {
                    let s = r + 1;
                    let prev = ps[(my_idx + k - 1) % k];
                    let recv_idx = (my_idx + k - s) % k;
                    ctx.begin_leg_chunk(li, lex, cix);
                    if compressed {
                        let (cin, t_in) =
                            ctx.recv_comp(prev, tag_c(cix, li, OFF_RING_AG + s as u64)).await;
                        let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
                        *t_all = t_all.join(t_dec);
                        blocks[recv_idx] = Some(dec);
                        *fwd = Some(Fwd::Comp(cin, t_in));
                    } else {
                        let (bin, t_in) =
                            ctx.recv_raw(prev, tag_c(cix, li, OFF_RING_AG + s as u64)).await;
                        *t_all = t_all.join(t_in);
                        blocks[recv_idx] = Some(bin.clone());
                        *fwd = Some(Fwd::Raw(bin, t_in));
                    }
                }
            }

            CursorKind::BcastTree {
                ps,
                my_idx,
                k,
                held,
            } => {
                let (k, my_idx) = (*k, *my_idx);
                let mask = 1usize << r;
                if mask < k && mask <= my_idx && my_idx < 2 * mask {
                    let from = ps[my_idx - mask];
                    ctx.begin_leg_chunk(li, lex, cix);
                    let (c, t_in) = ctx.recv_comp(from, tag_c(cix, li, my_idx as u64)).await;
                    *held = Some((c, t_in));
                }
            }

            CursorKind::BcastRaw { ps, my_idx } => {
                if r == 0 && *my_idx != 0 {
                    let (from, j) = (ps[0], *my_idx);
                    ctx.begin_leg_chunk(li, lex, cix);
                    let (d, t_in) = ctx.recv_raw(from, tag_c(cix, li, j as u64)).await;
                    st.data = d;
                    st.data_t = t_in;
                }
            }

            CursorKind::ScatterMember {
                leader,
                my_idx,
                pspan,
            } => {
                if r == 0 {
                    let (from, j, pspan) = (*leader, *my_idx, *pspan);
                    ctx.begin_leg_chunk(li, lex, cix);
                    let chunks = Chunks::new(total_elems, n);
                    let lo = chunks.start(me).clamp(st.lo, st.hi);
                    let hi = chunks.start((me + pspan).min(n)).clamp(st.lo, st.hi);
                    let (d, t_in) = if compressed && hi > lo {
                        let (c, t_in) = ctx.recv_comp(from, tag_c(cix, li, j as u64)).await;
                        ctx.decompress(stream, &c, t_in)
                    } else {
                        ctx.recv_raw(from, tag_c(cix, li, j as u64)).await
                    };
                    st.data = d;
                    st.data_t = t_in;
                    st.off = lo;
                }
            }
        }
        Ok(())
    }

    /// After the leg's last calendar round: reassemble multi-buffer
    /// results and run the deferred consumer kernels, exactly as the
    /// barrier executor's leg epilogue does.
    fn finalize(&mut self, ctx: &mut RankCtx, st: &mut ChunkState) -> Result<()> {
        let (li, lex, cix, stream) = (self.li, self.lex, self.cix, self.stream);
        match &mut self.kind {
            CursorKind::GatherLeader { parts, t_all, .. } => {
                let parts: Vec<DeviceBuf> = parts
                    .iter_mut()
                    .map(|p| p.take().expect("gather leader holds every part"))
                    .collect();
                st.data = DeviceBuf::concat(&parts)?;
                st.data_t = *t_all;
            }
            CursorKind::Ring { acc, acc_t, .. } => {
                st.data = DeviceBuf::concat(&acc[..])?;
                st.data_t = acc_t.iter().fold(VirtTime::ZERO, |a, b| a.join(*b));
            }
            CursorKind::AgRing { blocks, t_all, .. } => {
                let parts: Vec<DeviceBuf> = blocks
                    .iter_mut()
                    .map(|b| b.take().expect("allgather ring fills every block"))
                    .collect();
                st.data = DeviceBuf::concat(&parts)?;
                st.data_t = *t_all;
            }
            CursorKind::BcastTree { my_idx, held, .. } => {
                if *my_idx != 0 {
                    let (c, t_in) = held.take().expect("bcast member received the stream");
                    ctx.begin_leg_chunk(li, lex, cix);
                    let (d, t_d) = ctx.decompress(stream, &c, t_in);
                    st.data = d;
                    st.data_t = t_d;
                }
            }
            _ => {}
        }
        Ok(())
    }
}

/// The leg interpreter: the barrier executor at depth 1, the
/// round-granular chunk wavefront above. Chunk boundaries come from
/// the same [`Chunks`] floor arithmetic every chunked algorithm uses;
/// each chunk's legs run in schedule order, unrolled into exchange
/// rounds on the global calendar ([`Schedule::leg_rounds`]), with
/// chunk `c` running one round behind chunk `c−1`. Every superstep
/// first **issues** every in-flight chunk's round (kernels on the
/// chunk's own stream, then the sends) and only then **awaits** the
/// arrivals — so chunk `k`'s wire time hides behind the other chunks'
/// compress/reduce kernels, and the interleave is deadlock-free: the
/// calendar is rank-independent and every await matches a send issued
/// at the same or an earlier superstep, which well-orders the message
/// dependencies. `total_override` carries the vector length for
/// rooted ops whose non-root ranks hold empty inputs.
async fn run_legs_pipelined(
    ctx: &mut RankCtx,
    sched: &Schedule,
    legs: &[LegExec],
    input: DeviceBuf,
    depth: usize,
    total_override: Option<usize>,
) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    if n <= 1 {
        return Ok(input);
    }
    if sched.tree.ranks() != n {
        return Err(Error::collective(format!(
            "schedule compiled for {} ranks dispatched on a {n}-rank communicator",
            sched.tree.ranks()
        )));
    }
    // Element count of the collective's vector — the Reduce_scatter
    // chunk layout is over this (every rank contributes a same-length
    // vector), and the pipeline splits it.
    let total_elems = total_override.unwrap_or_else(|| input.elems());
    let depth = depth.clamp(1, MAX_PIPELINE_DEPTH).min(total_elems.max(1));
    let nl = sched.legs.len();

    if depth <= 1 || nl == 0 {
        let mut st = ChunkState {
            data: input,
            data_t: ctx.now(),
            off: 0,
            lo: 0,
            hi: total_elems,
        };
        for li in 0..nl {
            run_one_leg(ctx, sched, li, legs[li], total_elems, &mut st).await?;
        }
        ctx.end_leg();
        ctx.sync_device();
        return Ok(st.data);
    }

    // Split the payload into `depth` chunk windows. Ranks that do not
    // hold the full vector (a rooted op's non-roots) start each chunk
    // empty — the descent delivers their slices.
    let split = Chunks::new(total_elems, depth);
    let t0 = ctx.now();
    let have = input.elems();
    let mut states: Vec<ChunkState> = (0..depth)
        .map(|c| {
            let r = split.range(c);
            let (lo, hi) = (r.start, r.end);
            let data = if have >= hi {
                input.slice(lo..hi)
            } else {
                input.slice(0..0)
            };
            ChunkState {
                data,
                data_t: t0,
                off: lo,
                lo,
                hi,
            }
        })
        .collect();

    // Global round calendar: leg `li` occupies calendar rounds
    // `starts[li] .. starts[li] + rounds[li]`, identical on every rank
    // (leg_rounds takes the max over groups; smaller groups idle the
    // surplus rounds). Chunk `c` runs one round behind chunk `c−1`.
    let rounds: Vec<usize> = (0..nl).map(|li| sched.leg_rounds(li)).collect();
    let starts: Vec<usize> = rounds
        .iter()
        .scan(0usize, |acc, &r| {
            let s = *acc;
            *acc += r;
            Some(s)
        })
        .collect();
    let s_total = starts[nl - 1] + rounds[nl - 1];
    // Chunk c's (leg, round) at superstep `step`, or None if the chunk
    // is not yet started or already drained.
    let at = |step: usize, c: usize| -> Option<(usize, usize)> {
        let s = step.checked_sub(c)?;
        if s >= s_total {
            return None;
        }
        let li = starts.partition_point(|&b| b <= s) - 1;
        Some((li, s - starts[li]))
    };

    let mut cursors: Vec<Option<LegCursor>> = (0..depth).map(|_| None).collect();
    for step in 0..(s_total + depth - 1) {
        // Phase A: every in-flight chunk issues its round's kernels
        // and sends before any chunk blocks — this is the overlap.
        for (c, st) in states.iter_mut().enumerate() {
            let Some((li, r)) = at(step, c) else { continue };
            if r == 0 {
                cursors[c] = Some(LegCursor::new(ctx, sched, li, legs[li], c, st));
            }
            let cur = cursors[c].as_mut().expect("cursor opened at round 0");
            cur.issue(ctx, st, r, total_elems)?;
        }
        // Phase B: await the round's arrivals, oldest chunk first.
        for (c, st) in states.iter_mut().enumerate() {
            let Some((li, r)) = at(step, c) else { continue };
            let cur = cursors[c].as_mut().expect("cursor opened at round 0");
            cur.complete(ctx, st, r, total_elems).await?;
            if r + 1 == rounds[li] {
                cur.finalize(ctx, st)?;
                cursors[c] = None;
            }
        }
    }
    ctx.end_leg();
    ctx.sync_device();

    let outs: Vec<DeviceBuf> = states.into_iter().map(|s| s.data).collect();
    let out = if sched.op == Op::Allgather {
        // Chunk `c` gathered every rank's block-slice `c`: interleave
        // the gathered chunk vectors back into rank-major order.
        let mut parts = Vec::with_capacity(n * depth);
        for r in 0..n {
            for (c, o) in outs.iter().enumerate() {
                let l = split.len(c);
                parts.push(o.slice(r * l..(r + 1) * l));
            }
        }
        DeviceBuf::concat(&parts)?
    } else {
        // Chunk windows tile the vector in order: plain concatenation
        // (per-chunk scatter outputs are each rank's range ∩ window,
        // increasing and possibly empty).
        DeviceBuf::concat(&outs)?
    };
    Ok(out)
}

/// Compile-and-run with the fewest-error schedule over the cluster's
/// own [`TierTree`] — the default entry point for direct invocation
/// (the [`crate::comm::Communicator`] passes cost-tuned schedules
/// through the registry instead).
async fn hierarchical_default(ctx: &mut RankCtx, op: Op, input: DeviceBuf) -> Result<DeviceBuf> {
    if ctx.nranks() <= 1 {
        return Ok(input);
    }
    let tree: TierTree = ctx.tiers().clone();
    let sched = compile_min_error(op, &tree, ctx.compression_enabled())?;
    run_schedule(ctx, &sched, input).await
}

/// Hierarchical Allreduce over the cluster's tier tree (the PR 2
/// two-level schedule on 2-tier layouts). See the module docs.
pub fn allreduce_hierarchical(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(hierarchical_default(ctx, Op::Allreduce, input))
}

/// Hierarchical Reduce_scatter: the Allreduce ascent and top exchange,
/// then a scatter descent; rank `r` returns the fully-reduced chunk
/// `r`. Compression stays on the tier-≥1 legs, so the worst-case error
/// follows the tree (`≈ 2^⌈log₂ groups⌉` at the top), not the `N−1`
/// linear stages of the ring — the compliant fallback tight accuracy
/// budgets need.
pub fn reduce_scatter_hierarchical(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(hierarchical_default(ctx, Op::ReduceScatter, input))
}

/// Hierarchical Allgather: concatenate blocks up the tree, ring the
/// super-blocks across the top tier, broadcast the gathered vector
/// down. Every origin block is compressed once per crossed tier
/// (compress-once forwarding), never recompressed into aggregates.
pub fn allgather_hierarchical(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(hierarchical_default(ctx, Op::Allgather, input))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::allreduce_ring;
    use crate::coordinator::{run_collective, ClusterSpec, ExecPolicy};
    use crate::net::Topology;
    use crate::testkit::Pcg32;
    use crate::topo::compile_tuned;

    fn spec(n: usize, g: usize, policy: ExecPolicy) -> ClusterSpec {
        ClusterSpec::with_topology(Topology::new(n, g).unwrap(), policy)
    }

    fn spec_tiers(n: usize, widths: &[usize], policy: ExecPolicy) -> ClusterSpec {
        ClusterSpec::with_tiers(TierTree::new(n, widths).unwrap(), policy)
    }

    /// Integer-valued inputs: f32 sums of small integers are exact, so
    /// schedules with different reduction orders agree bit-for-bit.
    fn int_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
        (0..n)
            .map(|r| {
                let mut rng = Pcg32::new(seed, r as u64);
                DeviceBuf::Real(
                    (0..d)
                        .map(|_| rng.range_usize(0, 17) as f32 - 8.0)
                        .collect(),
                )
            })
            .collect()
    }

    fn real_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
        (0..n)
            .map(|r| {
                let mut rng = Pcg32::new(seed, r as u64);
                DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
            })
            .collect()
    }

    fn exact_sum(inputs: &[DeviceBuf]) -> Vec<f32> {
        let d = inputs[0].elems();
        let mut out = vec![0.0f32; d];
        for b in inputs {
            for (o, v) in out.iter_mut().zip(b.as_real()) {
                *o += v;
            }
        }
        out
    }

    #[test]
    fn uncompressed_matches_flat_ring_bitwise() {
        // Integer data → exact sums → the two schedules must agree to
        // the bit, including partial last nodes (n=10, g=4).
        for (n, g) in [(8usize, 4usize), (10, 4), (6, 2), (7, 3), (4, 4), (5, 1)] {
            let inputs = int_inputs(n, 33, 42);
            let ring = run_collective(&spec(n, g, ExecPolicy::nccl()), inputs.clone(), &allreduce_ring)
                .unwrap();
            let hier =
                run_collective(&spec(n, g, ExecPolicy::nccl()), inputs, &allreduce_hierarchical)
                    .unwrap();
            for r in 0..n {
                assert_eq!(
                    hier.outputs[r].as_real(),
                    ring.outputs[r].as_real(),
                    "n={n} g={g} rank {r}"
                );
            }
        }
    }

    #[test]
    fn three_tier_matches_flat_ring_bitwise() {
        // Deep trees, partial groups, width-1 tiers: still exact.
        for (n, widths) in [
            (16usize, &[2usize, 2, 4][..]),
            (24, &[2, 3, 4][..]),
            (13, &[2, 2, 4][..]),
            (27, &[3, 3, 3][..]),
            (32, &[2, 2, 2, 4][..]),
        ] {
            let inputs = int_inputs(n, 29, 7);
            let ring = run_collective(
                &spec(n, widths[0], ExecPolicy::nccl()),
                inputs.clone(),
                &allreduce_ring,
            )
            .unwrap();
            let hier = run_collective(
                &spec_tiers(n, widths, ExecPolicy::nccl()),
                inputs,
                &allreduce_hierarchical,
            )
            .unwrap();
            for r in 0..n {
                assert_eq!(
                    hier.outputs[r].as_real(),
                    ring.outputs[r].as_real(),
                    "n={n} widths={widths:?} rank {r}"
                );
            }
        }
    }

    #[test]
    fn all_ranks_identical_even_with_float_data() {
        // Leaders exchange symmetric pairwise sums and members take the
        // leader's bits: every output is bitwise identical, like flat
        // recursive doubling.
        let (n, g) = (12, 4);
        let report = run_collective(
            &spec(n, g, ExecPolicy::nccl()),
            real_inputs(n, 57, 9),
            &allreduce_hierarchical,
        )
        .unwrap();
        let first = report.outputs[0].as_real();
        for r in 1..n {
            assert_eq!(report.outputs[r].as_real(), first, "rank {r}");
        }
    }

    #[test]
    fn compressed_error_bounded_by_internode_stages() {
        // Only the internode leg compresses: the stacked error scales
        // with ⌈log₂ nodes⌉ (+2 for the non-pow2 fold/unfold), not with
        // the rank count.
        let eb = 1e-3f32;
        for (n, g, stages) in [(8usize, 4usize, 1usize), (12, 2, 5), (13, 4, 2)] {
            let inputs = real_inputs(n, 96, 5);
            let expect = exact_sum(&inputs);
            let report = run_collective(
                &spec(n, g, ExecPolicy::gzccl()).with_error_bound(eb as f64),
                inputs,
                &allreduce_hierarchical,
            )
            .unwrap();
            let tol = 3.0 * (stages as f32 + 1.0) * eb;
            for (r, out) in report.outputs.iter().enumerate() {
                for (i, (a, b)) in out.as_real().iter().zip(&expect).enumerate() {
                    assert!(
                        (a - b).abs() <= tol,
                        "n={n} g={g} rank {r} elem {i}: {a} vs {b} (tol {tol})"
                    );
                }
            }
        }
    }

    #[test]
    fn compression_stays_on_the_internode_leg() {
        // 16 ranks on 4 nodes: leaders run ⌈log₂4⌉ = 2 compressed
        // exchanges; members never touch the compressor.
        let n = 16;
        let g = 4;
        let inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(1 << 16)).collect();
        let report = run_collective(&spec(n, g, ExecPolicy::gzccl()), inputs, &allreduce_hierarchical)
            .unwrap();
        for r in 0..n {
            let c = &report.counters[r];
            if r % g == 0 {
                assert_eq!(c.compress_calls, 2, "leader {r}");
                assert_eq!(c.decompress_calls, 2, "leader {r}");
            } else {
                assert_eq!(c.compress_calls, 0, "member {r}");
                assert_eq!(c.decompress_calls, 0, "member {r}");
            }
        }
    }

    #[test]
    fn three_tier_cpr_counts_match_schedule_prediction() {
        let n = 32;
        let widths = [2usize, 4, 4];
        let tree = TierTree::new(n, &widths).unwrap();
        let sched = compile_min_error(Op::Allreduce, &tree, true).unwrap();
        let inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(1 << 14)).collect();
        let report = run_collective(
            &spec_tiers(n, &widths, ExecPolicy::gzccl()),
            inputs,
            &allreduce_hierarchical,
        )
        .unwrap();
        for r in 0..n {
            let (cpr, dec) = sched.cpr_stages_at(r);
            assert_eq!(report.counters[r].compress_calls, cpr, "rank {r} compress");
            assert_eq!(report.counters[r].decompress_calls, dec, "rank {r} decompress");
        }
    }

    #[test]
    fn single_node_and_single_gpu_degenerate() {
        // One node: reduce-to-leader + broadcast, no internode leg.
        let inputs = int_inputs(4, 16, 3);
        let expect = exact_sum(&inputs);
        let report =
            run_collective(&spec(4, 4, ExecPolicy::nccl()), inputs, &allreduce_hierarchical)
                .unwrap();
        for out in &report.outputs {
            assert_eq!(out.as_real(), &expect[..]);
        }
        // One GPU per node: pure recursive doubling over all ranks.
        let inputs = int_inputs(8, 16, 4);
        let expect = exact_sum(&inputs);
        let report =
            run_collective(&spec(8, 1, ExecPolicy::gzccl()), inputs, &allreduce_hierarchical)
                .unwrap();
        for out in &report.outputs {
            for (a, b) in out.as_real().iter().zip(&expect) {
                assert!((a - b).abs() <= 3.0 * 4.0 * 1e-4, "{a} vs {b}");
            }
        }
        // Single rank is the identity.
        let report = run_collective(
            &spec(1, 4, ExecPolicy::gzccl()),
            vec![DeviceBuf::Real(vec![1.0, 2.0])],
            &allreduce_hierarchical,
        )
        .unwrap();
        assert_eq!(report.outputs[0].as_real(), &[1.0, 2.0]);
    }

    #[test]
    fn beats_flat_redoub_at_multinode_scale_compressed() {
        // 32 ranks × 4 GPUs/node: flat gZ-ReDoub pays ⌈log₂32⌉ = 5
        // compressed internode exchanges; hierarchical pays ⌈log₂8⌉ = 3
        // plus µs-scale NVLink traffic.
        let n = 32;
        let d = (64 << 20) / 4;
        let mk = || -> Vec<DeviceBuf> { (0..n).map(|_| DeviceBuf::Virtual(d)).collect() };
        let redoub = run_collective(
            &spec(n, 4, ExecPolicy::gzccl()),
            mk(),
            &crate::collectives::allreduce_recursive_doubling,
        )
        .unwrap();
        let hier =
            run_collective(&spec(n, 4, ExecPolicy::gzccl()), mk(), &allreduce_hierarchical).unwrap();
        assert!(
            hier.makespan.as_secs() < redoub.makespan.as_secs(),
            "hier {} vs flat redoub {}",
            hier.makespan,
            redoub.makespan
        );
    }

    #[test]
    fn hierarchical_reduce_scatter_computes_chunked_sums() {
        for (n, widths) in [
            (8usize, &[4usize, 2][..]),
            (12, &[2, 3, 2][..]),
            (10, &[4, 3][..]),
        ] {
            let d = 97;
            let inputs = real_inputs(n, d, 11);
            let expect = exact_sum(&inputs);
            // Uncompressed: exact up to f32 reassociation (integer test
            // below is bitwise; here allow rounding noise).
            let report = run_collective(
                &spec_tiers(n, widths, ExecPolicy::nccl()),
                inputs.clone(),
                &reduce_scatter_hierarchical,
            )
            .unwrap();
            let chunks = Chunks::new(d, n);
            for r in 0..n {
                let got = report.outputs[r].as_real();
                let want = &expect[chunks.range(r)];
                assert_eq!(got.len(), want.len(), "rank {r} length");
                for (a, b) in got.iter().zip(want) {
                    assert!((a - b).abs() < 1e-4, "n={n} rank {r}: {a} vs {b}");
                }
            }
            // Compressed: error bounded by the schedule's amplification.
            let eb = 1e-3;
            let tree = TierTree::new(n, widths).unwrap();
            let amp = compile_min_error(Op::ReduceScatter, &tree, true)
                .unwrap()
                .amplification();
            let report = run_collective(
                &spec_tiers(n, widths, ExecPolicy::gzccl()).with_error_bound(eb),
                inputs,
                &reduce_scatter_hierarchical,
            )
            .unwrap();
            let tol = (amp as f32 + 1.0) * 1.5 * eb as f32;
            for r in 0..n {
                let got = report.outputs[r].as_real();
                let want = &expect[chunks.range(r)];
                for (a, b) in got.iter().zip(want) {
                    assert!(
                        (a - b).abs() <= tol,
                        "n={n} widths={widths:?} rank {r}: {a} vs {b} (tol {tol})"
                    );
                }
            }
        }
    }

    #[test]
    fn hierarchical_allgather_concatenates_in_rank_order() {
        for (n, widths) in [(8usize, &[4usize, 2][..]), (12, &[2, 3, 2][..])] {
            let d = 23;
            let inputs = real_inputs(n, d, 21);
            let expect: Vec<f32> = inputs.iter().flat_map(|b| b.as_real().to_vec()).collect();
            // Uncompressed: bitwise concatenation.
            let report = run_collective(
                &spec_tiers(n, widths, ExecPolicy::nccl()),
                inputs.clone(),
                &allgather_hierarchical,
            )
            .unwrap();
            for r in 0..n {
                assert_eq!(report.outputs[r].as_real(), &expect[..], "rank {r}");
            }
            // Compressed: forwarded streams pay one eb per crossed
            // tier.
            let eb = 1e-4;
            let tree = TierTree::new(n, widths).unwrap();
            let amp = compile_min_error(Op::Allgather, &tree, true)
                .unwrap()
                .amplification();
            let report = run_collective(
                &spec_tiers(n, widths, ExecPolicy::gzccl()).with_error_bound(eb),
                inputs,
                &allgather_hierarchical,
            )
            .unwrap();
            let tol = (amp as f32 + 1.0) * 1.5 * eb as f32;
            for r in 0..n {
                for (i, (a, b)) in report.outputs[r].as_real().iter().zip(&expect).enumerate() {
                    assert!(
                        (a - b).abs() <= tol,
                        "n={n} rank {r} elem {i}: {a} vs {b} (tol {tol})"
                    );
                }
            }
        }
    }

    #[test]
    fn tuned_three_tier_schedule_runs_and_matches_min_error_results() {
        // The cost-tuned legs (in-group doubling, ring tops) change the
        // timing, not the math: integer data stays bitwise equal to the
        // flat ring.
        let n = 24;
        let widths = [2usize, 3, 4];
        let tree = TierTree::new(n, &widths).unwrap();
        let sched = compile_tuned(
            Op::Allreduce,
            &tree,
            true,
            64 << 20,
            &crate::topo::CostModel::default_a100(),
        )
        .unwrap();
        let inputs = int_inputs(n, 41, 77);
        let ring = run_collective(
            &spec(n, 2, ExecPolicy::nccl()),
            inputs.clone(),
            &allreduce_ring,
        )
        .unwrap();
        let hier = run_collective(
            &spec_tiers(n, &widths, ExecPolicy::gzccl()),
            inputs,
            &SchedProg(sched.clone()),
        );
        // gzccl policy compresses → only check shape/consistency here;
        // run again uncompressed for the bitwise claim.
        assert!(hier.is_ok());
        let raw_sched = compile_tuned(
            Op::Allreduce,
            &tree,
            false,
            64 << 20,
            &crate::topo::CostModel::default_a100(),
        )
        .unwrap();
        let hier = run_collective(
            &spec_tiers(n, &widths, ExecPolicy::nccl()),
            int_inputs(n, 41, 77),
            &SchedProg(raw_sched),
        )
        .unwrap();
        for r in 0..n {
            assert_eq!(hier.outputs[r].as_real(), ring.outputs[r].as_real(), "rank {r}");
        }
    }
}
