//! Topology-aware two-level (hierarchical) Allreduce.
//!
//! The paper's testbed — and every GPU cluster it models — is two
//! networks glued together: NVLink-class links inside a node and a
//! shared Slingshot NIC between nodes. A flat schedule pays NIC latency
//! on hops that could ride NVLink; the hierarchical schedule never
//! does. Three phases:
//!
//! 1. **Intranode reduce** — every non-leader ships its vector to the
//!    node leader (lowest rank on the node) over NVLink, *raw*: at
//!    NVLink bandwidth, compression kernels cost more than they save,
//!    and keeping this leg lossless means the end-to-end error
//!    accounting is exactly that of the internode leg.
//! 2. **Internode Allreduce over leaders** — recursive doubling
//!    (gZ-ReDoub style) across one leader per node: `⌈log₂ nodes⌉`
//!    whole-vector exchanges, compressed once per step when the policy
//!    compresses. Non-power-of-two node counts use the MPICH remainder
//!    fold. This is the **only** leg that compresses, so the
//!    one-compression-per-hop error model holds with `nodes` in place
//!    of `ranks` — strictly fewer stages than flat gZ-ReDoub.
//! 3. **Intranode broadcast** — the leader forwards the finished vector
//!    to its node's members, raw over NVLink.
//!
//! Compared with the flat algorithms on an `N = M·g` cluster
//! (`M` nodes × `g` GPUs):
//!
//! * vs flat ring: `2⌈log₂M⌉` compression kernels instead of `2(N−1)`,
//!   `⌈log₂M⌉` internode rounds instead of `2(N−1)`.
//! * vs flat gZ-ReDoub: `log₂ g` fewer compression stages and internode
//!   exchanges, paid for with µs-scale NVLink traffic.
//!
//! Uncompressed, the schedule is exact: every rank of a node returns
//! the leader's bits, and leaders exchange symmetric pairwise sums, so
//! all N outputs are bitwise identical (like flat recursive doubling).

use crate::coordinator::{DeviceBuf, Payload, RankCtx};
use crate::error::Result;
use crate::gpu::StreamId;
use crate::sim::VirtTime;

/// Tag bases; offsets keep the three phases (and redoub rounds) from
/// colliding for any plausible rank count.
const TAG_HIER_UP: u64 = 0x4852_0000_0000; // + member rank
const TAG_HIER_X: u64 = 0x4852_1000_0000; // + redoub round
const TAG_HIER_FOLD: u64 = 0x4852_2000_0000;
const TAG_HIER_UNFOLD: u64 = 0x4852_3000_0000;
const TAG_HIER_DOWN: u64 = 0x4852_4000_0000; // + member rank

/// Two-level Allreduce. See the module docs for the schedule.
///
/// Works for any topology: a single-node communicator degenerates to
/// reduce-to-leader + broadcast, `gpus_per_node == 1` degenerates to
/// recursive doubling over all ranks, and partially-filled last nodes
/// are handled by the block-wise rank layout.
pub fn allreduce_hierarchical(ctx: &mut RankCtx, input: DeviceBuf) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let me = ctx.rank();
    if n == 1 {
        return Ok(input);
    }
    let topo = ctx.topology().clone();
    let node = topo.node_of(me);
    let leader = topo.leader_of(me);
    let members = topo.node_ranks(node);

    let stream = if ctx.policy().overlap {
        StreamId::NonDefault(0)
    } else {
        StreamId::Default
    };

    if me != leader {
        // Phase 1: ship the local vector to the node leader — raw, the
        // hop is NVLink. Then park until the leader's broadcast.
        let now = ctx.now();
        ctx.send(leader, TAG_HIER_UP + me as u64, Payload::Raw(input), now);
        let (out, _t) = ctx.recv_raw(leader, TAG_HIER_DOWN + me as u64);
        ctx.sync_device();
        return Ok(out);
    }

    // Phase 1 (leader): fold in every member's vector.
    let mut data = input;
    let mut data_t = ctx.now();
    for m in members.clone().skip(1) {
        let (theirs, t_in) = ctx.recv_raw(m, TAG_HIER_UP + m as u64);
        let (sum, t_sum) = ctx.reduce(stream, &data, &theirs, t_in.join(data_t))?;
        data = sum;
        data_t = t_sum;
    }

    // Phase 2: Allreduce across node leaders (the only compressed leg).
    if topo.nodes() > 1 {
        let (d, t) = leaders_recursive_doubling(ctx, stream, data, data_t, &topo)?;
        data = d;
        data_t = t;
    }

    // Phase 3: broadcast the finished vector to the node's members.
    for m in members.skip(1) {
        ctx.send(m, TAG_HIER_DOWN + m as u64, Payload::Raw(data.clone()), data_t);
    }
    ctx.sync_device();
    Ok(data)
}

/// Send the whole vector to `to`, compressed when the policy
/// compresses (one compression per internode exchange — Fig. 4).
fn send_whole(
    ctx: &mut RankCtx,
    stream: StreamId,
    to: usize,
    tag: u64,
    data: &DeviceBuf,
    data_t: VirtTime,
) {
    if ctx.compression_enabled() {
        // Async memset of the reused temp buffers, then compress on the
        // side stream (§3.3.4), exactly as flat gZ-ReDoub does.
        ctx.memset(stream, data.bytes(), data_t);
        let (c, t_c) = ctx.compress(stream, data, data_t);
        ctx.send(to, tag, Payload::Comp(c), t_c);
    } else {
        ctx.send(to, tag, Payload::Raw(data.clone()), data_t);
    }
}

/// Receive a whole vector from `from`, decompressing when compressed.
fn recv_whole(
    ctx: &mut RankCtx,
    stream: StreamId,
    from: usize,
    tag: u64,
) -> (DeviceBuf, VirtTime) {
    if ctx.compression_enabled() {
        let (c, t_in) = ctx.recv_comp(from, tag);
        ctx.decompress(stream, &c, t_in)
    } else {
        ctx.recv_raw(from, tag)
    }
}

/// Recursive-doubling Allreduce over the leader group (one rank per
/// node), MPICH remainder scheme for non-power-of-two node counts.
/// Only node leaders may call this.
fn leaders_recursive_doubling(
    ctx: &mut RankCtx,
    stream: StreamId,
    input: DeviceBuf,
    input_t: VirtTime,
    topo: &crate::net::Topology,
) -> Result<(DeviceBuf, VirtTime)> {
    let nodes = topo.nodes();
    let my_idx = topo.node_of(ctx.rank());
    debug_assert!(topo.is_leader(ctx.rank()));

    let pof2 = 1usize << (usize::BITS - 1 - nodes.leading_zeros()) as usize;
    let rem = nodes - pof2;

    let mut data = input;
    let mut data_t = input_t;

    // ---- Fold the remainder leaders in (even → odd pairs park). -----
    let newidx: isize;
    if my_idx < 2 * rem {
        if my_idx % 2 == 0 {
            let peer = topo.leader_of_node(my_idx + 1);
            send_whole(ctx, stream, peer, TAG_HIER_FOLD, &data, data_t);
            newidx = -1;
        } else {
            let peer = topo.leader_of_node(my_idx - 1);
            let (theirs, t_in) = recv_whole(ctx, stream, peer, TAG_HIER_FOLD);
            let (sum, t_sum) = ctx.reduce(stream, &data, &theirs, t_in.join(data_t))?;
            data = sum;
            data_t = t_sum;
            newidx = (my_idx / 2) as isize;
        }
    } else {
        newidx = (my_idx - rem) as isize;
    }

    // ---- Recursive doubling over pof2 leaders. ----------------------
    if newidx >= 0 {
        let nr = newidx as usize;
        let mut mask = 1usize;
        let mut round: u64 = 0;
        while mask < pof2 {
            let peer_nr = nr ^ mask;
            let peer_idx = if peer_nr < rem {
                peer_nr * 2 + 1
            } else {
                peer_nr + rem
            };
            let peer = topo.leader_of_node(peer_idx);
            send_whole(ctx, stream, peer, TAG_HIER_X + round, &data, data_t);
            let (theirs, t_in) = recv_whole(ctx, stream, peer, TAG_HIER_X + round);
            let (sum, t_sum) = ctx.reduce(stream, &data, &theirs, t_in.join(data_t))?;
            data = sum;
            data_t = t_sum;
            mask <<= 1;
            round += 1;
        }
    }

    // ---- Restore the parked remainder leaders. ----------------------
    if my_idx < 2 * rem {
        if my_idx % 2 == 1 {
            let peer = topo.leader_of_node(my_idx - 1);
            send_whole(ctx, stream, peer, TAG_HIER_UNFOLD, &data, data_t);
        } else {
            let peer = topo.leader_of_node(my_idx + 1);
            let (result, t_in) = recv_whole(ctx, stream, peer, TAG_HIER_UNFOLD);
            data = result;
            data_t = t_in;
        }
    }
    Ok((data, data_t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::allreduce_ring;
    use crate::coordinator::{run_collective, ClusterSpec, ExecPolicy};
    use crate::net::Topology;
    use crate::testkit::Pcg32;

    fn spec(n: usize, g: usize, policy: ExecPolicy) -> ClusterSpec {
        ClusterSpec::with_topology(Topology::new(n, g).unwrap(), policy)
    }

    /// Integer-valued inputs: f32 sums of small integers are exact, so
    /// schedules with different reduction orders agree bit-for-bit.
    fn int_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
        (0..n)
            .map(|r| {
                let mut rng = Pcg32::new(seed, r as u64);
                DeviceBuf::Real(
                    (0..d)
                        .map(|_| rng.range_usize(0, 17) as f32 - 8.0)
                        .collect(),
                )
            })
            .collect()
    }

    fn real_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
        (0..n)
            .map(|r| {
                let mut rng = Pcg32::new(seed, r as u64);
                DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
            })
            .collect()
    }

    fn exact_sum(inputs: &[DeviceBuf]) -> Vec<f32> {
        let d = inputs[0].elems();
        let mut out = vec![0.0f32; d];
        for b in inputs {
            for (o, v) in out.iter_mut().zip(b.as_real()) {
                *o += v;
            }
        }
        out
    }

    #[test]
    fn uncompressed_matches_flat_ring_bitwise() {
        // Integer data → exact sums → the two schedules must agree to
        // the bit, including partial last nodes (n=10, g=4).
        for (n, g) in [(8usize, 4usize), (10, 4), (6, 2), (7, 3), (4, 4), (5, 1)] {
            let inputs = int_inputs(n, 33, 42);
            let ring = run_collective(&spec(n, g, ExecPolicy::nccl()), inputs.clone(), &allreduce_ring)
                .unwrap();
            let hier =
                run_collective(&spec(n, g, ExecPolicy::nccl()), inputs, &allreduce_hierarchical)
                    .unwrap();
            for r in 0..n {
                assert_eq!(
                    hier.outputs[r].as_real(),
                    ring.outputs[r].as_real(),
                    "n={n} g={g} rank {r}"
                );
            }
        }
    }

    #[test]
    fn all_ranks_identical_even_with_float_data() {
        // Leaders exchange symmetric pairwise sums and members take the
        // leader's bits: every output is bitwise identical, like flat
        // recursive doubling.
        let (n, g) = (12, 4);
        let report = run_collective(
            &spec(n, g, ExecPolicy::nccl()),
            real_inputs(n, 57, 9),
            &allreduce_hierarchical,
        )
        .unwrap();
        let first = report.outputs[0].as_real();
        for r in 1..n {
            assert_eq!(report.outputs[r].as_real(), first, "rank {r}");
        }
    }

    #[test]
    fn compressed_error_bounded_by_internode_stages() {
        // Only the internode leg compresses: the stacked error scales
        // with ⌈log₂ nodes⌉ (+2 for the non-pow2 fold/unfold), not with
        // the rank count.
        let eb = 1e-3f32;
        for (n, g, stages) in [(8usize, 4usize, 1usize), (12, 2, 5), (13, 4, 2)] {
            let inputs = real_inputs(n, 96, 5);
            let expect = exact_sum(&inputs);
            let report = run_collective(
                &spec(n, g, ExecPolicy::gzccl()).with_error_bound(eb as f64),
                inputs,
                &allreduce_hierarchical,
            )
            .unwrap();
            let tol = 3.0 * (stages as f32 + 1.0) * eb;
            for (r, out) in report.outputs.iter().enumerate() {
                for (i, (a, b)) in out.as_real().iter().zip(&expect).enumerate() {
                    assert!(
                        (a - b).abs() <= tol,
                        "n={n} g={g} rank {r} elem {i}: {a} vs {b} (tol {tol})"
                    );
                }
            }
        }
    }

    #[test]
    fn compression_stays_on_the_internode_leg() {
        // 16 ranks on 4 nodes: leaders run ⌈log₂4⌉ = 2 compressed
        // exchanges; members never touch the compressor.
        let n = 16;
        let g = 4;
        let inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(1 << 16)).collect();
        let report = run_collective(&spec(n, g, ExecPolicy::gzccl()), inputs, &allreduce_hierarchical)
            .unwrap();
        for r in 0..n {
            let c = &report.counters[r];
            if r % g == 0 {
                assert_eq!(c.compress_calls, 2, "leader {r}");
                assert_eq!(c.decompress_calls, 2, "leader {r}");
            } else {
                assert_eq!(c.compress_calls, 0, "member {r}");
                assert_eq!(c.decompress_calls, 0, "member {r}");
            }
        }
    }

    #[test]
    fn single_node_and_single_gpu_degenerate() {
        // One node: reduce-to-leader + broadcast, no internode leg.
        let inputs = int_inputs(4, 16, 3);
        let expect = exact_sum(&inputs);
        let report =
            run_collective(&spec(4, 4, ExecPolicy::nccl()), inputs, &allreduce_hierarchical)
                .unwrap();
        for out in &report.outputs {
            assert_eq!(out.as_real(), &expect[..]);
        }
        // One GPU per node: pure recursive doubling over all ranks.
        let inputs = int_inputs(8, 16, 4);
        let expect = exact_sum(&inputs);
        let report =
            run_collective(&spec(8, 1, ExecPolicy::gzccl()), inputs, &allreduce_hierarchical)
                .unwrap();
        for out in &report.outputs {
            for (a, b) in out.as_real().iter().zip(&expect) {
                assert!((a - b).abs() <= 3.0 * 4.0 * 1e-4, "{a} vs {b}");
            }
        }
        // Single rank is the identity.
        let report = run_collective(
            &spec(1, 4, ExecPolicy::gzccl()),
            vec![DeviceBuf::Real(vec![1.0, 2.0])],
            &allreduce_hierarchical,
        )
        .unwrap();
        assert_eq!(report.outputs[0].as_real(), &[1.0, 2.0]);
    }

    #[test]
    fn beats_flat_redoub_at_multinode_scale_compressed() {
        // 32 ranks × 4 GPUs/node: flat gZ-ReDoub pays ⌈log₂32⌉ = 5
        // compressed internode exchanges; hierarchical pays ⌈log₂8⌉ = 3
        // plus µs-scale NVLink traffic.
        let n = 32;
        let d = (64 << 20) / 4;
        let mk = || -> Vec<DeviceBuf> { (0..n).map(|_| DeviceBuf::Virtual(d)).collect() };
        let redoub = run_collective(
            &spec(n, 4, ExecPolicy::gzccl()),
            mk(),
            &crate::collectives::allreduce_recursive_doubling,
        )
        .unwrap();
        let hier =
            run_collective(&spec(n, 4, ExecPolicy::gzccl()), mk(), &allreduce_hierarchical).unwrap();
        assert!(
            hier.makespan.as_secs() < redoub.makespan.as_secs(),
            "hier {} vs flat redoub {}",
            hier.makespan,
            redoub.makespan
        );
    }
}
