//! Hierarchical collectives: the executor for compiled
//! [`crate::topo::Schedule`]s.
//!
//! PR 2's two-level Allreduce hard-coded one leader tier; this module
//! generalizes it: the algorithm is now *data* — a sequence of
//! per-tier legs compiled by [`crate::topo::schedule`] from a
//! [`TierTree`] — and [`run_schedule`] interprets the legs against a
//! [`RankCtx`]. On a 2-tier tree with the min-error compile this is
//! exactly the PR 2 schedule (raw NVLink reduce to the node leader, a
//! compressed recursive-doubling exchange over one leader per node
//! with the MPICH remainder fold, raw broadcast back); deeper trees
//! add rack/pod tiers whose legs the tuner picks per tier, and the
//! same engine realizes hierarchical **Reduce_scatter** and
//! **Allgather**.
//!
//! Leg semantics (all groups advance the same leg sequence; a rank
//! engages a leg iff it leads its tier-`t−1` group):
//!
//! * **ReduceToLeader** — members ship whole vectors to the group
//!   leader, which folds them in rank order. Raw on tier 0 (NVLink),
//!   compressed above.
//! * **AllreduceRedoub / AllreduceRing** — in-group Allreduce over the
//!   participants: whole-vector recursive doubling (remainder fold for
//!   non-power-of-two counts) or the chunked ring, compressed once per
//!   exchange.
//! * **BcastFromLeader** — descent: raw legs fan out directly over
//!   NVLink; compressed legs forward one compress-once stream down a
//!   binomial tree (every consumer decompresses exactly once).
//! * **GatherToLeader / AllgatherRing** — the Allgather mirror:
//!   concatenate in rank order going up, ring the super-blocks across
//!   the top, broadcast the gathered vector down.
//! * **ScatterFromLeader** — the Reduce_scatter descent: the leader
//!   slices its vector by each participant's subtree chunk range and
//!   sends the share; after the tier-0 leg every rank holds chunk `r`
//!   of the `Chunks::new(total, nranks)` layout.
//!
//! Compression is confined to tiers ≥ 1, so the error accounting is
//! exactly what [`crate::topo::Schedule::amplification`] walks — the
//! schedule and its error model can never drift apart.
//!
//! **Per-leg bounds.** Execution is driven by an
//! [`crate::topo::ExecPlan`]: each leg carries its own
//! [`crate::topo::LegExec`] (compression mode + absolute error bound),
//! and [`run_plan`] interprets exactly that — entering a leg rebinds
//! the rank's compressor to the leg's bound
//! ([`RankCtx::begin_leg`]), so a budgeted dispatch whose per-tier
//! split assigns tier 1 and tier 2 different `eb`s genuinely runs
//! different compressors on them. [`run_schedule`] remains the
//! bare-schedule entry point: it derives the equivalent uniform plan
//! from the cluster's ambient policy and bound.

use crate::compress::CodecSpec;
use crate::coordinator::{CompBuf, CompressionMode, DeviceBuf, Payload, ProgFut, Program, RankCtx};
use crate::error::{Error, Result};
use crate::gpu::StreamId;
use crate::sim::VirtTime;
use crate::topo::{compile_min_error, ExecPlan, LegExec, LegKind, Schedule, TierTree};

use super::chunking::Chunks;
use super::Op;

/// Tag base; the leg index is encoded above bit 24, per-message
/// offsets (member index / round) below.
const TAG_SCHED: u64 = 0x544F_0000_0000;

fn tag(leg: usize, off: u64) -> u64 {
    TAG_SCHED + ((leg as u64) << 24) + off
}

/// Offsets keeping a leg's sub-exchanges apart (member indices occupy
/// the low range).
const OFF_REDOUB: u64 = 0x10_0000;
const OFF_FOLD: u64 = 0x20_0000;
const OFF_UNFOLD: u64 = 0x30_0000;
const OFF_RING_RS: u64 = 0x40_0000;
const OFF_RING_AG: u64 = 0x50_0000;

/// Send the whole vector to `to`, compressed when the leg compresses
/// (async memset of the reused temp buffers, then compress on the side
/// stream — §3.3.4, exactly as flat gZ-ReDoub does).
fn send_vec(
    ctx: &mut RankCtx,
    stream: StreamId,
    to: usize,
    tag: u64,
    data: &DeviceBuf,
    data_t: VirtTime,
    compressed: bool,
) {
    if compressed {
        ctx.memset(stream, data.bytes(), data_t);
        let (c, t_c) = ctx.compress(stream, data, data_t);
        ctx.send(to, tag, Payload::Comp(c), t_c);
    } else {
        ctx.send(to, tag, Payload::Raw(data.clone()), data_t);
    }
}

/// Receive a whole vector from `from`, decompressing when compressed.
async fn recv_vec(
    ctx: &mut RankCtx,
    stream: StreamId,
    from: usize,
    tag: u64,
    compressed: bool,
) -> (DeviceBuf, VirtTime) {
    if compressed {
        let (c, t_in) = ctx.recv_comp(from, tag).await;
        ctx.decompress(stream, &c, t_in)
    } else {
        ctx.recv_raw(from, tag).await
    }
}

/// [`Program`] adapter running an owned [`Schedule`] via
/// [`run_schedule`].
pub struct SchedProg(pub Schedule);

impl Program for SchedProg {
    fn run<'a>(&'a self, ctx: &'a mut RankCtx, input: DeviceBuf) -> ProgFut<'a> {
        Box::pin(async move { run_schedule(ctx, &self.0, input).await })
    }
}

/// [`Program`] adapter running an owned [`ExecPlan`] via [`run_plan`].
pub struct PlanProg(pub ExecPlan);

impl Program for PlanProg {
    fn run<'a>(&'a self, ctx: &'a mut RankCtx, input: DeviceBuf) -> ProgFut<'a> {
        Box::pin(async move { run_plan(ctx, &self.0, input).await })
    }
}

/// Execute a compiled [`ExecPlan`] (a hierarchical schedule whose legs
/// carry their own compression mode and error bound). Every rank of
/// the communicator must run the same plan over a same-length input
/// (the root-free ops: Allreduce, Reduce_scatter, Allgather).
pub async fn run_plan(ctx: &mut RankCtx, plan: &ExecPlan, input: DeviceBuf) -> Result<DeviceBuf> {
    let sched = plan.schedule.as_ref().ok_or_else(|| {
        Error::collective("run_plan needs a scheduled (hierarchical) execution plan")
    })?;
    if plan.legs.len() != sched.legs.len() {
        return Err(Error::collective(format!(
            "execution plan carries {} leg directives for a {}-leg schedule",
            plan.legs.len(),
            sched.legs.len()
        )));
    }
    run_legs(ctx, sched, &plan.legs, input).await
}

/// Execute a compiled hierarchical schedule at the cluster's ambient
/// policy and compressor bound — the bare-schedule entry point for
/// direct invocation; equivalent to [`run_plan`] over the uniform
/// [`ExecPlan`] of that schedule.
pub async fn run_schedule(ctx: &mut RankCtx, sched: &Schedule, input: DeviceBuf) -> Result<DeviceBuf> {
    let mode = ctx.policy().compression;
    let eb = ctx.compressor_error_bound().unwrap_or(0.0);
    // Tuned per-leg codecs are honored only when the ambient compressor
    // is the canonical error-bounded pipeline; an explicit ambient
    // codec choice wins over the tuner's.
    let ambient = ctx.compressor_spec();
    let honor_tuned = mode == CompressionMode::ErrorBounded
        && ambient.unwrap_or_else(CodecSpec::cuszp) == CodecSpec::cuszp();
    let legs: Vec<LegExec> = sched
        .legs
        .iter()
        .map(|l| {
            if l.compressed && mode != CompressionMode::None {
                match l.codec {
                    Some(c) if honor_tuned => LegExec::with_codec(c, eb),
                    _ => LegExec {
                        compression: mode,
                        codec: LegExec::default_codec(mode),
                        eb,
                    },
                }
            } else {
                LegExec::raw()
            }
        })
        .collect();
    run_legs(ctx, sched, &legs, input).await
}

/// The leg interpreter (see the module docs for per-leg semantics).
async fn run_legs(
    ctx: &mut RankCtx,
    sched: &Schedule,
    legs: &[LegExec],
    input: DeviceBuf,
) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let me = ctx.rank();
    if n <= 1 {
        return Ok(input);
    }
    let tree = &sched.tree;
    if tree.ranks() != n {
        return Err(Error::collective(format!(
            "schedule compiled for {} ranks dispatched on a {n}-rank communicator",
            tree.ranks()
        )));
    }
    let stream = if ctx.policy().overlap {
        StreamId::NonDefault(0)
    } else {
        StreamId::Default
    };

    // Element count of the *input* vector — the Reduce_scatter chunk
    // layout is over this (every rank contributes a same-length
    // vector).
    let total_elems = input.elems();
    let mut data = input;
    let mut data_t = ctx.now();
    // Global element offset of `data` during a scatter descent.
    let mut off = 0usize;

    for (li, leg) in sched.legs.iter().enumerate() {
        let t = leg.tier;
        if !tree.participates(t, me) {
            continue;
        }
        // Enter the leg: compress kernels below run at ITS bound and
        // record their observed error under its index.
        let lex = legs[li];
        let compressed = lex.compresses();
        ctx.begin_leg(li, lex);
        let group = tree.group_of(t, me);
        let ps = tree.group_participants(t, group);
        let k = ps.len();
        if k <= 1 {
            if leg.kind == LegKind::ScatterFromLeader {
                // Sole participant: nothing to exchange, but the
                // scatter descent still narrows the vector to this
                // subtree's chunk range.
                let pspan = tree.pspan(t);
                let chunks = Chunks::new(total_elems, n);
                let lo = chunks.start(me);
                let hi = chunks.start((me + pspan).min(n));
                data = data.slice(lo - off..hi - off);
                off = lo;
            }
            continue;
        }
        let my_idx = tree.relative_rank(t, me);
        match leg.kind {
            LegKind::ReduceToLeader => {
                if my_idx != 0 {
                    send_vec(ctx, stream, ps[0], tag(li, my_idx as u64), &data, data_t, compressed);
                    // `data` is stale until the mirrored descent leg.
                } else {
                    for (j, m) in ps.iter().enumerate().skip(1) {
                        let (theirs, t_in) =
                            recv_vec(ctx, stream, *m, tag(li, j as u64), compressed).await;
                        let (sum, t_sum) = ctx.reduce(stream, &data, &theirs, t_in.join(data_t))?;
                        data = sum;
                        data_t = t_sum;
                    }
                }
            }

            LegKind::GatherToLeader => {
                if my_idx != 0 {
                    send_vec(ctx, stream, ps[0], tag(li, my_idx as u64), &data, data_t, compressed);
                } else {
                    let mut parts = Vec::with_capacity(k);
                    let mut t_all = data_t;
                    parts.push(data.clone());
                    for (j, m) in ps.iter().enumerate().skip(1) {
                        let (theirs, t_in) =
                            recv_vec(ctx, stream, *m, tag(li, j as u64), compressed).await;
                        t_all = t_all.join(t_in);
                        parts.push(theirs);
                    }
                    data = DeviceBuf::concat(&parts)?;
                    data_t = t_all;
                }
            }

            LegKind::AllreduceRedoub => {
                // MPICH remainder scheme over the participant list —
                // the PR 2 leader exchange, generalized from "one
                // leader per node" to any tier's participants.
                let pof2 = 1usize << (usize::BITS - 1 - k.leading_zeros()) as usize;
                let rem = k - pof2;
                let newidx: isize;
                if my_idx < 2 * rem {
                    if my_idx % 2 == 0 {
                        send_vec(ctx, stream, ps[my_idx + 1], tag(li, OFF_FOLD), &data, data_t, compressed);
                        newidx = -1;
                    } else {
                        let (theirs, t_in) =
                            recv_vec(ctx, stream, ps[my_idx - 1], tag(li, OFF_FOLD), compressed)
                                .await;
                        let (sum, t_sum) = ctx.reduce(stream, &data, &theirs, t_in.join(data_t))?;
                        data = sum;
                        data_t = t_sum;
                        newidx = (my_idx / 2) as isize;
                    }
                } else {
                    newidx = (my_idx - rem) as isize;
                }
                if newidx >= 0 {
                    let nr = newidx as usize;
                    let mut mask = 1usize;
                    let mut round: u64 = 0;
                    while mask < pof2 {
                        let peer_nr = nr ^ mask;
                        let peer_idx = if peer_nr < rem {
                            peer_nr * 2 + 1
                        } else {
                            peer_nr + rem
                        };
                        let peer = ps[peer_idx];
                        send_vec(ctx, stream, peer, tag(li, OFF_REDOUB + round), &data, data_t, compressed);
                        let (theirs, t_in) =
                            recv_vec(ctx, stream, peer, tag(li, OFF_REDOUB + round), compressed)
                                .await;
                        let (sum, t_sum) = ctx.reduce(stream, &data, &theirs, t_in.join(data_t))?;
                        data = sum;
                        data_t = t_sum;
                        mask <<= 1;
                        round += 1;
                    }
                }
                if my_idx < 2 * rem {
                    if my_idx % 2 == 1 {
                        send_vec(ctx, stream, ps[my_idx - 1], tag(li, OFF_UNFOLD), &data, data_t, compressed);
                    } else {
                        let (result, t_in) =
                            recv_vec(ctx, stream, ps[my_idx + 1], tag(li, OFF_UNFOLD), compressed)
                                .await;
                        data = result;
                        data_t = t_in;
                    }
                }
            }

            LegKind::AllreduceRing => {
                let next = ps[(my_idx + 1) % k];
                let prev = ps[(my_idx + k - 1) % k];
                let chunks = Chunks::new(data.elems(), k);
                let mut acc: Vec<DeviceBuf> =
                    (0..k).map(|c| data.slice(chunks.range(c))).collect();
                let mut acc_t: Vec<VirtTime> = vec![data_t; k];
                // Reduce-scatter phase.
                for s in 1..k {
                    let send_idx = (my_idx + k - s) % k;
                    let recv_idx = (my_idx + k - s - 1) % k;
                    if compressed {
                        let (c, t_c) = ctx.compress(stream, &acc[send_idx], acc_t[send_idx]);
                        ctx.send(next, tag(li, OFF_RING_RS + s as u64), Payload::Comp(c), t_c);
                        let (cin, t_in) =
                            ctx.recv_comp(prev, tag(li, OFF_RING_RS + s as u64)).await;
                        let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
                        let (sum, t_sum) =
                            ctx.reduce(stream, &acc[recv_idx], &dec, t_dec.join(acc_t[recv_idx]))?;
                        acc[recv_idx] = sum;
                        acc_t[recv_idx] = t_sum;
                    } else {
                        ctx.send(
                            next,
                            tag(li, OFF_RING_RS + s as u64),
                            Payload::Raw(acc[send_idx].clone()),
                            acc_t[send_idx],
                        );
                        let (bin, t_in) =
                            ctx.recv_raw(prev, tag(li, OFF_RING_RS + s as u64)).await;
                        let (sum, t_sum) =
                            ctx.reduce(stream, &acc[recv_idx], &bin, t_in.join(acc_t[recv_idx]))?;
                        acc[recv_idx] = sum;
                        acc_t[recv_idx] = t_sum;
                    }
                }
                // Allgather phase: forward finished chunks verbatim.
                if compressed {
                    let (cmine, t0) = ctx.compress(stream, &acc[my_idx], acc_t[my_idx]);
                    let mut outgoing: CompBuf = cmine;
                    let mut out_t = t0;
                    for s in 1..k {
                        let recv_idx = (my_idx + k - s) % k;
                        ctx.send(
                            next,
                            tag(li, OFF_RING_AG + s as u64),
                            Payload::Comp(outgoing.clone()),
                            out_t,
                        );
                        let (cin, t_in) =
                            ctx.recv_comp(prev, tag(li, OFF_RING_AG + s as u64)).await;
                        let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
                        acc[recv_idx] = dec;
                        acc_t[recv_idx] = t_dec;
                        outgoing = cin;
                        out_t = t_in;
                    }
                } else {
                    let mut outgoing = acc[my_idx].clone();
                    let mut out_t = acc_t[my_idx];
                    for s in 1..k {
                        let recv_idx = (my_idx + k - s) % k;
                        ctx.send(
                            next,
                            tag(li, OFF_RING_AG + s as u64),
                            Payload::Raw(outgoing.clone()),
                            out_t,
                        );
                        let (bin, t_in) =
                            ctx.recv_raw(prev, tag(li, OFF_RING_AG + s as u64)).await;
                        acc[recv_idx] = bin.clone();
                        acc_t[recv_idx] = t_in;
                        outgoing = bin;
                        out_t = t_in;
                    }
                }
                data = DeviceBuf::concat(&acc)?;
                data_t = acc_t.iter().fold(VirtTime::ZERO, |a, b| a.join(*b));
            }

            LegKind::AllgatherRing => {
                let next = ps[(my_idx + 1) % k];
                let prev = ps[(my_idx + k - 1) % k];
                let mut blocks: Vec<Option<DeviceBuf>> = (0..k).map(|_| None).collect();
                let mut t_all = data_t;
                blocks[my_idx] = Some(data.clone());
                if compressed {
                    let (cmine, t0) = ctx.compress(stream, &data, data_t);
                    let mut outgoing: CompBuf = cmine;
                    let mut out_t = t0;
                    for s in 1..k {
                        let recv_idx = (my_idx + k - s) % k;
                        ctx.send(
                            next,
                            tag(li, OFF_RING_AG + s as u64),
                            Payload::Comp(outgoing.clone()),
                            out_t,
                        );
                        let (cin, t_in) =
                            ctx.recv_comp(prev, tag(li, OFF_RING_AG + s as u64)).await;
                        let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
                        t_all = t_all.join(t_dec);
                        blocks[recv_idx] = Some(dec);
                        outgoing = cin;
                        out_t = t_in;
                    }
                } else {
                    let mut outgoing = data.clone();
                    let mut out_t = data_t;
                    for s in 1..k {
                        let recv_idx = (my_idx + k - s) % k;
                        ctx.send(
                            next,
                            tag(li, OFF_RING_AG + s as u64),
                            Payload::Raw(outgoing.clone()),
                            out_t,
                        );
                        let (bin, t_in) =
                            ctx.recv_raw(prev, tag(li, OFF_RING_AG + s as u64)).await;
                        t_all = t_all.join(t_in);
                        blocks[recv_idx] = Some(bin.clone());
                        outgoing = bin;
                        out_t = t_in;
                    }
                }
                let parts: Vec<DeviceBuf> = blocks.into_iter().map(|b| b.unwrap()).collect();
                data = DeviceBuf::concat(&parts)?;
                data_t = t_all;
            }

            LegKind::BcastFromLeader => {
                if compressed {
                    // Compress-once stream forwarded down a binomial
                    // tree: every consumer decodes exactly once.
                    let mut held: Option<(CompBuf, VirtTime)> = None;
                    if my_idx == 0 {
                        ctx.memset(stream, data.bytes(), data_t);
                        let (c, t_c) = ctx.compress(stream, &data, data_t);
                        held = Some((c, t_c));
                    }
                    let mut mask = 1usize;
                    while mask < k {
                        if my_idx < mask {
                            if my_idx + mask < k {
                                let (c, t_c) = held.as_ref().expect("bcast sender holds the stream");
                                ctx.send(
                                    ps[my_idx + mask],
                                    tag(li, (my_idx + mask) as u64),
                                    Payload::Comp(c.clone()),
                                    *t_c,
                                );
                            }
                        } else if my_idx < 2 * mask {
                            let (c, t_in) =
                                ctx.recv_comp(ps[my_idx - mask], tag(li, my_idx as u64)).await;
                            held = Some((c, t_in));
                        }
                        mask <<= 1;
                    }
                    if my_idx != 0 {
                        let (c, t_in) = held.expect("bcast member received the stream");
                        let (d, t_d) = ctx.decompress(stream, &c, t_in);
                        data = d;
                        data_t = t_d;
                    }
                } else if my_idx == 0 {
                    // Raw NVLink fan-out, members in rank order.
                    for (j, m) in ps.iter().enumerate().skip(1) {
                        ctx.send(*m, tag(li, j as u64), Payload::Raw(data.clone()), data_t);
                    }
                } else {
                    let (d, t_in) = ctx.recv_raw(ps[0], tag(li, my_idx as u64)).await;
                    data = d;
                    data_t = t_in;
                }
            }

            LegKind::ScatterFromLeader => {
                let pspan = tree.pspan(t);
                let chunks = Chunks::new(total_elems, n);
                if my_idx == 0 {
                    for (j, m) in ps.iter().enumerate().skip(1) {
                        let lo = chunks.start(*m);
                        let hi = chunks.start((*m + pspan).min(n));
                        let slice = data.slice(lo - off..hi - off);
                        if compressed && slice.elems() > 0 {
                            let (c, t_c) = ctx.compress(stream, &slice, data_t);
                            ctx.send(*m, tag(li, j as u64), Payload::Comp(c), t_c);
                        } else {
                            ctx.send(*m, tag(li, j as u64), Payload::Raw(slice), data_t);
                        }
                    }
                    let lo = chunks.start(me);
                    let hi = chunks.start((me + pspan).min(n));
                    data = data.slice(lo - off..hi - off);
                    off = lo;
                } else {
                    let lo = chunks.start(me);
                    let hi = chunks.start((me + pspan).min(n));
                    let (d, t_in) = if compressed && hi > lo {
                        let (c, t_in) = ctx.recv_comp(ps[0], tag(li, my_idx as u64)).await;
                        ctx.decompress(stream, &c, t_in)
                    } else {
                        ctx.recv_raw(ps[0], tag(li, my_idx as u64)).await
                    };
                    data = d;
                    data_t = t_in;
                    off = lo;
                }
            }
        }
    }
    ctx.end_leg();
    ctx.sync_device();
    Ok(data)
}

/// Compile-and-run with the fewest-error schedule over the cluster's
/// own [`TierTree`] — the default entry point for direct invocation
/// (the [`crate::comm::Communicator`] passes cost-tuned schedules
/// through the registry instead).
async fn hierarchical_default(ctx: &mut RankCtx, op: Op, input: DeviceBuf) -> Result<DeviceBuf> {
    if ctx.nranks() <= 1 {
        return Ok(input);
    }
    let tree: TierTree = ctx.tiers().clone();
    let sched = compile_min_error(op, &tree, ctx.compression_enabled())?;
    run_schedule(ctx, &sched, input).await
}

/// Hierarchical Allreduce over the cluster's tier tree (the PR 2
/// two-level schedule on 2-tier layouts). See the module docs.
pub fn allreduce_hierarchical(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(hierarchical_default(ctx, Op::Allreduce, input))
}

/// Hierarchical Reduce_scatter: the Allreduce ascent and top exchange,
/// then a scatter descent; rank `r` returns the fully-reduced chunk
/// `r`. Compression stays on the tier-≥1 legs, so the worst-case error
/// follows the tree (`≈ 2^⌈log₂ groups⌉` at the top), not the `N−1`
/// linear stages of the ring — the compliant fallback tight accuracy
/// budgets need.
pub fn reduce_scatter_hierarchical(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(hierarchical_default(ctx, Op::ReduceScatter, input))
}

/// Hierarchical Allgather: concatenate blocks up the tree, ring the
/// super-blocks across the top tier, broadcast the gathered vector
/// down. Every origin block is compressed once per crossed tier
/// (compress-once forwarding), never recompressed into aggregates.
pub fn allgather_hierarchical(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(hierarchical_default(ctx, Op::Allgather, input))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::allreduce_ring;
    use crate::coordinator::{run_collective, ClusterSpec, ExecPolicy};
    use crate::net::Topology;
    use crate::testkit::Pcg32;
    use crate::topo::compile_tuned;

    fn spec(n: usize, g: usize, policy: ExecPolicy) -> ClusterSpec {
        ClusterSpec::with_topology(Topology::new(n, g).unwrap(), policy)
    }

    fn spec_tiers(n: usize, widths: &[usize], policy: ExecPolicy) -> ClusterSpec {
        ClusterSpec::with_tiers(TierTree::new(n, widths).unwrap(), policy)
    }

    /// Integer-valued inputs: f32 sums of small integers are exact, so
    /// schedules with different reduction orders agree bit-for-bit.
    fn int_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
        (0..n)
            .map(|r| {
                let mut rng = Pcg32::new(seed, r as u64);
                DeviceBuf::Real(
                    (0..d)
                        .map(|_| rng.range_usize(0, 17) as f32 - 8.0)
                        .collect(),
                )
            })
            .collect()
    }

    fn real_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
        (0..n)
            .map(|r| {
                let mut rng = Pcg32::new(seed, r as u64);
                DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
            })
            .collect()
    }

    fn exact_sum(inputs: &[DeviceBuf]) -> Vec<f32> {
        let d = inputs[0].elems();
        let mut out = vec![0.0f32; d];
        for b in inputs {
            for (o, v) in out.iter_mut().zip(b.as_real()) {
                *o += v;
            }
        }
        out
    }

    #[test]
    fn uncompressed_matches_flat_ring_bitwise() {
        // Integer data → exact sums → the two schedules must agree to
        // the bit, including partial last nodes (n=10, g=4).
        for (n, g) in [(8usize, 4usize), (10, 4), (6, 2), (7, 3), (4, 4), (5, 1)] {
            let inputs = int_inputs(n, 33, 42);
            let ring = run_collective(&spec(n, g, ExecPolicy::nccl()), inputs.clone(), &allreduce_ring)
                .unwrap();
            let hier =
                run_collective(&spec(n, g, ExecPolicy::nccl()), inputs, &allreduce_hierarchical)
                    .unwrap();
            for r in 0..n {
                assert_eq!(
                    hier.outputs[r].as_real(),
                    ring.outputs[r].as_real(),
                    "n={n} g={g} rank {r}"
                );
            }
        }
    }

    #[test]
    fn three_tier_matches_flat_ring_bitwise() {
        // Deep trees, partial groups, width-1 tiers: still exact.
        for (n, widths) in [
            (16usize, &[2usize, 2, 4][..]),
            (24, &[2, 3, 4][..]),
            (13, &[2, 2, 4][..]),
            (27, &[3, 3, 3][..]),
            (32, &[2, 2, 2, 4][..]),
        ] {
            let inputs = int_inputs(n, 29, 7);
            let ring = run_collective(
                &spec(n, widths[0], ExecPolicy::nccl()),
                inputs.clone(),
                &allreduce_ring,
            )
            .unwrap();
            let hier = run_collective(
                &spec_tiers(n, widths, ExecPolicy::nccl()),
                inputs,
                &allreduce_hierarchical,
            )
            .unwrap();
            for r in 0..n {
                assert_eq!(
                    hier.outputs[r].as_real(),
                    ring.outputs[r].as_real(),
                    "n={n} widths={widths:?} rank {r}"
                );
            }
        }
    }

    #[test]
    fn all_ranks_identical_even_with_float_data() {
        // Leaders exchange symmetric pairwise sums and members take the
        // leader's bits: every output is bitwise identical, like flat
        // recursive doubling.
        let (n, g) = (12, 4);
        let report = run_collective(
            &spec(n, g, ExecPolicy::nccl()),
            real_inputs(n, 57, 9),
            &allreduce_hierarchical,
        )
        .unwrap();
        let first = report.outputs[0].as_real();
        for r in 1..n {
            assert_eq!(report.outputs[r].as_real(), first, "rank {r}");
        }
    }

    #[test]
    fn compressed_error_bounded_by_internode_stages() {
        // Only the internode leg compresses: the stacked error scales
        // with ⌈log₂ nodes⌉ (+2 for the non-pow2 fold/unfold), not with
        // the rank count.
        let eb = 1e-3f32;
        for (n, g, stages) in [(8usize, 4usize, 1usize), (12, 2, 5), (13, 4, 2)] {
            let inputs = real_inputs(n, 96, 5);
            let expect = exact_sum(&inputs);
            let report = run_collective(
                &spec(n, g, ExecPolicy::gzccl()).with_error_bound(eb as f64),
                inputs,
                &allreduce_hierarchical,
            )
            .unwrap();
            let tol = 3.0 * (stages as f32 + 1.0) * eb;
            for (r, out) in report.outputs.iter().enumerate() {
                for (i, (a, b)) in out.as_real().iter().zip(&expect).enumerate() {
                    assert!(
                        (a - b).abs() <= tol,
                        "n={n} g={g} rank {r} elem {i}: {a} vs {b} (tol {tol})"
                    );
                }
            }
        }
    }

    #[test]
    fn compression_stays_on_the_internode_leg() {
        // 16 ranks on 4 nodes: leaders run ⌈log₂4⌉ = 2 compressed
        // exchanges; members never touch the compressor.
        let n = 16;
        let g = 4;
        let inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(1 << 16)).collect();
        let report = run_collective(&spec(n, g, ExecPolicy::gzccl()), inputs, &allreduce_hierarchical)
            .unwrap();
        for r in 0..n {
            let c = &report.counters[r];
            if r % g == 0 {
                assert_eq!(c.compress_calls, 2, "leader {r}");
                assert_eq!(c.decompress_calls, 2, "leader {r}");
            } else {
                assert_eq!(c.compress_calls, 0, "member {r}");
                assert_eq!(c.decompress_calls, 0, "member {r}");
            }
        }
    }

    #[test]
    fn three_tier_cpr_counts_match_schedule_prediction() {
        let n = 32;
        let widths = [2usize, 4, 4];
        let tree = TierTree::new(n, &widths).unwrap();
        let sched = compile_min_error(Op::Allreduce, &tree, true).unwrap();
        let inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(1 << 14)).collect();
        let report = run_collective(
            &spec_tiers(n, &widths, ExecPolicy::gzccl()),
            inputs,
            &allreduce_hierarchical,
        )
        .unwrap();
        for r in 0..n {
            let (cpr, dec) = sched.cpr_stages_at(r);
            assert_eq!(report.counters[r].compress_calls, cpr, "rank {r} compress");
            assert_eq!(report.counters[r].decompress_calls, dec, "rank {r} decompress");
        }
    }

    #[test]
    fn single_node_and_single_gpu_degenerate() {
        // One node: reduce-to-leader + broadcast, no internode leg.
        let inputs = int_inputs(4, 16, 3);
        let expect = exact_sum(&inputs);
        let report =
            run_collective(&spec(4, 4, ExecPolicy::nccl()), inputs, &allreduce_hierarchical)
                .unwrap();
        for out in &report.outputs {
            assert_eq!(out.as_real(), &expect[..]);
        }
        // One GPU per node: pure recursive doubling over all ranks.
        let inputs = int_inputs(8, 16, 4);
        let expect = exact_sum(&inputs);
        let report =
            run_collective(&spec(8, 1, ExecPolicy::gzccl()), inputs, &allreduce_hierarchical)
                .unwrap();
        for out in &report.outputs {
            for (a, b) in out.as_real().iter().zip(&expect) {
                assert!((a - b).abs() <= 3.0 * 4.0 * 1e-4, "{a} vs {b}");
            }
        }
        // Single rank is the identity.
        let report = run_collective(
            &spec(1, 4, ExecPolicy::gzccl()),
            vec![DeviceBuf::Real(vec![1.0, 2.0])],
            &allreduce_hierarchical,
        )
        .unwrap();
        assert_eq!(report.outputs[0].as_real(), &[1.0, 2.0]);
    }

    #[test]
    fn beats_flat_redoub_at_multinode_scale_compressed() {
        // 32 ranks × 4 GPUs/node: flat gZ-ReDoub pays ⌈log₂32⌉ = 5
        // compressed internode exchanges; hierarchical pays ⌈log₂8⌉ = 3
        // plus µs-scale NVLink traffic.
        let n = 32;
        let d = (64 << 20) / 4;
        let mk = || -> Vec<DeviceBuf> { (0..n).map(|_| DeviceBuf::Virtual(d)).collect() };
        let redoub = run_collective(
            &spec(n, 4, ExecPolicy::gzccl()),
            mk(),
            &crate::collectives::allreduce_recursive_doubling,
        )
        .unwrap();
        let hier =
            run_collective(&spec(n, 4, ExecPolicy::gzccl()), mk(), &allreduce_hierarchical).unwrap();
        assert!(
            hier.makespan.as_secs() < redoub.makespan.as_secs(),
            "hier {} vs flat redoub {}",
            hier.makespan,
            redoub.makespan
        );
    }

    #[test]
    fn hierarchical_reduce_scatter_computes_chunked_sums() {
        for (n, widths) in [
            (8usize, &[4usize, 2][..]),
            (12, &[2, 3, 2][..]),
            (10, &[4, 3][..]),
        ] {
            let d = 97;
            let inputs = real_inputs(n, d, 11);
            let expect = exact_sum(&inputs);
            // Uncompressed: exact up to f32 reassociation (integer test
            // below is bitwise; here allow rounding noise).
            let report = run_collective(
                &spec_tiers(n, widths, ExecPolicy::nccl()),
                inputs.clone(),
                &reduce_scatter_hierarchical,
            )
            .unwrap();
            let chunks = Chunks::new(d, n);
            for r in 0..n {
                let got = report.outputs[r].as_real();
                let want = &expect[chunks.range(r)];
                assert_eq!(got.len(), want.len(), "rank {r} length");
                for (a, b) in got.iter().zip(want) {
                    assert!((a - b).abs() < 1e-4, "n={n} rank {r}: {a} vs {b}");
                }
            }
            // Compressed: error bounded by the schedule's amplification.
            let eb = 1e-3;
            let tree = TierTree::new(n, widths).unwrap();
            let amp = compile_min_error(Op::ReduceScatter, &tree, true)
                .unwrap()
                .amplification();
            let report = run_collective(
                &spec_tiers(n, widths, ExecPolicy::gzccl()).with_error_bound(eb),
                inputs,
                &reduce_scatter_hierarchical,
            )
            .unwrap();
            let tol = (amp as f32 + 1.0) * 1.5 * eb as f32;
            for r in 0..n {
                let got = report.outputs[r].as_real();
                let want = &expect[chunks.range(r)];
                for (a, b) in got.iter().zip(want) {
                    assert!(
                        (a - b).abs() <= tol,
                        "n={n} widths={widths:?} rank {r}: {a} vs {b} (tol {tol})"
                    );
                }
            }
        }
    }

    #[test]
    fn hierarchical_allgather_concatenates_in_rank_order() {
        for (n, widths) in [(8usize, &[4usize, 2][..]), (12, &[2, 3, 2][..])] {
            let d = 23;
            let inputs = real_inputs(n, d, 21);
            let expect: Vec<f32> = inputs.iter().flat_map(|b| b.as_real().to_vec()).collect();
            // Uncompressed: bitwise concatenation.
            let report = run_collective(
                &spec_tiers(n, widths, ExecPolicy::nccl()),
                inputs.clone(),
                &allgather_hierarchical,
            )
            .unwrap();
            for r in 0..n {
                assert_eq!(report.outputs[r].as_real(), &expect[..], "rank {r}");
            }
            // Compressed: forwarded streams pay one eb per crossed
            // tier.
            let eb = 1e-4;
            let tree = TierTree::new(n, widths).unwrap();
            let amp = compile_min_error(Op::Allgather, &tree, true)
                .unwrap()
                .amplification();
            let report = run_collective(
                &spec_tiers(n, widths, ExecPolicy::gzccl()).with_error_bound(eb),
                inputs,
                &allgather_hierarchical,
            )
            .unwrap();
            let tol = (amp as f32 + 1.0) * 1.5 * eb as f32;
            for r in 0..n {
                for (i, (a, b)) in report.outputs[r].as_real().iter().zip(&expect).enumerate() {
                    assert!(
                        (a - b).abs() <= tol,
                        "n={n} rank {r} elem {i}: {a} vs {b} (tol {tol})"
                    );
                }
            }
        }
    }

    #[test]
    fn tuned_three_tier_schedule_runs_and_matches_min_error_results() {
        // The cost-tuned legs (in-group doubling, ring tops) change the
        // timing, not the math: integer data stays bitwise equal to the
        // flat ring.
        let n = 24;
        let widths = [2usize, 3, 4];
        let tree = TierTree::new(n, &widths).unwrap();
        let sched = compile_tuned(
            Op::Allreduce,
            &tree,
            true,
            64 << 20,
            &crate::topo::CostModel::default_a100(),
        )
        .unwrap();
        let inputs = int_inputs(n, 41, 77);
        let ring = run_collective(
            &spec(n, 2, ExecPolicy::nccl()),
            inputs.clone(),
            &allreduce_ring,
        )
        .unwrap();
        let hier = run_collective(
            &spec_tiers(n, &widths, ExecPolicy::gzccl()),
            inputs,
            &SchedProg(sched.clone()),
        );
        // gzccl policy compresses → only check shape/consistency here;
        // run again uncompressed for the bitwise claim.
        assert!(hier.is_ok());
        let raw_sched = compile_tuned(
            Op::Allreduce,
            &tree,
            false,
            64 << 20,
            &crate::topo::CostModel::default_a100(),
        )
        .unwrap();
        let hier = run_collective(
            &spec_tiers(n, &widths, ExecPolicy::nccl()),
            int_inputs(n, 41, 77),
            &SchedProg(raw_sched),
        )
        .unwrap();
        for r in 0..n {
            assert_eq!(hier.outputs[r].as_real(), ring.outputs[r].as_real(), "rank {r}");
        }
    }
}
