//! Collective algorithms.
//!
//! Each collective is a plain function over a [`RankCtx`]: the same code
//! runs baseline (uncompressed) and compression-enabled variants — the
//! [`crate::coordinator::ExecPolicy`] decides whether `compress`/
//! `decompress` are inserted and how they are scheduled.
//!
//! Algorithm inventory (paper §3.3.3):
//!
//! | Op             | Algorithms                                            |
//! |----------------|-------------------------------------------------------|
//! | Reduce_scatter | ring, hierarchical (multi-tier schedule)              |
//! | Allgather      | ring, Bruck, recursive doubling, hierarchical         |
//! | Allreduce      | ring (RS+AG), recursive doubling (gZ-ReDoub),         |
//! |                | hierarchical (multi-tier, topology-aware)             |
//! | Scatter        | binomial tree (gZ-Scatter multi-stream),              |
//! |                | hierarchical rooted descent — any root                |
//! | Bcast          | binomial tree, hierarchical compress-once descent —   |
//! |                | any root                                              |
//!
//! The hierarchical variants execute schedules compiled by
//! [`crate::topo::schedule`] from the cluster's
//! [`crate::topo::TierTree`] — see [`hierarchical`].

pub mod allgather;
pub mod allreduce;
pub mod bcast;
pub mod chunking;
pub mod hierarchical;
pub mod reduce_scatter;
pub mod scatter;

pub use allgather::{allgather_bruck, allgather_recursive_doubling, allgather_ring};
pub use allreduce::{allreduce_recursive_doubling, allreduce_reduce_bcast, allreduce_ring};
pub use bcast::{bcast_binomial, BcastProg};
pub use chunking::Chunks;
pub use hierarchical::{
    allgather_hierarchical, allreduce_hierarchical, reduce_scatter_hierarchical, run_plan,
    run_schedule, run_schedule_with, PlanProg, RootedDefaultProg, RootedProg, SchedProg,
    MAX_PIPELINE_DEPTH,
};
pub use reduce_scatter::reduce_scatter_ring;
pub use scatter::{scatter_binomial, ScatterProg};

/// Which collective operation (for dispatch and reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// Elementwise-sum Allreduce.
    Allreduce,
    /// Allgather.
    Allgather,
    /// Reduce_scatter.
    ReduceScatter,
    /// One-to-all Scatter.
    Scatter,
    /// One-to-all Broadcast.
    Bcast,
}

/// Which algorithm family realizes the operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Ring (bandwidth-optimal, N−1 steps).
    Ring,
    /// Recursive doubling (log N steps, whole-vector exchanges).
    RecursiveDoubling,
    /// Bruck (log N steps, shifting blocks).
    Bruck,
    /// Binomial tree (Scatter/Bcast).
    Binomial,
    /// Two-level topology-aware schedule: intranode NVLink legs around
    /// an internode collective over one leader per node, compression
    /// confined to the internode leg.
    Hierarchical,
    /// Degenerate no-op: what the tuner reports for single-rank
    /// communicators, where every collective is the identity.
    Identity,
}

/// Predicted compression-kernel invocations per rank — the complexity
/// table of §3.3.3, which the integration tests assert against actual
/// counter values. ([`crate::accuracy::cpr_stages`] unifies this
/// family behind one topology-resolved entry point, and
/// [`crate::accuracy::propagation`] builds the worst-case error model
/// on top of it.)
pub fn expected_cpr_stages(op: Op, algo: Algo, n: usize) -> Option<(usize, usize)> {
    if n <= 1 {
        return Some((0, 0));
    }
    let logn = usize::BITS as usize - (n - 1).leading_zeros() as usize;
    match (op, algo) {
        // (compressions, decompressions) per rank.
        (Op::ReduceScatter, Algo::Ring) => Some((n - 1, n - 1)),
        (Op::Allgather, Algo::Ring) => Some((1, n - 1)),
        // Ring Allreduce = RS + AG.
        (Op::Allreduce, Algo::Ring) => Some((n, 2 * (n - 1))),
        // Power-of-two ReDoub: log N compress + log N decompress.
        (Op::Allreduce, Algo::RecursiveDoubling) if n.is_power_of_two() => Some((logn, logn)),
        // Root-dependent: see expected_cpr_stages_at.
        (Op::Scatter, Algo::Binomial) | (Op::Bcast, Algo::Binomial) => None,
        // Topology-dependent: leaders compress ⌈log₂ nodes⌉ times,
        // members never — see expected_cpr_stages_hier.
        (Op::Allreduce, Algo::Hierarchical) => None,
        (_, Algo::Identity) => Some((0, 0)),
        _ => None,
    }
}

/// Rank-resolved variant of [`expected_cpr_stages`], covering the
/// root-dependent binomial-tree collectives of the gZCCL data-movement
/// framework (compress once at the root, forward compressed streams
/// verbatim, decompress once per consumer — §3.3.4):
///
/// * **Scatter**: the root compresses all N blocks (one multi-stream
///   batch of N kernels) and, like every rank, decompresses exactly its
///   own block; non-roots never compress.
/// * **Bcast**: the root compresses the whole vector once and keeps its
///   lossless copy (no decompression); every non-root decompresses the
///   forwarded stream once.
///
/// Rank-symmetric `(op, algo)` pairs fall through to
/// [`expected_cpr_stages`].
pub fn expected_cpr_stages_at(
    op: Op,
    algo: Algo,
    n: usize,
    rank: usize,
    root: usize,
) -> Option<(usize, usize)> {
    if n <= 1 {
        return Some((0, 0));
    }
    match (op, algo) {
        (Op::Scatter, Algo::Binomial) => Some(if rank == root { (n, 1) } else { (0, 1) }),
        (Op::Bcast, Algo::Binomial) => Some(if rank == root { (1, 0) } else { (0, 1) }),
        _ => expected_cpr_stages(op, algo, n),
    }
}

/// Per-rank compression-stage prediction for the two-level hierarchical
/// Allreduce over `nodes` nodes of `gpus_per_node` GPUs: only node
/// leaders compress, once per internode recursive-doubling exchange
/// (including the remainder fold/unfold for non-power-of-two node
/// counts); members ride raw NVLink legs.
pub fn expected_cpr_stages_hier(
    n: usize,
    gpus_per_node: usize,
    rank: usize,
) -> (usize, usize) {
    if n <= 1 || gpus_per_node == 0 {
        return (0, 0);
    }
    let nodes = n.div_ceil(gpus_per_node);
    if nodes <= 1 || rank % gpus_per_node != 0 {
        return (0, 0);
    }
    let pof2 = 1usize << (usize::BITS - 1 - nodes.leading_zeros()) as usize;
    let rem = nodes - pof2;
    let logp = pof2.trailing_zeros() as usize;
    let idx = rank / gpus_per_node;
    if idx < 2 * rem {
        if idx % 2 == 0 {
            // Parked remainder leader: one fold compress, one unfold
            // decompress.
            (1, 1)
        } else {
            // Carrying remainder leader: the fold adds a decompress,
            // the unfold adds a compress, around log₂(pof2) exchanges.
            (logp + 1, logp + 1)
        }
    } else {
        (logp, logp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpr_stage_table_matches_paper() {
        // §3.3.3: ring Allreduce needs N compressions and 2(N−1)
        // decompressions; ReDoub needs log N of each.
        assert_eq!(expected_cpr_stages(Op::Allreduce, Algo::Ring, 8), Some((8, 14)));
        assert_eq!(
            expected_cpr_stages(Op::Allreduce, Algo::RecursiveDoubling, 8),
            Some((3, 3))
        );
        assert_eq!(expected_cpr_stages(Op::Allgather, Algo::Ring, 64), Some((1, 63)));
        assert_eq!(
            expected_cpr_stages(Op::ReduceScatter, Algo::Ring, 64),
            Some((63, 63))
        );
        assert_eq!(expected_cpr_stages(Op::Allreduce, Algo::Ring, 1), Some((0, 0)));
    }

    #[test]
    fn root_dependent_stages_resolved_per_rank() {
        // Scatter: root compresses each of the N blocks once and
        // decompresses its own; non-roots only decompress their block.
        assert_eq!(expected_cpr_stages(Op::Scatter, Algo::Binomial, 8), None);
        assert_eq!(expected_cpr_stages_at(Op::Scatter, Algo::Binomial, 8, 0, 0), Some((8, 1)));
        assert_eq!(expected_cpr_stages_at(Op::Scatter, Algo::Binomial, 8, 5, 0), Some((0, 1)));
        // Bcast: one compression total (root), one decompression per
        // non-root; the root keeps its lossless copy.
        assert_eq!(expected_cpr_stages_at(Op::Bcast, Algo::Binomial, 8, 0, 0), Some((1, 0)));
        assert_eq!(expected_cpr_stages_at(Op::Bcast, Algo::Binomial, 8, 3, 0), Some((0, 1)));
        // Arbitrary roots shift the table with them.
        assert_eq!(expected_cpr_stages_at(Op::Scatter, Algo::Binomial, 8, 5, 5), Some((8, 1)));
        assert_eq!(expected_cpr_stages_at(Op::Bcast, Algo::Binomial, 8, 3, 3), Some((1, 0)));
        assert_eq!(expected_cpr_stages_at(Op::Bcast, Algo::Binomial, 8, 0, 3), Some((0, 1)));
        // Degenerate single-rank communicator never compresses.
        assert_eq!(expected_cpr_stages_at(Op::Scatter, Algo::Binomial, 1, 0, 0), Some((0, 0)));
        // Rank-symmetric ops fall through to the table.
        assert_eq!(
            expected_cpr_stages_at(Op::Allreduce, Algo::Ring, 8, 3, 0),
            expected_cpr_stages(Op::Allreduce, Algo::Ring, 8)
        );
    }

    #[test]
    fn hierarchical_stage_table() {
        // 16 ranks / 4 per node → 4 nodes: leaders run log₂4 = 2
        // compressed exchanges, members none.
        assert_eq!(expected_cpr_stages(Op::Allreduce, Algo::Hierarchical, 16), None);
        assert_eq!(expected_cpr_stages_hier(16, 4, 0), (2, 2));
        assert_eq!(expected_cpr_stages_hier(16, 4, 4), (2, 2));
        assert_eq!(expected_cpr_stages_hier(16, 4, 5), (0, 0));
        // Non-power-of-two node count (6 nodes): pof2 = 4, rem = 2.
        // Parked evens fold once; carrying odds pay one extra pair.
        assert_eq!(expected_cpr_stages_hier(12, 2, 0), (1, 1));
        assert_eq!(expected_cpr_stages_hier(12, 2, 2), (3, 3));
        assert_eq!(expected_cpr_stages_hier(12, 2, 8), (2, 2));
        // Single node or single rank: nothing compresses.
        assert_eq!(expected_cpr_stages_hier(4, 4, 0), (0, 0));
        assert_eq!(expected_cpr_stages_hier(1, 4, 0), (0, 0));
        // Identity is always a no-op.
        assert_eq!(expected_cpr_stages(Op::Allreduce, Algo::Identity, 8), Some((0, 0)));
    }
}
