//! Collective algorithms.
//!
//! Each collective is a plain function over a [`RankCtx`]: the same code
//! runs baseline (uncompressed) and compression-enabled variants — the
//! [`crate::coordinator::ExecPolicy`] decides whether `compress`/
//! `decompress` are inserted and how they are scheduled.
//!
//! Algorithm inventory (paper §3.3.3):
//!
//! | Op             | Algorithms                                   |
//! |----------------|----------------------------------------------|
//! | Reduce_scatter | ring                                         |
//! | Allgather      | ring, Bruck, recursive doubling              |
//! | Allreduce      | ring (RS+AG), recursive doubling (gZ-ReDoub) |
//! | Scatter        | binomial tree (gZ-Scatter multi-stream)      |
//! | Bcast          | binomial tree                                |

pub mod allgather;
pub mod allreduce;
pub mod bcast;
pub mod chunking;
pub mod reduce_scatter;
pub mod scatter;

pub use allgather::{allgather_bruck, allgather_recursive_doubling, allgather_ring};
pub use allreduce::{allreduce_recursive_doubling, allreduce_reduce_bcast, allreduce_ring};
pub use bcast::bcast_binomial;
pub use chunking::Chunks;
pub use reduce_scatter::reduce_scatter_ring;
pub use scatter::scatter_binomial;

/// Which collective operation (for dispatch and reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Elementwise-sum Allreduce.
    Allreduce,
    /// Allgather.
    Allgather,
    /// Reduce_scatter.
    ReduceScatter,
    /// One-to-all Scatter.
    Scatter,
    /// One-to-all Broadcast.
    Bcast,
}

/// Which algorithm family realizes the operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// Ring (bandwidth-optimal, N−1 steps).
    Ring,
    /// Recursive doubling (log N steps, whole-vector exchanges).
    RecursiveDoubling,
    /// Bruck (log N steps, shifting blocks).
    Bruck,
    /// Binomial tree (Scatter/Bcast).
    Binomial,
}

/// Predicted compression-kernel invocations per rank — the complexity
/// table of §3.3.3, which the integration tests assert against actual
/// counter values.
pub fn expected_cpr_stages(op: Op, algo: Algo, n: usize) -> Option<(usize, usize)> {
    if n <= 1 {
        return Some((0, 0));
    }
    let logn = usize::BITS as usize - (n - 1).leading_zeros() as usize;
    match (op, algo) {
        // (compressions, decompressions) per rank.
        (Op::ReduceScatter, Algo::Ring) => Some((n - 1, n - 1)),
        (Op::Allgather, Algo::Ring) => Some((1, n - 1)),
        // Ring Allreduce = RS + AG.
        (Op::Allreduce, Algo::Ring) => Some((n, 2 * (n - 1))),
        // Power-of-two ReDoub: log N compress + log N decompress.
        (Op::Allreduce, Algo::RecursiveDoubling) if n.is_power_of_two() => Some((logn, logn)),
        (Op::Scatter, Algo::Binomial) => None, // root-dependent; see tests
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpr_stage_table_matches_paper() {
        // §3.3.3: ring Allreduce needs N compressions and 2(N−1)
        // decompressions; ReDoub needs log N of each.
        assert_eq!(expected_cpr_stages(Op::Allreduce, Algo::Ring, 8), Some((8, 14)));
        assert_eq!(
            expected_cpr_stages(Op::Allreduce, Algo::RecursiveDoubling, 8),
            Some((3, 3))
        );
        assert_eq!(expected_cpr_stages(Op::Allgather, Algo::Ring, 64), Some((1, 63)));
        assert_eq!(
            expected_cpr_stages(Op::ReduceScatter, Algo::Ring, 64),
            Some((63, 63))
        );
        assert_eq!(expected_cpr_stages(Op::Allreduce, Algo::Ring, 1), Some((0, 0)));
    }
}
