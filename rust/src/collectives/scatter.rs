//! Binomial-tree Scatter — including gZ-Scatter (Fig. 5) — from any
//! root.
//!
//! The root holds N blocks; a binomial tree distributes them in log N
//! rounds (the subtree rooted at relative rank v with receive-mask m
//! covers blocks [v, v+m)). Arbitrary roots are handled by
//! **relative-rank rotation**: the tree is built over virtual ranks
//! `v = (rank − root) mod N`, with virtual block index v mapping to the
//! *actual* chunk `(v + root) mod N` — so rank r always ends up with
//! chunk r of the `Chunks::new(total, N)` layout, whatever the root.
//! Because a rotated block range wraps around the chunk layout, batch
//! offsets are derived from the actual chunk sizes (an even re-split of
//! the batch would misalign blocks whenever N ∤ total).
//!
//! gZ-Scatter (§3.3.4): the root compresses every block *individually*
//! (a whole-data compression could not be split: compressed streams
//! are not block-addressable and block sizes are data-dependent) with
//! the **multi-stream** kernel batch, synchronizes once to learn the
//! compressed sizes/offsets, packs the streams contiguously with async
//! device copies, and distributes. Intermediate ranks forward
//! compressed sub-ranges verbatim; each rank decompresses only its own
//! block on a non-default stream. Compression thus happens exactly once
//! per block, and every kernel is batched for utilization.
//!
//! The CPRP2P comparison path instead re-compresses on every tree hop
//! (fixed-rate), which is what makes it slow and error-stacking.

use crate::coordinator::{CompBuf, CompressionMode, DeviceBuf, Payload, ProgFut, Program, RankCtx};
use crate::error::{Error, Result};
use crate::gpu::StreamId;
use crate::sim::VirtTime;

use super::chunking::Chunks;

const TAG_SC: u64 = 0x5343_0000;
const TAG_SC_META: u64 = 0x5343_4D00;

/// Does this policy re-compress on every hop (CPRP2P) rather than
/// compress-once-at-root (gZCCL / C-Coll data-movement framework)?
fn per_hop_recompress(ctx: &RankCtx) -> bool {
    ctx.policy().compression == CompressionMode::FixedRate
}

/// [`Program`] adapter for [`scatter_binomial`]: scatter `total` total
/// elements from `root`.
pub struct ScatterProg {
    pub total: usize,
    pub root: usize,
}

impl Program for ScatterProg {
    fn run<'a>(&'a self, ctx: &'a mut RankCtx, input: DeviceBuf) -> ProgFut<'a> {
        Box::pin(async move { scatter_binomial(ctx, input, self.total, self.root).await })
    }
}

/// Binomial-tree Scatter from `root`. `input` is the full vector on the
/// root (ignored elsewhere); every rank returns its own block of the
/// `Chunks::new(total_elems, n)` layout.
pub async fn scatter_binomial(
    ctx: &mut RankCtx,
    input: DeviceBuf,
    total_elems: usize,
    root: usize,
) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let chunks = Chunks::new(total_elems, n);
    if n == 1 {
        return Ok(input);
    }
    if root >= n {
        // A real guard (not debug-only): the virtual-rank rotation
        // `rank + n - root` would wrap in release builds and hang or
        // panic the rank mesh.
        return Err(Error::collective(format!(
            "scatter root {root} out of range 0..{n}"
        )));
    }

    if ctx.compression_enabled() && !per_hop_recompress(ctx) {
        scatter_gz(ctx, input, chunks, root).await
    } else if ctx.compression_enabled() {
        scatter_cprp2p(ctx, input, chunks, root).await
    } else {
        scatter_raw(ctx, input, chunks, root).await
    }
}

/// Receive-phase bookkeeping: (receive mask, parent) for virtual rank
/// `me`; the (virtual) root gets (pof2 ≥ n, None). Shared with sibling
/// modules (bcast) and exported as `collectives::scatter::tree_position`.
pub fn tree_position(me: usize, n: usize) -> (usize, Option<usize>) {
    if me == 0 {
        let mut m = 1;
        while m < n {
            m <<= 1;
        }
        (m, None)
    } else {
        let mask = 1usize << me.trailing_zeros();
        (mask, Some(me - mask))
    }
}

/// The subtree block range [me, me+mask) clipped to n (virtual space).
fn subtree(me: usize, mask: usize, n: usize) -> std::ops::Range<usize> {
    me..(me + mask).min(n)
}

// ---------------------------------------------------------------------
// Uncompressed baseline (NCCL-class raw tree / Cray MPI CPU-centric).
// ---------------------------------------------------------------------
async fn scatter_raw(
    ctx: &mut RankCtx,
    input: DeviceBuf,
    chunks: Chunks,
    root: usize,
) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let me = ctx.rank();
    let vr = (me + n - root) % n;
    let actual = |v: usize| (v + root) % n;
    let (mask, vparent) = tree_position(vr, n);

    // Blocks this rank holds, indexed by VIRTUAL block index; virtual
    // block v is the actual chunk `actual(v)`.
    let (mut held, held_t): (Vec<Option<DeviceBuf>>, VirtTime) = if vr == 0 {
        (
            (0..n)
                .map(|v| Some(input.slice(chunks.range(actual(v)))))
                .collect(),
            ctx.now(),
        )
    } else {
        let parent = actual(vparent.unwrap());
        let (batch, t) = ctx.recv_raw(parent, TAG_SC + vr as u64).await;
        let mut held: Vec<Option<DeviceBuf>> = (0..n).map(|_| None).collect();
        let range = subtree(vr, mask, n);
        // The batch packs the subtree's blocks in virtual order with
        // their ACTUAL chunk sizes (a rotated range wraps the layout,
        // so an even re-split would misalign).
        let mut off = 0;
        for v in range {
            let len = chunks.len(actual(v));
            held[v] = Some(batch.slice(off..off + len));
            off += len;
        }
        (held, t)
    };

    // Send phase: halve the subtree (virtual space).
    let mut m = mask >> 1;
    while m > 0 {
        let dst_v = vr + m;
        if dst_v < n {
            let range = subtree(dst_v, m, n);
            let parts: Vec<DeviceBuf> = range
                .map(|v| held[v].take().expect("missing block to forward"))
                .collect();
            let batch = DeviceBuf::concat(&parts)?;
            ctx.send(actual(dst_v), TAG_SC + dst_v as u64, Payload::Raw(batch), held_t);
        }
        m >>= 1;
    }
    Ok(held[vr].take().expect("own block missing"))
}

// ---------------------------------------------------------------------
// gZ-Scatter (Fig. 5): multi-stream compress at root, pack, forward
// compressed, decompress own block only.
// ---------------------------------------------------------------------
async fn scatter_gz(
    ctx: &mut RankCtx,
    input: DeviceBuf,
    chunks: Chunks,
    root: usize,
) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let me = ctx.rank();
    let vr = (me + n - root) % n;
    let actual = |v: usize| (v + root) % n;
    let (mask, vparent) = tree_position(vr, n);
    let dstream = StreamId::NonDefault(0);

    let mut held: Vec<Option<CompBuf>> = (0..n).map(|_| None).collect();
    let held_t;

    if vr == 0 {
        // Multi-stream compression of all blocks (one batch), packed in
        // virtual order.
        let blocks: Vec<DeviceBuf> = (0..n)
            .map(|v| input.slice(chunks.range(actual(v))))
            .collect();
        let now = ctx.now();
        let (comp, t_c) = ctx.compress_multistream(&blocks, now);
        // Host-synchronize to learn the compressed sizes/offsets.
        ctx.sync_device();
        // Share the size table with the tree (small Meta message ahead
        // of each data send).
        let sizes: Vec<u64> = comp.iter().map(|c| c.bytes() as u64).collect();
        // Pack the per-stream outputs contiguously (async memcpys).
        let (_total, t_pack) = ctx.pack_d2d(&comp, t_c);
        for (v, c) in comp.into_iter().enumerate() {
            held[v] = Some(c);
        }
        held_t = t_pack;
        // Kick off metadata sends to direct children.
        let mut m = mask >> 1;
        while m > 0 {
            let dst_v = m; // the root's children sit at virtual ranks m
            if dst_v < n {
                ctx.send(
                    actual(dst_v),
                    TAG_SC_META + dst_v as u64,
                    Payload::Meta(sizes.clone()),
                    ctx.now(),
                );
            }
            m >>= 1;
        }
    } else {
        // Sizes first (needed to address the packed batch), then data.
        let parent = actual(vparent.unwrap());
        let (sizes, _tm) = ctx.recv_meta(parent, TAG_SC_META + vr as u64).await;
        let (batch, t) = ctx.recv_batch(parent, TAG_SC + vr as u64).await;
        let range = subtree(vr, mask, n);
        for (slot, v) in range.enumerate() {
            held[v] = Some(batch[slot].clone());
        }
        held_t = t;
        // Forward the size table to children.
        let mut m = mask >> 1;
        while m > 0 {
            let dst_v = vr + m;
            if dst_v < n {
                ctx.send(
                    actual(dst_v),
                    TAG_SC_META + dst_v as u64,
                    Payload::Meta(sizes.clone()),
                    ctx.now(),
                );
            }
            m >>= 1;
        }
    }

    // Send compressed sub-ranges down the tree (forward verbatim).
    let mut m = mask >> 1;
    while m > 0 {
        let dst_v = vr + m;
        if dst_v < n {
            let range = subtree(dst_v, m, n);
            let parts: Vec<CompBuf> = range
                .map(|v| held[v].take().expect("missing compressed block"))
                .collect();
            ctx.send(actual(dst_v), TAG_SC + dst_v as u64, Payload::Batch(parts), held_t);
        }
        m >>= 1;
    }

    // Decompress only our own block, on the non-default stream.
    let mine = held[vr].take().expect("own compressed block missing");
    let (out, _t) = ctx.decompress(dstream, &mine, held_t);
    ctx.sync_device();
    Ok(out)
}

// ---------------------------------------------------------------------
// CPRP2P: fixed-rate compression bolted onto every hop — decompress the
// whole received range, re-compress every forwarded range.
// ---------------------------------------------------------------------
async fn scatter_cprp2p(
    ctx: &mut RankCtx,
    input: DeviceBuf,
    chunks: Chunks,
    root: usize,
) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let me = ctx.rank();
    let vr = (me + n - root) % n;
    let actual = |v: usize| (v + root) % n;
    let (mask, vparent) = tree_position(vr, n);
    let stream = StreamId::Default;

    let mut held: Vec<Option<DeviceBuf>> = (0..n).map(|_| None).collect();
    let mut held_t = ctx.now();

    if vr == 0 {
        for v in 0..n {
            held[v] = Some(input.slice(chunks.range(actual(v))));
        }
    } else {
        let parent = actual(vparent.unwrap());
        let (cin, t_in) = ctx.recv_comp(parent, TAG_SC + vr as u64).await;
        // Decompress the whole range before anything can be forwarded.
        let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
        let range = subtree(vr, mask, n);
        // Actual chunk sizes, in virtual order — see scatter_raw.
        let mut off = 0;
        for v in range {
            let len = chunks.len(actual(v));
            held[v] = Some(dec.slice(off..off + len));
            off += len;
        }
        held_t = t_dec;
    }

    let mut m = mask >> 1;
    while m > 0 {
        let dst_v = vr + m;
        if dst_v < n {
            let range = subtree(dst_v, m, n);
            let parts: Vec<DeviceBuf> = range
                .map(|v| held[v].take().expect("missing block"))
                .collect();
            let batch = DeviceBuf::concat(&parts)?;
            // Re-compress this hop's payload (the CPRP2P tax).
            let (c, t_c) = ctx.compress(stream, &batch, held_t);
            ctx.send(actual(dst_v), TAG_SC + dst_v as u64, Payload::Comp(c), t_c);
        }
        m >>= 1;
    }
    Ok(held[vr].take().expect("own block missing"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_collective, ClusterSpec, ExecPolicy};
    use crate::testkit::Pcg32;

    fn scatter_inputs(n: usize, d: usize, root: usize) -> (Vec<DeviceBuf>, Vec<f32>) {
        let mut rng = Pcg32::seeded(31);
        let full = rng.uniform_vec(d, -1.0, 1.0);
        let inputs = (0..n)
            .map(|r| {
                if r == root {
                    DeviceBuf::Real(full.clone())
                } else {
                    DeviceBuf::Real(vec![])
                }
            })
            .collect();
        (inputs, full)
    }

    fn check_scatter_rooted(n: usize, d: usize, policy: ExecPolicy, tol: f32, root: usize) {
        let (inputs, full) = scatter_inputs(n, d, root);
        let report = run_collective(
            &ClusterSpec::new(n, policy),
            inputs,
            &ScatterProg { total: d, root },
        )
        .unwrap();
        let chunks = Chunks::new(d, n);
        for r in 0..n {
            let got = report.outputs[r].as_real();
            let want = &full[chunks.range(r)];
            assert_eq!(got.len(), want.len(), "root {root} rank {r} block size");
            for (i, (a, b)) in got.iter().zip(want).enumerate() {
                assert!(
                    (a - b).abs() <= tol,
                    "root {root} rank {r} elem {i}: {a} vs {b}"
                );
            }
        }
    }

    fn check_scatter(n: usize, d: usize, policy: ExecPolicy, tol: f32) {
        check_scatter_rooted(n, d, policy, tol, 0);
    }

    #[test]
    fn raw_scatter_exact_various_n() {
        for n in [2usize, 3, 4, 7, 8, 16] {
            check_scatter(n, 256, ExecPolicy::nccl(), 0.0);
        }
    }

    #[test]
    fn raw_scatter_exact_every_root() {
        // Every root of a non-power-of-two communicator with N ∤ D:
        // rotated block ranges wrap the layout and sizes differ by one.
        for n in [5usize, 8] {
            for root in 0..n {
                check_scatter_rooted(n, 253, ExecPolicy::nccl(), 0.0, root);
            }
        }
    }

    #[test]
    fn gz_scatter_exact_every_root() {
        for root in [0usize, 1, 4, 6] {
            check_scatter_rooted(7, 311, ExecPolicy::gzccl(), 1.1e-4, root);
        }
    }

    #[test]
    fn cprp2p_scatter_every_root() {
        for root in [0usize, 3, 7] {
            check_scatter_rooted(8, 256, ExecPolicy::cprp2p(), 0.1, root);
        }
    }

    #[test]
    fn cray_cpu_centric_scatter_exact() {
        check_scatter(8, 128, ExecPolicy::cray_mpi(), 0.0);
    }

    #[test]
    fn gz_scatter_single_eb_error() {
        // Compress-once-at-root: each block sees exactly one
        // compression regardless of tree depth.
        for n in [4usize, 8, 13] {
            check_scatter(n, 512, ExecPolicy::gzccl(), 1.1e-4);
        }
    }

    #[test]
    fn cprp2p_scatter_error_grows_with_depth() {
        // Values in [-1,1]: fixed-rate with 8 bits gives per-hop error
        // ~1/127 · blockmax; depth log2(8)=3 hops stack.
        check_scatter(8, 256, ExecPolicy::cprp2p(), 0.1);
    }

    #[test]
    fn gz_scatter_compress_counts_any_root() {
        let n = 8;
        let d = 1 << 16;
        for root in [0usize, 5] {
            let inputs: Vec<DeviceBuf> = (0..n)
                .map(|r| DeviceBuf::Virtual(if r == root { d } else { 0 }))
                .collect();
            let report = run_collective(
                &ClusterSpec::new(n, ExecPolicy::gzccl()),
                inputs,
                &ScatterProg { total: d, root },
            )
            .unwrap();
            // The root compresses each block exactly once (as one
            // multi-stream batch of N kernels); everyone decompresses
            // exactly one block.
            assert_eq!(report.counters[root].compress_calls, n);
            for (r, c) in report.counters.iter().enumerate() {
                if r != root {
                    assert_eq!(c.compress_calls, 0, "non-root must not compress");
                }
                assert_eq!(c.decompress_calls, 1, "rank {r} decompresses own block");
            }
        }
    }

    #[test]
    fn out_of_range_root_is_error() {
        let (inputs, _) = scatter_inputs(4, 64, 0);
        let res = run_collective(
            &ClusterSpec::new(4, ExecPolicy::nccl()),
            inputs,
            &ScatterProg { total: 64, root: 7 },
        );
        assert!(res.is_err());
    }

    #[test]
    fn cprp2p_recompresses_along_the_tree() {
        let n = 8;
        let d = 1 << 16;
        let mut inputs = vec![DeviceBuf::Virtual(d)];
        for _ in 1..n {
            inputs.push(DeviceBuf::Virtual(0));
        }
        let report = run_collective(
            &ClusterSpec::new(n, ExecPolicy::cprp2p()),
            inputs,
            &ScatterProg { total: d, root: 0 },
        )
        .unwrap();
        let total_cpr: usize = report.counters.iter().map(|c| c.compress_calls).sum();
        // Every edge of the binomial tree compresses: n−1 edges.
        assert_eq!(total_cpr, n - 1);
        // Intermediate ranks decompress ranges they merely forward.
        let total_dec: usize = report.counters.iter().map(|c| c.decompress_calls).sum();
        assert_eq!(total_dec, n - 1);
    }

    #[test]
    fn gz_scatter_faster_than_cprp2p() {
        let n = 16;
        let d = (64 << 20) / 4;
        let mk = |_n: usize| -> Vec<DeviceBuf> {
            let mut v = vec![DeviceBuf::Virtual(d)];
            for _ in 1..n {
                v.push(DeviceBuf::Virtual(0));
            }
            v
        };
        let gz = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            mk(n),
            &ScatterProg { total: d, root: 0 },
        )
        .unwrap();
        let cpr = run_collective(
            &ClusterSpec::new(n, ExecPolicy::cprp2p()),
            mk(n),
            &ScatterProg { total: d, root: 0 },
        )
        .unwrap();
        assert!(
            gz.makespan.as_secs() < cpr.makespan.as_secs(),
            "gz {} vs cprp2p {}",
            gz.makespan,
            cpr.makespan
        );
    }
}
