//! Binomial-tree Scatter — including gZ-Scatter (Fig. 5).
//!
//! The root holds N blocks; a binomial tree distributes them in log N
//! rounds (the subtree rooted at relative rank v with receive-mask m
//! covers blocks [v, v+m)).
//!
//! gZ-Scatter (§3.3.4): the root compresses every block *individually*
//! (a whole-data compression could not be split: compressed streams
//! are not block-addressable and block sizes are data-dependent) with
//! the **multi-stream** kernel batch, synchronizes once to learn the
//! compressed sizes/offsets, packs the streams contiguously with async
//! device copies, and distributes. Intermediate ranks forward
//! compressed sub-ranges verbatim; each rank decompresses only its own
//! block on a non-default stream. Compression thus happens exactly once
//! per block, and every kernel is batched for utilization.
//!
//! The CPRP2P comparison path instead re-compresses on every tree hop
//! (fixed-rate), which is what makes it slow and error-stacking.

use crate::coordinator::{CompBuf, CompressionMode, DeviceBuf, Payload, RankCtx};
use crate::error::Result;
use crate::gpu::StreamId;
use crate::sim::VirtTime;

use super::chunking::Chunks;

const TAG_SC: u64 = 0x5343_0000;
const TAG_SC_META: u64 = 0x5343_4D00;

/// Does this policy re-compress on every hop (CPRP2P) rather than
/// compress-once-at-root (gZCCL / C-Coll data-movement framework)?
fn per_hop_recompress(ctx: &RankCtx) -> bool {
    ctx.policy().compression == CompressionMode::FixedRate
}

/// Binomial-tree Scatter from root 0. `input` is the full vector on the
/// root (ignored elsewhere); every rank returns its own block of the
/// `Chunks::new(total_elems, n)` layout.
pub fn scatter_binomial(
    ctx: &mut RankCtx,
    input: DeviceBuf,
    total_elems: usize,
) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let _me = ctx.rank();
    let chunks = Chunks::new(total_elems, n);
    if n == 1 {
        return Ok(input);
    }

    if ctx.compression_enabled() && !per_hop_recompress(ctx) {
        scatter_gz(ctx, input, chunks)
    } else if ctx.compression_enabled() {
        scatter_cprp2p(ctx, input, chunks)
    } else {
        scatter_raw(ctx, input, chunks)
    }
}

/// Receive-phase bookkeeping: (receive mask, parent) for `me`; the root
/// gets (pof2 ≥ n, None). Shared with sibling modules (bcast) and
/// exported as `collectives::scatter::tree_position`.
pub fn tree_position(me: usize, n: usize) -> (usize, Option<usize>) {
    if me == 0 {
        let mut m = 1;
        while m < n {
            m <<= 1;
        }
        (m, None)
    } else {
        let mask = 1usize << me.trailing_zeros();
        (mask, Some(me - mask))
    }
}

/// The subtree block range [me, me+mask) clipped to n.
fn subtree(me: usize, mask: usize, n: usize) -> std::ops::Range<usize> {
    me..(me + mask).min(n)
}

// ---------------------------------------------------------------------
// Uncompressed baseline (NCCL-class raw tree / Cray MPI CPU-centric).
// ---------------------------------------------------------------------
fn scatter_raw(ctx: &mut RankCtx, input: DeviceBuf, chunks: Chunks) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let me = ctx.rank();
    let (mask, parent) = tree_position(me, n);

    // Blocks this rank holds (index range within [0, n)).
    let (mut held, mut held_t): (Vec<Option<DeviceBuf>>, VirtTime) = if me == 0 {
        (
            (0..n).map(|i| Some(input.slice(chunks.range(i)))).collect(),
            ctx.now(),
        )
    } else {
        let (batch, t) = ctx.recv_raw(parent.unwrap(), TAG_SC + me as u64);
        let mut held: Vec<Option<DeviceBuf>> = (0..n).map(|_| None).collect();
        let range = subtree(me, mask, n);
        let layout = Chunks::new(batch.elems(), range.len());
        for (slot, i) in range.clone().enumerate() {
            held[i] = Some(batch.slice(layout.range(slot)));
        }
        (held, t)
    };

    // Send phase: halve the subtree.
    let mut m = mask >> 1;
    while m > 0 {
        let dst = me + m;
        if dst < n {
            let range = subtree(dst, m, n);
            let parts: Vec<DeviceBuf> = range
                .clone()
                .map(|i| held[i].take().expect("missing block to forward"))
                .collect();
            let batch = DeviceBuf::concat(&parts);
            ctx.send(dst, TAG_SC + dst as u64, Payload::Raw(batch), held_t);
        }
        m >>= 1;
    }
    held_t = held_t.join(ctx.now());
    let _ = held_t;
    Ok(held[me].take().expect("own block missing"))
}

// ---------------------------------------------------------------------
// gZ-Scatter (Fig. 5): multi-stream compress at root, pack, forward
// compressed, decompress own block only.
// ---------------------------------------------------------------------
fn scatter_gz(ctx: &mut RankCtx, input: DeviceBuf, chunks: Chunks) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let me = ctx.rank();
    let (mask, parent) = tree_position(me, n);
    let dstream = StreamId::NonDefault(0);

    let mut held: Vec<Option<CompBuf>> = (0..n).map(|_| None).collect();
    let held_t;

    if me == 0 {
        // Multi-stream compression of all blocks (one batch).
        let blocks: Vec<DeviceBuf> = (0..n).map(|i| input.slice(chunks.range(i))).collect();
        let now = ctx.now();
        let (comp, t_c) = ctx.compress_multistream(&blocks, now);
        // Host-synchronize to learn the compressed sizes/offsets.
        ctx.sync_device();
        // Share the size table with the tree (small Meta message ahead
        // of each data send).
        let sizes: Vec<u64> = comp.iter().map(|c| c.bytes() as u64).collect();
        // Pack the per-stream outputs contiguously (async memcpys).
        let (_total, t_pack) = ctx.pack_d2d(&comp, t_c);
        for (i, c) in comp.into_iter().enumerate() {
            held[i] = Some(c);
        }
        held_t = t_pack;
        // Kick off metadata sends to direct children.
        let mut m = mask >> 1;
        while m > 0 {
            let dst = m; // root's children are at relative ranks m
            if dst < n {
                ctx.send(
                    dst,
                    TAG_SC_META + dst as u64,
                    Payload::Meta(sizes.clone()),
                    ctx.now(),
                );
            }
            m >>= 1;
        }
    } else {
        // Sizes first (needed to address the packed batch), then data.
        let (_sizes, _tm) = ctx.recv_meta(parent.unwrap(), TAG_SC_META + me as u64);
        let (batch, t) = ctx.recv_batch(parent.unwrap(), TAG_SC + me as u64);
        let range = subtree(me, mask, n);
        for (slot, i) in range.clone().enumerate() {
            held[i] = Some(batch[slot].clone());
        }
        held_t = t;
        // Forward the size table to children.
        let sizes = _sizes;
        let mut m = mask >> 1;
        while m > 0 {
            let dst = me + m;
            if dst < n {
                ctx.send(
                    dst,
                    TAG_SC_META + dst as u64,
                    Payload::Meta(sizes.clone()),
                    ctx.now(),
                );
            }
            m >>= 1;
        }
    }

    // Send compressed sub-ranges down the tree (forward verbatim).
    let mut m = mask >> 1;
    while m > 0 {
        let dst = me + m;
        if dst < n {
            let range = subtree(dst, m, n);
            let parts: Vec<CompBuf> = range
                .clone()
                .map(|i| held[i].take().expect("missing compressed block"))
                .collect();
            ctx.send(dst, TAG_SC + dst as u64, Payload::Batch(parts), held_t);
        }
        m >>= 1;
    }

    // Decompress only our own block, on the non-default stream.
    let mine = held[me].take().expect("own compressed block missing");
    let (out, _t) = ctx.decompress(dstream, &mine, held_t);
    ctx.sync_device();
    Ok(out)
}

// ---------------------------------------------------------------------
// CPRP2P: fixed-rate compression bolted onto every hop — decompress the
// whole received range, re-compress every forwarded range.
// ---------------------------------------------------------------------
fn scatter_cprp2p(ctx: &mut RankCtx, input: DeviceBuf, chunks: Chunks) -> Result<DeviceBuf> {
    let n = ctx.nranks();
    let me = ctx.rank();
    let (mask, parent) = tree_position(me, n);
    let stream = StreamId::Default;

    let mut held: Vec<Option<DeviceBuf>> = (0..n).map(|_| None).collect();
    let mut held_t = ctx.now();

    if me == 0 {
        for i in 0..n {
            held[i] = Some(input.slice(chunks.range(i)));
        }
    } else {
        let (cin, t_in) = ctx.recv_comp(parent.unwrap(), TAG_SC + me as u64);
        // Decompress the whole range before anything can be forwarded.
        let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
        let range = subtree(me, mask, n);
        let layout = Chunks::new(dec.elems(), range.len());
        for (slot, i) in range.clone().enumerate() {
            held[i] = Some(dec.slice(layout.range(slot)));
        }
        held_t = t_dec;
    }

    let mut m = mask >> 1;
    while m > 0 {
        let dst = me + m;
        if dst < n {
            let range = subtree(dst, m, n);
            let parts: Vec<DeviceBuf> = range
                .clone()
                .map(|i| held[i].take().expect("missing block"))
                .collect();
            let batch = DeviceBuf::concat(&parts);
            // Re-compress this hop's payload (the CPRP2P tax).
            let (c, t_c) = ctx.compress(stream, &batch, held_t);
            ctx.send(dst, TAG_SC + dst as u64, Payload::Comp(c), t_c);
        }
        m >>= 1;
    }
    Ok(held[me].take().expect("own block missing"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_collective, ClusterSpec, ExecPolicy};
    use crate::testkit::Pcg32;

    fn scatter_inputs(n: usize, d: usize) -> (Vec<DeviceBuf>, Vec<f32>) {
        let mut rng = Pcg32::seeded(31);
        let full = rng.uniform_vec(d, -1.0, 1.0);
        let mut inputs = vec![DeviceBuf::Real(full.clone())];
        for _ in 1..n {
            inputs.push(DeviceBuf::Real(vec![]));
        }
        (inputs, full)
    }

    fn check_scatter(n: usize, d: usize, policy: ExecPolicy, tol: f32) {
        let (inputs, full) = scatter_inputs(n, d);
        let report = run_collective(&ClusterSpec::new(n, policy), inputs, &move |ctx, input| {
            scatter_binomial(ctx, input, d)
        })
        .unwrap();
        let chunks = Chunks::new(d, n);
        for r in 0..n {
            let got = report.outputs[r].as_real();
            let want = &full[chunks.range(r)];
            assert_eq!(got.len(), want.len(), "rank {r} block size");
            for (i, (a, b)) in got.iter().zip(want).enumerate() {
                assert!((a - b).abs() <= tol, "rank {r} elem {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn raw_scatter_exact_various_n() {
        for n in [2usize, 3, 4, 7, 8, 16] {
            check_scatter(n, 256, ExecPolicy::nccl(), 0.0);
        }
    }

    #[test]
    fn cray_cpu_centric_scatter_exact() {
        check_scatter(8, 128, ExecPolicy::cray_mpi(), 0.0);
    }

    #[test]
    fn gz_scatter_single_eb_error() {
        // Compress-once-at-root: each block sees exactly one
        // compression regardless of tree depth.
        for n in [4usize, 8, 13] {
            check_scatter(n, 512, ExecPolicy::gzccl(), 1.1e-4);
        }
    }

    #[test]
    fn cprp2p_scatter_error_grows_with_depth() {
        // Values in [-1,1]: fixed-rate with 8 bits gives per-hop error
        // ~1/127 · blockmax; depth log2(8)=3 hops stack.
        check_scatter(8, 256, ExecPolicy::cprp2p(), 0.1);
    }

    #[test]
    fn gz_scatter_compress_counts() {
        let n = 8;
        let d = 1 << 16;
        let mut inputs = vec![DeviceBuf::Virtual(d)];
        for _ in 1..n {
            inputs.push(DeviceBuf::Virtual(0));
        }
        let report = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            inputs,
            &move |ctx, input| scatter_binomial(ctx, input, d),
        )
        .unwrap();
        // Root compresses each block exactly once (as one multi-stream
        // batch of N kernels); everyone decompresses exactly one block.
        assert_eq!(report.counters[0].compress_calls, n);
        for (r, c) in report.counters.iter().enumerate() {
            if r > 0 {
                assert_eq!(c.compress_calls, 0, "non-root must not compress");
            }
            assert_eq!(c.decompress_calls, 1, "rank {r} decompresses own block");
        }
    }

    #[test]
    fn cprp2p_recompresses_along_the_tree() {
        let n = 8;
        let d = 1 << 16;
        let mut inputs = vec![DeviceBuf::Virtual(d)];
        for _ in 1..n {
            inputs.push(DeviceBuf::Virtual(0));
        }
        let report = run_collective(
            &ClusterSpec::new(n, ExecPolicy::cprp2p()),
            inputs,
            &move |ctx, input| scatter_binomial(ctx, input, d),
        )
        .unwrap();
        let total_cpr: usize = report.counters.iter().map(|c| c.compress_calls).sum();
        // Every edge of the binomial tree compresses: n−1 edges.
        assert_eq!(total_cpr, n - 1);
        // Intermediate ranks decompress ranges they merely forward.
        let total_dec: usize = report.counters.iter().map(|c| c.decompress_calls).sum();
        assert_eq!(total_dec, n - 1);
    }

    #[test]
    fn gz_scatter_faster_than_cprp2p() {
        let n = 16;
        let d = (64 << 20) / 4;
        let mk = |_n: usize| -> Vec<DeviceBuf> {
            let mut v = vec![DeviceBuf::Virtual(d)];
            for _ in 1..n {
                v.push(DeviceBuf::Virtual(0));
            }
            v
        };
        let gz = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            mk(n),
            &move |ctx, input| scatter_binomial(ctx, input, d),
        )
        .unwrap();
        let cpr = run_collective(
            &ClusterSpec::new(n, ExecPolicy::cprp2p()),
            mk(n),
            &move |ctx, input| scatter_binomial(ctx, input, d),
        )
        .unwrap();
        assert!(
            gz.makespan.as_secs() < cpr.makespan.as_secs(),
            "gz {} vs cprp2p {}",
            gz.makespan,
            cpr.makespan
        );
    }
}
