//! Ring Reduce_scatter.
//!
//! The bandwidth-optimal large-message algorithm (§3.2.3): the input is
//! split into N chunks; each chunk travels the ring for N−1 steps,
//! accumulating every rank's contribution, and finishes at its owner.
//!
//! With compression enabled this is the expensive case the paper
//! characterizes: **N−1 compressions and N−1 decompressions per rank**,
//! each over a D/N-sized chunk — small chunks at scale ⇒ the GPU
//! utilization floor dominates (Fig. 3 / §3.2.3).

use crate::coordinator::{DeviceBuf, Payload, ProgFut, RankCtx};
use crate::error::Result;
use crate::gpu::StreamId;
use crate::sim::VirtTime;

use super::chunking::Chunks;

/// Tag base for reduce-scatter rounds.
const TAG_RS: u64 = 0x5253_0000;

/// Ring Reduce_scatter of `input`; rank `r` returns the fully-reduced
/// chunk `r`. The returned [`VirtTime`] is when the chunk is ready on
/// device (callers composing Allreduce chain it into the Allgather).
pub async fn reduce_scatter_ring_at(
    ctx: &mut RankCtx,
    input: DeviceBuf,
    ready: VirtTime,
) -> Result<(DeviceBuf, VirtTime)> {
    let n = ctx.nranks();
    let r = ctx.rank();
    if n == 1 {
        return Ok((input, ready));
    }
    let chunks = Chunks::new(input.elems(), n);
    // Current accumulated value of each chunk this rank has touched.
    let mut acc: Vec<DeviceBuf> = (0..n).map(|i| input.slice(chunks.range(i))).collect();
    // Per-chunk device-ready timestamps.
    let mut acc_ready: Vec<VirtTime> = vec![ready; n];

    let next = (r + 1) % n;
    let prev = (r + n - 1) % n;
    let stream = if ctx.policy().overlap {
        StreamId::NonDefault(0)
    } else {
        StreamId::Default
    };

    for s in 1..n {
        let send_idx = (r + n - s) % n;
        let recv_idx = (r + n - s - 1) % n;
        // Send the current value of chunk send_idx to the next rank.
        if ctx.compression_enabled() {
            let (c, t) = ctx.compress(stream, &acc[send_idx], acc_ready[send_idx]);
            ctx.send(next, TAG_RS + s as u64, Payload::Comp(c), t);
            let (cin, t_in) = ctx.recv_comp(prev, TAG_RS + s as u64).await;
            let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
            let dep = t_dec.join(acc_ready[recv_idx]);
            let (sum, t_sum) = ctx.reduce(stream, &acc[recv_idx], &dec, dep)?;
            acc[recv_idx] = sum;
            acc_ready[recv_idx] = t_sum;
        } else {
            ctx.send(
                next,
                TAG_RS + s as u64,
                Payload::Raw(acc[send_idx].clone()),
                acc_ready[send_idx],
            );
            let (bin, t_in) = ctx.recv_raw(prev, TAG_RS + s as u64).await;
            let dep = t_in.join(acc_ready[recv_idx]);
            let (sum, t_sum) = ctx.reduce(stream, &acc[recv_idx], &bin, dep)?;
            acc[recv_idx] = sum;
            acc_ready[recv_idx] = t_sum;
        }
    }
    let out_ready = acc_ready[r];
    Ok((acc.swap_remove(r), out_ready))
}

/// [`reduce_scatter_ring_at`] from time zero (standalone collective).
pub fn reduce_scatter_ring(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(async move {
        let now = ctx.now();
        let (out, t) = reduce_scatter_ring_at(ctx, input, now).await?;
        // Materialize: the op completes when the chunk is device-ready.
        if ctx.policy().overlap {
            let _ = t;
            ctx.sync_device();
        }
        Ok(out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_collective, ClusterSpec, ExecPolicy};
    use crate::testkit::Pcg32;

    fn inputs_real(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
        (0..n)
            .map(|r| {
                let mut rng = Pcg32::new(seed, r as u64);
                DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
            })
            .collect()
    }

    fn expected_sums(inputs: &[DeviceBuf]) -> Vec<f32> {
        let d = inputs[0].elems();
        let mut out = vec![0.0f32; d];
        for b in inputs {
            for (o, v) in out.iter_mut().zip(b.as_real()) {
                *o += v;
            }
        }
        out
    }

    #[test]
    fn uncompressed_ring_rs_computes_exact_sums() {
        let n = 8;
        let d = 64;
        let inputs = inputs_real(n, d, 42);
        let expect = expected_sums(&inputs);
        let spec = ClusterSpec::new(n, ExecPolicy::nccl());
        let report = run_collective(&spec, inputs, &reduce_scatter_ring).unwrap();
        let chunks = Chunks::new(d, n);
        for r in 0..n {
            let got = report.outputs[r].as_real();
            let want = &expect[chunks.range(r)];
            for (a, b) in got.iter().zip(want) {
                assert!((a - b).abs() < 1e-4, "rank {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn compressed_ring_rs_within_stacked_error_bound() {
        let n = 8;
        let d = 256;
        let eb = 1e-3;
        let inputs = inputs_real(n, d, 7);
        let expect = expected_sums(&inputs);
        let spec = ClusterSpec::new(n, ExecPolicy::gzccl()).with_error_bound(eb);
        let report = run_collective(&spec, inputs, &reduce_scatter_ring).unwrap();
        // Error stacking: each of the N−1 hops adds ≤ 2eb (compress +
        // reduce of decompressed values) — linear bound, loose.
        let bound = (2 * n) as f32 * eb as f32;
        let chunks = Chunks::new(d, n);
        for r in 0..n {
            let got = report.outputs[r].as_real();
            let want = &expect[chunks.range(r)];
            for (a, b) in got.iter().zip(want) {
                assert!((a - b).abs() < bound, "rank {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rs_ring_cpr_counts_match_paper() {
        let n = 8;
        let inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(1 << 16)).collect();
        let spec = ClusterSpec::new(n, ExecPolicy::gzccl());
        let report = run_collective(&spec, inputs, &reduce_scatter_ring).unwrap();
        for c in &report.counters {
            assert_eq!(c.compress_calls, n - 1);
            assert_eq!(c.decompress_calls, n - 1);
            assert_eq!(c.reduce_calls, n - 1);
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let spec = ClusterSpec::new(1, ExecPolicy::gzccl());
        let report = run_collective(
            &spec,
            vec![DeviceBuf::Real(vec![1.0, 2.0])],
            &reduce_scatter_ring,
        )
        .unwrap();
        assert_eq!(report.outputs[0].as_real(), &[1.0, 2.0]);
    }

    #[test]
    fn compression_reduces_wire_bytes() {
        let n = 4;
        let d = 1 << 20;
        let smooth: Vec<DeviceBuf> = (0..n)
            .map(|r| {
                DeviceBuf::Real(
                    (0..d)
                        .map(|i| ((i + r * 17) as f32 * 1e-5).sin())
                        .collect(),
                )
            })
            .collect();
        let base = run_collective(
            &ClusterSpec::new(n, ExecPolicy::nccl()),
            smooth.clone(),
            &reduce_scatter_ring,
        )
        .unwrap();
        let gz = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()).with_error_bound(1e-4),
            smooth,
            &reduce_scatter_ring,
        )
        .unwrap();
        assert!(
            gz.total_wire_bytes() < base.total_wire_bytes() / 4,
            "gz {} vs base {}",
            gz.total_wire_bytes(),
            base.total_wire_bytes()
        );
    }
}
