//! Allgather: ring, Bruck, and recursive-doubling algorithms.
//!
//! The paper's data-movement analysis (§3.3.3) concludes the **ring** is
//! the right choice under GPU compression: one compression of the local
//! block, N−1 forwarded (never recompressed) transfers, and N−1
//! decompressions that multi-stream/overlap away. Bruck and recursive
//! doubling are implemented as the comparison points: fewer steps but
//! more transferred volume (blocks double every round, all compressed
//! payloads still decompressed once per origin block).

use crate::coordinator::{CompBuf, CompressionMode, DeviceBuf, Payload, ProgFut, RankCtx};
use crate::error::Result;
use crate::gpu::StreamId;
use crate::sim::VirtTime;

use super::chunking::Chunks;

const TAG_AG: u64 = 0x4147_0000;

/// Ring Allgather. Rank r contributes `input` as block r; returns the
/// concatenation of all blocks (order 0..N). `ready` is when `input`
/// is device-ready (lets Allreduce chain RS→AG without a barrier).
pub async fn allgather_ring_at(
    ctx: &mut RankCtx,
    input: DeviceBuf,
    ready: VirtTime,
) -> Result<(DeviceBuf, VirtTime)> {
    let n = ctx.nranks();
    let r = ctx.rank();
    if n == 1 {
        return Ok((input, ready));
    }
    let next = (r + 1) % n;
    let prev = (r + n - 1) % n;
    let stream = if ctx.policy().overlap {
        StreamId::NonDefault(1)
    } else {
        StreamId::Default
    };

    let mut blocks: Vec<Option<DeviceBuf>> = (0..n).map(|_| None).collect();
    let mut blocks_ready: Vec<VirtTime> = vec![ready; n];

    if ctx.compression_enabled() && ctx.policy().compression == CompressionMode::FixedRate {
        // CPRP2P: compression lives in the p2p layer, so every hop
        // decompresses the incoming block and re-compresses it before
        // forwarding — N−1 compressions AND N−1 decompressions, plus
        // per-hop error stacking. This is the baseline the paper's
        // Fig. 2 characterizes.
        let mut outgoing: DeviceBuf = input.clone();
        let mut outgoing_t = ready;
        blocks[r] = Some(input);
        blocks_ready[r] = ready;
        for s in 1..n {
            let recv_idx = (r + n - s) % n;
            let (c, t_c) = ctx.compress(stream, &outgoing, outgoing_t);
            ctx.send(next, TAG_AG + s as u64, Payload::Comp(c), t_c);
            let (cin, t_in) = ctx.recv_comp(prev, TAG_AG + s as u64).await;
            let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
            blocks[recv_idx] = Some(dec.clone());
            blocks_ready[recv_idx] = t_dec;
            outgoing = dec;
            outgoing_t = t_dec;
        }
    } else if ctx.compression_enabled() {
        // ONE compression of the local block (the gZCCL invariant).
        let (cmine, t0) = ctx.compress(stream, &input, ready);
        blocks[r] = Some(input);
        blocks_ready[r] = ready;
        // Compressed blocks are forwarded verbatim around the ring.
        let mut outgoing: CompBuf = cmine;
        let mut outgoing_t = t0;
        for s in 1..n {
            let send_idx = (r + n - s + 1) % n;
            let _ = send_idx; // the outgoing buffer IS block send_idx
            let recv_idx = (r + n - s) % n;
            ctx.send(next, TAG_AG + s as u64, Payload::Comp(outgoing.clone()), outgoing_t);
            let (cin, t_in) = ctx.recv_comp(prev, TAG_AG + s as u64).await;
            // Decompress on the side stream; forwarding does not wait
            // for decompression (overlap of §3.3.4).
            let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
            blocks[recv_idx] = Some(dec);
            blocks_ready[recv_idx] = t_dec;
            outgoing = cin;
            outgoing_t = t_in;
        }
    } else {
        blocks[r] = Some(input.clone());
        let mut outgoing = input;
        let mut outgoing_t = ready;
        for s in 1..n {
            let recv_idx = (r + n - s) % n;
            ctx.send(next, TAG_AG + s as u64, Payload::Raw(outgoing.clone()), outgoing_t);
            let (bin, t_in) = ctx.recv_raw(prev, TAG_AG + s as u64).await;
            blocks[recv_idx] = Some(bin.clone());
            blocks_ready[recv_idx] = t_in;
            outgoing = bin;
            outgoing_t = t_in;
        }
    }

    let parts: Vec<DeviceBuf> = blocks.into_iter().map(|b| b.unwrap()).collect();
    let out = DeviceBuf::concat(&parts)?;
    let t = blocks_ready
        .into_iter()
        .fold(VirtTime::ZERO, |a, b| a.join(b));
    Ok((out, t))
}

/// Standalone ring Allgather from time zero.
pub fn allgather_ring(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(async move {
        let now = ctx.now();
        let (out, _t) = allgather_ring_at(ctx, input, now).await?;
        if ctx.policy().overlap {
            ctx.sync_device();
        }
        Ok(out)
    })
}

/// Recursive-doubling Allgather: log N rounds, exchanged volume doubles
/// each round. Requires a power-of-two communicator (callers fall back
/// to ring otherwise, as MPICH does).
pub fn allgather_recursive_doubling(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(async move {
    let n = ctx.nranks();
    let r = ctx.rank();
    if n == 1 {
        return Ok(input);
    }
    assert!(
        n.is_power_of_two(),
        "recursive-doubling allgather requires power-of-two ranks"
    );
    let stream = if ctx.policy().overlap {
        StreamId::NonDefault(1)
    } else {
        StreamId::Default
    };
    // Accumulated gathered region, kept in rank order within the
    // doubling group: after round k the rank holds 2^k blocks.
    let mut have: Vec<(usize, DeviceBuf)> = vec![(r, input)];
    let mut have_t = ctx.now();
    let mut mask = 1usize;
    let mut round = 0u64;
    while mask < n {
        let peer = r ^ mask;
        let mine = DeviceBuf::concat(&have.iter().map(|(_, b)| b.clone()).collect::<Vec<_>>())?;
        let (theirs, t_in) = if ctx.compression_enabled() {
            let (c, t_c) = ctx.compress(stream, &mine, have_t);
            ctx.send(peer, TAG_AG + 0x100 + round, Payload::Comp(c), t_c);
            let (cin, t_in) = ctx.recv_comp(peer, TAG_AG + 0x100 + round).await;
            let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
            (dec, t_dec)
        } else {
            ctx.send(peer, TAG_AG + 0x100 + round, Payload::Raw(mine.clone()), have_t);
            ctx.recv_raw(peer, TAG_AG + 0x100 + round).await
        };
        // The peer's region covers its own group of blocks.
        let peer_base = peer & !(mask - 1);
        let counts = Chunks::new(theirs.elems(), mask);
        let mut theirs_blocks: Vec<(usize, DeviceBuf)> = (0..mask)
            .map(|i| (peer_base + i, theirs.slice(counts.range(i))))
            .collect();
        have.append(&mut theirs_blocks);
        have.sort_by_key(|(idx, _)| *idx);
        have_t = have_t.join(t_in);
        mask <<= 1;
        round += 1;
    }
    if ctx.policy().overlap {
        ctx.sync_device();
    }
    let parts: Vec<DeviceBuf> = have.into_iter().map(|(_, b)| b).collect();
    DeviceBuf::concat(&parts)
    })
}

/// Bruck Allgather: log N rounds of shifted block exchanges; works for
/// any N. Output is rotated back into rank order at the end.
pub fn allgather_bruck(ctx: &mut RankCtx, input: DeviceBuf) -> ProgFut<'_> {
    Box::pin(async move {
    let n = ctx.nranks();
    let r = ctx.rank();
    if n == 1 {
        return Ok(input);
    }
    let stream = if ctx.policy().overlap {
        StreamId::NonDefault(1)
    } else {
        StreamId::Default
    };
    // Bruck keeps blocks in "local order": position p holds block
    // (r + p) mod n.
    let mut have: Vec<DeviceBuf> = vec![input];
    let mut have_t = ctx.now();
    let mut pofk = 1usize;
    let mut round = 0u64;
    while pofk < n {
        let send_to = (r + n - pofk) % n;
        let recv_from = (r + pofk) % n;
        let count = pofk.min(n - pofk);
        let mine = DeviceBuf::concat(&have[..count].to_vec())?;
        let (theirs, t_in) = if ctx.compression_enabled() {
            let (c, t_c) = ctx.compress(stream, &mine, have_t);
            ctx.send(send_to, TAG_AG + 0x200 + round, Payload::Comp(c), t_c);
            let (cin, t_in) = ctx.recv_comp(recv_from, TAG_AG + 0x200 + round).await;
            let (dec, t_dec) = ctx.decompress(stream, &cin, t_in);
            (dec, t_dec)
        } else {
            ctx.send(send_to, TAG_AG + 0x200 + round, Payload::Raw(mine.clone()), have_t);
            ctx.recv_raw(recv_from, TAG_AG + 0x200 + round).await
        };
        let counts = Chunks::new(theirs.elems(), count);
        for i in 0..count {
            have.push(theirs.slice(counts.range(i)));
        }
        have_t = have_t.join(t_in);
        pofk <<= 1;
        round += 1;
    }
    if ctx.policy().overlap {
        ctx.sync_device();
    }
    // Rotate local order back to rank order: block (r+p)%n is at p.
    let mut parts: Vec<Option<DeviceBuf>> = (0..n).map(|_| None).collect();
    for (p, b) in have.into_iter().enumerate().take(n) {
        parts[(r + p) % n] = Some(b);
    }
    DeviceBuf::concat(&parts.into_iter().map(|b| b.unwrap()).collect::<Vec<_>>())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_collective, ClusterSpec, ExecPolicy, Program};
    use crate::testkit::Pcg32;

    fn block(r: usize, d: usize) -> Vec<f32> {
        let mut rng = Pcg32::new(99, r as u64);
        rng.uniform_vec(d, -1.0, 1.0)
    }

    fn check_gathered(outputs: &[DeviceBuf], n: usize, d: usize, tol: f32) {
        let expect: Vec<f32> = (0..n).flat_map(|r| block(r, d)).collect();
        for (r, out) in outputs.iter().enumerate() {
            assert_eq!(out.elems(), n * d, "rank {r} size");
            for (i, (a, b)) in out.as_real().iter().zip(expect.iter()).enumerate() {
                assert!((a - b).abs() <= tol, "rank {r} elem {i}: {a} vs {b}");
            }
        }
    }

    fn run_ag(
        n: usize,
        d: usize,
        policy: ExecPolicy,
        f: impl Program,
    ) -> Vec<DeviceBuf> {
        let inputs: Vec<DeviceBuf> = (0..n).map(|r| DeviceBuf::Real(block(r, d))).collect();
        run_collective(&ClusterSpec::new(n, policy), inputs, &f)
            .unwrap()
            .outputs
    }

    #[test]
    fn ring_uncompressed_exact() {
        let out = run_ag(8, 32, ExecPolicy::nccl(), allgather_ring);
        check_gathered(&out, 8, 32, 0.0);
    }

    #[test]
    fn ring_compressed_within_single_eb() {
        // Allgather compresses each origin block exactly once: the
        // error is one compression deep regardless of N.
        let out = run_ag(8, 64, ExecPolicy::gzccl(), allgather_ring);
        check_gathered(&out, 8, 64, 1.1e-4);
    }

    #[test]
    fn ring_compressed_one_compress_per_rank() {
        let n = 8;
        let inputs: Vec<DeviceBuf> = (0..n).map(|_| DeviceBuf::Virtual(4096)).collect();
        let report = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            inputs,
            &allgather_ring,
        )
        .unwrap();
        for c in &report.counters {
            assert_eq!(c.compress_calls, 1, "gZ-Allgather compresses once");
            assert_eq!(c.decompress_calls, n - 1);
        }
    }

    #[test]
    fn recursive_doubling_exact_and_compressed() {
        let out = run_ag(8, 32, ExecPolicy::nccl(), allgather_recursive_doubling);
        check_gathered(&out, 8, 32, 0.0);
        // ReDoub recompresses aggregates each round: error stacks with
        // log N compressions.
        let out = run_ag(8, 32, ExecPolicy::gzccl(), allgather_recursive_doubling);
        check_gathered(&out, 8, 32, 4.0 * 1.1e-4);
    }

    #[test]
    fn bruck_exact_any_n() {
        for n in [3usize, 5, 8] {
            let out = run_ag(n, 16, ExecPolicy::nccl(), allgather_bruck);
            check_gathered(&out, n, 16, 0.0);
        }
    }

    #[test]
    fn bruck_compressed() {
        let out = run_ag(6, 32, ExecPolicy::gzccl(), allgather_bruck);
        check_gathered(&out, 6, 32, 4.0 * 1.1e-4);
    }

    #[test]
    fn ring_moves_less_volume_than_redoub_with_compression() {
        // §3.3.3: ring transfers each block once (compressed);
        // recursive doubling ships doubling aggregates: same order of
        // volume, but ring wins on compression count. Check compress
        // counters: ring = 1, redoub = log N.
        let n = 8;
        let mk = || -> Vec<DeviceBuf> { (0..n).map(|_| DeviceBuf::Virtual(1 << 16)).collect() };
        let ring = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            mk(),
            &allgather_ring,
        )
        .unwrap();
        let redoub = run_collective(
            &ClusterSpec::new(n, ExecPolicy::gzccl()),
            mk(),
            &allgather_recursive_doubling,
        )
        .unwrap();
        assert_eq!(ring.counters[0].compress_calls, 1);
        assert_eq!(redoub.counters[0].compress_calls, 3);
    }
}
