//! α–β link cost model.

/// Which physical path a message takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// GPU↔GPU on the same node (NVLink/NVSwitch class).
    IntraNode,
    /// Across nodes via the node NICs (Slingshot class).
    InterNode,
    /// Host↔device over PCIe (used by CPU-centric baselines).
    Pcie,
}

/// α–β parameters of one link class: `t(n) = alpha + n / beta`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Per-message latency in seconds (α).
    pub alpha: f64,
    /// Bandwidth in bytes/second (β).
    pub beta: f64,
}

impl LinkModel {
    /// Construct from latency (seconds) and bandwidth (bytes/sec).
    pub fn new(alpha: f64, beta: f64) -> Self {
        assert!(alpha >= 0.0 && beta > 0.0, "bad link model");
        LinkModel { alpha, beta }
    }

    /// Transfer time for `bytes` on this link.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.alpha + bytes as f64 / self.beta
    }

    /// Serialization-only time (no latency term) — the component that
    /// occupies the shared NIC for internode messages.
    pub fn serialization_time(&self, bytes: usize) -> f64 {
        bytes as f64 / self.beta
    }

    /// NVLink-class intranode defaults (A100 NVLink3, per-pair
    /// effective): ~5 µs latency, 200 GB/s.
    pub fn nvlink_default() -> Self {
        LinkModel::new(5e-6, 200e9)
    }

    /// Slingshot-10-class internode defaults: 100 Gbps = 12.5 GB/s per
    /// node NIC, ~15 µs end-to-end latency.
    pub fn slingshot10_default() -> Self {
        LinkModel::new(15e-6, 12.5e9)
    }

    /// PCIe gen4 x16 defaults: ~25 GB/s, 10 µs.
    pub fn pcie_default() -> Self {
        LinkModel::new(10e-6, 25e9)
    }

    /// Rack-uplink defaults: the aggregate leaf→spine capacity one
    /// rack's nodes share — a *throughput* bottleneck, not just a
    /// latency hop. A 16-node rack of 12.5 GB/s NICs demands 200 GB/s
    /// of full bisection; 12.5 GB/s (one NIC's worth for the whole
    /// rack, 16:1 oversubscription) models the thin uplinks of
    /// cost-optimized fat-tree pods. With a merely-mild ratio the
    /// cross-rack rounds pipeline behind compute and flat schedules
    /// hide the contention; at 16:1 the uplink's total busy time is a
    /// hard lower bound that only sending *less* across the boundary —
    /// the deep hierarchical schedule — escapes.
    pub fn rack_uplink_default() -> Self {
        LinkModel::new(25e-6, 12.5e9)
    }

    /// Spine/pod-uplink defaults for tiers above the rack: more
    /// aggregate capacity, more hops.
    pub fn spine_uplink_default() -> Self {
        LinkModel::new(50e-6, 25e9)
    }
}

/// Default uplink models for the tiers **above** node level of a
/// `depth`-tier [`crate::topo::TierTree`]: one entry per tier in
/// `2..depth` (empty for 2-tier trees — a node/fabric cluster has no
/// modeled uplinks). Tier 2 gets the rack uplink; deeper tiers the
/// spine uplink.
pub fn default_uplinks(depth: usize) -> Vec<LinkModel> {
    (2..depth)
        .map(|t| {
            if t == 2 {
                LinkModel::rack_uplink_default()
            } else {
                LinkModel::spine_uplink_default()
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_is_affine() {
        let l = LinkModel::new(1e-6, 1e9);
        let t1 = l.transfer_time(1_000_000);
        assert!((t1 - (1e-6 + 1e-3)).abs() < 1e-12);
        // Zero bytes = pure latency.
        assert!((l.transfer_time(0) - 1e-6).abs() < 1e-15);
    }

    #[test]
    fn serialization_excludes_latency() {
        let l = LinkModel::new(1e-3, 1e9);
        assert!((l.serialization_time(1_000_000) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn internode_slower_than_intranode_for_large_msgs() {
        let nv = LinkModel::nvlink_default();
        let ss = LinkModel::slingshot10_default();
        let n = 100 << 20;
        assert!(ss.transfer_time(n) > 10.0 * nv.transfer_time(n));
    }

    #[test]
    #[should_panic(expected = "bad link model")]
    fn zero_bandwidth_rejected() {
        LinkModel::new(0.0, 0.0);
    }
}
