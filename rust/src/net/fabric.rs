//! The fabric: routes virtual-time messages through link models,
//! per-node NIC contention, and per-tier uplink contention.

use std::sync::Arc;

use crate::sim::{SharedTimeline, VirtTime};
use crate::topo::TierTree;

use super::link::{LinkClass, LinkModel};
use super::topology::Topology;

/// One shared-stage reservation a message made on its way through the
/// fabric (flight-recorder detail; see [`Fabric::deliver_traced`]).
#[derive(Debug, Clone)]
pub struct Hop {
    /// Stage kind: `nic-tx`, `up-tx`, `up-rx` or `nic-rx`.
    pub kind: &'static str,
    /// Crossing tier for uplink stages (≥ 2); 0 for NIC stages.
    pub tier: usize,
    /// When the message was ready for this stage, virtual seconds.
    pub ready: f64,
    /// Queueing delay before the stage started serving it, seconds.
    pub wait: f64,
}

/// The route one delivery took: its crossing tier and every shared
/// stage it reserved, with per-stage queue waits. Filled by
/// [`Fabric::deliver_traced`] so the flight recorder can attribute
/// NIC serialization and rack/pod uplink contention on the timeline.
#[derive(Debug, Clone, Default)]
pub struct DeliverPath {
    /// Lowest-common-ancestor tier of the endpoints (0 = same node:
    /// the message rode NVLink and reserved nothing).
    pub lca: usize,
    /// Reserved stages in physical order.
    pub hops: Vec<Hop>,
}

impl DeliverPath {
    fn hop(&mut self, kind: &'static str, tier: usize, ready: VirtTime, start: VirtTime) {
        self.hops.push(Hop {
            kind,
            tier,
            ready: ready.as_secs(),
            wait: start.since(ready),
        });
    }
}

/// Network fabric for one simulated cluster.
///
/// Internode messages serialize on the sender's egress NIC and the
/// receiver's ingress NIC; intranode messages ride NVLink/NVSwitch and
/// see no NIC contention. The number of NICs per node is configurable:
/// the paper's testbed is Perlmutter-like (4 A100 + 4 Slingshot-10
/// NICs per node → one NIC per GPU, the default); setting
/// `nics_per_node = 1` reproduces a shared-NIC cluster.
///
/// On a multi-tier [`TierTree`] (built via [`Fabric::tiered`]) a
/// message additionally serializes on the **uplinks** of every tier
/// boundary it crosses: a cross-rack message reserves the sender
/// rack's egress uplink and the receiver rack's ingress uplink, so
/// concurrent cross-rack senders within one rack contend for the
/// oversubscribed leaf→spine capacity — the effect that makes deep
/// hierarchical schedules (one leader per rack crossing, instead of
/// every node leader) pay off. Two-tier fabrics have no uplinks and
/// behave exactly as before.
///
/// Delivery is cut-through: each stage starts receiving its link's
/// `alpha` after the upstream stage starts transmitting, so an
/// uncontended transfer costs one serialization plus the summed
/// latencies, not a serialization per hop.
#[derive(Debug, Clone)]
pub struct Fabric {
    tree: TierTree,
    topo: Topology,
    intranode: LinkModel,
    internode: LinkModel,
    /// Uplink models for tiers ≥ 2 (index `t − 2`; clamped to the last
    /// entry for deeper tiers).
    uplinks: Vec<LinkModel>,
    nics_per_node: usize,
    /// Egress NIC timelines, `nodes × nics_per_node`.
    nic_tx: Arc<Vec<SharedTimeline>>,
    /// Ingress NIC timelines, `nodes × nics_per_node`.
    nic_rx: Arc<Vec<SharedTimeline>>,
    /// Egress uplink timelines per tier ≥ 2: `[t − 2][tier-(t−1) group]`.
    up_tx: Arc<Vec<Vec<SharedTimeline>>>,
    /// Ingress uplink timelines per tier ≥ 2.
    up_rx: Arc<Vec<Vec<SharedTimeline>>>,
}

impl Fabric {
    /// Build a 2-tier fabric over `topo` with the given link models and
    /// one NIC per GPU (Perlmutter-like).
    pub fn new(topo: Topology, intranode: LinkModel, internode: LinkModel) -> Self {
        let nics = topo.gpus_per_node();
        Self::build(TierTree::from(&topo), intranode, internode, vec![], nics)
    }

    /// Build a 2-tier fabric with an explicit NIC count per node.
    pub fn with_nics(
        topo: Topology,
        intranode: LinkModel,
        internode: LinkModel,
        nics_per_node: usize,
    ) -> Self {
        Self::build(TierTree::from(&topo), intranode, internode, vec![], nics_per_node)
    }

    /// Build a multi-tier fabric over `tree`: `uplinks[t − 2]` is the
    /// shared leaf→spine capacity of each tier-`t − 1` group (empty for
    /// 2-tier trees). One NIC per GPU.
    pub fn tiered(
        tree: TierTree,
        intranode: LinkModel,
        internode: LinkModel,
        uplinks: Vec<LinkModel>,
    ) -> Self {
        let nics = tree.width(0);
        Self::build(tree, intranode, internode, uplinks, nics)
    }

    fn build(
        tree: TierTree,
        intranode: LinkModel,
        internode: LinkModel,
        uplinks: Vec<LinkModel>,
        nics_per_node: usize,
    ) -> Self {
        assert!(nics_per_node > 0);
        let topo = tree.to_topology();
        let n = topo.nodes() * nics_per_node;
        let mk = |count: usize| (0..count).map(|_| SharedTimeline::new()).collect::<Vec<_>>();
        let up: Vec<Vec<SharedTimeline>> =
            (2..tree.depth()).map(|t| mk(tree.groups(t - 1))).collect();
        let up2: Vec<Vec<SharedTimeline>> =
            (2..tree.depth()).map(|t| mk(tree.groups(t - 1))).collect();
        Fabric {
            tree,
            topo,
            intranode,
            internode,
            uplinks,
            nics_per_node,
            nic_tx: Arc::new(mk(n)),
            nic_rx: Arc::new(mk(n)),
            up_tx: Arc::new(up),
            up_rx: Arc::new(up2),
        }
    }

    /// NIC index serving `rank`.
    fn nic_of(&self, rank: usize) -> usize {
        self.topo.node_of(rank) * self.nics_per_node
            + self.topo.local_of(rank) % self.nics_per_node
    }

    /// Uplink model of tier `t` (≥ 2). Falls back to the internode
    /// model when no uplink was configured for that tier.
    fn uplink_model(&self, t: usize) -> LinkModel {
        if self.uplinks.is_empty() {
            self.internode
        } else {
            self.uplinks[(t - 2).min(self.uplinks.len() - 1)]
        }
    }

    /// Fabric with paper-testbed defaults (NVLink intranode,
    /// Slingshot-10 internode).
    pub fn default_cluster(topo: Topology) -> Self {
        Self::new(
            topo,
            LinkModel::nvlink_default(),
            LinkModel::slingshot10_default(),
        )
    }

    /// The 2-tier node-level view this fabric spans.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// The full tier tree this fabric spans (2-tier unless built with
    /// [`Fabric::tiered`]).
    pub fn tiers(&self) -> &TierTree {
        &self.tree
    }

    /// Link class used between two ranks.
    pub fn link_class(&self, from: usize, to: usize) -> LinkClass {
        if self.topo.same_node(from, to) {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }

    /// Model parameters of a link class.
    pub fn link_model(&self, class: LinkClass) -> LinkModel {
        match class {
            LinkClass::IntraNode => self.intranode,
            LinkClass::InterNode => self.internode,
            LinkClass::Pcie => LinkModel::pcie_default(),
        }
    }

    /// Compute the arrival time of `bytes` sent from `from` to `to`,
    /// departing (earliest) at `depart`. Reserves NIC — and, for hops
    /// crossing rack/pod boundaries, uplink — slots as a side effect,
    /// so concurrent senders contend at every shared stage.
    pub fn deliver(&self, from: usize, to: usize, bytes: usize, depart: VirtTime) -> VirtTime {
        self.deliver_path(from, to, bytes, depart, None)
    }

    /// [`Fabric::deliver`] that additionally records the route into
    /// `path`: the crossing tier and every shared-stage reservation
    /// with its queue wait. Timeline side effects are identical to an
    /// untraced delivery.
    pub fn deliver_traced(
        &self,
        from: usize,
        to: usize,
        bytes: usize,
        depart: VirtTime,
        path: &mut DeliverPath,
    ) -> VirtTime {
        self.deliver_path(from, to, bytes, depart, Some(path))
    }

    fn deliver_path(
        &self,
        from: usize,
        to: usize,
        bytes: usize,
        depart: VirtTime,
        mut path: Option<&mut DeliverPath>,
    ) -> VirtTime {
        let lca = self.tree.lca_tier(from, to);
        if let Some(p) = path.as_deref_mut() {
            p.lca = lca;
            p.hops.clear();
        }
        if lca == 0 {
            return depart + self.intranode.transfer_time(bytes);
        }
        let ser = self.internode.serialization_time(bytes);
        let tx = &self.nic_tx[self.nic_of(from)];
        let (tx_start, _) = tx.reserve(depart, ser);
        if let Some(p) = path.as_deref_mut() {
            p.hop("nic-tx", 0, depart, tx_start);
        }
        // Cut-through: each downstream stage follows the upstream start
        // by that stage's wire latency, overlapping serialization. The
        // physical order is NIC egress, then the sender side's uplinks
        // *ascending* (rack → pod) to the crossing tier, the receiver
        // side's uplinks *descending* back (pod → rack), then NIC
        // ingress. Each tier's latency is charged once, at its egress
        // handoff.
        let mut start = tx_start + self.internode.alpha;
        let mut chain_end = start;
        for t in 2..=lca {
            let lm = self.uplink_model(t);
            let ser_u = lm.serialization_time(bytes);
            let g_from = self.tree.group_of(t - 1, from);
            let (u_start, u_end) = self.up_tx[t - 2][g_from].reserve(start, ser_u);
            if let Some(p) = path.as_deref_mut() {
                p.hop("up-tx", t, start, u_start);
            }
            start = u_start + lm.alpha;
            chain_end = chain_end.join(u_end);
        }
        for t in (2..=lca).rev() {
            let lm = self.uplink_model(t);
            let ser_u = lm.serialization_time(bytes);
            let g_to = self.tree.group_of(t - 1, to);
            let (u_start, u_end) = self.up_rx[t - 2][g_to].reserve(start, ser_u);
            if let Some(p) = path.as_deref_mut() {
                p.hop("up-rx", t, start, u_start);
            }
            start = u_start;
            chain_end = chain_end.join(u_end);
        }
        let rx = &self.nic_rx[self.nic_of(to)];
        let (rx_start, rx_end) = rx.reserve(start, ser);
        if let Some(p) = path {
            p.hop("nic-rx", 0, start, rx_start);
        }
        rx_end.join(chain_end)
    }

    /// Total busy seconds across all egress NICs (diagnostic).
    pub fn nic_tx_busy_total(&self) -> f64 {
        self.nic_tx.iter().map(|t| t.busy_total()).sum()
    }

    /// Reset all NIC and uplink timelines (between runs).
    pub fn reset(&self) {
        for t in self.nic_tx.iter().chain(self.nic_rx.iter()) {
            t.reset();
        }
        for tier in self.up_tx.iter().chain(self.up_rx.iter()) {
            for t in tier {
                t.reset();
            }
        }
    }
}

/// A logical window onto a (possibly shared) physical [`Fabric`].
///
/// Multi-tenant runs place several communicators on one fabric: each
/// tenant sees a contiguous range of physical leaves starting at
/// `base`, addressed by its own logical ranks `0..n`. Collectives
/// program against the *logical* topology/tier views; `deliver` maps
/// logical ranks onto physical leaves, so tenants contend on the
/// shared NIC and uplink timelines exactly where their windows meet
/// the same physical resources. A [`FabricSlice::whole`] slice is the
/// identity mapping single-tenant runs use.
#[derive(Debug, Clone)]
pub struct FabricSlice {
    fabric: Fabric,
    base: usize,
    topo: Topology,
    tree: TierTree,
}

impl FabricSlice {
    /// The identity slice: the whole fabric, logical = physical.
    pub fn whole(fabric: Fabric) -> Self {
        let topo = fabric.topology().clone();
        let tree = fabric.tiers().clone();
        FabricSlice {
            fabric,
            base: 0,
            topo,
            tree,
        }
    }

    /// A tenant window: logical rank `r` maps to physical leaf
    /// `base + r`, and the tenant's collectives see `tree` as their
    /// layout. The window must fit inside the physical fabric.
    pub fn window(fabric: Fabric, base: usize, tree: TierTree) -> Self {
        let topo = tree.to_topology();
        assert!(
            base + topo.ranks() <= fabric.topology().ranks(),
            "tenant window [{}, {}) exceeds physical fabric of {} ranks",
            base,
            base + topo.ranks(),
            fabric.topology().ranks()
        );
        FabricSlice {
            fabric,
            base,
            topo,
            tree,
        }
    }

    /// First physical leaf of this window.
    pub fn base(&self) -> usize {
        self.base
    }

    /// The tenant-logical 2-tier view.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// The tenant-logical tier tree.
    pub fn tiers(&self) -> &TierTree {
        &self.tree
    }

    /// The underlying physical fabric.
    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    /// Deliver between *logical* ranks: reserves the physical NIC and
    /// uplink slots of the mapped leaves.
    pub fn deliver(&self, from: usize, to: usize, bytes: usize, depart: VirtTime) -> VirtTime {
        self.fabric
            .deliver(self.base + from, self.base + to, bytes, depart)
    }

    /// [`FabricSlice::deliver`] recording the route into `path` (see
    /// [`Fabric::deliver_traced`]).
    pub fn deliver_traced(
        &self,
        from: usize,
        to: usize,
        bytes: usize,
        depart: VirtTime,
        path: &mut DeliverPath,
    ) -> VirtTime {
        self.fabric
            .deliver_traced(self.base + from, self.base + to, bytes, depart, path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric_8x4() -> Fabric {
        Fabric::new(
            Topology::new(8, 4).unwrap(),
            LinkModel::new(1e-6, 100e9),
            LinkModel::new(10e-6, 10e9),
        )
    }

    /// 32 ranks: 2 GPUs/node, 4 nodes/rack, 4 racks; fast NICs, slow
    /// shared rack uplinks.
    fn fabric_tiered() -> Fabric {
        Fabric::tiered(
            TierTree::new(32, &[2, 4, 4]).unwrap(),
            LinkModel::new(1e-6, 100e9),
            LinkModel::new(10e-6, 10e9),
            vec![LinkModel::new(20e-6, 5e9)],
        )
    }

    #[test]
    fn intranode_has_no_contention() {
        let f = fabric_8x4();
        let n = 10_000_000;
        let t1 = f.deliver(0, 1, n, VirtTime::ZERO);
        let t2 = f.deliver(2, 3, n, VirtTime::ZERO);
        // Both pairs get full bandwidth simultaneously.
        assert_eq!(t1, t2);
        let expect = 1e-6 + n as f64 / 100e9;
        assert!((t1.as_secs() - expect).abs() < 1e-12);
    }

    #[test]
    fn per_gpu_nics_do_not_contend_across_ranks() {
        // Perlmutter-like default: each GPU has its own NIC.
        let f = fabric_8x4();
        let n = 10_000_000;
        let a1 = f.deliver(0, 4, n, VirtTime::ZERO);
        let a2 = f.deliver(1, 5, n, VirtTime::ZERO);
        assert_eq!(a1, a2);
    }

    #[test]
    fn shared_nic_mode_contends() {
        let f = Fabric::with_nics(
            Topology::new(8, 4).unwrap(),
            LinkModel::new(1e-6, 100e9),
            LinkModel::new(10e-6, 10e9),
            1,
        );
        let n = 10_000_000; // 1 ms serialization at 10 GB/s
        let a1 = f.deliver(0, 4, n, VirtTime::ZERO);
        let a2 = f.deliver(1, 5, n, VirtTime::ZERO);
        // Second message queues behind the first on the node NIC.
        assert!(a2.as_secs() > a1.as_secs() + 0.9e-3, "a1={a1} a2={a2}");
    }

    #[test]
    fn same_rank_messages_serialize_on_its_nic() {
        let f = fabric_8x4();
        let n = 10_000_000;
        let a1 = f.deliver(0, 4, n, VirtTime::ZERO);
        let a2 = f.deliver(0, 5, n, VirtTime::ZERO);
        assert!(a2 > a1);
    }

    #[test]
    fn internode_arrival_is_cut_through() {
        let f = fabric_8x4();
        let n = 10_000_000;
        let ser = n as f64 / 10e9;
        let t = f.deliver(0, 4, n, VirtTime::ZERO);
        // Cut-through: one serialization + wire latency.
        assert!((t.as_secs() - (ser + 10e-6)).abs() < 1e-12);
    }

    #[test]
    fn intra_rack_messages_skip_the_uplink() {
        let f = fabric_tiered();
        let n = 10_000_000;
        // Ranks 0 and 2: different nodes, same rack (ranks 0..8).
        let t = f.deliver(0, 2, n, VirtTime::ZERO);
        // NIC-bound arrival, no 5 GB/s uplink serialization.
        let nic_only = n as f64 / 10e9 + 10e-6;
        assert!((t.as_secs() - nic_only).abs() < 1e-12, "{t}");
    }

    #[test]
    fn cross_rack_senders_contend_for_the_rack_uplink() {
        let f = fabric_tiered();
        let n = 10_000_000; // 2 ms serialization at 5 GB/s
        // Ranks 0 and 2 are on different nodes (own NICs) in rack 0;
        // both send cross-rack: the rack-0 egress uplink serializes.
        let a1 = f.deliver(0, 8, n, VirtTime::ZERO);
        let a2 = f.deliver(2, 16, n, VirtTime::ZERO);
        let (first, second) = if a1 < a2 { (a1, a2) } else { (a2, a1) };
        assert!(
            second.as_secs() > first.as_secs() + 1.9e-3,
            "uplink must serialize: {first} then {second}"
        );
        // The same pair of sends stays parallel on a 2-tier fabric of
        // identical NICs.
        let flat = Fabric::new(
            Topology::new(32, 2).unwrap(),
            LinkModel::new(1e-6, 100e9),
            LinkModel::new(10e-6, 10e9),
        );
        assert_eq!(
            flat.deliver(0, 8, n, VirtTime::ZERO),
            flat.deliver(2, 16, n, VirtTime::ZERO)
        );
    }

    #[test]
    fn cross_rack_arrival_includes_uplink_serialization() {
        let f = fabric_tiered();
        let n = 10_000_000;
        let t = f.deliver(0, 8, n, VirtTime::ZERO);
        // The slowest stage (5 GB/s uplink) dominates: ≥ 2 ms.
        assert!(t.as_secs() >= n as f64 / 5e9, "{t}");
        // And the latencies of both crossed links are paid.
        assert!(t.as_secs() >= n as f64 / 5e9 + 10e-6 + 20e-6, "{t}");
    }

    #[test]
    fn reset_clears_contention() {
        let f = fabric_tiered();
        let n = 10_000_000;
        let t1 = f.deliver(0, 8, n, VirtTime::ZERO);
        f.reset();
        let t2 = f.deliver(0, 8, n, VirtTime::ZERO);
        assert_eq!(t1, t2);
    }

    #[test]
    fn clones_share_nic_state() {
        let f = fabric_8x4();
        let g = f.clone();
        let n = 10_000_000;
        let t1 = f.deliver(0, 4, n, VirtTime::ZERO);
        // Same source rank through a clone: shares the NIC timeline.
        let t2 = g.deliver(0, 5, n, VirtTime::ZERO);
        assert!(t2 > t1);
    }

    #[test]
    fn depart_time_is_respected() {
        let f = fabric_8x4();
        let t = f.deliver(0, 1, 0, VirtTime::secs(1.0));
        assert!(t.as_secs() >= 1.0);
    }

    #[test]
    fn slice_window_maps_logical_to_physical() {
        // Two 16-rank tenants on a 32-rank physical fabric: tenant B's
        // logical rank 0 is physical leaf 16.
        let f = fabric_tiered();
        let tenant_tree = TierTree::new(16, &[2, 4, 2]).unwrap();
        let a = FabricSlice::window(f.clone(), 0, tenant_tree.clone());
        let b = FabricSlice::window(f.clone(), 16, tenant_tree);
        assert_eq!(a.topology().ranks(), 16);
        assert_eq!(b.base(), 16);
        let n = 10_000_000;
        // Tenant-internal cross-node sends use disjoint physical NICs →
        // no contention between the two windows at the NIC stage.
        let t_a = a.deliver(0, 2, n, VirtTime::ZERO);
        let t_b = b.deliver(0, 2, n, VirtTime::ZERO);
        assert_eq!(t_a, t_b);
        // Same logical send through a whole-fabric identity slice on a
        // fresh fabric, from the same physical leaves: identical
        // arrival.
        let whole = FabricSlice::whole(fabric_tiered());
        let t_w = whole.deliver(16, 18, n, VirtTime::ZERO);
        assert_eq!(t_w, t_b);
    }

    #[test]
    #[should_panic(expected = "exceeds physical fabric")]
    fn slice_window_must_fit() {
        let f = fabric_tiered();
        let _ = FabricSlice::window(f, 24, TierTree::new(16, &[2, 4, 2]).unwrap());
    }
}
