//! The fabric: routes virtual-time messages through link models and
//! per-node NIC contention.

use std::sync::Arc;

use crate::sim::{SharedTimeline, VirtTime};

use super::link::{LinkClass, LinkModel};
use super::topology::Topology;

/// Network fabric for one simulated cluster.
///
/// Internode messages serialize on the sender's egress NIC and the
/// receiver's ingress NIC; intranode messages ride NVLink/NVSwitch and
/// see no NIC contention. The number of NICs per node is configurable:
/// the paper's testbed is Perlmutter-like (4 A100 + 4 Slingshot-10
/// NICs per node → one NIC per GPU, the default); setting
/// `nics_per_node = 1` reproduces a shared-NIC cluster.
///
/// Delivery is cut-through: the ingress NIC starts receiving `alpha`
/// after the egress starts transmitting, so an uncontended transfer
/// costs `alpha + bytes/beta`, not twice the serialization.
#[derive(Debug, Clone)]
pub struct Fabric {
    topo: Topology,
    intranode: LinkModel,
    internode: LinkModel,
    nics_per_node: usize,
    /// Egress NIC timelines, `nodes × nics_per_node`.
    nic_tx: Arc<Vec<SharedTimeline>>,
    /// Ingress NIC timelines, `nodes × nics_per_node`.
    nic_rx: Arc<Vec<SharedTimeline>>,
}

impl Fabric {
    /// Build a fabric over `topo` with the given link models and one
    /// NIC per GPU (Perlmutter-like).
    pub fn new(topo: Topology, intranode: LinkModel, internode: LinkModel) -> Self {
        let nics = topo.gpus_per_node();
        Self::with_nics(topo, intranode, internode, nics)
    }

    /// Build a fabric with an explicit NIC count per node.
    pub fn with_nics(
        topo: Topology,
        intranode: LinkModel,
        internode: LinkModel,
        nics_per_node: usize,
    ) -> Self {
        assert!(nics_per_node > 0);
        let n = topo.nodes() * nics_per_node;
        Fabric {
            topo,
            intranode,
            internode,
            nics_per_node,
            nic_tx: Arc::new((0..n).map(|_| SharedTimeline::new()).collect()),
            nic_rx: Arc::new((0..n).map(|_| SharedTimeline::new()).collect()),
        }
    }

    /// NIC index serving `rank`.
    fn nic_of(&self, rank: usize) -> usize {
        self.topo.node_of(rank) * self.nics_per_node
            + self.topo.local_of(rank) % self.nics_per_node
    }

    /// Fabric with paper-testbed defaults (NVLink intranode,
    /// Slingshot-10 internode).
    pub fn default_cluster(topo: Topology) -> Self {
        Self::new(
            topo,
            LinkModel::nvlink_default(),
            LinkModel::slingshot10_default(),
        )
    }

    /// The topology this fabric spans.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Link class used between two ranks.
    pub fn link_class(&self, from: usize, to: usize) -> LinkClass {
        if self.topo.same_node(from, to) {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }

    /// Model parameters of a link class.
    pub fn link_model(&self, class: LinkClass) -> LinkModel {
        match class {
            LinkClass::IntraNode => self.intranode,
            LinkClass::InterNode => self.internode,
            LinkClass::Pcie => LinkModel::pcie_default(),
        }
    }

    /// Compute the arrival time of `bytes` sent from `from` to `to`,
    /// departing (earliest) at `depart`. Reserves NIC slots as a side
    /// effect, so concurrent senders on a node contend.
    pub fn deliver(&self, from: usize, to: usize, bytes: usize, depart: VirtTime) -> VirtTime {
        match self.link_class(from, to) {
            LinkClass::IntraNode => depart + self.intranode.transfer_time(bytes),
            LinkClass::InterNode => {
                let ser = self.internode.serialization_time(bytes);
                let tx = &self.nic_tx[self.nic_of(from)];
                let (tx_start, _) = tx.reserve(depart, ser);
                // Cut-through: ingress follows egress by the wire
                // latency, overlapping the serialization.
                let rx = &self.nic_rx[self.nic_of(to)];
                let (_, rx_end) = rx.reserve(tx_start + self.internode.alpha, ser);
                rx_end
            }
            LinkClass::Pcie => unreachable!("PCIe handled by the GPU model"),
        }
    }

    /// Total busy seconds across all egress NICs (diagnostic).
    pub fn nic_tx_busy_total(&self) -> f64 {
        self.nic_tx.iter().map(|t| t.busy_total()).sum()
    }

    /// Reset all NIC timelines (between runs).
    pub fn reset(&self) {
        for t in self.nic_tx.iter().chain(self.nic_rx.iter()) {
            t.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric_8x4() -> Fabric {
        Fabric::new(
            Topology::new(8, 4).unwrap(),
            LinkModel::new(1e-6, 100e9),
            LinkModel::new(10e-6, 10e9),
        )
    }

    #[test]
    fn intranode_has_no_contention() {
        let f = fabric_8x4();
        let n = 10_000_000;
        let t1 = f.deliver(0, 1, n, VirtTime::ZERO);
        let t2 = f.deliver(2, 3, n, VirtTime::ZERO);
        // Both pairs get full bandwidth simultaneously.
        assert_eq!(t1, t2);
        let expect = 1e-6 + n as f64 / 100e9;
        assert!((t1.as_secs() - expect).abs() < 1e-12);
    }

    #[test]
    fn per_gpu_nics_do_not_contend_across_ranks() {
        // Perlmutter-like default: each GPU has its own NIC.
        let f = fabric_8x4();
        let n = 10_000_000;
        let a1 = f.deliver(0, 4, n, VirtTime::ZERO);
        let a2 = f.deliver(1, 5, n, VirtTime::ZERO);
        assert_eq!(a1, a2);
    }

    #[test]
    fn shared_nic_mode_contends() {
        let f = Fabric::with_nics(
            Topology::new(8, 4).unwrap(),
            LinkModel::new(1e-6, 100e9),
            LinkModel::new(10e-6, 10e9),
            1,
        );
        let n = 10_000_000; // 1 ms serialization at 10 GB/s
        let a1 = f.deliver(0, 4, n, VirtTime::ZERO);
        let a2 = f.deliver(1, 5, n, VirtTime::ZERO);
        // Second message queues behind the first on the node NIC.
        assert!(a2.as_secs() > a1.as_secs() + 0.9e-3, "a1={a1} a2={a2}");
    }

    #[test]
    fn same_rank_messages_serialize_on_its_nic() {
        let f = fabric_8x4();
        let n = 10_000_000;
        let a1 = f.deliver(0, 4, n, VirtTime::ZERO);
        let a2 = f.deliver(0, 5, n, VirtTime::ZERO);
        assert!(a2 > a1);
    }

    #[test]
    fn internode_arrival_is_cut_through() {
        let f = fabric_8x4();
        let n = 10_000_000;
        let ser = n as f64 / 10e9;
        let t = f.deliver(0, 4, n, VirtTime::ZERO);
        // Cut-through: one serialization + wire latency.
        assert!((t.as_secs() - (ser + 10e-6)).abs() < 1e-12);
    }

    #[test]
    fn reset_clears_contention() {
        let f = fabric_8x4();
        let n = 10_000_000;
        let t1 = f.deliver(0, 4, n, VirtTime::ZERO);
        f.reset();
        let t2 = f.deliver(0, 4, n, VirtTime::ZERO);
        assert_eq!(t1, t2);
    }

    #[test]
    fn clones_share_nic_state() {
        let f = fabric_8x4();
        let g = f.clone();
        let n = 10_000_000;
        let t1 = f.deliver(0, 4, n, VirtTime::ZERO);
        // Same source rank through a clone: shares the NIC timeline.
        let t2 = g.deliver(0, 5, n, VirtTime::ZERO);
        assert!(t2 > t1);
    }

    #[test]
    fn depart_time_is_respected() {
        let f = fabric_8x4();
        let t = f.deliver(0, 1, 0, VirtTime::secs(1.0));
        assert!(t.as_secs() >= 1.0);
    }
}
