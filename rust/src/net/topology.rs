//! Rank ↔ node layout.

use crate::error::{Error, Result};

/// Physical layout of ranks onto nodes.
///
/// Ranks are laid out block-wise (ranks `[k*g, (k+1)*g)` live on node
/// `k`, `g` = GPUs per node), matching how MPI launchers place ranks on
/// GPU clusters and how the paper counts "8 GPUs = minimum for both
/// internode and intranode communication".
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    ranks: usize,
    gpus_per_node: usize,
}

impl Topology {
    /// Build a topology of `ranks` total GPUs with `gpus_per_node` each.
    ///
    /// `ranks` need not be a multiple of `gpus_per_node` (the last node
    /// may be partially filled), but both must be non-zero.
    pub fn new(ranks: usize, gpus_per_node: usize) -> Result<Self> {
        if ranks == 0 {
            return Err(Error::config("topology: ranks must be > 0"));
        }
        if gpus_per_node == 0 {
            return Err(Error::config("topology: gpus_per_node must be > 0"));
        }
        Ok(Topology {
            ranks,
            gpus_per_node,
        })
    }

    /// Total number of ranks (= GPUs).
    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// GPUs per node.
    pub fn gpus_per_node(&self) -> usize {
        self.gpus_per_node
    }

    /// Number of nodes (ceiling division).
    pub fn nodes(&self) -> usize {
        self.ranks.div_ceil(self.gpus_per_node)
    }

    /// Node that hosts `rank`.
    pub fn node_of(&self, rank: usize) -> usize {
        debug_assert!(rank < self.ranks);
        rank / self.gpus_per_node
    }

    /// Local GPU index of `rank` on its node.
    pub fn local_of(&self, rank: usize) -> usize {
        rank % self.gpus_per_node
    }

    /// Whether two ranks share a node (→ NVLink path, no NIC involved).
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// The leader (lowest rank) of the node hosting `rank`. Hierarchical
    /// collectives elect this rank to run the internode leg.
    pub fn leader_of(&self, rank: usize) -> usize {
        self.node_of(rank) * self.gpus_per_node
    }

    /// Whether `rank` is its node's leader.
    pub fn is_leader(&self, rank: usize) -> bool {
        self.local_of(rank) == 0
    }

    /// The leader rank of node `node` (node indices are `0..nodes()`).
    pub fn leader_of_node(&self, node: usize) -> usize {
        debug_assert!(node < self.nodes());
        node * self.gpus_per_node
    }

    /// The rank range hosted on node `node` (the last node may be
    /// partially filled).
    pub fn node_ranks(&self, node: usize) -> std::ops::Range<usize> {
        let start = node * self.gpus_per_node;
        start..((node + 1) * self.gpus_per_node).min(self.ranks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_blockwise() {
        let t = Topology::new(8, 4).unwrap();
        assert_eq!(t.nodes(), 2);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert_eq!(t.local_of(5), 1);
        assert!(t.same_node(0, 3));
        assert!(!t.same_node(3, 4));
    }

    #[test]
    fn partial_last_node() {
        let t = Topology::new(10, 4).unwrap();
        assert_eq!(t.nodes(), 3);
        assert_eq!(t.node_of(9), 2);
    }

    #[test]
    fn paper_testbed_shape() {
        // 512 GPUs over 128 nodes, 4 GPUs each.
        let t = Topology::new(512, 4).unwrap();
        assert_eq!(t.nodes(), 128);
        assert!(t.same_node(508, 511));
        assert!(!t.same_node(3, 4));
    }

    #[test]
    fn zero_args_rejected() {
        assert!(Topology::new(0, 4).is_err());
        assert!(Topology::new(4, 0).is_err());
    }

    #[test]
    fn leaders_and_node_ranges() {
        let t = Topology::new(10, 4).unwrap();
        assert!(t.is_leader(0) && t.is_leader(4) && t.is_leader(8));
        assert!(!t.is_leader(3) && !t.is_leader(9));
        assert_eq!(t.leader_of(6), 4);
        assert_eq!(t.leader_of_node(2), 8);
        assert_eq!(t.node_ranks(1), 4..8);
        // Partial last node: only ranks 8 and 9.
        assert_eq!(t.node_ranks(2), 8..10);
        // Every node has a valid leader even when partially filled.
        for node in 0..t.nodes() {
            let l = t.leader_of_node(node);
            assert!(l < t.ranks());
            assert_eq!(t.node_of(l), node);
        }
    }

    #[test]
    fn single_node_cluster() {
        let t = Topology::new(4, 4).unwrap();
        assert_eq!(t.nodes(), 1);
        for a in 0..4 {
            for b in 0..4 {
                assert!(t.same_node(a, b));
            }
        }
    }
}
