//! Cluster network model.
//!
//! The paper's testbed is 128 nodes × 4 A100s, HPE Slingshot-10 (100
//! Gbps per node NIC) internode, NVLink intranode. We model:
//!
//! * [`Topology`] — rank ↔ (node, local GPU) layout,
//! * [`LinkModel`] — α–β cost per message class (latency + serialization),
//! * [`Fabric`] — per-node NIC contention via shared timelines, and the
//!   arrival-time computation used by the coordinator's send path.
//!
//! Intranode transfers (GPU↔GPU over NVLink/NVSwitch) do not touch the
//! NIC; internode transfers serialize on both the sender's and the
//! receiver's node NIC, which is exactly the effect that makes 4
//! GPUs/node contend for 12.5 GB/s and makes message-volume reduction
//! (compression) so profitable in the paper.

pub mod fabric;
pub mod link;
pub mod topology;

pub use fabric::{DeliverPath, Fabric, FabricSlice, Hop};
pub use link::{default_uplinks, LinkClass, LinkModel};
pub use topology::Topology;
