//! Per-rank host clock.
//!
//! Each rank owns a [`RankClock`]: the virtual timestamp its *host*
//! thread has reached, plus the phase accumulator. Device-side work
//! (kernels on streams, NIC transfers) advances *timelines*, not the
//! host clock; the host clock only advances when the host blocks (API
//! call cost, synchronization, blocking recv).

use super::phase::{Breakdown, Phase};
use super::time::VirtTime;

/// A rank's host clock + phase accounting.
#[derive(Debug, Clone, Default)]
pub struct RankClock {
    now: VirtTime,
    breakdown: Breakdown,
}

impl RankClock {
    /// A clock at time zero with an empty breakdown.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current host time.
    pub fn now(&self) -> VirtTime {
        self.now
    }

    /// Advance the host clock by `dur`, charging `phase`.
    pub fn advance(&mut self, phase: Phase, dur: f64) -> VirtTime {
        debug_assert!(dur >= 0.0);
        self.now += dur;
        self.breakdown.charge(phase, dur);
        self.now
    }

    /// Block the host until `t` (no-op if already past); the waiting gap
    /// is *not* charged to any phase — use [`RankClock::wait_charged`]
    /// when the wait itself is attributable (e.g. blocking on comm).
    pub fn wait_until(&mut self, t: VirtTime) -> VirtTime {
        self.now = self.now.join(t);
        self.now
    }

    /// Block until `t`, charging the waited gap to `phase`.
    pub fn wait_charged(&mut self, phase: Phase, t: VirtTime) -> VirtTime {
        let gap = t.since(self.now);
        if gap > 0.0 {
            self.breakdown.charge(phase, gap);
        }
        self.now = self.now.join(t);
        self.now
    }

    /// Charge `dur` to `phase` without advancing the host clock (device-
    /// side busy time that overlaps host progress).
    pub fn charge_only(&mut self, phase: Phase, dur: f64) {
        self.breakdown.charge(phase, dur);
    }

    /// The accumulated phase breakdown.
    pub fn breakdown(&self) -> Breakdown {
        self.breakdown
    }

    /// Reset to time zero and clear the breakdown.
    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_moves_clock_and_charges() {
        let mut c = RankClock::new();
        c.advance(Phase::Other, 1.0);
        c.advance(Phase::Cpr, 2.0);
        assert_eq!(c.now(), VirtTime::secs(3.0));
        assert_eq!(c.breakdown().cpr, 2.0);
        assert_eq!(c.breakdown().other, 1.0);
    }

    #[test]
    fn wait_until_never_goes_backwards() {
        let mut c = RankClock::new();
        c.advance(Phase::Other, 5.0);
        c.wait_until(VirtTime::secs(2.0));
        assert_eq!(c.now(), VirtTime::secs(5.0));
        c.wait_until(VirtTime::secs(7.0));
        assert_eq!(c.now(), VirtTime::secs(7.0));
    }

    #[test]
    fn wait_charged_charges_only_the_gap() {
        let mut c = RankClock::new();
        c.advance(Phase::Other, 1.0);
        c.wait_charged(Phase::Comm, VirtTime::secs(3.0));
        assert_eq!(c.breakdown().comm, 2.0);
        // Already past: nothing charged.
        c.wait_charged(Phase::Comm, VirtTime::secs(2.0));
        assert_eq!(c.breakdown().comm, 2.0);
    }

    #[test]
    fn charge_only_leaves_clock() {
        let mut c = RankClock::new();
        c.charge_only(Phase::Redu, 4.0);
        assert_eq!(c.now(), VirtTime::ZERO);
        assert_eq!(c.breakdown().redu, 4.0);
    }
}
