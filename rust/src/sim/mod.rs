//! Virtual-time simulation core.
//!
//! gZCCL's collectives run as *real* code (real bytes move between rank
//! threads, real compressors run) while *time* is virtual: every
//! operation charges a modeled duration to a resource timeline (a GPU
//! stream, a PCIe engine, a NIC). This module provides the primitives:
//!
//! * [`VirtTime`] — an `f64` seconds wrapper with explicit semantics,
//! * [`Timeline`] — a busy-until scalar resource (stream / NIC / engine),
//! * [`Phase`] / [`Breakdown`] — per-phase accounting matching the
//!   paper's CPR / COMM / DATAMOVE / REDU / OTHERS breakdown (Fig. 2,
//!   Table 2),
//! * [`RankClock`] — a rank's host clock plus its phase accumulator.
//!
//! The semantics are those of a conservative parallel discrete-event
//! simulation: ranks only ever *join* on timestamps they have received
//! (`max`), so causality cannot be violated.

pub mod clock;
pub mod phase;
pub mod time;
pub mod timeline;

pub use clock::RankClock;
pub use phase::{Breakdown, Phase};
pub use time::VirtTime;
pub use timeline::{SharedTimeline, Timeline};
