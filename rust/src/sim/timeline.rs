//! Resource timelines.
//!
//! A [`Timeline`] models a serially-reusable resource (a GPU stream, a
//! PCIe copy engine, a node NIC): it is busy until some timestamp, and a
//! new operation that becomes *ready* at `ready` actually *starts* at
//! `max(ready, busy_until)`. This single primitive gives us overlap,
//! pipelining, and contention for free.

use std::sync::{Arc, Mutex};

use super::time::VirtTime;

/// A single serially-reusable virtual resource.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    busy_until: VirtTime,
    /// Total busy time accumulated on this resource.
    busy_total: f64,
}

impl Timeline {
    /// A timeline that is free from time zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve the resource for `dur` seconds, not starting before
    /// `ready`. Returns `(start, end)` of the granted slot.
    ///
    /// A non-positive (or NaN) `dur` is clamped to zero in **all**
    /// build profiles: a negative duration would silently rewind
    /// `busy_until` and corrupt `busy_total` in release builds.
    pub fn reserve(&mut self, ready: VirtTime, dur: f64) -> (VirtTime, VirtTime) {
        let dur = dur.max(0.0);
        let start = ready.join(self.busy_until);
        let end = start + dur;
        self.busy_until = end;
        self.busy_total += dur;
        (start, end)
    }

    /// Timestamp at which the resource becomes free.
    pub fn busy_until(&self) -> VirtTime {
        self.busy_until
    }

    /// Total busy seconds accumulated.
    pub fn busy_total(&self) -> f64 {
        self.busy_total
    }

    /// Reset to free-at-zero (reused between runs).
    pub fn reset(&mut self) {
        self.busy_until = VirtTime::ZERO;
        self.busy_total = 0.0;
    }
}

/// An interval-allocating timeline with gap filling.
///
/// Rank threads progress through *virtual* time at different *wall*
/// speeds, so reservation requests arrive out of virtual-time order. A
/// high-water-mark timeline would queue an early-virtual-time message
/// behind a future round reserved by a faster thread — wildly inflating
/// latencies. This timeline instead allocates the earliest free
/// interval at-or-after `ready`, which makes the schedule insensitive
/// to wall-clock arrival order (up to ties). Used for NICs, where
/// packet interleaving is physical; per-rank GPU streams keep the FIFO
/// [`Timeline`] since their issue order *is* causal order.
#[derive(Debug, Clone, Default)]
pub struct IntervalTimeline {
    /// Sorted, non-overlapping (start, end) allocations.
    intervals: Vec<(f64, f64)>,
    busy_total: f64,
}

impl IntervalTimeline {
    /// An empty timeline.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve `dur` seconds at the earliest free slot ≥ `ready`.
    /// Non-positive (or NaN) durations are clamped to zero in all build
    /// profiles — see [`Timeline::reserve`].
    pub fn reserve(&mut self, ready: VirtTime, dur: f64) -> (VirtTime, VirtTime) {
        let dur = dur.max(0.0);
        let mut t = ready.as_secs();
        let mut pos = self.intervals.len();
        for (i, &(s, e)) in self.intervals.iter().enumerate() {
            if t + dur <= s {
                // Fits entirely in the gap before interval i.
                pos = i;
                break;
            }
            if e > t {
                t = e;
            }
        }
        self.intervals.insert(pos, (t, t + dur));
        self.busy_total += dur;
        (VirtTime::secs(t), VirtTime::secs(t + dur))
    }

    /// Latest allocated end (0 if empty).
    pub fn busy_until(&self) -> VirtTime {
        VirtTime::secs(self.intervals.last().map(|&(_, e)| e).unwrap_or(0.0))
    }

    /// Total busy seconds accumulated.
    pub fn busy_total(&self) -> f64 {
        self.busy_total
    }

    /// Reset to empty.
    pub fn reset(&mut self) {
        self.intervals.clear();
        self.busy_total = 0.0;
    }
}

/// A timeline shared between rank threads (e.g. the per-node NIC that
/// all four GPUs of a node contend on). Interior mutability + lock.
/// Uses interval allocation — see [`IntervalTimeline`].
#[derive(Debug, Clone, Default)]
pub struct SharedTimeline {
    inner: Arc<Mutex<IntervalTimeline>>,
}

impl SharedTimeline {
    /// A shared timeline that is free from time zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve a slot; see [`Timeline::reserve`].
    pub fn reserve(&self, ready: VirtTime, dur: f64) -> (VirtTime, VirtTime) {
        self.inner.lock().unwrap().reserve(ready, dur)
    }

    /// Timestamp at which the resource becomes free.
    pub fn busy_until(&self) -> VirtTime {
        self.inner.lock().unwrap().busy_until()
    }

    /// Total busy seconds accumulated.
    pub fn busy_total(&self) -> f64 {
        self.inner.lock().unwrap().busy_total()
    }

    /// Reset to free-at-zero.
    pub fn reset(&self) {
        self.inner.lock().unwrap().reset()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn back_to_back_reservations_serialize() {
        let mut t = Timeline::new();
        let (s1, e1) = t.reserve(VirtTime::ZERO, 1.0);
        assert_eq!(s1, VirtTime::ZERO);
        assert_eq!(e1, VirtTime::secs(1.0));
        // Ready at 0.5 but the resource is busy until 1.0.
        let (s2, e2) = t.reserve(VirtTime::secs(0.5), 1.0);
        assert_eq!(s2, VirtTime::secs(1.0));
        assert_eq!(e2, VirtTime::secs(2.0));
    }

    #[test]
    fn idle_gap_is_respected() {
        let mut t = Timeline::new();
        t.reserve(VirtTime::ZERO, 1.0);
        // Ready long after the resource frees: starts at ready.
        let (s, e) = t.reserve(VirtTime::secs(5.0), 0.25);
        assert_eq!(s, VirtTime::secs(5.0));
        assert_eq!(e, VirtTime::secs(5.25));
        assert!((t.busy_total() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn shared_timeline_contends_across_clones() {
        let t = SharedTimeline::new();
        let t2 = t.clone();
        t.reserve(VirtTime::ZERO, 2.0);
        let (s, _) = t2.reserve(VirtTime::ZERO, 1.0);
        assert_eq!(s, VirtTime::secs(2.0));
    }

    #[test]
    fn reset_clears_state() {
        let mut t = Timeline::new();
        t.reserve(VirtTime::ZERO, 3.0);
        t.reset();
        assert_eq!(t.busy_until(), VirtTime::ZERO);
        assert_eq!(t.busy_total(), 0.0);
    }

    #[test]
    fn negative_duration_is_clamped_not_rewound() {
        // Regression: a negative `dur` must not rewind `busy_until` or
        // corrupt `busy_total` — in any build profile.
        let mut t = Timeline::new();
        t.reserve(VirtTime::ZERO, 2.0);
        let (s, e) = t.reserve(VirtTime::ZERO, -5.0);
        assert_eq!(s, VirtTime::secs(2.0));
        assert_eq!(e, VirtTime::secs(2.0));
        assert_eq!(t.busy_until(), VirtTime::secs(2.0));
        assert!((t.busy_total() - 2.0).abs() < 1e-12);
        // NaN is treated as zero too.
        let (s, e) = t.reserve(VirtTime::secs(3.0), f64::NAN);
        assert_eq!(s, e);

        let mut it = IntervalTimeline::new();
        it.reserve(VirtTime::ZERO, 1.0);
        let (s, e) = it.reserve(VirtTime::ZERO, -1.0);
        assert_eq!(s, e);
        assert!((it.busy_total() - 1.0).abs() < 1e-12);
        assert_eq!(it.busy_until(), VirtTime::secs(1.0));
    }

    #[test]
    fn interval_timeline_gap_fills_out_of_order() {
        let mut t = IntervalTimeline::new();
        // A fast thread reserves a future slot first.
        let (s1, _) = t.reserve(VirtTime::secs(10.0), 1.0);
        assert_eq!(s1, VirtTime::secs(10.0));
        // A slower thread then asks for an earlier slot: must NOT queue
        // behind the future reservation.
        let (s2, e2) = t.reserve(VirtTime::secs(0.0), 1.0);
        assert_eq!(s2, VirtTime::ZERO);
        assert_eq!(e2, VirtTime::secs(1.0));
        // A request overlapping an allocation is pushed after it.
        let (s3, _) = t.reserve(VirtTime::secs(0.5), 1.0);
        assert_eq!(s3, VirtTime::secs(1.0));
        assert!((t.busy_total() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn interval_timeline_exact_gap_fit() {
        let mut t = IntervalTimeline::new();
        t.reserve(VirtTime::secs(0.0), 1.0);
        t.reserve(VirtTime::secs(3.0), 1.0);
        // A 2-second job fits exactly in [1, 3).
        let (s, e) = t.reserve(VirtTime::secs(0.0), 2.0);
        assert_eq!(s, VirtTime::secs(1.0));
        assert_eq!(e, VirtTime::secs(3.0));
        // Nothing fits in a 0-gap; goes to the end.
        let (s, _) = t.reserve(VirtTime::secs(0.0), 0.5);
        assert_eq!(s, VirtTime::secs(4.0));
    }

    #[test]
    fn shared_timeline_threads_serialize() {
        use std::thread;
        let t = SharedTimeline::new();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let t = t.clone();
                thread::spawn(move || t.reserve(VirtTime::ZERO, 1.0))
            })
            .collect();
        let mut slots: Vec<(f64, f64)> = handles
            .into_iter()
            .map(|h| {
                let (s, e) = h.join().unwrap();
                (s.as_secs(), e.as_secs())
            })
            .collect();
        slots.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        // Slots must tile [0, 8] without overlap.
        for (i, (s, e)) in slots.iter().enumerate() {
            assert!((s - i as f64).abs() < 1e-12);
            assert!((e - (i + 1) as f64).abs() < 1e-12);
        }
        assert!((t.busy_total() - 8.0).abs() < 1e-12);
    }
}
