//! Per-phase time accounting.
//!
//! The paper breaks collective runtime into compression (CPR),
//! communication (COMM), host-device transfer (DATAMOVE), reduction
//! (REDU) and everything else (OTHERS) — Fig. 2 and Table 2. Every
//! modeled operation in the coordinator is tagged with a [`Phase`], and
//! a [`Breakdown`] accumulates busy seconds per phase.

use std::fmt;
use std::ops::{Add, AddAssign};

/// Phase tag for a modeled operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Compression + decompression kernels.
    Cpr,
    /// Network communication (intra- or internode).
    Comm,
    /// Host<->device data movement (PCIe staging).
    DataMove,
    /// Reduction kernels (GPU) or host reduction loops.
    Redu,
    /// Kernel launches, memsets, synchronization, packing, misc.
    Other,
}

impl Phase {
    /// All phases, in the paper's reporting order.
    pub const ALL: [Phase; 5] = [
        Phase::Cpr,
        Phase::Comm,
        Phase::DataMove,
        Phase::Redu,
        Phase::Other,
    ];

    /// Short label used in tables.
    pub fn label(self) -> &'static str {
        match self {
            Phase::Cpr => "CPR",
            Phase::Comm => "COMM",
            Phase::DataMove => "DATAMOVE",
            Phase::Redu => "REDU",
            Phase::Other => "OTHERS",
        }
    }
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Accumulated busy seconds per phase for one rank (or aggregated).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Breakdown {
    /// Compression/decompression seconds.
    pub cpr: f64,
    /// Communication seconds.
    pub comm: f64,
    /// Host-device transfer seconds.
    pub datamove: f64,
    /// Reduction seconds.
    pub redu: f64,
    /// Everything else.
    pub other: f64,
}

impl Breakdown {
    /// Zeroed breakdown.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `dur` seconds to `phase`.
    pub fn charge(&mut self, phase: Phase, dur: f64) {
        debug_assert!(dur >= 0.0);
        match phase {
            Phase::Cpr => self.cpr += dur,
            Phase::Comm => self.comm += dur,
            Phase::DataMove => self.datamove += dur,
            Phase::Redu => self.redu += dur,
            Phase::Other => self.other += dur,
        }
    }

    /// Seconds charged to `phase`.
    pub fn get(&self, phase: Phase) -> f64 {
        match phase {
            Phase::Cpr => self.cpr,
            Phase::Comm => self.comm,
            Phase::DataMove => self.datamove,
            Phase::Redu => self.redu,
            Phase::Other => self.other,
        }
    }

    /// Sum over all phases.
    pub fn total(&self) -> f64 {
        self.cpr + self.comm + self.datamove + self.redu + self.other
    }

    /// Fraction of the total charged to `phase` (0 if empty).
    pub fn fraction(&self, phase: Phase) -> f64 {
        let t = self.total();
        if t <= 0.0 {
            0.0
        } else {
            self.get(phase) / t
        }
    }

    /// Render as `CPR 42.6% | COMM 46.3% | ...` percentages.
    pub fn percent_string(&self) -> String {
        Phase::ALL
            .iter()
            .map(|p| format!("{} {:5.2}%", p.label(), 100.0 * self.fraction(*p)))
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

impl Add for Breakdown {
    type Output = Breakdown;
    fn add(self, o: Breakdown) -> Breakdown {
        Breakdown {
            cpr: self.cpr + o.cpr,
            comm: self.comm + o.comm,
            datamove: self.datamove + o.datamove,
            redu: self.redu + o.redu,
            other: self.other + o.other,
        }
    }
}

impl AddAssign for Breakdown {
    fn add_assign(&mut self, o: Breakdown) {
        *self = *self + o;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_and_total() {
        let mut b = Breakdown::new();
        b.charge(Phase::Cpr, 1.0);
        b.charge(Phase::Comm, 2.0);
        b.charge(Phase::Other, 1.0);
        assert_eq!(b.total(), 4.0);
        assert_eq!(b.get(Phase::Comm), 2.0);
        assert!((b.fraction(Phase::Cpr) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_fraction_is_zero() {
        let b = Breakdown::new();
        assert_eq!(b.fraction(Phase::Redu), 0.0);
    }

    #[test]
    fn add_accumulates_fieldwise() {
        let mut a = Breakdown::new();
        a.charge(Phase::Redu, 1.5);
        let mut b = Breakdown::new();
        b.charge(Phase::Redu, 0.5);
        b.charge(Phase::DataMove, 2.0);
        let c = a + b;
        assert_eq!(c.redu, 2.0);
        assert_eq!(c.datamove, 2.0);
        a += b;
        assert_eq!(a, c);
    }

    #[test]
    fn percent_string_mentions_all_phases() {
        let mut b = Breakdown::new();
        b.charge(Phase::Cpr, 1.0);
        let s = b.percent_string();
        for p in Phase::ALL {
            assert!(s.contains(p.label()), "{s} missing {p}");
        }
    }
}
