//! Virtual timestamps.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time, in seconds since the start of a collective.
///
/// Plain `f64` underneath; the wrapper exists so that timestamps and
/// durations cannot be silently mixed with unrelated floats, and so that
/// `max`-join semantics read naturally at call sites.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct VirtTime(pub f64);

impl VirtTime {
    /// Time zero (start of the operation under simulation).
    pub const ZERO: VirtTime = VirtTime(0.0);

    /// Construct from seconds.
    pub fn secs(s: f64) -> Self {
        VirtTime(s)
    }

    /// Construct from microseconds.
    pub fn micros(us: f64) -> Self {
        VirtTime(us * 1e-6)
    }

    /// Seconds as f64.
    pub fn as_secs(self) -> f64 {
        self.0
    }

    /// Microseconds as f64.
    pub fn as_micros(self) -> f64 {
        self.0 * 1e6
    }

    /// Milliseconds as f64.
    pub fn as_millis(self) -> f64 {
        self.0 * 1e3
    }

    /// Join: the later of two timestamps (dependency merge).
    pub fn join(self, other: VirtTime) -> VirtTime {
        VirtTime(self.0.max(other.0))
    }

    /// Saturating difference (returns zero if `other` is later).
    pub fn since(self, other: VirtTime) -> f64 {
        (self.0 - other.0).max(0.0)
    }
}

impl Add<f64> for VirtTime {
    type Output = VirtTime;
    fn add(self, d: f64) -> VirtTime {
        VirtTime(self.0 + d)
    }
}

impl AddAssign<f64> for VirtTime {
    fn add_assign(&mut self, d: f64) {
        self.0 += d;
    }
}

impl Sub<VirtTime> for VirtTime {
    type Output = f64;
    fn sub(self, other: VirtTime) -> f64 {
        self.0 - other.0
    }
}

impl fmt::Display for VirtTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1.0 {
            write!(f, "{:.4}s", self.0)
        } else if self.0 >= 1e-3 {
            write!(f, "{:.3}ms", self.0 * 1e3)
        } else {
            write!(f, "{:.2}us", self.0 * 1e6)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_takes_max() {
        let a = VirtTime::secs(1.0);
        let b = VirtTime::secs(2.0);
        assert_eq!(a.join(b), b);
        assert_eq!(b.join(a), b);
    }

    #[test]
    fn arithmetic() {
        let t = VirtTime::secs(1.0) + 0.5;
        assert!((t.as_secs() - 1.5).abs() < 1e-12);
        assert!((t - VirtTime::secs(1.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn since_saturates() {
        assert_eq!(VirtTime::secs(1.0).since(VirtTime::secs(2.0)), 0.0);
        assert_eq!(VirtTime::secs(2.0).since(VirtTime::secs(0.5)), 1.5);
    }

    #[test]
    fn display_units() {
        assert_eq!(format!("{}", VirtTime::secs(2.5)), "2.5000s");
        assert_eq!(format!("{}", VirtTime::secs(0.002)), "2.000ms");
        assert_eq!(format!("{}", VirtTime::micros(12.0)), "12.00us");
    }

    #[test]
    fn micros_round_trip() {
        let t = VirtTime::micros(123.0);
        assert!((t.as_micros() - 123.0).abs() < 1e-9);
    }
}
