//! Lossy compressors.
//!
//! Two real compressors, matching the two families the paper contrasts:
//!
//! * [`cuszp::CuszpLike`] — **error-bounded** (cuSZp-class): prequant +
//!   integer 1D Lorenzo + per-block fixed-length bit packing. Output
//!   size is data-dependent (unknown ahead of time); pointwise error is
//!   guaranteed ≤ the absolute bound. This is what gZCCL uses.
//! * [`fixed_rate::FixedRate`] — **fixed-rate** (1D-ZFP-class, the
//!   CPRP2P baseline): per-block scaled truncation to a fixed bit
//!   budget. Output size is known ahead of time; error is *unbounded*
//!   (scales with block magnitude), which is exactly the accuracy
//!   hazard the paper attributes to prior work.
//!
//! Both compress real bytes — compression ratios and accuracy results in
//! the experiments are genuine, not modeled. Only GPU *timing* comes
//! from the cost model ([`crate::gpu::KernelModel`]).

pub mod bitpack;
pub mod cuszp;
pub mod fixed_rate;
pub mod profile;

pub use cuszp::CuszpLike;
pub use fixed_rate::FixedRate;
pub use profile::CompressionProfile;

use crate::error::Result;

/// A lossy floating-point compressor.
pub trait Compressor: Send + Sync {
    /// Human-readable name (used in reports).
    fn name(&self) -> &'static str;

    /// Compress `data` into a self-describing byte stream.
    fn compress(&self, data: &[f32]) -> Vec<u8>;

    /// Decompress a stream produced by [`Compressor::compress`].
    fn decompress(&self, stream: &[u8]) -> Result<Vec<f32>>;

    /// Whether the pointwise absolute error is guaranteed bounded.
    fn is_error_bounded(&self) -> bool;

    /// The absolute error bound, if [`Compressor::is_error_bounded`].
    fn error_bound(&self) -> Option<f64>;

    /// Exact output size for `n` input values, if pre-known (fixed-rate
    /// compressors only — this property is what lets CPRP2P pre-post
    /// receives, and what costs it bounded accuracy).
    fn fixed_output_size(&self, n: usize) -> Option<usize>;

    /// A variant of this compressor rebound to a different absolute
    /// error bound — what lets one [`crate::coordinator::RankCtx`] run
    /// different legs of an execution plan at different bounds.
    /// `None` when the family has no per-call bound to rebind
    /// (fixed-rate) or `eb` is not a usable bound.
    fn rebound(&self, eb: f64) -> Option<std::sync::Arc<dyn Compressor>> {
        let _ = eb;
        None
    }
}

/// Compression ratio of a (raw, compressed) pair in bytes.
pub fn ratio(raw_bytes: usize, compressed_bytes: usize) -> f64 {
    if compressed_bytes == 0 {
        f64::INFINITY
    } else {
        raw_bytes as f64 / compressed_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_basics() {
        assert_eq!(ratio(100, 10), 10.0);
        assert_eq!(ratio(100, 0), f64::INFINITY);
    }
}
